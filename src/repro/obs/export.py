"""Streaming export for repro.obs: incremental JSONL telemetry while a
run is still going, plus OpenMetrics text exposition of a metrics
registry.

``Session.snapshot()`` is an end-of-session artifact — useless when the
question is "is the 40-minute adversary search making progress or
wedged?".  :class:`ObsStreamer` appends one JSON object per event to a
file and flushes every write, so ``tail -f telemetry.jsonl`` answers
that live.  Open one through the session::

    with obs.session(mode="metrics", stream="telemetry.jsonl"):
        sim.saturation_sweep(g, "tornado", routing="ugal")   # probes stream
        obs.emit("checkpoint", phase="done")                 # ad-hoc events

``obs.emit(kind, **fields)`` is the instrumentation verb: no-op without
a streaming session (same one-global-read discipline as ``obs.span``).
The pre-wired emitters: ``saturation_sweep`` streams one event per
probe, ``adversary.worst_case`` and ``faults.degradation_sweep`` stream
:class:`Progress` done/total/ETA records, and ``benchmarks/run.py
--stream`` streams section boundaries.

:func:`openmetrics_text` renders a registry (or a snapshot dict) in the
OpenMetrics text format — dots to underscores, ``[variant]`` to a
``variant`` label, counters suffixed ``_total``, histograms as
summaries with quantile labels — so a Prometheus-family scraper can
ingest BENCH telemetry without any new dependency.
"""

from __future__ import annotations

import json
import re
import time

__all__ = ["ObsStreamer", "Progress", "openmetrics_text",
           "write_openmetrics"]

STREAM_SCHEMA = "repro.obs/stream/1"


class ObsStreamer:
    """Append-only JSONL event stream.  The first line is a header with
    the schema tag and the unix start time; every subsequent line is one
    event ``{"kind": ..., "t_s": <seconds since header>, ...fields}``.
    Writes flush immediately (the point is tailing a live file).
    Thread-safe via the file object's own lock + single ``write`` call
    per event."""

    def __init__(self, path: str):
        self.path = path
        self._fh = open(path, "w")
        self._t0 = time.monotonic()
        self._fh.write(json.dumps({"schema": STREAM_SCHEMA,
                                   "t0_unix": time.time()}) + "\n")
        self._fh.flush()
        self.events = 0

    def emit(self, kind: str, **fields) -> None:
        if self._fh is None:
            return
        rec = {"kind": kind, "t_s": round(time.monotonic() - self._t0, 6)}
        for k, v in fields.items():
            if isinstance(v, (str, int, bool)) or v is None:
                rec[k] = v
            else:
                try:
                    rec[k] = float(v)
                except (TypeError, ValueError):
                    rec[k] = str(v)
        self._fh.write(json.dumps(rec) + "\n")
        self._fh.flush()
        self.events += 1

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "ObsStreamer":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class Progress:
    """Done/total/ETA emitter for a counted loop.

    ``step()`` emits a ``progress`` event (label, done, total, pct,
    rate per second, eta_s) through :func:`repro.obs.emit` — free when
    no streaming session is active — and mirrors done/eta into gauges
    (``<label>.done`` / ``<label>.eta_s``) when a session records
    metrics.  ``every`` throttles emission to at most one event per
    that many seconds (0 = every step; loop iterations at probe/trial
    granularity are coarse enough to stream unthrottled)."""

    def __init__(self, label: str, total: int | None = None,
                 every: float = 0.0):
        self.label = label
        self.total = None if total is None else int(total)
        self.every = float(every)
        self.done = 0
        self._t0 = time.monotonic()
        self._last_emit = -1e30

    def step(self, n: int = 1, **fields) -> None:
        self.done += int(n)
        now = time.monotonic()
        if now - self._last_emit < self.every:
            return
        self._last_emit = now
        elapsed = now - self._t0
        rate = self.done / elapsed if elapsed > 0 else 0.0
        rec = {"label": self.label, "done": self.done,
               "elapsed_s": round(elapsed, 3),
               "rate": round(rate, 4)}
        if self.total is not None:
            rec["total"] = self.total
            rec["pct"] = round(100.0 * self.done / max(self.total, 1), 2)
            if rate > 0 and self.done < self.total:
                rec["eta_s"] = round((self.total - self.done) / rate, 1)
        from . import current, emit
        emit("progress", **rec, **fields)
        s = current()
        if s is not None and s.enabled:
            s.metrics.gauge(f"{self.label}.done").set(float(self.done))
            if "eta_s" in rec:
                s.metrics.gauge(f"{self.label}.eta_s").set(rec["eta_s"])


# -- OpenMetrics text exposition ------------------------------------------

_VARIANT = re.compile(r"\[([^\]]*)\]")


def _om_name(name: str) -> tuple[str, str | None]:
    """``sim.backend[pallas]`` -> (``repro_sim_backend``, ``pallas``)."""
    variant = None
    m = _VARIANT.search(name)
    if m:
        variant = m.group(1)
        name = name[:m.start()] + name[m.end():]
    name = re.sub(r"[^a-zA-Z0-9_:]", "_", name.replace(".", "_"))
    return "repro_" + name.strip("_"), variant


def _om_value(v: float) -> str:
    return repr(float(v))


def openmetrics_text(metrics) -> str:
    """Render a metrics collection as OpenMetrics text.

    ``metrics`` is a :class:`MetricsRegistry`, a :class:`Session`, or a
    snapshot dict (``name -> {"type": ..., ...}`` — the ``"metrics"``
    block of ``Session.snapshot()``).  Counters export as ``_total``
    with ``# TYPE counter``; gauges as gauges; histograms and series as
    summaries (quantile labels + ``_count``/``_sum``).  Ends with the
    mandatory ``# EOF``."""
    snap = getattr(metrics, "metrics", metrics)   # Session -> registry
    if hasattr(snap, "snapshot"):                 # registry -> dict
        snap = snap.snapshot()
    if snap is None:
        snap = {}
    lines: list[str] = []
    for name in sorted(snap):
        rec = snap[name]
        om, variant = _om_name(name)
        label = f'{{variant="{variant}"}}' if variant is not None else ""
        kind = rec.get("type")
        if kind == "counter":
            lines.append(f"# TYPE {om} counter")
            lines.append(f"{om}_total{label} {_om_value(rec['value'])}")
        elif kind == "gauge":
            lines.append(f"# TYPE {om} gauge")
            lines.append(f"{om}{label} {_om_value(rec['value'])}")
        elif kind in ("histogram", "series"):
            lines.append(f"# TYPE {om} summary")
            count = int(rec.get("count", 0))
            mean = rec.get("mean", 0.0) if count else 0.0
            for q in ("p50", "p90", "p99"):
                if q in rec:
                    qv = {"p50": "0.5", "p90": "0.9", "p99": "0.99"}[q]
                    if variant is not None:
                        ql = f'{{variant="{variant}",quantile="{qv}"}}'
                    else:
                        ql = f'{{quantile="{qv}"}}'
                    lines.append(f"{om}{ql} {_om_value(rec[q])}")
            lines.append(f"{om}_count{label} {count}")
            lines.append(f"{om}_sum{label} {_om_value(mean * count)}")
    lines.append("# EOF")
    return "\n".join(lines) + "\n"


def write_openmetrics(path: str, metrics) -> None:
    with open(path, "w") as fh:
        fh.write(openmetrics_text(metrics))
