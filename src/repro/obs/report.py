"""Single-file HTML reports for repro.obs: sessions, postmortem
bundles, and BENCH trajectories rendered with inline-SVG sparklines and
bar charts — zero dependencies, one self-contained file, open it
anywhere.

Three section kinds compose into one report:

* **BENCH trajectory** — every ``BENCH_*.json`` under a directory in
  sorted order (the stacked-PR perf trajectory benchmarks/compare.py
  diffs): per-file total-seconds bars, per-entry wall-time and
  ``max_rel_err`` sparklines across the trajectory with last-hop
  deltas, and presence changes.
* **Session** — a ``Session.snapshot()``: balance/stability gauge
  tiles (the paper's balanced-utilization thesis at a glance), the
  span table, counters, histogram summaries, and per-step series
  sparklines when raw curves are supplied (a live session has them;
  a snapshot dict only has summaries).
* **Postmortem bundle** — a watchdog dump: the trigger banner, the
  run context, and the flight recorder's ring-buffer channels as
  sparklines (the last-W steps before the anomaly).

Programmatic::

    from repro.obs import report
    report.render_report("report.html", bench_dir=".",
                         sessions=[("sweep", sess.snapshot(),
                                    report.session_series(sess))],
                         bundles=[obs.load_bundle(path)])

CLI (what scripts/ci.sh and examples/topology_explorer.py call)::

    python -m repro.obs.report -o report.html --bench-dir . \
        --bundle postmortems/postmortem_dest_stability_200.json \
        --session snap.json
"""

from __future__ import annotations

import argparse
import glob as globmod
import html as htmlmod
import json
import os
import sys
import time

__all__ = ["render_report", "html_report", "session_series", "main"]

_CSS = """
body { font-family: -apple-system, 'Segoe UI', Roboto, sans-serif;
       margin: 2em auto; max-width: 72em; color: #1a1a2e; }
h1 { font-size: 1.5em; border-bottom: 2px solid #16213e; }
h2 { font-size: 1.15em; margin-top: 2em; color: #16213e; }
h3 { font-size: 0.95em; margin-bottom: 0.3em; }
table { border-collapse: collapse; font-size: 0.82em; margin: 0.6em 0; }
th, td { padding: 2px 10px; text-align: right; }
th { border-bottom: 1px solid #888; text-align: right; }
td.l, th.l { text-align: left; }
tr:nth-child(even) { background: #f4f5fa; }
.tiles { display: flex; flex-wrap: wrap; gap: 8px; margin: 0.6em 0; }
.tile { border: 1px solid #d0d4e4; border-radius: 6px;
        padding: 6px 12px; background: #fafbff; }
.tile .v { font-size: 1.25em; font-weight: 600; }
.tile .k { font-size: 0.72em; color: #555; }
.spark { vertical-align: middle; }
.banner { border-left: 5px solid #c0392b; background: #fdf0ee;
          padding: 8px 14px; margin: 0.8em 0; font-size: 0.9em; }
.ok { border-left-color: #27ae60; background: #eefbf2; }
.up { color: #c0392b; } .down { color: #27ae60; }
.muted { color: #777; font-size: 0.8em; }
svg { overflow: visible; }
"""


def _esc(s) -> str:
    return htmlmod.escape(str(s))


def _fmt(v, digits: int = 4) -> str:
    if v is None:
        return "—"
    try:
        f = float(v)
    except (TypeError, ValueError):
        return _esc(v)
    if f != f:
        return "nan"
    if f == int(f) and abs(f) < 1e12:
        return str(int(f))
    return f"{f:.{digits}g}"


def _spark(values, w: int = 180, h: int = 30, color: str = "#16213e") -> str:
    """Inline-SVG sparkline of a numeric sequence (empty-safe)."""
    vals = [float(v) for v in values
            if isinstance(v, (int, float)) and float(v) == float(v)]
    if len(vals) < 2:
        return '<span class="muted">·</span>'
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    n = len(vals)
    pts = " ".join(
        f"{(w - 4) * i / (n - 1) + 2:.1f},"
        f"{h - 3 - (h - 6) * (v - lo) / span:.1f}"
        for i, v in enumerate(vals))
    last_y = h - 3 - (h - 6) * (vals[-1] - lo) / span
    return (f'<svg class="spark" width="{w}" height="{h}">'
            f'<polyline points="{pts}" fill="none" stroke="{color}" '
            f'stroke-width="1.3"/>'
            f'<circle cx="{w - 2}" cy="{last_y:.1f}" r="2" '
            f'fill="{color}"/></svg>')


def _bars(items, w: int = 420, color: str = "#3b5bdb") -> str:
    """Horizontal bar chart from ``[(label, value), ...]``."""
    items = [(str(k), float(v)) for k, v in items]
    if not items:
        return '<span class="muted">no data</span>'
    vmax = max((v for _k, v in items), default=0.0) or 1.0
    rowh, lab_w = 18, 180
    h = rowh * len(items) + 4
    parts = [f'<svg width="{w + lab_w + 70}" height="{h}">']
    for i, (k, v) in enumerate(items):
        y = i * rowh + 2
        bw = max(w * v / vmax, 1.0)
        parts.append(
            f'<text x="{lab_w - 6}" y="{y + 12}" text-anchor="end" '
            f'font-size="11">{_esc(k[:28])}</text>'
            f'<rect x="{lab_w}" y="{y + 2}" width="{bw:.1f}" '
            f'height="{rowh - 6}" fill="{color}" rx="2"/>'
            f'<text x="{lab_w + bw + 5:.1f}" y="{y + 12}" '
            f'font-size="11">{_fmt(v)}</text>')
    parts.append("</svg>")
    return "".join(parts)


def _tiles(pairs) -> str:
    """Stat tiles from ``[(label, value), ...]``."""
    cells = "".join(
        f'<div class="tile"><div class="v">{_fmt(v)}</div>'
        f'<div class="k">{_esc(k)}</div></div>' for k, v in pairs)
    return f'<div class="tiles">{cells}</div>'


# -- BENCH trajectory ------------------------------------------------------

def _bench_files(bench_dir: str, pattern: str) -> list:
    out = []
    for path in sorted(globmod.glob(os.path.join(bench_dir, pattern))):
        try:
            with open(path) as fh:
                payload = json.load(fh)
        except (OSError, json.JSONDecodeError):
            continue
        if "entries" in payload:
            out.append((os.path.basename(path), payload))
    return out


def _bench_section(files: list) -> str:
    if not files:
        return ("<h2>BENCH trajectory</h2>"
                '<p class="muted">no BENCH files found</p>')
    parts = [f"<h2>BENCH trajectory ({len(files)} files)</h2>"]
    totals = [(name, payload.get("total_seconds", 0.0))
              for name, payload in files]
    parts.append("<h3>total wall seconds per artifact</h3>")
    parts.append(_bars(totals))
    # per-entry series across the trajectory
    order: list[str] = []
    by_entry: dict = {}
    for fname, payload in files:
        for e in payload.get("entries", []):
            name = e.get("name")
            if name not in by_entry:
                by_entry[name] = {}
                order.append(name)
            by_entry[name][fname] = e
    fnames = [f for f, _p in files]
    rows = []
    for name in order:
        recs = by_entry[name]
        secs = [recs[f].get("seconds") if f in recs else None
                for f in fnames]
        errs = [recs[f].get("max_rel_err") if f in recs else None
                for f in fnames]
        have = [f for f in fnames if f in recs]
        present = (f"{len(have)}/{len(fnames)}"
                   if len(have) < len(fnames) else "all")
        s_list = [s for s in secs if s is not None]
        e_list = [e for e in errs if e is not None]
        d_sec = d_err = ""
        if len(s_list) >= 2 and s_list[-2] > 0:
            pct = 100.0 * (s_list[-1] - s_list[-2]) / s_list[-2]
            cls = "up" if pct > 10 else ("down" if pct < -10 else "")
            d_sec = f'<span class="{cls}">{pct:+.0f}%</span>'
        if len(e_list) >= 2:
            dv = e_list[-1] - e_list[-2]
            cls = "up" if dv > 1e-6 else ("down" if dv < -1e-6 else "")
            d_err = f'<span class="{cls}">{dv:+.2g}</span>'
        rows.append(
            f'<tr><td class="l">{_esc(name)}</td>'
            f"<td>{_spark(secs)}</td><td>{_fmt(s_list[-1] if s_list else None)}"
            f"</td><td>{d_sec}</td>"
            f"<td>{_spark(errs, color='#c0392b')}</td>"
            f"<td>{_fmt(e_list[-1] if e_list else None)}</td>"
            f"<td>{d_err}</td><td>{present}</td></tr>")
    parts.append(
        '<h3>per-entry trajectory</h3><table><tr><th class="l">entry</th>'
        "<th>seconds</th><th>last</th><th>Δ</th>"
        "<th>max_rel_err</th><th>last</th><th>Δ</th><th>present</th></tr>"
        + "".join(rows) + "</table>")
    crashed = [(f, [e.get("section") for e in p.get("errors") or []])
               for f, p in files if p.get("errors")]
    for fname, sections in crashed:
        parts.append(f'<div class="banner">crashed sections in '
                     f"{_esc(fname)}: {_esc(sections)}</div>")
    return "".join(parts)


# -- session snapshots -----------------------------------------------------

# the gauges worth a tile, in display order (the paper's balance story)
_TILE_GAUGES = ("sim.balance.gini", "sim.balance.p99_over_mean",
                "sim.balance.max_over_mean", "sim.dest_stability.min",
                "sim.dest_stability.mean", "sim.theta", "sim.residual",
                "sim.alpha", "sim.delivered_rate")


def session_series(sess) -> dict:
    """Raw per-step curves of a LIVE session's series metrics —
    ``{name: [floats]}`` — for sparkline rendering (snapshots only keep
    summaries)."""
    out = {}
    reg = getattr(sess, "metrics", None)
    if reg is None:
        return out
    for name in reg.names():
        m = reg.get(name)
        if getattr(m, "kind", None) == "series":
            out[name] = list(m.values)
    return out


def _session_section(title: str, snap: dict, series: dict | None) -> str:
    if not snap:
        return (f"<h2>session: {_esc(title)}</h2>"
                '<p class="muted">empty snapshot</p>')
    parts = [f"<h2>session: {_esc(title)} "
             f'<span class="muted">mode={_esc(snap.get("mode"))}</span></h2>']
    metrics = snap.get("metrics") or {}
    tiles = [(n, metrics[n]["value"]) for n in _TILE_GAUGES
             if n in metrics and "value" in metrics[n]]
    if tiles:
        parts.append(_tiles(tiles))
    spans = snap.get("spans") or {}
    if spans:
        ranked = sorted(spans.items(),
                        key=lambda kv: -kv[1].get("total_s", 0.0))
        rows = "".join(
            f'<tr><td class="l">{_esc(n)}</td><td>{r.get("count")}</td>'
            f'<td>{_fmt(r.get("total_s"))}</td>'
            f'<td>{_fmt(r.get("max_s"))}</td></tr>'
            for n, r in ranked[:20])
        parts.append('<h3>spans (top 20 by total time)</h3><table>'
                     '<tr><th class="l">span</th><th>count</th>'
                     "<th>total_s</th><th>max_s</th></tr>"
                     + rows + "</table>")
    kinds: dict = {"counter": [], "gauge": [], "histogram": [],
                   "series": []}
    for name in sorted(metrics):
        kinds.setdefault(metrics[name].get("type"), []).append(name)
    if kinds["counter"]:
        rows = "".join(
            f'<tr><td class="l">{_esc(n)}</td>'
            f'<td>{_fmt(metrics[n]["value"])}</td></tr>'
            for n in kinds["counter"])
        parts.append('<h3>counters</h3><table><tr><th class="l">counter'
                     "</th><th>total</th></tr>" + rows + "</table>")
    if kinds["histogram"]:
        rows = "".join(
            f'<tr><td class="l">{_esc(n)}</td>'
            + "".join(f"<td>{_fmt(metrics[n].get(k))}</td>"
                      for k in ("count", "mean", "min", "p50", "p90",
                                "p99", "max"))
            + "</tr>" for n in kinds["histogram"])
        parts.append('<h3>histograms</h3><table><tr><th class="l">'
                     "histogram</th><th>count</th><th>mean</th><th>min"
                     "</th><th>p50</th><th>p90</th><th>p99</th><th>max"
                     "</th></tr>" + rows + "</table>")
    if kinds["series"]:
        rows = []
        for n in kinds["series"]:
            rec = metrics[n]
            curve = (series or {}).get(n)
            spk = (_spark(curve, w=260) if curve
                   else '<span class="muted">summary only</span>')
            rows.append(f'<tr><td class="l">{_esc(n)}</td><td>{spk}</td>'
                        f'<td>{_fmt(rec.get("count"))}</td>'
                        f'<td>{_fmt(rec.get("last"))}</td>'
                        f'<td>{_fmt(rec.get("max"))}</td></tr>')
        parts.append('<h3>series</h3><table><tr><th class="l">series'
                     "</th><th>curve</th><th>count</th><th>last</th>"
                     "<th>max</th></tr>" + "".join(rows) + "</table>")
    return "".join(parts)


# -- postmortem bundles ----------------------------------------------------

def _bundle_section(bundle: dict) -> str:
    trig = bundle.get("trigger") or {}
    parts = [f"<h2>postmortem: {_esc(trig.get('name', '?'))}</h2>",
             f'<div class="banner"><b>{_esc(trig.get("name"))}</b> — '
             f"{_esc(bundle.get('reason', ''))}</div>"]
    ctx = dict(bundle.get("context") or {})
    ctx["git_rev"] = bundle.get("git_rev")
    if ctx:
        rows = "".join(
            f'<tr><td class="l">{_esc(k)}</td>'
            f'<td class="l">{_esc(_fmt(v) if isinstance(v, float) else v)}'
            f"</td></tr>" for k, v in sorted(ctx.items()))
        parts.append('<h3>context</h3><table><tr><th class="l">key</th>'
                     '<th class="l">value</th></tr>' + rows + "</table>")
    rec = bundle.get("recorder")
    if rec and rec.get("channels"):
        steps = rec.get("steps") or []
        lo = steps[0] if steps else "?"
        hi = steps[-1] if steps else "?"
        parts.append(f"<h3>flight recorder — steps {lo}..{hi} "
                     f'(window {rec.get("window")})</h3>')
        rows = []
        for name in sorted(rec["channels"]):
            vals = rec["channels"][name]
            last = vals[-1] if vals else None
            rows.append(
                f'<tr><td class="l">{_esc(name)}</td>'
                f"<td>{_spark(vals, w=300, color='#c0392b')}</td>"
                f"<td>{_fmt(last)}</td></tr>")
        parts.append('<table><tr><th class="l">channel</th><th>last-W '
                     "curve</th><th>last</th></tr>"
                     + "".join(rows) + "</table>")
    sample = bundle.get("sample") or {}
    if sample:
        rows = "".join(
            f'<tr><td class="l">{_esc(k)}</td><td>{_fmt(v)}</td></tr>'
            for k, v in sorted(sample.items()))
        parts.append('<h3>firing sample</h3><table><tr><th class="l">'
                     "field</th><th>value</th></tr>" + rows + "</table>")
    if bundle.get("metrics"):
        parts.append(_session_section(
            "bundle metrics", {"mode": "bundle",
                               "metrics": bundle["metrics"],
                               "spans": bundle.get("spans") or {}}, None))
    return "".join(parts)


# -- top level -------------------------------------------------------------

def html_report(bench_dir: str | None = None,
                bench_glob: str = "BENCH_*.json",
                sessions=None, bundles=None,
                title: str = "repro observability report") -> str:
    """Assemble the single-file HTML document (as a string)."""
    body = [f"<h1>{_esc(title)}</h1>",
            f'<p class="muted">generated '
            f"{time.strftime('%Y-%m-%d %H:%M:%S')}</p>"]
    if bench_dir is not None:
        body.append(_bench_section(_bench_files(bench_dir, bench_glob)))
    for entry in (sessions or []):
        name, snap = entry[0], entry[1]
        series = entry[2] if len(entry) > 2 else None
        body.append(_session_section(name, snap or {}, series))
    for bundle in (bundles or []):
        body.append(_bundle_section(bundle))
    return ("<!DOCTYPE html><html><head><meta charset='utf-8'>"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            "<body>" + "".join(body) + "</body></html>")


def render_report(out_path: str, **kwargs) -> str:
    """Write :func:`html_report` to ``out_path``; returns the path."""
    doc = html_report(**kwargs)
    with open(out_path, "w") as fh:
        fh.write(doc)
    return out_path


def _load_session_arg(path: str) -> list:
    """A --session file is either one snapshot or a BENCH payload with
    per-section snapshots under "obs"."""
    with open(path) as fh:
        payload = json.load(fh)
    base = os.path.basename(path)
    if payload.get("schema") == "repro.obs/1":
        return [(base, payload)]
    if "obs" in payload:
        return [(f"{base}:{sec}", snap)
                for sec, snap in payload["obs"].items()]
    raise ValueError(f"{path}: neither a session snapshot nor a BENCH "
                     f"payload with an 'obs' block")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("-o", "--out", default="report.html")
    ap.add_argument("--bench-dir", default=None, metavar="PATH",
                    help="render the BENCH_*.json trajectory under PATH")
    ap.add_argument("--glob", default="BENCH_*.json")
    ap.add_argument("--session", action="append", default=[],
                    metavar="SNAP.json",
                    help="session snapshot file (or BENCH payload with an "
                         "'obs' block); repeatable")
    ap.add_argument("--bundle", action="append", default=[],
                    metavar="BUNDLE.json",
                    help="postmortem bundle from a watchdog; repeatable")
    ap.add_argument("--title", default="repro observability report")
    args = ap.parse_args(argv)
    try:
        sessions = []
        for path in args.session:
            sessions.extend(_load_session_arg(path))
        from .watchdog import load_bundle
        bundles = [load_bundle(p) for p in args.bundle]
        render_report(args.out, bench_dir=args.bench_dir,
                      bench_glob=args.glob, sessions=sessions,
                      bundles=bundles, title=args.title)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"# report failed: {e}", file=sys.stderr)
        return 2
    print(f"# wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
