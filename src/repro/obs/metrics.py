"""Metric primitives for repro.obs: counters, gauges, histograms, and
append-only time series behind one pluggable registry.

Everything is plain-Python + numpy (zero new dependencies) and
process-local: a :class:`MetricsRegistry` belongs to one
:class:`repro.obs.Session`, so two concurrent sessions never share
state.  ``snapshot()`` renders the whole registry as JSON-safe dicts —
the stable export schema embedded in BENCH files (see
docs/observability.md for the metric-name taxonomy).

``balance_stats`` is the paper-thesis statistic: given a vector of
per-link utilizations (or loads) it reports the Gini coefficient,
p99-over-mean, and max-over-mean — the "how balanced is the fabric"
numbers the projective-network claim is about.
"""

from __future__ import annotations

import threading

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Series", "MetricsRegistry",
           "balance_stats"]


class Counter:
    """Monotone accumulator (``add``); float-valued so fluid mass and
    call counts share one type.

    Mutation takes a per-metric lock: the threaded CPU slab loop
    (``perf.flags().sim_workers > 1``) can publish wave telemetry from
    worker threads, and ``self.value += v`` is a read-modify-write that
    loses increments under free-threaded interleaving.  The lock only
    costs when a session is active (obs off hands out NULL_METRIC)."""

    __slots__ = ("name", "value", "_lock")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def add(self, v: float = 1.0) -> None:
        with self._lock:
            self.value += v

    def snapshot(self) -> dict:
        return {"type": "counter", "value": float(self.value)}


class Gauge:
    """Last-write-wins value (``set``)."""

    __slots__ = ("name", "value", "_lock")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.value = v

    def snapshot(self) -> dict:
        return {"type": "gauge", "value": float(self.value)}


class Series:
    """Append-only time series (one value per step / iteration).

    ``snapshot()`` exports summary statistics only — per-step values can
    run to thousands of points, and BENCH files must stay diffable;
    callers that want the raw curve read ``.values`` (or
    ``np.asarray(series)``) programmatically.
    """

    __slots__ = ("name", "values", "_lock")
    kind = "series"

    def __init__(self, name: str):
        self.name = name
        self.values: list[float] = []
        self._lock = threading.Lock()

    def append(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.values.append(v)

    def __len__(self) -> int:
        return len(self.values)

    def __array__(self, dtype=None):
        return np.asarray(self.values, dtype=dtype)

    def snapshot(self) -> dict:
        if not self.values:
            return {"type": "series", "count": 0}
        a = np.asarray(self.values, dtype=np.float64)
        return {"type": "series", "count": int(a.size),
                "mean": float(a.mean()), "min": float(a.min()),
                "max": float(a.max()), "last": float(a[-1])}


class Histogram:
    """Value distribution; keeps raw observations (cheap at the volumes
    obs runs at) and summarizes to count/mean/percentiles on export."""

    __slots__ = ("name", "_vals", "_lock")
    kind = "histogram"

    def __init__(self, name: str):
        self.name = name
        self._vals: list = []
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._vals.append(v)

    def observe_many(self, values) -> None:
        a = np.asarray(values, dtype=np.float64).ravel()
        with self._lock:
            self._vals.append(a)

    @property
    def values(self) -> np.ndarray:
        with self._lock:
            vals = list(self._vals)
        if not vals:
            return np.empty(0, dtype=np.float64)
        return np.concatenate([np.atleast_1d(np.asarray(v, np.float64))
                               for v in vals])

    def snapshot(self) -> dict:
        a = self.values
        if a.size == 0:
            return {"type": "histogram", "count": 0}
        return {"type": "histogram", "count": int(a.size),
                "mean": float(a.mean()), "min": float(a.min()),
                "max": float(a.max()),
                "p50": float(np.percentile(a, 50)),
                "p90": float(np.percentile(a, 90)),
                "p99": float(np.percentile(a, 99))}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram,
          "series": Series}


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors.  Re-requesting a
    name with a different kind is an error (the taxonomy is global; see
    docs/observability.md)."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    def _get(self, kind: str, name: str):
        m = self._metrics.get(name)
        if m is not None:
            if m.kind != kind:
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, requested {kind}")
            return m
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = _KINDS[kind](name)
                self._metrics[name] = m
            return m

    def counter(self, name: str) -> Counter:
        return self._get("counter", name)

    def gauge(self, name: str) -> Gauge:
        return self._get("gauge", name)

    def histogram(self, name: str) -> Histogram:
        return self._get("histogram", name)

    def series(self, name: str) -> Series:
        return self._get("series", name)

    def get(self, name: str):
        return self._metrics.get(name)

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def names(self) -> list[str]:
        return sorted(self._metrics)

    def snapshot(self) -> dict:
        return {name: self._metrics[name].snapshot()
                for name in sorted(self._metrics)}


def balance_stats(loads) -> dict:
    """Balance statistics of a nonnegative load/utilization vector: the
    paper's balanced-utilization thesis, measured.

    Returns ``gini`` (0 = perfectly balanced, -> 1 as one link carries
    everything), ``p99_over_mean`` and ``max_over_mean`` (both 1.0 when
    flat; ``max_over_mean`` is ``1/u`` in the paper's utilization
    notation), plus ``mean``/``max``/``n`` for context."""
    x = np.asarray(loads, dtype=np.float64).ravel()
    x = x[np.isfinite(x)]
    n = int(x.size)
    if n == 0 or float(x.sum()) <= 0.0:
        return {"gini": 0.0, "p99_over_mean": 1.0, "max_over_mean": 1.0,
                "mean": 0.0, "max": 0.0, "n": n}
    xs = np.sort(x)
    i = np.arange(1, n + 1, dtype=np.float64)
    gini = float(2.0 * (i * xs).sum() / (n * xs.sum()) - (n + 1) / n)
    mean = float(x.mean())
    return {"gini": gini,
            "p99_over_mean": float(np.percentile(x, 99) / mean),
            "max_over_mean": float(x.max() / mean),
            "mean": mean, "max": float(x.max()), "n": n}
