"""Flight recorder for repro.obs: a bounded ring buffer of per-step
channels that survives until something goes wrong.

The PR 8 series capture (``_SimCapture``) keeps *whole* per-step curves
— fine for a 400-step probe, wrong for the minutes-to-hours regime the
ROADMAP's sim-driven adversary pushes into, where the interesting steps
are the last few hundred before a collapse and everything earlier is
noise.  :class:`FlightRecorder` keeps exactly the last ``window`` steps
of a fixed channel set in preallocated float64 ring arrays: appending is
one modulo index + one row write, so a recorder armed for a million-step
run costs the same per step as for a thousand-step one and never grows.

Channels are fixed by the FIRST :meth:`record` call (the simulator's
step monitor records the ``SimRun.history`` keys — delivered / accepted
/ offered in per-segment normalized units, occupancy / src_backlog /
diverted raw — plus compact state digests: per-VC occupancy sums and
the running conservation residual).  Because the per-step values are
recorded as the SAME float64 divisions the run's own history arrays
perform, a reloaded bundle window compares bit-exactly against
``SimRun.history`` (pinned in tests/test_recorder_watchdog.py: Python's
``json`` round-trips float64 via the shortest-repr rule exactly).

Arm one via the session::

    with obs.session(mode="metrics", recorder=obs.FlightRecorder(256)) as s:
        run = sim.simulate(g, "tornado", routing="ugal_threshold(0)", ...)
    win = s.recorder.window_arrays()   # {"step": ..., "delivered": ..., ...}

The watchdog (:mod:`repro.obs.watchdog`) snapshots the recorder into
every postmortem bundle — the flight recorder is the forensic payload,
the watchdog decides when to dump it.
"""

from __future__ import annotations

import numpy as np

__all__ = ["FlightRecorder"]


class FlightRecorder:
    """Bounded ring buffer of per-step channel values.

    ``window`` is the number of trailing steps retained.  The channel
    set is fixed by the first :meth:`record` call; later calls must pass
    the same keys (missing keys raise — a silent NaN would corrupt the
    bit-exactness contract the postmortem tests rely on).
    """

    __slots__ = ("window", "count", "_names", "_buf", "_steps")

    def __init__(self, window: int = 256):
        window = int(window)
        if window < 1:
            raise ValueError(f"recorder window must be >= 1, got {window}")
        self.window = window
        self.count = 0          # total record() calls (steps seen)
        self._names: list[str] | None = None
        self._buf: np.ndarray | None = None     # (window, C) float64
        self._steps: np.ndarray | None = None   # (window,) int64

    @property
    def channels(self) -> list[str]:
        """Channel names, in recorded column order ([] before first use)."""
        return list(self._names) if self._names is not None else []

    def record(self, step: int, values: dict) -> None:
        """Append one step's channel row.  ``values`` maps channel name
        -> float; the first call fixes the channel set and order."""
        if self._names is None:
            self._names = sorted(values)
            self._buf = np.zeros((self.window, len(self._names)),
                                 dtype=np.float64)
            self._steps = np.full(self.window, -1, dtype=np.int64)
        i = self.count % self.window
        buf = self._buf
        for j, name in enumerate(self._names):
            buf[i, j] = values[name]
        self._steps[i] = step
        self.count += 1

    def __len__(self) -> int:
        return min(self.count, self.window)

    def reset(self) -> None:
        """Forget everything, including the channel set."""
        self.count = 0
        self._names = self._buf = self._steps = None

    def _order(self) -> np.ndarray:
        """Row indices of the live window in chronological order."""
        n = len(self)
        if self.count <= self.window:
            return np.arange(n)
        head = self.count % self.window
        return np.concatenate([np.arange(head, self.window),
                               np.arange(0, head)])

    def window_arrays(self) -> dict:
        """The live window, oldest first: ``{"step": int64 array,
        <channel>: float64 array, ...}`` (empty dict before first use).
        Arrays are copies — safe to hold across further recording."""
        if self._names is None:
            return {}
        idx = self._order()
        out = {"step": self._steps[idx].copy()}
        for j, name in enumerate(self._names):
            out[name] = self._buf[idx, j].copy()
        return out

    def snapshot(self) -> dict:
        """JSON-safe export of the live window (the postmortem-bundle
        payload).  Floats serialize via repr, which round-trips float64
        bit-exactly."""
        win = self.window_arrays()
        steps = win.pop("step", None)
        return {"schema": "repro.obs/recorder/1",
                "window": self.window,
                "count": self.count,
                "steps": ([] if steps is None else
                          [int(s) for s in steps]),
                "channels": {name: [float(v) for v in arr]
                             for name, arr in win.items()}}
