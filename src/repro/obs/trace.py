"""Span tracing for repro.obs: nestable context managers recording wall
time into an active :class:`Session`, exported as Chrome-trace/Perfetto
JSON (``chrome://tracing`` / https://ui.perfetto.dev) or a compact JSONL
event log.

Two span flavors share one class:

* ``obs.span(name, **attrs)`` — returns the shared no-op singleton
  unless a tracing session is active: the instrumentation seams all over
  the stack cost one global read + one ``is None`` check when obs is
  off (the 25 ms fused sim step stays 25 ms).
* ``obs.timed(name, **attrs)`` — ALWAYS measures (``.seconds`` is valid
  with obs off) and records only when tracing.  ``sync(*objs)``
  registers jax pytrees to ``block_until_ready`` before the end
  timestamp is taken, so async-dispatched device work is charged to the
  span that launched it — the trainer/serve step-timing fix rides on
  this.

Timestamps are ``perf_counter_ns`` relative to the session start;
``Session.chrome_trace()`` converts to the microsecond ``ts``/``dur``
complete events ("ph": "X") Perfetto renders with nesting inferred per
thread.
"""

from __future__ import annotations

import json
import threading
import time

from .metrics import MetricsRegistry

__all__ = ["Span", "Session", "NULL_SPAN", "NULL_SESSION"]


class Span:
    """One timed region.  Use as a context manager; ``set(**attrs)``
    annotates mid-flight, ``sync(*objs)`` defers the end timestamp past
    ``jax.block_until_ready`` of the registered objects."""

    __slots__ = ("name", "attrs", "_session", "_t0_ns", "dur_ns",
                 "_sync_objs", "_depth")

    def __init__(self, name: str, attrs: dict, session: "Session | None"):
        self.name = name
        self.attrs = attrs
        self._session = session
        self._t0_ns = 0
        self.dur_ns = 0
        self._sync_objs = None
        self._depth = 0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def sync(self, *objs) -> "Span":
        if self._sync_objs is None:
            self._sync_objs = []
        self._sync_objs.extend(objs)
        return self

    @property
    def seconds(self) -> float:
        return self.dur_ns / 1e9

    def __enter__(self) -> "Span":
        s = self._session
        if s is not None:
            tls = s._tls
            self._depth = getattr(tls, "depth", 0)
            tls.depth = self._depth + 1
        self._t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        if self._sync_objs is not None:
            try:
                import jax
                jax.block_until_ready(self._sync_objs)
            except Exception:
                pass  # jax absent or non-pytree objects: nothing to wait on
        self.dur_ns = time.perf_counter_ns() - self._t0_ns
        s = self._session
        if s is not None:
            s._tls.depth = self._depth
            s._record(self)
        return False


class _NullSpan:
    """The shared do-nothing span ``obs.span`` hands out when no tracing
    session is active.  A singleton: the overhead-guard test pins that
    repeated ``span()`` calls return this same object."""

    __slots__ = ()
    name = None
    attrs: dict = {}
    dur_ns = 0
    seconds = 0.0

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def sync(self, *objs):
        return self


NULL_SPAN = _NullSpan()


class Session:
    """One observability capture: an event list (tracing) + a
    :class:`MetricsRegistry`, both thread-safe.  ``mode`` is ``metrics``
    (counters/gauges/histograms only) or ``trace`` (spans too).  Install
    via :func:`repro.obs.session`; nesting pushes a stack and the
    innermost session receives everything.

    Three optional attachments ride on the session (all None by
    default, so the simulator's hot-loop hooks stay one attribute read
    + ``is None`` test):

    * ``recorder`` — a :class:`repro.obs.FlightRecorder`; the sim's
      step monitor records its per-step channels into the ring buffer.
    * ``watchdog`` — a :class:`repro.obs.Watchdog`; bound to this
      session so its postmortem bundles snapshot the recorder, spans,
      and metrics.
    * ``stream`` — an :class:`repro.obs.ObsStreamer` (or a path string,
      opened and owned by the session): live JSONL telemetry via
      ``obs.emit`` / ``obs.Progress``.
    """

    enabled = True

    def __init__(self, mode: str = "trace",
                 registry: MetricsRegistry | None = None,
                 series: bool | None = None,
                 recorder=None, watchdog=None, stream=None):
        if mode not in ("metrics", "trace"):
            raise ValueError(f"unknown obs mode {mode!r}; "
                             f"options: none, metrics, trace")
        self.mode = mode
        self.metrics = registry if registry is not None else MetricsRegistry()
        # per-step series capture (sim per-VC occupancy, window link-util
        # accumulation, ...) costs host work inside hot loops; default on
        # only under full tracing, overridable either way
        self.series = (mode == "trace") if series is None else bool(series)
        self.recorder = recorder
        self.watchdog = watchdog
        if watchdog is not None:
            watchdog.bind(self)
        self._own_stream = isinstance(stream, str)
        if self._own_stream:
            from .export import ObsStreamer
            stream = ObsStreamer(stream)
        self.stream = stream
        self.events: list = []  # (name, t0_ns, dur_ns, tid, depth, attrs)
        self._t0_ns = time.perf_counter_ns()
        self._wall0 = time.time()
        self._lock = threading.Lock()
        self._tls = threading.local()

    def close(self) -> None:
        """Release owned resources (the stream, when opened from a path
        string); called by ``obs.session`` on exit."""
        if self._own_stream and self.stream is not None:
            self.stream.close()

    @property
    def tracing(self) -> bool:
        return self.mode == "trace"

    def _record(self, span: Span) -> None:
        ev = (span.name, span._t0_ns - self._t0_ns, span.dur_ns,
              threading.get_ident(), span._depth,
              span.attrs if span.attrs else None)
        with self._lock:
            self.events.append(ev)

    # -- summaries ---------------------------------------------------------

    def span_summary(self) -> dict:
        """name -> {count, total_s, max_s} over recorded spans."""
        out: dict = {}
        with self._lock:
            events = list(self.events)
        for name, _t0, dur, _tid, _d, _a in events:
            rec = out.setdefault(name, {"count": 0, "total_s": 0.0,
                                        "max_s": 0.0})
            rec["count"] += 1
            rec["total_s"] += dur / 1e9
            rec["max_s"] = max(rec["max_s"], dur / 1e9)
        for rec in out.values():
            rec["total_s"] = round(rec["total_s"], 6)
            rec["max_s"] = round(rec["max_s"], 6)
        return dict(sorted(out.items()))

    def top_spans(self, k: int = 5) -> list:
        """The k span names with the largest total wall time, as
        ``(name, total_s, count)`` tuples."""
        summ = self.span_summary()
        ranked = sorted(summ.items(), key=lambda kv: -kv[1]["total_s"])
        return [(name, rec["total_s"], rec["count"])
                for name, rec in ranked[:k]]

    def snapshot(self) -> dict:
        """JSON-safe export of everything: the stable schema BENCH files
        embed (schema name pinned in docs/observability.md)."""
        return {"schema": "repro.obs/1", "mode": self.mode,
                "spans": self.span_summary(),
                "metrics": self.metrics.snapshot()}

    # -- trace export ------------------------------------------------------

    def chrome_trace(self) -> dict:
        """The Chrome-trace/Perfetto JSON object (complete "X" events,
        microsecond units, nesting inferred per tid)."""
        with self._lock:
            events = list(self.events)
        tids: dict = {}
        trace = [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
                  "args": {"name": "repro"}}]
        for name, t0, dur, tid, _depth, attrs in events:
            vtid = tids.setdefault(tid, len(tids))
            ev = {"name": name, "cat": name.split(".", 1)[0], "ph": "X",
                  "ts": t0 / 1e3, "dur": dur / 1e3, "pid": 0, "tid": vtid}
            if attrs:
                ev["args"] = _json_safe(attrs)
            trace.append(ev)
        return {"displayTimeUnit": "ms", "traceEvents": trace}

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.chrome_trace(), fh)

    def write_jsonl(self, path: str) -> None:
        """Compact one-event-per-line log; the first line is a header
        with the schema tag and the session's unix start time."""
        with self._lock:
            events = list(self.events)
        with open(path, "w") as fh:
            fh.write(json.dumps({"schema": "repro.obs/1",
                                 "t0_unix": self._wall0,
                                 "mode": self.mode}) + "\n")
            for name, t0, dur, tid, depth, attrs in events:
                rec = {"name": name, "ts_us": round(t0 / 1e3, 3),
                       "dur_us": round(dur / 1e3, 3), "tid": tid,
                       "depth": depth}
                if attrs:
                    rec["attrs"] = _json_safe(attrs)
                fh.write(json.dumps(rec) + "\n")


def _json_safe(attrs: dict) -> dict:
    out = {}
    for k, v in attrs.items():
        if isinstance(v, (str, int, bool)) or v is None:
            out[k] = v
        else:
            try:
                out[k] = float(v)
            except (TypeError, ValueError):
                out[k] = str(v)
    return out


class _NullSession:
    """What ``obs.session()`` yields when the mode resolves to ``none``:
    same surface, nothing recorded, ``snapshot()`` is None (callers use
    that to skip embedding empty obs blocks)."""

    enabled = False
    tracing = False
    series = False
    mode = "none"
    events: list = []
    recorder = None
    watchdog = None
    stream = None

    def snapshot(self):
        return None

    def span_summary(self) -> dict:
        return {}

    def top_spans(self, k: int = 5) -> list:
        return []

    def close(self) -> None:
        pass


NULL_SESSION = _NullSession()
