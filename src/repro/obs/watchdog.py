"""Anomaly watchdogs for repro.obs: declarative triggers over the
simulator's per-step sample stream that dump a self-contained postmortem
bundle the moment something breaks — while the flight recorder still
holds the evidence.

The trigger taxonomy (docs/observability.md):

* :func:`residual` — the running flow-conservation identity
  (``|injected − delivered − occupancy − backlog − dropped| /
  injected``) exceeds a tolerance: mass is leaking or appearing, the
  cardinal simulator bug class.
* :func:`nonfinite` — NaN/inf in the step stats or negative fluid mass:
  the numerical smoke alarm (a float32 fused backend gone wrong fires
  this long before the aggregate curves look off).
* :func:`dest_stability` — the minimum per-dest-column
  delivered/offered ratio over a rolling window collapses below a
  floor: the sharp per-column knee criterion, live (this is the trigger
  a past-knee ``ugal_threshold`` probe fires; see the e2e test).
* :func:`step_time` — one step's wall time spikes past a multiple of
  the running mean: a recompile, a swap storm, a wedged device.
* :func:`oscillation` — sweep-level: a probe at HIGHER offered load
  reports stable after a LOWER one collapsed, so the knee bisection is
  chasing a non-monotone stability frontier (fed by
  ``saturation_sweep`` via :meth:`Watchdog.on_probe`).

On firing, the watchdog writes a postmortem bundle
(``repro.obs/postmortem/1``): trigger + reason + step, the run context
(`SimConfig` fields, demand fingerprint, backend, git rev), the flight
recorder's ring-buffer snapshot, and the session's span summary and
metrics snapshot.  ``action="continue"`` (default) keeps the run going
— one bundle per trigger, ``max_bundles`` total — while
``action="halt"`` raises :class:`WatchdogFired` after the dump.

Wire one through the session::

    wd = obs.Watchdog([obs.dest_stability(ratio=0.5)], dir="postmortems")
    with obs.session(mode="metrics", recorder=obs.FlightRecorder(128),
                     watchdog=wd):
        sim.simulate(g, "tornado", routing="ugal_threshold(0)",
                     offered=2.0 * theta)
    assert wd.fired                  # [(trigger_name, bundle_path), ...]
    bundle = obs.load_bundle(wd.fired[0][1])

Triggers declare what per-step inputs they ``need`` ("dest_mass",
"step_seconds") so the simulator's monitor only computes the expensive
digests a trigger actually consumes.
"""

from __future__ import annotations

import json
import os
import subprocess
import time

import numpy as np

__all__ = ["Watchdog", "WatchdogFired", "Trigger", "residual", "nonfinite",
           "dest_stability", "step_time", "oscillation", "load_bundle"]

BUNDLE_SCHEMA = "repro.obs/postmortem/1"


class WatchdogFired(RuntimeError):
    """Raised by a halting watchdog after the postmortem bundle is on
    disk.  ``trigger`` / ``reason`` / ``path`` identify what fired."""

    def __init__(self, trigger: str, reason: str, path: str | None):
        super().__init__(f"watchdog trigger {trigger!r} fired: {reason}"
                         + (f" (bundle: {path})" if path else ""))
        self.trigger = trigger
        self.reason = reason
        self.path = path


class Trigger:
    """One anomaly predicate over the per-step sample stream.

    Subclasses set ``name``, declare ``needs`` (tags of expensive
    per-step inputs they consume: "dest_mass", "step_seconds"), and
    implement :meth:`check` returning a human-readable reason string
    when the predicate fires (None otherwise).  A trigger fires at most
    once per run (re-armed by :meth:`reset`)."""

    name = "trigger"
    needs: frozenset = frozenset()

    def __init__(self):
        self.fired = False

    def reset(self) -> None:
        self.fired = False

    def check(self, sample: dict):
        raise NotImplementedError

    def describe(self) -> dict:
        """JSON-safe self-description for the bundle."""
        return {"name": self.name}


class _Residual(Trigger):
    name = "residual"

    def __init__(self, tol: float = 1e-6, warmup: int = 8):
        super().__init__()
        self.tol = float(tol)
        self.warmup = int(warmup)

    def check(self, sample):
        if sample["step"] < self.warmup:
            return None
        r = sample.get("residual")
        if r is not None and r > self.tol:
            return (f"conservation residual {r:.3e} > tol {self.tol:.1e} "
                    f"at step {sample['step']}")
        return None

    def describe(self):
        return {"name": self.name, "tol": self.tol, "warmup": self.warmup}


class _NonFinite(Trigger):
    name = "nonfinite"
    # negative-mass detection wants the per-dest mass digest when a
    # dest_stability trigger already pays for it, but must not force it:
    # the row stats alone catch NaN/inf propagation
    _STAT_KEYS = ("delivered", "accepted", "offered", "occupancy",
                  "src_backlog", "diverted")

    def __init__(self, mass_floor: float = -1e-6):
        super().__init__()
        self.mass_floor = float(mass_floor)

    def check(self, sample):
        for k in self._STAT_KEYS:
            v = sample.get(k)
            if v is not None and not np.isfinite(v):
                return f"non-finite {k}={v!r} at step {sample['step']}"
        for k in ("occupancy", "src_backlog"):
            v = sample.get(k)
            if v is not None and v < self.mass_floor:
                return (f"negative mass {k}={v:.3e} at step "
                        f"{sample['step']}")
        mn = sample.get("dest_mass_min")
        if mn is not None:
            if not np.isfinite(mn):
                return f"non-finite dest mass at step {sample['step']}"
            if mn < self.mass_floor:
                return (f"negative per-dest mass {mn:.3e} at step "
                        f"{sample['step']}")
        return None

    def describe(self):
        return {"name": self.name, "mass_floor": self.mass_floor}


class _DestStability(Trigger):
    """Consumes the ``dest_stability_min`` digest the simulator's step
    monitor computes (rolling per-dest delivered/offered over the
    watchdog's :meth:`Watchdog.stability_window` — the _SimCapture
    mass-bookkeeping identity evaluated live each step instead of once
    at the run's end)."""

    name = "dest_stability"
    needs = frozenset({"dest_mass"})

    def __init__(self, ratio: float = 0.5, window: int = 32,
                 warmup: int = 32):
        super().__init__()
        self.ratio = float(ratio)
        self.window = int(window)
        self.warmup = int(warmup)

    def check(self, sample):
        mn = sample.get("dest_stability_min")
        if mn is None or not np.isfinite(mn):
            return None
        if sample["step"] < self.warmup + self.window:
            return None
        if mn < self.ratio:
            col = sample.get("dest_stability_col")
            where = f" (dest col {col})" if col is not None else ""
            return (f"per-dest stability collapsed: min ratio {mn:.4f} < "
                    f"{self.ratio}{where} over the trailing window at "
                    f"step {sample['step']}")
        return None

    def describe(self):
        return {"name": self.name, "ratio": self.ratio,
                "window": self.window, "warmup": self.warmup}


class _StepTime(Trigger):
    name = "step_time"
    needs = frozenset({"step_seconds"})

    def __init__(self, factor: float = 20.0, warmup: int = 16,
                 floor_s: float = 0.05):
        super().__init__()
        self.factor = float(factor)
        self.warmup = int(warmup)
        self.floor_s = float(floor_s)
        self._sum = 0.0
        self._n = 0

    def reset(self):
        super().reset()
        self._sum = 0.0
        self._n = 0

    def check(self, sample):
        dt = sample.get("step_seconds")
        if dt is None:
            return None
        self._n += 1
        self._sum += dt
        if self._n <= self.warmup:
            return None
        mean = (self._sum - dt) / (self._n - 1)
        if dt > self.floor_s and dt > self.factor * max(mean, 1e-12):
            return (f"step {sample['step']} took {dt:.3f}s, "
                    f"{dt / max(mean, 1e-12):.0f}x the running mean "
                    f"{mean * 1e3:.2f}ms")
        return None

    def describe(self):
        return {"name": self.name, "factor": self.factor,
                "warmup": self.warmup, "floor_s": self.floor_s}


class _Oscillation(Trigger):
    """Sweep-level: fed probe outcomes via Watchdog.on_probe, not
    per-step samples."""

    name = "oscillation"

    def __init__(self):
        super().__init__()
        self._min_unstable = None   # smallest offered load seen to collapse
        self._probes = 0

    def reset(self):
        super().reset()
        self._min_unstable = None
        self._probes = 0

    def check(self, sample):   # not step-driven
        return None

    def on_probe(self, offered: float, stable: bool):
        self._probes += 1
        if not stable:
            if (self._min_unstable is None
                    or offered < self._min_unstable):
                self._min_unstable = offered
            return None
        if (self._min_unstable is not None
                and offered > self._min_unstable * (1 + 1e-12)):
            return (f"knee oscillation: probe at offered={offered:.6g} "
                    f"is stable ABOVE the collapsed probe at "
                    f"offered={self._min_unstable:.6g} "
                    f"(probe #{self._probes}) — the stability frontier "
                    f"is non-monotone")
        return None

    def describe(self):
        return {"name": self.name}


def residual(tol: float = 1e-6, warmup: int = 8) -> Trigger:
    """Fire when the running conservation residual exceeds ``tol``."""
    return _Residual(tol, warmup)


def nonfinite(mass_floor: float = -1e-6) -> Trigger:
    """Fire on NaN/inf step stats or negative fluid mass."""
    return _NonFinite(mass_floor)


def dest_stability(ratio: float = 0.5, window: int = 32,
                   warmup: int = 32) -> Trigger:
    """Fire when the min per-dest delivered/offered ratio over a rolling
    ``window`` drops below ``ratio`` (after ``warmup`` + ``window``
    steps).  Needs the per-dest mass digest — the one trigger that costs
    a host pass over the dest tensors per step."""
    return _DestStability(ratio, window, warmup)


def step_time(factor: float = 20.0, warmup: int = 16,
              floor_s: float = 0.05) -> Trigger:
    """Fire when one step's wall time exceeds ``factor`` times the
    running mean (and ``floor_s`` absolute — sub-50ms spikes are
    scheduler noise, not anomalies)."""
    return _StepTime(factor, warmup, floor_s)


def oscillation() -> Trigger:
    """Fire when a sweep's stability frontier is non-monotone in
    offered load (a stable probe above a collapsed one)."""
    return _Oscillation()


def _git_rev() -> str | None:
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=5,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except Exception:
        return None


def _json_safe(v):
    if isinstance(v, dict):
        return {str(k): _json_safe(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    if isinstance(v, np.ndarray):
        return [_json_safe(x) for x in v.tolist()]
    if isinstance(v, (np.floating,)):
        return float(v)
    if isinstance(v, (np.integer,)):
        return int(v)
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


class Watchdog:
    """A set of anomaly triggers plus the postmortem dump policy.

    ``triggers`` is a list from the factory functions above;
    ``action`` is ``"continue"`` (dump and keep running; default) or
    ``"halt"`` (dump, then raise :class:`WatchdogFired`); ``dir`` is
    where bundles land (created on first dump; None keeps bundles
    in-memory only — ``last_bundle`` holds the dict); ``max_bundles``
    caps total dumps per watchdog so a persistent anomaly cannot spam
    the disk.

    The simulator calls :meth:`begin_run` with the run's context,
    :meth:`on_step` with one sample dict per step, and
    ``saturation_sweep`` calls :meth:`on_probe` per probe.  ``fired``
    accumulates ``(trigger_name, bundle_path)`` tuples."""

    def __init__(self, triggers, action: str = "continue",
                 dir: str | None = "postmortems", max_bundles: int = 4):
        if action not in ("continue", "halt"):
            raise ValueError(f"unknown watchdog action {action!r}; "
                             f"options: continue, halt")
        self.triggers = list(triggers)
        self.action = action
        self.dir = dir
        self.max_bundles = int(max_bundles)
        self.fired: list = []        # (trigger_name, path-or-None)
        self.last_bundle: dict | None = None
        self._session = None
        self._context: dict = {}

    def bind(self, session) -> None:
        """Attach the session whose recorder/spans/metrics the bundle
        snapshots (done by ``Session.__init__``)."""
        self._session = session

    def needs(self, tag: str) -> bool:
        """True when any live trigger consumes the per-step input
        ``tag`` ("dest_mass", "step_seconds") — the monitor skips
        computing digests nothing will read."""
        return any(tag in t.needs and not t.fired for t in self.triggers)

    def stability_window(self) -> int | None:
        """The rolling window (steps) the per-dest stability digest
        should use — the max over armed dest_stability triggers, None
        when none is armed (the monitor then skips the per-step
        dest-mass pass entirely)."""
        wins = [t.window for t in self.triggers
                if isinstance(t, _DestStability) and not t.fired]
        return max(wins) if wins else None

    @property
    def exhausted(self) -> bool:
        return (len(self.fired) >= self.max_bundles
                or all(t.fired for t in self.triggers))

    def begin_run(self, **context) -> None:
        """Install one run's context (config fields, demand fingerprint,
        backend, steps) and re-arm per-run trigger state.  Fired
        triggers stay fired: one bundle per trigger per watchdog."""
        self._context = _json_safe(context)
        for t in self.triggers:
            if not t.fired:
                t.reset()

    def on_step(self, sample: dict) -> None:
        """Evaluate every armed trigger against one step sample; dump
        (and optionally halt) on the first that fires."""
        if self.exhausted:
            return
        for t in self.triggers:
            if t.fired:
                continue
            reason = t.check(sample)
            if reason is not None:
                self._fire(t, reason, sample)

    def on_probe(self, offered: float, stable: bool) -> None:
        """Feed one sweep probe outcome to the oscillation trigger(s)."""
        if self.exhausted:
            return
        for t in self.triggers:
            if t.fired or not isinstance(t, _Oscillation):
                continue
            reason = t.on_probe(float(offered), bool(stable))
            if reason is not None:
                self._fire(t, reason,
                           {"offered": float(offered), "stable": stable})

    def _fire(self, trigger: Trigger, reason: str, sample: dict) -> None:
        trigger.fired = True
        bundle = self._bundle(trigger, reason, sample)
        path = None
        if self.dir is not None and len(self.fired) < self.max_bundles:
            os.makedirs(self.dir, exist_ok=True)
            step = sample.get("step", "probe")
            path = os.path.join(
                self.dir, f"postmortem_{trigger.name}_{step}.json")
            with open(path, "w") as fh:
                json.dump(bundle, fh, indent=1)
        self.last_bundle = bundle
        self.fired.append((trigger.name, path))
        if self.action == "halt":
            raise WatchdogFired(trigger.name, reason, path)

    def _bundle(self, trigger: Trigger, reason: str, sample: dict) -> dict:
        sess = self._session
        rec = getattr(sess, "recorder", None) if sess is not None else None
        # drop the heavy per-dest arrays from the frozen sample; the
        # digest scalars and the recorder window carry the story
        slim = {k: v for k, v in sample.items()
                if k not in ("dest_mass", "off_dest")}
        return {
            "schema": BUNDLE_SCHEMA,
            "trigger": trigger.describe(),
            "reason": reason,
            "sample": _json_safe(slim),
            "context": self._context,
            "git_rev": _git_rev(),
            "t_unix": time.time(),
            "recorder": rec.snapshot() if rec is not None else None,
            "spans": (sess.span_summary()
                      if sess is not None and sess.enabled else {}),
            "metrics": (sess.metrics.snapshot()
                        if sess is not None and sess.enabled else {}),
        }


def load_bundle(path: str) -> dict:
    """Reload a postmortem bundle; validates the schema tag."""
    with open(path) as fh:
        bundle = json.load(fh)
    if bundle.get("schema") != BUNDLE_SCHEMA:
        raise ValueError(f"{path}: not a postmortem bundle "
                         f"(schema={bundle.get('schema')!r})")
    return bundle
