"""repro.obs — zero-dependency tracing, metrics, and telemetry for the
fabric stack.

Three faces (see docs/observability.md for the full taxonomy):

* **tracing** — ``with obs.span("sim.sweep", pattern=...):`` records
  nestable wall-time spans into the active session, exported as
  Chrome-trace/Perfetto JSON (``Session.write_chrome``) or JSONL
  (``write_jsonl``).  The hot seams are pre-instrumented: utilization
  engine dispatch, routing solves (incl. ``blend_optimum`` probe
  counts), ``saturation_sweep`` bracket/bisection probes, placement
  ``greedy_swap``, fault surgery, and the sim backend dispatch.
* **metrics** — ``obs.counter("sim.delivered").add(x)`` etc. against the
  session's :class:`MetricsRegistry`; the simulator publishes its
  conservation counters (bit-exact with ``SimRun``'s own accounting)
  and the per-link utilization balance statistics
  (:func:`balance_stats` — the paper's balanced-utilization thesis,
  measured).
* **export** — ``Session.snapshot()`` is the stable JSON schema
  ``benchmarks/run.py`` embeds per BENCH section and
  ``benchmarks/compare.py`` diffs across a trajectory.

Everything is off by default: with no active session every helper
returns a shared no-op singleton (one module-global read per call — no
allocation, no branches in the caller), and the ``obs`` perf flag
(``REPRO_PERF=obs=trace``) only selects the default mode of
``obs.session()`` — nothing records until a session is entered:

    from repro import obs
    with obs.session(mode="trace") as sess:
        sweep = sim.saturation_sweep(g, "tornado", routing="ugal")
        sess.write_chrome("trace.json")
        print(sess.top_spans())

``obs.timed(name)`` is the exception to "off means free": it always
measures (and only *records* under tracing), and its ``sync()`` hook
blocks on registered jax values before closing — the correct way to
time async-dispatched device work (used by repro.train.trainer and
repro.launch.serve).
"""

from __future__ import annotations

from contextlib import contextmanager

from .export import ObsStreamer, Progress, openmetrics_text, write_openmetrics
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry, Series,
                      balance_stats)
from .recorder import FlightRecorder
from .trace import NULL_SESSION, NULL_SPAN, Session, Span
from .watchdog import (Watchdog, WatchdogFired, dest_stability, load_bundle,
                       nonfinite, oscillation, residual, step_time)

__all__ = [
    "Session", "Span", "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "Series", "balance_stats", "session", "current", "span", "timed",
    "counter", "gauge", "histogram", "series", "NULL_SPAN", "NULL_SESSION",
    "FlightRecorder", "Watchdog", "WatchdogFired", "residual", "nonfinite",
    "dest_stability", "step_time", "oscillation", "load_bundle",
    "ObsStreamer", "Progress", "openmetrics_text", "write_openmetrics",
    "emit", "recorder", "watchdog",
]

# innermost active session last; module-global so the fast path is one
# attribute load + truth test
_STACK: list = []


def current():
    """The innermost active :class:`Session`, or None."""
    return _STACK[-1] if _STACK else None


@contextmanager
def session(mode: str | None = None, registry: MetricsRegistry | None = None,
            series: bool | None = None, recorder=None, watchdog=None,
            stream=None):
    """Enter an observability session.  ``mode`` None resolves from the
    ``obs`` perf flag (``REPRO_PERF=obs=none|metrics|trace``); mode
    ``none`` yields the inert :data:`NULL_SESSION` without installing
    anything.  ``series`` forces per-step series capture on/off (default:
    on only under ``trace`` — the per-step host work is the expensive
    part; see docs/observability.md).

    ``recorder`` arms a :class:`FlightRecorder` ring buffer,
    ``watchdog`` a :class:`Watchdog` (bound to this session so its
    postmortem bundles snapshot the recorder/spans/metrics), and
    ``stream`` opens live JSONL telemetry (an :class:`ObsStreamer` or a
    path string — a string is owned and closed on session exit)."""
    if mode is None:
        from ..perf import flags
        mode = flags().obs
    if mode in (None, "", "none", "off", False, 0):
        yield NULL_SESSION
        return
    s = Session(mode, registry, series=series, recorder=recorder,
                watchdog=watchdog, stream=stream)
    _STACK.append(s)
    try:
        yield s
    finally:
        _STACK.remove(s)
        s.close()


class _NullMetric:
    """Accepts every metric verb, does nothing; handed out when no
    session is active so call sites never branch."""

    __slots__ = ()
    value = 0.0
    values: list = []

    def add(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def observe_many(self, values) -> None:
        pass

    def append(self, v: float) -> None:
        pass


NULL_METRIC = _NullMetric()


def span(name: str, **attrs):
    """A tracing span: real when the active session traces, the shared
    :data:`NULL_SPAN` singleton otherwise (the no-op fast path)."""
    s = _STACK[-1] if _STACK else None
    if s is None or s.mode != "trace":
        return NULL_SPAN
    return Span(name, attrs, s)


def timed(name: str, **attrs) -> Span:
    """A span that ALWAYS measures (``.seconds`` valid with obs off) and
    records only under tracing.  ``.sync(*jax_values)`` defers the end
    timestamp past ``block_until_ready`` — use this to time
    async-dispatched device work."""
    s = _STACK[-1] if _STACK else None
    return Span(name, attrs, s if (s is not None and s.mode == "trace")
                else None)


def counter(name: str):
    s = _STACK[-1] if _STACK else None
    return NULL_METRIC if s is None else s.metrics.counter(name)


def gauge(name: str):
    s = _STACK[-1] if _STACK else None
    return NULL_METRIC if s is None else s.metrics.gauge(name)


def histogram(name: str):
    s = _STACK[-1] if _STACK else None
    return NULL_METRIC if s is None else s.metrics.histogram(name)


def series(name: str):
    s = _STACK[-1] if _STACK else None
    return NULL_METRIC if s is None else s.metrics.series(name)


def recorder():
    """The active session's :class:`FlightRecorder`, or None — same
    one-global-read fast path as :func:`span` when obs is off."""
    s = _STACK[-1] if _STACK else None
    return None if s is None else s.recorder


def watchdog():
    """The active session's :class:`Watchdog`, or None."""
    s = _STACK[-1] if _STACK else None
    return None if s is None else s.watchdog


def emit(kind: str, **fields) -> None:
    """Stream one telemetry event through the active session's
    :class:`ObsStreamer` — a no-op (one global read, no allocation)
    without a streaming session.  The live-progress verb behind
    :class:`Progress` and the sweep/adversary/faults emitters."""
    s = _STACK[-1] if _STACK else None
    if s is None:
        return
    st = s.stream
    if st is not None:
        st.emit(kind, **fields)
