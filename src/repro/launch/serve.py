"""Serving launcher: batched generation over the continuous-batching
Engine with synthetic or stdin prompts.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m \
      --requests 8 --max-new 16

(Reduced-family weights are randomly initialized — this exercises the
serving path: per-request unpadded prefill, fused ragged decode over the
per-family caches.)
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from .. import obs
from ..configs import ARCHS, get_arch
from ..models import build, unbox
from ..serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="smollm-135m")
    ap.add_argument("--full", action="store_true",
                    help="full config (default: reduced family)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_arch(args.arch) if args.full else get_arch(args.arch).reduced()
    bundle = build(cfg)
    params = unbox(bundle.init(jax.random.key(args.seed)))
    eng = Engine(cfg, params, ServeConfig(max_batch=args.max_batch,
                                          max_len=args.max_len))
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.integers(4, min(24, args.max_len // 2)))
        eng.submit(rng.integers(0, cfg.vocab, plen).astype(np.int32),
                   max_new=args.max_new)
    # the engine's decode loop dispatches jax work asynchronously; close
    # the bracket only after the returned tokens have landed on the host,
    # else tok/s over-reports (the old perf_counter pair did exactly that)
    with obs.timed("serve.run", requests=args.requests) as sp:
        results = eng.run()
        sp.sync(results)
    dt = sp.seconds
    n_tok = sum(len(v) for v in results.values())
    for rid in sorted(results)[:4]:
        print(f"req {rid}: {results[rid]}")
    print(f"served {len(results)} requests / {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
