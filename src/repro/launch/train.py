"""Training launcher: the production entry point around repro.train.Trainer.

On a real cluster each host runs this under `jax.distributed` and the mesh
is the production (pod, data, model) mesh; on this CPU container it runs
the same code on the host mesh with a reduced or full config.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --reduced --seq 128 --batch 4

Checkpoints land in --ckpt-dir; re-running resumes exactly (step, data
order and rng are pure functions of the saved step).
"""

from __future__ import annotations

import argparse

from ..configs import ARCHS, get_arch
from ..data import DataConfig
from ..optim import AdamWConfig, cosine_schedule
from ..train.train_step import TrainStepConfig
from ..train.trainer import Trainer, TrainerConfig
from .mesh import make_host_mesh, make_production_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the same family")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_train")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--grad-compress", action="store_true",
                    help="error-feedback int8 gradient compression")
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the (16,16) production mesh (needs 256 devices)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh(1, 1))

    data = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                      global_batch=args.batch,
                      memory_tokens=(cfg.vision.n_image_tokens if cfg.vision
                                     else 0),
                      d_model=cfg.d_model)
    trainer = Trainer(
        cfg=cfg, data=data, mesh=mesh,
        tcfg=TrainerConfig(total_steps=args.steps,
                           checkpoint_every=args.ckpt_every,
                           checkpoint_dir=args.ckpt_dir, log_every=10),
        scfg=TrainStepConfig(
            optimizer=AdamWConfig(lr=cosine_schedule(
                args.lr, warmup=min(20, args.steps // 10 + 1),
                total=args.steps)),
            zero1=args.zero1, grad_compress=args.grad_compress),
    )
    trainer.run()


if __name__ == "__main__":
    main()
