import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod AOT dry-run: lower + compile every (arch x shape x mesh) cell
with ShapeDtypeStruct stand-ins (no allocation), proving the sharding
config is coherent, and extract the roofline terms from the compiled
artifact.

Usage:
  python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh pod1
  python -m repro.launch.dryrun --all [--mesh pod1|pod2] [--jobs N]

The FIRST line above sets 512 host placeholder devices BEFORE any jax
import — do not move it.
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_arch
from ..configs.base import ShapeConfig
from ..models import (DEFAULT_RULES, build, cache_logical_axes, init_model,
                      resolve_specs, unbox)
from ..train.train_step import (TrainStepConfig, init_train_state,
                                make_train_step)
from .mesh import make_production_mesh

OUT_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "experiments", "dryrun"))

COLLECTIVE_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+\[[^\]]*\]\S*))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device result bytes of every collective op, by kind."""
    out: dict[str, float] = {}
    for m in COLLECTIVE_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] = out.get(kind, 0) + _shape_bytes(shape_str)
    out["total"] = sum(v for k, v in out.items())
    return out


def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _shard_batch_spec(mesh, batch_dim_size):
    axes = _batch_axes(mesh)
    n = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a]
                     for a in axes])) if axes else 1
    return P(axes) if axes and batch_dim_size % n == 0 else P(None)


def _abstract(tree, specs, mesh):
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs)


def _lower_any(cfg, shape: ShapeConfig, mesh):
    from ..models import DEFAULT_RULES
    from ..perf import flags
    bundle = build(cfg)
    if shape.kind == "train":
        rules = DEFAULT_RULES.replace(ff=None) if flags().replicate_ff \
            else DEFAULT_RULES
        ts = TrainStepConfig(zero1=flags().zero1, rules=rules)
        step_fn, _ = make_train_step(cfg, mesh, ts, donate=False)
        abstract_state = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.key(0), ts))
        batch = bundle.input_specs(shape)["batch"]
        return step_fn.lower(abstract_state, batch)
    if shape.kind == "prefill":
        return _lower_prefill(cfg, bundle, shape, mesh)
    return _lower_decode(cfg, bundle, shape, mesh)


def _compile_metrics(cfg, shape, mesh):
    t0 = time.time()
    lowered = _lower_any(cfg, shape, mesh)
    compiled = lowered.compile()
    compile_s = time.time() - t0
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    return {
        "compile_seconds": round(compile_s, 1),
        "flops": cost.get("flops", 0.0),
        "bytes_accessed": cost.get("bytes accessed", 0.0),
        "transcendentals": cost.get("transcendentals", 0.0),
        "collective_bytes_per_device": collective_bytes(text),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "generated_code_bytes": mem.generated_code_size_in_bytes,
        },
        "hlo_chars": len(text),
    }


def lower_cell(arch: str, shape_name: str, multi_pod: bool):
    """Compile the cell; correct scan-body undercounting with 1- vs 2-period
    unrolled probes (XLA cost_analysis counts a while body once, so the
    corrected totals are main + (reps-1) * (probe2 - probe1))."""
    from ..models.transformer import layer_plan

    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    if shape.kind == "decode" and shape_name == "long_500k" and not cfg.sub_quadratic:
        return {"status": "skipped",
                "reason": "long_500k requires sub-quadratic attention "
                          "(full-attention arch; see DESIGN.md)"}

    with mesh:
        main = _compile_metrics(cfg, shape, mesh)
        plan = layer_plan(cfg)
        probe = None
        corrected = {k: main[k] for k in ("flops", "bytes_accessed",
                                          "transcendentals")}
        corrected["collective_bytes_per_device"] = dict(
            main["collective_bytes_per_device"])
        if cfg.scan_layers and plan.reps > 1:
            p_cfgs = [cfg.replace(n_layers=plan.prefix + k * plan.period,
                                  scan_layers=False) for k in (1, 2)]
            p1 = _compile_metrics(p_cfgs[0], shape, mesh)
            p2 = _compile_metrics(p_cfgs[1], shape, mesh)
            probe = {"p1": {k: p1[k] for k in corrected if k != "collective_bytes_per_device"},
                     "p2": {k: p2[k] for k in corrected if k != "collective_bytes_per_device"},
                     "p1_coll": p1["collective_bytes_per_device"],
                     "p2_coll": p2["collective_bytes_per_device"]}
            extra = plan.reps - 1
            for k in ("flops", "bytes_accessed", "transcendentals"):
                corrected[k] = main[k] + extra * (p2[k] - p1[k])
            allk = set(main["collective_bytes_per_device"]) | \
                set(p1["collective_bytes_per_device"]) | \
                set(p2["collective_bytes_per_device"])
            for k in allk:
                corrected["collective_bytes_per_device"][k] = (
                    main["collective_bytes_per_device"].get(k, 0.0)
                    + extra * (p2["collective_bytes_per_device"].get(k, 0.0)
                               - p1["collective_bytes_per_device"].get(k, 0.0)))

    return {
        "status": "ok",
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "perf_flags": os.environ.get("REPRO_PERF", ""),
        "n_devices": 512 if multi_pod else 256,
        "scan_reps": plan.reps,
        **{k: main[k] for k in ("compile_seconds", "memory", "hlo_chars")},
        "raw": {k: main[k] for k in ("flops", "bytes_accessed",
                                     "transcendentals",
                                     "collective_bytes_per_device")},
        "probe": probe,
        "flops": corrected["flops"],
        "bytes_accessed": corrected["bytes_accessed"],
        "transcendentals": corrected["transcendentals"],
        "collective_bytes_per_device": corrected["collective_bytes_per_device"],
    }


def _serve_param_args(cfg, bundle, mesh):
    boxed = jax.eval_shape(lambda k: init_model(cfg, k), jax.random.key(0))
    specs = bundle.param_specs(mesh)
    params_abs = jax.tree.map(
        lambda b, s: jax.ShapeDtypeStruct(b.value.shape, b.value.dtype,
                                          sharding=NamedSharding(mesh, s)),
        boxed, specs,
        is_leaf=lambda x: hasattr(x, "value") and hasattr(x, "axes"))
    return params_abs


def _memory_abstract(cfg, shape, mesh, batch):
    if cfg.vision is not None:
        sh = (batch, cfg.vision.n_image_tokens, cfg.d_model)
    elif cfg.encoder is not None:
        sh = (batch, max(1, shape.seq_len // cfg.encoder.frame_ratio), cfg.d_model)
    else:
        return None
    return jax.ShapeDtypeStruct(sh, jnp.bfloat16,
                                sharding=NamedSharding(
                                    mesh, _shard_batch_spec(mesh, batch)))


def _output_shardings(cfg, mesh, logits_shape, cache_shape):
    """(logits, cache) NamedShardings from logical axes."""
    lspec = resolve_specs(("batch", None, "vocab"), DEFAULT_RULES, mesh,
                          tuple(logits_shape.shape))
    cache_axes = cache_logical_axes(cache_shape)
    cache_specs = jax.tree.map(
        lambda l, a: resolve_specs(a, DEFAULT_RULES, mesh, tuple(l.shape)),
        cache_shape, cache_axes,
        is_leaf=lambda x: hasattr(x, "shape") and hasattr(x, "dtype"))
    to_ns = lambda s: NamedSharding(mesh, s)
    return to_ns(lspec), jax.tree.map(to_ns, cache_specs), cache_specs


def _lower_prefill(cfg, bundle, shape: ShapeConfig, mesh):
    b = shape.global_batch
    tok = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32,
                               sharding=NamedSharding(
                                   mesh, P(*_shard_batch_spec(mesh, b), None)))
    params_abs = _serve_param_args(cfg, bundle, mesh)
    mem = _memory_abstract(cfg, shape, mesh, b)

    def prefill(params, tokens, memory):
        return bundle.prefill(params, tokens, memory=memory, mesh=mesh)

    logits_shape, cache_shape = jax.eval_shape(prefill, params_abs, tok, mem)
    lsh, csh, _ = _output_shardings(cfg, mesh, logits_shape, cache_shape)
    return jax.jit(prefill, out_shardings=(lsh, csh)).lower(
        params_abs, tok, mem)


def _lower_decode(cfg, bundle, shape: ShapeConfig, mesh):
    b = shape.global_batch
    params_abs = _serve_param_args(cfg, bundle, mesh)
    mem = _memory_abstract(cfg, shape, mesh, b)
    bspec = _shard_batch_spec(mesh, b)

    # cache structure: eval_shape of a prefill at the cache's context length
    ctx = shape.seq_len if cfg.window is None else min(shape.seq_len, cfg.window)
    def _pf(params, tokens, memory):
        return bundle.prefill(params, tokens, memory=memory, mesh=None,
                              cache_slots=ctx)
    tok_for_cache = jax.ShapeDtypeStruct((b, ctx), jnp.int32)
    logits_sh, cache_shape = jax.eval_shape(_pf, params_abs, tok_for_cache, mem)
    lsh, csh, cache_specs = _output_shardings(cfg, mesh, logits_sh, cache_shape)
    cache_abs = _abstract(cache_shape, cache_specs, mesh)

    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                               sharding=NamedSharding(mesh, P(*bspec, None)))
    pos = jax.ShapeDtypeStruct((b, 1), jnp.int32,
                               sharding=NamedSharding(mesh, P(*bspec, None)))

    def decode(params, cache, tokens, positions):
        return bundle.decode_step(params, cache, tokens, positions, mesh=mesh)

    dec_logits_sh = NamedSharding(mesh, resolve_specs(
        ("batch", None, "vocab"), DEFAULT_RULES, mesh, (b, 1, cfg.vocab)))
    return jax.jit(decode, out_shardings=(dec_logits_sh, csh)).lower(
        params_abs, cache_abs, tok, pos)


def cell_path(arch, shape_name, mesh_name):
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(OUT_DIR, f"{arch}__{shape_name}__{mesh_name}.json")


def run_cell(arch, shape_name, multi_pod, force=False):
    mesh_name = "pod2" if multi_pod else "pod1"
    path = cell_path(arch, shape_name, mesh_name)
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    t0 = time.time()
    try:
        result = lower_cell(arch, shape_name, multi_pod)
    except Exception as e:  # record failures — they are bugs to fix
        result = {"status": "error", "arch": arch, "shape": shape_name,
                  "mesh": mesh_name, "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    result["wall_seconds"] = round(time.time() - t0, 1)
    with open(path, "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=[*SHAPES, None])
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    meshes = {"pod1": [False], "pod2": [True], "both": [False, True]}[args.mesh]
    cells = []
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    for a in archs:
        for s in shapes:
            for mp in meshes:
                cells.append((a, s, mp))
    ok = err = skip = 0
    for a, s, mp in cells:
        r = run_cell(a, s, mp, force=args.force)
        tag = r["status"]
        ok += tag == "ok"
        err += tag == "error"
        skip += tag == "skipped"
        msg = r.get("error", "")[:120] if tag == "error" else (
            f"flops={r.get('flops', 0):.3e} "
            f"coll={r.get('collective_bytes_per_device', {}).get('total', 0):.3e}B"
            if tag == "ok" else r.get("reason", ""))
        print(f"[{tag:7s}] {a:24s} {s:12s} {'pod2' if mp else 'pod1'}  {msg}",
              flush=True)
        if tag == "ok":
            print(f"          memory/device: "
                  f"args={r['memory']['argument_bytes']/2**30:.2f}GiB "
                  f"temp={r['memory']['temp_bytes']/2**30:.2f}GiB "
                  f"compile={r['compile_seconds']}s", flush=True)
    print(f"done: {ok} ok, {skip} skipped, {err} errors")
    sys.exit(1 if err else 0)


if __name__ == "__main__":
    main()
