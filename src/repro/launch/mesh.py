"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): 16x16 = 256 chips per pod, 2 pods = 512 chips multi-pod.
The 'pod' axis is the slow (DCN / projective-fabric) dimension — DP and
optionally pipeline stages map onto it; 'data'/'model' are intra-pod ICI.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — for tests."""
    import numpy as np
    devs = np.array(jax.devices()[: data * model]).reshape(data, model)
    return jax.sharding.Mesh(devs, ("data", "model"))
