from .pipeline import DataConfig, batch_specs, host_shard_batch, synthetic_batch
