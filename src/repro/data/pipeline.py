"""Deterministic, resumable, sharding-aware synthetic LM data pipeline.

Tokens are a counter-mode hash of (seed, step, position) — any host can
materialize exactly its shard of any step without coordination, which is
what makes checkpoint-resume and elastic re-sharding exact: the pipeline
has no state beyond the integer ``step``.

A real deployment would swap `synthetic_batch` for a tokenized shard reader
with the same (step -> batch) contract; everything downstream (trainer,
checkpointing, elasticity) only sees the contract.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["DataConfig", "synthetic_batch", "host_shard_batch", "batch_specs"]


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    memory_tokens: int = 0     # vlm/audio stub frontend length
    d_model: int = 0


def _hash_u32(x: np.ndarray) -> np.ndarray:
    """splitmix32-style avalanche, vectorized."""
    x = (x ^ (x >> np.uint32(16))) * np.uint32(0x7FEB352D)
    x &= np.uint32(0xFFFFFFFF)
    x = (x ^ (x >> np.uint32(15))) * np.uint32(0x846CA68B)
    x &= np.uint32(0xFFFFFFFF)
    return x ^ (x >> np.uint32(16))


def synthetic_batch(cfg: DataConfig, step: int, rows: slice | None = None):
    """Materialize (a slice of) the global batch for `step` as numpy.

    Content has Zipf-ish marginals + short-range correlation so losses are
    non-trivially learnable (models can beat the unigram entropy).
    """
    rows = rows if rows is not None else slice(0, cfg.global_batch)
    r0, r1 = rows.start, rows.stop
    b = r1 - r0
    pos = np.arange(cfg.seq_len, dtype=np.uint32)[None, :]
    row = np.arange(r0, r1, dtype=np.uint32)[:, None]
    base = _hash_u32(np.uint32(cfg.seed) ^ _hash_u32(
        np.uint32(step) + np.uint32(0x9E3779B9) * row))
    raw = _hash_u32(base + pos * np.uint32(0x85EBCA6B))
    # Zipf-ish: square the uniform to concentrate mass at small ids
    u = raw.astype(np.float64) / 2**32
    tok = np.minimum((u * u * cfg.vocab).astype(np.int32), cfg.vocab - 1)
    # short-range correlation: every third token repeats its predecessor
    tok[:, 2::3] = tok[:, 1::3][:, : tok[:, 2::3].shape[1]]
    out = {"tokens": tok}
    if cfg.memory_tokens:
        mem_raw = _hash_u32(base[:, :1] + np.arange(
            cfg.memory_tokens * cfg.d_model, dtype=np.uint32)[None, :])
        mem = (mem_raw.astype(np.float32) / 2**31 - 1.0).reshape(
            b, cfg.memory_tokens, cfg.d_model)
        out["memory"] = mem.astype(np.float32)
    return out


def host_shard_batch(cfg: DataConfig, step: int, host_id: int, n_hosts: int):
    """The rows this host owns — the multi-host contract."""
    per = cfg.global_batch // n_hosts
    return synthetic_batch(cfg, step, slice(host_id * per, (host_id + 1) * per))


def batch_specs(cfg: DataConfig):
    s = {"tokens": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len), jnp.int32)}
    if cfg.memory_tokens:
        s["memory"] = jax.ShapeDtypeStruct(
            (cfg.global_batch, cfg.memory_tokens, cfg.d_model), jnp.bfloat16)
    return s
