"""Train-step factory: builds the jitted, sharded train step for an arch on
a mesh, with the distribution features switchable per config:

* plain DP+TP+EP (GSPMD-inserted all-reduce), or
* ZeRO-1 ``bucketed_rs`` mode: reduce-scatter grads + all-gather updates
  (collective bytes halve vs. all-reduce at scale),
* optional error-feedback int8 gradient compression (ef8),
* remat / scan-over-layers come from the ArchConfig.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ArchConfig
from ..models import DEFAULT_RULES, ShardingRules, boxed_specs, build, unbox
from ..optim import (AdamWConfig, adamw_init, adamw_update, ef_compress_grads,
                     ef_init)

__all__ = ["TrainState", "TrainStepConfig", "make_train_state_specs",
           "make_train_step", "init_train_state"]


@dataclass(frozen=True)
class TrainStepConfig:
    optimizer: AdamWConfig = AdamWConfig()
    grad_compress: bool = False       # error-feedback int8
    zero1: bool = False               # reduce-scatter/all-gather grad path
    rules: ShardingRules = DEFAULT_RULES


class TrainState(dict):
    """params / opt (m, v, count) / step / ef_errors (optional)."""


def _opt_cfg(cfg: ArchConfig, ts: TrainStepConfig) -> AdamWConfig:
    """bf16 AdamW moments for bf16-param archs (671B-scale memory)."""
    import dataclasses
    if cfg.bf16_params and ts.optimizer.state_dtype == jnp.float32:
        return dataclasses.replace(ts.optimizer, state_dtype=jnp.bfloat16)
    return ts.optimizer


def init_train_state(cfg: ArchConfig, key, ts: TrainStepConfig) -> dict:
    bundle = build(cfg)
    params = unbox(bundle.init(key))
    state = {"params": params,
             "opt": adamw_init(params, _opt_cfg(cfg, ts))._asdict(),
             "step": jnp.zeros((), jnp.int32)}
    if ts.grad_compress:
        state["ef"] = ef_init(params)
    return state


def make_train_state_specs(cfg: ArchConfig, mesh: Mesh, ts: TrainStepConfig):
    """PartitionSpec pytree for the full train state."""
    bundle = build(cfg)
    pspecs = bundle.param_specs(mesh, ts.rules)
    specs = {"params": pspecs,
             "opt": {"m": pspecs, "v": pspecs, "count": P()},
             "step": P()}
    if ts.grad_compress:
        specs["ef"] = pspecs
    return specs


def batch_pspec(mesh: Mesh) -> P:
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return P(axes or None)


def make_train_step(cfg: ArchConfig, mesh: Mesh,
                    ts: TrainStepConfig = TrainStepConfig(),
                    donate: bool = True):
    """Returns (step_fn, state_specs, batch_specs_fn). step_fn(state, batch)
    -> (state, metrics); jit with shardings attached."""
    bundle = build(cfg)
    state_specs = make_train_state_specs(cfg, mesh, ts)
    from ..optim.adamw import AdamWState

    def loss_wrapper(params, batch):
        return bundle.loss(params, batch, mesh=mesh)

    def _value_and_grad(params, batch):
        from ..perf import flags
        mb = flags().microbatch
        bsz = batch["tokens"].shape[0]
        if mb <= 1 or bsz % mb:
            return jax.value_and_grad(loss_wrapper, has_aux=True)(params, batch)
        # gradient accumulation over microbatches: live activation temp ÷ mb,
        # grads reduced/updated once.  Microbatches are re-constrained to the
        # full DP sharding (the reshape alone would pin each microbatch to a
        # subset of the data axis); tokens are tiny so the reshard is cheap.
        bspec = batch_pspec(mesh)
        split = jax.tree.map(
            lambda x: jax.lax.with_sharding_constraint(
                x.reshape(mb, bsz // mb, *x.shape[1:]),
                NamedSharding(mesh, P(None, *bspec))), batch)

        def micro(carry, mbatch):
            g_acc, loss_acc, aux = carry
            (loss, metrics), g = jax.value_and_grad(
                loss_wrapper, has_aux=True)(params, mbatch)
            g_acc = jax.tree.map(lambda a, b: a + b / mb, g_acc, g)
            return (g_acc, loss_acc + loss / mb,
                    jax.tree.map(lambda a, b: a + b / mb, aux, metrics)), None

        g0 = jax.tree.map(jnp.zeros_like, params)
        probe = jax.eval_shape(
            lambda p, b: loss_wrapper(p, b)[1], params,
            jax.tree.map(lambda x: x[0], split))
        m0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), probe)
        # unroll: exact AOT cost accounting (a while-op body is counted once
        # by XLA cost_analysis) — and the unrolled grad-accum loop lets the
        # scheduler overlap one microbatch's collectives with the next's
        # compute on the real target
        (grads, loss, metrics), _ = jax.lax.scan(
            micro, (g0, jnp.zeros((), jnp.float32), m0), split, unroll=True)
        return (loss, metrics), grads

    def step_fn(state, batch):
        params = state["params"]
        (loss, metrics), grads = _value_and_grad(params, batch)
        if ts.grad_compress:
            grads, new_ef = ef_compress_grads(grads, state["ef"])
        opt_state = AdamWState(state["opt"]["m"], state["opt"]["v"],
                               state["opt"]["count"])
        if ts.zero1:
            # ZeRO-1: shard otherwise-replicated grads over the data axis so
            # the DP reduction lowers to reduce-scatter, the optimizer update
            # runs sharded, and the param refresh is an all-gather.
            dsize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
            gspecs = state_specs["params"]

            def z1(g, s):
                replicated = all(a is None for a in (tuple(s) or (None,)))
                if replicated and g.ndim and g.shape[0] % dsize == 0 and dsize > 1:
                    return jax.lax.with_sharding_constraint(
                        g, NamedSharding(mesh, P(*(("data",)
                                                   + (None,) * (g.ndim - 1)))))
                return g
            grads = jax.tree.map(z1, grads, gspecs)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, _opt_cfg(cfg, ts))
        new_state = {"params": new_params, "opt": new_opt._asdict(),
                     "step": state["step"] + 1}
        if ts.grad_compress:
            new_state["ef"] = new_ef
        metrics = {"loss": loss, **metrics, **opt_metrics}
        return new_state, metrics

    bspec = batch_pspec(mesh)
    in_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs),
        {"tokens": NamedSharding(mesh, bspec),
         **({"memory": NamedSharding(mesh, bspec)}
            if (cfg.vision or cfg.encoder) else {})},
    )
    out_shardings = (
        jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs),
        NamedSharding(mesh, P()),
    )
    jitted = jax.jit(step_fn, in_shardings=in_shardings,
                     out_shardings=out_shardings,
                     donate_argnums=(0,) if donate else ())
    return jitted, state_specs
