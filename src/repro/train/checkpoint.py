"""Sharded, atomic, async checkpointing (fault-tolerance substrate).

Layout:  <dir>/step_<N>/shard_<host>.npz + MANIFEST.json, written to a
``.tmp`` sibling and renamed only after fsync — a crash mid-write never
corrupts the latest-complete checkpoint.  ``restore`` picks the newest
step with a complete manifest.  The async writer overlaps serialization
with the next training steps and is joined before the next save (or at
exit), bounding staleness to one checkpoint.

Single-process here (host 0 owns everything); the shard split is by
flattened-leaf index so a k-host restore redistributes cleanly.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from dataclasses import dataclass

import jax
import numpy as np

__all__ = ["CheckpointManager", "save_checkpoint", "restore_checkpoint",
           "latest_step"]


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
             for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save_checkpoint(directory: str, step: int, state, *, n_shards: int = 1,
                    extra_meta: dict | None = None) -> str:
    names, leaves, _ = _flatten_with_names(state)
    host_leaves = [np.asarray(l) for l in leaves]
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    per = max(1, (len(names) + n_shards - 1) // n_shards)
    shard_files = []
    for s in range(n_shards):
        lo, hi = s * per, min((s + 1) * per, len(names))
        if lo >= hi and s > 0:
            break
        payload = {f"arr_{i}": host_leaves[i] for i in range(lo, hi)}
        fn = os.path.join(tmp, f"shard_{s:04d}.npz")
        np.savez(fn, **payload)
        shard_files.append((os.path.basename(fn), lo, hi))
    manifest = {"step": step, "names": names,
                "shards": shard_files, "time": time.time(),
                **(extra_meta or {})}
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(directory, d, "MANIFEST.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, state_like, step: int | None = None):
    """Restore into the structure of `state_like` (shapes validated)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {directory}")
    d = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(d, "MANIFEST.json")) as f:
        manifest = json.load(f)
    names, leaves, treedef = _flatten_with_names(state_like)
    if names != manifest["names"]:
        raise ValueError("checkpoint/state structure mismatch: "
                         f"{set(names) ^ set(manifest['names'])}")
    arrays: dict[int, np.ndarray] = {}
    for fn, lo, hi in manifest["shards"]:
        with np.load(os.path.join(d, fn)) as z:
            for i in range(lo, hi):
                arrays[i] = z[f"arr_{i}"]
    out_leaves = []
    for i, like in enumerate(leaves):
        arr = arrays[i]
        if tuple(arr.shape) != tuple(like.shape):
            raise ValueError(f"shape mismatch for {names[i]}: "
                             f"{arr.shape} vs {like.shape}")
        out_leaves.append(arr.astype(like.dtype))
    return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest


@dataclass
class CheckpointManager:
    """Async double-buffered writer with bounded staleness."""

    directory: str
    keep: int = 3
    n_shards: int = 1
    _thread: threading.Thread | None = None
    _last_path: str | None = None

    def save_async(self, step: int, state, extra_meta: dict | None = None):
        self.join()
        host_state = jax.tree.map(np.asarray, state)  # snapshot off-device

        def work():
            self._last_path = save_checkpoint(
                self.directory, step, host_state, n_shards=self.n_shards,
                extra_meta=extra_meta)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def join(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.directory)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
