from .checkpoint import CheckpointManager, latest_step, restore_checkpoint, save_checkpoint
from .elastic import largest_submesh_shape, remesh, reshard_state
from .train_step import (TrainStepConfig, init_train_state, make_train_state_specs,
                         make_train_step)
from .trainer import Trainer, TrainerConfig
