"""Training loop with the fault-tolerance features a 1000-node run needs:

* periodic async checkpoints + exact resume (step, rng, data cursor are all
  pure functions of the saved integer step);
* straggler mitigation: per-step deadline watchdog — a step exceeding
  ``straggler_factor`` x the rolling median is recorded and surfaced (on a
  real cluster the same hook triggers hot-spare swap; here it is exercised
  by fault-injection tests);
* elastic re-meshing: on (simulated) host loss, rebuild the largest valid
  submesh, re-resolve shardings, and restore from the last checkpoint —
  `elastic.py` owns the mesh math; the trainer just calls it.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import numpy as np
from jax.sharding import Mesh

from .. import obs
from ..configs.base import ArchConfig
from ..data import DataConfig, synthetic_batch
from .checkpoint import CheckpointManager, latest_step, restore_checkpoint
from .train_step import TrainStepConfig, init_train_state, make_train_step

__all__ = ["TrainerConfig", "Trainer", "StepStats"]


@dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    log_every: int = 10
    straggler_factor: float = 3.0
    keep_checkpoints: int = 3


@dataclass
class StepStats:
    step: int
    loss: float
    seconds: float
    straggler: bool


@dataclass
class Trainer:
    cfg: ArchConfig
    data: DataConfig
    mesh: Mesh
    tcfg: TrainerConfig = field(default_factory=TrainerConfig)
    scfg: TrainStepConfig = field(default_factory=TrainStepConfig)
    fault_hook: Callable[[int], str | None] | None = None  # test injection

    def __post_init__(self):
        self.step_fn, self.state_specs = make_train_step(
            self.cfg, self.mesh, self.scfg)
        self.ckpt = CheckpointManager(self.tcfg.checkpoint_dir,
                                      keep=self.tcfg.keep_checkpoints)
        self.history: list[StepStats] = []
        self.straggler_steps: list[int] = []
        self.restarts: int = 0

    # -- state ---------------------------------------------------------
    def fresh_state(self, seed: int = 0):
        return init_train_state(self.cfg, jax.random.key(seed), self.scfg)

    def resume_or_init(self, seed: int = 0):
        state = self.fresh_state(seed)
        last = latest_step(self.tcfg.checkpoint_dir)
        if last is not None:
            state, manifest = restore_checkpoint(
                self.tcfg.checkpoint_dir, state, last)
            print(f"[trainer] resumed from step {last}")
        return state

    # -- loop ----------------------------------------------------------
    def run(self, state=None, seed: int = 0):
        state = state if state is not None else self.resume_or_init(seed)
        step = int(np.asarray(state["step"]))
        durations: list[float] = []
        while step < self.tcfg.total_steps:
            # straggler watchdog times the WHOLE iteration (input pipeline +
            # step + any stall), not just the jitted step — that is what a
            # deadline-based hot-spare policy sees on a real cluster.
            # obs.timed closes AFTER block_until_ready on the new state:
            # jax dispatches the step asynchronously, so a bare
            # perf_counter bracket that only syncs the scalar loss
            # under-measures the step (param updates still in flight)
            sp = obs.timed("train.step", step=step)
            with sp:
                if self.fault_hook is not None:
                    fault = self.fault_hook(step)
                    if fault == "crash":
                        # simulate process death: drop in-memory state; a
                        # real restart re-enters run() and resumes from
                        # checkpoint, REPLAYING from the checkpointed step
                        # (the data pipeline is a pure function of step,
                        # so the replay is exact)
                        self.ckpt.join()
                        self.restarts += 1
                        state = self.resume_or_init(seed)
                        step = int(np.asarray(state["step"]))
                        continue
                batch = self._device_batch(step)
                state, metrics = self.step_fn(state, batch)
                sp.sync(state, metrics)
            loss = float(np.asarray(metrics["loss"]))  # already on host
            dt = sp.seconds
            straggler = False
            if len(durations) >= 5:
                med = float(np.median(durations[-20:]))
                if dt > self.tcfg.straggler_factor * med:
                    straggler = True
                    self.straggler_steps.append(step)
            durations.append(dt)
            self.history.append(StepStats(step, loss, dt, straggler))
            if step % self.tcfg.log_every == 0 or step == self.tcfg.total_steps - 1:
                print(f"[trainer] step {step:5d} loss {loss:.4f} "
                      f"{dt*1e3:7.1f} ms{'  STRAGGLER' if straggler else ''}")
            if (step + 1) % self.tcfg.checkpoint_every == 0:
                self.ckpt.save_async(step + 1, state,
                                     extra_meta={"arch": self.cfg.name})
            step += 1
        self.ckpt.join()
        return state

    def _device_batch(self, step: int):
        host = synthetic_batch(self.data, step)
        batch = {"tokens": jax.numpy.asarray(host["tokens"])}
        if "memory" in host:
            batch["memory"] = jax.numpy.asarray(host["memory"],
                                                jax.numpy.bfloat16)
        return batch
