"""Elastic re-meshing: recover the largest valid production mesh after node
loss and re-shard a checkpointed state onto it.

The contract a 1000-node deployment needs:
  1. detect the surviving device set,
  2. choose the largest (pod, data, model) mesh the survivors can form while
     keeping the model-axis size (TP/EP degree must not change — weights are
     sharded by it); data/pod axes absorb the loss,
  3. recompute shardings from the SAME logical rules and restore from the
     last checkpoint (the data pipeline is a pure function of step, so no
     input state is lost).

On CPU we exercise the same code path with host-platform device counts.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["largest_submesh_shape", "remesh"]


def largest_submesh_shape(n_devices: int, model_axis: int,
                          prefer_pods: int = 2) -> tuple[int, ...]:
    """Largest (pod, data, model) with pod*data*model <= n_devices, model
    fixed, pod in {prefer_pods, ..., 1}, data maximal."""
    if n_devices < model_axis:
        raise ValueError(f"cannot keep model axis {model_axis} with only "
                         f"{n_devices} devices")
    for pods in range(prefer_pods, 0, -1):
        data = n_devices // (model_axis * pods)
        if data >= 1:
            if pods == 1:
                return (data, model_axis)
            return (pods, data, model_axis)
    raise ValueError("no valid submesh")


def remesh(devices, model_axis: int, prefer_pods: int = 2) -> Mesh:
    """Build the survivor mesh from an explicit device list."""
    shape = largest_submesh_shape(len(devices), model_axis, prefer_pods)
    n = int(np.prod(shape))
    dev = np.asarray(devices[:n]).reshape(shape)
    names = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    return Mesh(dev, names)


def reshard_state(state, mesh: Mesh, state_specs):
    """Place a host-restored state onto a (new) mesh per the same specs."""
    from jax.sharding import NamedSharding

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))
    return jax.tree.map(put, state, state_specs)
