"""AdamW in pure JAX with sharding-preserving pytree states.

The moment dtypes are configurable (``state_dtype``) — at 671B on 512 chips
fp32 (m, v) alone is 10.5 GB/chip, so the deepseek config runs bf16 moments
(an error <1e-3 relative on the update; validated in tests against fp32).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float | Callable[[jnp.ndarray], jnp.ndarray] = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: Any = jnp.float32


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p, dtype=cfg.state_dtype)
    return AdamWState(m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def adamw_update(grads, state: AdamWState, params,
                 cfg: AdamWConfig = AdamWConfig()):
    """Returns (new_params, new_state, metrics)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else 1.0
    lr = cfg.lr(count) if callable(cfg.lr) else cfg.lr
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        update = (m32 / b1c) / (jnp.sqrt(v32 / b2c) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (update + cfg.weight_decay * p32)
        return (p_new.astype(p.dtype), m32.astype(cfg.state_dtype),
                v32.astype(cfg.state_dtype))

    flat = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], flat,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(new_m, new_v, count), \
        {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr
