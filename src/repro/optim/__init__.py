from .adamw import (AdamWConfig, AdamWState, adamw_init, adamw_update,
                    cosine_schedule, global_norm)
from .compress import compress, decompress, ef_compress_grads, ef_init

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update",
           "cosine_schedule", "global_norm", "compress", "decompress",
           "ef_compress_grads", "ef_init"]
