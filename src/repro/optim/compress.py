"""Error-feedback gradient compression (a distributed-optimization trick).

int8 block-quantized gradients with a persistent error accumulator: the
quantization residual is fed back into the next step's gradient, which keeps
SGD/Adam convergence (Karimireddy et al.-style EF).  Used as an optional
stage before the gradient all-reduce to cut DP collective bytes 4x
(fp32->int8) / 2x (bf16->int8).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ef_init", "compress", "decompress", "ef_compress_grads"]

BLOCK = 256


def ef_init(params):
    return jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), pad


def compress(g):
    """fp grad -> (int8 codes, per-block fp32 scales, pad)."""
    flat, pad = _pad_to_block(g.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.where(scale == 0, 1.0, scale)
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return codes, scale, pad


def decompress(codes, scale, pad, shape):
    flat = (codes.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def ef_compress_grads(grads, errors):
    """Apply error feedback + quantize round-trip to a grad pytree.
    Returns (compressed-then-decompressed grads, new error accumulators).
    In a multi-host deployment the int8 codes are what crosses the wire."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        codes, scale, pad = compress(corrected)
        approx = decompress(codes, scale, pad, g.shape)
        return approx.astype(g.dtype), corrected - approx
    out = jax.tree.map(one, grads, errors)
    g_new = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    e_new = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return g_new, e_new
