"""Fabric models: a physical interconnect = topology graph + link rate +
terminals per router.  The paper's saturation analysis (Eq. 1: per-node
injection bandwidth a = Δ·u/k̄ link-equivalents) prices uniform-traffic
collectives on any fabric; a 3D torus builder covers the TPU-pod reference
point.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core import Graph, utilization
from ..core.reference import dragonfly_canonical_stats

__all__ = ["FabricModel", "torus3d_graph", "make_fabric"]


def torus3d_graph(x: int, y: int, z: int) -> Graph:
    """3D torus (TPU-pod ICI reference). Wrap links dropped for dims < 3."""
    n = x * y * z
    coords = np.stack(np.unravel_index(np.arange(n), (x, y, z)), 1)
    edges = []
    for d, size in enumerate((x, y, z)):
        if size == 1:
            continue
        nxt = coords.copy()
        nxt[:, d] = (nxt[:, d] + 1) % size
        dst = np.ravel_multi_index((nxt[:, 0], nxt[:, 1], nxt[:, 2]), (x, y, z))
        mask = np.ones(n, dtype=bool)
        if size == 2:  # avoid doubled edge on wrap of size-2 dims
            mask = coords[:, d] == 0
        edges.append(np.stack([np.arange(n)[mask], dst[mask]], 1))
    g = Graph(n, np.concatenate(edges), name=f"torus3d({x},{y},{z})")
    g.meta.update(family="torus3d", dims=(x, y, z))
    return g


@dataclass
class FabricModel:
    graph: Graph
    link_gbps: float = 400.0          # per-link, each direction (50 GB/s)
    terminals_per_router: float = 1.0
    kbar: float | None = None
    u: float | None = None
    name: str = ""

    def __post_init__(self):
        if self.kbar is None or self.u is None:
            if self.graph.meta.get("family") == "dragonfly":
                # canonical (l-g-l) routing, per the paper's Table 2 convention
                self.kbar, self.u = dragonfly_canonical_stats(self.graph.meta["h"])
            else:
                sources = None
                if self.graph.n > 3000:  # sample sources for very large graphs
                    rng = np.random.default_rng(0)
                    sources = rng.choice(self.graph.n, 256, replace=False)
                rep = utilization(self.graph, sources=sources)
                self.kbar, self.u = rep.kbar, rep.u
        if not self.name:
            self.name = self.graph.name

    @property
    def link_bytes_per_s(self) -> float:
        return self.link_gbps * 1e9 / 8

    @property
    def injection_links(self) -> float:
        """Eq. (1): per-ROUTER saturation injection bandwidth under uniform
        traffic, in link-equivalents: a = Δ·u/k̄."""
        return self.graph.max_degree * self.u / self.kbar

    @property
    def node_uniform_bw(self) -> float:
        """bytes/s each TERMINAL can inject at saturation (uniform traffic)."""
        return self.injection_links * self.link_bytes_per_s / self.terminals_per_router

    # Beyond this size the dense (N, N) demand matrices of the pattern
    # engine stop being the right tool (25k routers = 5 GB per matrix);
    # evaluate patterns on a representative smaller instance instead.
    PATTERN_MAX_N = 8192

    def pattern_report(self, pattern, routing: str = "minimal"):
        """Saturation analysis of one traffic pattern on this fabric
        (repro.core.traffic), cached per (spec, routing) for registry-spec
        strings (ad-hoc TrafficPattern objects are evaluated fresh).

        ``routing`` is any registered routing model (repro.core.routing):
        "minimal", "valiant", "ugal", "ugal(source)", ...  Non-uniform
        patterns always use the model's own path accounting, including on
        dragonfly — the canonical l-g-l convention this model applies to
        dragonfly's UNIFORM stats has no published per-pattern
        counterpart."""
        from ..core.traffic import make_pattern, saturation_report
        if self.graph.n > self.PATTERN_MAX_N:
            raise ValueError(
                f"pattern saturation needs dense (N, N) demand matrices; "
                f"N={self.graph.n} > {self.PATTERN_MAX_N}.  Evaluate the "
                f"pattern on a smaller instance of the same family.")
        pat = make_pattern(pattern)
        # spec strings key by value; ad-hoc TrafficPattern objects by
        # identity (the cached entry keeps the object alive, so its id is
        # stable) — repeated collective_time calls with the same object
        # then pay one saturation analysis, and a different object that
        # happens to reuse a registry name cannot alias a stale entry
        key = ((pattern, routing) if isinstance(pattern, str)
               else (id(pat), routing))
        cache = self.graph._struct_cache.setdefault("fabric_patterns", {})
        if key not in cache:
            cache[key] = (pat, saturation_report(self.graph, pat,
                                                 routing=routing))
        return cache[key][1]

    def _is_uniform(self, pattern) -> bool:
        from ..core.traffic import make_pattern
        return make_pattern(pattern).name == "uniform"

    @staticmethod
    def _uniform_routing_kind(routing) -> str:
        """Classify a routing spec for the uniform fast path: "minimal"
        (also any UGAL blend — on uniform traffic the Valiant loads are
        exactly 2x the minimal loads, so the theta-maximizing blend is
        alpha = 1, pure minimal), "valiant", or "other" (unknown models
        evaluate through pattern_report)."""
        from ..core.routing import make_routing
        name = make_routing(routing).name  # validates the spec
        if name == "valiant":
            return "valiant"
        if name in ("minimal", "ugal", "ugal(source)") \
                or name.startswith("ugal_threshold"):
            # every threshold variant shares the blend's uniform identity
            # (alpha = 1 for finite T, minimal outright for T = inf)
            return "minimal"
        return "other"

    def pattern_node_bw(self, pattern, routing: str = "minimal") -> float:
        """bytes/s each TERMINAL can inject at saturation under an arbitrary
        traffic pattern — the generalized Eq. (1): theta replaces Δ·u/k̄.

        The uniform pattern routes through ``node_uniform_bw`` so fabric
        conventions are preserved exactly: dragonfly keeps its canonical
        l-g-l Table-2 stats (shortest-path theta is ~35% lower there) and
        Eq. 1's Δ (not mean-degree) convention holds on irregular graphs;
        Valiant halves it, and UGAL reduces to minimal (blend alpha = 1 on
        uniform traffic), per the uniform two-phase identity."""
        if self._is_uniform(pattern):
            kind = self._uniform_routing_kind(routing)
            if kind != "other":
                bw = self.node_uniform_bw
                return bw / 2.0 if kind == "valiant" else bw
        rep = self.pattern_report(pattern, routing)
        return rep.theta * self.link_bytes_per_s / self.terminals_per_router

    def place(self, mesh_shape, axis_names, strategy="group", seed: int = 0,
              schedule=None, routing="minimal"):
        """Place a (pod, data, model)-shaped chip mesh on this fabric via
        a registered placement strategy (fabric.placement)."""
        from .placement import place_mesh
        return place_mesh(self.graph, mesh_shape, axis_names,
                          int(self.terminals_per_router), strategy,
                          seed=seed, schedule=schedule, routing=routing)

    def placement_report(self, profile, placement, routing: str = "ugal",
                         engine: str | None = None):
        """Saturation analysis of one (StepProfile, Placement) pair under
        a routing model: theta of the placement's router-level demand
        matrix in Eq. 1's link-equivalent units (fabric.placement)."""
        from .placement import placement_report
        if self.graph.n > self.PATTERN_MAX_N:
            raise ValueError(
                f"placement saturation needs dense (N, N) demand matrices; "
                f"N={self.graph.n} > {self.PATTERN_MAX_N}.")
        return placement_report(placement, profile, routing=routing,
                                engine=engine)

    def simulate_pattern(self, pattern, routing: str = "ugal_threshold(0)",
                         offered: float | None = None,
                         steps: int | None = None, config=None):
        """Replay a traffic pattern through the flow-level simulator
        (repro.sim) on this fabric: the measured counterpart of
        ``pattern_report`` — per-hop threshold-UGAL, finite buffers, and
        queueing latency instead of the fluid closed form.  ``offered``
        defaults to 0.9x the matching fluid theta (a stable sub-saturation
        point whose Little's-law latency is meaningful); returns the
        SimRun (theta in link-equivalents, as everywhere)."""
        from ..sim import fluid_routing_spec, simulate
        if self.graph.n > self.PATTERN_MAX_N:
            raise ValueError(
                f"simulation needs dense (router, slot, dest) tensors; "
                f"N={self.graph.n} > {self.PATTERN_MAX_N}.")
        if offered is None:
            offered = 0.9 * self.pattern_report(
                pattern, fluid_routing_spec(routing)).theta
        return simulate(self.graph, pattern, routing=routing,
                        offered=offered, steps=steps, config=config)

    def pattern_kbar(self, pattern, routing: str = "minimal") -> float:
        """Demand-weighted mean hop count under the pattern (2 phases under
        Valiant); prices the latency term of small-message collectives.
        Uniform keeps the fabric's own k̄ convention (see pattern_node_bw)."""
        if self._is_uniform(pattern):
            kind = self._uniform_routing_kind(routing)
            if kind != "other":
                return 2.0 * self.kbar if kind == "valiant" else self.kbar
        return self.pattern_report(pattern, routing).kbar_eff


def make_fabric(kind: str, link_gbps: float = 400.0, **kw) -> FabricModel:
    from ..core import (build_topology, demi_pn_graph, dragonfly_graph,
                        hamming_graph, mms_graph, oft_graph, pn_graph)
    builders = {
        "demi_pn": demi_pn_graph, "pn": pn_graph, "oft": oft_graph,
        "mms": mms_graph, "slimfly": mms_graph, "dragonfly": dragonfly_graph,
        "hamming": hamming_graph, "torus3d": torus3d_graph,
    }
    delta0 = kw.pop("terminals_per_router", 1.0)
    g = builders[kind](*kw.pop("args", ()), **kw)
    return FabricModel(g, link_gbps=link_gbps, terminals_per_router=delta0,
                       name=g.name)
