"""The Section-5 selector, operationalized: given a training job's per-step
collective profile (straight from the dry-run JSONs) and a chip budget,
evaluate candidate fabrics on (a) the paper's $-and-Watts model and (b)
per-step collective time from the saturation model — the full loop from
'compiled XLA program' to 'which network should the cluster buy'.

With a mesh shape, the buy loop goes placement-aware: each candidate
places the job via a registered placement strategy (fabric.placement),
compiles the (profile, placement) pair into a router-level demand matrix,
and prices the step off the busiest link under the routing the fabric
actually runs (default ugal) — the quantity Eq. 1's uniform closed form
approximates.  ``fragmentation_sweep`` compares multi-tenant layouts
(packed vs interleaved vs chip-major linear) at pod scale under optional
background adversary traffic.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core import (DirectNetworkSpec, cable_split, dollars_per_node,
                    electrical_groups, utilization, watts_per_node)
from ..core.reference import dragonfly_canonical_stats
from ..core.routing import make_routing
from .collectives import PER_HOP_LATENCY_S, collective_time
from .model import FabricModel, torus3d_graph
from .placement import (Placement, _assign_slots, _model_major_order,
                        placement_demand)

__all__ = ["FabricCandidate", "candidate_fabrics", "plan", "StepProfile",
           "placement_step_seconds", "fragmentation_sweep"]


@dataclass
class StepProfile:
    """Per-step per-device collective bytes by kind (from the dry-run)."""
    bytes_by_kind: dict
    steps_per_run: int = 1

    @classmethod
    def from_dryrun(cls, record: dict) -> "StepProfile":
        coll = dict(record.get("collective_bytes_per_device", {}))
        coll.pop("total", None)
        return cls(bytes_by_kind=coll)


def placement_step_seconds(fabric: FabricModel, profile, placement: Placement,
                           routing="ugal", engine: str | None = None) -> float:
    """Per-step collective seconds of a PLACED job: the (profile,
    placement) demand matrix is routed under ``routing`` and the busiest
    link's bytes serialize the step (per-arc capacity =
    ``link_bytes_per_s``), plus one demand-weighted hop-latency term per
    collective phase — the placement-aware replacement for the uniform
    Eq. 1 pricing of ``FabricCandidate.step_comm_seconds``."""
    demand = placement_demand(profile, placement)
    by_kind = getattr(profile, "bytes_by_kind", profile)
    n_ops = sum(1 for b in by_kind.values()
                if (b[1] if isinstance(b, tuple) else b))
    if not demand.any():  # every byte stays router-local
        return 0.0
    res = make_routing(routing).evaluate(
        placement.graph, demand, np.arange(placement.graph.n), engine)
    return (float(res.loads.max()) / fabric.link_bytes_per_s
            + n_ops * res.kbar_eff * PER_HOP_LATENCY_S)


@dataclass
class FabricCandidate:
    fabric: FabricModel
    terminals: int
    radix: int
    dollars_per_node: float
    watts_per_node: float

    def step_comm_seconds(self, profile: StepProfile, placement=None,
                          routing="minimal") -> float:
        """Uniform Eq. 1 pricing by default; with a Placement, the
        placement-aware busiest-link pricing of
        :func:`placement_step_seconds` under ``routing``."""
        if placement is not None:
            return placement_step_seconds(self.fabric, profile, placement,
                                          routing=routing)
        n = self.terminals
        return sum(collective_time(self.fabric, kind, b, n).total_s
                   for kind, b in profile.bytes_by_kind.items())


def _mk_candidate(g, delta0, name=None) -> FabricCandidate:
    if g.meta.get("family") == "dragonfly":
        kbar, u = dragonfly_canonical_stats(g.meta["h"])
    else:
        sources = None
        if g.n > 3000:
            sources = np.random.default_rng(0).choice(g.n, 256, replace=False)
        rep = utilization(g, sources=sources)
        kbar, u = rep.kbar, rep.u
    fab = FabricModel(g, terminals_per_router=delta0, kbar=kbar, u=u,
                      name=name or g.name)
    labels = electrical_groups(g, delta0)
    ne, no = cable_split(g, labels)
    leaf = g.meta.get("leaf_mask")
    n_leaf = int(leaf.sum()) if leaf is not None else g.n
    spec = DirectNetworkSpec(
        name=fab.name, terminals=int(round(n_leaf * delta0)),
        radix=int(round(g.max_degree + delta0)),
        routers=g.n, degree=g.max_degree, terminals_per_router=delta0,
        kbar=kbar, u=u, electrical_cables=ne, optical_cables=no)
    return FabricCandidate(fab, spec.terminals, spec.radix,
                           dollars_per_node(spec), watts_per_node(spec))


def candidate_fabrics(min_terminals: int, max_radix: int = 64):
    """Instantiate the main families at the smallest size covering the
    terminal count within the radix budget."""
    from ..core import (demi_pn_graph, dragonfly_graph, hamming_graph,
                        mms_graph, pn_graph)
    from ..core.gf import is_prime_power
    out = []

    def try_family(builder, params, delta0_of, name):
        for p in params:
            try:
                g = builder(p)
            except Exception:
                continue
            d0 = delta0_of(g)
            if g.max_degree + d0 > max_radix:
                continue
            if g.n * d0 >= min_terminals:
                out.append(_mk_candidate(g, d0, name=f"{name}({p})"))
                return

    pps = [q for q in range(3, 80) if is_prime_power(q)]
    try_family(demi_pn_graph, pps, lambda g: (g.meta["q"] + 1) // 2, "demi-PN")
    try_family(pn_graph, pps, lambda g: max(1, round(2 * (g.meta["q"] + 1) / 5)), "PN")
    try_family(mms_graph, [q for q in pps if q % 4 != 2],
               lambda g: max(1, round(4 / 9 * g.max_degree)), "SF-MMS")
    try_family(dragonfly_graph, list(range(2, 17)), lambda g: g.meta["h"],
               "dragonfly")
    try_family(hamming_graph, list(range(4, 40)), lambda g: g.meta["side"],
               "Hamming2D")
    return out


# Beyond this router count a candidate's dense placement demand matrix
# stops being the right tool (FabricModel.PATTERN_MAX_N analogue for the
# buy loop); such candidates keep their uniform Eq. 1 pricing.
PLACEMENT_MAX_N = 2048


def plan(profile: StepProfile, min_terminals: int, max_radix: int = 64,
         mesh_shape=None, axis_names=("model", "data"),
         placement_strategy="group", routing="ugal", seed: int = 0,
         resilience_k: int = 0, resilience_trials: int = 4,
         resilience_seed: int = 0):
    """Rank fabrics by step-communication time and report $/W; returns list
    of dict rows sorted by comm time.

    With ``mesh_shape``, each candidate that can host the job (and has at
    most ``PLACEMENT_MAX_N`` routers) is additionally priced
    placement-aware: the job is placed via ``placement_strategy``, its
    demand matrix routed under ``routing``, and ``placed_comm_ms`` (the
    busiest-link step time) drives the ranking — per-step collective time
    under the congestion the actual schedule causes, not the uniform
    closed form.

    With ``resilience_k > 0``, each candidate with at most
    ``PLACEMENT_MAX_N`` routers also gets a graceful-degradation score:
    ``resilience_theta`` is the WORST uniform-traffic theta over
    ``resilience_trials`` seeded draws of ``resilience_k`` link failures
    (connectivity-preserving, routed under ``routing``), and
    ``resilience_frac`` that worst theta as a fraction of the pristine
    value — how much of the fabric's throughput guarantee survives the
    failure scenario.  Ranking stays by comm time; resilience is a
    reported trade-off column."""
    rows = []
    for cand in candidate_fabrics(min_terminals, max_radix):
        t = cand.step_comm_seconds(profile)
        row = {
            "fabric": cand.fabric.name,
            "terminals": cand.terminals,
            "radix": cand.radix,
            "kbar": round(cand.fabric.kbar, 3),
            "u": round(cand.fabric.u, 3),
            "kbar_over_u": round(cand.fabric.kbar / cand.fabric.u, 3),
            "step_comm_ms": round(t * 1e3, 3),
            "usd_per_node": round(cand.dollars_per_node, 2),
            "watts_per_node": round(cand.watts_per_node, 2),
        }
        if resilience_k > 0 and cand.fabric.graph.n <= PLACEMENT_MAX_N:
            from ..core.faults import degradation_sweep
            sweep = degradation_sweep(
                cand.fabric.graph, k_failures=(int(resilience_k),),
                trials=resilience_trials, pattern="uniform",
                routing=routing, kind="links", seed=resilience_seed)
            worst = float(sweep.worst[0])
            row["resilience_k"] = int(resilience_k)
            row["resilience_theta"] = round(worst, 4)
            row["resilience_frac"] = round(worst / sweep.pristine_theta, 4)
        if mesh_shape is not None:
            n_chips = int(np.prod(mesh_shape))
            g = cand.fabric.graph
            d0 = int(cand.fabric.terminals_per_router)
            if g.n <= PLACEMENT_MAX_N and n_chips <= g.n * d0:
                from .placement import schedule_from_profile
                schedule = schedule_from_profile(profile, tuple(axis_names))
                p = cand.fabric.place(mesh_shape, axis_names,
                                      strategy=placement_strategy, seed=seed,
                                      schedule=schedule, routing=routing)
                placed = placement_step_seconds(cand.fabric, profile, p,
                                                routing=routing)
                row["placed_comm_ms"] = round(placed * 1e3, 3)
                row["placement_strategy"] = placement_strategy
                row["placement_routing"] = routing
        rows.append(row)
    # placed (congestion-aware) and uniform step times are differently
    # modeled quantities: rank placeable candidates first among
    # themselves, un-placeable ones after (by their uniform figure)
    return sorted(rows, key=lambda r: (("placed_comm_ms" not in r)
                                       if mesh_shape is not None else False,
                                       r.get("placed_comm_ms",
                                             r["step_comm_ms"])))


# ---------------------------------------------------------------------------
# Fragmentation at pod scale: multi-tenant layout comparison
# ---------------------------------------------------------------------------

FRAGMENTATION_LAYOUTS = ("packed", "interleaved", "linear")


def _layout_slots(g, jobs, delta0: int, layout: str) -> list[np.ndarray]:
    """Router-slot sequence per job.  ``packed``/``linear`` hand each job
    a contiguous slab of router slots; ``interleaved`` deals slots
    round-robin across jobs — the fragmented schedule where tenants split
    each router's terminals and every model group is forced off-router."""
    chips = [int(np.prod(mesh)) for mesh, _, _ in jobs]
    capacity = g.n * delta0
    if sum(chips) > capacity:
        raise ValueError(f"{sum(chips)} chips > {capacity} terminals "
                         f"({g.n} routers x {delta0})")
    slot_router = np.repeat(np.arange(g.n), delta0)
    if layout in ("packed", "linear"):
        cuts = np.cumsum([0] + chips)
        return [slot_router[cuts[j]:cuts[j + 1]] for j in range(len(jobs))]
    if layout == "interleaved":
        j_count = len(jobs)
        return [slot_router[j::j_count][:chips[j]] for j in range(j_count)]
    raise ValueError(f"unknown layout {layout!r}; "
                     f"options: {FRAGMENTATION_LAYOUTS}")


def fragmentation_demand(g, jobs, delta0: int, layout: str) -> np.ndarray:
    """Combined router-level demand of several co-tenant jobs under one
    layout.  ``jobs`` is an iterable of (mesh_shape, axis_names, profile);
    ``packed``/``interleaved`` fill each job's slots model-group-major,
    ``linear`` chip-major (the naive scheduler both placement strategies
    beat)."""
    demand = np.zeros((g.n, g.n))
    for (mesh, axes, prof), slots in zip(jobs,
                                         _layout_slots(g, jobs, delta0,
                                                       layout)):
        order = (None if layout == "linear"
                 else _model_major_order(mesh, tuple(axes)))
        p = Placement(g, tuple(mesh), tuple(axes), _assign_slots(slots, order))
        demand += placement_demand(prof, p)
    return demand


def fragmentation_sweep(g, jobs, delta0: int,
                        layouts=FRAGMENTATION_LAYOUTS, routing="ugal",
                        background=None, background_scale: float = 1.0,
                        engine: str | None = None) -> dict:
    """Score multi-tenant layouts at pod scale: theta of the combined
    (jobs + optional background pattern) demand per layout under one
    routing model.  ``background`` is any traffic-pattern spec (e.g.
    ``"tornado"`` — a hostile co-tenant), scaled so its busiest source
    injects ``background_scale``x the jobs' busiest per-chip wire bytes.
    theta is normalized by the layout-INVARIANT busiest per-chip wire
    bytes (fabric.placement.chip_wire_bytes), so layouts compare by
    actual step throughput rather than each being rescaled by its own
    peak router.  Returns ``{"layouts": {layout: row}, "best": name}``;
    packed placement keeping TP/EP groups on whole routers dominates the
    fragmented interleaved schedule wherever group locality matters."""
    from ..core.traffic import make_pattern
    from .placement import chip_wire_bytes
    jobs = list(jobs)
    per_chip = max(chip_wire_bytes(prof, tuple(mesh), tuple(axes))
                   for mesh, axes, prof in jobs)
    if per_chip == 0.0:
        raise ValueError("no job puts bytes on the wire")
    bg = None
    if background is not None:
        bg = make_pattern(background).demand(g)
        bg *= background_scale * per_chip / float(bg.sum(axis=1).max())
    rows = {}
    model = make_routing(routing)
    active = np.arange(g.n)
    for layout in layouts:
        demand = fragmentation_demand(g, jobs, delta0, layout)
        if bg is not None:
            demand = demand + bg
        res = model.evaluate(g, demand / per_chip, active, engine)
        mx = float(res.loads.max())
        rows[layout] = {"theta": 1.0 / mx, "u": float(res.loads.mean()) / mx,
                        "max_load": mx, "kbar_eff": res.kbar_eff,
                        "alpha": res.alpha}
    return {"layouts": rows,
            "best": max(rows, key=lambda k: rows[k]["theta"])}
