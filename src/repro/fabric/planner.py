"""The Section-5 selector, operationalized: given a training job's per-step
collective profile (straight from the dry-run JSONs) and a chip budget,
evaluate candidate fabrics on (a) the paper's $-and-Watts model and (b)
per-step collective time from the saturation model — the full loop from
'compiled XLA program' to 'which network should the cluster buy'.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core import (DirectNetworkSpec, cable_split, dollars_per_node,
                    electrical_groups, utilization, watts_per_node)
from ..core.reference import dragonfly_canonical_stats
from .collectives import collective_time
from .model import FabricModel, torus3d_graph

__all__ = ["FabricCandidate", "candidate_fabrics", "plan", "StepProfile"]


@dataclass
class StepProfile:
    """Per-step per-device collective bytes by kind (from the dry-run)."""
    bytes_by_kind: dict
    steps_per_run: int = 1

    @classmethod
    def from_dryrun(cls, record: dict) -> "StepProfile":
        coll = dict(record.get("collective_bytes_per_device", {}))
        coll.pop("total", None)
        return cls(bytes_by_kind=coll)


@dataclass
class FabricCandidate:
    fabric: FabricModel
    terminals: int
    radix: int
    dollars_per_node: float
    watts_per_node: float

    def step_comm_seconds(self, profile: StepProfile) -> float:
        n = self.terminals
        return sum(collective_time(self.fabric, kind, b, n).total_s
                   for kind, b in profile.bytes_by_kind.items())


def _mk_candidate(g, delta0, name=None) -> FabricCandidate:
    if g.meta.get("family") == "dragonfly":
        kbar, u = dragonfly_canonical_stats(g.meta["h"])
    else:
        sources = None
        if g.n > 3000:
            sources = np.random.default_rng(0).choice(g.n, 256, replace=False)
        rep = utilization(g, sources=sources)
        kbar, u = rep.kbar, rep.u
    fab = FabricModel(g, terminals_per_router=delta0, kbar=kbar, u=u,
                      name=name or g.name)
    labels = electrical_groups(g, delta0)
    ne, no = cable_split(g, labels)
    leaf = g.meta.get("leaf_mask")
    n_leaf = int(leaf.sum()) if leaf is not None else g.n
    spec = DirectNetworkSpec(
        name=fab.name, terminals=int(round(n_leaf * delta0)),
        radix=int(round(g.max_degree + delta0)),
        routers=g.n, degree=g.max_degree, terminals_per_router=delta0,
        kbar=kbar, u=u, electrical_cables=ne, optical_cables=no)
    return FabricCandidate(fab, spec.terminals, spec.radix,
                           dollars_per_node(spec), watts_per_node(spec))


def candidate_fabrics(min_terminals: int, max_radix: int = 64):
    """Instantiate the main families at the smallest size covering the
    terminal count within the radix budget."""
    from ..core import (demi_pn_graph, dragonfly_graph, hamming_graph,
                        mms_graph, pn_graph)
    from ..core.gf import is_prime_power
    out = []

    def try_family(builder, params, delta0_of, name):
        for p in params:
            try:
                g = builder(p)
            except Exception:
                continue
            d0 = delta0_of(g)
            if g.max_degree + d0 > max_radix:
                continue
            if g.n * d0 >= min_terminals:
                out.append(_mk_candidate(g, d0, name=f"{name}({p})"))
                return

    pps = [q for q in range(3, 80) if is_prime_power(q)]
    try_family(demi_pn_graph, pps, lambda g: (g.meta["q"] + 1) // 2, "demi-PN")
    try_family(pn_graph, pps, lambda g: max(1, round(2 * (g.meta["q"] + 1) / 5)), "PN")
    try_family(mms_graph, [q for q in pps if q % 4 != 2],
               lambda g: max(1, round(4 / 9 * g.max_degree)), "SF-MMS")
    try_family(dragonfly_graph, list(range(2, 17)), lambda g: g.meta["h"],
               "dragonfly")
    try_family(hamming_graph, list(range(4, 40)), lambda g: g.meta["side"],
               "Hamming2D")
    return out


def plan(profile: StepProfile, min_terminals: int, max_radix: int = 64):
    """Rank fabrics by step-communication time and report $/W; returns list
    of dict rows sorted by comm time."""
    rows = []
    for cand in candidate_fabrics(min_terminals, max_radix):
        t = cand.step_comm_seconds(profile)
        rows.append({
            "fabric": cand.fabric.name,
            "terminals": cand.terminals,
            "radix": cand.radix,
            "kbar": round(cand.fabric.kbar, 3),
            "u": round(cand.fabric.u, 3),
            "kbar_over_u": round(cand.fabric.kbar / cand.fabric.u, 3),
            "step_comm_ms": round(t * 1e3, 3),
            "usd_per_node": round(cand.dollars_per_node, 2),
            "watts_per_node": round(cand.watts_per_node, 2),
        })
    return sorted(rows, key=lambda r: r["step_comm_ms"])
