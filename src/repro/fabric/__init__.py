from .collectives import (CollectiveCost, allgather_time, allreduce_time,
                          alltoall_time, collective_time, reducescatter_time)
from .model import FabricModel, make_fabric, torus3d_graph
from .planner import FabricCandidate, StepProfile, candidate_fabrics, plan
