from .collectives import (CollectiveCost, allgather_time, allreduce_time,
                          alltoall_time, bytes_on_wire, collective_time,
                          reducescatter_time)
from .model import FabricModel, make_fabric, torus3d_graph
from .placement import (PLACEMENT_STRATEGIES, Placement, PlacementStrategy,
                        collective_traffic, evaluate_placements,
                        greedy_improve, link_loads, make_placement_strategy,
                        place_mesh, placement_demand, placement_report,
                        placement_search, register_placement,
                        schedule_from_profile)
from .planner import (FabricCandidate, StepProfile, candidate_fabrics,
                      fragmentation_sweep, placement_step_seconds, plan)
