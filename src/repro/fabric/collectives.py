"""Collective-time estimation on a fabric, grounded in the paper's
saturation model.

A reduce-scatter / all-gather / all-to-all of uniformly-spread data IS the
paper's uniform traffic pattern, so its duration at saturation is

    t = bytes_sent_per_node / node_uniform_bw,
    node_uniform_bw = (Δ · u / k̄) · link_bw / Δ0          (Eq. 1)

— i.e. the k̄/u cost figure directly multiplies collective time.  All-reduce
is reduce-scatter + all-gather.  A latency term (hops × per-hop latency)
covers the small-message regime.

Every entry point takes an optional ``pattern`` (any repro.core.traffic
spec, e.g. ``"hot_region(0.2,4)"`` or ``"collective(ring-all-reduce)"``)
and ``routing`` (any repro.core.routing model: "minimal", "valiant",
"ugal", "ugal(source)"): the saturation throughput of that pattern under
that routing then replaces Eq. 1's uniform Δ·u/k̄ and its demand-weighted
hop count replaces k̄ in the latency term — collectives priced under the
congestion their actual schedule (or competing background traffic)
causes, with "ugal" modeling the adaptive minimal/Valiant choice a real
large-radix router makes per packet.
"""

from __future__ import annotations

from dataclasses import dataclass

from .model import FabricModel

__all__ = ["CollectiveCost", "collective_time", "allreduce_time",
           "allgather_time", "alltoall_time", "reducescatter_time",
           "bytes_on_wire", "RING_OPS", "SPREAD_OPS"]

PER_HOP_LATENCY_S = 0.5e-6

# Collectives whose schedule serializes over ring neighbours vs. spreading
# uniformly over the group (MoE dispatch / personalized exchange).
RING_OPS = ("all-reduce", "all-gather", "reduce-scatter")
SPREAD_OPS = ("all-to-all", "collective-permute")

# Bytes each rank puts on the wire per unit payload, relative to the
# (n-1)/n baseline every timer below prices: all-reduce is rs + ag.
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}


def bytes_on_wire(op: str, bytes_amount: float, n: int) -> float:
    """Bytes ONE rank sends for one ``op`` on an ``n``-rank group — the
    single source of truth for the (n-1)/n byte accounting the timers
    below price and the placement demand pipeline aggregates
    (fabric.placement.placement_demand)."""
    if op not in _WIRE_FACTOR:
        raise ValueError(f"unknown collective {op!r}; "
                         f"options: {RING_OPS + SPREAD_OPS}")
    if n <= 1:
        return 0.0
    return _WIRE_FACTOR[op] * bytes_amount * (n - 1) / n


@dataclass
class CollectiveCost:
    op: str
    bytes_per_node: float
    bandwidth_s: float
    latency_s: float

    @property
    def total_s(self) -> float:
        return self.bandwidth_s + self.latency_s


def _node_bw(fabric: FabricModel, pattern, routing: str) -> float:
    if pattern is None:
        return fabric.node_uniform_bw
    return fabric.pattern_node_bw(pattern, routing)


def _hops(fabric: FabricModel, pattern, routing: str) -> float:
    if pattern is None:
        return fabric.kbar
    return fabric.pattern_kbar(pattern, routing)


def allgather_time(fabric: FabricModel, bytes_global: float, n: int,
                   pattern=None, routing: str = "minimal") -> CollectiveCost:
    """Each node ends with bytes_global; sends its 1/n shard to n-1 peers
    (uniform destinations)."""
    sent = bytes_on_wire("all-gather", bytes_global, n)
    return CollectiveCost("all-gather", bytes_global / n,
                          sent / _node_bw(fabric, pattern, routing),
                          _hops(fabric, pattern, routing) * PER_HOP_LATENCY_S)


def reducescatter_time(fabric: FabricModel, bytes_global: float, n: int,
                       pattern=None, routing: str = "minimal") -> CollectiveCost:
    sent = bytes_on_wire("reduce-scatter", bytes_global, n)
    return CollectiveCost("reduce-scatter", bytes_global / n,
                          sent / _node_bw(fabric, pattern, routing),
                          _hops(fabric, pattern, routing) * PER_HOP_LATENCY_S)


def allreduce_time(fabric: FabricModel, bytes_global: float, n: int,
                   pattern=None, routing: str = "minimal") -> CollectiveCost:
    rs = reducescatter_time(fabric, bytes_global, n, pattern, routing)
    ag = allgather_time(fabric, bytes_global, n, pattern, routing)
    return CollectiveCost("all-reduce", bytes_global,
                          rs.bandwidth_s + ag.bandwidth_s,
                          rs.latency_s + ag.latency_s)


def alltoall_time(fabric: FabricModel, bytes_per_node: float, n: int,
                  pattern=None, routing: str = "minimal") -> CollectiveCost:
    """Personalized all-to-all: the exact uniform-traffic pattern."""
    sent = bytes_on_wire("all-to-all", bytes_per_node, n)
    return CollectiveCost("all-to-all", bytes_per_node,
                          sent / _node_bw(fabric, pattern, routing),
                          _hops(fabric, pattern, routing) * PER_HOP_LATENCY_S)


def collective_time(fabric: FabricModel, op: str, bytes_amount: float,
                    n: int, pattern=None, routing: str = "minimal") -> CollectiveCost:
    fn = {"all-reduce": allreduce_time, "all-gather": allgather_time,
          "reduce-scatter": reducescatter_time, "all-to-all": alltoall_time,
          "collective-permute": alltoall_time}[op]
    return fn(fabric, bytes_amount, n, pattern, routing)
