"""Placement: map logical mesh coordinates (pod, data, model) onto the
terminals of a physical fabric graph and evaluate per-link load for a
step's collective schedule.

This closes the loop the paper leaves open: Section 2 prices UNIFORM
traffic with the closed form u = a·k̄/Δ; a training step's traffic is
structured (rings over the DP axis, all-to-all inside TP/EP groups), so the
load actually seen by each link depends on where the job's chips sit.  We
route the schedule over shortest paths (equal split, the paper's minimal-
routing model) and report max/mean link load — the placement analogue of
Theorem 3.9's counting argument.

Strategies:
  linear  — chips fill routers in index order (what a naive scheduler does)
  group   — each model-axis group is packed onto consecutive routers
            (electrical-group-aligned; for PN fabrics this is the subplane
            partition of Figure 2)
  random  — seeded shuffle baseline
plus ``greedy_improve``: pairwise-swap descent on max-link load.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core import Graph
from ..core.graph import bfs_distances_batched

__all__ = ["Placement", "place_mesh", "collective_traffic", "link_loads",
           "greedy_improve", "evaluate_placements"]


@dataclass
class Placement:
    """chip -> router assignment for a (pod, data, model)-shaped mesh."""
    graph: Graph
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    router_of: np.ndarray  # (n_chips,) router index per flattened chip

    @property
    def n_chips(self) -> int:
        return int(np.prod(self.mesh_shape))


def place_mesh(g: Graph, mesh_shape, axis_names, terminals_per_router: int,
               strategy: str = "linear", seed: int = 0) -> Placement:
    n_chips = int(np.prod(mesh_shape))
    capacity = g.n * terminals_per_router
    if n_chips > capacity:
        raise ValueError(f"{n_chips} chips > {capacity} terminals "
                         f"({g.n} routers x {terminals_per_router})")
    slots = np.repeat(np.arange(g.n), terminals_per_router)[:n_chips]
    if strategy == "linear":
        router_of = slots
    elif strategy == "random":
        rng = np.random.default_rng(seed)
        router_of = rng.permutation(
            np.repeat(np.arange(g.n), terminals_per_router))[:n_chips]
    elif strategy == "group":
        # pack each model-axis group contiguously: chips that talk the most
        # (TP/EP collectives) share a router/electrical group
        idx = np.arange(n_chips).reshape(mesh_shape)
        order = np.moveaxis(idx, axis_names.index("model"), -1).reshape(-1)
        router_of = np.empty(n_chips, dtype=np.int64)
        router_of[order] = slots
    else:
        raise ValueError(strategy)
    return Placement(g, tuple(mesh_shape), tuple(axis_names), router_of)


def collective_traffic(mesh_shape, axis_names, bytes_by_axis: dict):
    """Chip-to-chip traffic for one step.

    bytes_by_axis: {axis: (kind, bytes_global)} with kind in
    {'ring', 'all_to_all'}; 'ring' models all-reduce/all-gather/reduce-
    scatter (2(n-1)/n of the payload between ring neighbours), 'all_to_all'
    models MoE dispatch (payload/n between every ordered pair in the group).
    Returns (src_chip, dst_chip, bytes) arrays.
    """
    n_chips = int(np.prod(mesh_shape))
    coords = np.stack(np.unravel_index(np.arange(n_chips), mesh_shape), 1)
    srcs, dsts, byts = [], [], []
    for axis, (kind, payload) in bytes_by_axis.items():
        ax = axis_names.index(axis)
        n = mesh_shape[ax]
        if n == 1:
            continue
        nxt = coords.copy()
        if kind == "ring":
            nxt[:, ax] = (nxt[:, ax] + 1) % n
            dst = np.ravel_multi_index(nxt.T, mesh_shape)
            per = payload * 2.0 * (n - 1) / n
            srcs.append(np.arange(n_chips)); dsts.append(dst)
            byts.append(np.full(n_chips, per))
        elif kind == "all_to_all":
            for shift in range(1, n):
                nxt = coords.copy()
                nxt[:, ax] = (nxt[:, ax] + shift) % n
                dst = np.ravel_multi_index(nxt.T, mesh_shape)
                srcs.append(np.arange(n_chips)); dsts.append(dst)
                byts.append(np.full(n_chips, payload / n))
        else:
            raise ValueError(kind)
    return (np.concatenate(srcs), np.concatenate(dsts), np.concatenate(byts))


def link_loads(p: Placement, traffic) -> dict:
    """Route traffic over shortest paths (equal split over next hops, the
    minimal-routing model of Section 2) and accumulate per-arc load."""
    g = p.graph
    src, dst, byts = traffic
    rs, rd = p.router_of[src], p.router_of[dst]
    # aggregate router-to-router demands
    key = rs * g.n + rd
    agg = np.zeros(g.n * g.n)
    np.add.at(agg, key, byts)
    dist = bfs_distances_batched(g, np.arange(g.n)).astype(np.int64)
    arc_load = np.zeros(len(g.indices))
    for s in range(g.n):
        demand = agg[s * g.n: (s + 1) * g.n].copy()
        demand[s] = 0.0
        if not demand.any():
            continue
        # push flow from s along the shortest-path DAG with equal next-hop
        # (ECMP-style) split: process nodes far-to-near; down[v] = bytes
        # that must transit v (own demand + downstream shares)
        order = np.argsort(dist[s])
        down = demand.copy()
        for v in order[::-1]:
            if v == s or down[v] <= 0:
                continue
            lo, hi = g.indptr[v], g.indptr[v + 1]
            nbrs = g.indices[lo:hi]
            preds = lo + np.nonzero(dist[s][nbrs] == dist[s][v] - 1)[0]
            if len(preds) == 0:
                continue
            share = down[v] / len(preds)
            for a in preds:
                u = g.indices[a]
                # arc u->v carries `share`; find arc id (u, v)
                lo_u, hi_u = g.indptr[u], g.indptr[u + 1]
                arc = lo_u + int(np.nonzero(g.indices[lo_u:hi_u] == v)[0][0])
                arc_load[arc] += share
                down[u] += share
    return {"loads": arc_load, "max": float(arc_load.max(initial=0.0)),
            "mean": float(arc_load.mean() if len(arc_load) else 0.0)}


def greedy_improve(p: Placement, traffic, iters: int = 200,
                   seed: int = 0) -> tuple[Placement, float]:
    """Pairwise-swap descent on max link load."""
    rng = np.random.default_rng(seed)
    best = p.router_of.copy()
    best_load = link_loads(p, traffic)["max"]
    cur = Placement(p.graph, p.mesh_shape, p.axis_names, best)
    for _ in range(iters):
        i, j = rng.integers(0, p.n_chips, 2)
        if cur.router_of[i] == cur.router_of[j]:
            continue
        cand = cur.router_of.copy()
        cand[i], cand[j] = cand[j], cand[i]
        trial = Placement(p.graph, p.mesh_shape, p.axis_names, cand)
        m = link_loads(trial, traffic)["max"]
        if m < best_load:
            best_load, cur = m, trial
    return cur, best_load


def evaluate_placements(g: Graph, mesh_shape, axis_names, delta0: int,
                        bytes_by_axis: dict, seed: int = 0) -> dict:
    """Compare strategies; returns {strategy: {max, mean}}."""
    traffic = collective_traffic(mesh_shape, axis_names, bytes_by_axis)
    out = {}
    for strat in ("linear", "group", "random"):
        p = place_mesh(g, mesh_shape, axis_names, delta0, strat, seed=seed)
        r = link_loads(p, traffic)
        out[strat] = {"max": r["max"], "mean": r["mean"]}
    return out
