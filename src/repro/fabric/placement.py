"""Placement-aware demand pipeline: map logical mesh coordinates
(pod, data, model) onto the terminals of a physical fabric graph and
score the resulting traffic through the routing registry.

This closes the loop the paper leaves open: Section 2 prices UNIFORM
traffic with the closed form u = a·k̄/Δ; a training step's traffic is
structured (rings over the DP axis, all-to-all inside TP/EP groups), so
the load actually seen by each link depends on where the job's chips sit.
A ``(StepProfile, Placement)`` pair compiles into a router-level (N, N)
demand matrix (:func:`placement_demand`, reusing fabric.collectives' byte
accounting), which flows through ``arc_loads_weighted`` /
``saturation_report`` under ANY registered routing model — minimal,
Valiant, or the UGAL blend a real large-radix router runs.  theta of that
matrix (demand normalized so the busiest router injects one unit) is the
placement analogue of Theorem 3.9's counting argument, comparable across
fabrics in Eq. 1's link-equivalent units.

Placement strategies are a registry (:data:`PLACEMENT_STRATEGIES`,
mirroring the traffic-pattern and routing registries):

  linear       chips fill routers in index order (a naive scheduler)
  group        each model-axis group is packed onto consecutive routers
               (electrical-group-aligned; for PN fabrics the subplane
               partition of Figure 2)
  random       seeded shuffle baseline
  orbit        group packing onto an automorphism-orbit-sorted router
               order (leaf columns first on indirect networks): a single
               model group spanning a whole orbit one-chip-per-router
               produces uniform-shaped demand on an automorphism-
               invariant active set, so ``arc_loads_weighted`` routes it
               through PR 1's orbit shortcut
  greedy_swap  pairwise-swap descent on max arc load under the scoring
               routing model, seeded from another strategy

``evaluate_placements`` / ``placement_search`` score strategies by theta
under a chosen routing model (default ugal — the routing the fabric
actually runs) and optionally by the worst case over
``repro.core.adversary`` restricted to the routers the job occupies.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .. import obs
from ..core import Graph
from ..core.routing import make_routing, parse_spec
from .collectives import RING_OPS, SPREAD_OPS, bytes_on_wire

__all__ = ["Placement", "PlacementStrategy", "PLACEMENT_STRATEGIES",
           "register_placement", "make_placement_strategy", "place_mesh",
           "collective_traffic", "schedule_from_profile", "placement_demand",
           "placement_report", "link_loads", "greedy_improve",
           "evaluate_placements", "placement_search", "DEFAULT_STRATEGIES",
           "AXIS_OF_OP"]


@dataclass
class Placement:
    """chip -> router assignment for a (pod, data, model)-shaped mesh."""
    graph: Graph
    mesh_shape: tuple[int, ...]
    axis_names: tuple[str, ...]
    router_of: np.ndarray  # (n_chips,) router index per flattened chip

    @property
    def n_chips(self) -> int:
        return int(np.prod(self.mesh_shape))

    @property
    def occupied(self) -> np.ndarray:
        """Sorted router ids hosting at least one chip."""
        return np.unique(self.router_of)


# ---------------------------------------------------------------------------
# Schedule -> chip traffic -> router demand
# ---------------------------------------------------------------------------

# Which mesh axis each collective kind of a StepProfile rides: gradient
# rings run over the data-parallel axis, MoE dispatch / personalized
# exchange inside the model (TP/EP) groups.
AXIS_OF_OP = {"all-reduce": "data", "all-gather": "data",
              "reduce-scatter": "data",
              "all-to-all": "model", "collective-permute": "model"}


def schedule_from_profile(profile, axis_names, axis_of=None) -> dict:
    """Map a StepProfile's per-device collective bytes onto mesh axes.

    Returns ``{axis: (kind, payload)}`` for :func:`collective_traffic`,
    with kind ``'ring'`` (DP gradient schedule) or ``'all_to_all'``
    (TP/EP group exchange).  Byte accounting delegates to
    fabric.collectives: the ring kind prices the all-reduce wire bytes
    2(n-1)/n · payload, so an all-gather / reduce-scatter (half the wire
    bytes) folds in as payload/2.  Ops with zero bytes are dropped; an op
    whose axis is missing from ``axis_names`` raises."""
    axis_of = dict(AXIS_OF_OP, **(axis_of or {}))
    by_kind = getattr(profile, "bytes_by_kind", profile)
    ring = {}
    a2a = {}
    for op, b in by_kind.items():
        if op not in axis_of:
            raise ValueError(f"unknown collective kind {op!r}; "
                             f"options: {sorted(AXIS_OF_OP)}")
        if b == 0:
            continue
        axis = axis_of[op]
        if axis not in axis_names:
            raise ValueError(f"profile has {op} bytes but the mesh has no "
                             f"{axis!r} axis (axes: {axis_names})")
        if op in RING_OPS:
            # ring kind = all-reduce accounting (2(n-1)/n); scale other
            # ring ops by their wire-byte ratio (n-independent)
            ring[axis] = ring.get(axis, 0.0) + b * (
                bytes_on_wire(op, 1.0, 2) / bytes_on_wire("all-reduce", 1.0, 2))
        elif op in SPREAD_OPS:
            a2a[axis] = a2a.get(axis, 0.0) + b
    out = {}
    for axis, payload in ring.items():
        out[axis] = ("ring", payload)
    for axis, payload in a2a.items():
        if axis in out:
            raise ValueError(f"axis {axis!r} carries both ring and "
                             f"all-to-all traffic; remap with axis_of")
        out[axis] = ("all_to_all", payload)
    return out


def collective_traffic(mesh_shape, axis_names, bytes_by_axis: dict):
    """Chip-to-chip traffic for one step.

    bytes_by_axis: {axis: (kind, bytes_global)} with kind in
    {'ring', 'all_to_all'}; 'ring' models all-reduce/all-gather/reduce-
    scatter (2(n-1)/n of the payload between ring neighbours, the
    all-reduce wire accounting of fabric.collectives), 'all_to_all'
    models MoE dispatch (payload/n between every ordered pair in the
    group).  Returns (src_chip, dst_chip, bytes) arrays.
    """
    n_chips = int(np.prod(mesh_shape))
    coords = np.stack(np.unravel_index(np.arange(n_chips), mesh_shape), 1)
    srcs, dsts, byts = [], [], []
    for axis, (kind, payload) in bytes_by_axis.items():
        ax = axis_names.index(axis)
        n = mesh_shape[ax]
        if n == 1:
            continue
        nxt = coords.copy()
        if kind == "ring":
            nxt[:, ax] = (nxt[:, ax] + 1) % n
            dst = np.ravel_multi_index(nxt.T, mesh_shape)
            per = bytes_on_wire("all-reduce", payload, n)
            srcs.append(np.arange(n_chips)); dsts.append(dst)
            byts.append(np.full(n_chips, per))
        elif kind == "all_to_all":
            for shift in range(1, n):
                nxt = coords.copy()
                nxt[:, ax] = (nxt[:, ax] + shift) % n
                dst = np.ravel_multi_index(nxt.T, mesh_shape)
                srcs.append(np.arange(n_chips)); dsts.append(dst)
                byts.append(np.full(n_chips, payload / n))
        else:
            raise ValueError(kind)
    if not srcs:
        z = np.zeros(0, dtype=np.int64)
        return z, z.copy(), np.zeros(0)
    return (np.concatenate(srcs), np.concatenate(dsts), np.concatenate(byts))


def _router_demand(p: Placement, traffic) -> np.ndarray:
    """Aggregate chip-to-chip traffic to a router-level (N, N) demand
    matrix; same-router bytes land on the diagonal and are zeroed (local
    to the router's terminals, never on the fabric)."""
    src, dst, byts = traffic
    d = np.zeros((p.graph.n, p.graph.n))
    np.add.at(d, (p.router_of[src], p.router_of[dst]), byts)
    np.fill_diagonal(d, 0.0)
    return d


def placement_demand(profile, placement: Placement, axis_of=None) -> np.ndarray:
    """Compile (StepProfile, Placement) into the router-level (N, N)
    demand matrix of one training step — the object the whole routing
    stack consumes.

    ``profile`` is a fabric.planner.StepProfile (or anything with
    ``bytes_by_kind``), or directly a ``{axis: (kind, bytes)}`` schedule
    as taken by :func:`collective_traffic`.  The matrix is in BYTES per
    step; ``saturation_report(g, placement_demand(...), routing=...)``
    normalizes it (busiest router injects one unit) and reports theta in
    Eq. 1's link-equivalent units."""
    schedule = (profile if isinstance(profile, dict)
                else schedule_from_profile(profile, placement.axis_names,
                                           axis_of))
    traffic = collective_traffic(placement.mesh_shape, placement.axis_names,
                                 schedule)
    return _router_demand(placement, traffic)


def chip_wire_bytes(profile, mesh_shape, axis_names, axis_of=None) -> float:
    """Bytes ONE chip puts on the wire per step under the schedule —
    identical for every chip and independent of placement, which makes it
    the right normalizer for placement theta (below)."""
    schedule = (profile if isinstance(profile, dict)
                else schedule_from_profile(profile, tuple(axis_names),
                                           axis_of))
    total = 0.0
    for axis, (kind, payload) in schedule.items():
        n = mesh_shape[axis_names.index(axis)]
        op = "all-reduce" if kind == "ring" else "all-to-all"
        total += bytes_on_wire(op, payload, n)
    return total


def placement_report(placement: Placement, profile, routing="ugal",
                     engine: str | None = None, axis_of=None, faults=None):
    """Saturation analysis of one (profile, placement) pair under one
    routing model, as a repro.core.traffic ``SaturationReport``.

    The demand is normalized so the busiest CHIP injects one unit
    (:func:`chip_wire_bytes` — a placement-invariant constant), NOT the
    busiest router: theta = 1/max_load is then the fraction of one
    link's bandwidth every chip can sustainably inject, comparable
    across strategies AND fabrics in Eq. 1's link-equivalent units.
    (Row normalization would rescale each layout by its own peak router
    and erase exactly the locality differences placement search is
    after.)  Raises ValueError when every byte stays router-local (the
    fabric is idle — theta is unbounded).

    ``faults`` (a repro.core.faults.FaultSet) evaluates the same
    per-chip-normalized demand on the degraded fabric — the pristine
    busiest-chip unit is kept, so degraded placement theta is directly
    comparable to pristine.  A fault that kills an occupied router drops
    that router's demand with it (the job has lost those chips)."""
    from ..core.traffic import SaturationReport
    g = placement.graph
    demand = placement_demand(profile, placement, axis_of)
    per_chip = chip_wire_bytes(profile, placement.mesh_shape,
                               placement.axis_names, axis_of)
    if per_chip == 0.0 or not demand.any():
        raise ValueError("placement demand is all router-local "
                         "(theta unbounded); nothing to route")
    norm = demand / per_chip
    label = None
    if faults is not None and not faults.empty:
        label = faults.label
        norm = faults.restrict_demand(g, norm)
        if not norm.any():
            raise ValueError("faults removed every inter-router byte of "
                             "the placement")
        active = faults.restrict_active(g, None)
        g = faults.apply(g)
    else:
        active = np.arange(g.n)
    model = make_routing(routing)
    res = model.evaluate(g, norm, active, engine)
    mx = float(res.loads.max())
    mean = float(res.loads.mean())
    return SaturationReport(
        pattern=f"placement({'x'.join(map(str, placement.mesh_shape))})",
        routing=model.name, theta=1.0 / mx, u=mean / mx, max_load=mx,
        mean_load=mean, kbar_eff=res.kbar_eff, diameter=int(res.diameter),
        total_demand=float(norm.sum()), loads=res.loads, alpha=res.alpha,
        faults=label)


def link_loads(p: Placement, traffic, routing="minimal",
               engine: str | None = None) -> dict:
    """Per-arc load of chip-to-chip traffic under a registered routing
    model — a thin parity shim over the weighted engines: the traffic is
    aggregated to a router demand matrix (:func:`_router_demand`) and
    routed by repro.core.routing.  Under ``"minimal"`` this is the
    equal-split shortest-path accounting the pre-registry implementation
    computed with its own per-source BFS (bit-compatible on the paper's
    diameter-2 fabrics; see tests/test_placement_pipeline.py for the
    parity pin and the ECMP-vs-path-split note on higher-diameter
    graphs)."""
    g = p.graph
    demand = _router_demand(p, traffic)
    if not demand.any():  # every byte stays router-local
        zeros = np.zeros(len(g.indices))
        return {"loads": zeros, "max": 0.0, "mean": 0.0, "kbar_eff": 0.0}
    res = make_routing(routing).evaluate(g, demand, np.arange(g.n), engine)
    return {"loads": res.loads, "max": float(res.loads.max()),
            "mean": float(res.loads.mean()), "kbar_eff": res.kbar_eff}


# ---------------------------------------------------------------------------
# Strategy registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlacementStrategy:
    """A named chip->router assignment recipe.

    ``assign(g, mesh_shape, axis_names, delta0, seed=..., schedule=...,
    routing=..., engine=...)`` returns the (n_chips,) router index array;
    strategies that don't score traffic ignore the trailing keywords."""

    name: str
    assign: Callable[..., np.ndarray] = field(repr=False)
    description: str = ""


PLACEMENT_STRATEGIES: dict[str, Callable[..., PlacementStrategy]] = {}


def register_placement(name: str):
    """Register a strategy factory: ``fn(*args) -> PlacementStrategy``."""

    def deco(fn):
        PLACEMENT_STRATEGIES[name] = fn
        return fn

    return deco


def make_placement_strategy(spec) -> PlacementStrategy:
    """Build a strategy from a registry name with optional arguments
    (``"group"``, ``"greedy_swap(120)"``); passes PlacementStrategy
    instances through."""
    if isinstance(spec, PlacementStrategy):
        return spec
    return parse_spec(spec, PLACEMENT_STRATEGIES, "placement strategy")


def _model_axis(axis_names) -> int:
    """The axis whose groups exchange all-to-all; falls back to the last
    axis for meshes without a named model axis."""
    return (axis_names.index("model") if "model" in axis_names
            else len(axis_names) - 1)


def _model_major_order(mesh_shape, axis_names) -> np.ndarray:
    """Chip ids reordered so each model-axis group is contiguous."""
    idx = np.arange(int(np.prod(mesh_shape))).reshape(mesh_shape)
    return np.moveaxis(idx, _model_axis(axis_names), -1).reshape(-1)


def _assign_slots(slots: np.ndarray,
                  chip_order: np.ndarray | None = None) -> np.ndarray:
    """Deal an explicit router-slot sequence to chips (in chip_order,
    default chip-major)."""
    slots = np.asarray(slots, dtype=np.int64)
    if chip_order is None:
        return slots
    router_of = np.empty(len(slots), dtype=np.int64)
    router_of[chip_order] = slots
    return router_of


def _fill(router_order: np.ndarray, n_chips: int, delta0: int,
          chip_order: np.ndarray | None = None) -> np.ndarray:
    """Deal delta0 slots per router (in router_order) to chips (in
    chip_order, default chip-major)."""
    return _assign_slots(np.repeat(router_order, delta0)[:n_chips],
                         chip_order)


@register_placement("linear")
def _linear() -> PlacementStrategy:
    def assign(g, mesh_shape, axis_names, delta0, **kw):
        return _fill(np.arange(g.n), int(np.prod(mesh_shape)), delta0)

    return PlacementStrategy("linear", assign,
                             "chips fill routers in index order")


@register_placement("group")
def _group() -> PlacementStrategy:
    # pack each model-axis group contiguously: chips that talk the most
    # (TP/EP collectives) share a router/electrical group
    def assign(g, mesh_shape, axis_names, delta0, **kw):
        return _fill(np.arange(g.n), int(np.prod(mesh_shape)), delta0,
                     _model_major_order(mesh_shape, axis_names))

    return PlacementStrategy("group", assign,
                             "model-axis groups packed onto consecutive routers")


@register_placement("random")
def _random() -> PlacementStrategy:
    def assign(g, mesh_shape, axis_names, delta0, seed=0, **kw):
        rng = np.random.default_rng(seed)
        return rng.permutation(
            np.repeat(np.arange(g.n), delta0))[:int(np.prod(mesh_shape))]

    return PlacementStrategy("random", assign, "seeded shuffle baseline")


def _orbit_router_order(g: Graph) -> np.ndarray:
    """Routers sorted leaf-columns-first, then by automorphism vertex
    orbit, then by index; graphs without known generators keep index
    order (the strategy degenerates to group)."""
    from ..core.orbits import orbit_info
    info = orbit_info(g)
    orbit = (info.vertex_orbit if info is not None
             else np.zeros(g.n, dtype=np.int64))
    leaf = g.meta.get("leaf_mask")
    spine_first = (np.zeros(g.n, dtype=np.int64) if leaf is None
                   else (~np.asarray(leaf, dtype=bool)).astype(np.int64))
    return np.lexsort((np.arange(g.n), orbit, spine_first))


@register_placement("orbit")
def _orbit() -> PlacementStrategy:
    def assign(g, mesh_shape, axis_names, delta0, **kw):
        return _fill(_orbit_router_order(g), int(np.prod(mesh_shape)),
                     delta0, _model_major_order(mesh_shape, axis_names))

    return PlacementStrategy(
        "orbit", assign,
        "group packing onto an automorphism-orbit-sorted router order "
        "(leaf columns first); orbit-spanning groups hit the orbit shortcut")


def _swap_descent(p: Placement, demand_of, iters: int, seed: int,
                  routing, engine) -> tuple[Placement, float, list[float]]:
    """Pairwise-swap descent on max arc load.  Deterministic for a given
    seed (the candidate swap sequence is drawn up front) and monotone:
    a swap is kept only when it strictly lowers the objective."""
    model = make_routing(routing)
    g = p.graph
    active = np.arange(g.n)

    def objective(router_of) -> float:
        d = demand_of(router_of)
        if not d.any():
            return 0.0
        return float(model.evaluate(g, d, active, engine).loads.max())

    cur = p.router_of.copy()
    with obs.span("placement.greedy_swap", iters=int(iters),
                  chips=int(p.n_chips), routing=str(routing)) as sp:
        evals = obs.counter("placement.swap_evals")
        accepts = obs.counter("placement.swap_accepted")
        best = objective(cur)
        history = [best]
        pairs = np.random.default_rng(seed).integers(0, p.n_chips,
                                                     (iters, 2))
        for i, j in pairs:
            if cur[i] == cur[j] or best == 0.0:
                history.append(best)
                continue
            cand = cur.copy()
            cand[i], cand[j] = cand[j], cand[i]
            evals.add(1.0)
            m = objective(cand)
            if m < best:
                accepts.add(1.0)
                best, cur = m, cand
            history.append(best)
        sp.set(best=best)
    return (Placement(g, p.mesh_shape, p.axis_names, cur), best, history)


@register_placement("greedy_swap")
def _greedy_swap(iters: int = 200, start: str = "group") -> PlacementStrategy:
    def assign(g, mesh_shape, axis_names, delta0, seed=0, schedule=None,
               routing="minimal", engine=None, **kw):
        if schedule is None:
            raise ValueError("greedy_swap needs the schedule it descends "
                             "on; pass schedule= to place_mesh")
        base = make_placement_strategy(start).assign(
            g, mesh_shape, axis_names, delta0, seed=seed, schedule=schedule,
            routing=routing, engine=engine)
        p0 = Placement(g, tuple(mesh_shape), tuple(axis_names), base)
        traffic = collective_traffic(mesh_shape, axis_names, schedule)
        src, dst, byts = traffic

        def demand_of(router_of):
            d = np.zeros((g.n, g.n))
            np.add.at(d, (router_of[src], router_of[dst]), byts)
            np.fill_diagonal(d, 0.0)
            return d

        p, _, _ = _swap_descent(p0, demand_of, iters, seed, routing, engine)
        return p.router_of

    return PlacementStrategy(f"greedy_swap({iters},{start})", assign,
                             "pairwise-swap descent on max arc load")


def place_mesh(g: Graph, mesh_shape, axis_names, terminals_per_router: int,
               strategy="linear", seed: int = 0, schedule=None,
               routing="minimal", engine: str | None = None) -> Placement:
    """Assign a (pod, data, model)-shaped chip mesh to routers via a
    registered strategy.  ``schedule``/``routing``/``engine`` feed the
    traffic-scoring strategies (greedy_swap); the geometric strategies
    ignore them."""
    n_chips = int(np.prod(mesh_shape))
    capacity = g.n * terminals_per_router
    if n_chips > capacity:
        raise ValueError(f"{n_chips} chips > {capacity} terminals "
                         f"({g.n} routers x {terminals_per_router})")
    strat = make_placement_strategy(strategy)
    router_of = np.asarray(
        strat.assign(g, tuple(mesh_shape), tuple(axis_names),
                     terminals_per_router, seed=seed, schedule=schedule,
                     routing=routing, engine=engine), dtype=np.int64)
    if (np.bincount(router_of, minlength=g.n) > terminals_per_router).any():
        raise ValueError(f"strategy {strat.name!r} oversubscribed a router "
                         f"beyond {terminals_per_router} terminals")
    return Placement(g, tuple(mesh_shape), tuple(axis_names), router_of)


# ---------------------------------------------------------------------------
# Search and comparison
# ---------------------------------------------------------------------------


def greedy_improve(p: Placement, traffic, iters: int = 200, seed: int = 0,
                   routing="minimal", engine: str | None = None,
                   return_history: bool = False):
    """Pairwise-swap descent on max arc load under ``routing``.
    Seed-deterministic (the swap sequence is pre-drawn) with a monotone
    non-increasing objective; ``return_history=True`` also returns the
    per-iteration best objective."""
    src, dst, byts = traffic
    g = p.graph

    def demand_of(router_of):
        d = np.zeros((g.n, g.n))
        np.add.at(d, (router_of[src], router_of[dst]), byts)
        np.fill_diagonal(d, 0.0)
        return d

    placed, best, history = _swap_descent(p, demand_of, iters, seed,
                                          routing, engine)
    if return_history:
        return placed, best, history
    return placed, best


DEFAULT_STRATEGIES = ("linear", "group", "random", "orbit")


def _strategy_row(g, placement, schedule, routing, engine) -> dict:
    per_chip = chip_wire_bytes(schedule, placement.mesh_shape,
                               placement.axis_names)
    try:
        rep = placement_report(placement, schedule, routing=routing,
                               engine=engine)
    except ValueError:  # all traffic router-local: the fabric is idle
        return {"theta": float("inf"), "u": 1.0, "max_load": 0.0,
                "kbar_eff": 0.0, "alpha": None, "max_bytes": 0.0,
                "mean_bytes": 0.0}
    return {"theta": rep.theta, "u": rep.u, "max_load": rep.max_load,
            "kbar_eff": rep.kbar_eff, "alpha": rep.alpha,
            "max_bytes": rep.max_load * per_chip,
            "mean_bytes": rep.mean_load * per_chip}


def evaluate_placements(g: Graph, mesh_shape, axis_names, delta0: int,
                        profile, strategies=DEFAULT_STRATEGIES,
                        routing="ugal", seed: int = 0,
                        engine: str | None = None) -> dict:
    """Compare placement strategies on one fabric; returns
    ``{strategy: {theta, u, max_load, kbar_eff, alpha, max_bytes,
    mean_bytes}}`` with theta in Eq. 1's link-equivalent units — demand
    normalized so the busiest CHIP injects one unit (see
    :func:`placement_report`), comparable across strategies and fabrics,
    unlike raw max-bytes.  ``max_bytes`` keeps the raw per-step
    busiest-link bytes for capacity planning."""
    schedule = (profile if isinstance(profile, dict)
                else schedule_from_profile(profile, tuple(axis_names)))
    out = {}
    for spec in strategies:
        strat = make_placement_strategy(spec)
        p = place_mesh(g, mesh_shape, axis_names, delta0, strat, seed=seed,
                       schedule=schedule, routing=routing, engine=engine)
        out[strat.name] = _strategy_row(g, p, schedule, routing, engine)
    return out


def placement_search(g: Graph, mesh_shape, axis_names, delta0: int, profile,
                     strategies=DEFAULT_STRATEGIES + ("greedy_swap",),
                     routing="ugal", seed: int = 0,
                     engine: str | None = None, adversary: bool = False,
                     n_random: int = 4) -> dict:
    """Strategy search scored by theta under ``routing`` (default ugal —
    the routing the fabric actually runs), optionally cross-checked by
    the worst case repro.core.adversary finds over the routers the job
    occupies (``adv_theta``: how robust the occupied set is to hostile
    tenant traffic).  Returns ``{"rows": {strategy: row}, "best": name,
    "placements": {strategy: Placement}}`` with best = argmax theta
    (ties broken by adv_theta when searched)."""
    schedule = (profile if isinstance(profile, dict)
                else schedule_from_profile(profile, tuple(axis_names)))
    rows, placements = {}, {}
    adv_cache: dict[bytes, tuple] = {}  # strategies often share occupied sets
    for spec in strategies:
        strat = make_placement_strategy(spec)
        p = place_mesh(g, mesh_shape, axis_names, delta0, strat, seed=seed,
                       schedule=schedule, routing=routing, engine=engine)
        row = _strategy_row(g, p, schedule, routing, engine)
        if adversary:
            from ..core.adversary import worst_case
            key = p.occupied.tobytes()
            if key not in adv_cache:
                adv = worst_case(g, routing, n_random=n_random, seed=seed,
                                 engine=engine, targets_mask=p.occupied)
                adv_cache[key] = (adv.worst_theta, adv.worst_pattern)
            row["adv_theta"], row["adv_pattern"] = adv_cache[key]
        rows[strat.name] = row
        placements[strat.name] = p
    best = max(rows, key=lambda k: (rows[k]["theta"],
                                    rows[k].get("adv_theta", 0.0)))
    return {"rows": rows, "best": best, "placements": placements}
