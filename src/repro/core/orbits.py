"""Automorphism orbits of the paper's algebraic topologies.

Every family in Sections 3/4/6 is built from a group action (PGL(3,q) on
the projective plane, F_q-translations on MMS/Paley, coordinate symmetries
on Hamming/hypercube, S_n on MLFM), so a *known subgroup* H <= Aut(G) is
available in closed form — no graph-isomorphism search needed.

Why this accelerates utilization (Theorem 3.9): with L_s the per-arc load
vector of source s under uniform minimal routing, the total T = sum_s L_s
satisfies T(phi(a)) = T(a) for every automorphism phi, i.e. T is constant
on H-arc-orbits.  Moreover sum_{a in O} L_s(a) is constant as s ranges
over an H-vertex-orbit V (phi permutes O), hence

    T(a) = sum_V |V| * (sum_{a' in orbit(a)} L_{rep(V)}(a')) / |orbit(a)|

needs one Brandes sweep per *vertex orbit* instead of per vertex.  For the
vertex-transitive families (PN, demi-PN, MMS, Hamming) that is a single
sweep; OFT has two orbits (leaf columns / spine column) by column symmetry.
The identity holds for any subgroup, so partial generator sets are safe —
they just yield more orbits and less speedup, never wrong loads.

Generators are returned as vertex permutations; ``orbit_info`` validates
each one against the arc structure (a non-automorphism raises), computes
vertex- and arc-orbits by label propagation, and caches on the graph.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .gf import GF, get_field
from .graph import Graph
from .projective import num_points, normalize_points, point_index, points

__all__ = ["OrbitInfo", "automorphism_generators", "orbit_info"]


@dataclass
class OrbitInfo:
    vertex_orbit: np.ndarray   # (N,)  orbit id per vertex, ids dense from 0
    vertex_reps: np.ndarray    # (n_vorb,) representative vertex per orbit
    vertex_sizes: np.ndarray   # (n_vorb,)
    arc_orbit: np.ndarray      # (A,)  orbit id per directed arc
    arc_sizes: np.ndarray      # (n_aorb,)

    @property
    def n_vertex_orbits(self) -> int:
        return len(self.vertex_reps)


# ---------------------------------------------------------------------------
# GF(q) 3x3 matrix helpers (for the PGL / PGO actions on P2(F_q))
# ---------------------------------------------------------------------------


def _gf_matvec3(f: GF, m: np.ndarray, vecs: np.ndarray) -> np.ndarray:
    """(..., 3) canonical vectors -> M @ v over GF(q)."""
    out = np.zeros_like(vecs)
    for i in range(3):
        acc = f.mul(m[i, 0], vecs[..., 0])
        acc = f.add(acc, f.mul(m[i, 1], vecs[..., 1]))
        acc = f.add(acc, f.mul(m[i, 2], vecs[..., 2]))
        out[..., i] = acc
    return out


def _gf_mat3_cofactor(f: GF, m: np.ndarray) -> np.ndarray:
    """Cofactor matrix over GF(q); equals det(M) * inv(M)^T for invertible M."""
    c = np.zeros((3, 3), dtype=np.int64)
    for i in range(3):
        for j in range(3):
            r = [k for k in range(3) if k != i]
            s = [k for k in range(3) if k != j]
            ad = f.mul(m[r[0], s[0]], m[r[1], s[1]])
            bc = f.mul(m[r[0], s[1]], m[r[1], s[0]])
            minor = f.sub(ad, bc)
            c[i, j] = minor if (i + j) % 2 == 0 else f.neg(minor)
    return c


def _pgl_point_line_perms(q: int, m: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Permutations induced by M in PGL(3,q) on points and on (dual) lines.

    Points map by v -> Mv; line coefficient vectors by w -> M^{-T} w, so
    incidence v.w = 0 is preserved.  M^{-T} is the cofactor matrix up to the
    (projectively irrelevant) det factor.
    """
    f = get_field(q)
    pts = points(q)
    pperm = point_index(q, normalize_points(f, _gf_matvec3(f, m, pts)))
    cof = _gf_mat3_cofactor(f, m)
    lperm = point_index(q, normalize_points(f, _gf_matvec3(f, cof, pts)))
    return pperm, lperm


def _frobenius_point_perm(q: int) -> np.ndarray | None:
    """x -> x^p on coordinates (semilinear; preserves incidence and the dot
    form).  Canonical leading-1 representatives stay canonical."""
    f = get_field(q)
    if f.m == 1:
        return None
    return point_index(q, f.pow(points(q), f.p))


def _orthogonal_generators(q: int) -> list[np.ndarray]:
    """3x3 matrices M with M^T M = I over GF(q): coordinate permutations, a
    sign flip, and one plane rotation per coordinate plane (a^2 + b^2 = 1).
    These commute with the polarity, so they act on demi-PN = ER_q."""
    f = get_field(q)
    eye = np.eye(3, dtype=np.int64)
    cyc = np.array([[0, 0, 1], [1, 0, 0], [0, 1, 0]], dtype=np.int64)
    swap01 = np.array([[0, 1, 0], [1, 0, 0], [0, 0, 1]], dtype=np.int64)
    flip = eye.copy()
    flip[2, 2] = f.neg(1)
    mats = [cyc, swap01, flip]
    # sqrt table: squaring image -> one preimage (covers odd and even char)
    xs = np.arange(q, dtype=np.int64)
    sqrt_tab = np.full(q, -1, dtype=np.int64)
    sqrt_tab[f.mul(xs, xs)] = xs
    found = 0
    for a in range(2, q):
        bsq = f.sub(1, f.mul(a, a))
        b = int(sqrt_tab[bsq])
        if b <= 0:
            continue
        mats.append(np.array([[a, b, 0], [f.neg(b), a, 0], [0, 0, 1]],
                             dtype=np.int64))
        mats.append(np.array([[1, 0, 0], [0, a, b], [0, f.neg(b), a]],
                             dtype=np.int64))
        found += 1
        if found >= 2:
            break
    return mats


# ---------------------------------------------------------------------------
# Per-family vertex-permutation generators
# ---------------------------------------------------------------------------


def _gens_pn(g: Graph) -> list[np.ndarray]:
    q = g.meta["q"]
    n = num_points(q)
    f = get_field(q)
    xi = f.primitive_element()
    mats = [
        np.array([[0, 0, 1], [1, 0, 0], [0, 1, 0]], dtype=np.int64),   # cycle
        np.array([[1, 1, 0], [0, 1, 0], [0, 0, 1]], dtype=np.int64),   # shear
        np.array([[xi, 0, 0], [0, 1, 0], [0, 0, 1]], dtype=np.int64),  # scale
    ]
    gens = []
    for m in mats:
        pp, lp = _pgl_point_line_perms(q, m)
        gens.append(np.concatenate([pp, n + lp]))
    frob = _frobenius_point_perm(q)
    if frob is not None:
        gens.append(np.concatenate([frob, n + frob]))
    # duality: the incidence form is symmetric, so point i <-> line i
    idx = np.arange(n)
    gens.append(np.concatenate([n + idx, idx]))
    return gens


def _gens_demi_pn(g: Graph) -> list[np.ndarray]:
    q = g.meta["q"]
    f = get_field(q)
    pts = points(q)
    gens = []
    for m in _orthogonal_generators(q):
        gens.append(point_index(q, normalize_points(f, _gf_matvec3(f, m, pts))))
    frob = _frobenius_point_perm(q)
    if frob is not None:
        gens.append(frob)
    return gens


def _gens_oft(g: Graph) -> list[np.ndarray]:
    q = g.meta["q"]
    n = num_points(q)
    f = get_field(q)
    xi = f.primitive_element()
    mats = [
        np.array([[0, 0, 1], [1, 0, 0], [0, 1, 0]], dtype=np.int64),
        np.array([[1, 1, 0], [0, 1, 0], [0, 0, 1]], dtype=np.int64),
        np.array([[xi, 0, 0], [0, 1, 0], [0, 0, 1]], dtype=np.int64),
    ]
    gens = []
    for m in mats:
        pp, lp = _pgl_point_line_perms(q, m)
        gens.append(np.concatenate([pp, n + lp, 2 * n + pp]))
    frob = _frobenius_point_perm(q)
    if frob is not None:
        gens.append(np.concatenate([frob, n + frob, 2 * n + frob]))
    # column reversal 0 <-> 2 (the relation is symmetric in the two leaf cols)
    idx = np.arange(n)
    gens.append(np.concatenate([2 * n + idx, n + idx, idx]))
    return gens


def _gens_mms(g: Graph) -> list[np.ndarray]:
    q = g.meta["q"]
    f = get_field(q)
    qq = q * q
    s = np.repeat(np.arange(2), qq)
    x = np.tile(np.repeat(np.arange(q), q), 2)
    y = np.tile(np.arange(q), 2 * q)
    basis = [int(f.p**i) for i in range(f.m)]  # additive basis of F_q

    def idx(ss, xx, yy):
        return ss * qq + xx * q + yy

    gens = []
    for t in basis:
        # y-translation: (s, x, y) -> (s, x, y + t)
        gens.append(idx(s, x, f.add(y, t)))
        # psi_t: (0,x,y) -> (0, x+t, y);  (1,x,y) -> (1, x, y - t*x)
        x2 = np.where(s == 0, f.add(x, t), x)
        y2 = np.where(s == 0, y, f.sub(y, f.mul(t, x)))
        gens.append(idx(s, x2, y2))
        # phi_t: (1,x,y) -> (1, x+t, y);  (0,x,y) -> (0, x, y + t*x)
        x3 = np.where(s == 1, f.add(x, t), x)
        y3 = np.where(s == 1, y, f.add(y, f.mul(t, x)))
        gens.append(idx(s, x3, y3))
    return gens


def _gens_hamming(g: Graph) -> list[np.ndarray]:
    n, dim = g.meta["side"], g.meta["dim"]
    size = n**dim
    coords = np.stack(np.unravel_index(np.arange(size), (n,) * dim), axis=1)

    def ravel(c):
        return np.ravel_multi_index(tuple(c[:, k] for k in range(dim)), (n,) * dim)

    gens = []
    for d in range(dim):
        c = coords.copy()
        c[:, d] = (c[:, d] + 1) % n  # symbol cycle in coordinate d
        gens.append(ravel(c))
    c = coords.copy()  # symbol transposition 0<->1 in coordinate 0
    c[:, 0] = np.where(c[:, 0] == 0, 1, np.where(c[:, 0] == 1, 0, c[:, 0]))
    gens.append(ravel(c))
    if dim > 1:
        gens.append(ravel(coords[:, np.roll(np.arange(dim), 1)]))  # coord cycle
        c = coords.copy()
        c[:, [0, 1]] = c[:, [1, 0]]
        gens.append(ravel(c))
    return gens


def _gens_hypercube(g: Graph) -> list[np.ndarray]:
    dim = g.meta["dim"]
    v = np.arange(2**dim)
    gens = [v ^ (1 << d) for d in range(dim)]
    if dim > 1:  # swap bits 0 and 1
        b0, b1 = (v >> 0) & 1, (v >> 1) & 1
        gens.append((v & ~np.int64(3)) | (b0 << 1) | b1)
    return gens


def _sym_group_gens(n: int) -> list[np.ndarray]:
    idx = np.arange(n)
    gens = [np.roll(idx, -1)]
    if n > 1:
        t = idx.copy()
        t[[0, 1]] = [1, 0]
        gens.append(t)
    return gens


def _gens_complete(g: Graph) -> list[np.ndarray]:
    return _sym_group_gens(g.n)


def _gens_bipartite(g: Graph) -> list[np.ndarray]:
    n = g.n // 2
    gens = []
    for p in _sym_group_gens(n):
        gens.append(np.concatenate([p, n + np.arange(n)]))
    idx = np.arange(n)
    gens.append(np.concatenate([n + idx, idx]))  # side swap
    return gens


def _gens_paley(g: Graph) -> list[np.ndarray]:
    q = g.meta["q"]
    f = get_field(q)
    x = np.arange(q)
    gens = [f.add(x, int(f.p**i)) for i in range(f.m)]
    xi = f.primitive_element()
    gens.append(f.mul(f.mul(xi, xi), x))  # scaling by a nonzero square
    return gens


def _gens_mlfm(g: Graph) -> list[np.ndarray]:
    n = g.meta["n_mesh"]
    n_leaves = n * (n - 1)
    pairs = [(a, b) for a in range(n) for b in range(a + 1, n)]
    pair_id = {ab: n_leaves + s for s, ab in enumerate(pairs)}
    la = np.repeat(np.arange(n), n - 1)
    li = np.tile(np.arange(n - 1), n)
    gens = []
    for sig in _sym_group_gens(n):
        leaf = sig[la] * (n - 1) + li
        spine = np.array([pair_id[tuple(sorted((sig[a], sig[b])))]
                          for a, b in pairs], dtype=np.int64)
        gens.append(np.concatenate([leaf, spine]))
    if n - 1 > 1:  # replica S_{n-1} in column 0 (others follow by conjugation)
        perm = np.arange(g.n)
        perm[[0, 1]] = [1, 0]
        gens.append(perm)
        perm = np.arange(g.n)
        perm[: n - 1] = np.roll(perm[: n - 1], -1)
        gens.append(perm)
    return gens


_FAMILY_GENS = {
    "pn": _gens_pn,
    "demi_pn": _gens_demi_pn,
    "oft": _gens_oft,
    "mms": _gens_mms,
    "hamming": _gens_hamming,
    "hypercube": _gens_hypercube,
    "complete": _gens_complete,
    "bipartite": _gens_bipartite,
    "paley": _gens_paley,
    "mlfm": _gens_mlfm,
}


def automorphism_generators(g: Graph) -> list[np.ndarray] | None:
    """Known automorphism generators for ``g`` (vertex permutations), or
    None when the family has no closed-form group here (turan, dragonfly,
    random, ad-hoc graphs).  Degraded graphs (repro.core.faults) keep
    their family meta for traffic-pattern semantics but a fault set
    breaks the symmetry, so they never get the family's generators."""
    if g.meta.get("faults"):
        return None
    fn = _FAMILY_GENS.get(g.meta.get("family"))
    return None if fn is None else fn(g)


# ---------------------------------------------------------------------------
# Orbit computation
# ---------------------------------------------------------------------------


def _arc_permutation(g: Graph, vperm: np.ndarray) -> np.ndarray:
    """Permutation induced on directed arcs; raises if ``vperm`` is not an
    automorphism (an image pair is not an arc)."""
    order, keys = g.arc_sort_by_pair()
    qkeys = vperm[g.arc_src] * np.int64(g.n) + vperm[g.indices]
    pos = np.searchsorted(keys, qkeys)
    if (pos >= len(keys)).any() or (keys[np.minimum(pos, len(keys) - 1)] != qkeys).any():
        raise ValueError("permutation is not a graph automorphism")
    return order[pos]


def _label_components(n: int, perms: list[np.ndarray]) -> np.ndarray:
    """Connected components of x ~ p(x): min-label propagation with pointer
    jumping.  Returns the minimum element of each orbit as its label."""
    lab = np.arange(n, dtype=np.int64)
    inv = []
    for p in perms:
        ip = np.empty_like(p)
        ip[p] = np.arange(n, dtype=np.int64)
        inv.append(ip)
    while True:
        prev = lab
        for p in perms:
            lab = np.minimum(lab, lab[p])
        for ip in inv:
            lab = np.minimum(lab, lab[ip])
        lab = np.minimum(lab, lab[lab])
        lab = np.minimum(lab, lab[lab])
        if np.array_equal(lab, prev):
            return lab


def orbit_info(g: Graph, preserve_mask: np.ndarray | None = None) -> OrbitInfo | None:
    """Vertex/arc orbits of the known automorphism subgroup of ``g``.

    When ``preserve_mask`` is given, only generators that fix the mask
    set-wise are used (needed for leaf-restricted traffic, Section 6); the
    result is cached per mask on the graph instance.
    """
    key = None if preserve_mask is None else preserve_mask.tobytes()
    cache = getattr(g, "_orbit_cache", None)
    if cache is None:
        cache = {}
        g._orbit_cache = cache
    if key in cache:
        return cache[key]

    gens = automorphism_generators(g)
    info = None
    if gens:
        if preserve_mask is not None:
            gens = [p for p in gens if np.array_equal(preserve_mask[p], preserve_mask)]
        if gens:
            arc_perms = [_arc_permutation(g, p) for p in gens]
            vlab = _label_components(g.n, gens)
            alab = _label_components(len(g.arc_src), arc_perms)
            vreps, vorb = np.unique(vlab, return_inverse=True)
            _, aorb = np.unique(alab, return_inverse=True)
            info = OrbitInfo(
                vertex_orbit=vorb,
                vertex_reps=vreps,
                vertex_sizes=np.bincount(vorb),
                arc_orbit=aorb,
                arc_sizes=np.bincount(aorb),
            )
    cache[key] = info
    return info
