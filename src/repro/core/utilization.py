"""Link utilization at saturation under uniform traffic with minimal routing.

This is the paper's central quantitative lever (Section 2 / Theorem 3.9):
with one unit of traffic per ordered vertex pair, split evenly across all
shortest paths, each directed arc carries some load; saturation normalizes
the maximum arc to 1, so

    u = mean(arc load) / max(arc load)

and the serviceable compute nodes per router are Δ0 = Δ·u/k̄ (Eq. 1).

Implemented as a Brandes-style shortest-path DAG accumulation, vectorized
over arcs per BFS level, optionally restricted to leaf↔leaf traffic for
indirect networks (Section 6).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .graph import Graph, bfs_distances

__all__ = ["arc_loads", "utilization", "UtilizationReport"]


@dataclass
class UtilizationReport:
    u: float
    mean_load: float
    max_load: float
    loads: np.ndarray  # per directed arc, normalized so each source sends 1/(#targets)
    kbar: float  # average distance between (restricted) pairs
    diameter: int


def arc_loads(g: Graph, sources=None, targets_mask: np.ndarray | None = None) -> tuple[np.ndarray, float, int]:
    """Per-arc load under uniform traffic, plus (k̄, diameter) of the pairs used.

    ``sources`` defaults to every vertex (or every leaf if ``targets_mask``
    given); traffic flows from each source to every other target vertex,
    1 unit per ordered pair, split across shortest paths.
    """
    n = g.n
    arc_u = g.arc_src
    arc_v = g.indices
    loads = np.zeros(arc_u.shape[0], dtype=np.float64)
    if targets_mask is None:
        targets_mask = np.ones(n, dtype=bool)
    if sources is None:
        sources = np.nonzero(targets_mask)[0]
    sources = np.asarray(sources, dtype=np.int64)

    dist_sum = 0.0
    pair_count = 0
    diam = 0
    tmask_f = targets_mask.astype(np.float64)
    for s in sources:
        dist = bfs_distances(g, int(s))
        if (dist < 0).any():
            raise ValueError("graph is disconnected")
        lv_u = dist[arc_u]
        lv_v = dist[arc_v]
        tree = lv_v == lv_u + 1
        maxd = int(dist.max())
        diam = max(diam, int(dist[targets_mask].max()))
        dist_sum += float(dist[targets_mask].sum())
        pair_count += int(targets_mask.sum()) - int(targets_mask[s])

        # forward: shortest-path counts
        sigma = np.zeros(n, dtype=np.float64)
        sigma[s] = 1.0
        for lvl in range(1, maxd + 1):
            m = tree & (lv_v == lvl)
            np.add.at(sigma, arc_v[m], sigma[arc_u[m]])

        # backward: accumulate traffic (terminal deliveries included)
        delta = np.zeros(n, dtype=np.float64)
        for lvl in range(maxd, 0, -1):
            m = tree & (lv_v == lvl)
            mv = arc_v[m]
            coeff = (tmask_f[mv] + delta[mv]) / sigma[mv]
            c = sigma[arc_u[m]] * coeff
            loads[m] += c
            np.add.at(delta, arc_u[m], c)

    kbar = dist_sum / pair_count
    return loads, kbar, diam


def utilization(g: Graph, sources=None, targets_mask: np.ndarray | None = None) -> UtilizationReport:
    """The paper's u = mean/max arc load at saturation."""
    if targets_mask is None:
        targets_mask = g.meta.get("leaf_mask")
    loads, kbar, diam = arc_loads(g, sources, targets_mask)
    mx = float(loads.max())
    mean = float(loads.mean())
    return UtilizationReport(u=mean / mx, mean_load=mean, max_load=mx,
                             loads=loads, kbar=kbar, diameter=diam)


def valiant_report(g: Graph, sources=None) -> UtilizationReport:
    """Valiant two-phase randomized routing [paper ref 40]: every packet
    goes s -> (uniform random intermediate m) -> t via minimal paths.

    By linearity of expectation each phase is exactly one uniform-traffic
    ensemble, so the expected per-arc load is 2x the minimal-routing load,
    the load RATIOS (hence u) are unchanged, and the effective path length
    is 2·k̄ — the paper's point that randomization buys worst-case
    guarantees for non-uniform traffic at half the uniform throughput
    (Δ0 ≤ Δ·u/(2k̄) at saturation)."""
    rep = utilization(g, sources)
    return UtilizationReport(u=rep.u, mean_load=rep.mean_load * 2.0,
                             max_load=rep.max_load * 2.0,
                             loads=rep.loads * 2.0, kbar=2.0 * rep.kbar,
                             diameter=rep.diameter)
