"""Link utilization at saturation under uniform traffic with minimal routing.

This is the paper's central quantitative lever (Section 2 / Theorem 3.9):
with one unit of traffic per ordered vertex pair, split evenly across all
shortest paths, each directed arc carries some load; saturation normalizes
the maximum arc to 1, so

    u = mean(arc load) / max(arc load)

and the serviceable compute nodes per router are Δ0 = Δ·u/k̄ (Eq. 1).

Implemented as Brandes-style shortest-path DAG accumulation.  Several
engines compute the same quantity (see ``arc_loads``'s ``engine`` arg and
repro.perf for the selection flags):

  naive  — the reference implementation: one Python-level BFS + forward/
           backward sweep per source.  O(S) interpreted loops; kept as the
           parity oracle and for ad-hoc graphs.
  numpy  — batched all-source engine.  A whole block of sources advances
           one BFS level per step; the forward sigma recurrence and the
           backward delta recurrence become (S, N) x (N, N) GEMMs on the
           dense adjacency (float32 for the exact integer path counts,
           float64 for the load accumulation).  Bipartite graphs (PN, OFT,
           MLFM, hypercube, K_{n,n}) run on the half-size biadjacency
           blocks — 4x fewer FLOPs and per-level load matrices that land
           directly on the arc coordinates.  Beyond ``util_dense_max``
           vertices a CSR gather + add.reduceat sweep in a transposed
           (N, S) layout replaces the GEMMs.
  jax    — the same level-synchronous dense recurrences as jnp matmuls,
           jit-compiled per (shape, level-count) and chunked over source
           blocks to bound device memory; float64 via a scoped x64 switch.
  pallas — the jax engine's recurrences through the fused mask+GEMM
           pallas kernels (repro.kernels.mask_gemm): the distance-table
           mask runs in the GEMM epilogue instead of as a second pass
           over the (S, N) level state.  Compiled float32 on TPU;
           float64 under the pallas interpreter elsewhere (the parity /
           development path).
  orbit  — automorphism shortcut (repro.core.orbits): the total load
           vector is constant on arc orbits, and per-arc-orbit sums are
           constant as the source ranges over a vertex orbit, so one
           Brandes sweep per vertex orbit (usually 1–2 for the paper's
           families) replaces N of them.  Exact, not an approximation.

``arc_loads``/``utilization`` keep the seed's drop-in signature; traffic
can be restricted to leaf vertices for indirect networks (Section 6) via
``targets_mask``.

``arc_loads_weighted`` generalizes the same recurrences from the implicit
uniform all-to-all to an arbitrary demand matrix D[s, t] (units of traffic
from s to t, split across shortest paths): the backward coefficient
``(targets + delta) / sigma`` simply becomes ``(D[s] + delta) / sigma``,
so every batched engine handles a whole block of weighted sources in one
level-synchronous sweep — a permutation pattern costs one sweep, not N.
The uniform case is ``D = ones - I`` and reproduces ``arc_loads`` exactly.
See repro.core.traffic for the pattern registry built on top.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .. import obs
from ..perf import flags
from .graph import Graph, bfs_distances

__all__ = ["arc_loads", "arc_loads_weighted", "utilization",
           "UtilizationReport", "valiant_report"]

_ENGINES = ("auto", "naive", "numpy", "csr", "jax", "pallas", "orbit")

# float32 GEMMs are exact on integer path counts below 2^24; promote to
# float64 past this guard.
_F32_EXACT_MAX = float(2**23)

# Cap BLAS threads around the GEMM engines: at the couple-hundred-row
# shapes a source block produces, OpenBLAS's own threading measures 3-4x
# SLOWER than one core, and two single-thread sweeps overlap via
# _run_units instead.  Talk to the loaded OpenBLAS directly over ctypes —
# threadpoolctl's first scan costs >100 ms, which would land inside the
# first (cold) utilization call.
_BLAS_CTL = None  # (set_fn, get_fn) | False once probed


def _openblas_ctl():
    global _BLAS_CTL
    if _BLAS_CTL is None:
        _BLAS_CTL = False
        try:
            import ctypes

            with open("/proc/self/maps") as fh:
                paths = {line.split()[-1] for line in fh
                         if "openblas" in line.lower() and line.rstrip().endswith(".so")}
            for path in sorted(paths):
                lib = ctypes.CDLL(path)
                for suffix in ("", "64_", "_64_"):
                    for prefix in ("openblas_", "scipy_openblas_"):
                        try:
                            set_fn = getattr(lib, f"{prefix}set_num_threads{suffix}")
                            get_fn = getattr(lib, f"{prefix}get_num_threads{suffix}")
                        except AttributeError:
                            continue
                        get_fn.restype = ctypes.c_int
                        _BLAS_CTL = (set_fn, get_fn)
                        return _BLAS_CTL
        except OSError:  # non-linux / static BLAS: leave the pool alone
            pass
    return _BLAS_CTL


class _blas_limit:
    """Context manager pinning OpenBLAS to util_blas_threads threads."""

    def __enter__(self):
        self._prev = None
        k = flags().util_blas_threads
        ctl = _openblas_ctl()
        if k > 0 and ctl:
            set_fn, get_fn = ctl
            self._prev = get_fn()
            set_fn(k)
        return self

    def __exit__(self, *exc):
        if self._prev is not None:
            _openblas_ctl()[0](self._prev)
        return False


def _run_units(fns, workers: int | None = None):
    """Run independent work units, threaded when util_workers allows.

    numpy releases the GIL inside GEMMs and ufunc loops, so two
    single-BLAS-thread sweeps overlap almost perfectly on two cores.
    Exceptions (e.g. the disconnected-graph ValueError) re-raise in the
    caller.  ``workers`` overrides the util_workers flag — the fused sim
    step (repro.sim.kernel) reuses this wave loop under its own
    sim_workers flag."""
    import threading

    if workers is None:
        workers = flags().util_workers
    if len(fns) <= 1 or workers <= 1:
        return [f() for f in fns]
    results = [None] * len(fns)
    errors = [None] * len(fns)

    def run(i):
        try:
            results[i] = fns[i]()
        except BaseException as e:  # noqa: BLE001 - re-raised below
            errors[i] = e

    for lo in range(0, len(fns), workers):  # waves of `workers` threads
        wave = [threading.Thread(target=run, args=(i,))
                for i in range(lo, min(lo + workers, len(fns)))]
        for t in wave:
            t.start()
        for t in wave:
            t.join()
    for e in errors:
        if e is not None:
            raise e
    return results


@dataclass
class UtilizationReport:
    u: float
    mean_load: float
    max_load: float
    loads: np.ndarray  # per directed arc, normalized so each source sends 1/(#targets)
    kbar: float  # average distance between (restricted) pairs
    diameter: int


# ---------------------------------------------------------------------------
# Engine: naive (the reference per-source implementation)
# ---------------------------------------------------------------------------


def _arc_loads_naive(g: Graph, sources: np.ndarray, targets_mask: np.ndarray,
                     demand: np.ndarray | None = None):
    n = g.n
    arc_u = g.arc_src
    arc_v = g.indices
    loads = np.zeros(arc_u.shape[0], dtype=np.float64)

    dist_sum = 0.0
    pair_count: float = 0
    diam = 0
    tmask_f = targets_mask.astype(np.float64)
    for s in sources:
        dist = bfs_distances(g, int(s))
        if (dist < 0).any():
            raise ValueError("graph is disconnected")
        lv_u = dist[arc_u]
        lv_v = dist[arc_v]
        tree = lv_v == lv_u + 1
        maxd = int(dist.max())
        if demand is None:
            w = tmask_f
            diam = max(diam, int(dist[targets_mask].max()))
            dist_sum += float(dist[targets_mask].sum())
            pair_count += int(targets_mask.sum()) - int(targets_mask[s])
        else:
            w = demand[s]
            active = w > 0
            if active.any():
                diam = max(diam, int(dist[active].max()))
            dist_sum += float((dist * w).sum())
            pair_count += float(w.sum())

        # forward: shortest-path counts
        sigma = np.zeros(n, dtype=np.float64)
        sigma[s] = 1.0
        for lvl in range(1, maxd + 1):
            m = tree & (lv_v == lvl)
            np.add.at(sigma, arc_v[m], sigma[arc_u[m]])

        # backward: accumulate traffic (terminal deliveries included)
        delta = np.zeros(n, dtype=np.float64)
        for lvl in range(maxd, 0, -1):
            m = tree & (lv_v == lvl)
            mv = arc_v[m]
            coeff = (w[mv] + delta[mv]) / sigma[mv]
            c = sigma[arc_u[m]] * coeff
            loads[m] += c
            np.add.at(delta, arc_u[m], c)

    return loads, dist_sum, pair_count, diam


# ---------------------------------------------------------------------------
# Engine: numpy, dense generic (level-synchronous GEMMs on (S, N) blocks)
# ---------------------------------------------------------------------------


def _source_block_rows(n: int) -> int:
    blk = flags().util_block
    if blk > 0:
        return blk
    # ~48 MB per (B, N) float64 working array
    return max(32, (48 << 20) // max(8 * n, 1))


def _forward_levels(a32, a64, src_pos, n):
    """Shared level-synchronous forward sweep: distances + path counts for a
    block of sources given one-hot positions.  Returns (D, sigma, maxd).

    Level 1 is a row gather from the adjacency (the one-hot GEMM is a
    copy); masked updates use arithmetic instead of boolean fancy indexing
    (the latter measures ~10x slower at these shapes)."""
    b = len(src_pos)
    rows = np.arange(b)
    dist = np.full((b, n), -1, dtype=np.int16)
    dist[rows, src_pos] = 0
    sigma = np.zeros((b, n), dtype=np.float64)
    sigma[rows, src_pos] = 1.0
    front = None
    f64 = False
    lvl = 0
    while True:
        lvl += 1
        if (dist >= 0).all():
            return dist, sigma, lvl - 1  # saves the final GEMM
        if lvl == 1:
            nxt = a32[src_pos].copy()
        else:
            nxt = front @ (a64 if f64 else a32)
            if not f64 and nxt.size and nxt.max() >= _F32_EXACT_MAX:
                front = front.astype(np.float64)
                nxt = front @ a64
                f64 = True
        new = (nxt > 0) & (dist < 0)
        if not new.any():
            return dist, sigma, lvl - 1
        nxt *= new
        dist += new * np.int16(lvl + 1)
        sigma += nxt
        front = nxt


def _loads_dense_generic(g: Graph, sources: np.ndarray, targets_mask: np.ndarray,
                         demand: np.ndarray | None = None):
    n = g.n
    a64 = g.adjacency_dense(np.float64)
    a32 = g.adjacency_dense(np.float32)
    arc_u, arc_v = g.arc_src, g.indices
    n_arcs = arc_u.shape[0]
    loads = np.zeros(n_arcs, dtype=np.float64)
    tm = targets_mask.astype(np.float64)
    t_count = int(targets_mask.sum())
    dist_sum = 0.0
    pair_count: float = 0
    diam = 0

    # With full all-to-all traffic, reversing every path gives
    # loads[u->v] == loads[v->u] in total, so only half the arcs need the
    # per-arc reduction; the mirror is a gather at the end.  An arbitrary
    # demand matrix has no such symmetry.
    symmetric = (demand is None and bool(targets_mask.all())
                 and np.array_equal(sources, np.arange(n)))
    arc_sel = np.nonzero(arc_u < arc_v)[0] if symmetric else np.arange(n_arcs)

    def sweep(src):
        b = len(src)
        dist, sigma, maxd = _forward_levels(a32, a64, src, n)
        if (dist < 0).any():
            raise ValueError("graph is disconnected")
        if demand is None:
            w = tm[None, :]
            dm = dist[:, targets_mask]
            diam = int(dm.max())
            dist_sum = float(dm.sum(dtype=np.float64))
            pair_count = b * t_count - int(targets_mask[src].sum())
        else:
            w = demand[src]  # (b, n) per-source demand rows
            active = w > 0
            diam = int(dist[active].max()) if active.any() else 0
            dist_sum = float((dist * w).sum(dtype=np.float64))
            pair_count = float(w.sum())

        sinv = 1.0 / sigma  # sigma >= 1 everywhere once connected
        delta = np.zeros((b, n), dtype=np.float64)
        ctot = np.zeros((b, n), dtype=np.float64)
        for lvl in range(maxd, 0, -1):
            coeff = (w + delta) * sinv
            coeff *= dist == lvl
            ctot += coeff
            if lvl >= 2:
                # delta_u += sigma_u * sum_{v in N(u) at lvl} coeff_v
                delta += sigma * ((coeff @ a64) * (dist == lvl - 1))

        # per-arc load: sum_s sigma[s,u] * coeff[s,v] over tree arcs, in a
        # transposed layout so every gather is a contiguous row copy
        part = np.zeros(n_arcs, dtype=np.float64)
        sig_t = np.ascontiguousarray(sigma.T)
        c_t = np.ascontiguousarray(ctot.T)
        d_t = np.ascontiguousarray(dist.T)
        achunk = max(1024, (48 << 20) // max(8 * b, 1))
        for alo in range(0, len(arc_sel), achunk):
            ids = arc_sel[alo : alo + achunk]
            au = arc_u[ids]
            av = arc_v[ids]
            e = sig_t[au] * c_t[av]
            e *= d_t[av] == d_t[au] + 1
            part[ids] = e.sum(axis=1)
        return part, dist_sum, pair_count, diam

    workers = max(1, flags().util_workers)
    block = min(_source_block_rows(n), max(1, -(-len(sources) // workers)))
    units = [sources[lo : lo + block] for lo in range(0, len(sources), block)]
    for part, dsum, pcount, dia in _run_units([lambda u=u: sweep(u) for u in units]):
        loads += part
        dist_sum += dsum
        pair_count += pcount
        diam = max(diam, dia)
    if symmetric:
        loads[g.reverse_arcs()[arc_sel]] = loads[arc_sel]
    return loads, dist_sum, pair_count, diam


# ---------------------------------------------------------------------------
# Engine: numpy, dense bipartite (half-size biadjacency blocks)
# ---------------------------------------------------------------------------


def _bip_structure(g: Graph, side: np.ndarray):
    cache = g._struct_cache
    if "bip_dense" not in cache:
        left = np.nonzero(side == 0)[0]
        right = np.nonzero(side == 1)[0]
        pos = np.empty(g.n, dtype=np.int64)
        pos[left] = np.arange(len(left))
        pos[right] = np.arange(len(right))
        b64 = np.zeros((len(left), len(right)), dtype=np.float64)
        eu, ev = g.edges[:, 0], g.edges[:, 1]
        swap = side[eu] == 1
        lu = np.where(swap, ev, eu)
        rv = np.where(swap, eu, ev)
        b64[pos[lu], pos[rv]] = 1.0
        mats = {
            (0, 64): b64,
            (0, 32): b64.astype(np.float32),
            (1, 64): np.ascontiguousarray(b64.T),
        }
        mats[(1, 32)] = mats[(1, 64)].astype(np.float32)
        # directed arcs grouped by source side, as flat indices into the
        # (nX, nY) per-level load matrices; flat_rl_sym indexes the
        # *transposed* entry of the (nL, nR) matrix, for the path-reversal
        # shortcut of the all-source engine
        arcs_lr = np.nonzero(side[g.arc_src] == 0)[0]
        arcs_rl = np.nonzero(side[g.arc_src] == 1)[0]
        flat_lr = pos[g.arc_src[arcs_lr]] * len(right) + pos[g.indices[arcs_lr]]
        flat_rl = pos[g.arc_src[arcs_rl]] * len(left) + pos[g.indices[arcs_rl]]
        flat_rl_sym = pos[g.indices[arcs_rl]] * len(right) + pos[g.arc_src[arcs_rl]]
        cache["bip_dense"] = (left, right, pos, mats,
                              (arcs_lr, flat_lr), (arcs_rl, flat_rl), flat_rl_sym)
    return cache["bip_dense"]


def _bip_forward(pos_src, nx_, ny_, bxy32, bxy64, byx32, byx64):
    """Level-alternating forward sweep for sources on side X.  Level 1 is a
    row gather from the biadjacency.  Returns (dx, dy, sig_x, sig_y, maxd)."""
    b = len(pos_src)
    rows = np.arange(b)
    dx = np.full((b, nx_), -1, dtype=np.int16)
    dy = np.full((b, ny_), -1, dtype=np.int16)
    dx[rows, pos_src] = 0
    sig_x = np.zeros((b, nx_), dtype=np.float64)
    sig_x[rows, pos_src] = 1.0
    sig_y = np.zeros((b, ny_), dtype=np.float64)
    front = None
    f64 = False
    lvl = 0
    while True:
        lvl += 1
        odd = lvl % 2 == 1
        if (dx >= 0).all() and (dy >= 0).all():
            return dx, dy, sig_x, sig_y, lvl - 1  # saves the final GEMM
        if lvl == 1:
            nxt = bxy32[pos_src].copy()
        else:
            mat32 = bxy32 if odd else byx32
            mat64 = bxy64 if odd else byx64
            nxt = front @ (mat64 if f64 else mat32)
            if not f64 and nxt.size and nxt.max() >= _F32_EXACT_MAX:
                front = front.astype(np.float64)
                nxt = front @ mat64
                f64 = True
        d_tgt = dy if odd else dx
        s_tgt = sig_y if odd else sig_x
        new = (nxt > 0) & (d_tgt < 0)
        if not new.any():
            return dx, dy, sig_x, sig_y, lvl - 1
        nxt *= new
        d_tgt += new * np.int16(lvl + 1)
        s_tgt += nxt
        front = nxt


def _loads_dense_bipartite(g: Graph, sources: np.ndarray,
                           targets_mask: np.ndarray, side: np.ndarray):
    """General bipartite engine (arbitrary sources / target masks)."""
    left, right, pos, mats, lr, rl, _ = _bip_structure(g, side)
    halves = (left, right)
    t_count = int(targets_mask.sum())
    loads = np.zeros(g.arc_src.shape[0], dtype=np.float64)
    dist_sum = 0.0
    pair_count = 0
    diam = 0

    for x in (0, 1):  # source side
        srcs = sources[side[sources] == x]
        if len(srcs) == 0:
            continue
        y = 1 - x
        nx_, ny_ = len(halves[x]), len(halves[y])
        bxy64, bxy32 = mats[(x, 64)], mats[(x, 32)]
        byx64, byx32 = mats[(y, 64)], mats[(y, 32)]
        tmx = targets_mask[halves[x]].astype(np.float64)
        tmy = targets_mask[halves[y]].astype(np.float64)
        # per-level load matrices, accumulated over source blocks
        m_xy = np.zeros((nx_, ny_), dtype=np.float64)
        m_yx = np.zeros((ny_, nx_), dtype=np.float64)

        block = _source_block_rows(max(nx_, ny_))
        for lo in range(0, len(srcs), block):
            sb = srcs[lo : lo + block]
            b = len(sb)
            dx, dy, sig_x, sig_y, maxd = _bip_forward(
                pos[sb], nx_, ny_, bxy32, bxy64, byx32, byx64)
            if (dx < 0).any() or (dy < 0).any():
                raise ValueError("graph is disconnected")
            tx_mask = targets_mask[halves[x]]
            ty_mask = targets_mask[halves[y]]
            if tx_mask.any():
                dmx = dx[:, tx_mask]
                diam = max(diam, int(dmx.max()))
                dist_sum += float(dmx.sum(dtype=np.float64))
            if ty_mask.any():
                dmy = dy[:, ty_mask]
                diam = max(diam, int(dmy.max()))
                dist_sum += float(dmy.sum(dtype=np.float64))
            pair_count += b * t_count - int(targets_mask[sb].sum())

            sinv_x = 1.0 / sig_x
            sinv_y = 1.0 / sig_y
            delta_x = np.zeros((b, nx_), dtype=np.float64)
            delta_y = np.zeros((b, ny_), dtype=np.float64)
            for lvl in range(maxd, 0, -1):
                odd = lvl % 2 == 1
                d_v, sinv_v, tm_v, delta_v = (
                    (dy, sinv_y, tmy, delta_y) if odd else (dx, sinv_x, tmx, delta_x))
                d_u, sig_u, delta_u = (
                    (dx, sig_x, delta_x) if odd else (dy, sig_y, delta_y))
                mu = d_u == lvl - 1
                coeff = (tm_v[None, :] + delta_v) * sinv_v
                coeff *= d_v == lvl
                f_prev = sig_u * mu
                if odd:
                    m_xy += f_prev.T @ coeff
                else:
                    m_yx += f_prev.T @ coeff
                if lvl >= 2:
                    # coeff @ B_vu: use the pre-transposed contiguous block
                    # so BLAS runs the NN (fastest) kernel
                    back_t = byx64 if odd else bxy64
                    delta_u += sig_u * ((coeff @ back_t) * mu)

        arcs_fwd, flat_fwd = lr if x == 0 else rl
        arcs_bwd, flat_bwd = rl if x == 0 else lr
        loads[arcs_fwd] += m_xy.ravel()[flat_fwd]
        loads[arcs_bwd] += m_yx.ravel()[flat_bwd]
    return loads, dist_sum, pair_count, diam


def _loads_dense_bipartite_all(g: Graph, targets_mask: np.ndarray, side: np.ndarray):
    """All-source full-traffic bipartite fast path.

    Beyond the general engine it exploits path reversal — total loads
    satisfy loads[u->v] == loads[v->u] — so only the (nL, nR) load matrix
    for L->R arcs is accumulated: from L-sources at odd BFS levels (level 1
    is a plain row scatter of the level-1 coefficients, no GEMM) and from
    R-sources at even levels.  delta GEMMs that only feed coefficients no
    L->R arc consumes are skipped outright.
    """
    left, right, pos, mats, lr, rl, flat_rl_sym = _bip_structure(g, side)
    halves = (left, right)
    n = g.n
    loads = np.zeros(g.arc_src.shape[0], dtype=np.float64)

    def sweep(x, sb):
        """One source block on side x; returns (m_lr partial, dist_sum, diam)."""
        y = 1 - x
        nx_, ny_ = len(halves[x]), len(halves[y])
        bxy64, bxy32 = mats[(x, 64)], mats[(x, 32)]
        byx64, byx32 = mats[(y, 64)], mats[(y, 32)]
        # parity of the levels whose tree arcs point L->R: odd levels for
        # L-sources (u in L even, v in R odd), even levels for R-sources
        want_odd = x == 0
        b = len(sb)
        dx, dy, sig_x, sig_y, maxd = _bip_forward(
            pos[sb], nx_, ny_, bxy32, bxy64, byx32, byx64)
        if (dx < 0).any() or (dy < 0).any():
            raise ValueError("graph is disconnected")
        diam = max(int(dx.max()), int(dy.max()))
        dist_sum = float(dx.sum(dtype=np.float64)) + float(dy.sum(dtype=np.float64))

        m_lr = np.zeros((len(left), len(right)), dtype=np.float64)
        sinv_x = 1.0 / sig_x
        sinv_y = 1.0 / sig_y
        delta_x = np.zeros((b, nx_), dtype=np.float64)
        delta_y = np.zeros((b, ny_), dtype=np.float64)
        for lvl in range(maxd, 0, -1):
            odd = lvl % 2 == 1
            emit = odd == want_odd  # level's tree arcs point L->R?
            if lvl == 1 and not emit:
                break  # nothing below needs coeff at level 1
            d_v, sinv_v, delta_v = (
                (dy, sinv_y, delta_y) if odd else (dx, sinv_x, delta_x))
            d_u, sig_u, delta_u = (
                (dx, sig_x, delta_x) if odd else (dy, sig_y, delta_y))
            mu = d_u == lvl - 1
            coeff = (1.0 + delta_v) * sinv_v
            coeff *= d_v == lvl
            if emit:
                if lvl == 1:
                    # only reachable for L-sources: f_prev is the one-hot
                    # source block, so the GEMM is a row scatter
                    m_lr[pos[sb]] += coeff
                else:
                    # u side is L here for either source side (odd levels
                    # sit on Y=L when sources are on R)
                    m_lr += (sig_u * mu).T @ coeff
            need_delta = lvl >= 3 or (lvl == 2 and want_odd)
            if need_delta:
                back_t = byx64 if odd else bxy64
                delta_u += sig_u * ((coeff @ back_t) * mu)
        return m_lr, dist_sum, diam

    units = []
    for x in (0, 1):  # source side
        srcs = halves[x]
        block = _source_block_rows(max(len(halves[x]), len(halves[1 - x])))
        for lo in range(0, len(srcs), block):
            units.append((x, srcs[lo : lo + block]))
    parts = _run_units([lambda u=u: sweep(*u) for u in units])
    m_lr = parts[0][0]
    for p in parts[1:]:
        m_lr += p[0]
    dist_sum = sum(p[1] for p in parts)
    diam = max(p[2] for p in parts)

    arcs_lr, flat_lr = lr
    arcs_rl, _ = rl
    flat = m_lr.ravel()
    loads[arcs_lr] = flat[flat_lr]
    loads[arcs_rl] = flat[flat_rl_sym]
    return loads, dist_sum, n * (n - 1), diam


# ---------------------------------------------------------------------------
# Engine: numpy, CSR (transposed reduceat sweeps; for N > util_dense_max)
# ---------------------------------------------------------------------------


def _loads_csr(g: Graph, sources: np.ndarray, targets_mask: np.ndarray,
               demand: np.ndarray | None = None):
    n = g.n
    arc_u, arc_v = g.arc_src, g.indices
    n_arcs = arc_u.shape[0]
    if n_arcs == 0:
        raise ValueError("graph is disconnected")
    rows_by_dst = arc_u[g.arcs_by_dst()]
    # clip trailing degree-0 offsets (== n_arcs) that reduceat rejects;
    # their rows are overwritten via the deg0 mask below
    starts = np.minimum(g.indptr[:-1], n_arcs - 1)
    deg0 = g.degrees == 0
    tm = targets_mask.astype(np.float64)
    t_count = int(targets_mask.sum())
    loads = np.zeros(n_arcs, dtype=np.float64)
    dist_sum = 0.0
    pair_count: float = 0
    diam = 0

    blk = flags().util_block
    if blk <= 0:
        blk = max(4, (96 << 20) // max(8 * n_arcs, 1))
    for lo in range(0, len(sources), blk):
        sb = sources[lo : lo + blk]
        b = len(sb)
        cols = np.arange(b)
        dist_t = np.full((n, b), -1, dtype=np.int16)
        dist_t[sb, cols] = 0
        sig_t = np.zeros((n, b), dtype=np.float64)
        sig_t[sb, cols] = 1.0
        lvl = 0
        while True:
            lvl += 1
            contrib = sig_t[rows_by_dst] * (dist_t[rows_by_dst] == lvl - 1)
            red = np.add.reduceat(contrib, starts, axis=0)
            if deg0.any():
                red[deg0] = 0.0
            new = (red > 0) & (dist_t < 0)
            if not new.any():
                maxd = lvl - 1
                break
            dist_t[new] = lvl
            sig_t[new] = red[new]
        if (dist_t < 0).any():
            raise ValueError("graph is disconnected")
        if demand is None:
            wt = tm[:, None]
            dm = dist_t[targets_mask]
            diam = max(diam, int(dm.max()))
            dist_sum += float(dm.sum(dtype=np.float64))
            pair_count += b * t_count - int(targets_mask[sb].sum())
        else:
            wt = np.ascontiguousarray(demand[sb].T)  # (n, b) demand columns
            active = wt > 0
            if active.any():
                diam = max(diam, int(dist_t[active].max()))
            dist_sum += float((dist_t * wt).sum(dtype=np.float64))
            pair_count += float(wt.sum())

        delta_t = np.zeros((n, b), dtype=np.float64)
        for lvl in range(maxd, 0, -1):
            m = dist_t == lvl
            coeff = np.zeros((n, b), dtype=np.float64)
            np.divide(wt + delta_t, sig_t, out=coeff, where=m)
            contrib = sig_t[arc_u] * coeff[arc_v]
            contrib *= dist_t[arc_u] == lvl - 1
            loads += contrib.sum(axis=1)
            if lvl >= 2:
                red = np.add.reduceat(contrib, starts, axis=0)
                if deg0.any():
                    red[deg0] = 0.0
                delta_t += red
    return loads, dist_sum, pair_count, diam


# ---------------------------------------------------------------------------
# Engine: jax (jnp GEMM recurrences, jit per shape, chunked source blocks)
# ---------------------------------------------------------------------------


def _jax_available() -> bool:
    try:
        import jax  # noqa: F401

        return True
    except ImportError:
        return False


def _loads_jax(g: Graph, sources: np.ndarray, targets_mask: np.ndarray,
               demand: np.ndarray | None = None):
    import jax
    import jax.numpy as jnp

    old_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        return _loads_jax_x64(g, sources, targets_mask, jax, jnp, demand)
    finally:
        jax.config.update("jax_enable_x64", old_x64)


def _loads_jax_x64(g: Graph, sources, targets_mask, jax, jnp, demand=None):
    n = g.n
    adj = jnp.asarray(g.adjacency_dense(np.float64))
    arc_u = jnp.asarray(g.arc_src)
    arc_v = jnp.asarray(g.indices)
    tm = jnp.asarray(targets_mask.astype(np.float64))
    t_count = int(targets_mask.sum())

    @jax.jit
    def fwd_step(front, dist, sigma, lvl):
        nxt = front @ adj
        new = (nxt > 0) & (dist < 0)
        nxt = nxt * new
        dist = jnp.where(new, lvl, dist)
        sigma = jnp.where(new, nxt, sigma)
        return nxt, dist, sigma, new.any()

    @jax.jit
    def bwd_step(delta, ctot, dist, sigma, lvl):
        m = dist == lvl
        coeff = jnp.where(m, (tm[None, :] + delta) / jnp.where(m, sigma, 1.0), 0.0)
        delta = delta + sigma * ((coeff @ adj) * (dist == lvl - 1))
        return delta, ctot + coeff

    @jax.jit
    def bwd_step_weighted(w, delta, ctot, dist, sigma, lvl):
        m = dist == lvl
        coeff = jnp.where(m, (w + delta) / jnp.where(m, sigma, 1.0), 0.0)
        delta = delta + sigma * ((coeff @ adj) * (dist == lvl - 1))
        return delta, ctot + coeff

    @jax.jit
    def arc_sum(sigma, ctot, dist):
        s_u = sigma[:, arc_u]
        c_v = ctot[:, arc_v]
        tree = dist[:, arc_v] == dist[:, arc_u] + 1
        return (s_u * c_v * tree).sum(axis=0)

    loads = np.zeros(g.arc_src.shape[0], dtype=np.float64)
    dist_sum = 0.0
    pair_count: float = 0
    diam = 0
    block = _source_block_rows(n)
    for lo in range(0, len(sources), block):
        sb = sources[lo : lo + block]
        b = len(sb)
        rows = np.arange(b)
        front0 = np.zeros((b, n), dtype=np.float64)
        front0[rows, sb] = 1.0
        dist0 = np.full((b, n), -1, dtype=np.int32)
        dist0[rows, sb] = 0
        front = jnp.asarray(front0)
        dist = jnp.asarray(dist0)
        sigma = jnp.asarray(front0)
        lvl = 0
        while True:
            lvl += 1
            front, dist, sigma, any_new = fwd_step(front, dist, sigma, lvl)
            if not bool(any_new):
                maxd = lvl - 1
                break
        dist_np = np.asarray(dist)
        if (dist_np < 0).any():
            raise ValueError("graph is disconnected")
        if demand is None:
            dm = dist_np[:, targets_mask]
            diam = max(diam, int(dm.max()))
            dist_sum += float(dm.sum(dtype=np.float64))
            pair_count += b * t_count - int(targets_mask[sb].sum())
        else:
            w_np = demand[sb]
            active = w_np > 0
            if active.any():
                diam = max(diam, int(dist_np[active].max()))
            dist_sum += float((dist_np * w_np).sum(dtype=np.float64))
            pair_count += float(w_np.sum())
            w = jnp.asarray(w_np)

        delta = jnp.zeros((b, n), dtype=jnp.float64)
        ctot = jnp.zeros((b, n), dtype=jnp.float64)
        for l in range(maxd, 0, -1):
            if demand is None:
                delta, ctot = bwd_step(delta, ctot, dist, sigma, l)
            else:
                delta, ctot = bwd_step_weighted(w, delta, ctot, dist, sigma, l)
        loads += np.asarray(arc_sum(sigma, ctot, dist))
    return loads, dist_sum, pair_count, diam


def _loads_pallas(g: Graph, sources: np.ndarray, targets_mask: np.ndarray,
                  demand: np.ndarray | None = None):
    """``engine="pallas"``: the jax engine's level recurrences through the
    fused mask+GEMM kernels (repro.kernels.mask_gemm) — compiled float32
    on TPU, float64 under the pallas interpreter elsewhere (the parity /
    development path, same convention as repro.sim's pallas backends)."""
    import jax
    import jax.numpy as jnp

    if jax.default_backend() == "tpu":
        return _loads_pallas_impl(g, sources, targets_mask, jax, jnp,
                                  demand, interpret=False, f64=False)
    old_x64 = jax.config.jax_enable_x64
    jax.config.update("jax_enable_x64", True)
    try:
        return _loads_pallas_impl(g, sources, targets_mask, jax, jnp,
                                  demand, interpret=True, f64=True)
    finally:
        jax.config.update("jax_enable_x64", old_x64)


def _loads_pallas_impl(g: Graph, sources, targets_mask, jax, jnp,
                       demand=None, *, interpret, f64):
    from ..kernels.mask_gemm import backward_step, frontier_step

    n = g.n
    dtype = jnp.float64 if f64 else jnp.float32
    adj = jnp.asarray(g.adjacency_dense(np.float64), dtype)
    arc_u = jnp.asarray(g.arc_src)
    arc_v = jnp.asarray(g.indices)
    tm = jnp.asarray(targets_mask, dtype)
    t_count = int(targets_mask.sum())

    @jax.jit
    def coeff_of(w, delta, dist, sigma, lvl):
        m = dist == lvl
        return jnp.where(m, (w + delta) / jnp.where(m, sigma, 1.0), 0.0)

    @jax.jit
    def arc_sum(sigma, ctot, dist):
        s_u = sigma[:, arc_u]
        c_v = ctot[:, arc_v]
        tree = dist[:, arc_v] == dist[:, arc_u] + 1
        return (s_u * c_v * tree).sum(axis=0)

    loads = np.zeros(g.arc_src.shape[0], dtype=np.float64)
    dist_sum = 0.0
    pair_count: float = 0
    diam = 0
    block = _source_block_rows(n)
    for lo in range(0, len(sources), block):
        sb = sources[lo : lo + block]
        b = len(sb)
        rows = np.arange(b)
        front0 = np.zeros((b, n), dtype=np.float64)
        front0[rows, sb] = 1.0
        dist0 = np.full((b, n), -1, dtype=np.int32)
        dist0[rows, sb] = 0
        front = jnp.asarray(front0, dtype)
        dist = jnp.asarray(dist0)
        sigma = jnp.asarray(front0, dtype)
        lvl = 0
        while True:
            lvl += 1
            front, dist, sigma = frontier_step(front, adj, dist, sigma,
                                               lvl, interpret=interpret)
            if not bool((front > 0).any()):
                maxd = lvl - 1
                break
        dist_np = np.asarray(dist)
        if (dist_np < 0).any():
            raise ValueError("graph is disconnected")
        if demand is None:
            w = tm[None, :]
            dm = dist_np[:, targets_mask]
            diam = max(diam, int(dm.max()))
            dist_sum += float(dm.sum(dtype=np.float64))
            pair_count += b * t_count - int(targets_mask[sb].sum())
        else:
            w_np = demand[sb]
            active = w_np > 0
            if active.any():
                diam = max(diam, int(dist_np[active].max()))
            dist_sum += float((dist_np * w_np).sum(dtype=np.float64))
            pair_count += float(w_np.sum())
            w = jnp.asarray(w_np, dtype)

        delta = jnp.zeros((b, n), dtype=dtype)
        ctot = jnp.zeros((b, n), dtype=dtype)
        for l in range(maxd, 0, -1):
            coeff = coeff_of(w, delta, dist, sigma, l)
            delta = backward_step(coeff, adj, dist, sigma, delta, l - 1,
                                  interpret=interpret)
            ctot = ctot + coeff
        loads += np.asarray(arc_sum(sigma, ctot, dist), dtype=np.float64)
    return loads, dist_sum, pair_count, diam


# ---------------------------------------------------------------------------
# Engine: orbit shortcut
# ---------------------------------------------------------------------------


def _loads_orbit(g: Graph, targets_mask: np.ndarray, inner):
    """One Brandes sweep per vertex orbit; returns None when no known
    automorphism subgroup applies (caller falls back to an exact engine)."""
    from .orbits import orbit_info

    full = bool(targets_mask.all())
    info = orbit_info(g, None if full else targets_mask)
    if info is None:
        return None
    t_count = int(targets_mask.sum())
    used = np.unique(info.vertex_orbit[targets_mask])
    n_aorb = len(info.arc_sizes)
    orbit_sums = np.zeros(n_aorb, dtype=np.float64)
    dist_sum = 0.0
    diam = 0
    for orb in used:
        rep = int(info.vertex_reps[orb])
        size = float(info.vertex_sizes[orb])
        loads_r, dsum_r, _, diam_r = inner(g, np.array([rep]), targets_mask)
        orbit_sums += size * np.bincount(info.arc_orbit, weights=loads_r,
                                         minlength=n_aorb)
        dist_sum += size * dsum_r
        diam = max(diam, diam_r)
    loads = orbit_sums[info.arc_orbit] / info.arc_sizes[info.arc_orbit]
    pair_count = t_count * (t_count - 1)
    return loads, dist_sum, pair_count, diam


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------


def _loads_numpy(g: Graph, sources: np.ndarray, targets_mask: np.ndarray,
                 demand: np.ndarray | None = None):
    if g.n <= flags().util_dense_max:
        with _blas_limit():
            if demand is not None:
                # arbitrary per-pair demand: the half-size bipartite fast
                # paths assume uniform weights, so run the generic engine
                return _loads_dense_generic(g, sources, targets_mask, demand)
            side = g.bipartition()
            if side is not None:
                if targets_mask.all() and np.array_equal(sources, np.arange(g.n)):
                    return _loads_dense_bipartite_all(g, targets_mask, side)
                return _loads_dense_bipartite(g, sources, targets_mask, side)
            return _loads_dense_generic(g, sources, targets_mask)
    return _loads_csr(g, sources, targets_mask, demand)


def _exact_engine(g: Graph):
    """auto's exact-path choice by graph size: dense GEMMs while the dense
    adjacency is reasonable, then jax (if present) up to util_jax_max, then
    the memory-lean CSR sweep."""
    fl = flags()
    if g.n <= fl.util_dense_max:
        obs.counter("util.engine[numpy]").add(1.0)
        return _loads_numpy
    if _jax_available() and g.n <= fl.util_jax_max:
        obs.counter("util.engine[jax]").add(1.0)
        return _loads_jax
    obs.counter("util.engine[csr]").add(1.0)
    return _loads_csr


def arc_loads(g: Graph, sources=None, targets_mask: np.ndarray | None = None,
              engine: str | None = None) -> tuple[np.ndarray, float, int]:
    """Per-arc load under uniform traffic, plus (k̄, diameter) of the pairs used.

    ``sources`` defaults to every vertex (or every leaf if ``targets_mask``
    given); traffic flows from each source to every other target vertex,
    1 unit per ordered pair, split across shortest paths.  ``engine``
    overrides the REPRO_PERF ``util_engine`` flag (see module docstring).
    """
    n = g.n
    if targets_mask is None:
        targets_mask = np.ones(n, dtype=bool)
    else:
        targets_mask = np.asarray(targets_mask, dtype=bool)
    default_sources = sources is None
    if sources is None:
        sources = np.nonzero(targets_mask)[0]
    sources = np.asarray(sources, dtype=np.int64)

    eng = (engine if engine is not None else flags().util_engine).lower()
    if eng not in _ENGINES:
        raise ValueError(f"unknown engine {eng!r}; options: {_ENGINES}")

    with obs.span("util.arc_loads", engine=eng, n=g.n):
        obs.counter(f"util.dispatch[{eng}]").add(1.0)
        if eng == "naive":
            res = _arc_loads_naive(g, sources, targets_mask)
        elif eng == "orbit" or (eng == "auto" and flags().util_orbits
                                and default_sources):
            res = (_loads_orbit(g, targets_mask, _exact_engine(g))
                   if default_sources else None)
            if res is None:
                if eng == "orbit":
                    raise ValueError(
                        f"no known automorphism generators for "
                        f"{g.name or g.meta.get('family')!r}"
                        " (or sources/targets not orbit-compatible)")
                res = _exact_engine(g)(g, sources, targets_mask)
            else:
                obs.counter("util.engine[orbit]").add(1.0)
        elif eng == "numpy":
            res = _loads_numpy(g, sources, targets_mask)
        elif eng == "csr":
            res = _loads_csr(g, sources, targets_mask)
        elif eng == "jax":
            if not _jax_available():
                raise RuntimeError(
                    "engine='jax' requested but jax is not importable")
            res = _loads_jax(g, sources, targets_mask)
        elif eng == "pallas":
            if not _jax_available():
                raise RuntimeError(
                    "engine='pallas' requested but jax is not importable")
            res = _loads_pallas(g, sources, targets_mask)
        else:  # auto, orbits disabled or explicit sources
            res = _exact_engine(g)(g, sources, targets_mask)

    loads, dist_sum, pair_count, diam = res
    kbar = dist_sum / pair_count
    return loads, kbar, diam


def _uniform_demand_split(demand: np.ndarray):
    """Detect a uniform-shaped demand: ``w * (ones - I)`` on some active
    vertex set, zero elsewhere.  Returns ``(w, active_mask)`` or None.

    Such a matrix commutes with the graph's full automorphism group (any
    subgroup preserving the active set), so the orbit shortcut of
    :func:`arc_loads` applies: the weighted sweep reduces to the uniform
    one scaled by w."""
    rows = demand.any(axis=1)
    if not np.array_equal(rows, demand.any(axis=0)):
        return None
    active = np.nonzero(rows)[0]
    if len(active) < 2:
        return None
    block = demand[np.ix_(active, active)]
    w = block[0, 1]
    if w <= 0.0:
        return None
    expect = np.full(block.shape, w)
    np.fill_diagonal(expect, 0.0)
    if not np.array_equal(block, expect):
        return None
    return w, rows


def arc_loads_weighted(g: Graph, demand,
                       engine: str | None = None
                       ) -> tuple[np.ndarray, float, int]:
    """Per-arc load under an arbitrary traffic matrix, split across all
    shortest paths (the demand-matrix generalization of Theorem 3.9).

    ``demand[s, t]`` is the traffic s injects for t (any nonnegative
    units); the diagonal is ignored.  A TrafficPattern instance (anything
    with a ``demand(g)`` method) is accepted directly and built against
    ``g``.  Returns ``(loads, kbar, diameter)`` where ``kbar`` is the
    demand-weighted mean hop count ``sum(D * dist) / sum(D)`` and
    ``diameter`` the longest hop count any demand actually travels.
    ``engine`` as in :func:`arc_loads`; under ``auto``/``orbit`` a
    uniform-shaped demand (``w * (ones - I)`` over an active set — the
    only matrices the automorphism shortcut is exact for) routes through
    the orbit path of :func:`arc_loads` scaled by w, and anything else
    runs the exact engines.
    """
    n = g.n
    if hasattr(demand, "demand") and callable(demand.demand):
        demand = demand.demand(g)  # TrafficPattern duck-type
    demand = np.array(demand, dtype=np.float64)  # private copy, diag zeroed
    if demand.shape != (n, n):
        raise ValueError(f"demand must be ({n}, {n}), got {demand.shape}")
    if not np.isfinite(demand).all():
        raise ValueError("demand must be finite")
    if (demand < 0).any():
        raise ValueError("demand must be nonnegative")
    np.fill_diagonal(demand, 0.0)
    total = float(demand.sum())
    if total == 0.0:
        raise ValueError("demand matrix is all zero")
    sources = np.nonzero(demand.any(axis=1))[0]
    targets_mask = np.ones(n, dtype=bool)

    eng = (engine if engine is not None else flags().util_engine).lower()
    if eng not in _ENGINES:
        raise ValueError(f"unknown engine {eng!r}; options: {_ENGINES}")

    if eng == "orbit" or (eng == "auto" and flags().util_orbits):
        uni = _uniform_demand_split(demand)
        if uni is not None:
            w, mask = uni
            try:
                loads, kbar, diam = arc_loads(g, targets_mask=mask,
                                              engine=eng)
            except ValueError:
                # engine="orbit" on a family without known generators:
                # keep the weighted path's documented contract (the exact
                # engines run instead of raising)
                pass
            else:
                return loads * w, kbar, diam

    with obs.span("util.arc_loads_weighted", engine=eng, n=g.n):
        obs.counter(f"util.dispatch[{eng}]").add(1.0)
        if eng == "naive":
            res = _arc_loads_naive(g, sources, targets_mask, demand)
        elif eng == "numpy":
            res = _loads_numpy(g, sources, targets_mask, demand)
        elif eng == "csr":
            res = _loads_csr(g, sources, targets_mask, demand)
        elif eng == "jax":
            if not _jax_available():
                raise RuntimeError(
                    "engine='jax' requested but jax is not importable")
            res = _loads_jax(g, sources, targets_mask, demand)
        elif eng == "pallas":
            if not _jax_available():
                raise RuntimeError(
                    "engine='pallas' requested but jax is not importable")
            res = _loads_pallas(g, sources, targets_mask, demand)
        else:  # auto / orbit: the exact-path choice by graph size
            res = _exact_engine(g)(g, sources, targets_mask, demand)

    loads, dist_sum, total_demand, diam = res
    return loads, dist_sum / total_demand, diam


def utilization(g: Graph, sources=None, targets_mask: np.ndarray | None = None,
                engine: str | None = None) -> UtilizationReport:
    """The paper's u = mean/max arc load at saturation."""
    if targets_mask is None:
        targets_mask = g.meta.get("leaf_mask")
    loads, kbar, diam = arc_loads(g, sources, targets_mask, engine=engine)
    mx = float(loads.max())
    mean = float(loads.mean())
    return UtilizationReport(u=mean / mx, mean_load=mean, max_load=mx,
                             loads=loads, kbar=kbar, diameter=diam)


def valiant_report(g: Graph, sources=None) -> UtilizationReport:
    """Valiant two-phase randomized routing [paper ref 40]: every packet
    goes s -> (uniform random intermediate m) -> t via minimal paths.

    By linearity of expectation each phase is exactly one uniform-traffic
    ensemble, so the expected per-arc load is 2x the minimal-routing load,
    the load RATIOS (hence u) are unchanged, and the effective path length
    is 2·k̄ — the paper's point that randomization buys worst-case
    guarantees for non-uniform traffic at half the uniform throughput
    (Δ0 ≤ Δ·u/(2k̄) at saturation)."""
    rep = utilization(g, sources)
    return UtilizationReport(u=rep.u, mean_load=rep.mean_load * 2.0,
                             max_load=rep.max_load * 2.0,
                             loads=rep.loads * 2.0, kbar=2.0 * rep.kbar,
                             diameter=rep.diameter)
