"""Finite-field arithmetic GF(q) for q = p^m, vectorized over numpy arrays.

Elements of GF(p^m) are encoded as integers in [0, q): the integer's base-p
digits are the coefficients of the element's polynomial representation over
GF(p).  Multiplication uses discrete log/antilog tables built from a
primitive polynomial found by exhaustive search (cheap for the q used by the
paper's constructions, q <= ~1024).

The tables make every field op a numpy gather, so constructing the incidence
structures of Section 3 stays vectorized end to end.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field

import numpy as np

__all__ = ["GF", "is_prime", "is_prime_power", "prime_power_decompose"]


def is_prime(n: int) -> bool:
    if n < 2:
        return False
    if n < 4:
        return True
    if n % 2 == 0:
        return False
    f = 3
    while f * f <= n:
        if n % f == 0:
            return False
        f += 2
    return True


def prime_power_decompose(q: int) -> tuple[int, int] | None:
    """Return (p, m) with q == p**m and p prime, or None."""
    if q < 2:
        return None
    for p in range(2, q + 1):
        if p * p > q:
            break
        if q % p:
            continue
        m, r = 0, q
        while r % p == 0:
            r //= p
            m += 1
        return (p, m) if r == 1 and is_prime(p) else None
    return (q, 1) if is_prime(q) else None


def is_prime_power(q: int) -> bool:
    return prime_power_decompose(q) is not None


def _poly_mul_mod(a: np.ndarray, b: np.ndarray, mod_poly: np.ndarray, p: int) -> np.ndarray:
    """Multiply two polynomials over GF(p) and reduce by the monic mod_poly."""
    m = len(mod_poly) - 1
    prod = np.zeros(len(a) + len(b) - 1, dtype=np.int64)
    for i, ai in enumerate(a):
        if ai:
            prod[i : i + len(b)] = (prod[i : i + len(b)] + ai * b) % p
    # Reduce: mod_poly is monic of degree m.
    for d in range(len(prod) - 1, m - 1, -1):
        c = prod[d]
        if c:
            prod[d - m : d + 1] = (prod[d - m : d + 1] - c * mod_poly) % p
    return prod[:m] % p


def _int_to_poly(x: int, p: int, m: int) -> np.ndarray:
    out = np.zeros(m, dtype=np.int64)
    for i in range(m):
        out[i] = x % p
        x //= p
    return out


def _poly_to_int(c: np.ndarray, p: int) -> int:
    v = 0
    for coeff in reversed(c.tolist()):
        v = v * p + int(coeff)
    return v


def _find_primitive_poly(p: int, m: int) -> np.ndarray:
    """Exhaustively find a monic primitive polynomial of degree m over GF(p).

    Primitivity is checked directly: x must generate all q-1 nonzero elements
    of GF(p)[x]/(f).  O(q^2) worst case; fine for q <= ~2048.
    """
    q = p**m
    x_poly = np.zeros(m, dtype=np.int64)
    if m == 1:
        x_poly[0] = 1  # placeholder, unused for m == 1
    else:
        x_poly[1] = 1
    for tail in range(p**m):
        mod_poly = np.zeros(m + 1, dtype=np.int64)
        mod_poly[m] = 1
        mod_poly[:m] = _int_to_poly(tail, p, m)
        if mod_poly[0] == 0:  # constant term 0 => divisible by x
            continue
        # Walk powers of x; primitive iff the orbit has size q-1.
        seen = 1
        cur = x_poly.copy()
        start = _poly_to_int(cur, p)
        ok = True
        for _ in range(q - 2):
            cur = _poly_mul_mod(cur, x_poly, mod_poly, p)
            v = _poly_to_int(cur, p)
            if v == start or v == 0:
                ok = False
                break
            seen += 1
        if ok and seen == q - 1:
            # cur is now x^(q-1); primitive iff it equals 1.
            if _poly_to_int(cur, p) == 1:
                return mod_poly
    raise ValueError(f"no primitive polynomial found for GF({p}^{m})")


@dataclass
class GF:
    """The finite field GF(q), q = p^m, with vectorized numpy arithmetic."""

    q: int
    p: int = field(init=False)
    m: int = field(init=False)
    exp: np.ndarray = field(init=False, repr=False)  # exp[i] = g^i, len 2(q-1)
    log: np.ndarray = field(init=False, repr=False)  # log[x] for x in 1..q-1
    _neg: np.ndarray = field(init=False, repr=False)
    _inv: np.ndarray = field(init=False, repr=False)
    _add_hi: np.ndarray = field(init=False, repr=False)  # add table, q x q (small q)

    def __post_init__(self) -> None:
        pm = prime_power_decompose(self.q)
        if pm is None:
            raise ValueError(f"q={self.q} is not a prime power")
        self.p, self.m = pm
        p, m, q = self.p, self.m, self.q
        if m == 1:
            # Prime field: addition is mod-p; find multiplicative generator.
            g = self._find_generator_prime(p)
            exp = np.empty(max(2 * (q - 1), 1), dtype=np.int64)
            cur = 1
            for i in range(q - 1):
                exp[i] = cur
                cur = (cur * g) % p
            exp[q - 1 : 2 * (q - 1)] = exp[: q - 1]
            self.exp = exp
            log = np.zeros(q, dtype=np.int64)
            log[exp[: q - 1]] = np.arange(q - 1)
            self.log = log
            self._neg = (-np.arange(q)) % p
            self._add_hi = np.add.outer(np.arange(q), np.arange(q)) % p
        else:
            mod_poly = _find_primitive_poly(p, m)
            # exp table via repeated multiplication by x.
            exp = np.empty(2 * (q - 1), dtype=np.int64)
            cur = np.zeros(m, dtype=np.int64)
            cur[0] = 1  # the element 1
            x_poly = np.zeros(m, dtype=np.int64)
            x_poly[1] = 1
            for i in range(q - 1):
                exp[i] = _poly_to_int(cur, p)
                cur = _poly_mul_mod(cur, x_poly, mod_poly, p)
            exp[q - 1 :] = exp[: q - 1]
            self.exp = exp
            log = np.zeros(q, dtype=np.int64)
            log[exp[: q - 1]] = np.arange(q - 1)
            self.log = log
            # Addition: digitwise mod-p.  Precompute full table (q<=1024 ok).
            a = np.arange(q)
            digits_a = np.stack([(a // p**i) % p for i in range(m)], axis=-1)
            s = (digits_a[:, None, :] + digits_a[None, :, :]) % p
            weights = p ** np.arange(m)
            self._add_hi = (s * weights).sum(axis=-1)
            self._neg = ((-digits_a) % p * weights).sum(axis=-1)
        # Inverse table.
        inv = np.zeros(q, dtype=np.int64)
        nz = np.arange(1, q)
        inv[nz] = self.exp[(q - 1) - self.log[nz]]
        self._inv = inv

    @staticmethod
    def _find_generator_prime(p: int) -> int:
        if p == 2:
            return 1
        # factor p-1
        n = p - 1
        factors = []
        d = 2
        while d * d <= n:
            if n % d == 0:
                factors.append(d)
                while n % d == 0:
                    n //= d
            d += 1
        if n > 1:
            factors.append(n)
        for g in range(2, p):
            if all(pow(g, (p - 1) // f, p) != 1 for f in factors):
                return g
        raise ValueError("no generator")

    # -- vectorized ops (accept ints or numpy arrays, return int64 arrays) --
    def add(self, a, b):
        return self._add_hi[np.asarray(a), np.asarray(b)]

    def neg(self, a):
        return self._neg[np.asarray(a)]

    def sub(self, a, b):
        return self._add_hi[np.asarray(a), self._neg[np.asarray(b)]]

    def mul(self, a, b):
        a = np.asarray(a)
        b = np.asarray(b)
        out = self.exp[self.log[a] + self.log[b]]
        return np.where((a == 0) | (b == 0), 0, out)

    def inv(self, a):
        a = np.asarray(a)
        if np.any(a == 0):
            raise ZeroDivisionError("inverse of 0 in GF(q)")
        return self._inv[a]

    def div(self, a, b):
        return self.mul(a, self.inv(b))

    def pow(self, a, k: int):
        a = np.asarray(a)
        if k == 0:
            return np.ones_like(a)
        out = self.exp[(self.log[a] * (k % (self.q - 1))) % (self.q - 1)]
        return np.where(a == 0, 0, out)

    def primitive_element(self) -> int:
        return int(self.exp[1]) if self.q > 2 else 1

    def squares(self) -> np.ndarray:
        """The set of nonzero squares of GF(q)."""
        e = np.arange(0, self.q - 1, 2)
        return np.unique(self.exp[e])

    def dot3(self, u: np.ndarray, v: np.ndarray) -> np.ndarray:
        """Scalar product of 3-vectors over GF(q); u, v shaped (..., 3)."""
        t0 = self.mul(u[..., 0], v[..., 0])
        t1 = self.mul(u[..., 1], v[..., 1])
        t2 = self.mul(u[..., 2], v[..., 2])
        return self.add(self.add(t0, t1), t2)


@functools.lru_cache(maxsize=None)
def get_field(q: int) -> GF:
    return GF(q)
