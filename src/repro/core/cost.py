"""The paper's cost/power model (Sections 2.1, 5.3, 6).

Two levels:
  * the abstract model — Eq. (1) Δ0 = Δ·u/k̄, Eq. (2) C_node = c_i + c_t·k̄/u
    + c_r(1+k̄/u)/R, and the k̄/u cost figure used throughout Figs. 7-9;
  * the concrete $-and-Watts model of Section 5.3: routers at
    350.4·R − 892.3 $, electrical cables at 0.985 $/Gbps, optical cables at
    7.7432 / 7.9178 $/Gbps (10k / 25k-node cases), 40 Gbps links, SerDes
    power 2.8 W/port — verified to reproduce Tables 4, 5 and 6 exactly
    (power) / to cable-split accuracy (dollars).
"""

from __future__ import annotations

from dataclasses import dataclass

__all__ = [
    "DirectNetworkSpec",
    "CostParams",
    "cost_figure",
    "max_terminals_per_router",
    "cost_per_node_generic",
    "dollars_per_node",
    "watts_per_node",
    "network_summary",
]

LINK_GBPS = 40.0
ELECTRICAL_PER_GBPS = 0.985  # $/Gbps at ~1 m intra-rack average
OPTICAL_PER_GBPS_10K = 7.7432  # $/Gbps, ~10k-node system layout
OPTICAL_PER_GBPS_25K = 7.9178  # $/Gbps, ~25k-node system layout
ROUTER_COST_SLOPE = 350.4  # $/port
ROUTER_COST_OFFSET = -892.3  # $
SERDES_W_PER_PORT = 2.8  # Watts


def max_terminals_per_router(delta: float, u: float, kbar: float) -> float:
    """Eq. (1): Δ0 ≤ Δ·u/k̄ (equality = full bisection, no oversubscription)."""
    return delta * u / kbar


def cost_figure(kbar: float, u: float) -> float:
    """The k̄/u cost measure of Figs. 7 and 9 (port count per node − 1)."""
    return kbar / u


def cost_per_node_generic(radix: float, kbar: float, u: float,
                          c_i: float = 1.0, c_t: float = 1.0, c_r: float = 0.0) -> float:
    """Eq. (2)."""
    return c_i + c_t * kbar / u + c_r * (1 + kbar / u) / radix


@dataclass
class DirectNetworkSpec:
    """A realized network: graph-level parameters + cable layout split."""

    name: str
    terminals: int  # T
    radix: int  # R
    routers: int  # N
    degree: float  # Δ (max degree for the irregular demi-PN)
    terminals_per_router: float  # Δ0
    kbar: float
    u: float
    electrical_cables: int
    optical_cables: int
    indirect: bool = False

    @property
    def subscription(self) -> float:
        """Δ0 / (Δ·u/k̄): 1.0 = exactly full bisection (Tables 4-5 row)."""
        return self.terminals_per_router / max_terminals_per_router(self.degree, self.u, self.kbar)


def dollars_per_node(spec: DirectNetworkSpec, optical_per_gbps: float | None = None) -> float:
    """Section 5.3 installation cost per compute node."""
    if optical_per_gbps is None:
        optical_per_gbps = (OPTICAL_PER_GBPS_10K if spec.terminals < 17500
                            else OPTICAL_PER_GBPS_25K)
    router_cost = spec.routers * (ROUTER_COST_SLOPE * spec.radix + ROUTER_COST_OFFSET)
    cable_cost = (spec.electrical_cables * ELECTRICAL_PER_GBPS * LINK_GBPS
                  + spec.optical_cables * optical_per_gbps * LINK_GBPS)
    return (router_cost + cable_cost) / spec.terminals


def watts_per_node(spec: DirectNetworkSpec) -> float:
    """SerDes power: 2.8 W × total ports / terminals = 2.8·N·R/T."""
    return SERDES_W_PER_PORT * spec.routers * spec.radix / spec.terminals


@dataclass
class CostParams:
    optical_per_gbps: float | None = None


def network_summary(spec: DirectNetworkSpec, params: CostParams = CostParams()) -> dict:
    return {
        "name": spec.name,
        "T": spec.terminals,
        "R": spec.radix,
        "N": spec.routers,
        "delta0": spec.terminals_per_router,
        "kbar": round(spec.kbar, 4),
        "u": round(spec.u, 4),
        "subscription": round(spec.subscription, 3),
        "electrical_cables": spec.electrical_cables,
        "optical_cables": spec.optical_cables,
        "cost_per_node_usd": round(dollars_per_node(spec, params.optical_per_gbps), 2),
        "power_per_node_w": round(watts_per_node(spec), 2),
        "cost_figure_kbar_over_u": round(cost_figure(spec.kbar, spec.u), 4),
    }
