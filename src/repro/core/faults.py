"""Fault injection and degraded-fabric analysis: what survives of theta
when links and routers die.

The paper's cost case rests on balanced utilization of a *pristine*
fabric; at scale component failure is the steady state, so every theta
claim in this repo is answerable under failure through one object:

``FaultSet``
    An immutable set of down links (undirected endpoint pairs) and down
    routers.  ``apply(g)`` compiles a pristine :class:`Graph` into the
    degraded subgraph — link faults remove edges in place (N preserved,
    family meta kept so traffic patterns stay exact), router faults
    remove the vertex and relabel survivors compactly (family meta
    dropped; ``meta["fault_survivors"]`` maps new ids back).  Both paths
    go through :meth:`Graph.subgraph`, so every derived cache
    (bipartition, arc sorts, dense adjacency) is rebuilt from scratch,
    and ``meta["faults"]`` marks the graph so the orbit machinery never
    applies the pristine family's automorphisms to it.

``random_faults`` / ``targeted_faults``
    Seeded random-k draws (resampled until the degraded graph stays
    connected) and the adversarial greedy cut — remove the max-load
    link/router under a routing model, re-evaluating after each cut.

``fault_report``
    Connectivity/partition report of a fault set: component count and
    sizes, surviving active vertices, whether the analytic engines can
    evaluate the degraded graph at all.

``degraded_report``
    The analytic reroute seam: the traffic pattern is built and
    normalized on the PRISTINE graph (busiest pristine source injects
    one unit — degraded theta stays comparable to pristine theta),
    restricted to the survivors, and evaluated by any registered routing
    model (minimal / valiant / ugal / ugal_threshold) on the degraded
    graph.  ``saturation_report(g, p, faults=fs)`` delegates here.

``degradation_sweep``
    theta-vs-k curves with percentile bands: per trial one seeded
    failure ORDER is drawn and each k takes a prefix of it (nested
    faults), so every trial's curve is monotone whenever theta is
    monotone under adding faults — the resilience analogue of the
    paper's Table 5, serialized by benchmarks/fault_bench.py into
    BENCH_6.json.

See docs/faults.md for semantics, the live-sim event model (repro.sim),
and the static-vs-dynamic parity conditions.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .. import obs
from .graph import Graph, bfs_distances
from .routing import make_routing

__all__ = [
    "FaultSet", "FaultReport", "DegradationSweep", "fault_report",
    "random_faults", "targeted_faults", "degraded_report",
    "degradation_sweep",
]


@dataclass(frozen=True)
class FaultSet:
    """An immutable set of failed components of one graph.

    ``links`` are undirected endpoint pairs (order-insensitive,
    deduplicated); ``routers`` vertex ids.  A link incident to a down
    router is redundant but allowed.  The set is graph-agnostic until
    validated/applied against a specific graph."""

    links: tuple = ()
    routers: tuple = ()

    def __post_init__(self):
        links = tuple(sorted({(min(int(u), int(v)), max(int(u), int(v)))
                              for u, v in self.links}))
        for u, v in links:
            if u == v:
                raise ValueError(f"link fault ({u}, {v}) is a self-loop")
        routers = tuple(sorted({int(r) for r in self.routers}))
        object.__setattr__(self, "links", links)
        object.__setattr__(self, "routers", routers)

    # ---- identity ----
    @property
    def empty(self) -> bool:
        return not self.links and not self.routers

    @property
    def label(self) -> str:
        """Canonical human/cache key, e.g. ``links[0-3,5-9]+routers[2]``."""
        parts = []
        if self.links:
            parts.append("links[" + ",".join(f"{u}-{v}"
                                             for u, v in self.links) + "]")
        if self.routers:
            parts.append("routers[" + ",".join(map(str, self.routers)) + "]")
        return "+".join(parts) if parts else "none"

    # ---- resolution against a graph ----
    def edge_ids(self, g: Graph) -> np.ndarray:
        """Undirected edge ids of the down links; raises if a pair is not
        an edge of ``g``."""
        if not self.links:
            return np.empty(0, dtype=np.int64)
        e = np.sort(g.edges, axis=1)
        packed = e[:, 0] * np.int64(g.n) + e[:, 1]
        order = np.argsort(packed)
        want = np.array([u * g.n + v for u, v in self.links], dtype=np.int64)
        pos = np.searchsorted(packed[order], want)
        bad = (pos >= len(packed)) | (packed[order][np.minimum(
            pos, len(packed) - 1)] != want)
        if bad.any():
            missing = [self.links[i] for i in np.nonzero(bad)[0]]
            raise ValueError(f"link faults {missing} are not edges of "
                             f"{g.name or 'the graph'}")
        return order[pos]

    def router_ids(self, g: Graph) -> np.ndarray:
        rid = np.array(self.routers, dtype=np.int64)
        if rid.size and (rid.min() < 0 or rid.max() >= g.n):
            raise ValueError(f"router fault ids out of range for N={g.n}")
        return rid

    def router_mask(self, g: Graph) -> np.ndarray:
        """(N,) bool: True where the router survives."""
        ok = np.ones(g.n, dtype=bool)
        ok[self.router_ids(g)] = False
        return ok

    def edge_alive(self, g: Graph) -> np.ndarray:
        """(E,) bool over ``g.edges``: True where the undirected edge
        survives (neither failed itself nor incident to a dead router)."""
        alive = np.ones(g.num_edges, dtype=bool)
        alive[self.edge_ids(g)] = False
        rok = self.router_mask(g)
        return alive & rok[g.edges[:, 0]] & rok[g.edges[:, 1]]

    def survivors(self, g: Graph) -> np.ndarray:
        """Old-label ids of surviving routers (identity when no router
        faults)."""
        return np.nonzero(self.router_mask(g))[0]

    # ---- compilation ----
    def apply(self, g: Graph) -> Graph:
        """Compile the degraded graph.  Link-only faults preserve N and
        the family meta (traffic patterns built on the degraded graph
        stay exact); router faults relabel survivors and drop
        family/dims meta (coordinates no longer cover the vertex set).
        ``meta["faults"]`` is set either way, which disables the orbit
        shortcut (repro.core.orbits) — a fault set breaks the pristine
        symmetry."""
        if self.empty:
            raise ValueError("empty FaultSet; nothing to apply")
        name = f"{g.name or 'graph'}!{self.label}"
        if not self.routers:
            meta = dict(g.meta)
            meta["faults"] = self.label
            return g.subgraph(edge_mask=self.edge_alive(g), name=name,
                              meta=meta)
        vm = self.router_mask(g)
        if vm.sum() < 2:
            raise ValueError("router faults leave fewer than 2 routers")
        meta = {k: v for k, v in g.meta.items()
                if k not in ("family", "dims", "leaf_mask")}
        meta["faults"] = self.label
        meta["fault_survivors"] = np.nonzero(vm)[0]
        leaf = g.meta.get("leaf_mask")
        if leaf is not None:
            meta["leaf_mask"] = np.asarray(leaf, dtype=bool)[vm]
        return g.subgraph(edge_mask=self.edge_alive(g), vertex_mask=vm,
                          name=name, meta=meta)

    # ---- restriction helpers (pristine-built objects -> degraded) ----
    def restrict_demand(self, g: Graph, demand: np.ndarray) -> np.ndarray:
        """Restrict a pristine (N, N) demand matrix to the survivors —
        dead routers take their rows/columns (their injected and
        addressed traffic) with them; no renormalization, so degraded
        theta stays in the pristine busiest-source units."""
        demand = np.asarray(demand, dtype=np.float64)
        if demand.shape != (g.n, g.n):
            raise ValueError(f"demand is {demand.shape}, graph has N={g.n}")
        if not self.routers:
            return demand.copy()
        surv = self.survivors(g)
        return demand[np.ix_(surv, surv)].copy()

    def restrict_active(self, g: Graph, targets_mask=None) -> np.ndarray:
        """Degraded-label ids of surviving active vertices.
        ``targets_mask`` is a pristine (N,) bool mask (None = all
        vertices); the result indexes the graph ``apply`` returns."""
        if targets_mask is None:
            active = np.ones(g.n, dtype=bool)
        else:
            active = np.asarray(targets_mask, dtype=bool).copy()
        vm = self.router_mask(g)
        new_id = np.cumsum(vm) - 1
        keep = active & vm
        return new_id[np.nonzero(keep)[0]]


@dataclass
class FaultReport:
    """Connectivity/partition report of one (graph, FaultSet)."""

    faults: str
    n_pristine: int
    n_degraded: int
    routers_down: int
    links_down: int            # edges removed beyond the dead routers'
    edges_removed: int         # total undirected edges lost
    n_components: int
    component_sizes: tuple
    connected: bool            # whole degraded graph one component
    active_survivors: int
    active_connected: bool     # surviving active set in one component
    evaluable: bool            # analytic engines can run (connected, >=2)


def fault_report(g: Graph, fs: FaultSet) -> FaultReport:
    """Partition analysis of the degraded graph: what the fault set cut
    off, and whether the analytic engines (which require every vertex
    reachable from the active set) can evaluate it at all."""
    gd = fs.apply(g) if not fs.empty else g
    comp = np.full(gd.n, -1, dtype=np.int64)
    sizes = []
    for start in range(gd.n):
        if comp[start] >= 0:
            continue
        reach = bfs_distances(gd, start) >= 0
        comp[reach] = len(sizes)
        sizes.append(int(reach.sum()))
    leaf = gd.meta.get("leaf_mask")
    act = (np.arange(gd.n) if leaf is None
           else np.nonzero(np.asarray(leaf, dtype=bool))[0])
    act_conn = bool(len(act) > 0 and np.unique(comp[act]).size == 1)
    connected = len(sizes) <= 1
    return FaultReport(
        faults=fs.label, n_pristine=g.n, n_degraded=gd.n,
        routers_down=len(fs.routers), links_down=len(fs.links),
        edges_removed=g.num_edges - gd.num_edges,
        n_components=len(sizes), component_sizes=tuple(sizes),
        connected=connected, active_survivors=int(len(act)),
        active_connected=act_conn,
        evaluable=bool(connected and len(act) >= 2))


# ---------------------------------------------------------------------------
# Fault-set constructors
# ---------------------------------------------------------------------------


def _links_from_edges(g: Graph, edge_ids) -> tuple:
    e = g.edges[np.asarray(edge_ids, dtype=np.int64)]
    return tuple((int(u), int(v)) for u, v in e)


def random_faults(g: Graph, k_links: int = 0, k_routers: int = 0,
                  seed: int = 0, require_connected: bool = True,
                  max_tries: int = 64) -> FaultSet:
    """A seeded uniform draw of ``k_links`` dead edges and ``k_routers``
    dead routers.  With ``require_connected`` (the default) the draw is
    resampled until the degraded graph is connected with at least two
    surviving active vertices — the regime every analytic engine and the
    simulator's masked tables require."""
    k_links, k_routers = int(k_links), int(k_routers)
    if k_links < 0 or k_routers < 0:
        raise ValueError("fault counts must be >= 0")
    if k_links > g.num_edges:
        raise ValueError(f"k_links={k_links} > {g.num_edges} edges")
    if k_routers >= g.n - 1:
        raise ValueError(f"k_routers={k_routers} leaves < 2 of {g.n} routers")
    if k_links == 0 and k_routers == 0:
        return FaultSet()
    rng = np.random.default_rng(np.random.SeedSequence([int(seed), g.n]))
    for _ in range(max_tries):
        eids = rng.choice(g.num_edges, size=k_links, replace=False)
        rids = rng.choice(g.n, size=k_routers, replace=False)
        fs = FaultSet(links=_links_from_edges(g, eids),
                      routers=tuple(int(r) for r in rids))
        if not require_connected:
            return fs
        rep = fault_report(g, fs)
        if rep.evaluable:
            return fs
    raise ValueError(
        f"no connected degraded graph found in {max_tries} draws for "
        f"k_links={k_links}, k_routers={k_routers} on {g.name or 'graph'}")


def targeted_faults(g: Graph, k: int, kind: str = "links",
                    pattern="uniform", routing: str = "minimal",
                    engine: str | None = None,
                    require_connected: bool = True) -> FaultSet:
    """The adversarial cut: greedily remove the component carrying the
    highest routed load under ``(pattern, routing)``, re-evaluating the
    degraded graph after each removal — k rounds of 'kill the busiest
    link (or router)'.  With ``require_connected`` a removal that would
    disconnect the survivors is skipped for the next-loaded candidate."""
    from .traffic import make_pattern, normalize_demand
    if kind not in ("links", "routers"):
        raise ValueError(f"unknown fault kind {kind!r}; "
                         f"options: links, routers")
    k = int(k)
    leaf = g.meta.get("leaf_mask")
    mask = None if leaf is None else np.asarray(leaf, dtype=bool)
    demand = normalize_demand(make_pattern(pattern).demand(g, mask))
    model = make_routing(routing)
    links: list = []
    routers: list = []
    with obs.span("faults.targeted", kind=kind, k=k, routing=routing):
        _targeted_rounds(g, k, kind, demand, mask, model, engine,
                         require_connected, links, routers)
    return FaultSet(links=tuple(links), routers=tuple(routers))


def _targeted_rounds(g, k, kind, demand, mask, model, engine,
                     require_connected, links, routers):
    """The greedy kill-the-busiest rounds of :func:`targeted_faults`,
    mutating ``links``/``routers`` in place (one round per counter
    tick)."""
    for _ in range(k):
        obs.counter("faults.targeted_rounds").add(1.0)
        fs = FaultSet(links=tuple(links), routers=tuple(routers))
        gd = fs.apply(g) if not fs.empty else g
        dem = fs.restrict_demand(g, demand)
        act = fs.restrict_active(g, mask)
        res = model.evaluate(gd, dem, act, engine)
        surv = fs.survivors(g)
        if kind == "links":
            score = np.zeros(gd.num_edges)
            np.maximum.at(score, gd.arc_edge_id, res.loads)
            order = np.argsort(score)[::-1]
            cands = [(int(surv[gd.edges[e, 0]]), int(surv[gd.edges[e, 1]]))
                     for e in order]
            grow = lambda c: FaultSet(links=tuple(links) + (c,),
                                      routers=tuple(routers))
        else:
            score = np.zeros(gd.n)
            np.add.at(score, gd.arc_src, res.loads)
            order = np.argsort(score)[::-1]
            cands = [int(surv[v]) for v in order]
            grow = lambda c: FaultSet(links=tuple(links),
                                      routers=tuple(routers) + (c,))
        for cand in cands:
            trial = grow(cand)
            if not require_connected or fault_report(g, trial).evaluable:
                if kind == "links":
                    links.append(cand)
                else:
                    routers.append(cand)
                break
        else:
            raise ValueError(
                f"every remaining {kind[:-1]} cut disconnects "
                f"{g.name or 'the graph'} after {len(links) + len(routers)} "
                f"removals")


# ---------------------------------------------------------------------------
# Analytic reroute
# ---------------------------------------------------------------------------


def degraded_report(g: Graph, pattern, faults: FaultSet,
                    routing: str = "minimal", engine: str | None = None,
                    targets_mask=None):
    """``saturation_report`` of a faulted fabric.

    The pattern's demand is built and normalized on the PRISTINE graph
    (busiest pristine source = 1 unit), then restricted to the
    survivors: degraded theta is in the same units as pristine theta, so
    the ratio is the surviving throughput fraction.  Routing re-converges
    on the degraded graph — any registered model."""
    from .traffic import SaturationReport, make_pattern, normalize_demand
    pat = make_pattern(pattern)
    if targets_mask is None:
        targets_mask = g.meta.get("leaf_mask")
    demand = normalize_demand(pat.demand(g, targets_mask))
    if faults.empty:
        gd, dem, act = g, demand, None
        act = (np.arange(g.n) if targets_mask is None else
               np.nonzero(np.asarray(targets_mask, dtype=bool))[0])
    else:
        gd = faults.apply(g)
        dem = faults.restrict_demand(g, demand)
        act = faults.restrict_active(g, targets_mask)
    if len(act) < 2:
        raise ValueError("fewer than 2 active vertices survive the faults")
    if dem.sum() <= 0:
        raise ValueError("faults removed every demand source/target")
    model = make_routing(routing)
    res = model.evaluate(gd, dem, act, engine)
    mx = float(res.loads.max())
    mean = float(res.loads.mean())
    return SaturationReport(
        pattern=pat.name, routing=model.name, theta=1.0 / mx, u=mean / mx,
        max_load=mx, mean_load=mean, kbar_eff=res.kbar_eff,
        diameter=int(res.diameter), total_demand=float(dem.sum()),
        loads=res.loads, alpha=res.alpha, faults=faults.label)


@dataclass
class DegradationSweep:
    """theta-vs-failures curves of one (graph, pattern, routing).

    ``thetas[t, j]`` is trial t's theta at ``k_failures[j]`` dead
    components; within a trial the fault sets are NESTED (prefixes of
    one seeded failure order), so each trial's curve is monotone
    whenever theta is monotone under adding faults.  ``worst``/``mean``/
    ``best`` and the percentile ``bands`` summarize across trials."""

    pattern: str
    routing: str
    kind: str
    k_failures: tuple
    thetas: np.ndarray = field(repr=False)   # (trials, K)
    mean: np.ndarray = field(repr=False)
    worst: np.ndarray = field(repr=False)
    best: np.ndarray = field(repr=False)
    bands: dict = field(repr=False)          # percentile -> (K,) curve
    pristine_theta: float = 0.0
    trials: int = 0
    seed: int = 0


def _nested_draw(g: Graph, ks, kind: str, rng, max_tries: int):
    """One failure ORDER whose every k-prefix keeps the degraded graph
    evaluable; returns the permutation (edge or vertex ids)."""
    pool = g.num_edges if kind == "links" else g.n
    if ks[-1] > (pool if kind == "links" else g.n - 2):
        raise ValueError(f"k={ks[-1]} {kind} failures exceed the graph")
    for _ in range(max_tries):
        perm = rng.permutation(pool)
        ok = True
        for k in ks:
            if k == 0:
                continue
            if kind == "links":
                fs = FaultSet(links=_links_from_edges(g, perm[:k]))
            else:
                fs = FaultSet(routers=tuple(int(v) for v in perm[:k]))
            if not fault_report(g, fs).evaluable:
                ok = False
                break
        if ok:
            return perm
    raise ValueError(f"no connected nested {kind} failure order found in "
                     f"{max_tries} draws (max k={ks[-1]})")


def degradation_sweep(g: Graph, k_failures=(0, 1, 2, 5), trials: int = 8,
                      pattern="uniform", routing: str = "minimal",
                      kind: str = "links", seed: int = 0,
                      engine: str | None = None, targets_mask=None,
                      percentiles=(10, 50, 90),
                      max_tries: int = 64) -> DegradationSweep:
    """theta-vs-k curves with percentile bands: ``trials`` seeded nested
    failure orders, each evaluated at every k in ``k_failures`` under one
    routing model.  The resilience analogue of the paper's Table 5."""
    if kind not in ("links", "routers"):
        raise ValueError(f"unknown fault kind {kind!r}; "
                         f"options: links, routers")
    ks = tuple(sorted({int(k) for k in k_failures}))
    if ks[0] < 0:
        raise ValueError("k_failures must be >= 0")
    if targets_mask is None:
        targets_mask = g.meta.get("leaf_mask")
    from .traffic import saturation_report
    with obs.span("faults.degradation_sweep", kind=kind,
                  routing=routing, trials=int(trials), k_max=ks[-1]):
        pristine = saturation_report(g, pattern, routing=routing,
                                     engine=engine,
                                     targets_mask=targets_mask).theta
        thetas = np.empty((int(trials), len(ks)), dtype=np.float64)
        prog = obs.Progress("faults.trials", total=int(trials) * len(ks))
        for t in range(int(trials)):
            rng = np.random.default_rng(
                np.random.SeedSequence([int(seed), t]))
            perm = _nested_draw(g, ks, kind, rng, max_tries)
            for j, k in enumerate(ks):
                if k == 0:
                    thetas[t, j] = pristine
                    prog.step(trial=t, k=int(k))
                    continue
                if kind == "links":
                    fs = FaultSet(links=_links_from_edges(g, perm[:k]))
                else:
                    fs = FaultSet(routers=tuple(int(v) for v in perm[:k]))
                thetas[t, j] = degraded_report(
                    g, pattern, fs, routing=routing, engine=engine,
                    targets_mask=targets_mask).theta
                prog.step(trial=t, k=int(k), theta=float(thetas[t, j]))
    bands = {int(p): np.percentile(thetas, p, axis=0) for p in percentiles}
    return DegradationSweep(
        pattern=str(pattern), routing=str(routing), kind=kind, k_failures=ks,
        thetas=thetas, mean=thetas.mean(axis=0), worst=thetas.min(axis=0),
        best=thetas.max(axis=0), bands=bands, pristine_theta=float(pristine),
        trials=int(trials), seed=int(seed))
