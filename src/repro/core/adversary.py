"""Adversarial traffic evaluation: the worst pattern per (topology, model).

PolarFly (arXiv:2208.01695) and LACIN (arXiv:2601.05668) both evaluate
their topologies under an adaptive-routing adversarial regime: for each
candidate network, report saturation throughput under a battery of named
patterns plus the worst permutation a search can find, for minimal,
Valiant, AND adaptive (UGAL) routing.  This module reproduces that
comparison for the paper's families:

``worst_case(g, model)``
    Searches the traffic-pattern registry plus ``n_random`` sampled
    permutations for the theta-minimizing pattern under one routing
    model.  theta = 1/max_load with demand normalized to one unit per
    busiest source (repro.core.traffic semantics throughout).

``adversarial_report(g, patterns, models)``
    The per-topology slab of the PolarFly-style table: theta for every
    (pattern, model) cell, sharing the minimal/Valiant sweeps across the
    models built from them (UGAL adds only its breakpoint scan), plus a
    ``worst_perm`` row per model over the sampled permutations.

``adversarial_table(cases, ...)``
    The full table over named topologies — benchmarks/run.py --only
    routing serializes it into BENCH_3.json.

The searched permutations are seeded ``random_permutation(seed)``
patterns, so any worst-case found is reproducible by name; the named
adversaries (tornado, transpose, bit_reversal, shift) are the structured
patterns the literature reports, and on the paper's arc-transitive
PN/demi-PN families the random search confirms their flatness — theta
barely moves across permutations — while torus/dragonfly collapse.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .. import obs
from .graph import Graph
from .routing import evaluate_models, make_routing
from .traffic import make_pattern, normalize_demand

__all__ = [
    "AdversaryReport", "worst_case", "adversarial_report",
    "adversarial_table", "DEFAULT_ADVERSARY_PATTERNS", "DEFAULT_MODELS",
]

DEFAULT_ADVERSARY_PATTERNS = ("uniform", "tornado", "transpose", "shift(1)",
                              "bit_reversal")
DEFAULT_MODELS = ("minimal", "valiant", "ugal")


@dataclass
class AdversaryReport:
    """Worst pattern found for one (graph, routing model)."""

    routing: str
    worst_pattern: str
    worst_theta: float
    thetas: dict[str, float] = field(repr=False)   # pattern spec -> theta
    alphas: dict[str, float | None] = field(repr=False, default_factory=dict)


def _active_and_mask(g: Graph, targets_mask):
    """Resolve the active vertex set.  ``targets_mask`` may be a boolean
    (N,) mask or an integer array of vertex ids (e.g. a Placement's
    occupied routers — fabric.placement feeds these to score how robust
    a job's router set is to hostile tenant traffic)."""
    if targets_mask is None:
        targets_mask = g.meta.get("leaf_mask")
    if targets_mask is None:
        return np.arange(g.n), None
    targets_mask = np.asarray(targets_mask)
    if targets_mask.dtype != bool:
        ids = np.unique(targets_mask.astype(np.int64))
        mask = np.zeros(g.n, dtype=bool)
        mask[ids] = True
        return ids, mask
    return np.nonzero(targets_mask)[0], targets_mask


def _candidate_specs(patterns, n_random: int, seed: int):
    """Named patterns plus seeded random permutations; every candidate is
    a registry spec string, so a worst case found is reproducible by
    name."""
    rng = np.random.default_rng(seed)
    randoms = [f"random_permutation({int(s)})"
               for s in rng.integers(0, 2**31 - 1, size=n_random)]
    return list(patterns), randoms


def _evaluate_specs(g, specs, models, engine, targets_mask, faults=None):
    """{spec: {model: RoutingResult}} with demand built and normalized
    once per spec and the minimal/Valiant sweeps shared across models.

    With ``faults`` (a repro.core.faults.FaultSet), demand is still built
    and normalized on the PRISTINE graph — degraded theta stays in
    pristine busiest-source units — then restricted to the survivors and
    evaluated on the degraded graph (repro.core.faults semantics)."""
    active, mask = _active_and_mask(g, targets_mask)
    if faults is not None and not faults.empty:
        gd = faults.apply(g)
        act_d = faults.restrict_active(g, mask)
        if len(act_d) < 2:
            raise ValueError("fewer than 2 active vertices survive the "
                             "faults")
        out = {}
        prog = obs.Progress("adversary.candidates", total=len(specs))
        for spec in specs:
            obs.counter("adversary.candidates").add(1.0)
            with obs.span("adversary.candidate", pattern=str(spec),
                          faulted=True):
                demand = normalize_demand(make_pattern(spec).demand(g, mask))
                dem = faults.restrict_demand(g, demand)
                if dem.sum() <= 0:
                    raise ValueError(
                        f"faults removed every demand of {spec!r}")
                out[spec] = evaluate_models(gd, dem, act_d, models, engine)
            prog.step(pattern=str(spec), faulted=True)
        return out
    out = {}
    prog = obs.Progress("adversary.candidates", total=len(specs))
    for spec in specs:
        obs.counter("adversary.candidates").add(1.0)
        with obs.span("adversary.candidate", pattern=str(spec)):
            demand = normalize_demand(make_pattern(spec).demand(g, mask))
            out[spec] = evaluate_models(g, demand, active, models, engine)
        prog.step(pattern=str(spec))
    return out


def worst_case(g: Graph, model="minimal",
               patterns=DEFAULT_ADVERSARY_PATTERNS, n_random: int = 8,
               seed: int = 0, engine: str | None = None,
               targets_mask=None, faults=None) -> AdversaryReport:
    """theta-minimizing pattern for one routing model: the named battery
    plus ``n_random`` seeded permutations.  ``faults`` (a FaultSet)
    evaluates every candidate on the degraded graph — the worst pattern
    of a wounded fabric."""
    named, randoms = _candidate_specs(patterns, n_random, seed)
    spec = make_routing(model)  # validate before paying for sweeps
    with obs.span("adversary.search", routing=spec.name,
                  candidates=len(named) + len(randoms)):
        results = _evaluate_specs(g, named + randoms, [model], engine,
                                  targets_mask, faults=faults)
    thetas = {s: 1.0 / r[model].max_load for s, r in results.items()}
    alphas = {s: r[model].alpha for s, r in results.items()}
    worst = min(thetas, key=thetas.get)
    return AdversaryReport(routing=spec.name, worst_pattern=worst,
                           worst_theta=thetas[worst], thetas=thetas,
                           alphas=alphas)


def adversarial_report(g: Graph, patterns=DEFAULT_ADVERSARY_PATTERNS,
                       models=DEFAULT_MODELS, n_random: int = 8,
                       seed: int = 0, engine: str | None = None,
                       targets_mask=None, faults=None):
    """One topology's slab of the PolarFly-style table.

    Returns ``(rows, worst)`` where ``rows`` is a list of dicts — one per
    (pattern, model) cell over the named patterns plus a ``worst_perm``
    pseudo-pattern per model (the theta-minimizing sampled permutation,
    with the realizing spec recorded) — and ``worst`` maps each model to
    its overall min theta across every candidate evaluated."""
    named, randoms = _candidate_specs(patterns, n_random, seed)
    results = _evaluate_specs(g, named + randoms, list(models), engine,
                              targets_mask, faults=faults)

    rows = []
    for spec in named:
        for model in models:
            r = results[spec][model]
            row = {"pattern": spec, "routing": r.routing,
                   "theta": 1.0 / r.max_load, "kbar_eff": r.kbar_eff}
            if r.alpha is not None:
                row["alpha"] = r.alpha
            rows.append(row)
    worst = {}
    for model in models:
        name = make_routing(model).name
        all_thetas = {s: 1.0 / results[s][model].max_load
                      for s in named + randoms}
        worst[name] = {"min_theta": min(all_thetas.values()),
                       "worst_pattern": min(all_thetas, key=all_thetas.get)}
        if randoms:
            rand_thetas = {s: all_thetas[s] for s in randoms}
            worst_rand = min(rand_thetas, key=rand_thetas.get)
            r = results[worst_rand][model]
            row = {"pattern": "worst_perm", "routing": r.routing,
                   "theta": rand_thetas[worst_rand], "kbar_eff": r.kbar_eff,
                   "realized_by": worst_rand, "searched": len(randoms)}
            if r.alpha is not None:
                row["alpha"] = r.alpha
            rows.append(row)
    return rows, worst


def adversarial_table(cases, patterns=DEFAULT_ADVERSARY_PATTERNS,
                      models=DEFAULT_MODELS, n_random: int = 8,
                      seed: int = 0, engine: str | None = None,
                      faults=None):
    """The full adversarial comparison: ``cases`` is an iterable of
    ``(name, graph)`` pairs (see benchmarks.routing_bench for the paper's
    PN/demi-PN/OFT vs torus/dragonfly line-up).  Returns
    ``{name: {"n": N, "rows": [...], "worst": {model: {...}}}}``.
    ``faults`` applies one FaultSet to every case (the table of a shared
    failure scenario); per-case fault sets belong in separate calls."""
    table = {}
    for name, g in cases:
        rows, worst = adversarial_report(g, patterns=patterns, models=models,
                                         n_random=n_random, seed=seed,
                                         engine=engine, faults=faults)
        table[name] = {"n": g.n, "rows": rows, "worst": worst}
    return table
