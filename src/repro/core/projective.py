"""Finite projective planes P2(F_q) and the paper's topologies built on them.

Implements, per the paper's Section 3 and Section 6:
  * the canonical point set of P2(F_q) (Remark 3.1) and its incidence relation,
  * PN      = G_q     (Definition 3.2): the incidence / Levi graph,
  * demi-PN = Ḡ_q     (Definition 3.6): point/line identified quotient,
  * OFT     = Ĝ_q     (Definition 6.1): two-level Orthogonal Fat Tree,
  * MLFM               (Section 6, Fig. 10): Fujitsu Multi-layer Full-Mesh,
  * the Baer-subplane partition of P2(F_{p^2}) via a Singer cycle (Fig. 2),
    used for electrical-group layout.

Point indexing (N = q^2+q+1):
  i in [0, q^2)        -> (1, x, y), x = i // q, y = i % q
  i in [q^2, q^2+q)    -> (0, 1, x), x = i - q^2
  i == q^2 + q         -> (0, 0, 1)
Lines are indexed by their dual points with the same scheme.
"""

from __future__ import annotations

import numpy as np

from .gf import GF, get_field, prime_power_decompose
from .graph import Graph

__all__ = [
    "num_points",
    "points",
    "normalize_points",
    "point_index",
    "incidence_lists",
    "self_orthogonal_points",
    "pn_graph",
    "demi_pn_graph",
    "oft_graph",
    "mlfm_graph",
    "subplane_classes",
]


def num_points(q: int) -> int:
    return q * q + q + 1


def points(q: int) -> np.ndarray:
    """Canonical representatives of P2(F_q), shape (N, 3)."""
    n = num_points(q)
    pts = np.zeros((n, 3), dtype=np.int64)
    i = np.arange(q * q)
    pts[: q * q, 0] = 1
    pts[: q * q, 1] = i // q
    pts[: q * q, 2] = i % q
    pts[q * q : q * q + q, 1] = 1
    pts[q * q : q * q + q, 2] = np.arange(q)
    pts[q * q + q] = (0, 0, 1)
    return pts


def normalize_points(f: GF, vecs: np.ndarray) -> np.ndarray:
    """Scale nonzero projective 3-vectors to canonical form (leading 1)."""
    vecs = np.asarray(vecs, dtype=np.int64)
    out = vecs.copy()
    a, b = vecs[..., 0], vecs[..., 1]
    lead = np.where(a != 0, a, np.where(b != 0, b, vecs[..., 2]))
    if np.any(lead == 0):
        raise ValueError("zero vector is not a projective point")
    scale = f.inv(lead)
    for k in range(3):
        out[..., k] = f.mul(vecs[..., k], scale)
    return out


def point_index(q: int, canon: np.ndarray) -> np.ndarray:
    """Canonical (..., 3) vectors -> point indices."""
    canon = np.asarray(canon, dtype=np.int64)
    a, b, c = canon[..., 0], canon[..., 1], canon[..., 2]
    idx = np.where(
        a == 1,
        b * q + c,
        np.where(b == 1, q * q + c, q * q + q),
    )
    return idx


def incidence_lists(q: int) -> np.ndarray:
    """inc[j] = sorted indices of the q+1 points on line j (dual-indexed).

    Built case-by-case from the linear equation a + b*x + c*y = 0, so the
    whole incidence structure costs O(q^3) table lookups, never O(N^2).
    """
    f = get_field(q)
    pts = points(q)
    n = num_points(q)
    a, b, c = pts[:, 0], pts[:, 1], pts[:, 2]
    inc = np.empty((n, q + 1), dtype=np.int64)
    xs = np.arange(q, dtype=np.int64)

    m1 = c != 0  # lines with c != 0
    if m1.any():
        a1, b1, c1 = a[m1], b[m1], c[m1]
        cinv = f.inv(c1)
        # the one point of shape (0, 1, x): x = -b/c
        inc[m1, 0] = q * q + f.mul(f.neg(b1), cinv)
        # q points (1, x, y): y = -(a + b x)/c
        y = f.mul(f.neg(f.add(a1[:, None], f.mul(b1[:, None], xs[None, :]))), cinv[:, None])
        inc[m1, 1:] = xs[None, :] * q + y

    m2 = (c == 0) & (b != 0)  # contains (0,0,1); points (1, -a/b, y) all y
    if m2.any():
        a2, b2 = a[m2], b[m2]
        inc[m2, 0] = q * q + q
        x0 = f.mul(f.neg(a2), f.inv(b2))
        inc[m2, 1:] = x0[:, None] * q + xs[None, :]

    m3 = (c == 0) & (b == 0)  # the line (1,0,0): (0,0,1) and all (0,1,x)
    if m3.any():
        inc[m3, 0] = q * q + q
        inc[m3, 1:] = q * q + xs[None, :]

    inc.sort(axis=1)
    return inc


def self_orthogonal_points(q: int) -> np.ndarray:
    """Indices of the q+1 points P with P ⊥ P (degree-q vertices of Ḡ_q)."""
    f = get_field(q)
    pts = points(q)
    return np.nonzero(f.dot3(pts, pts) == 0)[0]


def pn_graph(q: int) -> Graph:
    """PN: the incidence graph G_q (Definition 3.2).

    Vertices: [0, N) = points (side 0), [N, 2N) = lines (side 1).
    """
    _check_prime_power(q)
    n = num_points(q)
    inc = incidence_lists(q)
    lines = np.repeat(np.arange(n), q + 1) + n
    pts = inc.reshape(-1)
    g = Graph(2 * n, np.stack([pts, lines], axis=1), name=f"PN({q})")
    g.meta.update(q=q, family="pn", bipartite=True)
    return g


def demi_pn_graph(q: int) -> Graph:
    """demi-PN: the modified incidence graph Ḡ_q (Definition 3.6)."""
    _check_prime_power(q)
    n = num_points(q)
    inc = incidence_lists(q)
    lines = np.repeat(np.arange(n), q + 1)
    pts = inc.reshape(-1)
    mask = pts != lines  # drop the self-orthogonal fixed incidences
    g = Graph(n, np.stack([pts[mask], lines[mask]], axis=1), name=f"demi-PN({q})")
    g.meta.update(q=q, family="demi_pn", bipartite=False)
    return g


def oft_graph(q: int) -> Graph:
    """OFT: Ĝ_q (Definition 6.1), the two-level Orthogonal Fat Tree.

    Columns: [0, N) leaves, [N, 2N) spines, [2N, 3N) leaves.
    """
    _check_prime_power(q)
    n = num_points(q)
    inc = incidence_lists(q)
    lines = np.repeat(np.arange(n), q + 1)
    pts = inc.reshape(-1)
    e0 = np.stack([pts, lines + n], axis=1)  # {(0,P),(1,L)}, P ⊥ L
    e1 = np.stack([pts + n, lines + 2 * n], axis=1)  # {(1,P),(2,L)}, P ⊥ L
    g = Graph(3 * n, np.concatenate([e0, e1]), name=f"OFT({q})")
    leaf = np.ones(3 * n, dtype=bool)
    leaf[n : 2 * n] = False
    g.meta.update(q=q, family="oft", indirect=True, leaf_mask=leaf)
    return g


def mlfm_graph(n_mesh: int) -> Graph:
    """Fujitsu Multi-layer Full-Mesh from the incidence graph of K_n (Fig. 10).

    Leaves (a, i), a in [0,n), i in [0,n-1); spine {a,b} adjacent to every
    replica of a and of b.  Leaves first, then spines.
    """
    n = n_mesh
    n_leaves = n * (n - 1)
    pairs = [(a, b) for a in range(n) for b in range(a + 1, n)]
    edges = []
    for s, (a, b) in enumerate(pairs):
        spine = n_leaves + s
        for i in range(n - 1):
            edges.append((a * (n - 1) + i, spine))
            edges.append((b * (n - 1) + i, spine))
    g = Graph(n_leaves + len(pairs), np.array(edges, dtype=np.int64), name=f"MLFM({n})")
    leaf = np.zeros(g.n, dtype=bool)
    leaf[:n_leaves] = True
    g.meta.update(n_mesh=n, family="mlfm", indirect=True, leaf_mask=leaf)
    return g


# ---------------------------------------------------------------------------
# Baer-subplane partition via a Singer cycle (layout of Fig. 2).
# ---------------------------------------------------------------------------


def _find_irreducible_cubic(f: GF, rng: np.random.Generator) -> np.ndarray:
    """Monic cubic over GF(q) with no roots (cubic => irreducible)."""
    xs = np.arange(f.q, dtype=np.int64)
    while True:
        c0, c1, c2 = (int(rng.integers(f.q)) for _ in range(3))
        if c0 == 0:
            continue
        # evaluate x^3 + c2 x^2 + c1 x + c0 at all x
        v = f.add(f.add(f.pow(xs, 3), f.mul(c2, f.mul(xs, xs))), f.add(f.mul(c1, xs), c0))
        if not np.any(v == 0):
            return np.array([c0, c1, c2, 1], dtype=np.int64)


def _ext_mul(f: GF, g: np.ndarray, u: tuple, v: tuple) -> tuple:
    """Multiply two GF(q)[t]/(g) elements given as 3-tuples over GF(q)."""
    prod = [0] * 5
    for i in range(3):
        if u[i] == 0:
            continue
        for j in range(3):
            prod[i + j] = int(f.add(prod[i + j], f.mul(u[i], v[j])))
    # reduce degree 4 then 3 by monic g = t^3 + g2 t^2 + g1 t + g0
    for d in (4, 3):
        c = prod[d]
        if c:
            prod[d] = 0
            for k in range(3):
                prod[d - 3 + k] = int(f.sub(prod[d - 3 + k], f.mul(c, g[k])))
    return tuple(prod[:3])


def _ext_pow(f: GF, g: np.ndarray, u: tuple, k: int) -> tuple:
    out = (1, 0, 0)
    base = u
    while k:
        if k & 1:
            out = _ext_mul(f, g, out, base)
        base = _ext_mul(f, g, base, base)
        k >>= 1
    return out


def _factorize(n: int) -> list[int]:
    fs, d = [], 2
    while d * d <= n:
        if n % d == 0:
            fs.append(d)
            while n % d == 0:
                n //= d
        d += 1
    if n > 1:
        fs.append(n)
    return fs


def subplane_classes(q: int, seed: int = 0) -> np.ndarray:
    """Partition the points of P2(F_{p^2}) into p^2-p+1 Baer subplanes.

    Returns class[i] in [0, p^2-p+1) for each point index i.  Uses the Singer
    cycle of PG(2, q): points are F_{q^3}*/F_q*, a cyclic group of order N;
    the cosets of its subgroup of order p^2+p+1 are Baer subplanes [21].
    """
    pm = prime_power_decompose(q)
    if pm is None:
        raise ValueError(f"q={q} not a prime power")
    p2 = int(round(q**0.5))
    if p2 * p2 != q:
        raise ValueError(f"q={q} is not a square; no Baer-subplane partition")
    f = get_field(q)
    rng = np.random.default_rng(seed)
    g = _find_irreducible_cubic(f, rng)
    order = q**3 - 1
    factors = _factorize(order)
    # find a primitive element xi of GF(q^3)*
    while True:
        xi = tuple(int(rng.integers(f.q)) for _ in range(3))
        if xi == (0, 0, 0):
            continue
        if all(_ext_pow(f, g, xi, order // pf) != (1, 0, 0) for pf in factors):
            break
    n = num_points(q)
    r = q - p2 + 1  # = p^2 - p + 1 classes
    classes = np.full(n, -1, dtype=np.int64)
    cur = (1, 0, 0)
    for i in range(n * (q - 1)):
        # the Singer cycle on points has period N; normalize and assign
        vec = np.array([cur[0], cur[1], cur[2]], dtype=np.int64)
        idx = int(point_index(q, normalize_points(f, vec)))
        if classes[idx] < 0:
            classes[idx] = i % r
        cur = _ext_mul(f, g, cur, xi)
        if not np.any(classes < 0):
            break
    if np.any(classes < 0):
        raise RuntimeError("Singer cycle failed to cover all points")
    return classes


def subplane_line_classes(q: int, point_classes: np.ndarray) -> np.ndarray:
    """Class of each line: the unique Baer subplane it meets in p+1 points.

    A line of PG(2, p^2) meets one subplane of the partition in p+1 points
    and every other in exactly 1, so the argmax of per-class point counts is
    well defined; this makes each layout group an induced copy of G_p in
    G_{p^2} (Figure 2).
    """
    p = int(round(q**0.5))
    inc = incidence_lists(q)
    n = num_points(q)
    r = q - p + 1
    cls_on_line = point_classes[inc]  # (N, q+1)
    counts = np.zeros((n, r), dtype=np.int64)
    rows = np.repeat(np.arange(n), q + 1)
    np.add.at(counts, (rows, cls_on_line.reshape(-1)), 1)
    line_cls = counts.argmax(axis=1)
    if not (counts.max(axis=1) == p + 1).all():
        raise RuntimeError("Baer partition property violated")
    return line_cls


def _check_prime_power(q: int) -> None:
    if prime_power_decompose(q) is None:
        raise ValueError(f"q={q} must be a prime power")
