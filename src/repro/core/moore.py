"""Moore and generalized Moore bounds (Section 2.2)."""

from __future__ import annotations

import numpy as np

__all__ = [
    "moore_bound",
    "moore_distance_distribution",
    "generalized_moore_distribution",
    "generalized_moore_kbar",
    "kbar_approx",
    "terminals_bound",
]


def moore_bound(delta: int, k: int) -> int:
    """M(Δ, k) = (Δ(Δ-1)^k - 2)/(Δ - 2), Eq. (3)."""
    if delta == 2:
        return 2 * k + 1
    return (delta * (delta - 1) ** k - 2) // (delta - 2)


def moore_distance_distribution(delta: int, k: int) -> np.ndarray:
    w = np.zeros(k + 1, dtype=np.float64)
    w[0] = 1
    for t in range(1, k + 1):
        w[t] = delta * (delta - 1) ** (t - 1)
    return w


def generalized_moore_distribution(delta: int, k: int, n: int) -> np.ndarray:
    """W(t) for a generalized Moore graph on n vertices: Moore-full up to
    k-1, remainder at distance k."""
    if n > moore_bound(delta, k):
        raise ValueError("n exceeds the Moore bound for this (Δ, k)")
    if k >= 1 and n <= moore_bound(delta, k - 1):
        raise ValueError("n fits in diameter k-1; use a smaller k")
    w = moore_distance_distribution(delta, k - 1)
    w = np.append(w, n - w.sum())
    return w


def generalized_moore_kbar(delta: int, k: int, n: int) -> float:
    """Exact minimum average distance for an n-vertex degree-Δ graph."""
    w = generalized_moore_distribution(delta, k, n)
    return float((np.arange(k + 1) * w).sum() / (n - 1))


def min_kbar(delta: int, n: int) -> float:
    """Generalized-Moore lower bound on k̄ for any degree-Δ graph on n vertices."""
    k = 1
    while moore_bound(delta, k) < n:
        k += 1
    return generalized_moore_kbar(delta, k, n)


def kbar_approx(delta: int, k: int, n: int) -> float:
    """Eq. (4): k̄ ≈ k - Δ^(k-1)/N (large-Δ approximation)."""
    return k - delta ** (k - 1) / n


def terminals_bound(radix: int, k: int, kbar: float) -> float:
    """Eq. (5): T ≈ R^k k̄^(k-1) / ((k - k̄)(k̄+1)^k) — the scaling law used
    as the thick lower-bound curve of Fig. 7."""
    if not (0 < kbar < k):
        raise ValueError("need 0 < k̄ < k")
    return radix**k * kbar ** (k - 1) / ((k - kbar) * (kbar + 1) ** k)
