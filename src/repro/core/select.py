"""Closed-form realizations of every Table-2/3 family + the Section-5
optimal-topology selector: given a router radix budget and a terminal
target, enumerate feasible networks and rank them by the k̄/u cost figure.

Formulas follow Tables 2 and 3 exactly; where the paper uses limit values
(Turán, Delorme, generalized quadrangle/hexagon incidence) we do too, and
where exact k̄/u are cheap (PN, demi-PN, Hamming, hypercube, complete,
bipartite) we use the exact expressions.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from .gf import is_prime_power
from .moore import min_kbar

__all__ = ["Realization", "realizations_for_family", "all_realizations",
           "select_topology", "FAMILIES"]


@dataclass
class Realization:
    family: str
    param: int  # q, n, h, r ... primary size parameter
    terminals: float
    radix: float
    routers: float
    degree: float
    delta0: float
    kbar: float
    u: float
    diameter: int
    extra: dict = field(default_factory=dict)

    @property
    def cost_figure(self) -> float:
        return self.kbar / self.u


def _mk(family, param, N, delta, kbar, u, k, **extra) -> Realization:
    delta0 = delta * u / kbar
    return Realization(family=family, param=param, terminals=N * delta0,
                       radix=delta + delta0, routers=N, degree=delta,
                       delta0=delta0, kbar=kbar, u=u, diameter=k, extra=extra)


def _complete(n):  # K_N
    return _mk("complete", n, n, n - 1, 1.0, 1.0, 1)


def _turan(n, r):
    if n % r:
        return None
    kbar = 1 + (n / r - 1) / (n - 1)
    return _mk("turan", n, n, n - n / r, kbar, 1.0, 2, r=r)


def _bipartite(n):  # K_{n,n}
    kbar = (n + 2 * (n - 1)) / (2 * n - 1)
    return _mk("bipartite", n, 2 * n, n, kbar, 1.0, 2)


def _hamming2(n):
    kbar = 2 * n / (n + 1)
    return _mk("hamming2", n, n * n, 2 * (n - 1), kbar, 1.0, 2, side=n)


def _hamming3(n):
    # W: 3(n-1) at 1, 3(n-1)^2 at 2, (n-1)^3 at 3
    N = n**3
    kbar = (3 * (n - 1) + 6 * (n - 1) ** 2 + 3 * (n - 1) ** 3) / (N - 1)
    return _mk("hamming3", n, N, 3 * (n - 1), kbar, 1.0, 3, side=n)


def _demi_pn(q):
    if not is_prime_power(q):
        return None
    N = q * q + q + 1
    kbar = 2 - (q + 1) / N
    u = (2 * q * q + q + 1) / (2 * q * (q + 1))
    return _mk("demi_pn", q, N, q + 1, kbar, u, 2)


def _pn(q):
    if not is_prime_power(q):
        return None
    N = 2 * (q * q + q + 1)
    kbar = (5 * q * q + 3 * q + 1) / (2 * q * q + 2 * q + 1)
    return _mk("pn", q, N, q + 1, kbar, 1.0, 3)


def _mms(q):
    if not is_prime_power(q) or q % 4 == 2 or q == 2:
        return None
    eps = {1: 1, 3: -1, 0: 0}[q % 4]
    N = 2 * q * q
    delta = (3 * q - eps) / 2
    kbar = 2 - delta / (N - 1)
    return _mk("mms", q, N, delta, kbar, 8 / 9, 2, eps=eps)


def _dragonfly(h):
    N = 4 * h**3 + 2 * h
    delta = 3 * h - 1
    # paper's Table 3 dimensioning: Δ0 = h, i.e. effective k̄/u = Δ/h
    r = _mk("dragonfly", h, N, delta, 3.0, 1.0, 3)
    r.delta0 = h
    r.terminals = N * h
    r.radix = 4 * h - 1
    return r


def _delorme_q(q):  # Delorme's graph on generalized quadrangles (k̄ → 3)
    # exists for q an odd power of 2
    m = int(round(np.log2(q)))
    if 2**m != q or m % 2 == 0:
        return None
    N = q**3 + q**2 + q + 1
    return _mk("delorme_q", q, N, q + 1, 3.0, 1.0, 3)


def _gq_incidence(q):  # incidence graph of generalized quadrangles (k̄ → 3.5)
    if not is_prime_power(q):
        return None
    N = 2 * (q**3 + q**2 + q + 1)
    return _mk("gq_incidence", q, N, q + 1, 3.5, 1.0, 4)


def _delorme_h(q):  # Delorme on generalized hexagons (k̄ → 5)
    m = int(round(np.log2(q)))
    if 2**m != q or m % 2 == 0:
        return None
    N = q**5 + q**4 + q**3 + q**2 + q + 1
    return _mk("delorme_h", q, N, q + 1, 5.0, 1.0, 5)


def _gh_incidence(q):  # incidence graph of generalized hexagons (k̄ → 5.5)
    if not is_prime_power(q):
        return None
    N = 2 * (q**5 + q**4 + q**3 + q**2 + q + 1)
    return _mk("gh_incidence", q, N, q + 1, 5.5, 1.0, 6)


def _hypercube(n):
    N = 2**n
    kbar = n * 2 ** (n - 1) / (N - 1)
    return _mk("hypercube", n, N, n, kbar, 1.0, n)


def _random(n_log2, delta):
    N = 2**n_log2
    kbar = max(np.log(N) / np.log(delta), 1.0)
    return _mk("random", N, N, delta, kbar, 0.8, int(np.ceil(kbar)), d=delta)


FAMILIES = {
    "complete": ("n", _complete),
    "turan": ("n", None),  # handled specially (two params)
    "bipartite": ("n", _bipartite),
    "hamming2": ("n", _hamming2),
    "hamming3": ("n", _hamming3),
    "demi_pn": ("q", _demi_pn),
    "pn": ("q", _pn),
    "mms": ("q", _mms),
    "dragonfly": ("h", _dragonfly),
    "delorme_q": ("q", _delorme_q),
    "gq_incidence": ("q", _gq_incidence),
    "delorme_h": ("q", _delorme_h),
    "gh_incidence": ("q", _gh_incidence),
    "hypercube": ("n", _hypercube),
}


def realizations_for_family(family: str, max_radix: int,
                            turan_r: int = 3) -> list[Realization]:
    out: list[Realization] = []
    if family == "turan":
        for n in range(turan_r, 4 * max_radix):
            r = _turan(n, turan_r)
            if r and r.radix <= max_radix:
                out.append(r)
        return out
    _, fn = FAMILIES[family]
    if family == "random":
        fn = _random
    for p in range(2, 6 * max_radix):
        r = fn(p)
        if r is None:
            continue
        if r.radix > max_radix:
            if family in ("hypercube",):  # monotone in param
                break
            if p > 3 * max_radix:
                break
            continue
        out.append(r)
    return out


def all_realizations(max_radix: int) -> dict[str, list[Realization]]:
    return {fam: realizations_for_family(fam, max_radix) for fam in FAMILIES
            if fam != "turan"} | {"turan": realizations_for_family("turan", max_radix)}


def select_topology(terminals: int, max_radix: int,
                    slack: float = 1.0) -> list[Realization]:
    """Feasible realizations with T >= terminals·slack, sorted by k̄/u then
    by router count — the Section-5 'optimal topology is the curve
    immediately above the (R, T) point' rule."""
    cands = [r for fam in all_realizations(max_radix).values() for r in fam
             if r.terminals >= terminals * slack]
    return sorted(cands, key=lambda r: (r.cost_figure, r.routers))
