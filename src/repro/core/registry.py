"""Name -> graph constructor registry for benchmarks, tests and the CLI."""

from __future__ import annotations

from typing import Callable

from .graph import Graph
from .mms import mms_graph
from .projective import demi_pn_graph, mlfm_graph, oft_graph, pn_graph
from .reference import (
    complete_bipartite_graph,
    complete_graph,
    dragonfly_graph,
    hamming_graph,
    hypercube_graph,
    paley_graph,
    random_regular_graph,
    turan_graph,
)

__all__ = ["TOPOLOGIES", "build_topology"]

TOPOLOGIES: dict[str, Callable[..., Graph]] = {
    "pn": pn_graph,
    "demi_pn": demi_pn_graph,
    "oft": oft_graph,
    "mlfm": mlfm_graph,
    "mms": mms_graph,
    "slimfly": mms_graph,
    "complete": complete_graph,
    "turan": turan_graph,
    "bipartite": complete_bipartite_graph,
    "paley": paley_graph,
    "hamming": hamming_graph,
    "dragonfly": dragonfly_graph,
    "hypercube": hypercube_graph,
    "random": random_regular_graph,
}


def build_topology(name: str, *args, **kwargs) -> Graph:
    try:
        fn = TOPOLOGIES[name]
    except KeyError:
        raise KeyError(f"unknown topology {name!r}; options: {sorted(TOPOLOGIES)}") from None
    return fn(*args, **kwargs)
