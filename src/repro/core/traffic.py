"""Traffic patterns and saturation analysis — the demand-matrix view of
Theorem 3.9.

The paper's utilization u = mean/max arc load is defined for UNIFORM
all-to-all traffic; its balance argument ("symmetric networks keep every
link equally busy, so Eq. 1's a = Δ·u/k̄ is achievable") only bites when
competing topologies are stressed with the traffic that unbalances them.
This module states the general problem: a traffic matrix D[s, t] gives the
demand each source injects for each target, split evenly across all
shortest paths (or routed through Valiant intermediates), and the engines
of repro.core.utilization accumulate the per-arc load L_a:

    L_a = sum_{s,t} D[s,t] · (# shortest s->t paths through a) / (# s->t paths)

Normalizing D so the busiest source injects 1 unit, the saturation
throughput is theta = 1 / max_a L_a — the fraction of one link's bandwidth
every node can sustainably inject under that pattern.  For uniform traffic
theta IS Eq. 1's a = Δ·u/k̄; for adversarial patterns (tornado shifts,
bit-reversal, hot regions) theta collapses on asymmetric topologies while
the paper's PN/demi-PN families, being arc-transitive, degrade gracefully
— and Valiant routing [paper ref 40] buys back worst-case guarantees at
half the uniform throughput.

Patterns are registered in ``PATTERNS`` and built by name (with optional
``name(arg, ...)`` parameters) via :func:`make_pattern`:

  uniform             all-to-all, 1 unit per ordered pair
  bit_reversal        rank i -> bit-reversed rank (FFT / transpose phases)
  transpose           (r, c) -> (c, r) on the largest square rank grid
  shift(k)            rank i -> i+k mod m (neighbor exchange; halo phases)
  tornado             shift by m//2 — the classic torus worst case
  random_permutation(seed)  a sampled permutation (Valiant's average case)
  hot_region(frac, boost)   all-to-all with a boosted hot target region
  collective(op)      demand of one fabric collective (see below)

``collective`` derives its matrix from the schedules fabric.collectives
prices: spread ops (``all-to-all``, ``all-gather``, ``reduce-scatter``,
``all-reduce``) send each node's bytes uniformly to all peers, while the
``ring-*`` variants serialize the same bytes over the rank-ring shift
permutation — which is exactly how a DC ring all-reduce turns a balanced
topology into a single hot cycle.

``saturation_report(g, pattern, routing=...)`` evaluates one pattern;
``saturation_sweep`` runs a battery and reports the worst case — the
quantitative form of the paper's "suboptimal designs" claim.
"""

from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .graph import Graph
from .utilization import arc_loads_weighted

__all__ = [
    "TrafficPattern", "PATTERNS", "register_pattern", "make_pattern",
    "SaturationReport", "saturation_report", "saturation_sweep",
    "DEFAULT_SWEEP", "COLLECTIVE_OPS",
]


# ---------------------------------------------------------------------------
# Pattern objects and registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrafficPattern:
    """A named recipe producing a demand matrix for any graph.

    ``builder(g, active)`` receives the graph and the sorted vertex ids
    that send/receive traffic (all vertices, or the leaf set of an
    indirect network) and returns a dense (N, N) float64 demand matrix.
    """

    name: str
    builder: Callable[[Graph, np.ndarray], np.ndarray] = field(repr=False)
    description: str = ""

    def demand(self, g: Graph, targets_mask: np.ndarray | None = None) -> np.ndarray:
        if targets_mask is None:
            targets_mask = g.meta.get("leaf_mask")
        if targets_mask is None:
            active = np.arange(g.n)
        else:
            active = np.nonzero(np.asarray(targets_mask, dtype=bool))[0]
        if len(active) < 2:
            raise ValueError("need at least 2 active vertices")
        d = self.builder(g, active)
        np.fill_diagonal(d, 0.0)
        return d


PATTERNS: dict[str, Callable[..., TrafficPattern]] = {}


def register_pattern(name: str):
    """Register a pattern factory: ``fn(*args) -> TrafficPattern``."""

    def deco(fn):
        PATTERNS[name] = fn
        return fn

    return deco


def _perm_demand(n: int, active: np.ndarray, perm: np.ndarray,
                 weight: float = 1.0) -> np.ndarray:
    """Demand matrix for rank permutation ``perm`` over the active set.
    Fixed points become self-demand and are zeroed by ``demand()``."""
    d = np.zeros((n, n), dtype=np.float64)
    d[active, active[perm]] = weight
    return d


@register_pattern("uniform")
def _uniform() -> TrafficPattern:
    def build(g, active):
        d = np.zeros((g.n, g.n), dtype=np.float64)
        d[np.ix_(active, active)] = 1.0
        return d

    return TrafficPattern("uniform", build, "all-to-all, 1 unit per ordered pair")


@register_pattern("bit_reversal")
def _bit_reversal() -> TrafficPattern:
    def build(g, active):
        m = len(active)
        bits = max(1, (m - 1).bit_length())
        i = np.arange(m)
        rev = np.zeros(m, dtype=np.int64)
        for b in range(bits):
            rev |= ((i >> b) & 1) << (bits - 1 - b)
        perm = np.where(rev < m, rev, i)  # out-of-range reversals stay home
        return _perm_demand(g.n, active, perm)

    return TrafficPattern("bit_reversal", build,
                          "rank -> bit-reversed rank (FFT exchange phase)")


@register_pattern("transpose")
def _transpose() -> TrafficPattern:
    def build(g, active):
        m = len(active)
        side = math.isqrt(m)
        perm = np.arange(m)
        sq = side * side
        r, c = np.divmod(np.arange(sq), side)
        perm[:sq] = c * side + r  # (r, c) -> (c, r); ranks beyond sq stay home
        return _perm_demand(g.n, active, perm)

    return TrafficPattern("transpose", build,
                          "matrix transpose on the largest square rank grid")


@register_pattern("shift")
def _shift(k: int = 1) -> TrafficPattern:
    def build(g, active):
        m = len(active)
        perm = (np.arange(m) + int(k)) % m
        return _perm_demand(g.n, active, perm)

    return TrafficPattern(f"shift({k})", build, f"rank i -> i+{k} mod m")


@register_pattern("tornado")
def _tornado() -> TrafficPattern:
    def build(g, active):
        m = len(active)
        perm = (np.arange(m) + m // 2) % m
        return _perm_demand(g.n, active, perm)

    return TrafficPattern("tornado", build,
                          "half-ring shift — the classic torus adversary")


@register_pattern("random_permutation")
def _random_permutation(seed: int = 0) -> TrafficPattern:
    def build(g, active):
        rng = np.random.default_rng(int(seed))
        perm = rng.permutation(len(active))
        return _perm_demand(g.n, active, perm)

    return TrafficPattern(f"random_permutation({seed})", build,
                          "a sampled rank permutation")


@register_pattern("hot_region")
def _hot_region(frac: float = 0.125, boost: float = 8.0) -> TrafficPattern:
    if not 0.0 < frac < 1.0:
        raise ValueError(f"frac must be in (0, 1), got {frac}")

    def build(g, active):
        m = len(active)
        hot = active[: max(1, int(round(frac * m)))]
        d = np.zeros((g.n, g.n), dtype=np.float64)
        d[np.ix_(active, active)] = 1.0
        d[np.ix_(active, hot)] = float(boost)
        return d

    return TrafficPattern(f"hot_region({frac},{boost})", build,
                          f"all-to-all with a {boost}x-hot {frac:.0%} target region")


COLLECTIVE_OPS = ("all-to-all", "all-gather", "reduce-scatter", "all-reduce",
                  "ring-all-gather", "ring-reduce-scatter", "ring-all-reduce")


@register_pattern("collective")
def _collective(op: str = "all-reduce", bytes_global: float = 1.0) -> TrafficPattern:
    """Demand matrix of one collective, matching fabric.collectives' byte
    accounting: spread ops send ``bytes/m`` to every peer (their uniform-
    destination schedule is the paper's uniform traffic); ring ops push the
    same total around the rank ring, i.e. ``(m-1)/m · bytes`` (2x for
    all-reduce) down each rank's shift(1) arc."""
    if op not in COLLECTIVE_OPS:
        raise ValueError(f"unknown collective {op!r}; options: {COLLECTIVE_OPS}")

    def build(g, active):
        m = len(active)
        per_pair = float(bytes_global) / m
        if op.startswith("ring-"):
            phases = 2 * (m - 1) if op == "ring-all-reduce" else m - 1
            perm = (np.arange(m) + 1) % m
            return _perm_demand(g.n, active, perm, weight=phases * per_pair)
        scale = 2.0 if op == "all-reduce" else 1.0  # rs + ag
        d = np.zeros((g.n, g.n), dtype=np.float64)
        d[np.ix_(active, active)] = scale * per_pair
        return d

    return TrafficPattern(f"collective({op})", build,
                          f"one {op} of {bytes_global:g} bytes (global)")


_SPEC_RE = re.compile(r"^\s*([A-Za-z_][A-Za-z0-9_-]*)\s*(?:\((.*)\))?\s*$")


def make_pattern(spec) -> TrafficPattern:
    """Build a pattern from a registry name with optional arguments:
    ``"tornado"``, ``"shift(3)"``, ``"hot_region(0.2, 4)"``,
    ``"collective(ring-all-reduce)"``.  Passes TrafficPattern through."""
    if isinstance(spec, TrafficPattern):
        return spec
    m = _SPEC_RE.match(str(spec))
    if not m or m.group(1) not in PATTERNS:
        raise ValueError(f"unknown traffic pattern {spec!r}; "
                         f"options: {sorted(PATTERNS)}")
    name, argstr = m.group(1), m.group(2)
    args = []
    for tok in filter(None, (t.strip() for t in (argstr or "").split(","))):
        try:
            args.append(int(tok))
        except ValueError:
            try:
                args.append(float(tok))
            except ValueError:
                args.append(tok)
    return PATTERNS[name](*args)


# ---------------------------------------------------------------------------
# Saturation analysis
# ---------------------------------------------------------------------------


@dataclass
class SaturationReport:
    """Load statistics of one (pattern, routing) on one graph.

    Demand is normalized so the busiest source injects 1 unit; arcs have
    unit capacity, so ``theta = 1/max_load`` is the per-node saturation
    injection rate in link-equivalents (uniform: Eq. 1's a = Δ·u/k̄) and
    ``u = mean/max`` is the paper's balance figure for this pattern."""

    pattern: str
    routing: str
    theta: float
    u: float
    max_load: float
    mean_load: float
    kbar_eff: float  # demand-weighted hops (both phases under Valiant)
    diameter: int    # longest hops traveled (Valiant: two-leg upper bound)
    total_demand: float
    loads: np.ndarray = field(repr=False)


def _normalize_rows(demand: np.ndarray) -> np.ndarray:
    peak = demand.sum(axis=1).max()
    if peak <= 0:
        raise ValueError("demand matrix is all zero")
    return demand / peak


def _valiant_demands(demand: np.ndarray, active: np.ndarray):
    """Exact expected two-phase Valiant demand: every packet routes
    s -> (uniform random intermediate m != endpoint, within the active
    set) -> t.  Phase 1 spreads each source's row sum over the
    intermediates, phase 2 collects each target's column sum from them —
    two rank-1 matrices, so Valiant costs two weighted sweeps whatever the
    pattern.  For uniform traffic this reproduces valiant_report exactly:
    2x the minimal loads at 2x k̄."""
    n = demand.shape[0]
    m = len(active)
    act = np.zeros(n, dtype=np.float64)
    act[active] = 1.0
    rs = demand.sum(axis=1)
    cs = demand.sum(axis=0)
    d1 = np.outer(rs, act) / (m - 1)
    d2 = np.outer(act, cs) / (m - 1)
    return d1, d2


def saturation_report(g: Graph, pattern, routing: str = "minimal",
                      engine: str | None = None,
                      targets_mask: np.ndarray | None = None) -> SaturationReport:
    """Evaluate one traffic pattern on ``g`` under minimal or Valiant
    routing.  ``pattern`` is a spec for :func:`make_pattern` (or a
    TrafficPattern); ``targets_mask`` defaults to the graph's leaf mask
    for indirect networks."""
    if routing not in ("minimal", "valiant"):
        raise ValueError(f"routing must be 'minimal' or 'valiant', got {routing!r}")
    pat = make_pattern(pattern)
    if targets_mask is None:
        targets_mask = g.meta.get("leaf_mask")
    demand = _normalize_rows(pat.demand(g, targets_mask))
    total = float(demand.sum())

    if routing == "minimal":
        loads, kbar_eff, diam = arc_loads_weighted(g, demand, engine=engine)
    else:
        active = (np.arange(g.n) if targets_mask is None
                  else np.nonzero(np.asarray(targets_mask, dtype=bool))[0])
        d1, d2 = _valiant_demands(demand, active)
        l1, k1, dm1 = arc_loads_weighted(g, d1, engine=engine)
        if np.array_equal(d1, d2):  # e.g. uniform: both phases identical
            l2, k2, dm2 = l1, k1, dm1
        else:
            l2, k2, dm2 = arc_loads_weighted(g, d2, engine=engine)
        loads = l1 + l2
        kbar_eff = k1 + k2  # both phases have total demand == sum(D)
        # upper bound on the longest two-leg route: the worst phase-1 and
        # phase-2 legs need not share an intermediate (tight on the
        # vertex-transitive families)
        diam = dm1 + dm2

    mx = float(loads.max())
    mean = float(loads.mean())
    return SaturationReport(
        pattern=pat.name, routing=routing, theta=1.0 / mx, u=mean / mx,
        max_load=mx, mean_load=mean, kbar_eff=kbar_eff, diameter=int(diam),
        total_demand=total, loads=loads)


DEFAULT_SWEEP = ("uniform", "bit_reversal", "transpose", "tornado",
                 "random_permutation", "hot_region")


def saturation_sweep(g: Graph, patterns=DEFAULT_SWEEP,
                     routings=("minimal", "valiant"),
                     engine: str | None = None,
                     targets_mask: np.ndarray | None = None):
    """Run a battery of patterns; returns ``(reports, summary)`` where
    ``summary`` names the worst pattern per routing — min theta (the
    throughput guarantee) and the worst-case u over patterns."""
    reports = [saturation_report(g, p, routing=r, engine=engine,
                                 targets_mask=targets_mask)
               for p in patterns for r in routings]
    summary = {}
    for r in routings:
        rs = [rep for rep in reports if rep.routing == r]
        worst = min(rs, key=lambda rep: rep.theta)
        summary[r] = {"min_theta": worst.theta, "worst_pattern": worst.pattern,
                      "worst_u": min(rep.u for rep in rs)}
    return reports, summary
