"""Traffic patterns and saturation analysis — the demand-matrix view of
Theorem 3.9.

The paper's utilization u = mean/max arc load is defined for UNIFORM
all-to-all traffic; its balance argument ("symmetric networks keep every
link equally busy, so Eq. 1's a = Δ·u/k̄ is achievable") only bites when
competing topologies are stressed with the traffic that unbalances them.
This module states the general problem: a traffic matrix D[s, t] gives the
demand each source injects for each target, split evenly across all
shortest paths (or routed through Valiant intermediates), and the engines
of repro.core.utilization accumulate the per-arc load L_a:

    L_a = sum_{s,t} D[s,t] · (# shortest s->t paths through a) / (# s->t paths)

Normalizing D so the busiest source injects 1 unit, the saturation
throughput is theta = 1 / max_a L_a — the fraction of one link's bandwidth
every node can sustainably inject under that pattern.  For uniform traffic
theta IS Eq. 1's a = Δ·u/k̄; for adversarial patterns (tornado shifts,
bit-reversal, hot regions) theta collapses on asymmetric topologies while
the paper's PN/demi-PN families, being arc-transitive, degrade gracefully
— and Valiant routing [paper ref 40] buys back worst-case guarantees at
half the uniform throughput.

Patterns are registered in ``PATTERNS`` and built by name (with optional
``name(arg, ...)`` parameters) via :func:`make_pattern`:

  uniform             all-to-all, 1 unit per ordered pair
  bit_reversal        rank i -> bit-reversed rank (FFT / transpose phases)
  transpose           (r, c) -> (c, r) on the largest square rank grid
  shift(k)            rank i -> i+k mod m (neighbor exchange; halo phases)
  tornado             the classic one-directional worst case (Dally-
                      Towles): shift by ceil(k/2)-1 within coordinate
                      0's ring on a torus, by ceil(m/2)-1 on the rank
                      ring elsewhere
  random_permutation(seed)  a sampled permutation (Valiant's average case)
  hot_region(frac, boost)   all-to-all with a boosted hot target region
  collective(op)      demand of one fabric collective (see below)

``collective`` derives its matrix from the schedules fabric.collectives
prices: spread ops (``all-to-all``, ``all-gather``, ``reduce-scatter``,
``all-reduce``) send each node's bytes uniformly to all peers, while the
``ring-*`` variants serialize the same bytes over the rank-ring shift
permutation — which is exactly how a DC ring all-reduce turns a balanced
topology into a single hot cycle.

``saturation_report(g, pattern, routing=...)`` evaluates one pattern;
``saturation_sweep`` runs a battery and reports the worst case — the
quantitative form of the paper's "suboptimal designs" claim.

Routing models live in repro.core.routing: ``routing`` accepts any
registered spec (``"minimal"``, ``"valiant"``, ``"ugal"``,
``"ugal(source)"``, or a RoutingModel instance); ``saturation_report`` is
a thin shim that normalizes the pattern's demand and wraps the model's
RoutingResult.  The adversarial search over patterns (worst-found
permutations per routing model) is repro.core.adversary.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .graph import Graph
from .routing import make_routing, parse_spec

__all__ = [
    "TrafficPattern", "PATTERNS", "register_pattern", "make_pattern",
    "matrix_pattern", "SaturationReport", "saturation_report",
    "saturation_sweep", "DEFAULT_SWEEP", "COLLECTIVE_OPS",
    "normalize_demand",
]


# ---------------------------------------------------------------------------
# Pattern objects and registry
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TrafficPattern:
    """A named recipe producing a demand matrix for any graph.

    ``builder(g, active)`` receives the graph and the sorted vertex ids
    that send/receive traffic (all vertices, or the leaf set of an
    indirect network) and returns a dense (N, N) float64 demand matrix.
    """

    name: str
    builder: Callable[[Graph, np.ndarray], np.ndarray] = field(repr=False)
    description: str = ""

    def demand(self, g: Graph, targets_mask: np.ndarray | None = None) -> np.ndarray:
        if targets_mask is None:
            targets_mask = g.meta.get("leaf_mask")
        if targets_mask is None:
            active = np.arange(g.n)
        else:
            active = np.nonzero(np.asarray(targets_mask, dtype=bool))[0]
        if len(active) < 2:
            raise ValueError("need at least 2 active vertices")
        d = self.builder(g, active)
        np.fill_diagonal(d, 0.0)
        return d


PATTERNS: dict[str, Callable[..., TrafficPattern]] = {}


def register_pattern(name: str):
    """Register a pattern factory: ``fn(*args) -> TrafficPattern``."""

    def deco(fn):
        PATTERNS[name] = fn
        return fn

    return deco


def _perm_demand(n: int, active: np.ndarray, perm: np.ndarray,
                 weight: float = 1.0) -> np.ndarray:
    """Demand matrix for rank permutation ``perm`` over the active set.
    Fixed points become self-demand and are zeroed by ``demand()``."""
    d = np.zeros((n, n), dtype=np.float64)
    d[active, active[perm]] = weight
    return d


@register_pattern("uniform")
def _uniform() -> TrafficPattern:
    def build(g, active):
        d = np.zeros((g.n, g.n), dtype=np.float64)
        d[np.ix_(active, active)] = 1.0
        return d

    return TrafficPattern("uniform", build, "all-to-all, 1 unit per ordered pair")


@register_pattern("bit_reversal")
def _bit_reversal() -> TrafficPattern:
    def build(g, active):
        m = len(active)
        bits = max(1, (m - 1).bit_length())
        i = np.arange(m)
        rev = np.zeros(m, dtype=np.int64)
        for b in range(bits):
            rev |= ((i >> b) & 1) << (bits - 1 - b)
        perm = np.where(rev < m, rev, i)  # out-of-range reversals stay home
        return _perm_demand(g.n, active, perm)

    return TrafficPattern("bit_reversal", build,
                          "rank -> bit-reversed rank (FFT exchange phase)")


@register_pattern("transpose")
def _transpose() -> TrafficPattern:
    def build(g, active):
        m = len(active)
        side = math.isqrt(m)
        perm = np.arange(m)
        sq = side * side
        r, c = np.divmod(np.arange(sq), side)
        perm[:sq] = c * side + r  # (r, c) -> (c, r); ranks beyond sq stay home
        return _perm_demand(g.n, active, perm)

    return TrafficPattern("transpose", build,
                          "matrix transpose on the largest square rank grid")


@register_pattern("shift")
def _shift(k: int = 1) -> TrafficPattern:
    def build(g, active):
        m = len(active)
        perm = (np.arange(m) + int(k)) % m
        return _perm_demand(g.n, active, perm)

    return TrafficPattern(f"shift({k})", build, f"rank i -> i+{k} mod m")


@register_pattern("tornado")
def _tornado() -> TrafficPattern:
    # The classic Dally-Towles adversary: shift by ceil(k/2)-1 — one hop
    # SHORT of halfway — so every packet travels the same direction and
    # minimal routing loads only half the ring's arcs.  On a k-ary n-cube
    # the textbook form shifts coordinate 0 within its own ring (each node
    # (x, y, ...) sends to (x + ceil(k/2)-1 mod k, y, ...)); on anything
    # else the shift applies to the rank ring.  (PR 2 shipped the flat
    # rank shift(m//2), which splits both directions — theta 1.0 on the
    # 4^3 torus, no adversary at all.)
    def build(g, active):
        dims = g.meta.get("dims")
        if (g.meta.get("family") == "torus3d" and dims
                and len(active) == g.n):
            coords = list(np.unravel_index(np.arange(g.n), dims))
            d = next((i for i, s in enumerate(dims) if s >= 2), 0)
            k = dims[d]
            coords[d] = (coords[d] + max(1, (k + 1) // 2 - 1)) % k
            perm = np.ravel_multi_index(coords, dims)
            return _perm_demand(g.n, active, perm)
        m = len(active)
        k = max(1, (m + 1) // 2 - 1)
        perm = (np.arange(m) + k) % m
        return _perm_demand(g.n, active, perm)

    return TrafficPattern("tornado", build,
                          "one-directional near-half-ring shift "
                          "(the classic torus adversary)")


@register_pattern("random_permutation")
def _random_permutation(seed: int = 0) -> TrafficPattern:
    def build(g, active):
        rng = np.random.default_rng(int(seed))
        perm = rng.permutation(len(active))
        return _perm_demand(g.n, active, perm)

    return TrafficPattern(f"random_permutation({seed})", build,
                          "a sampled rank permutation")


@register_pattern("hot_region")
def _hot_region(frac: float = 0.125, boost: float = 8.0) -> TrafficPattern:
    if not 0.0 < frac < 1.0:
        raise ValueError(f"frac must be in (0, 1), got {frac}")

    def build(g, active):
        m = len(active)
        hot = active[: max(1, int(round(frac * m)))]
        d = np.zeros((g.n, g.n), dtype=np.float64)
        d[np.ix_(active, active)] = 1.0
        d[np.ix_(active, hot)] = float(boost)
        return d

    return TrafficPattern(f"hot_region({frac},{boost})", build,
                          f"all-to-all with a {boost}x-hot {frac:.0%} target region")


COLLECTIVE_OPS = ("all-to-all", "all-gather", "reduce-scatter", "all-reduce",
                  "ring-all-gather", "ring-reduce-scatter", "ring-all-reduce")


@register_pattern("collective")
def _collective(op: str = "all-reduce", bytes_global: float = 1.0) -> TrafficPattern:
    """Demand matrix of one collective, matching fabric.collectives' byte
    accounting: spread ops send ``bytes/m`` to every peer (their uniform-
    destination schedule is the paper's uniform traffic); ring ops push the
    same total around the rank ring, i.e. ``(m-1)/m · bytes`` (2x for
    all-reduce) down each rank's shift(1) arc."""
    if op not in COLLECTIVE_OPS:
        raise ValueError(f"unknown collective {op!r}; options: {COLLECTIVE_OPS}")

    def build(g, active):
        m = len(active)
        per_pair = float(bytes_global) / m
        if op.startswith("ring-"):
            phases = 2 * (m - 1) if op == "ring-all-reduce" else m - 1
            perm = (np.arange(m) + 1) % m
            return _perm_demand(g.n, active, perm, weight=phases * per_pair)
        scale = 2.0 if op == "all-reduce" else 1.0  # rs + ag
        d = np.zeros((g.n, g.n), dtype=np.float64)
        d[np.ix_(active, active)] = scale * per_pair
        return d

    return TrafficPattern(f"collective({op})", build,
                          f"one {op} of {bytes_global:g} bytes (global)")


def matrix_pattern(demand, name: str | None = None) -> TrafficPattern:
    """Wrap a raw (N, N) demand matrix as an ad-hoc TrafficPattern, so
    the adversary harness and placement work can feed explicit matrices
    through ``saturation_report`` without registering a builder.  The
    matrix is copied at build time (``demand()`` zeroes the diagonal)."""
    arr = np.asarray(demand, dtype=np.float64)
    if arr.ndim != 2 or arr.shape[0] != arr.shape[1]:
        raise ValueError(f"demand matrix must be square (N, N), "
                         f"got shape {arr.shape}")

    def build(g, active):
        if arr.shape != (g.n, g.n):
            raise ValueError(f"demand matrix is {arr.shape}, graph has "
                             f"N={g.n}")
        return arr.copy()

    label = name or f"matrix({arr.shape[0]}x{arr.shape[1]})"
    return TrafficPattern(label, build, "explicit demand matrix")


def make_pattern(spec) -> TrafficPattern:
    """Build a pattern from a registry name with optional arguments:
    ``"tornado"``, ``"shift(3)"``, ``"hot_region(0.2, 4)"``,
    ``"collective(ring-all-reduce)"``.  Passes TrafficPattern instances
    through and wraps raw (N, N) arrays via :func:`matrix_pattern`."""
    if isinstance(spec, TrafficPattern):
        return spec
    if isinstance(spec, (np.ndarray, list, tuple)) or (
            hasattr(spec, "__array__") and not isinstance(spec, str)):
        return matrix_pattern(spec)
    return parse_spec(spec, PATTERNS, "traffic pattern")


# ---------------------------------------------------------------------------
# Saturation analysis
# ---------------------------------------------------------------------------


@dataclass
class SaturationReport:
    """Load statistics of one (pattern, routing) on one graph.

    Demand is normalized so the busiest source injects 1 unit; arcs have
    unit capacity, so ``theta = 1/max_load`` is the per-node saturation
    injection rate in link-equivalents (uniform: Eq. 1's a = Δ·u/k̄) and
    ``u = mean/max`` is the paper's balance figure for this pattern."""

    pattern: str
    routing: str
    theta: float
    u: float
    max_load: float
    mean_load: float
    kbar_eff: float  # demand-weighted hops (both phases under Valiant)
    diameter: int    # longest hops traveled (Valiant: two-leg upper bound)
    total_demand: float
    loads: np.ndarray = field(repr=False)
    alpha: float | None = None  # blend weight on minimal (ugal models)
    faults: str | None = None   # FaultSet label when evaluated degraded


def normalize_demand(demand: np.ndarray) -> np.ndarray:
    """Scale a demand matrix so the busiest source injects one unit —
    the normalization behind every theta in this module (and the one
    fabric.placement's byte matrices go through)."""
    peak = demand.sum(axis=1).max()
    if peak <= 0:
        raise ValueError("demand matrix is all zero")
    return demand / peak


_normalize_rows = normalize_demand  # pre-PR 4 private name


def saturation_report(g: Graph, pattern, routing: str = "minimal",
                      engine: str | None = None,
                      targets_mask: np.ndarray | None = None,
                      faults=None) -> SaturationReport:
    """Evaluate one traffic pattern on ``g`` under one routing model.

    ``pattern`` is a spec for :func:`make_pattern` (a registry name, a
    TrafficPattern, or a raw (N, N) demand matrix); ``routing`` a spec for
    repro.core.routing's :func:`make_routing` ("minimal", "valiant",
    "ugal", "ugal(source)", or a RoutingModel); ``targets_mask`` defaults
    to the graph's leaf mask for indirect networks.  With ``faults`` (a
    repro.core.faults.FaultSet) the pattern is built and normalized on the
    pristine graph, restricted to the survivors, and evaluated on the
    degraded graph — see :func:`repro.core.faults.degraded_report`."""
    if faults is not None and not faults.empty:
        from .faults import degraded_report
        return degraded_report(g, pattern, faults, routing=routing,
                               engine=engine, targets_mask=targets_mask)
    model = make_routing(routing)
    pat = make_pattern(pattern)
    if targets_mask is None:
        targets_mask = g.meta.get("leaf_mask")
    demand = normalize_demand(pat.demand(g, targets_mask))
    total = float(demand.sum())
    active = (np.arange(g.n) if targets_mask is None
              else np.nonzero(np.asarray(targets_mask, dtype=bool))[0])
    res = model.evaluate(g, demand, active, engine)

    mx = float(res.loads.max())
    mean = float(res.loads.mean())
    return SaturationReport(
        pattern=pat.name, routing=model.name, theta=1.0 / mx, u=mean / mx,
        max_load=mx, mean_load=mean, kbar_eff=res.kbar_eff,
        diameter=int(res.diameter), total_demand=total, loads=res.loads,
        alpha=res.alpha)


DEFAULT_SWEEP = ("uniform", "bit_reversal", "transpose", "tornado",
                 "random_permutation", "hot_region")


def saturation_sweep(g: Graph, patterns=DEFAULT_SWEEP,
                     routings=("minimal", "valiant"),
                     engine: str | None = None,
                     targets_mask: np.ndarray | None = None):
    """Run a battery of patterns; returns ``(reports, summary)`` where
    ``summary`` names the worst pattern per routing — min theta (the
    throughput guarantee) and the worst-case u over patterns."""
    reports = [saturation_report(g, p, routing=r, engine=engine,
                                 targets_mask=targets_mask)
               for p in patterns for r in routings]
    summary = {}
    for r in routings:
        rs = [rep for rep in reports if rep.routing == r]
        worst = min(rs, key=lambda rep: rep.theta)
        summary[r] = {"min_theta": worst.theta, "worst_pattern": worst.pattern,
                      "worst_u": min(rep.u for rep in rs)}
    return reports, summary
