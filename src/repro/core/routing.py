"""Routing models: pluggable maps from (graph, demand) to arc loads.

The paper's cost argument (Theorem 3.9 / Eq. 1) prices a topology under
two pure routings: minimal (every packet on a shortest path) and Valiant
(every packet through a uniform random intermediate).  Real large-radix
routers run UGAL — per-packet they choose between the minimal route and a
Valiant detour based on local queue depth — so the pure-minimal vs
pure-Valiant bracket *understates* every topology under adversarial
traffic.  This module generalizes repro.core.traffic's fixed
``"minimal"|"valiant"`` pair to a registry of routing models sharing one
interface:

    model = make_routing("ugal")
    res = model.evaluate(g, demand, active)      # -> RoutingResult
    theta = 1.0 / res.loads.max()                # if demand is normalized

A model maps ``(graph, demand)`` to a per-arc load vector plus the
demand-weighted hop count and worst-case hop count of the routes it uses.
``saturation_report`` (repro.core.traffic) stays the user-facing entry
point — it normalizes demand so the busiest source injects one unit and
wraps the result with theta = 1/max_load.

Shipped models
--------------
``minimal``
    One weighted Brandes sweep (repro.core.utilization): demand split
    evenly over all shortest paths.

``valiant``
    Exact expected two-phase load: phase 1 spreads each source's row sum
    over uniform random intermediates, phase 2 collects each target's
    column sum — two rank-1 demand matrices, so Valiant costs two weighted
    sweeps whatever the pattern.  (Bit-identical to PR 2's
    ``saturation_report(..., routing="valiant")``.)

``ugal`` / ``ugal(source)``
    UGAL modeled as the theta-maximizing convex blend of the two pure
    load vectors.  Sending fraction ``alpha`` of every packet minimally
    and ``1 - alpha`` via Valiant yields loads
    ``L(alpha) = alpha * L_min + (1 - alpha) * L_val``, so

        theta(alpha) = 1 / max_a L_a(alpha)

    and ``max_a L_a(alpha)`` is the upper envelope of one line per arc —
    piecewise linear and convex in alpha.  Its minimum therefore sits at
    alpha = 0, alpha = 1, or an arc-crossing breakpoint of the envelope;
    :func:`blend_optimum` finds it exactly with a cutting-plane descent
    that evaluates the envelope (one O(arcs) max) per visited breakpoint.
    The whole model costs the two pure sweeps plus that
    O(arcs * breakpoints) scan — it reuses PR 2's batched weighted sweep
    engines unchanged.

    ``ugal(source)`` refines the single global alpha to one blend weight
    per source (the granularity a per-packet adaptive router actually
    has), solved as a small LP: minimize t subject to
    ``sum_s alpha_s L_min[s] + (1 - alpha_s) L_val[s] <= t`` per arc,
    ``0 <= alpha_s <= 1``.  This needs per-source load vectors (one sweep
    per source, not one batched sweep) and scipy's linprog, so it is
    opt-in and guarded to small graphs.

Registering a new model (e.g. a per-hop adaptive or piecewise-UGAL
variant) takes one decorated factory::

    @register_routing("my_model")
    def _my_model(knob: float = 1.0) -> RoutingModel:
        def evaluate(g, demand, active, engine=None):
            ...
            return RoutingResult("my_model", loads, kbar_eff, diam)
        return RoutingModel("my_model", evaluate, "docstring line")

after which ``saturation_report(g, pat, routing="my_model(2.5)")``, the
fabric collective timers, and the adversarial harness
(repro.core.adversary) all pick it up.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .. import obs
from .graph import Graph
from .utilization import arc_loads_weighted

__all__ = [
    "RoutingModel", "RoutingResult", "ROUTINGS", "register_routing",
    "make_routing", "blend_optimum", "evaluate_models", "valiant_demands",
]


@dataclass
class RoutingResult:
    """Arc loads of one routing model on one (graph, demand) instance.

    ``loads`` is per directed arc in the graph's arc order; ``kbar_eff``
    the demand-weighted mean hops actually traveled (both phases under
    Valiant); ``diameter`` the longest hop count any demand travels (an
    upper bound for two-leg routes).  ``alpha`` is the blend weight on the
    minimal load vector for blend models (1.0 = pure minimal), ``alphas``
    the per-source weights when ``ugal(source)`` solved the LP, and
    ``breakpoints`` how many envelope lines the exact blend scan visited.
    """

    routing: str
    loads: np.ndarray = field(repr=False)
    kbar_eff: float = 0.0
    diameter: int = 0
    alpha: float | None = None
    alphas: np.ndarray | None = field(default=None, repr=False)
    breakpoints: int = 0

    @property
    def max_load(self) -> float:
        return float(self.loads.max())


@dataclass(frozen=True)
class RoutingModel:
    """A named routing model: ``evaluate(g, demand, active, engine)``
    returns a :class:`RoutingResult`.  ``demand`` is a dense (N, N)
    matrix (diagonal ignored), ``active`` the sorted vertex ids that send
    and receive traffic (all vertices, or the leaf set of an indirect
    network), ``engine`` the arc-load engine override (see
    repro.core.utilization)."""

    name: str
    evaluate: Callable[..., RoutingResult] = field(repr=False)
    description: str = ""


ROUTINGS: dict[str, Callable[..., RoutingModel]] = {}


def register_routing(name: str):
    """Register a routing-model factory: ``fn(*args) -> RoutingModel``."""

    def deco(fn):
        ROUTINGS[name] = fn
        return fn

    return deco


_SPEC_RE = re.compile(r"^\s*([A-Za-z_][A-Za-z0-9_-]*)\s*(?:\((.*)\))?\s*$")


def parse_spec(spec, registry: dict, kind: str):
    """Shared ``name`` / ``name(arg, ...)`` spec parser for the pattern
    and routing registries: tokens coerce int -> float -> str, and an
    unknown name raises ``ValueError("unknown {kind} ...")``."""
    m = _SPEC_RE.match(str(spec))
    if not m or m.group(1) not in registry:
        raise ValueError(f"unknown {kind} {spec!r}; "
                         f"options: {sorted(registry)}")
    name, argstr = m.group(1), m.group(2)
    args = []
    for tok in filter(None, (t.strip() for t in (argstr or "").split(","))):
        try:
            args.append(int(tok))
        except ValueError:
            try:
                args.append(float(tok))
            except ValueError:
                args.append(tok)
    return registry[name](*args)


def make_routing(spec) -> RoutingModel:
    """Build a routing model from a registry name with optional arguments:
    ``"minimal"``, ``"valiant"``, ``"ugal"``, ``"ugal(source)"``.  Passes
    RoutingModel instances through."""
    if isinstance(spec, RoutingModel):
        return spec
    return parse_spec(spec, ROUTINGS, "routing model")


# ---------------------------------------------------------------------------
# The two pure models (refactored out of repro.core.traffic, PR 2)
# ---------------------------------------------------------------------------


def valiant_demands(demand: np.ndarray, active: np.ndarray):
    """Exact expected two-phase Valiant demand: every packet routes
    s -> (uniform random intermediate m != endpoint, within the active
    set) -> t.  Phase 1 spreads each source's row sum over the
    intermediates, phase 2 collects each target's column sum from them —
    two rank-1 matrices, so Valiant costs two weighted sweeps whatever the
    pattern.  For uniform traffic this reproduces valiant_report exactly:
    2x the minimal loads at 2x k̄."""
    n = demand.shape[0]
    m = len(active)
    act = np.zeros(n, dtype=np.float64)
    act[active] = 1.0
    rs = demand.sum(axis=1)
    cs = demand.sum(axis=0)
    d1 = np.outer(rs, act) / (m - 1)
    d2 = np.outer(act, cs) / (m - 1)
    return d1, d2


def _minimal_parts(g: Graph, demand: np.ndarray, engine):
    with obs.span("routing.sweep[minimal]", n=g.n):
        return arc_loads_weighted(g, demand, engine=engine)


def _valiant_parts(g: Graph, demand: np.ndarray, active: np.ndarray, engine):
    with obs.span("routing.sweep[valiant]", n=g.n):
        d1, d2 = valiant_demands(demand, active)
        l1, k1, dm1 = arc_loads_weighted(g, d1, engine=engine)
        if np.array_equal(d1, d2):  # e.g. uniform: both phases identical
            l2, k2, dm2 = l1, k1, dm1
        else:
            l2, k2, dm2 = arc_loads_weighted(g, d2, engine=engine)
    # upper bound on the longest two-leg route: the worst phase-1 and
    # phase-2 legs need not share an intermediate (tight on the
    # vertex-transitive families)
    return l1 + l2, k1 + k2, dm1 + dm2


@register_routing("minimal")
def _minimal() -> RoutingModel:
    def evaluate(g, demand, active, engine=None):
        loads, kbar, diam = _minimal_parts(g, demand, engine)
        return RoutingResult("minimal", loads, kbar, int(diam))

    return RoutingModel("minimal", evaluate,
                        "demand split evenly over all shortest paths")


@register_routing("valiant")
def _valiant() -> RoutingModel:
    def evaluate(g, demand, active, engine=None):
        loads, kbar, diam = _valiant_parts(g, demand, active, engine)
        return RoutingResult("valiant", loads, kbar, int(diam))

    return RoutingModel("valiant", evaluate,
                        "exact expected two-phase randomized routing")


# ---------------------------------------------------------------------------
# UGAL: the theta-maximizing convex blend
# ---------------------------------------------------------------------------


def blend_optimum(l_min: np.ndarray, l_val: np.ndarray,
                  max_iter: int = 10_000) -> tuple[float, float, int]:
    """Minimize ``f(alpha) = max(alpha*l_min + (1-alpha)*l_val)`` over
    ``alpha`` in [0, 1]; returns ``(alpha, f(alpha), breakpoints)``.

    Each arc contributes the line ``l_val[a] + alpha*(l_min[a]-l_val[a])``;
    f is their upper envelope — piecewise linear and convex — so the
    minimum sits at an endpoint or at a crossing of two envelope lines.
    Cutting-plane descent: keep one binding line at each end of the
    current bracket, jump to their crossing (the lower bound's argmin),
    evaluate the true envelope there (one O(arcs) max), and shrink the
    bracket with the newly discovered binding line.  Every iteration
    either certifies optimality (envelope meets the lower bound) or adds
    a distinct envelope line, so termination is finite and exact."""
    l_min = np.asarray(l_min, dtype=np.float64)
    l_val = np.asarray(l_val, dtype=np.float64)
    slope = l_min - l_val

    def probe(x: float):
        v = l_val + slope * x
        a = int(np.argmax(v))
        return float(v[a]), float(slope[a]), float(l_val[a])

    f0, s0, b0 = probe(0.0)
    f1, s1, b1 = probe(1.0)
    # a nonnegative binding slope at 0 (resp. nonpositive at 1) certifies
    # the endpoint: the convex envelope can only rise from there
    if s0 >= 0.0:
        return 0.0, f0, 1
    if s1 <= 0.0:
        return 1.0, f1, 1
    visited = 2
    slo, blo = s0, b0
    shi, bhi = s1, b1
    best_x, best_f = (0.0, f0) if f0 <= f1 else (1.0, f1)
    tol = 1e-12 * max(f0, f1)
    for _ in range(max_iter):
        x = (bhi - blo) / (slo - shi)  # crossing of the two binding lines
        lower = blo + slo * x          # lower bound on min f
        fx, sx, bx = probe(x)
        visited += 1
        if fx < best_f:
            best_x, best_f = x, fx
        if fx <= lower + tol:          # envelope meets its lower bound
            return best_x, best_f, visited
        if sx < 0.0:
            slo, blo = sx, bx
        elif sx > 0.0:
            shi, bhi = sx, bx
        else:                          # flat binding line: x is the optimum
            return x, fx, visited
    return best_x, best_f, visited


def _blend_result(min_parts, val_parts) -> RoutingResult:
    l_min, k_min, d_min = min_parts
    l_val, k_val, d_val = val_parts
    alpha, _, visited = blend_optimum(l_min, l_val)
    # breakpoint-probe telemetry: each visited point is one O(arcs)
    # envelope max — the blend solver's entire marginal cost
    obs.counter("routing.blend.solves").add(1.0)
    obs.counter("routing.blend.probes").add(float(visited))
    if alpha == 1.0:
        # pure minimal: reuse the exact sweep output bitwise (the balanced
        # case, e.g. any uniform demand where l_val == 2*l_min)
        return RoutingResult("ugal", l_min, k_min, int(d_min),
                             alpha=1.0, breakpoints=visited)
    if alpha == 0.0:
        return RoutingResult("ugal", l_val, k_val, int(d_val),
                             alpha=0.0, breakpoints=visited)
    loads = alpha * l_min + (1.0 - alpha) * l_val
    kbar = alpha * k_min + (1.0 - alpha) * k_val
    return RoutingResult("ugal", loads, kbar, int(max(d_min, d_val)),
                         alpha=float(alpha), breakpoints=visited)


def _ugal_blend(g, demand, active, engine):
    return _blend_result(_minimal_parts(g, demand, engine),
                         _valiant_parts(g, demand, active, engine))


# Per-source granularity needs one sweep per source (the batched engines
# only return summed loads); guard the LP path to instances where that
# and the (sources x arcs) constraint matrix stay small.
UGAL_SOURCE_MAX_N = 512


def _per_source_vectors(g, demand, active, engine):
    """(S, A) minimal and Valiant load matrices plus per-source
    (dist_sum, demand_total) pairs, one row per demand-carrying source."""
    sources = np.nonzero(demand.any(axis=1))[0]
    n_arcs = len(g.arc_src)
    lm = np.zeros((len(sources), n_arcs))
    lv = np.zeros((len(sources), n_arcs))
    km = np.zeros(len(sources))
    kv = np.zeros(len(sources))
    tot = np.zeros(len(sources))
    dm = dv = 0
    for i, s in enumerate(sources):
        row = np.zeros_like(demand)
        row[s] = demand[s]
        tot[i] = row.sum()
        lm[i], kbar_s, d1 = arc_loads_weighted(g, row, engine=engine)
        km[i] = kbar_s * tot[i]
        lv[i], kv_s, d2 = _valiant_parts(g, row, active, engine)
        kv[i] = kv_s * tot[i]
        dm, dv = max(dm, int(d1)), max(dv, int(d2))
    return sources, lm, lv, km, kv, tot, dm, dv


def _ugal_source_lp(g, demand, active, engine):
    """Per-source blend weights via LP: minimize t s.t. for every arc
    ``sum_s alpha_s*l_min[s] + (1-alpha_s)*l_val[s] <= t``, alpha in
    [0, 1]^S.  Exact theta at the granularity a per-packet adaptive
    router actually has; needs scipy and one sweep per source."""
    try:
        from scipy.optimize import linprog
    except ImportError as e:  # pragma: no cover - scipy is in the image
        raise RuntimeError(
            "ugal(source) solves a per-source LP and needs scipy; "
            "use the closed-form global blend 'ugal' instead") from e
    if g.n > UGAL_SOURCE_MAX_N:
        raise ValueError(
            f"ugal(source) runs one sweep per source and an (S x A) LP; "
            f"N={g.n} > {UGAL_SOURCE_MAX_N}.  Use 'ugal' (global blend) "
            f"or a smaller instance of the same family.")
    srcs, lm, lv, km, kv, tot, d_min, d_val = _per_source_vectors(
        g, demand, active, engine)
    s_count, n_arcs = lm.shape
    # variables x = (alpha_0..alpha_{S-1}, t)
    a_ub = np.hstack([(lm - lv).T, -np.ones((n_arcs, 1))])
    b_ub = -lv.sum(axis=0)
    c = np.zeros(s_count + 1)
    c[-1] = 1.0
    bounds = [(0.0, 1.0)] * s_count + [(None, None)]
    res = linprog(c, A_ub=a_ub, b_ub=b_ub, bounds=bounds, method="highs")
    if not res.success:  # pragma: no cover - LP is always feasible/bounded
        raise RuntimeError(f"ugal(source) LP failed: {res.message}")
    alphas = np.clip(res.x[:s_count], 0.0, 1.0)
    loads = alphas @ lm + (1.0 - alphas) @ lv
    total = tot.sum()
    kbar = float((alphas * km + (1.0 - alphas) * kv).sum() / total)
    full = np.zeros(g.n)
    full[srcs] = alphas
    mean_alpha = float((alphas * tot).sum() / total)
    return RoutingResult("ugal(source)", loads, kbar,
                         int(max(d_min, d_val)), alpha=mean_alpha,
                         alphas=full)


@register_routing("ugal_threshold")
def _ugal_threshold(threshold: float = 0.0) -> RoutingModel:
    """Fluid approximation of per-hop threshold-UGAL: divert a packet to
    the Valiant detour only when the minimal queue's expected delay
    exceeds the detour estimate by more than ``threshold`` flits.

    In the fluid (infinite-buffer) limit the saturation throughput is
    THRESHOLD-INVARIANT for any finite T: below the blend optimum the
    margin keeps queues bounded and traffic minimal; at saturation the
    minimal queues grow until the rule fires, so the steady-state split
    converges to the same theta-maximizing blend — T only shifts the
    queue depth (and therefore latency) at which diversion starts, which
    the simulator (repro.sim) resolves and this closed form cannot.
    ``ugal_threshold(inf)`` never diverts and degenerates to minimal —
    the same degeneration a finite buffer shallower than T forces, since
    a queue can then never grow past the margin (see docs/simulation.md).
    The registry thus exposes the fluid approximation next to repro.sim's
    measured ground truth under one spec family."""
    t = float(threshold)
    if not t >= 0.0:  # rejects negatives, -inf, and nan; +inf passes
        raise ValueError(f"threshold must be >= 0 or inf, got {threshold!r}")
    name = f"ugal_threshold({t:g})"

    def evaluate(g, demand, active, engine=None):
        if np.isinf(t):
            loads, kbar, diam = _minimal_parts(g, demand, engine)
            return RoutingResult(name, loads, kbar, int(diam), alpha=1.0)
        res = _ugal_blend(g, demand, active, engine)
        res.routing = name
        return res

    return RoutingModel(name, evaluate,
                        "threshold-UGAL fluid limit (= the ugal blend; "
                        "inf = minimal)")


@register_routing("ugal")
def _ugal(granularity: str = "global") -> RoutingModel:
    if granularity not in ("global", "source"):
        raise ValueError(f"ugal granularity must be 'global' or 'source', "
                         f"got {granularity!r}")
    if granularity == "source":
        return RoutingModel(
            "ugal(source)",
            lambda g, demand, active, engine=None:
                _ugal_source_lp(g, demand, active, engine),
            "per-source theta-maximizing blend (LP)")
    return RoutingModel(
        "ugal",
        lambda g, demand, active, engine=None:
            _ugal_blend(g, demand, active, engine),
        "theta-maximizing convex blend of minimal and Valiant")


# ---------------------------------------------------------------------------
# Shared-sweep evaluation (the adversary harness's inner loop)
# ---------------------------------------------------------------------------


def _shared_kind(spec) -> str | None:
    """'minimal' | 'valiant' | 'ugal' when a STRING spec resolves through
    the built-in factories to the sweep-sharing trio; None for custom
    factories, RoutingModel instances, and ugal(source) — those always
    run their own ``evaluate``, even if their display name collides with
    a built-in's."""
    if not isinstance(spec, str):
        return None
    m = _SPEC_RE.match(spec)
    factory = ROUTINGS.get(m.group(1)) if m else None
    if factory is _minimal:
        return "minimal"
    if factory is _valiant:
        return "valiant"
    if factory is _ugal and make_routing(spec).name == "ugal":
        return "ugal"  # the global blend; ugal(source) needs its own path
    return None


def evaluate_models(g: Graph, demand: np.ndarray, active: np.ndarray,
                    models=("minimal", "valiant", "ugal"),
                    engine: str | None = None) -> dict:
    """Evaluate several routing models on one demand matrix, sharing the
    minimal and Valiant sweeps across the built-in trio (ugal adds only
    its O(arcs * breakpoints) scan).  The result dict is keyed by each
    entry of ``models`` verbatim (spec string or RoutingModel instance).
    Sweep sharing applies only to specs resolving to the built-in
    factories (see :func:`_shared_kind`); everything else evaluates
    through its own ``evaluate``."""
    out: dict = {}
    min_parts = val_parts = None
    with obs.span("routing.evaluate_models", n=g.n, models=len(models)):
        for spec in models:
            kind = _shared_kind(spec)
            if kind in ("minimal", "ugal") and min_parts is None:
                min_parts = _minimal_parts(g, demand, engine)
            if kind in ("valiant", "ugal") and val_parts is None:
                val_parts = _valiant_parts(g, demand, active, engine)
            if kind == "minimal":
                loads, kbar, diam = min_parts
                out[spec] = RoutingResult("minimal", loads, kbar, int(diam))
            elif kind == "valiant":
                loads, kbar, diam = val_parts
                out[spec] = RoutingResult("valiant", loads, kbar, int(diam))
            elif kind == "ugal":
                out[spec] = _blend_result(min_parts, val_parts)
            else:
                out[spec] = make_routing(spec).evaluate(g, demand, active,
                                                        engine)
    return out
