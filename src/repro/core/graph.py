"""Graph container and vectorized analytics used by the cost model.

Everything operates on plain numpy; graphs here model router-level fabrics
(N up to a few tens of thousands), so dense/CSR numpy is the right tool —
no JAX needed at this layer.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

__all__ = ["Graph", "bfs_distances", "distance_distribution"]


@dataclass
class Graph:
    """Undirected simple graph as an edge list + CSR adjacency."""

    n: int
    edges: np.ndarray  # (E, 2) int64, each undirected edge once, u < v not required
    name: str = ""
    meta: dict = field(default_factory=dict)

    indptr: np.ndarray = field(init=False, repr=False)
    indices: np.ndarray = field(init=False, repr=False)
    # For directed-arc bookkeeping: arc k is (arc_src[k] -> indices[k]).
    arc_src: np.ndarray = field(init=False, repr=False)
    # arc_edge_id[k] = undirected edge id of arc k.
    arc_edge_id: np.ndarray = field(init=False, repr=False)

    def __post_init__(self) -> None:
        e = np.asarray(self.edges, dtype=np.int64).reshape(-1, 2)
        if e.size and (e.min() < 0 or e.max() >= self.n):
            raise ValueError("edge endpoint out of range")
        if np.any(e[:, 0] == e[:, 1]):
            raise ValueError("self-loop")
        # Dedup undirected edges.
        key = np.sort(e, axis=1)
        _, uniq_idx = np.unique(key[:, 0] * self.n + key[:, 1], return_index=True)
        e = key[np.sort(uniq_idx)]
        self.edges = e
        m = e.shape[0]
        src = np.concatenate([e[:, 0], e[:, 1]])
        dst = np.concatenate([e[:, 1], e[:, 0]])
        eid = np.concatenate([np.arange(m), np.arange(m)])
        order = np.argsort(src, kind="stable")
        src, dst, eid = src[order], dst[order], eid[order]
        self.indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(self.indptr, src + 1, 1)
        self.indptr = np.cumsum(self.indptr)
        self.indices = dst
        self.arc_src = src
        self.arc_edge_id = eid

    # ---- basic invariants ----
    @property
    def num_edges(self) -> int:
        return self.edges.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.n else 0

    def is_regular(self) -> bool:
        d = self.degrees
        return bool(d.size == 0 or (d == d[0]).all())

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def adjacency_dense(self) -> np.ndarray:
        a = np.zeros((self.n, self.n), dtype=bool)
        a[self.edges[:, 0], self.edges[:, 1]] = True
        a[self.edges[:, 1], self.edges[:, 0]] = True
        return a

    # ---- distances ----
    def distances_from(self, source: int) -> np.ndarray:
        return bfs_distances(self, source)

    def distance_distribution(self, sources=None) -> np.ndarray:
        return distance_distribution(self, sources)

    def diameter(self, sources=None) -> int:
        dist = self.distance_distribution(sources)
        return len(dist) - 1

    def average_distance(self, sources=None) -> float:
        """Mean distance over ordered pairs of distinct vertices (paper's k̄)."""
        w = self.distance_distribution(sources).astype(np.float64)
        total_pairs = w[1:].sum()
        return float((np.arange(len(w)) * w).sum() / total_pairs)

    def is_connected(self) -> bool:
        if self.n == 0:
            return True
        return bool((bfs_distances(self, 0) >= 0).all())


def bfs_distances(g: Graph, source: int) -> np.ndarray:
    """BFS distances from one source; -1 for unreachable."""
    dist = np.full(g.n, -1, dtype=np.int64)
    dist[source] = 0
    frontier = np.array([source], dtype=np.int64)
    d = 0
    while frontier.size:
        nbrs = _gather_neighbors(g, frontier)
        nbrs = nbrs[dist[nbrs] < 0]
        if nbrs.size == 0:
            break
        frontier = np.unique(nbrs)
        d += 1
        dist[frontier] = d
    return dist


def _gather_neighbors(g: Graph, frontier: np.ndarray) -> np.ndarray:
    """Concatenate neighbor lists of all frontier vertices, vectorized."""
    starts = g.indptr[frontier]
    counts = g.indptr[frontier + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    # Classic multi-range gather.
    idx = np.ones(total, dtype=np.int64)
    cum = np.cumsum(counts)
    idx[0] = starts[0]
    idx[cum[:-1]] = starts[1:] - (starts[:-1] + counts[:-1]) + 1
    idx = np.cumsum(idx)
    return g.indices[idx]


def distance_distribution(g: Graph, sources=None) -> np.ndarray:
    """W(t): number of ordered (s, t != s) pairs at distance t, averaged over
    the chosen sources (all vertices by default) so W(t) is 'per vertex' —
    matching the paper's distance-distribution convention.

    For vertex-transitive graphs a single source gives the exact answer;
    pass e.g. ``sources=[0]`` to exploit that.
    """
    if sources is None:
        sources = np.arange(g.n)
    sources = np.asarray(sources, dtype=np.int64)
    counts: list[np.ndarray] = []
    maxd = 0
    acc = np.zeros(1, dtype=np.float64)
    for s in sources:
        dist = bfs_distances(g, int(s))
        if (dist < 0).any():
            raise ValueError("graph is disconnected")
        w = np.bincount(dist)
        if len(w) > len(acc):
            acc = np.pad(acc, (0, len(w) - len(acc)))
        acc[: len(w)] += w
        maxd = max(maxd, len(w) - 1)
    acc /= len(sources)
    acc[0] = 1.0
    return acc[: maxd + 1]
