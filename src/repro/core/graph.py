"""Graph container and vectorized analytics used by the cost model.

Everything operates on plain numpy; graphs here model router-level fabrics
(N up to a few tens of thousands), so dense/CSR numpy is the right tool —
the JAX layer (repro.core.utilization's ``engine="jax"``) sits on top of
the same arrays.

Distance queries come in two shapes:
  * ``bfs_distances``          — one source, CSR frontier expansion;
  * ``bfs_distances_batched``  — an (S, N) block of sources advanced one
    BFS level at a time.  Small graphs use dense float32 matmuls (BLAS does
    a whole level for every source in one GEMM); large graphs fall back to
    a CSR gather + ``logical_or.reduceat`` sweep in a transposed (N, S)
    layout so every big array access is row-contiguous.

The Graph object lazily caches derived structure (dense adjacency,
bipartition, arc sort orders) because the utilization engines and the
orbit machinery ask for them repeatedly.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..perf import flags

__all__ = ["Graph", "bfs_distances", "bfs_distances_batched", "distance_distribution"]


def _dense_max_n() -> int:
    """Above this vertex count the dense (N, N) adjacency and the GEMM-based
    batched BFS stop being the right tool; CSR sweeps take over.  Shared
    with the utilization engines via the util_dense_max perf flag."""
    return flags().util_dense_max


@dataclass
class Graph:
    """Undirected simple graph as an edge list + CSR adjacency."""

    n: int
    edges: np.ndarray  # (E, 2) int64, each undirected edge once, u < v not required
    name: str = ""
    meta: dict = field(default_factory=dict)

    indptr: np.ndarray = field(init=False, repr=False)
    indices: np.ndarray = field(init=False, repr=False)
    # For directed-arc bookkeeping: arc k is (arc_src[k] -> indices[k]).
    arc_src: np.ndarray = field(init=False, repr=False)
    # arc_edge_id[k] = undirected edge id of arc k.
    arc_edge_id: np.ndarray = field(init=False, repr=False)

    def __post_init__(self) -> None:
        e = np.asarray(self.edges, dtype=np.int64).reshape(-1, 2)
        if e.size and (e.min() < 0 or e.max() >= self.n):
            raise ValueError("edge endpoint out of range")
        if np.any(e[:, 0] == e[:, 1]):
            raise ValueError("self-loop")
        # Dedup undirected edges.
        key = np.sort(e, axis=1)
        _, uniq_idx = np.unique(key[:, 0] * self.n + key[:, 1], return_index=True)
        e = key[np.sort(uniq_idx)]
        self.edges = e
        m = e.shape[0]
        src = np.concatenate([e[:, 0], e[:, 1]])
        dst = np.concatenate([e[:, 1], e[:, 0]])
        eid = np.concatenate([np.arange(m), np.arange(m)])
        order = np.argsort(src, kind="stable")
        src, dst, eid = src[order], dst[order], eid[order]
        self.indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.add.at(self.indptr, src + 1, 1)
        self.indptr = np.cumsum(self.indptr)
        self.indices = dst
        self.arc_src = src
        self.arc_edge_id = eid
        self._struct_cache: dict = {"__sig__": self._structure_signature()}

    # ---- basic invariants ----
    @property
    def num_edges(self) -> int:
        return self.edges.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    @property
    def max_degree(self) -> int:
        return int(self.degrees.max()) if self.n else 0

    def is_regular(self) -> bool:
        d = self.degrees
        return bool(d.size == 0 or (d == d[0]).all())

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    # ---- cached structure ----
    # Every piece of derived structure goes through _struct(): one
    # accessor owns cache construction, and the cache is stamped with a
    # structure signature so a graph whose edges were mutated in place
    # (or a shallow copy sharing the parent's cache dict) can never serve
    # stale bipartition / arc-sort / dense-adjacency arrays.  Derived
    # graphs (FaultSet.apply, masked route tables) are built through
    # :meth:`subgraph`, which goes through the constructor and therefore
    # starts with an empty cache.

    def _structure_signature(self) -> tuple:
        e = self.edges
        return (self.n, e.shape[0],
                int(e[:, 0].sum()) if e.size else 0,
                int(e[:, 1].sum()) if e.size else 0)

    def _struct(self, key, build):
        """Central cache accessor: returns ``cache[key]``, building and
        storing it on first use; drops the whole cache if the edge
        structure no longer matches the signature it was built for."""
        sig = self._structure_signature()
        cache = getattr(self, "_struct_cache", None)
        if cache is None or cache.get("__sig__") != sig:
            cache = {"__sig__": sig}
            self._struct_cache = cache
        if key not in cache:
            cache[key] = build()
        return cache[key]

    def adjacency_dense(self, dtype=bool) -> np.ndarray:
        """Dense adjacency, cached per dtype (used by the GEMM engines)."""

        def build():
            a = np.zeros((self.n, self.n), dtype=dtype)
            one = True if np.dtype(dtype) == bool else 1
            a[self.edges[:, 0], self.edges[:, 1]] = one
            a[self.edges[:, 1], self.edges[:, 0]] = one
            return a

        return self._struct(("adj", np.dtype(dtype).str), build)

    def bipartition(self) -> np.ndarray | None:
        """2-coloring side[v] in {0,1} if the graph is bipartite, else None.

        Works per connected component (BFS parity).  The utilization engine
        uses this to run its GEMMs on the half-size biadjacency blocks.
        """

        def build():
            side = np.full(self.n, -1, dtype=np.int8)
            for start in range(self.n):
                if side[start] >= 0:
                    continue
                dist = bfs_distances(self, start)
                comp = dist >= 0
                side[comp] = (dist[comp] % 2).astype(np.int8)
            u, v = self.edges[:, 0], self.edges[:, 1]
            ok = bool((side[u] != side[v]).all()) if self.num_edges else True
            return side if ok else None

        return self._struct("bip", build)

    def arc_sort_by_pair(self) -> tuple[np.ndarray, np.ndarray]:
        """(order, keys): arc ids sorted by (src, dst) and the sorted packed
        keys src*n + dst — a vectorized arc-id lookup table."""

        def build():
            keys = self.arc_src * np.int64(self.n) + self.indices
            order = np.argsort(keys, kind="stable")
            return order, keys[order]

        return self._struct("pairsort", build)

    def reverse_arcs(self) -> np.ndarray:
        """rev[k] = arc id of (v -> u) for arc k = (u -> v)."""

        def build():
            order, keys = self.arc_sort_by_pair()
            qkeys = self.indices * np.int64(self.n) + self.arc_src
            return order[np.searchsorted(keys, qkeys)]

        return self._struct("revarc", build)

    def arcs_by_dst(self) -> np.ndarray:
        """Arc ids sorted by destination; group v occupies
        indptr[v]:indptr[v+1] (in-degree equals degree, graph undirected)."""
        return self._struct("dstsort",
                            lambda: np.argsort(self.indices, kind="stable"))

    # ---- derived graphs ----
    def subgraph(self, edge_mask=None, vertex_mask=None, name: str = "",
                 meta: dict | None = None) -> "Graph":
        """Derived graph built through the constructor, so every cache
        (CSR, bipartition, arc sorts, dense adjacency) is rebuilt from
        scratch — the only sanctioned way to make degraded/masked copies.

        ``edge_mask`` is an (E,) bool keep-mask over ``self.edges``;
        ``vertex_mask`` an (N,) bool keep-mask — dropped vertices take
        their incident edges with them and survivors are relabeled
        compactly in index order.  ``meta`` is NOT inherited: derived
        structure rarely keeps the parent's family semantics (orbit
        generators, torus coordinates), so the caller states what still
        holds."""
        e = self.edges
        keep = (np.ones(e.shape[0], dtype=bool) if edge_mask is None
                else np.asarray(edge_mask, dtype=bool).copy())
        if keep.shape != (e.shape[0],):
            raise ValueError(f"edge_mask is {keep.shape}, graph has "
                             f"{e.shape[0]} edges")
        if vertex_mask is None:
            return Graph(self.n, e[keep], name=name, meta=dict(meta or {}))
        vm = np.asarray(vertex_mask, dtype=bool)
        if vm.shape != (self.n,):
            raise ValueError(f"vertex_mask is {vm.shape}, graph has "
                             f"N={self.n}")
        keep &= vm[e[:, 0]] & vm[e[:, 1]]
        new_id = np.cumsum(vm) - 1
        return Graph(int(vm.sum()), new_id[e[keep]], name=name,
                     meta=dict(meta or {}))

    # ---- distances ----
    def distances_from(self, source: int) -> np.ndarray:
        return bfs_distances(self, source)

    def distance_distribution(self, sources=None) -> np.ndarray:
        return distance_distribution(self, sources)

    def diameter(self, sources=None) -> int:
        dist = self.distance_distribution(sources)
        return len(dist) - 1

    def average_distance(self, sources=None) -> float:
        """Mean distance over ordered pairs of distinct vertices (paper's k̄)."""
        w = self.distance_distribution(sources).astype(np.float64)
        total_pairs = w[1:].sum()
        return float((np.arange(len(w)) * w).sum() / total_pairs)

    def is_connected(self) -> bool:
        if self.n == 0:
            return True
        return bool((bfs_distances(self, 0) >= 0).all())


def bfs_distances(g: Graph, source: int) -> np.ndarray:
    """BFS distances from one source; -1 for unreachable."""
    dist = np.full(g.n, -1, dtype=np.int64)
    dist[source] = 0
    frontier = np.array([source], dtype=np.int64)
    d = 0
    while frontier.size:
        nbrs = _gather_neighbors(g, frontier)
        nbrs = nbrs[dist[nbrs] < 0]
        if nbrs.size == 0:
            break
        frontier = np.unique(nbrs)
        d += 1
        dist[frontier] = d
    return dist


def _gather_neighbors(g: Graph, frontier: np.ndarray) -> np.ndarray:
    """Concatenate neighbor lists of all frontier vertices, vectorized."""
    starts = g.indptr[frontier]
    counts = g.indptr[frontier + 1] - starts
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    # Classic multi-range gather.
    idx = np.ones(total, dtype=np.int64)
    cum = np.cumsum(counts)
    idx[0] = starts[0]
    idx[cum[:-1]] = starts[1:] - (starts[:-1] + counts[:-1]) + 1
    idx = np.cumsum(idx)
    return g.indices[idx]


def bfs_distances_batched(g: Graph, sources, block: int = 0) -> np.ndarray:
    """Level-synchronous BFS from a block of sources at once: (S, N) int16,
    -1 for unreachable.  Dense-GEMM frontier advance for small graphs, CSR
    reduceat sweep for large ones; chunks sources to bound memory."""
    sources = np.asarray(sources, dtype=np.int64)
    s_tot = len(sources)
    out = np.empty((s_tot, g.n), dtype=np.int16)
    if block <= 0:
        block = _bfs_block_rows(g.n)
    for lo in range(0, s_tot, block):
        chunk = sources[lo : lo + block]
        if g.n <= _dense_max_n():
            out[lo : lo + block] = _bfs_block_dense(g, chunk)
        else:
            out[lo : lo + block] = _bfs_block_csr(g, chunk)
    return out


def _bfs_block_rows(n: int) -> int:
    # ~64 MB of float32 frontier per chunk on the dense path
    return max(32, (64 << 20) // max(4 * n, 1))


def _bfs_block_dense(g: Graph, sources: np.ndarray) -> np.ndarray:
    a32 = g.adjacency_dense(np.float32)
    s = len(sources)
    rows = np.arange(s)
    dist = np.full((s, g.n), -1, dtype=np.int16)
    dist[rows, sources] = 0
    frontier = np.zeros((s, g.n), dtype=np.float32)
    frontier[rows, sources] = 1.0
    reached = dist >= 0
    lvl = 0
    while True:
        lvl += 1
        new = (frontier @ a32 > 0) & ~reached
        if not new.any():
            return dist
        dist[new] = lvl
        reached |= new
        frontier = new.astype(np.float32)


def _bfs_block_csr(g: Graph, sources: np.ndarray) -> np.ndarray:
    """Transposed (N, S) layout: the (A, S) per-level gather is then a
    contiguous row copy, and logical_or.reduceat collapses arcs into their
    destination groups (arcs sorted by dst share indptr with the CSR)."""
    s = len(sources)
    dist_t = np.full((g.n, s), -1, dtype=np.int16)
    dist_t[sources, np.arange(s)] = 0
    n_arcs = len(g.arc_src)
    if n_arcs == 0:
        return np.ascontiguousarray(dist_t.T)
    rows_by_dst = g.arc_src[g.arcs_by_dst()]
    # trailing degree-0 vertices would put an offset == n_arcs into
    # reduceat, which rejects it; clip and overwrite their rows below
    starts = np.minimum(g.indptr[:-1], n_arcs - 1)
    deg0 = g.degrees == 0
    frontier_t = np.zeros((g.n, s), dtype=bool)
    frontier_t[sources, np.arange(s)] = True
    lvl = 0
    while True:
        lvl += 1
        red = np.logical_or.reduceat(frontier_t[rows_by_dst], starts, axis=0)
        if deg0.any():
            red[deg0] = False  # reduceat repeats offsets for empty groups
        new = red & (dist_t < 0)
        if not new.any():
            return np.ascontiguousarray(dist_t.T)
        dist_t[new] = lvl
        frontier_t = new


def distance_distribution(g: Graph, sources=None) -> np.ndarray:
    """W(t): number of ordered (s, t != s) pairs at distance t, averaged over
    the chosen sources (all vertices by default) so W(t) is 'per vertex' —
    matching the paper's distance-distribution convention.

    For vertex-transitive graphs a single source gives the exact answer;
    pass e.g. ``sources=[0]`` to exploit that.
    """
    if sources is None:
        sources = np.arange(g.n)
    sources = np.asarray(sources, dtype=np.int64)
    # stream source blocks so memory stays O(N * block), not O(N^2)
    block = _bfs_block_rows(g.n)
    acc = np.zeros(1, dtype=np.float64)
    for lo in range(0, len(sources), block):
        dist = bfs_distances_batched(g, sources[lo : lo + block], block=block)
        if (dist < 0).any():
            raise ValueError("graph is disconnected")
        w = np.bincount(dist.ravel().astype(np.int64))
        if len(w) > len(acc):
            acc = np.pad(acc, (0, len(w) - len(acc)))
        acc[: len(w)] += w
    acc /= len(sources)
    acc[0] = 1.0
    return acc
