"""McKay–Miller–Širáň graphs (the Slim Fly topology of [2]), per Section 4.2.

Vertices (s, x, y), s in {0,1}, x,y in F_q; index = s*q^2 + x*q + y.
Local edges:  (s,x,y1) ~ (s,x,y2)   iff y1 - y2 in X_s,
Global edges: (0,x1,y1) ~ (1,x2,y2) iff y1 - y2 = x2 * x1,
with X_0 the (epsilon-adjusted) even powers of a primitive element and
X_1 = xi * X_0.  Degree (3q - eps)/2, diameter 2, N = 2 q^2.
"""

from __future__ import annotations

import numpy as np

from .gf import get_field, prime_power_decompose
from .graph import Graph

__all__ = ["mms_graph", "mms_eps", "mms_generator_sets"]


def mms_eps(q: int) -> int:
    r = q % 4
    if r == 1:
        return 1
    if r == 3:
        return -1
    if r == 0:
        return 0
    raise ValueError(f"q={q}: q ≡ 2 (mod 4) has no MMS graph (q must be a prime power != 2)")


def mms_generator_sets(q: int) -> tuple[np.ndarray, np.ndarray, int]:
    """Return (X0, X1, eps) per the paper's case split on eps."""
    f = get_field(q)
    eps = mms_eps(q)
    xi = f.primitive_element()
    powers = f.exp[: q - 1]  # xi^0 .. xi^(q-2)
    if eps == 1:
        x0 = powers[0 : q - 2 : 2]  # 1, xi^2, ..., xi^(q-3)
    elif eps == -1:
        # ± even powers: exponents {0,2,..,(q-3)/2} ∪ {(q-1)/2,(q-1)/2+2,..,q-2},
        # the closed-under-negation set with X0 ∩ xi*X0 = {1,-1} the paper needs.
        idx = list(range(0, (q - 1) // 2, 2)) + list(range((q - 1) // 2, q - 1, 2))
        x0 = powers[np.array(idx, dtype=np.int64)]
    else:  # eps == 0 (q a power of 2)
        x0 = powers[0 : q - 1 : 2]  # 1, xi^2, ..., xi^(q-2)
    x1 = f.mul(xi, x0)
    assert len(x0) == (q - eps) // 2, (len(x0), q, eps)
    union = set(x0.tolist()) | set(x1.tolist())
    assert union == set(range(1, q)), "X0 ∪ X1 must be F_q \\ {0}"
    return np.asarray(x0), np.asarray(x1), eps


def mms_graph(q: int) -> Graph:
    """Slim Fly MMS(q) for q a prime power, q != 2."""
    if prime_power_decompose(q) is None:
        raise ValueError(f"q={q} must be a prime power")
    f = get_field(q)
    x0, x1, eps = mms_generator_sets(q)
    qq = q * q
    edges = []

    # Local edges: within column (s, x), connect y1 ~ y2 when y1 - y2 in X_s.
    ys = np.arange(q, dtype=np.int64)
    diff = f.sub(ys[:, None], ys[None, :])  # (q, q)
    for s, xset in ((0, x0), (1, x1)):
        mask = np.isin(diff, xset)
        y1, y2 = np.nonzero(mask)
        keep = y1 < y2  # X_s is symmetric (xi^(q-1)/2 = -1 cases handled by defn)
        y1, y2 = y1[keep], y2[keep]
        for x in range(q):
            base = s * qq + x * q
            edges.append(np.stack([base + y1, base + y2], axis=1))

    # Global edges: (0,x1,y1) ~ (1,x2,y2) iff y1 - y2 = x2*x1.
    xs = np.arange(q, dtype=np.int64)
    x1g, x2g = np.meshgrid(xs, xs, indexing="ij")
    prod = f.mul(x2g.ravel(), x1g.ravel())  # (q*q,)
    y1g = np.repeat(ys[None, :], q * q, axis=0)  # for each (x1,x2), all y1
    y2g = f.sub(y1g, prod[:, None])
    src = (x1g.ravel()[:, None] * q + y1g).ravel()
    dst = (qq + x2g.ravel()[:, None] * q + y2g).ravel()
    edges.append(np.stack([src, dst], axis=1))

    g = Graph(2 * qq, np.concatenate(edges), name=f"SF-MMS({q})")
    n_local = int(sum(e.shape[0] for e in edges[:-1]))
    g.meta.update(q=q, eps=eps, family="mms", n_local_edges=n_local,
                  n_global_edges=int(edges[-1].shape[0]))
    return g
