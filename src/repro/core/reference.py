"""Reference topologies of Sections 4.1/4.4 and Table 2/3.

complete, Turán, complete bipartite, Paley, Hamming 2D/3D (flattened
butterfly), dragonfly (balanced, absolute global arrangement), hypercube,
random regular.
"""

from __future__ import annotations

import numpy as np

from .gf import get_field
from .graph import Graph

__all__ = [
    "complete_graph",
    "turan_graph",
    "complete_bipartite_graph",
    "paley_graph",
    "hamming_graph",
    "dragonfly_graph",
    "hypercube_graph",
    "random_regular_graph",
]


def complete_graph(n: int) -> Graph:
    i, j = np.triu_indices(n, k=1)
    g = Graph(n, np.stack([i, j], axis=1), name=f"K{n}")
    g.meta.update(family="complete")
    return g


def turan_graph(n: int, r: int) -> Graph:
    """Complete multipartite Turán(n, r): parts of size floor/ceil(n/r)."""
    part = np.arange(n) % r  # balanced assignment
    i, j = np.triu_indices(n, k=1)
    mask = part[i] != part[j]
    g = Graph(n, np.stack([i[mask], j[mask]], axis=1), name=f"Turan({n},{r})")
    g.meta.update(family="turan", r=r)
    return g


def complete_bipartite_graph(n: int) -> Graph:
    i = np.repeat(np.arange(n), n)
    j = n + np.tile(np.arange(n), n)
    g = Graph(2 * n, np.stack([i, j], axis=1), name=f"K{n},{n}")
    g.meta.update(family="bipartite", bipartite=True)
    return g


def paley_graph(q: int) -> Graph:
    """Paley(q), q ≡ 1 (mod 4) a prime power."""
    if q % 4 != 1:
        raise ValueError("Paley graph needs q ≡ 1 (mod 4)")
    f = get_field(q)
    sq = f.squares()
    a = np.arange(q)
    diff = f.sub(a[:, None], a[None, :])
    i, j = np.nonzero(np.isin(diff, sq))
    keep = i < j
    g = Graph(q, np.stack([i[keep], j[keep]], axis=1), name=f"Paley({q})")
    g.meta.update(family="paley", q=q)
    return g


def hamming_graph(n: int, dim: int = 2) -> Graph:
    """Hamming graph K_n^dim (2D = flattened butterfly / rook's graph)."""
    size = n**dim
    coords = np.stack(np.unravel_index(np.arange(size), (n,) * dim), axis=1)
    edges = []
    for d in range(dim):
        # vertices agreeing everywhere but coordinate d form a K_n
        other = [k for k in range(dim) if k != d]
        key = np.zeros(size, dtype=np.int64)
        for k in other:
            key = key * n + coords[:, k]
        order = np.argsort(key * n + coords[:, d], kind="stable")
        grp = order.reshape(-1, n)  # each row: the n vertices of one clique
        i, j = np.triu_indices(n, k=1)
        edges.append(np.stack([grp[:, i].ravel(), grp[:, j].ravel()], axis=1))
    g = Graph(size, np.concatenate(edges), name=f"Hamming(K{n}^{dim})")
    g.meta.update(family="hamming", side=n, dim=dim)
    return g


def dragonfly_graph(h: int) -> Graph:
    """Balanced dragonfly [27]: a=2h routers/group, h global links/router,
    g = 2h^2+1 groups, one global link between every pair of groups
    (absolute arrangement)."""
    a = 2 * h
    g_count = a * h + 1  # 2h^2 + 1
    n = a * g_count
    edges = []
    # local: complete graph within each group
    i, j = np.triu_indices(a, k=1)
    for grp in range(g_count):
        base = grp * a
        edges.append(np.stack([base + i, base + j], axis=1))
    # global: group A's port index e in [0, a*h) targets group (e if e < A else e+1);
    # the mirror port on group B is (A if A < B else A-1).
    glob = []
    for A in range(g_count):
        for e in range(a * h):
            B = e if e < A else e + 1
            if A < B:  # add each inter-group link once
                pa = A * a + e // h
                eb = A if A < B else A - 1
                pb = B * a + eb // h
                glob.append((pa, pb))
    edges.append(np.array(glob, dtype=np.int64))
    n_local = int(sum(e.shape[0] for e in edges[:-1]))
    gr = Graph(n, np.concatenate(edges), name=f"dragonfly({h})")
    gr.meta.update(family="dragonfly", h=h, groups=g_count, routers_per_group=a,
                   n_local_edges=n_local, n_global_edges=len(glob))
    return gr


def dragonfly_canonical_stats(h: int) -> tuple[float, float]:
    """(k̄, u) under CANONICAL dragonfly routing (l-g-l, one global hop).

    The paper's Table 2/4/5 dragonfly rows assume this routing, which is
    balanced (u = 1).  True shortest-path routing exploits g-g shortcuts
    through intermediate groups and is measurably unbalanced (u ≈ 0.74 at
    h = 7) — see EXPERIMENTS.md; utilization() reports that number.
    """
    a = 2 * h
    n = a * (a * h + 1)
    kbar = ((a - 1) * 1.0 + (n - a) * (3.0 - 2.0 / a)) / (n - 1)
    return kbar, 1.0


def hypercube_graph(n: int) -> Graph:
    size = 2**n
    v = np.arange(size)
    edges = [np.stack([v[v < (v ^ (1 << d))], (v ^ (1 << d))[v < (v ^ (1 << d))]], axis=1)
             for d in range(n)]
    g = Graph(size, np.concatenate(edges), name=f"Q{n}")
    g.meta.update(family="hypercube", dim=n)
    return g


def random_regular_graph(n: int, d: int, seed: int = 0) -> Graph:
    """Random d-regular graph via the pairing model with retry."""
    if (n * d) % 2:
        raise ValueError("n*d must be even")
    rng = np.random.default_rng(seed)
    for _ in range(200):
        stubs = np.repeat(np.arange(n), d)
        rng.shuffle(stubs)
        e = stubs.reshape(-1, 2)
        e.sort(axis=1)
        if np.any(e[:, 0] == e[:, 1]):
            continue
        key = e[:, 0] * n + e[:, 1]
        if len(np.unique(key)) != len(key):
            continue
        g = Graph(n, e, name=f"random({n},{d})")
        if g.is_connected():
            g.meta.update(family="random", d=d, seed=seed)
            return g
    raise RuntimeError("failed to sample a simple connected regular graph")
