"""repro.core — the paper's contribution: projective-plane network
topologies, the generalized-Moore machinery, and the k̄/u cost model."""

from .cost import (
    CostParams,
    DirectNetworkSpec,
    cost_figure,
    dollars_per_node,
    max_terminals_per_router,
    network_summary,
    watts_per_node,
)
from .gf import GF, get_field, is_prime_power, prime_power_decompose
from .graph import Graph, bfs_distances, bfs_distances_batched, distance_distribution
from .layout import cable_split, electrical_groups, group_sizes
from .mms import mms_graph
from .moore import generalized_moore_kbar, kbar_approx, min_kbar, moore_bound, terminals_bound
from .orbits import OrbitInfo, automorphism_generators, orbit_info
from .projective import (
    demi_pn_graph,
    incidence_lists,
    mlfm_graph,
    num_points,
    oft_graph,
    pn_graph,
    points,
    self_orthogonal_points,
    subplane_classes,
    subplane_line_classes,
)
from .reference import (
    complete_bipartite_graph,
    complete_graph,
    dragonfly_graph,
    hamming_graph,
    hypercube_graph,
    paley_graph,
    random_regular_graph,
    turan_graph,
)
from .adversary import (
    AdversaryReport,
    adversarial_report,
    adversarial_table,
    worst_case,
)
from .faults import (
    DegradationSweep,
    FaultReport,
    FaultSet,
    degradation_sweep,
    degraded_report,
    fault_report,
    random_faults,
    targeted_faults,
)
from .registry import TOPOLOGIES, build_topology
from .routing import (
    ROUTINGS,
    RoutingModel,
    RoutingResult,
    blend_optimum,
    evaluate_models,
    make_routing,
    register_routing,
)
from .select import Realization, all_realizations, realizations_for_family, select_topology
from .traffic import (
    DEFAULT_SWEEP,
    PATTERNS,
    SaturationReport,
    TrafficPattern,
    make_pattern,
    matrix_pattern,
    normalize_demand,
    register_pattern,
    saturation_report,
    saturation_sweep,
)
from .utilization import (
    UtilizationReport,
    arc_loads,
    arc_loads_weighted,
    utilization,
    valiant_report,
)

__all__ = [k for k in dir() if not k.startswith("_")]
