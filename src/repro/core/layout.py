"""Electrical-group layout (Section 5.3): partition routers into groups of
~500 compute nodes; intra-group cables are electrical, inter-group optical.

Natural groupings are used where the topology has one (Hamming rows, MMS
column pairs, dragonfly group bundles, Baer subplanes for PN(p^2)); a greedy
edge-maximizing partitioner covers the rest (the paper's own demi-PN/PN
splits are produced the same way — 'trying to maximize the connections
inside a group').
"""

from __future__ import annotations

import numpy as np

from .graph import Graph
from .projective import subplane_classes, subplane_line_classes

__all__ = ["electrical_groups", "cable_split", "group_sizes"]


def electrical_groups(g: Graph, terminals_per_router: float,
                      target_nodes: int = 500) -> np.ndarray:
    """Return group label per router."""
    per_group = max(1, int(round(target_nodes / max(terminals_per_router, 1e-9))))
    fam = g.meta.get("family", "")
    if fam == "hamming" and g.meta.get("dim") == 2:
        n = g.meta["side"]
        return np.arange(g.n) // n  # rows (each a K_n clique)
    if fam == "mms":
        q = g.meta["q"]
        col = np.arange(g.n) // q  # column (s, x); pair (0,x) with (1,x)
        return col % (g.n // q // 2)
    if fam == "dragonfly":
        a = g.meta["routers_per_group"]
        merge = max(1, per_group // a)
        return (np.arange(g.n) // a) // merge
    if fam in ("pn", "demi_pn"):
        q = g.meta["q"]
        p = int(round(q**0.5))
        if p * p == q:
            cls = subplane_classes(q)
            if fam == "pn":
                cls = np.concatenate([cls, subplane_line_classes(q, cls)])
            # merge subplanes up to the target size
            sub_size = (2 if fam == "pn" else 1) * (p * p + p + 1)
            merge = max(1, per_group // sub_size)
            return cls // merge
        return _greedy_groups(g, per_group)
    return _greedy_groups(g, per_group)


def _greedy_groups(g: Graph, per_group: int) -> np.ndarray:
    """Seed-and-grow partition maximizing intra-group edges."""
    label = np.full(g.n, -1, dtype=np.int64)
    deg = g.degrees
    cur = 0
    order = np.argsort(-deg)  # high-degree seeds first
    adj_count = np.zeros(g.n, dtype=np.int64)  # neighbors in current group
    for seed in order:
        if label[seed] >= 0:
            continue
        members = [int(seed)]
        label[seed] = cur
        adj_count[:] = 0
        nb = g.neighbors(int(seed))
        np.add.at(adj_count, nb[label[nb] < 0], 1)
        while len(members) < per_group:
            free = label < 0
            if not free.any():
                break
            cand_scores = np.where(free, adj_count, -1)
            best = int(np.argmax(cand_scores))
            if cand_scores[best] < 0:
                break
            if cand_scores[best] == 0:
                # no attached candidate: stop growing rather than fragment
                break
            label[best] = cur
            members.append(best)
            nb = g.neighbors(best)
            np.add.at(adj_count, nb[label[nb] < 0], 1)
        cur += 1
    # any stragglers (isolated leftovers) get their own groups
    for v in np.nonzero(label < 0)[0]:
        label[v] = cur
        cur += 1
    return label


def cable_split(g: Graph, labels: np.ndarray) -> tuple[int, int]:
    """(electrical, optical) undirected cable counts for a grouping."""
    same = labels[g.edges[:, 0]] == labels[g.edges[:, 1]]
    return int(same.sum()), int((~same).sum())


def group_sizes(labels: np.ndarray) -> np.ndarray:
    return np.bincount(labels)
