from .engine import Engine, ServeConfig, greedy_sample
