"""Batched serving engine: continuous-batching prefill/decode over the
unified model API, with per-family caches (GQA ring / MLA compressed /
SSD state / RG-LRU state) handled uniformly as pytrees.

The engine keeps a fixed decode batch of ``max_batch`` slots; finished
sequences free their slot and queued requests are prefilled into it
(prefill is per-request; decode is one fused batched step).  This is the
serve-side analogue of the train loop and what `serve_step` lowers in the
dry-run.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..models import build, unbox

__all__ = ["ServeConfig", "Engine", "greedy_sample"]


@dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_len: int = 256
    temperature: float = 0.0


def greedy_sample(logits):
    return jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


@dataclass
class Engine:
    cfg: ArchConfig
    params: Any
    scfg: ServeConfig = ServeConfig()
    mesh: Any = None

    def __post_init__(self):
        self._decode = jax.jit(functools.partial(
            self._decode_impl, self.cfg), static_argnames=())
        self._next_rid = 0
        self.queue: list[Request] = []
        self.done: list[Request] = []

    def _decode_impl(self, cfg, params, cache, tokens, positions):
        bundle = build(cfg)
        logits, cache = bundle.decode_step(params, cache, tokens, positions,
                                           mesh=self.mesh)
        return greedy_sample(logits), cache

    def submit(self, prompt: np.ndarray, max_new: int = 16) -> int:
        rid = self._next_rid
        self._next_rid += 1
        self.queue.append(Request(rid, np.asarray(prompt, np.int32), max_new))
        return rid

    def run(self, memory=None) -> dict[int, list[int]]:
        """Serve everything in the queue; returns {rid: generated tokens}.

        Requests are processed in batches of up to max_batch with a shared
        fused decode step per iteration (continuous batching semantics at
        batch granularity)."""
        bundle = build(self.cfg)
        results: dict[int, list[int]] = {}
        while self.queue:
            active = [self.queue.pop(0) for _ in
                      range(min(self.scfg.max_batch, len(self.queue)))]
            # per-request unpadded prefill (padding would contaminate SSM /
            # RG-LRU state and unmasked attention rows); decode is one fused
            # ragged batch — cached positions beyond a row's own length are
            # masked by its per-row kv_len = position + 1.
            caches, first, plens = [], [], []
            for r in active:
                logits, c = bundle.prefill(
                    self.params, jnp.asarray(r.prompt[None]), memory=memory,
                    mesh=self.mesh, cache_slots=self.scfg.max_len)
                caches.append(c)
                first.append(greedy_sample(logits))
                plens.append(len(r.prompt))
            cache = bundle.concat_caches(caches)
            next_tok = jnp.concatenate(first, 0)
            pos = np.asarray(plens, np.int32)[:, None]
            max_new = max(r.max_new for r in active)
            for step in range(max_new):
                for i, r in enumerate(active):
                    if step < r.max_new:
                        r.out.append(int(next_tok[i]))
                next_tok, cache = self._decode(
                    self.params, cache, next_tok[:, None], jnp.asarray(pos))
                pos += 1
            for r in active:
                r.done = True
                results[r.rid] = r.out
                self.done.append(r)
        return results
