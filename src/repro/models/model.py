"""Public model API: build(cfg) -> ModelBundle with init / loss / prefill /
decode plus spec derivation for the AOT dry-run."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ArchConfig, ShapeConfig
from .common import Boxed, boxed_specs, unbox, DEFAULT_RULES, ShardingRules
from .transformer import count_params, forward, init_model, model_flops

__all__ = ["ModelBundle", "build", "loss_fn", "cache_logical_axes"]


def loss_fn(cfg: ArchConfig, params, batch, *, mesh=None, impl="auto"):
    """Next-token cross-entropy (+ MoE aux + MTP). batch: tokens (B,S)
    [+ memory for vlm/audio]."""
    tokens = batch["tokens"]
    out = forward(cfg, params, tokens, mode="train",
                  memory_inputs=batch.get("memory"), mesh=mesh, impl=impl)
    logits = out["logits"]
    targets = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones_like(tokens, jnp.float32).at[:, -1].set(0.0)

    def ce(lg, tg, mk):
        lse = jax.nn.logsumexp(lg, axis=-1)
        # one-hot contraction instead of take_along_axis: stays sharded over
        # a vocab-parallel (model-axis) logits layout, no all-gather
        onehot = (tg[..., None] == jnp.arange(lg.shape[-1])[None, None, :])
        gold = jnp.sum(lg * onehot.astype(lg.dtype), axis=-1)
        return (((lse - gold) * mk).sum() / jnp.clip(mk.sum(), 1.0))

    loss = ce(logits, targets, mask)
    metrics = {"ce": loss, "aux": out["aux"]}
    loss = loss + out["aux"]
    if "mtp_logits" in out:
        t2 = jnp.roll(tokens, -2, axis=1)
        m2 = jnp.ones_like(tokens, jnp.float32).at[:, -2:].set(0.0)
        mtp_loss = ce(out["mtp_logits"], t2, m2)
        metrics["mtp"] = mtp_loss
        loss = loss + 0.3 * mtp_loss
    return loss, metrics


def cache_logical_axes(cache_tree):
    """Assign logical sharding axes to a cache pytree by leaf name/rank.

    Leaves under the scanned ``body`` subtree carry a leading LAYER axis
    (stacked by lax.scan) before the batch axis; missing that made the
    batch rule land on the layer dim and the big decode caches resolve to
    fully-replicated (observed: 464 GiB/device on deepseek decode_32k).
    """
    def assign(path, leaf):
        names = [p.key if hasattr(p, "key") else str(p) for p in path]
        name = names[-1]
        nd = len(leaf.shape)
        if "body" in names:  # strip the stacked layer dim for the name rules
            nd -= 1
        if name in ("k", "v"):
            axes = ("batch", "kv_heads", "kv_seq", None)
        elif name == "kpos":
            axes = ("batch", "kv_seq")
        elif name in ("ckv", "krope"):
            axes = ("batch", "kv_seq", None)
        elif name == "conv":
            axes = ("batch", None, "ff")
        elif name == "state":
            axes = ("batch", None, None, None) if nd == 4 else ("batch", "ff")
        elif name == "enc_memory":
            axes = ("batch", None, None)
        else:
            axes = ("batch",) + (None,) * (nd - 1)
        assert len(axes) == nd, (names, leaf.shape, axes)
        if "body" in names:
            axes = (None,) + axes  # the stacked layer dim is never sharded
        return axes
    return jax.tree_util.tree_map_with_path(assign, cache_tree)


@dataclass
class ModelBundle:
    cfg: ArchConfig

    def init(self, key) -> dict:
        return init_model(self.cfg, key)

    def abstract_params(self, key=None) -> dict:
        """Boxed ShapeDtypeStruct params — no allocation (for the dry-run)."""
        return jax.eval_shape(lambda k: init_model(self.cfg, k),
                              jax.random.key(0))

    def param_specs(self, mesh: Mesh, rules: ShardingRules = DEFAULT_RULES):
        boxed = self.abstract_params()
        return boxed_specs(boxed, rules, mesh)

    def loss(self, params, batch, *, mesh=None, impl="auto"):
        return loss_fn(self.cfg, params, batch, mesh=mesh, impl=impl)

    def prefill(self, params, tokens, *, memory=None, mesh=None, impl="auto",
                cache_slots=None):
        out = forward(self.cfg, params, tokens, mode="prefill",
                      memory_inputs=memory, mesh=mesh, impl=impl,
                      cache_slots=cache_slots)
        return out["logits"], out["cache"]

    def decode_step(self, params, cache, tokens, positions, *, mesh=None,
                    impl="auto"):
        out = forward(self.cfg, params, tokens, mode="decode",
                      positions=positions, cache=cache, mesh=mesh, impl=impl)
        return out["logits"], out["cache"]

    @staticmethod
    def concat_caches(caches: list):
        """Merge per-request caches along each leaf's BATCH axis (leaves
        under the scanned 'body' subtree carry a leading layer axis, so
        batch is not always axis 0)."""
        import jax.tree_util as jtu
        if len(caches) == 1:
            return caches[0]
        axes_tree = cache_logical_axes(caches[0])
        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        flat_axes = jtu.tree_flatten(axes_tree, is_leaf=is_axes)[0]
        treedef = jtu.tree_structure(caches[0])
        flat = [jtu.tree_flatten(c)[0] for c in caches]
        merged = [jnp.concatenate(leaves, axis=ax.index("batch"))
                  for ax, leaves in zip(flat_axes, zip(*flat))]
        return jtu.tree_unflatten(treedef, merged)

    def num_params(self) -> int:
        return count_params(self.cfg)

    def num_active_params(self) -> int:
        return count_params(self.cfg, active_only=True)

    def flops(self, tokens: int, mode: str = "train") -> float:
        return model_flops(self.cfg, tokens, mode)

    # ---- dry-run inputs -----------------------------------------------
    def input_specs(self, shape: ShapeConfig):
        """ShapeDtypeStruct stand-ins for one step at the given shape."""
        cfg = self.cfg
        b = shape.global_batch
        tok = jax.ShapeDtypeStruct((b, shape.seq_len), jnp.int32)
        extras = {}
        if cfg.vision is not None:
            extras["memory"] = jax.ShapeDtypeStruct(
                (b, cfg.vision.n_image_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.encoder is not None:
            extras["memory"] = jax.ShapeDtypeStruct(
                (b, max(1, shape.seq_len // cfg.encoder.frame_ratio), cfg.d_model),
                jnp.bfloat16)
        if shape.kind == "train":
            return {"batch": {"tokens": tok, **({"memory": extras["memory"]}
                                                if extras else {})}}
        if shape.kind == "prefill":
            return {"tokens": tok, **({"memory": extras["memory"]} if extras else {})}
        # decode: one token against a seq_len cache
        dec_tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        return {"tokens": dec_tok, "positions": pos, **extras}


def build(cfg: ArchConfig) -> ModelBundle:
    return ModelBundle(cfg)
