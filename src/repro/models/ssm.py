"""Mamba-2 (SSD) mixer block: in_proj -> causal depthwise conv -> SSD scan
-> gated RMSNorm -> out_proj.  Train/prefill use the chunked SSD kernel
path; decode keeps (conv tail, SSD state) as the cache — O(1) per token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ops
from .common import box, truncated_normal_init
from .layers import rms_norm

__all__ = ["init_ssd_block", "apply_ssd_block", "ssd_block_cache_shape"]


def _dims(cfg: ArchConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    conv_dim = d_inner + 2 * ssm.n_groups * ssm.d_state
    return d_inner, n_heads, conv_dim


def init_ssd_block(cfg: ArchConfig, key):
    ssm = cfg.ssm
    m = cfg.d_model
    d_inner, h, conv_dim = _dims(cfg)
    d_in_proj = 2 * d_inner + 2 * ssm.n_groups * ssm.d_state + h
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    e = "fsdp" if cfg.fsdp else None
    return {
        "norm": box(jnp.ones((m,), dt), (None,)),
        "in_proj": box(truncated_normal_init(ks[0], (m, d_in_proj), dt), (e, "ff")),
        "conv_w": box(truncated_normal_init(ks[1], (ssm.d_conv, conv_dim), dt,
                                            fan_in_dims=(0,)), ("conv", "ff")),
        "conv_b": box(jnp.zeros((conv_dim,), dt), ("ff",)),
        "a_log": box(jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dt), (None,)),
        "dt_bias": box(jnp.zeros((h,), dt), (None,)),
        "d_skip": box(jnp.ones((h,), dt), (None,)),
        "gate_norm": box(jnp.ones((d_inner,), dt), ("ff",)),
        "out_proj": box(truncated_normal_init(ks[2], (d_inner, m), dt), ("ff", e)),
    }


def _causal_conv(x, w, b):
    """x: (B, S, C); w: (K, C) depthwise; left-padded causal."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp.astype(jnp.float32), w.astype(jnp.float32)[:, None, :],
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1])
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def ssd_block_cache_shape(cfg: ArchConfig, batch: int):
    ssm = cfg.ssm
    d_inner, h, conv_dim = _dims(cfg)
    return {
        "conv": (batch, ssm.d_conv - 1, conv_dim),
        "state": (batch, h, ssm.d_state, ssm.head_dim),
    }


def apply_ssd_block(cfg: ArchConfig, p, x, *, mode: str, cache=None,
                    impl: str = "auto"):
    ssm = cfg.ssm
    b, s, m = x.shape
    d_inner, h, conv_dim = _dims(cfg)
    gn = ssm.n_groups * ssm.d_state
    hidden = rms_norm(x, p["norm"], cfg.norm_eps)
    zxbcdt = hidden @ p["in_proj"].astype(hidden.dtype)
    z, xbc, dt_raw = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)

    if mode == "decode":
        conv_tail = cache["conv"]  # (B, d_conv-1, conv_dim)
        window = jnp.concatenate([conv_tail, xbc], axis=1)  # (B, d_conv, C)
        conv_out = (window.astype(jnp.float32)
                    * p["conv_w"].astype(jnp.float32)[None]).sum(1) \
            + p["conv_b"].astype(jnp.float32)
        xbc_act = jax.nn.silu(conv_out).astype(x.dtype)[:, None]  # (B,1,C)
        new_conv = window[:, 1:]
    else:
        xbc_act = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"])
                              .astype(jnp.float32)).astype(x.dtype)
        new_conv = None
        if mode == "prefill":
            pad = max(0, ssm.d_conv - 1 - s)
            tail = jnp.pad(xbc, ((0, 0), (pad, 0), (0, 0)))[:, -(ssm.d_conv - 1):]
            new_conv = tail

    xs, bmat, cmat = jnp.split(xbc_act, [d_inner, d_inner + gn], axis=-1)
    xs = xs.reshape(b, -1, h, ssm.head_dim)
    bmat = bmat.reshape(b, -1, ssm.n_groups, ssm.d_state)
    cmat = cmat.reshape(b, -1, ssm.n_groups, ssm.d_state)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))

    if mode == "decode":
        y_t, new_state = ops.ssd_decode_step(
            cache["state"], xs[:, 0], dt[:, 0], p["a_log"], bmat[:, 0],
            cmat[:, 0], p["d_skip"])
        y = y_t[:, None]
        new_cache = {"conv": new_conv, "state": new_state}
    else:
        from ..perf import flags
        state_in = cache["state"] if (cache and "state" in cache) else None
        y, final_state = ops.ssd(xs, dt, p["a_log"], bmat, cmat, p["d_skip"],
                                 chunk=flags().ssd_chunk or ssm.chunk,
                                 impl=impl, state=state_in)
        new_cache = ({"conv": new_conv, "state": final_state}
                     if mode == "prefill" else None)

    y = y.reshape(b, -1, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"].astype(y.dtype), new_cache
