"""Model zoo: unified block-based decoder for the 10 assigned archs."""

from .common import (Boxed, box, boxed_specs, logical_specs, resolve_specs,
                     unbox, DEFAULT_RULES, ShardingRules)
from .model import ModelBundle, build, cache_logical_axes, loss_fn
from .transformer import count_params, forward, init_model, layer_plan, model_flops

__all__ = [
    "Boxed", "box", "boxed_specs", "logical_specs", "resolve_specs", "unbox",
    "DEFAULT_RULES", "ShardingRules", "ModelBundle", "build",
    "cache_logical_axes", "loss_fn", "count_params", "forward", "init_model",
    "layer_plan", "model_flops",
]
