"""Mixture-of-Experts block: top-k router, shared experts, and two dispatch
paths:

* ``dense``  — loop-over-experts masked compute, exact, used for CPU smoke
  tests and as the correctness oracle for the sharded path;
* ``a2a``    — production expert parallelism via shard_map +
  jax.lax.all_to_all over the 'model' mesh axis: tokens are sharded over
  every mesh axis, experts over 'model'; each device scatters its tokens
  into per-expert capacity bins, all-to-alls them to the owning expert
  shard, runs the expert MLPs as one batched matmul, and reverses the
  exchange.  Capacity overflow drops (standard Switch-style), with the
  capacity factor in the config.

The expert weights carry logical axes (expert -> model, ff -> fsdp), so the
optimizer state is fully sharded; the forward all-gathers the ff shards
(ZeRO-3) inside the shard_map body.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

# jax >= 0.5 exposes shard_map at top level; older jax keeps it experimental
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map

from ..configs.base import ArchConfig
from .common import Boxed, box, truncated_normal_init
from .layers import init_mlp, apply_mlp, rms_norm

__all__ = ["init_moe", "apply_moe", "router_topk", "moe_aux_loss"]


def init_moe(cfg: ArchConfig, key):
    moe = cfg.moe
    m, f, e = cfg.d_model, moe.d_ff_expert, moe.n_experts
    ks = jax.random.split(key, 6)
    emb_ax = "fsdp" if cfg.fsdp else None
    dt = cfg.param_dtype
    p = {
        "norm": box(jnp.ones((m,), dt), (None,)),
        "router": box(truncated_normal_init(ks[0], (m, e), dt), (None, None)),
        "w_gate": box(truncated_normal_init(ks[1], (e, m, f), dt,
                                            fan_in_dims=(1,)),
                      ("expert", None, "expert_ff")),
        "w_up": box(truncated_normal_init(ks[2], (e, m, f), dt, fan_in_dims=(1,)),
                    ("expert", None, "expert_ff")),
        "w_down": box(truncated_normal_init(ks[3], (e, f, m), dt, fan_in_dims=(1,)),
                      ("expert", "expert_ff", None)),
    }
    if moe.n_shared:
        shared_cfg = cfg.replace(mlp_act="silu_glu")
        p["shared"] = init_mlp(shared_cfg, ks[4], d_ff=moe.d_ff_expert * moe.n_shared)
    return p


def router_topk(cfg: ArchConfig, logits):
    """Top-k gating with renormalized weights. logits: (T, E)."""
    k = cfg.moe.top_k
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_w, top_idx = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.clip(top_w.sum(-1, keepdims=True), 1e-9)
    return probs, top_w, top_idx


def moe_aux_loss(probs, top_idx, n_experts: int):
    """Switch-style load-balancing loss: E * Σ_e f_e · p_e."""
    t = probs.shape[0]
    assign = jax.nn.one_hot(top_idx[:, 0], n_experts, dtype=jnp.float32)
    f_e = assign.mean(0)
    p_e = probs.mean(0)
    return n_experts * jnp.sum(f_e * p_e)


def _expert_mlp(x, w_gate, w_up, w_down):
    """x: (E, C, M) batched per-expert MLP (fp32 operands, baseline)."""
    h = jax.nn.silu(jnp.einsum("ecm,emf->ecf", x, w_gate)) \
        * jnp.einsum("ecm,emf->ecf", x, w_up)
    return jnp.einsum("ecf,efm->ecm", h, w_down)


def _expert_mlp_any(x, w_gate, w_up, w_down):
    """Dispatch on the bf16_experts perf flag: bf16 operand streams with
    fp32 MXU accumulation instead of materialized fp32 casts of the
    (all-gathered) expert weights — halves the dominant byte stream."""
    from ..perf import flags
    if not flags().bf16_experts:
        return _expert_mlp(x.astype(jnp.float32), w_gate.astype(jnp.float32),
                           w_up.astype(jnp.float32),
                           w_down.astype(jnp.float32))
    dt = jnp.bfloat16
    xe = x.astype(dt)
    g = jnp.einsum("ecm,emf->ecf", xe, w_gate.astype(dt),
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecm,emf->ecf", xe, w_up.astype(dt),
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(dt)
    return jnp.einsum("ecf,efm->ecm", h, w_down.astype(dt),
                      preferred_element_type=jnp.float32)


def _dense_path(cfg, p, x2d, top_w, top_idx):
    """Oracle path: per-expert masked compute (small configs only)."""
    moe = cfg.moe
    out = jnp.zeros_like(x2d)
    for e in range(moe.n_experts):
        w = ((top_idx == e).astype(x2d.dtype) * top_w.astype(x2d.dtype)).sum(-1)  # (T,)
        h = jax.nn.silu(x2d @ p["w_gate"][e].astype(x2d.dtype)) \
            * (x2d @ p["w_up"][e].astype(x2d.dtype))
        out = out + (h @ p["w_down"][e].astype(x2d.dtype)) * w[:, None]
    return out


def _a2a_body(x_loc, wi, wg, wu, wd, *, cfg: ArchConfig, capacity: int,
              model_axis: str, gather_axes: tuple, all_axes: tuple,
              e_pad: int | None = None):
    """shard_map body. x_loc: (t_loc, M) local tokens; wi: router (M, E);
    wg/wu/wd: local expert shards (E_loc, M, F_loc).  When n_experts does
    not divide the EP axis, callers zero-pad the expert dim to ``e_pad``
    and the router logits are -inf-padded so no token routes to a pad."""
    moe = cfg.moe
    e_total = e_pad or moe.n_experts
    t_loc, m = x_loc.shape
    if gather_axes:
        wg = jax.lax.all_gather(wg, gather_axes, axis=2, tiled=True)
        wu = jax.lax.all_gather(wu, gather_axes, axis=2, tiled=True)
        wd = jax.lax.all_gather(wd, gather_axes, axis=1, tiled=True)

    logits = x_loc @ wi.astype(x_loc.dtype)          # (t_loc, n_experts)
    probs, top_w, top_idx = router_topk(cfg, logits)  # over REAL experts

    # scatter tokens into (E, C, M) send bins; overflow beyond C drops
    flat_e = top_idx.reshape(-1)                     # (t_loc*k,)
    flat_w = top_w.reshape(-1).astype(x_loc.dtype)
    flat_t = jnp.repeat(jnp.arange(t_loc), moe.top_k)
    onehot = jax.nn.one_hot(flat_e, e_total, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot - 1   # (t_loc*k, E)
    slot = (pos_in_e * onehot).sum(-1)                   # position within expert
    keep = slot < capacity
    send = jnp.zeros((e_total, capacity, m), x_loc.dtype)
    send = send.at[flat_e, jnp.where(keep, slot, 0)].add(
        jnp.where(keep, 1.0, 0.0)[:, None] * x_loc[flat_t])

    # exchange over the model axis: (E, C, M) -> (E_loc, C*mp, M)
    recv = jax.lax.all_to_all(send, model_axis, split_axis=0, concat_axis=1,
                              tiled=True)
    y = _expert_mlp_any(recv, wg, wu, wd).astype(x_loc.dtype)
    back = jax.lax.all_to_all(y, model_axis, split_axis=1, concat_axis=0,
                              tiled=True)                # (E, C, M)

    # combine: weighted gather back to tokens
    gathered = back[flat_e, jnp.where(keep, slot, 0)]    # (t_loc*k, M)
    gathered = gathered * (flat_w * keep.astype(flat_w.dtype))[:, None]
    out = jnp.zeros_like(x_loc).at[flat_t].add(gathered)

    # global Switch balance loss: pmean the per-expert factors BEFORE the
    # product (a per-device product of local means would depend on how
    # tokens happen to be grouped across devices)
    assign = jax.nn.one_hot(top_idx[:, 0], moe.n_experts, dtype=jnp.float32)
    f_e = jax.lax.pmean(assign.mean(0), all_axes)
    p_e = jax.lax.pmean(probs.mean(0), all_axes)
    aux = moe.n_experts * jnp.sum(f_e * p_e)
    return out, aux


def _global_scatter_path(cfg: ArchConfig, p, x2d):
    """Scatter-dispatch in pjit-land (no shard_map): build (E, C, M) bins
    globally and let GSPMD place them on the expert-sharded mesh axis.
    Used for decode-scale token counts where per-device sharding of the
    token dim is impossible."""
    moe = cfg.moe
    t, m = x2d.shape
    logits = x2d @ p["router"].astype(x2d.dtype)
    probs, top_w, top_idx = router_topk(cfg, logits)
    capacity = max(1, int(np.ceil(t * moe.top_k / moe.n_experts
                                  * moe.capacity_factor)))
    flat_e = top_idx.reshape(-1)
    flat_w = top_w.reshape(-1).astype(x2d.dtype)
    flat_t = jnp.repeat(jnp.arange(t), moe.top_k)
    onehot = jax.nn.one_hot(flat_e, moe.n_experts, dtype=jnp.int32)
    slot = (jnp.cumsum(onehot, axis=0) * onehot - 1).max(-1)
    keep = (slot >= 0) & (slot < capacity)
    slot = jnp.where(keep, slot, 0)
    send = jnp.zeros((moe.n_experts, capacity, m), x2d.dtype)
    send = send.at[flat_e, slot].add(keep.astype(x2d.dtype)[:, None] * x2d[flat_t])
    y = _expert_mlp_any(send, p["w_gate"], p["w_up"],
                        p["w_down"]).astype(x2d.dtype)
    gathered = y[flat_e, slot] * (flat_w * keep.astype(flat_w.dtype))[:, None]
    out = jnp.zeros_like(x2d).at[flat_t].add(gathered)
    return out, moe_aux_loss(probs, top_idx, moe.n_experts)


def apply_moe(cfg: ArchConfig, p, x, *, mesh: Mesh | None = None,
              impl: str = "auto"):
    """x: (B, S, M) -> (y, aux_loss)."""
    moe = cfg.moe
    b, s, m = x.shape
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    x2d = h.reshape(b * s, m)

    n_dev = int(np.prod(mesh.devices.shape)) if mesh is not None else 1
    use_a2a = (impl in ("a2a", "auto") and mesh is not None
               and "model" in mesh.axis_names and n_dev > 1
               and (b * s) % n_dev == 0)
    if use_a2a:
        from ..perf import flags
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        all_axes = tuple(mesh.axis_names)
        gather_axes = tuple(a for a in all_axes if a != "model" and sizes[a] > 1)
        batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
        nb = int(np.prod([sizes[a] for a in batch_axes])) if batch_axes else 1
        t_loc = (b * s) // n_dev
        capacity = max(1, int(np.ceil(t_loc * moe.top_k / moe.n_experts
                                      * moe.capacity_factor)))
        ep = sizes["model"]
        e_pad = -(-moe.n_experts // ep) * ep  # next multiple of the EP axis
        wg, wu, wd = p["w_gate"], p["w_up"], p["w_down"]
        if e_pad != moe.n_experts:
            # zero-pad dead expert slots (never routed: top_k only sees the
            # real logits); keeps EP for e.g. 40 experts on a 16-way axis
            padw = ((0, e_pad - moe.n_experts), (0, 0), (0, 0))
            wg, wu, wd = (jnp.pad(w, padw) for w in (wg, wu, wd))
        body = functools.partial(_a2a_body, cfg=cfg, capacity=capacity,
                                 model_axis="model", gather_axes=gather_axes,
                                 all_axes=all_axes, e_pad=e_pad)
        weight_specs = (P(None, None),
                        P("model", None, gather_axes or None),
                        P("model", None, gather_axes or None),
                        P("model", gather_axes or None, None))
        use_3d = (flags().moe_3d and b % nb == 0 and s % ep == 0)
        if use_3d:
            # §Perf moe_3d: enter shard_map in the residual's NATIVE layout
            # (batch->dp, seq->model) and flatten per-device INSIDE the body.
            # The 2D baseline's (B·S, M) flatten has no efficient SPMD
            # transition from that layout, so GSPMD replicates the full
            # activation ('involuntary full rematerialization': a 28 GiB
            # fp32 all-gather per MoE layer on deepseek train_4k).
            def body3d(x3, wi_, wg_, wu_, wd_):
                bl, sl, m_ = x3.shape
                out, aux = body(x3.reshape(bl * sl, m_), wi_, wg_, wu_, wd_)
                return out.reshape(bl, sl, m_), aux
            tok3 = P(batch_axes or None, "model", None)
            out3d, aux = _shard_map(
                body3d, mesh=mesh, in_specs=(tok3, *weight_specs),
                out_specs=(tok3, P()),
            )(h, p["router"], wg, wu, wd)
            out2d = None  # stay 3D end-to-end (no flatten round-trip)
        else:
            tok_spec = P(all_axes)  # tokens sharded over every axis
            out2d, aux = _shard_map(
                body, mesh=mesh,
                in_specs=(tok_spec, *weight_specs),
                out_specs=(tok_spec, P()),
            )(x2d, p["router"], wg, wu, wd)
    elif impl != "dense" and mesh is not None and n_dev > 1:
        # global scatter-dispatch path (decode-sized batches): no shard_map,
        # GSPMD shards the (E, C, M) bins over the model axis.
        out2d, aux = _global_scatter_path(cfg, p, x2d)
    else:
        logits = x2d @ p["router"].astype(x2d.dtype)
        probs, top_w, top_idx = router_topk(cfg, logits)
        out2d = _dense_path(cfg, p, x2d, top_w, top_idx)
        aux = moe_aux_loss(probs, top_idx, moe.n_experts)

    y = out3d if out2d is None else out2d.reshape(b, s, m)
    if "shared" in p:
        y = y + apply_mlp(cfg.replace(mlp_act="silu_glu"), p["shared"], h,
                          skip_norm=True)
    return y, aux * moe.router_aux_weight
