"""Shared model machinery: boxed params with logical sharding axes,
rule-based PartitionSpec resolution, initializers, dtype policy.

Params are pytrees of :class:`Boxed` leaves carrying ``(value, logical
axes)``; ``unbox`` strips to plain arrays for compute, ``logical_specs`` +
``resolve_specs`` turn the axes into mesh PartitionSpecs.  This keeps the
sharding annotation exactly adjacent to the initializer that created the
weight — the MaxText pattern without the flax dependency.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "Boxed", "box", "unbox", "logical_specs", "resolve_specs", "ShardingRules",
    "DEFAULT_RULES", "truncated_normal_init", "zeros_init", "scale_init",
    "Policy", "DEFAULT_POLICY", "with_sharding",
]


@jax.tree_util.register_pytree_node_class
class Boxed:
    """A parameter leaf: array + logical axis names (one per dim)."""

    __slots__ = ("value", "axes")

    def __init__(self, value, axes):
        self.value = value
        self.axes = tuple(axes)

    def tree_flatten(self):
        return (self.value,), self.axes

    @classmethod
    def tree_unflatten(cls, axes, children):
        return cls(children[0], axes)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Boxed({shape}, axes={self.axes})"


def box(value, axes) -> Boxed:
    assert len(axes) == value.ndim, (value.shape, axes)
    return Boxed(value, axes)


def _is_boxed(x) -> bool:
    return isinstance(x, Boxed)


def unbox(tree):
    """Boxed pytree -> plain array pytree."""
    return jax.tree.map(lambda b: b.value, tree, is_leaf=_is_boxed)


def logical_specs(tree):
    """Boxed pytree -> pytree of logical-axis tuples."""
    return jax.tree.map(lambda b: b.axes, tree, is_leaf=_is_boxed)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """logical axis name -> mesh axis (or tuple of mesh axes, or None)."""

    rules: tuple[tuple[str, Any], ...]

    def lookup(self, name: str | None):
        if name is None:
            return None
        for k, v in self.rules:
            if k == name:
                return v
        return None

    def replace(self, **kw) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kw)
        return ShardingRules(tuple(d.items()))


DEFAULT_RULES = ShardingRules(rules=(
    ("batch", ("pod", "data")),
    ("fsdp", ("data", "pod")),  # ZeRO-3 weight-shard dims (large models)
    ("embed", None),
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("ff", "model"),
    ("expert", "model"),
    ("expert_ff", "fsdp_proxy"),  # resolved via the 'fsdp' rule at use site
    ("seq", None),
    ("kv_seq", None),
    ("state", None),
    ("conv", None),
))


def _mesh_axes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def resolve_specs(axes_tree, rules: ShardingRules, mesh: Mesh, shapes_tree=None):
    """Logical axes pytree -> PartitionSpec pytree, dropping any assignment
    that does not divide the dimension (e.g. kv_heads=1 on a 16-way model
    axis falls back to replication)."""
    sizes = _mesh_axes(mesh)

    def one(axes, shape):
        spec, used = [], set()
        for d, name in enumerate(axes):
            assign = rules.lookup(name)
            if assign == "fsdp_proxy":
                assign = rules.lookup("fsdp")
            ok = None
            if assign is not None:
                parts = (assign,) if isinstance(assign, str) else tuple(assign)
                parts = tuple(p for p in parts if p in sizes and p not in used)
                total = int(np.prod([sizes[p] for p in parts])) if parts else 1
                if parts and shape is not None and shape[d] % total == 0:
                    ok = parts if len(parts) > 1 else parts[0]
                    used.update(parts)
                elif parts and shape is None:
                    ok = parts if len(parts) > 1 else parts[0]
                    used.update(parts)
            spec.append(ok)
        return PartitionSpec(*spec)

    if shapes_tree is None:
        return jax.tree.map(lambda a: one(a, None), axes_tree,
                            is_leaf=lambda x: isinstance(x, tuple) and all(
                                isinstance(e, (str, type(None))) for e in x))
    return jax.tree.map(one, axes_tree, shapes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(e, (str, type(None))) for e in x))


def boxed_specs(params, rules: ShardingRules, mesh: Mesh):
    """Boxed pytree (or ShapeDtypeStruct-boxed) -> PartitionSpec pytree."""
    def one(b: Boxed):
        return resolve_specs(b.axes, rules, mesh, tuple(b.value.shape))
    return jax.tree.map(one, params, is_leaf=_is_boxed)


def with_sharding(x, spec: PartitionSpec, mesh: Mesh):
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---- initializers -----------------------------------------------------------


def truncated_normal_init(key, shape, dtype, scale: float | None = None,
                          fan_in_dims=(0,)):
    fan_in = int(np.prod([shape[d] for d in fan_in_dims])) or 1
    std = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def zeros_init(key, shape, dtype, **_):
    return jnp.zeros(shape, dtype)


def scale_init(value: float):
    def init(key, shape, dtype, **_):
        return jnp.full(shape, value, dtype)
    return init


@dataclasses.dataclass(frozen=True)
class Policy:
    """dtype policy: storage/compute/softmax accumulation."""

    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    accum_dtype: Any = jnp.float32

    def cast_compute(self, tree):
        return jax.tree.map(
            lambda x: x.astype(self.compute_dtype)
            if isinstance(x, jax.Array) and jnp.issubdtype(x.dtype, jnp.floating) else x,
            tree)


DEFAULT_POLICY = Policy()
