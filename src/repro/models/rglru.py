"""RecurrentGemma/Griffin recurrent block: linear -> causal conv -> RG-LRU,
gated by a GeLU branch.  Decode cache = (conv tail, LRU state) — O(1)/token.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig
from ..kernels import ops
from .common import box, truncated_normal_init
from .layers import rms_norm

__all__ = ["init_rglru_block", "apply_rglru_block", "rglru_block_cache_shape"]


def _width(cfg: ArchConfig) -> int:
    return cfg.rglru.lru_width or cfg.d_model


def init_rglru_block(cfg: ArchConfig, key):
    m = cfg.d_model
    w = _width(cfg)
    dconv = cfg.rglru.d_conv
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    e = "fsdp" if cfg.fsdp else None
    return {
        "norm": box(jnp.ones((m,), dt), (None,)),
        "w_x": box(truncated_normal_init(ks[0], (m, w), dt), (e, "ff")),
        "w_gate": box(truncated_normal_init(ks[1], (m, w), dt), (e, "ff")),
        "conv_w": box(truncated_normal_init(ks[2], (dconv, w), dt,
                                            fan_in_dims=(0,)), ("conv", "ff")),
        "conv_b": box(jnp.zeros((w,), dt), ("ff",)),
        "w_a": box(truncated_normal_init(ks[3], (w, w), dt), ("ff", None)),
        "w_i": box(truncated_normal_init(ks[4], (w, w), dt), ("ff", None)),
        # init Λ so a ≈ 0.9..0.999 (standard LRU init)
        "a_param": box(jnp.log(jnp.expm1(-jnp.log(
            jnp.linspace(0.9, 0.999, w)) / cfg.rglru.c)).astype(dt), ("ff",)),
        "w_out": box(truncated_normal_init(ks[5], (w, m), dt), ("ff", e)),
    }


def rglru_block_cache_shape(cfg: ArchConfig, batch: int):
    w = _width(cfg)
    return {"conv": (batch, cfg.rglru.d_conv - 1, w), "state": (batch, w)}


def apply_rglru_block(cfg: ArchConfig, p, x, *, mode: str, cache=None):
    b, s, m = x.shape
    w = _width(cfg)
    c = cfg.rglru.c
    hidden = rms_norm(x, p["norm"], cfg.norm_eps)
    xb = hidden @ p["w_x"].astype(hidden.dtype)          # (B,S,W)
    gate = jax.nn.gelu(hidden @ p["w_gate"].astype(hidden.dtype))

    if mode == "decode":
        window = jnp.concatenate([cache["conv"], xb], axis=1)  # (B, dconv, W)
        conv_out = (window.astype(jnp.float32)
                    * p["conv_w"].astype(jnp.float32)[None]).sum(1) \
            + p["conv_b"].astype(jnp.float32)
        xc = conv_out.astype(x.dtype)                    # (B, W)
        a_gate = xc @ p["w_a"].astype(xc.dtype)
        i_gate = xc @ p["w_i"].astype(xc.dtype)
        y_t, state = ops.rglru_decode_step(cache["state"], xc, a_gate, i_gate,
                                           p["a_param"], c=c)
        y = y_t[:, None]
        new_cache = {"conv": window[:, 1:], "state": state}
    else:
        k = p["conv_w"].shape[0]
        xp = jnp.pad(xb, ((0, 0), (k - 1, 0), (0, 0)))
        conv_out = jax.lax.conv_general_dilated(
            xp.astype(jnp.float32), p["conv_w"].astype(jnp.float32)[:, None, :],
            window_strides=(1,), padding="VALID",
            dimension_numbers=("NWC", "WIO", "NWC"), feature_group_count=w)
        xc = (conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
        a_gate = xc @ p["w_a"].astype(xc.dtype)
        i_gate = xc @ p["w_i"].astype(xc.dtype)
        state_in = cache["state"] if (cache and "state" in cache) else None
        y, state = ops.rglru(xc, a_gate, i_gate, p["a_param"], state=state_in, c=c)
        new_cache = None
        if mode == "prefill":
            pad = max(0, k - 1 - s)
            tail = jnp.pad(xb, ((0, 0), (pad, 0), (0, 0)))[:, -(k - 1):]
            new_cache = {"conv": tail, "state": state}

    out = (y * gate[:, : y.shape[1]]) @ p["w_out"].astype(y.dtype)
    return out, new_cache
