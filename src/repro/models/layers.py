"""Neural building blocks: norms, rope, GQA/MLA attention, MLPs.

All blocks follow the same convention: ``init_*`` returns a Boxed pytree
(weights + logical sharding axes), ``apply_*`` consumes the plain-array
pytree.  Attention supports train (full causal), prefill (cache write) and
decode (single position vs. cache, ring-buffer for sliding window).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchConfig
from ..kernels import ops
from .common import Boxed, box, truncated_normal_init

__all__ = [
    "rms_norm", "rope", "init_attention", "apply_attention",
    "init_mla", "apply_mla", "init_mlp", "apply_mlp",
    "init_embedding",
]


def _embed_ax(cfg: ArchConfig):
    return "fsdp" if cfg.fsdp else None


def rms_norm(x, w, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(x.dtype)


def rope(x, positions, theta: float):
    """x: (B, H, S, D); positions: (S,) or (B, S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (np.arange(0, half) * 2.0 / d))
    pos = jnp.asarray(positions, jnp.float32)
    if pos.ndim == 1:
        pos = pos[None, :]
    angles = pos[:, None, :, None] * freqs[None, None, None, :]  # (B,1,S,half)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------


def init_attention(cfg: ArchConfig, key, cross: bool = False):
    m, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    if cross:
        hkv = max(1, cfg.n_kv_heads)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    e = _embed_ax(cfg)
    dt = cfg.param_dtype
    p = {
        "wq": box(truncated_normal_init(k1, (m, hq, dh), dt), (e, "heads", None)),
        "wk": box(truncated_normal_init(k2, (m, hkv, dh), dt), (e, "kv_heads", None)),
        "wv": box(truncated_normal_init(k3, (m, hkv, dh), dt), (e, "kv_heads", None)),
        "wo": box(truncated_normal_init(k4, (hq, dh, m), dt, fan_in_dims=(0, 1)),
                  ("heads", None, e)),
        "norm": box(jnp.ones((m,), dt), (None,)),
    }
    if cross:
        p["gate"] = box(jnp.zeros((), dt), ())
    return p


def batch_axes_for(mesh, bsz: int, model_dim_divisible: bool):
    """Mesh axes carrying the batch dim.  With the dp_over_model perf flag,
    blocks whose model-parallel dim does NOT divide the model axis spread
    batch over it instead of replicating (see perf.PerfFlags)."""
    from ..perf import flags
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    nb = int(np.prod([sizes[a] for a in batch_axes])) if batch_axes else 1
    mp = sizes.get("model", 1)
    if (flags().dp_over_model and not model_dim_divisible and mp > 1
            and bsz % (nb * mp) == 0):
        return batch_axes + ("model",)
    return batch_axes if (batch_axes and bsz % nb == 0) else ()


def _constrain_heads(x, mesh):
    """Pin (B, H, S, D) activations to head-sharding over the model axis.
    Without this, sequence-parallel residuals let GSPMD resolve the attention
    einsum by replicating heads across 'model' (observed: 16x activation
    blow-up on MLA at 128 heads).  Unshardable head counts fall back to
    replication, or to batch-over-model under the dp_over_model flag."""
    if mesh is None:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    heads_ok = "model" in sizes and x.shape[1] % sizes["model"] == 0
    bspec = batch_axes_for(mesh, x.shape[0], heads_ok) or None
    hspec = "model" if (heads_ok and "model" not in (bspec or ())) else None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(bspec, hspec, None, None)))


def apply_attention(cfg: ArchConfig, p, x, *, positions, mode: str,
                    cache=None, memory=None, window=None,
                    cache_slots: int | None = None, mesh=None,
                    impl: str = "auto") -> tuple[Any, Any]:
    """mode: 'train' | 'prefill' | 'decode'.  memory: cross-attn source
    (B, T, M) — cross layers cache K/V from memory at prefill.
    Returns (output (B,S,M), new_cache)."""
    b, s, m = x.shape
    hq, dh = p["wq"].shape[1], p["wq"].shape[2]
    hkv = p["wk"].shape[1]
    h = rms_norm(x, p["norm"], cfg.norm_eps)
    q = _constrain_heads(
        jnp.einsum("bsm,mhd->bhsd", h, p["wq"].astype(h.dtype)), mesh)
    cross = memory is not None

    if cross:
        if mode in ("train", "prefill") or cache is None or cache.get("k") is None:
            hm = memory.astype(h.dtype)
            k = jnp.einsum("btm,mhd->bhtd", hm, p["wk"].astype(h.dtype))
            v = jnp.einsum("btm,mhd->bhtd", hm, p["wv"].astype(h.dtype))
        else:
            k, v = cache["k"], cache["v"]
        out = ops.attention(q, k, v, causal=False, impl=impl)
        new_cache = {"k": k, "v": v} if mode != "train" else None
    else:
        k = _constrain_heads(
            jnp.einsum("bsm,mhd->bhsd", h, p["wk"].astype(h.dtype)), mesh)
        v = _constrain_heads(
            jnp.einsum("bsm,mhd->bhsd", h, p["wv"].astype(h.dtype)), mesh)
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if mode == "train":
            out = ops.attention(q, k, v, causal=True, window=window, impl=impl)
            new_cache = None
        elif mode == "prefill":
            out = ops.attention(q, k, v, causal=True, window=window, impl=impl)
            slots = cache_slots if cache_slots is not None else (
                min(window, s) if window is not None else s)
            if slots < s:
                # ring invariant: position p lives at slot p % slots
                keep_k = jnp.roll(k[:, :, -slots:], s % slots, axis=2)
                keep_v = jnp.roll(v[:, :, -slots:], s % slots, axis=2)
                kpos = jnp.roll(jnp.arange(s - slots, s), s % slots)
                kpos = jnp.broadcast_to(kpos[None, :], (b, slots)).astype(jnp.int32)
                new_cache = {"k": keep_k, "v": keep_v, "kpos": kpos}
            else:
                pad = slots - s
                kc = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
                vc = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
                kpos = jnp.concatenate(
                    [jnp.arange(s), jnp.full((pad,), 2**30)]).astype(jnp.int32)
                kpos = jnp.broadcast_to(kpos[None, :], (b, slots))
                new_cache = {"k": kc, "v": vc, "kpos": kpos}
        else:  # decode: s == 1, write into ring/linear cache
            ck, cv, kpos = cache["k"], cache["v"], cache["kpos"]
            slots = ck.shape[2]
            pos = positions.reshape(b) if hasattr(positions, "reshape") else jnp.full((b,), positions)
            slot = (pos % slots).astype(jnp.int32)
            ck = jax.vmap(lambda c, kk, sl: jax.lax.dynamic_update_slice(
                c, kk, (0, sl, 0)))(ck, k[:, :, 0:1], slot)
            cv = jax.vmap(lambda c, vv, sl: jax.lax.dynamic_update_slice(
                c, vv, (0, sl, 0)))(cv, v[:, :, 0:1], slot)
            kpos = jax.vmap(lambda kp, pp, sl: jax.lax.dynamic_update_slice(
                kp, pp[None].astype(jnp.int32), (sl,)))(kpos, pos, slot)
            mask_pos = kpos[:, None, None, :]  # (B,1,1,slots)
            qpos = pos[:, None, None, None]
            mask = mask_pos <= qpos
            if window is not None:
                mask &= mask_pos > qpos - window
            logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                                jnp.repeat(ck, hq // hkv, 1).astype(jnp.float32)) * dh**-0.5
            logits = jnp.where(mask, logits, -1e30)
            probs = jax.nn.softmax(logits, axis=-1)
            out = jnp.einsum("bhqk,bhkd->bhqd",
                             probs, jnp.repeat(cv, hq // hkv, 1).astype(jnp.float32)).astype(x.dtype)
            new_cache = {"k": ck, "v": cv, "kpos": kpos}

    y = jnp.einsum("bhsd,hdm->bsm", out, p["wo"].astype(out.dtype))
    if cross:
        y = y * jnp.tanh(p["gate"]).astype(y.dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention)
# ---------------------------------------------------------------------------


def init_mla(cfg: ArchConfig, key):
    mla = cfg.mla
    m, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    e = _embed_ax(cfg)
    dt = cfg.param_dtype
    qk = mla.qk_nope + mla.qk_rope
    return {
        "wq_a": box(truncated_normal_init(ks[0], (m, mla.q_lora), dt), (e, None)),
        "q_norm": box(jnp.ones((mla.q_lora,), dt), (None,)),
        "wq_b": box(truncated_normal_init(ks[1], (mla.q_lora, h, qk), dt),
                    (None, "heads", None)),
        "wkv_a": box(truncated_normal_init(ks[2], (m, mla.kv_lora + mla.qk_rope), dt),
                     (e, None)),
        "kv_norm": box(jnp.ones((mla.kv_lora,), dt), (None,)),
        "wkv_b": box(truncated_normal_init(
            ks[3], (mla.kv_lora, h, mla.qk_nope + mla.v_head), dt),
            (None, "heads", None)),
        "wo": box(truncated_normal_init(ks[4], (h, mla.v_head, m), dt,
                                        fan_in_dims=(0, 1)), ("heads", None, e)),
        "norm": box(jnp.ones((m,), dt), (None,)),
    }


def apply_mla(cfg: ArchConfig, p, x, *, positions, mode: str, cache=None,
              cache_slots: int | None = None, mesh=None, impl: str = "auto"):
    """MLA with the compressed-KV cache: at serve time only (c_kv, k_rope)
    per token is cached (kv_lora + qk_rope floats), the MLA memory win."""
    mla = cfg.mla
    b, s, m = x.shape
    h = cfg.n_heads
    hidden = rms_norm(x, p["norm"], cfg.norm_eps)
    q_lat = rms_norm(hidden @ p["wq_a"].astype(hidden.dtype), p["q_norm"], cfg.norm_eps)
    q = _constrain_heads(
        jnp.einsum("bsl,lhd->bhsd", q_lat, p["wq_b"].astype(hidden.dtype)), mesh)
    q_nope, q_rope = q[..., :mla.qk_nope], q[..., mla.qk_nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv_a = hidden @ p["wkv_a"].astype(hidden.dtype)  # (B,S,kv_lora+rope)
    c_kv = rms_norm(kv_a[..., :mla.kv_lora], p["kv_norm"], cfg.norm_eps)
    k_rope_new = rope(kv_a[..., None, :, mla.kv_lora:],
                      positions, cfg.rope_theta)  # (B,1,S,rope)

    if mode == "decode" and cache is not None:
        pos = positions.reshape(b)
        slot = pos.astype(jnp.int32)
        ckv = jax.vmap(lambda c, n, sl: jax.lax.dynamic_update_slice(
            c, n, (sl, 0)))(cache["ckv"], c_kv, slot)
        krope = jax.vmap(lambda c, n, sl: jax.lax.dynamic_update_slice(
            c, n, (sl, 0)))(cache["krope"], k_rope_new[:, 0], slot)
        kv_len = pos + 1
        new_cache = {"ckv": ckv, "krope": krope}
        c_use, r_use = ckv, krope[:, None]
    else:
        kv_len = None
        c_use, r_use = c_kv, k_rope_new
        new_cache = None
        if mode == "prefill":
            ckv_c, krope_c = c_kv, k_rope_new[:, 0]
            if cache_slots is not None and cache_slots > s:
                pad = cache_slots - s
                ckv_c = jnp.pad(ckv_c, ((0, 0), (0, pad), (0, 0)))
                krope_c = jnp.pad(krope_c, ((0, 0), (0, pad), (0, 0)))
            new_cache = {"ckv": ckv_c, "krope": krope_c}

    kv = _constrain_heads(
        jnp.einsum("bsl,lhd->bhsd", c_use, p["wkv_b"].astype(hidden.dtype)), mesh)
    k_nope, v = kv[..., :mla.qk_nope], kv[..., mla.qk_nope:]
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(r_use, (*k_nope.shape[:-1], mla.qk_rope))], -1)
    qfull = jnp.concatenate([q_nope, q_rope], -1)
    scale = (mla.qk_nope + mla.qk_rope) ** -0.5
    if mode == "decode":
        # causal masking is expressed purely through kv_len (all cached
        # positions < kv_len are attendable by the single new token)
        out = ops.attention(qfull, k, v, causal=False,
                            kv_len=kv_len, scale=scale, impl="jnp")
    else:
        out = ops.attention(qfull, k, v, causal=True, scale=scale, impl=impl)
    y = jnp.einsum("bhsd,hdm->bsm", out, p["wo"].astype(out.dtype))
    return y, new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg: ArchConfig, key, d_ff: int | None = None):
    m = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    e = _embed_ax(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    p = {"norm": box(jnp.ones((m,), dt), (None,))}
    if cfg.mlp_act.endswith("_glu"):
        p["w_gate"] = box(truncated_normal_init(ks[0], (m, f), dt), (e, "ff"))
        p["w_up"] = box(truncated_normal_init(ks[1], (m, f), dt), (e, "ff"))
    else:
        p["w_up"] = box(truncated_normal_init(ks[1], (m, f), dt), (e, "ff"))
    p["w_down"] = box(truncated_normal_init(ks[2], (f, m), dt), ("ff", e))
    return p


def apply_mlp(cfg: ArchConfig, p, x, *, skip_norm: bool = False):
    h = x if skip_norm else rms_norm(x, p["norm"], cfg.norm_eps)
    act = {"silu_glu": jax.nn.silu, "gelu_glu": jax.nn.gelu,
           "gelu": jax.nn.gelu}[cfg.mlp_act]
    if cfg.mlp_act.endswith("_glu"):
        hidden = act(h @ p["w_gate"].astype(h.dtype)) * (h @ p["w_up"].astype(h.dtype))
    else:
        hidden = act(h @ p["w_up"].astype(h.dtype))
    return hidden @ p["w_down"].astype(h.dtype)


def init_embedding(cfg: ArchConfig, key):
    dt = cfg.param_dtype
    e = _embed_ax(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embed": box(truncated_normal_init(k1, (cfg.vocab, cfg.d_model), dt,
                                            scale=0.02), ("vocab", e))}
    if not cfg.tie_embeddings:
        p["lm_head"] = box(truncated_normal_init(k2, (cfg.d_model, cfg.vocab), dt),
                           (e, "vocab"))
    return p
