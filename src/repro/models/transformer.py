"""The unified block-based decoder covering all 10 assigned architectures.

A model is a sequence of blocks; each block has a mixer (attention / MLA /
SSD / RG-LRU / cross-attention) and optionally an MLP or MoE.  Layers are
grouped into (prefix | scanned periodic body | suffix) so a 61-layer
DeepSeek or 100-layer VLM lowers to O(1) HLO via jax.lax.scan with
per-block remat.

Three entry points share the block machinery:
  forward(..., mode='train')    -> logits (+ aux losses)
  forward(..., mode='prefill')  -> logits + cache
  forward(..., mode='decode')   -> next-token logits + updated cache
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ArchConfig
from .common import Boxed, box, unbox, truncated_normal_init
from .layers import (apply_attention, apply_mla, apply_mlp, init_attention,
                     init_embedding, init_mla, init_mlp, rms_norm)
from .moe import apply_moe, init_moe
from .rglru import apply_rglru_block, init_rglru_block
from .ssm import apply_ssd_block, init_ssd_block

__all__ = ["layer_plan", "init_model", "forward", "model_flops"]


# ---------------------------------------------------------------------------
# Layer plan: (prefix, body period x reps, suffix)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerPlan:
    kinds: tuple[str, ...]          # per-layer block kind
    has_moe: tuple[bool, ...]       # per-layer MoE flag
    prefix: int                     # unrolled leading layers
    period: int                     # scanned super-layer length
    reps: int                       # scan length
    suffix: int                     # unrolled trailing layers


def layer_plan(cfg: ArchConfig) -> LayerPlan:
    kinds = []
    for i in range(cfg.n_layers):
        k = cfg.pattern[i % len(cfg.pattern)]
        if k == "attn" and cfg.encoder is not None:
            k = "dec_xattn"  # enc-dec decoders: self + cross + mlp
        kinds.append(k)
    moe_flags = []
    for i in range(cfg.n_layers):
        moe_flags.append(cfg.moe is not None and i >= cfg.moe.first_dense
                         and kinds[i] in ("attn", "dec_xattn", "xattn"))
    prefix = cfg.moe.first_dense if cfg.moe else 0
    period = len(cfg.pattern)
    if not cfg.scan_layers:
        return LayerPlan(tuple(kinds), tuple(moe_flags), cfg.n_layers, period, 0, 0)
    reps = (cfg.n_layers - prefix) // period
    suffix = cfg.n_layers - prefix - reps * period
    return LayerPlan(tuple(kinds), tuple(moe_flags), prefix, period, reps, suffix)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_block(cfg: ArchConfig, kind: str, use_moe: bool, key):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {}
    if kind == "attn":
        p["mixer"] = (init_mla(cfg, ks[0]) if cfg.mla is not None
                      else init_attention(cfg, ks[0]))
    elif kind == "xattn":
        p["mixer"] = init_attention(cfg, ks[0], cross=True)
    elif kind == "dec_xattn":
        p["mixer"] = init_attention(cfg, ks[0])
        p["cross"] = init_attention(cfg, ks[1], cross=True)
    elif kind == "ssd":
        p["mixer"] = init_ssd_block(cfg, ks[0])
    elif kind == "rglru":
        p["mixer"] = init_rglru_block(cfg, ks[0])
    else:
        raise ValueError(kind)
    if kind != "ssd" and cfg.d_ff + (cfg.moe.d_ff_expert if cfg.moe else 0) > 0:
        p["mlp"] = init_moe(cfg, ks[2]) if use_moe else init_mlp(cfg, ks[2])
    return p


def _stack_boxed(trees):
    """Stack a list of Boxed pytrees along a new leading (layer) axis."""
    def stack(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return Boxed(vals, (None,) + leaves[0].axes)
    return jax.tree.map(stack, *trees, is_leaf=lambda x: isinstance(x, Boxed))


def init_model(cfg: ArchConfig, key) -> dict:
    plan = layer_plan(cfg)
    k_embed, k_layers, k_extra = jax.random.split(key, 3)
    params: dict[str, Any] = init_embedding(cfg, k_embed)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)

    params["prefix"] = [
        _init_block(cfg, plan.kinds[i], plan.has_moe[i], layer_keys[i])
        for i in range(plan.prefix)]
    body = []
    for r in range(plan.reps):
        base = plan.prefix + r * plan.period
        super_layer = {
            f"pos{j}": _init_block(cfg, plan.kinds[base + j],
                                   plan.has_moe[base + j], layer_keys[base + j])
            for j in range(plan.period)}
        body.append(super_layer)
    params["body"] = _stack_boxed(body) if body else {}
    tail_base = plan.prefix + plan.reps * plan.period
    params["suffix"] = [
        _init_block(cfg, plan.kinds[tail_base + i], plan.has_moe[tail_base + i],
                    layer_keys[tail_base + i])
        for i in range(plan.suffix)]
    params["final_norm"] = box(jnp.ones((cfg.d_model,), jnp.float32), (None,))

    ke = jax.random.split(k_extra, 4)
    if cfg.encoder is not None:
        enc_cfg = cfg.replace(pattern=("attn",), moe=None, mla=None,
                              encoder=None, n_layers=cfg.encoder.n_layers)
        enc_keys = jax.random.split(ke[0], cfg.encoder.n_layers)
        enc_body = [{f"pos0": _init_block(enc_cfg, "attn", False, enc_keys[i])}
                    for i in range(cfg.encoder.n_layers)]
        params["encoder"] = {
            "body": _stack_boxed(enc_body),
            "adapter": box(truncated_normal_init(
                ke[1], (cfg.d_model, cfg.d_model), jnp.float32), (None, None)),
            "final_norm": box(jnp.ones((cfg.d_model,), jnp.float32), (None,)),
        }
    if cfg.mtp:
        params["mtp"] = {
            "proj": box(truncated_normal_init(
                ke[2], (2 * cfg.d_model, cfg.d_model), jnp.float32),
                (None, None)),
            "block": _init_block(cfg.replace(moe=None), "attn", False, ke[3]),
            "norm": box(jnp.ones((cfg.d_model,), jnp.float32), (None,)),
        }
    return params


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def _apply_block(cfg: ArchConfig, kind: str, use_moe: bool, p, h, *,
                 mode, positions, cache, memory, mesh, impl, cache_slots):
    new_cache: dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    window = cfg.window
    if kind == "attn":
        if cfg.mla is not None:
            out, c = apply_mla(cfg, p["mixer"], h, positions=positions,
                               mode=mode, cache=(cache or {}).get("mixer"),
                               cache_slots=cache_slots, mesh=mesh, impl=impl)
        else:
            out, c = apply_attention(cfg, p["mixer"], h, positions=positions,
                                     mode=mode, cache=(cache or {}).get("mixer"),
                                     window=window, cache_slots=cache_slots,
                                     mesh=mesh, impl=impl)
        h = h + out
        new_cache["mixer"] = c
    elif kind == "xattn":
        out, c = apply_attention(cfg, p["mixer"], h, positions=positions,
                                 mode=mode, cache=(cache or {}).get("mixer"),
                                 memory=memory, mesh=mesh, impl=impl)
        h = h + out
        new_cache["mixer"] = c
    elif kind == "dec_xattn":
        out, c = apply_attention(cfg, p["mixer"], h, positions=positions,
                                 mode=mode, cache=(cache or {}).get("mixer"),
                                 cache_slots=cache_slots, mesh=mesh, impl=impl)
        h = h + out
        new_cache["mixer"] = c
        out, c = apply_attention(cfg, p["cross"], h, positions=positions,
                                 mode=mode, cache=(cache or {}).get("cross"),
                                 memory=memory, mesh=mesh, impl=impl)
        h = h + out
        new_cache["cross"] = c
    elif kind == "ssd":
        out, c = apply_ssd_block(cfg, p["mixer"], h, mode=mode,
                                 cache=(cache or {}).get("mixer"), impl=impl)
        h = h + out
        new_cache["mixer"] = c
    elif kind == "rglru":
        out, c = apply_rglru_block(cfg, p["mixer"], h, mode=mode,
                                   cache=(cache or {}).get("mixer"))
        h = h + out
        new_cache["mixer"] = c
    else:
        raise ValueError(kind)

    if "mlp" in p:
        if use_moe:
            out, a = apply_moe(cfg, p["mlp"], h, mesh=mesh, impl="auto")
            aux = aux + a
        else:
            out = apply_mlp(cfg, p["mlp"], h)
        h = h + out
    if mesh is not None:
        from .layers import batch_axes_for
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        mp = sizes.get("model", 1)
        if kind == "ssd":
            div = ((cfg.ssm.expand * cfg.d_model) // cfg.ssm.head_dim) % mp == 0
        elif kind == "rglru":
            div = (cfg.rglru.lru_width or cfg.d_model) % mp == 0
        else:
            div = cfg.n_heads % mp == 0
        batch_axes = batch_axes_for(mesh, h.shape[0], div)
        if batch_axes:
            # Megatron-SP: between attention/MoE blocks the residual stream is
            # also sharded over 'model' along sequence — the remat-saved h per
            # layer shrinks by the TP degree; GSPMD inserts the all-gather /
            # reduce-scatter pair at the block entry/exit.  Sequential mixers
            # (ssd/rglru) keep a batch-only layout.
            seq_ax = None
            if (cfg.seq_shard and mode == "train" and kind in
                    ("attn", "xattn", "dec_xattn") and "model" in sizes
                    and "model" not in batch_axes
                    and h.shape[1] % sizes["model"] == 0):
                seq_ax = "model"
            h = jax.lax.with_sharding_constraint(
                h, jax.sharding.NamedSharding(mesh, P(batch_axes, seq_ax, None)))
    return h, (new_cache or None), aux


def _constrain_logits(cfg: ArchConfig, logits, mesh):
    """Vocab-parallel logits (Megatron-style): keeps the (B,S,V) fp32
    tensor sharded over the model axis; the CE runs sharded with psum'd
    logsumexp instead of materializing V per device."""
    if mesh is None:
        return logits
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    nb = int(np.prod([sizes[a] for a in batch_axes])) if batch_axes else 1
    spec_b = batch_axes if (batch_axes and logits.shape[0] % nb == 0) else None
    spec_v = "model" if ("model" in sizes
                         and logits.shape[-1] % sizes["model"] == 0) else None
    return jax.lax.with_sharding_constraint(
        logits, jax.sharding.NamedSharding(mesh, P(spec_b, None, spec_v)))


def _run_encoder(cfg: ArchConfig, params, frames, mesh, impl):
    """Bidirectional encoder over stub frame embeddings (B, Sf, M)."""
    enc_cfg = cfg.replace(pattern=("attn",), moe=None, mla=None, encoder=None,
                          n_layers=cfg.encoder.n_layers)
    h = frames @ params["adapter"].astype(frames.dtype)
    positions = jnp.arange(frames.shape[1])

    def body(h, layer_p):
        blk = layer_p["pos0"]
        hn = rms_norm(h, blk["mixer"]["norm"], enc_cfg.norm_eps)
        q = jnp.einsum("bsm,mhd->bhsd", hn, blk["mixer"]["wq"].astype(h.dtype))
        k = jnp.einsum("bsm,mhd->bhsd", hn, blk["mixer"]["wk"].astype(h.dtype))
        v = jnp.einsum("bsm,mhd->bhsd", hn, blk["mixer"]["wv"].astype(h.dtype))
        from ..kernels import ops
        o = ops.attention(q, k, v, causal=False, impl=impl)
        h = h + jnp.einsum("bhsd,hdm->bsm", o, blk["mixer"]["wo"].astype(h.dtype))
        h = h + apply_mlp(enc_cfg, blk["mlp"], h)
        return h, None

    fn = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        h, _ = jax.lax.scan(fn, h, params["body"])
    else:  # unrolled (exact AOT accounting; used by small archs + probes)
        for i in range(cfg.encoder.n_layers):
            h, _ = fn(h, jax.tree.map(lambda a: a[i], params["body"]))
    return rms_norm(h, params["final_norm"], cfg.norm_eps)


def forward(cfg: ArchConfig, params, tokens, *, mode: str = "train",
            positions=None, cache=None, memory_inputs=None,
            mesh: Mesh | None = None, impl: str = "auto",
            cache_slots: int | None = None):
    """tokens: (B, S) int32.  memory_inputs: image/frame embeddings for
    vlm/audio archs.  Returns dict(logits=..., cache=..., aux=..., mtp_logits=...).
    """
    plan = layer_plan(cfg)
    b, s = tokens.shape
    embed = params["embed"]
    h = jnp.take(embed, tokens, axis=0).astype(jnp.bfloat16)
    if positions is None:
        positions = jnp.arange(s)

    memory = None
    if cfg.encoder is not None:
        if mode == "decode" and cache is not None and "enc_memory" in cache:
            memory = cache["enc_memory"]
        else:
            memory = _run_encoder(cfg, params["encoder"],
                                  memory_inputs.astype(jnp.bfloat16), mesh, impl)
    elif cfg.vision is not None:
        if mode == "decode" and cache is not None and "enc_memory" in cache:
            memory = cache["enc_memory"]
        else:
            memory = memory_inputs.astype(jnp.bfloat16) if memory_inputs is not None else None

    aux_total = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}

    # prefix
    pc = []
    for i in range(plan.prefix):
        c_in = cache["prefix"][i] if cache else None
        h, c, a = _apply_block(cfg, plan.kinds[i], plan.has_moe[i],
                               params["prefix"][i], h, mode=mode,
                               positions=positions, cache=c_in, memory=memory,
                               mesh=mesh, impl=impl, cache_slots=cache_slots)
        aux_total += a
        pc.append(c)
    new_cache["prefix"] = pc

    # scanned body
    if plan.reps:
        def body_fn(carry, xs):
            h, aux = carry
            layer_p, c_in = xs
            cs = {}
            for j in range(plan.period):
                kind = plan.kinds[plan.prefix + j]
                moe_f = plan.has_moe[plan.prefix + j]
                h, c, a = _apply_block(cfg, kind, moe_f, layer_p[f"pos{j}"], h,
                                       mode=mode, positions=positions,
                                       cache=(c_in or {}).get(f"pos{j}"),
                                       memory=memory, mesh=mesh, impl=impl,
                                       cache_slots=cache_slots)
                aux = aux + a
                cs[f"pos{j}"] = c
            return (h, aux), cs

        fn = jax.checkpoint(body_fn) if cfg.remat else body_fn
        body_cache_in = cache["body"] if cache else None
        if body_cache_in is None:
            # build a None-structured xs: scan needs matching pytrees, so
            # pass an empty dict tree when no cache flows in
            xs = (params["body"], {f"pos{j}": None for j in range(plan.period)})
        else:
            xs = (params["body"], body_cache_in)
        (h, aux_total), body_cache_out = jax.lax.scan(fn, (h, aux_total), xs)
        new_cache["body"] = body_cache_out

    # suffix
    sc = []
    base = plan.prefix + plan.reps * plan.period
    for i in range(plan.suffix):
        c_in = cache["suffix"][i] if cache else None
        h, c, a = _apply_block(cfg, plan.kinds[base + i], plan.has_moe[base + i],
                               params["suffix"][i], h, mode=mode,
                               positions=positions, cache=c_in, memory=memory,
                               mesh=mesh, impl=impl, cache_slots=cache_slots)
        aux_total += a
        sc.append(c)
    new_cache["suffix"] = sc

    hf = rms_norm(h, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = (hf @ head.astype(hf.dtype)).astype(jnp.float32)
    logits = _constrain_logits(cfg, logits, mesh)

    out = {"logits": logits, "aux": aux_total}
    if mode in ("prefill", "decode"):
        if memory is not None:
            new_cache["enc_memory"] = memory
        out["cache"] = new_cache

    if cfg.mtp and mode == "train":
        mtp = params["mtp"]
        shifted = jnp.roll(tokens, -1, axis=1)
        emb_next = jnp.take(embed, shifted, axis=0).astype(hf.dtype)
        mtp_in = jnp.concatenate(
            [rms_norm(h, mtp["norm"], cfg.norm_eps), emb_next], axis=-1) \
            @ mtp["proj"].astype(hf.dtype)
        mtp_h, _, _ = _apply_block(cfg.replace(moe=None), "attn", False,
                                   mtp["block"], mtp_in, mode="train",
                                   positions=positions, cache=None, memory=None,
                                   mesh=mesh, impl=impl, cache_slots=None)
        mtp_hf = rms_norm(mtp_h, params["final_norm"], cfg.norm_eps)
        out["mtp_logits"] = (mtp_hf @ head.astype(mtp_hf.dtype)).astype(jnp.float32)
    return out


# ---------------------------------------------------------------------------
# Analytic FLOPs (6·N·D dense / 6·N_active·D MoE) for §Roofline
# ---------------------------------------------------------------------------


def count_params(cfg: ArchConfig, active_only: bool = False) -> int:
    """Parameter count from config algebra (no allocation)."""
    m, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    plan = layer_plan(cfg)
    total = v * m + (0 if cfg.tie_embeddings else m * v)
    for i, kind in enumerate(plan.kinds):
        if kind in ("attn", "dec_xattn"):
            if cfg.mla is not None:
                mla = cfg.mla
                qk = mla.qk_nope + mla.qk_rope
                total += (m * mla.q_lora + mla.q_lora * h * qk
                          + m * (mla.kv_lora + mla.qk_rope)
                          + mla.kv_lora * h * (mla.qk_nope + mla.v_head)
                          + h * mla.v_head * m)
            else:
                total += m * h * dh + 2 * m * hkv * dh + h * dh * m
            if kind == "dec_xattn":
                total += m * h * dh + 2 * m * hkv * dh + h * dh * m
        elif kind == "xattn":
            total += m * h * dh + 2 * m * hkv * dh + h * dh * m
        elif kind == "ssd":
            ssm = cfg.ssm
            d_inner = ssm.expand * m
            gn = ssm.n_groups * ssm.d_state
            nh = d_inner // ssm.head_dim
            total += m * (2 * d_inner + 2 * gn + nh) + d_inner * m
        elif kind == "rglru":
            w = cfg.rglru.lru_width or m
            total += 2 * m * w + 2 * w * w + w * m
        if plan.has_moe[i]:
            moe = cfg.moe
            n_e = (moe.top_k if active_only else moe.n_experts)
            total += 3 * moe.d_ff_expert * m * n_e + m * moe.n_experts
            total += 3 * moe.d_ff_expert * moe.n_shared * m
        elif kind in ("attn", "xattn", "dec_xattn") and f > 0:
            mult = 3 if cfg.mlp_act.endswith("_glu") else 2
            total += mult * m * f
        elif kind == "rglru" and f > 0:
            mult = 3 if cfg.mlp_act.endswith("_glu") else 2
            total += mult * m * f
    if cfg.encoder is not None:
        mult = 3 if cfg.mlp_act.endswith("_glu") else 2
        total += cfg.encoder.n_layers * (m * h * dh + 2 * m * hkv * dh
                                         + h * dh * m + mult * m * f)
    return int(total)


def model_flops(cfg: ArchConfig, tokens: int, mode: str = "train") -> float:
    """MODEL_FLOPS = 6·N_active·D for train, 2·N_active·D for inference."""
    n_active = count_params(cfg, active_only=True)
    mult = 6.0 if mode == "train" else 2.0
    return mult * n_active * tokens
