"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf].

61L d_model=7168 128H (MLA) d_ff_expert=2048 vocab=129280, MoE 256e top-8,
first 3 layers dense (d_ff=18432), MLA q_lora=1536 kv_lora=512.
"""

from .base import ArchConfig, MLAConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=18432,  # dense layers (first_dense) use this
    vocab=129280,
    head_dim=128,
    mlp_act="silu_glu",
    moe=MoEConfig(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                  first_dense=3),
    mla=MLAConfig(q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128),
    mtp=True,
    fsdp=True,
    seq_shard=True,
    bf16_params=True,
)
