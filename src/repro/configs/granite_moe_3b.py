"""granite-moe-3b-a800m [moe] — 40 experts top-8
[hf:ibm-granite/granite-3.0-3b-a800m lineage; spec'd as 40e top-8].

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155.
"""

from .base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    mlp_act="silu_glu",
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
    fsdp=True,
    seq_shard=True,
)
