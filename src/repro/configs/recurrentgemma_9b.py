"""recurrentgemma-9b [hybrid] — RG-LRU + local attn, 1:2 [arXiv:2402.19427].

38L d_model=4096 16H (GQA kv=1 => MQA local attention, window 2048)
d_ff=12288 vocab=256000; block pattern (rec, rec, attn).
"""

from .base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    head_dim=256,
    mlp_act="gelu_glu",
    window=2048,
    pattern=("rglru", "rglru", "attn"),
    rglru=RGLRUConfig(lru_width=None, d_conv=4, c=8.0),
    fsdp=True,
    seq_shard=True,
    sub_quadratic=True,
)
