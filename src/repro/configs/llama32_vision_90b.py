"""llama-3.2-vision-90b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision lineage, scaled per assignment].

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; every 5th layer
is gated cross-attention to precomputed patch embeddings (frontend STUB:
``input_specs`` supplies (batch, 1600, d_model) image features).
"""

from .base import ArchConfig, VisionConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    mlp_act="silu_glu",
    rope_theta=500_000.0,
    pattern=("attn", "attn", "attn", "attn", "xattn"),
    vision=VisionConfig(n_image_tokens=1600, cross_every=5),
    fsdp=True,
    seq_shard=True,
    bf16_params=True,
)
