"""granite-20b [dense] — llama-arch, code [arXiv:2405.04324; hf].

52L d_model=6144 48H (GQA kv=1 => MQA) d_ff=24576 vocab=49152.
gpt-bigcode lineage: plain GELU MLP rather than SwiGLU.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab=49152,
    mlp_act="gelu",
    fsdp=True,
    seq_shard=True,
)
