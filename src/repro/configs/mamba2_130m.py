"""mamba2-130m [ssm] — SSD (state-space duality) [arXiv:2405.21060].

24L d_model=768, attn-free (d_ff=0), vocab=50280, ssm_state=128.
d_inner = 2*768 = 1536, head_dim 64 => 24 SSD heads, chunk 256.
"""

from .base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=24,          # d_inner / head_dim
    n_kv_heads=24,
    d_ff=0,              # attn-free, no MLP block (Mamba-2 block only)
    vocab=50280,
    pattern=("ssd",),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk=256),
    tie_embeddings=True,
    fsdp=False,
    sub_quadratic=True,
)
