"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA [arXiv:2401.16818].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, sliding window 4096.
SWA makes decode memory O(window), so long_500k is runnable.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab=32000,
    mlp_act="silu_glu",
    window=4096,
    fsdp=True,
    seq_shard=True,
    sub_quadratic=True,
)
