"""smollm-135m [dense] — llama-arch small [hf:HuggingFaceTB/SmolLM-135M; hf].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
"""

from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    mlp_act="silu_glu",
    tie_embeddings=True,
    fsdp=False,  # 135M: pure DP replication is optimal
)
