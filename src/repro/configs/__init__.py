"""Architecture registry: --arch <id> -> ArchConfig."""

from .base import SHAPES, ArchConfig, ShapeConfig
from .codeqwen15_7b import CONFIG as codeqwen15_7b
from .deepseek_v3_671b import CONFIG as deepseek_v3_671b
from .granite_20b import CONFIG as granite_20b
from .granite_moe_3b import CONFIG as granite_moe_3b
from .h2o_danube3_4b import CONFIG as h2o_danube3_4b
from .llama32_vision_90b import CONFIG as llama32_vision_90b
from .mamba2_130m import CONFIG as mamba2_130m
from .recurrentgemma_9b import CONFIG as recurrentgemma_9b
from .seamless_m4t_v2 import CONFIG as seamless_m4t_v2
from .smollm_135m import CONFIG as smollm_135m

ARCHS: dict[str, ArchConfig] = {
    c.name: c
    for c in [
        mamba2_130m,
        deepseek_v3_671b,
        granite_moe_3b,
        codeqwen15_7b,
        granite_20b,
        h2o_danube3_4b,
        smollm_135m,
        recurrentgemma_9b,
        llama32_vision_90b,
        seamless_m4t_v2,
    ]
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {sorted(ARCHS)}")
    return ARCHS[name]


__all__ = ["ARCHS", "SHAPES", "ArchConfig", "ShapeConfig", "get_arch"]
