"""seamless-m4t-large-v2 [audio] — enc-dec, multimodal [arXiv:2308.11596; hf].

24L d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.  Transformer backbone
only: 24L speech encoder over STUB frame embeddings (precomputed
(batch, seq/4, d_model) features) + 24L text decoder with cross-attention.
"""

from .base import ArchConfig, EncoderConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,  # decoder layers; encoder has its own 24 (EncoderConfig)
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    mlp_act="gelu",
    encoder=EncoderConfig(n_layers=24, frontend="stub", frame_ratio=4),
    fsdp=False,  # 2.3B total: DP+TP suffices
    # unrolled layers: exact AOT cost accounting for the enc+dec stacks
    # (cheap at d_model=1024; scanned archs use the probe correction instead)
    scan_layers=False,
)
