"""Architecture configuration schema shared by all 10 assigned archs.

Every field is plain data so configs hash/compare cleanly and can be used
as static jit arguments.  ``reduced()`` produces the CPU-smoke variant of
the same family (small widths, few layers/experts, tiny vocab).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0          # shared (always-on) experts
    first_dense: int = 0       # leading dense layers (deepseek: 3)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001


@dataclass(frozen=True)
class MLAConfig:
    q_lora: int = 1536
    kv_lora: int = 512
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int | None = None   # default d_model
    d_conv: int = 4
    c: float = 8.0                 # the RG-LRU gate constant


@dataclass(frozen=True)
class EncoderConfig:
    n_layers: int = 24
    frontend: str = "stub"         # precomputed frame/patch embeddings
    frame_ratio: int = 4           # encoder frames = seq_len // frame_ratio


@dataclass(frozen=True)
class VisionConfig:
    n_image_tokens: int = 1600     # stub: precomputed patch embeddings
    cross_every: int = 5           # every 5th layer is cross-attention


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None    # default d_model // n_heads
    mlp_act: str = "silu_glu"      # silu_glu | gelu
    window: int | None = None      # sliding-window attention size
    pattern: tuple[str, ...] = ("attn",)  # per-layer mixer kinds, cycled
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    rglru: RGLRUConfig | None = None
    encoder: EncoderConfig | None = None
    vision: VisionConfig | None = None
    mtp: bool = False              # multi-token-prediction extra head
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # distribution knobs
    fsdp: bool = False             # ZeRO-3 weight sharding over the data axis
    seq_shard: bool = False        # Megatron-SP: inter-block h sharded over model
    bf16_params: bool = False      # bf16 weights + bf16 AdamW moments (671B-scale)
    remat: bool = True
    scan_layers: bool = True
    sub_quadratic: bool = False    # eligible for long_500k

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def param_dtype(self):
        import jax.numpy as jnp
        return jnp.bfloat16 if self.bf16_params else jnp.float32

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """CPU-smoke variant of the same family."""
        kw: dict = dict(
            n_layers=min(self.n_layers, len(self.pattern) * 2),
            d_model=128, n_heads=4, d_ff=256, vocab=512,
            n_kv_heads=min(self.n_kv_heads, 2), head_dim=32,
            fsdp=False, window=min(self.window, 64) if self.window else None,
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, n_experts=8, top_k=2, d_ff_expert=64,
                first_dense=min(self.moe.first_dense, 1))
            kw["n_layers"] = 2 + kw["moe"].first_dense
        if self.mla:
            kw["mla"] = MLAConfig(q_lora=64, kv_lora=32, qk_nope=16, qk_rope=16, v_head=16)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16, chunk=32)
        if self.rglru:
            kw["rglru"] = dataclasses.replace(self.rglru, lru_width=None)
            kw["n_layers"] = 3
        if self.encoder:
            kw["encoder"] = dataclasses.replace(self.encoder, n_layers=2)
        if self.vision:
            kw["vision"] = dataclasses.replace(self.vision, n_image_tokens=16, cross_every=2)
            kw["n_layers"] = 4
        return self.replace(**kw)


# Shape grid shared by all LM archs (the assignment's 4 shapes).
@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
