"""Pure-jnp oracles for every kernel: the correctness ground truth.

These are deliberately naive (fp32, O(S^2) attention, O(L) sequential SSD
recurrence) — tests sweep shapes/dtypes and assert the Pallas kernels (in
interpret mode) and the production jnp paths match these.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["attention_ref", "ssd_ref", "rglru_ref"]


def attention_ref(q, k, v, *, causal: bool = True, window: int | None = None,
                  q_offset: int = 0, kv_len=None, scale: float | None = None):
    """Masked multi-head attention, GQA-aware.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
    Query position i sits at global index q_offset + i; key j at j.
    Masks: causal (global_q >= k), sliding window (k > global_q - window),
    kv_len (k < kv_len, for padded decode caches).
    """
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else d**-0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if group > 1:
        kf = jnp.repeat(kf, group, axis=1)
        vf = jnp.repeat(vf, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf)
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    mask = jnp.broadcast_to(mask, (b, hq, sq, skv))
    if kv_len is not None:
        kl = jnp.asarray(kv_len).reshape(b, 1, 1, 1)
        mask &= kpos[None, None] < kl
    logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (can happen with kv_len=0) -> zeros, not NaN
    probs = jnp.where(mask.any(-1, keepdims=True), probs, 0.0)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vf)
    return out.astype(q.dtype)


def ssd_ref(x, dt, a_log, b_mat, c_mat, d_skip, *, state=None):
    """Mamba-2 SSD, exact sequential recurrence (the oracle).

    x: (B, L, H, P)   inputs per head
    dt: (B, L, H)     softplus-activated step sizes (already positive)
    a_log: (H,)       log(-A) per head (A = -exp(a_log) < 0)
    b_mat, c_mat: (B, L, G, N) with H % G == 0
    d_skip: (H,)      skip connection
    state: optional (B, H, N, P) initial state
    Returns (y (B, L, H, P), final_state (B, H, N, P)).
    """
    bsz, length, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = jnp.repeat(b_mat.astype(jnp.float32), rep, axis=2)  # (B, L, H, N)
    cf = jnp.repeat(c_mat.astype(jnp.float32), rep, axis=2)
    if state is None:
        state = jnp.zeros((bsz, h, n, p), jnp.float32)

    def step(s, inp):
        xt, dtt, bt, ct = inp  # (B,H,P), (B,H), (B,H,N), (B,H,N)
        decay = jnp.exp(dtt * a[None, :])  # (B,H)
        s = s * decay[..., None, None] + jnp.einsum(
            "bhn,bhp->bhnp", bt, xt * dtt[..., None])
        y = jnp.einsum("bhn,bhnp->bhp", ct, s)
        return s, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    final, ys = jax.lax.scan(step, state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1) + xf * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final


def rglru_ref(x, a_gate, i_gate, a_param, *, state=None, c: float = 8.0):
    """RG-LRU (RecurrentGemma), exact sequential recurrence.

    x, a_gate, i_gate: (B, L, D) — pre-computed gate pre-activations.
    a_param: (D,) — the learnable Λ; log_a = -c * softplus(Λ) * sigmoid(a_gate).
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (sigmoid(i_t) ⊙ x_t)
    Returns (y (B, L, D), final state (B, D)).
    """
    bsz, length, d = x.shape
    xf = x.astype(jnp.float32)
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32))[None, None, :] \
        * jax.nn.sigmoid(a_gate.astype(jnp.float32))
    a = jnp.exp(log_a)
    gated_x = xf * jax.nn.sigmoid(i_gate.astype(jnp.float32))
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    if state is None:
        state = jnp.zeros((bsz, d), jnp.float32)

    def step(h, inp):
        at, bt = inp
        h = at * h + bt
        return h, h

    xs = (jnp.moveaxis(a, 1, 0), jnp.moveaxis(mult * gated_x, 1, 0))
    final, hs = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype), final
