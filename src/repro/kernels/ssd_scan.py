"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Grid (batch, heads, chunks) with the chunk dimension innermost
('arbitrary'), carrying the (N, P) fp32 state in VMEM scratch across
chunks.  Each chunk does three MXU matmuls:

    scores = (C B^T) ⊙ exp(segsum)         (Q, Q)
    y      = scores @ (x·dt) + (C @ S_in) ⊙ exp(cum)    (Q, P)
    S_out  = exp(cum[-1]) S_in + B^T @ (exp(cum[-1]-cum) ⊙ x·dt)

Cumulative sums are computed as a lower-triangular matmul so everything
maps to the MXU (no serial scan inside the kernel).

Validated in interpret mode against kernels.ref.ssd_ref; TPU is the target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

__all__ = ["ssd_scan"]


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, dskip_ref, y_ref,
            state_ref, *, chunk, n_chunks):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (Q,)
    bmat = b_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    cmat = c_ref[0, :, 0, :].astype(jnp.float32)     # (Q, N)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))    # scalar, negative
    d_skip = dskip_ref[0].astype(jnp.float32)

    da = dt * a                                       # (Q,)
    # inclusive cumsum via lower-triangular ones matmul (MXU-friendly)
    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    cum = jax.lax.dot_general(tril, da[:, None], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[:, 0]  # (Q,)

    seg = cum[:, None] - cum[None, :]                 # cum_i - cum_j
    causal = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    # mask before exp: seg > 0 above the diagonal would overflow to inf
    decay = jnp.exp(jnp.where(causal, seg, -jnp.inf))
    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * decay
    xdt = x * dt[:, None]                             # (Q, P)
    y = jax.lax.dot_general(scores, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    s_in = state_ref[...]                             # (N, P)
    y += jax.lax.dot_general(cmat, s_in, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]
    y += x * d_skip
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    w = jnp.exp(cum[-1] - cum)[:, None]               # (Q, 1)
    state_ref[...] = jnp.exp(cum[-1]) * s_in + jax.lax.dot_general(
        bmat, xdt * w, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a_log, b_mat, c_mat, d_skip, *, chunk: int = 128,
             interpret: bool = False):
    """x: (B, L, H, P); dt: (B, L, H); a_log, d_skip: (H,);
    b_mat, c_mat: (B, L, G, N).  Returns y: (B, L, H, P)."""
    bsz, length, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    assert h % g == 0
    rep = h // g
    chunk = min(chunk, length)
    assert length % chunk == 0, (length, chunk)
    n_chunks = length // chunk

    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    grid = (bsz, h, n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
            pl.BlockSpec((1, chunk, 1), lambda ib, ih, ic: (ib, ic, ih)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda ib, ih, ic, r=rep: (ib, ic, ih // r, 0)),
            pl.BlockSpec((1, chunk, 1, n),
                         lambda ib, ih, ic, r=rep: (ib, ic, ih // r, 0)),
            pl.BlockSpec((1,), lambda ib, ih, ic: (ih,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, 1, p), lambda ib, ih, ic: (ib, ic, ih, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, a_log, b_mat, c_mat, d_skip)
