"""Pallas TPU kernels for the compute hot-spots of the assigned
architectures (flash attention, Mamba-2 SSD scan) + jit'd wrappers (ops)
+ pure-jnp oracles (ref).

The model-substrate kernels (flash attention, SSD scan) serve the
frameworks trained/served on the projective fabrics; sim_step and
mask_gemm are the topology side's own hot spots — the flow-level
simulator's fused sparse-destination step (repro.sim
``backend="pallas"``) and the batched-Brandes mask+GEMM level
recurrences (repro.core.utilization ``engine="pallas"``).
"""

from . import ops, ref
from .flash_attention import flash_attention
from .mask_gemm import backward_step, frontier_step
from .sim_step import DEST_TILE, fused_step_update
from .ssd_scan import ssd_scan

__all__ = ["ops", "ref", "flash_attention", "ssd_scan",
           "fused_step_update", "DEST_TILE", "frontier_step",
           "backward_step"]
