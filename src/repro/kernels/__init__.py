"""Pallas TPU kernels for the compute hot-spots of the assigned
architectures (flash attention, Mamba-2 SSD scan) + jit'd wrappers (ops)
+ pure-jnp oracles (ref).

The paper itself is a network-topology contribution with no kernel-level
component; these kernels serve the model substrate the framework trains/
serves on the projective fabrics.
"""

from . import ops, ref
from .flash_attention import flash_attention
from .ssd_scan import ssd_scan

__all__ = ["ops", "ref", "flash_attention", "ssd_scan"]
