"""Jit'd production wrappers for the kernel layer.

Each op has (a) the Pallas TPU kernel (the deploy target; validated in
interpret mode on CPU), and (b) a memory-efficient pure-jnp path with the
same blocked structure, used for CPU smoke tests AND for the multi-pod AOT
dry-run (the CPU backend cannot lower Mosaic kernels; the jnp path has the
same matmul/bytes structure so the roofline terms are representative).

``impl='auto'`` picks pallas on TPU backends, jnp elsewhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .flash_attention import flash_attention
from .ssd_scan import ssd_scan

__all__ = ["attention", "ssd", "ssd_decode_step", "rglru", "rglru_decode_step",
           "default_impl"]


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _attention_jnp_blocked(q, k, v, *, causal, window, q_offset, kv_len,
                           scale, block_q):
    """Flash-structured jnp attention: scan over query blocks, full-KV
    online softmax per block — O(block_q · Skv) live logits."""
    from ..perf import flags
    pf = flags()
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = scale if scale is not None else d**-0.5
    block_q = max(1, min(block_q, sq))
    if sq % block_q:
        block_q = 1  # odd sizes: degrade gracefully (smoke tests)
    n_blocks = sq // block_q
    grouped = pf.gqa_grouped and group > 1
    # perf: bf16 K/V operands with fp32 MXU accumulation halve the streamed
    # bytes; the paper-faithful baseline upcasts to fp32 first
    kv_dtype = k.dtype if (pf.prob_bf16 and k.dtype == jnp.bfloat16) \
        else jnp.float32
    kf = k.astype(kv_dtype)
    vf = v.astype(kv_dtype)
    if group > 1 and not grouped:
        kf = jnp.repeat(kf, group, axis=1)
        vf = jnp.repeat(vf, group, axis=1)
    kpos = jnp.arange(skv)[None, :]
    if kv_len is not None:
        kl = jnp.asarray(kv_len).reshape(b, 1, 1, 1, *(
            (1,) if grouped else ()))

    qb = q.reshape(b, hq, n_blocks, block_q, d).astype(jnp.float32) * scale

    def one_block(i, qblk):  # qblk: (B, H, block_q, d)
        if grouped:  # (B, Hkv, G, blk, d) x (B, Hkv, Skv, d): K/V unrepeated
            qg = qblk.reshape(b, hkv, group, block_q, d)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(kv_dtype), kf,
                           preferred_element_type=jnp.float32)
        else:
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk.astype(kv_dtype), kf,
                           preferred_element_type=jnp.float32)
        qpos = q_offset + i * block_q + jnp.arange(block_q)[:, None]
        mask = jnp.ones((block_q, skv), dtype=bool)
        if causal:
            mask &= qpos >= kpos
        if window is not None:
            mask &= kpos > qpos - window
        mask = jnp.broadcast_to(mask, s.shape)
        if kv_len is not None:
            mask &= (kpos[None, None] < kl) if not grouped else \
                (kpos[None, None, None] < kl)
        s = jnp.where(mask, s, -jnp.inf)
        m = s.max(axis=-1, keepdims=True)
        p = jnp.exp(s - jnp.maximum(m, -1e30))
        p = jnp.where(mask, p, 0.0)
        l = p.sum(axis=-1, keepdims=True)
        pc = p.astype(kv_dtype) if pf.prob_bf16 else p
        if grouped:
            o = jnp.einsum("bhgqk,bhkd->bhgqd", pc, vf,
                           preferred_element_type=jnp.float32)
            o = o.reshape(b, hq, block_q, vf.shape[-1])
            l = l.reshape(b, hq, block_q, 1)
        else:
            o = jnp.einsum("bhqk,bhkd->bhqd", pc, vf,
                           preferred_element_type=jnp.float32)
        o = o / jnp.where(l == 0, 1.0, l)
        return o

    # checkpoint each block: the vjp recomputes its (block_q, Skv) logits
    # instead of saving them — flash-attention memory behaviour in pure jnp.
    # unroll=True: no while op, so AOT cost_analysis counts every block
    # (scan bodies are otherwise counted once — see EXPERIMENTS.md §Dry-run).
    one_block_ckpt = jax.checkpoint(one_block)
    _, out = jax.lax.scan(
        lambda _, args: ((), one_block_ckpt(*args)), (),
        (jnp.arange(n_blocks), jnp.moveaxis(qb, 2, 0)), unroll=True)
    dv = vf.shape[-1]  # may differ from d (MLA: v_head != qk dim)
    out = jnp.moveaxis(out, 0, 2).reshape(b, hq, sq, dv)
    return out.astype(q.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "scale", "impl", "block_q", "block_k"))
def attention(q, k, v, *, causal: bool = True, window: int | None = None,
              q_offset: int = 0, kv_len=None, scale: float | None = None,
              impl: str = "auto", block_q: int = 1024, block_k: int = 512):
    """Multi-head GQA attention; see kernels.ref.attention_ref for semantics."""
    if impl == "auto":
        impl = default_impl()
    if impl == "pallas" and kv_len is None:
        bq = min(block_q, q.shape[2])
        bk = min(block_k, k.shape[2])
        if q.shape[2] % bq == 0 and k.shape[2] % bk == 0 and q.shape[3] >= 8:
            return flash_attention(q, k, v, causal=causal, window=window,
                                   q_offset=q_offset, scale=scale,
                                   block_q=bq, block_k=bk)
    if impl == "pallas_interpret" and kv_len is None:
        return flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, scale=scale,
                               block_q=min(block_q, q.shape[2]),
                               block_k=min(block_k, k.shape[2]), interpret=True)
    return _attention_jnp_blocked(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset, kv_len=kv_len,
                                  scale=scale, block_q=block_q)


# ---------------------------------------------------------------------------
# Mamba-2 SSD
# ---------------------------------------------------------------------------


def _ssd_jnp_chunked(x, dt, a_log, b_mat, c_mat, d_skip, *, chunk, state):
    """Chunked SSD: lax.scan over chunks carrying the (B,H,N,P) state, with
    each chunk's O(Q^2) intra work checkpointed — one chunk's score matrix
    live at a time (the jnp mirror of the Pallas kernel's VMEM behaviour)."""
    bsz, length, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    chunk = min(chunk, length)
    if length % chunk:
        chunk = length
    nc = length // chunk
    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,)
    xf = x.astype(jnp.float32).reshape(bsz, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bsz, nc, chunk, h)
    bf = b_mat.astype(jnp.float32).reshape(bsz, nc, chunk, g, n)
    cf = c_mat.astype(jnp.float32).reshape(bsz, nc, chunk, g, n)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    if state is None:
        state = jnp.zeros((bsz, h, n, p), jnp.float32)

    def per_chunk(s_in, inp):
        xc, dtc, bc, cc = inp  # (B,Q,H,P), (B,Q,H), (B,Q,G,N), (B,Q,G,N)
        bh = jnp.repeat(bc, rep, axis=2)  # (B,Q,H,N)
        ch = jnp.repeat(cc, rep, axis=2)
        da = dtc * a[None, None, :]                       # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)
        seg = cum[:, :, None, :] - cum[:, None, :, :]     # (B,Qi,Qj,H)
        # mask BEFORE exp: in the non-causal region seg > 0 and exp(seg) can
        # overflow to inf, which the where() hides in the forward pass but
        # turns into 0*inf = NaN in its VJP.  exp(-inf) = 0 is safe both ways.
        seg = jnp.where(causal[None, :, :, None], seg, -jnp.inf)
        decay = jnp.exp(seg)
        xdt = xc * dtc[..., None]
        scores = jnp.einsum("bihn,bjhn->bijh", ch, bh) * decay
        y = jnp.einsum("bijh,bjhp->bihp", scores, xdt)
        y += jnp.einsum("bihn,bhnp->bihp", ch, s_in) * jnp.exp(cum)[..., None]
        w = jnp.exp(cum[:, -1:, :] - cum)                 # (B,Q,H)
        s_out = s_in * jnp.exp(cum[:, -1, :])[..., None, None] \
            + jnp.einsum("bjhn,bjhp->bhnp", bh, xdt * w[..., None])
        return s_out, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0))
    # unroll=True: exact AOT flop accounting (no while op), one chunk's
    # scores live at a time thanks to the checkpoint
    final, ys = jax.lax.scan(jax.checkpoint(per_chunk), state, xs, unroll=True)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, length, h, p) \
        + x.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype), final


@functools.partial(jax.jit, static_argnames=("chunk", "impl"))
def ssd(x, dt, a_log, b_mat, c_mat, d_skip, *, chunk: int = 256,
        impl: str = "auto", state=None):
    """Mamba-2 SSD over a full sequence. Returns (y, final_state)."""
    if impl == "auto":
        impl = default_impl()
    if impl == "pallas" and state is None and x.shape[1] % min(chunk, x.shape[1]) == 0:
        y = ssd_scan(x, dt, a_log, b_mat, c_mat, d_skip, chunk=chunk)
        # final state not produced by the kernel path; recompute cheaply when
        # needed (prefill uses the jnp path to also return state)
        _, final = _ssd_jnp_chunked(x, dt, a_log, b_mat, c_mat, d_skip,
                                    chunk=chunk, state=state)
        return y, final
    if impl == "pallas_interpret" and state is None:
        y = ssd_scan(x, dt, a_log, b_mat, c_mat, d_skip,
                     chunk=min(chunk, x.shape[1]), interpret=True)
        _, final = _ssd_jnp_chunked(x, dt, a_log, b_mat, c_mat, d_skip,
                                    chunk=chunk, state=state)
        return y, final
    return _ssd_jnp_chunked(x, dt, a_log, b_mat, c_mat, d_skip, chunk=chunk,
                            state=state)


@jax.jit
def ssd_decode_step(state, x_t, dt_t, a_log, b_t, c_t, d_skip):
    """One-token SSD update. state: (B,H,N,P); x_t: (B,H,P); dt_t: (B,H);
    b_t, c_t: (B,G,N).  Returns (y_t (B,H,P), new_state)."""
    bsz, h, n, p = state.shape
    g = b_t.shape[1]
    rep = h // g
    a = -jnp.exp(a_log.astype(jnp.float32))
    bt = jnp.repeat(b_t.astype(jnp.float32), rep, axis=1)
    ct = jnp.repeat(c_t.astype(jnp.float32), rep, axis=1)
    decay = jnp.exp(dt_t.astype(jnp.float32) * a[None, :])
    xdt = x_t.astype(jnp.float32) * dt_t.astype(jnp.float32)[..., None]
    new_state = state * decay[..., None, None] + jnp.einsum("bhn,bhp->bhnp", bt, xdt)
    y = jnp.einsum("bhn,bhnp->bhp", ct, new_state) \
        + x_t.astype(jnp.float32) * d_skip.astype(jnp.float32)[None, :, None]
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("c",))
def rglru(x, a_gate, i_gate, a_param, *, state=None, c: float = 8.0):
    """RG-LRU over a sequence via associative scan. Returns (y, final_state)."""
    bsz, length, d = x.shape
    xf = x.astype(jnp.float32)
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32))[None, None, :] \
        * jax.nn.sigmoid(a_gate.astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * xf * jax.nn.sigmoid(i_gate.astype(jnp.float32))
    if state is not None:
        # fold the carry-in as a virtual step 0
        a = jnp.concatenate([jnp.zeros_like(a[:, :1]), a], axis=1)
        b = jnp.concatenate([state[:, None, :].astype(jnp.float32), b], axis=1)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    if state is not None:
        h = h[:, 1:]
    return h.astype(x.dtype), h[:, -1].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("c",))
def rglru_decode_step(state, x_t, a_gate_t, i_gate_t, a_param, *, c: float = 8.0):
    """One-token RG-LRU update. state, x_t, gates: (B, D)."""
    xf = x_t.astype(jnp.float32)
    log_a = -c * jax.nn.softplus(a_param.astype(jnp.float32))[None, :] \
        * jax.nn.sigmoid(a_gate_t.astype(jnp.float32))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    h = a * state + mult * xf * jax.nn.sigmoid(i_gate_t.astype(jnp.float32))
    return h.astype(x_t.dtype), h
