"""Fused simulator-step kernel: forward share, credit throttle, and
ECMP enqueue of one virtual channel in a single pass over blocked
``(router, out-slot, dest-tile)`` state.

The flow-level simulator (repro.sim) spends its step almost entirely in
one contraction per VC: apply the proportional forward share and the
credit damping to every queue cell, eject the delivered diagonal, and
enqueue the decided inflow through the equal-split minimal table —
four sweeps over the ``(N, K, M)`` queue tensor when written naively.
This kernel fuses them into one read and one write per populated
``(router-block, dest-tile)`` block:

    q_out = q * fac[r, k]                     # forward + credit retention
          - q * corr[r, k] * deliver[r, k, d]  # ejected fluid keeps no credit
          + inflow[r, d] * split[r, k, d]      # per-hop ECMP enqueue

with ``fac = 1 - share * damp`` and ``corr = share * (1 - damp)`` folded
host-side (both are O(N·K)).  The second output accumulates the
post-step per-slot occupancy ``o_out[r, k] = sum_d q_out`` across dest
tiles (flash-attention-style revisiting of the output block along the
innermost grid axis), which the next step's share computation consumes.

The dest axis is *blocked-sparse*: ``tile_mask`` (one int32 per dest
tile, scalar-prefetched) marks the populated tiles; unpopulated tiles —
zero fluid and zero inflow, so the contraction is identically zero —
are skipped under ``pl.when`` and only pay the (clipped) output write.
This is the kernel seam behind ``SimConfig(backend="pallas")``; the
numpy float64 engine remains the parity oracle and
``backend="pallas_interpret"`` runs this exact kernel through the
pallas interpreter on CPU (tests/test_sim_kernel.py).

Block structure and the compiler-params compat shim follow
flash_attention.py / ssd_scan.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_step_update", "fused_decision", "DEST_TILE"]

# dest-tile width: the TPU lane dimension; also the block the numpy
# fused path (repro.sim.kernel) uses so both backends skip identical
# (router, dest-tile) blocks
DEST_TILE = 128


def _kernel(mask_ref, q_ref, split_ref, deliver_ref, fac_ref, corr_ref,
            inflow_ref, qout_ref, oout_ref, *, m):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        oout_ref[...] = jnp.zeros_like(oout_ref)

    @pl.when(mask_ref[j] != 0)
    def _compute():
        q = q_ref[...]
        upd = q * fac_ref[...][:, :, None]
        upd -= q * corr_ref[...][:, :, None] * deliver_ref[...]
        upd += inflow_ref[...][:, None, :] * split_ref[...]
        qout_ref[...] = upd
        # a partial last tile is block-padded with undefined values (the
        # write-back is clipped, but the occupancy sum must exclude them)
        bd = q_ref.shape[-1]
        col = j * bd + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bd), 2)
        oout_ref[...] += jnp.where(col < m, upd, 0.0).sum(axis=-1)

    @pl.when(mask_ref[j] == 0)
    def _skip():
        # unpopulated tile: no fluid, no inflow -> the block stays zero
        qout_ref[...] = jnp.zeros_like(qout_ref)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d",
                                             "interpret"))
def fused_step_update(q, split, deliver, fac, corr, inflow, tile_mask,
                      block_n: int = 128, block_d: int = DEST_TILE,
                      interpret: bool = False):
    """One VC's fused forward/throttle/enqueue update.

    Args:
      q:         (N, K, M) queue tensor (float32/float64).
      split:     (N, K, M) equal-split minimal table.
      deliver:   (N, K, M) delivery mask (head == dest), same dtype as q.
      fac:       (N, K)    ``1 - share * damp`` retention factor.
      corr:      (N, K)    ``share * (1 - damp)`` delivery correction.
      inflow:    (N, M)    decided vc inflow to enqueue.
      tile_mask: (ceil(M / block_d),) int32, nonzero = populated tile.

    Returns ``(q_out, o_out)``: the updated queues and the per-slot
    post-step occupancy ``q_out.sum(-1)``.
    """
    n, k, m = q.shape
    bn = min(block_n, n)
    bd = min(block_d, m)
    grid = (pl.cdiv(n, bn), pl.cdiv(m, bd))

    qkd = pl.BlockSpec((bn, k, bd), lambda i, j, mask: (i, 0, j))
    nk = pl.BlockSpec((bn, k), lambda i, j, mask: (i, 0))
    nd = pl.BlockSpec((bn, bd), lambda i, j, mask: (i, j))

    kwargs = {}
    if not interpret:
        from ._compat import CompilerParams
        kwargs["compiler_params"] = CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        functools.partial(_kernel, m=m),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[qkd, qkd, qkd, nk, nk, nd],
            out_specs=[qkd, nk],
        ),
        out_shape=[jax.ShapeDtypeStruct((n, k, m), q.dtype),
                   jax.ShapeDtypeStruct((n, k), q.dtype)],
        interpret=interpret,
        **kwargs,
    )(jnp.asarray(tile_mask, jnp.int32), q, split, deliver, fac, corr,
      inflow)


def _decision_kernel(mask_ref, b0_ref, split_ref, dist_ref, hval_ref,
                     cand_ref, qval_ref, out_ref, *, thr):
    j = pl.program_id(1)

    @pl.when(mask_ref[j] != 0)
    def _compute():
        # ECMP-split-weighted vc0 backlog toward each dest in the tile —
        # the q_min contraction of the per-hop UGAL rule, evaluated only
        # where candidate fluid exists
        q_min = (b0_ref[...][:, :, None] * split_ref[...]).sum(axis=1)
        divert = dist_ref[...] * q_min > thr + hval_ref[...] * qval_ref[...]
        out_ref[...] = jnp.where(divert, cand_ref[...], 0.0)

    @pl.when(mask_ref[j] == 0)
    def _skip():
        # no candidate fluid in the tile: nothing can divert
        out_ref[...] = jnp.zeros_like(out_ref)


@functools.partial(jax.jit, static_argnames=("thr", "block_n", "block_d",
                                             "interpret"))
def fused_decision(b0, split, dist, hval, cand, q_val, tile_mask,
                   thr: float, block_n: int = 128,
                   block_d: int = DEST_TILE, interpret: bool = False):
    """The per-hop UGAL decision as one blocked pass: divert candidates.

    Folds the ``q_min = einsum("nk,nkm->nm", b0, split)`` backlog gather
    and the threshold comparison into per-(router-block, dest-tile)
    blocks, skipping tiles with no candidate fluid (``tile_mask``) — the
    decision-phase companion of :func:`fused_step_update`, sharing its
    block structure so both kernels skip identical tiles.

    Args:
      b0:        (N, K)    vc0 backlog per out-slot.
      split:     (N, K, M) equal-split minimal table (M may be the
                 compacted dest axis).
      dist:      (N, M)    remaining minimal hops.
      hval:      (N, M)    mean two-leg detour estimate.
      cand:      (N, M)    enqueueing vc0 candidate fluid.
      q_val:     (N,)      weighted vc1 backlog.
      tile_mask: (ceil(M / block_d),) int32, nonzero = candidates there.
      thr:       the threshold T in flit units (static: one compile per
                 SimConfig).

    Returns the (N, M) diverting candidate fluid ``cand * [divert]``.
    Rows with zero backlog never divert (``0 > thr + hval*q_val`` is
    false for ``thr >= 0``), so a partial last tile's block padding is
    discarded by the clipped write-back, exactly as in the step kernel.
    """
    n, k, m = split.shape
    bn = min(block_n, n)
    bd = min(block_d, m)
    grid = (pl.cdiv(n, bn), pl.cdiv(m, bd))

    qkd = pl.BlockSpec((bn, k, bd), lambda i, j, mask: (i, 0, j))
    nk = pl.BlockSpec((bn, k), lambda i, j, mask: (i, 0))
    nd = pl.BlockSpec((bn, bd), lambda i, j, mask: (i, j))
    n1 = pl.BlockSpec((bn, 1), lambda i, j, mask: (i, 0))

    kwargs = {}
    if not interpret:
        from ._compat import CompilerParams
        kwargs["compiler_params"] = CompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    return pl.pallas_call(
        functools.partial(_decision_kernel, thr=thr),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[nk, qkd, nd, nd, nd, n1],
            out_specs=nd,
        ),
        out_shape=jax.ShapeDtypeStruct((n, m), cand.dtype),
        interpret=interpret,
        **kwargs,
    )(jnp.asarray(tile_mask, jnp.int32), b0, split, dist, hval, cand,
      q_val.reshape(n, 1))
