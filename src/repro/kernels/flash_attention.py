"""Pallas TPU flash attention (causal / sliding-window / GQA), forward AND
backward.

Forward: grid (batch, q_heads, q_blocks, kv_blocks); the kv dimension is
innermost with 'arbitrary' semantics so the fp32 (acc, m, l) VMEM scratch
carries the online-softmax state across kv blocks; also emits the per-row
logsumexp for the backward.  Blocks are MXU-aligned (128) by default.
Fully-masked (q_block, kv_block) tiles are skipped with pl.when.

Backward (FlashAttention-2 recompute scheme, no (Sq, Skv) materialization):
  D  = rowsum(dO ∘ O)                     (jnp preprocess)
  dq : grid (b, hq, n_q, n_kv), kv innermost, dq accumulated in VMEM
  dkv: grid (b, hq, n_kv, n_q), q innermost, dk/dv accumulated in VMEM,
       per-q-head results group-summed to the kv heads outside the kernel.

Validated in interpret mode against kernels.ref.attention_ref (values AND
vjp cotangents) over a shape/dtype sweep (tests/test_kernels.py); TPU is
the compile target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention"]

_NEG_INF = -1e30


def _tile_mask(q_start, k_start, *, causal, window, block_q, block_k):
    qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def _tile_live(q_start, k_start, *, causal, window, block_q, block_k):
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window is not None:
        live &= k_start + block_k - 1 > q_start - window
    return live


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref, *,
                scale, causal, window, q_offset, block_q, block_k, n_kv):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q + q_offset
    k_start = ik * block_k

    @pl.when(_tile_live(q_start, k_start, causal=causal, window=window,
                        block_q=block_q, block_k=block_k))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          block_q=block_q, block_k=block_k)
        s = jnp.where(mask, s, _NEG_INF)
        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new) * mask.astype(jnp.float32)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_prev + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ik == n_kv - 1)
    def _finish():
        l = l_ref[...]
        o = acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = o.astype(o_ref.dtype)
        lse_ref[0, 0] = (m_ref[...] + jnp.log(jnp.maximum(l, 1e-30)))

    del iq


def _fwd(q, k, v, *, causal, window, q_offset, scale, block_q, block_k,
         interpret):
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    n_q, n_kv = sq // block_q, skv // block_k
    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=block_q, block_k=block_k, n_kv=n_kv)
    grid = (b, hq, n_q, n_kv)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_q, 1), lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(q.shape, q.dtype),
            jax.ShapeDtypeStruct((b, hq, sq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return o, lse


# ---------------------------------------------------------------------------
# Backward
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref, dq_ref,
               dq_acc, *, scale, causal, window, q_offset, block_q, block_k,
               n_kv):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = iq * block_q + q_offset
    k_start = ik * block_k

    @pl.when(_tile_live(q_start, k_start, causal=causal, window=window,
                        block_q=block_q, block_k=block_k))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)       # (bq, d)
        lse = lse_ref[0, 0]                          # (bq, 1)
        dsum = dsum_ref[0, 0]                        # (bq, 1)
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          block_q=block_q, block_k=block_k)
        s = jnp.where(mask, s, _NEG_INF)
        p = jnp.exp(s - lse)                         # masked entries -> 0
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dsum)                         # (bq, bk)
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    @pl.when(ik == n_kv - 1)
    def _finish():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, dsum_ref,
                dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                q_offset, block_q, block_k, n_q):
    ik = pl.program_id(2)
    iq = pl.program_id(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = iq * block_q + q_offset
    k_start = ik * block_k

    @pl.when(_tile_live(q_start, k_start, causal=causal, window=window,
                        block_q=block_q, block_k=block_k))
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        dsum = dsum_ref[0, 0]
        s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = _tile_mask(q_start, k_start, causal=causal, window=window,
                          block_q=block_q, block_k=block_k)
        s = jnp.where(mask, s, _NEG_INF)
        p = jnp.exp(s - lse)                         # (bq, bk)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)      # (bk, d)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - dsum)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bk, d)

    @pl.when(iq == n_q - 1)
    def _finish():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_impl(q, k, v, o, lse, do, *, causal, window, q_offset, scale,
              block_q, block_k, interpret):
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    n_q, n_kv = sq // block_q, skv // block_k
    dsum = (do.astype(jnp.float32) * o.astype(jnp.float32)).sum(-1,
                                                                keepdims=True)

    q_spec = pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, iq, ik: (ib, ih, iq, 0))
    kv_spec_q = pl.BlockSpec((1, 1, block_k, d),
                             lambda ib, ih, iq, ik, g=group: (ib, ih // g, ik, 0))
    row_spec = pl.BlockSpec((1, 1, block_q, 1), lambda ib, ih, iq, ik: (ib, ih, iq, 0))

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          window=window, q_offset=q_offset, block_q=block_q,
                          block_k=block_k, n_kv=n_kv),
        grid=(b, hq, n_q, n_kv),
        in_specs=[q_spec, kv_spec_q, kv_spec_q, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, dsum)

    # dk/dv: q innermost; per-q-head partials, group-summed outside
    q_spec2 = pl.BlockSpec((1, 1, block_q, d), lambda ib, ih, ik, iq: (ib, ih, iq, 0))
    kv_spec2 = pl.BlockSpec((1, 1, block_k, d),
                            lambda ib, ih, ik, iq, g=group: (ib, ih // g, ik, 0))
    row_spec2 = pl.BlockSpec((1, 1, block_q, 1), lambda ib, ih, ik, iq: (ib, ih, iq, 0))
    out_kv2 = pl.BlockSpec((1, 1, block_k, d), lambda ib, ih, ik, iq: (ib, ih, ik, 0))
    dkh, dvh = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          window=window, q_offset=q_offset, block_q=block_q,
                          block_k=block_k, n_q=n_q),
        grid=(b, hq, n_kv, n_q),
        in_specs=[q_spec2, kv_spec2, kv_spec2, q_spec2, row_spec2, row_spec2],
        out_specs=[out_kv2, out_kv2],
        out_shape=[jax.ShapeDtypeStruct((b, hq, skv, d), jnp.float32),
                   jax.ShapeDtypeStruct((b, hq, skv, d), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((block_k, d), jnp.float32),
                        pltpu.VMEM((block_k, d), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v, do, lse, dsum)
    dk = dkh.reshape(b, hkv, group, skv, d).sum(2).astype(k.dtype)
    dv = dvh.reshape(b, hkv, group, skv, d).sum(2).astype(v.dtype)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp plumbing + public entry point
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, window, q_offset, scale, block_q, block_k,
           interpret):
    o, _ = _fwd(q, k, v, causal=causal, window=window, q_offset=q_offset,
                scale=scale, block_q=block_q, block_k=block_k,
                interpret=interpret)
    return o


def _flash_fwd(q, k, v, causal, window, q_offset, scale, block_q, block_k,
               interpret):
    o, lse = _fwd(q, k, v, causal=causal, window=window, q_offset=q_offset,
                  scale=scale, block_q=block_q, block_k=block_k,
                  interpret=interpret)
    return o, (q, k, v, o, lse)


def _flash_bwd(causal, window, q_offset, scale, block_q, block_k, interpret,
               res, do):
    q, k, v, o, lse = res
    dq, dk, dv = _bwd_impl(q, k, v, o, lse, do, causal=causal, window=window,
                           q_offset=q_offset, scale=scale, block_q=block_q,
                           block_k=block_k, interpret=interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "scale", "block_q",
                     "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int | None = None,
                    q_offset: int = 0, scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D).  Returns (B, Hq, Sq, D).
    Differentiable: the backward is the two-kernel FlashAttention-2
    recompute scheme above (no (Sq, Skv) tensor ever leaves VMEM)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    scale = scale if scale is not None else d**-0.5
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, block_q, skv, block_k)
    return _flash(q, k, v, causal, window, q_offset, scale, block_q, block_k,
                  interpret)
