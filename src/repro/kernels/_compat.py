"""Version-compat shims for the pallas TPU kernels."""

from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
