"""Fused mask+GEMM kernels for the batched-Brandes level recurrences.

repro.core.utilization's level-synchronous engines spend each BFS level
in one (S, N) x (N, N) GEMM followed by elementwise masking against the
distance table — two full passes over the (S, N) level state when
written as separate XLA ops.  These kernels fuse the mask into the GEMM
epilogue, one per recurrence direction:

  frontier_step  — forward sigma recurrence:
                     t     = front @ adj
                     new   = (t > 0) & (dist < 0)
                     nxt   = t * new
                     dist' = where(new, lvl, dist)
                     sigma'= where(new, t, sigma)
  backward_step  — backward delta recurrence (the dependency
                   accumulation; the O(S·N) coefficient itself stays
                   host-side):
                     delta' = delta + sigma * ((coeff @ adj) * (dist == lvl))

Block structure follows flash_attention.py / sim_step.py: grid
``(rows, cols, contraction)`` with the contraction axis innermost, the
output block revisited across it as the accumulator, and the mask
epilogue applied on the final contraction step.  The level index is
scalar-prefetched so one trace serves every BFS level.  Inputs are
zero-padded host-side to block multiples (``dist`` with -2, which no
mask matches) — partial pallas blocks are padded with *undefined*
values, so in-kernel masking would otherwise be needed on every tile.

This is the ``util_engine="pallas"`` seam (repro.core.utilization
``_loads_pallas``): compiled on TPU, pallas-interpreter elsewhere — the
same convention as repro.sim's ``backend="pallas_interpret"`` parity
path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["frontier_step", "backward_step"]

_BLOCK = 128


def _fwd_kernel(lvl_ref, x_ref, a_ref, dist_ref, sigma_ref,
                nxt_ref, dout_ref, sout_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        nxt_ref[...] = jnp.zeros_like(nxt_ref)

    nxt_ref[...] += jnp.dot(x_ref[...], a_ref[...],
                            preferred_element_type=nxt_ref.dtype)

    @pl.when(k == nk - 1)
    def _finalize():
        t = nxt_ref[...]
        dist = dist_ref[...]
        new = (t > 0) & (dist < 0)
        nxt_ref[...] = jnp.where(new, t, 0.0)
        dout_ref[...] = jnp.where(new, lvl_ref[0], dist)
        sout_ref[...] = jnp.where(new, t, sigma_ref[...])


def _bwd_kernel(lvl_ref, x_ref, a_ref, dist_ref, sigma_ref, delta_ref,
                out_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(x_ref[...], a_ref[...],
                            preferred_element_type=out_ref.dtype)

    @pl.when(k == nk - 1)
    def _finalize():
        t = jnp.where(dist_ref[...] == lvl_ref[0], out_ref[...], 0.0)
        out_ref[...] = delta_ref[...] + sigma_ref[...] * t


def _pad(x, rows, cols, fill=0):
    b, n = x.shape
    if b == rows and n == cols:
        return x
    return jnp.pad(x, ((0, rows - b), (0, cols - n)),
                   constant_values=fill)


def _grid_call(kernel, lvl, mats, dists, out_shapes, b, n, block,
               interpret):
    """Shared blocked (rows, cols, contraction) dispatch.

    ``mats`` = (x, adj, *dense float operands), ``dists`` = the int32
    distance table; everything is padded to ``block`` multiples and the
    outputs clipped back to (b, n).
    """
    bb = min(block, b)
    bn = min(block, n)
    rows = pl.cdiv(b, bb) * bb
    cols = pl.cdiv(n, bn) * bn
    grid = (rows // bb, cols // bn, cols // bn)

    x, adj, *rest = mats
    x = _pad(x, rows, cols)
    adj = _pad(adj, cols, cols)
    rest = [_pad(r, rows, cols) for r in rest]
    dist = _pad(dists, rows, cols, fill=-2)  # -2: matches no level mask

    xs = pl.BlockSpec((bb, bn), lambda i, j, k, lvl: (i, k))
    as_ = pl.BlockSpec((bn, bn), lambda i, j, k, lvl: (k, j))
    ys = pl.BlockSpec((bb, bn), lambda i, j, k, lvl: (i, j))

    kwargs = {}
    if not interpret:
        from ._compat import CompilerParams
        kwargs["compiler_params"] = CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
    outs = pl.pallas_call(
        functools.partial(kernel, nk=grid[2]),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[xs, as_] + [ys] * (len(rest) + 1),
            out_specs=[ys] * len(out_shapes),
        ),
        out_shape=[jax.ShapeDtypeStruct((rows, cols), dt)
                   for dt in out_shapes],
        interpret=interpret,
        **kwargs,
    )(jnp.asarray([lvl], jnp.int32), x, adj, dist, *rest)
    return [o[:b, :n] for o in outs]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def frontier_step(front, adj, dist, sigma, lvl, block: int = _BLOCK,
                  interpret: bool = False):
    """One forward BFS level: ``(nxt, dist', sigma')`` fused with the
    frontier GEMM.  ``front``/``sigma`` are (S, N) float, ``dist``
    (S, N) int32, ``lvl`` the level being claimed."""
    b, n = front.shape
    nxt, dout, sout = _grid_call(
        _fwd_kernel, lvl, (front, adj, sigma), dist,
        (front.dtype, jnp.int32, front.dtype), b, n, block, interpret)
    return nxt, dout, sout


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def backward_step(coeff, adj, dist, sigma, delta, lvl,
                  block: int = _BLOCK, interpret: bool = False):
    """One backward dependency level:
    ``delta + sigma * ((coeff @ adj) * (dist == lvl))`` in one fused
    pass (``lvl`` here is the *parent* level, the caller's lvl-1)."""
    b, n = coeff.shape
    (out,) = _grid_call(
        _bwd_kernel, lvl, (coeff, adj, sigma, delta), dist,
        (coeff.dtype,), b, n, block, interpret)
    return out
