"""Blocked sparse-destination step backends for the flow-level simulator.

The dense engine (repro.sim.engine) materializes every intermediate of
the step — ``mv``/``del``/``cont`` tensors, an ``np.add.at`` scatter —
which costs ~30 passes over the O(N·K·M) queue state per step and caps
instances at SIM_MAX_CELLS.  This module is the ``backend="pallas"``
seam: the same step semantics, restructured around one fused
forward/throttle/enqueue contraction per VC over a *blocked* dest axis
(tiles of :data:`repro.kernels.sim_step.DEST_TILE` destinations), with
only populated (router, dest-tile) blocks computed:

* ``backend="pallas"`` — on a TPU backend the contraction runs as the
  pallas kernel :func:`repro.kernels.sim_step.fused_step_update`; on CPU
  it runs a numpy implementation with the *same blocked structure*
  (mirroring the convention of ``repro.kernels.ops``: the CPU backend
  cannot lower Mosaic kernels, so the host path reproduces the kernel's
  block/bytes shape).  Five passes over the queue state instead of ~30:

    1. per-tile occupancy reduction (carried across steps while the
       state round-trips untouched, e.g. inside ``Simulator.run``),
    2. the arrival gather ``arr[h] = sum share(a)·q[a]`` over reverse
       arcs as one sparse-matrix product (delivered fluid is the
       extracted ``(router, self-dest)`` column, O(N) per tile — the
       deliver mask has at most one hit per arc),
    3. the fused update ``q·fac - q·corr·deliver + inflow·split`` tile
       by tile, which is exactly the pallas kernel's contraction.

  The contiguous live slabs of step 3 are independent work units
  (disjoint output column ranges), run in waves of ``sim_workers``
  threads past a live-cell threshold — the ``util_workers`` idiom of
  repro.core.utilization one layer down, bitwise deterministic at any
  worker count.

* ``backend="pallas_interpret"`` — the pallas kernel itself through the
  pallas interpreter on CPU: slow, but bit-for-bit the TPU program;
  this is the backend the parity tests drive against the numpy float64
  oracle (tests/test_sim_kernel.py).

Both backends accept float32 (the TPU-native dtype, default) or float64
state via ``SimConfig(dtype=...)``; the dense numpy float64 engine stays
the parity oracle, with knee-level agreement at tolerance rather than
bitwise (rounding shifts individual threshold decisions, not the knee).

Destination sparsity has a static half too, and it is per VC.  Under
``minimal`` routing the Simulator shrinks the active set itself (see
``Simulator(demand=...)``).  Under ``ugal``/``valiant`` the active set
must stay whole — diversions spread over every active intermediate —
but only the *final-destination* axes need the demanded columns: with
``dest_cols`` the fused backends carry q0/q2/src and the PEND pool's
dest axis on the compacted ``C`` demanded columns while q1/stage2 keep
the full ``M`` mid axis (:class:`_DestAxis` holds the index-remapped
views).  The stage-2 column closure is the demanded set itself —
diverted fluid keeps its final destination — so the compaction is exact,
and a pn27-class fabric (64M dense cells) sweeps adaptively in a
few-M-cell compacted state.  The per-hop UGAL decision (q_min gather +
threshold + candidate mask) is fused into the same blocked pass /
its own pallas kernel (:func:`repro.kernels.sim_step.fused_decision`)
instead of running as unfused dense ops.
"""

from __future__ import annotations

import time
from collections import defaultdict

import numpy as np

from .engine import _BIG, _TINY, SimConfig
from .tables import RouteTables

__all__ = ["make_step_sparse", "step_aux", "resolve_dtype",
           "SPARSE_BACKENDS"]

SPARSE_BACKENDS = ("pallas", "pallas_interpret")

# dest-tile width shared with the pallas kernel (import kept lazy so the
# numpy path works without jax installed)
DEST_TILE = 128

# live queue cells per step below which the slab loop stays serial:
# thread spawn/join per wave costs ~0.1 ms, which only pays for itself
# once the numpy work per step clears ~1M cells
SIM_THREAD_MIN_CELLS = 1_000_000


class _StepAux:
    """Arc-level index structure shared by the fused backends.

    Everything here depends only on the RouteTables: the reverse-arc
    pairing that turns the arrival scatter into a gather, the per-arc
    dest index of the head router (the deliver mask has at most one true
    per arc — delivery is O(N·K), not O(N·K·M)), and the dest tiling.
    """

    def __init__(self, t: RouteTables, tile: int = DEST_TILE):
        n, k, m = t.n, t.k, t.m
        self.n, self.k, self.m = n, k, m
        nk = n * k
        head_flat = t.head.reshape(-1)
        inv_act = np.full(n + 1, m, dtype=np.int64)
        inv_act[t.active] = np.arange(m)
        # dest index of each arc's head (m = not a dest); fluid on arc a
        # addressed to dd[a] is delivered, everything else transits
        self.dd = inv_act[head_flat]                      # (NK,)
        self.self_d = inv_act[:n]                         # (N,)
        # reverse-arc pairing from the head table alone (multi-edges are
        # matched in slot order, so the pairing is a perfect matching on
        # real arcs even on multigraphs)
        buckets: dict = defaultdict(lambda: ([], []))
        for a in range(nk):
            h = head_flat[a]
            if h >= n:
                continue
            r = a // k
            lo, hi = (r, h) if r <= h else (h, r)
            buckets[(lo, hi)][0 if r <= h else 1].append(a)
        rev = np.full(nk, -1, dtype=np.int64)
        for (lo, hi), (fwd, bwd) in buckets.items():
            if lo == hi:  # self-loop: pair consecutive slots
                for x, y in zip(fwd[0::2], fwd[1::2]):
                    rev[x], rev[y] = y, x
                continue
            if len(fwd) != len(bwd):
                raise ValueError("head table is not symmetric: cannot "
                                 "pair reverse arcs")
            for x, y in zip(fwd, bwd):
                rev[x], rev[y] = y, x
        self.rev = rev                                    # (NK,) partner
        real = np.nonzero(rev >= 0)[0]
        # deliver fixup: arcs whose head is a dest
        fr = real[self.dd[real] < m]
        self.fix_arc = fr                                 # (F,) arc flats
        self.fix_dst = self.dd[fr]                        # (F,) dest col
        self.fix_router = fr // k                         # (F,) own router
        # delivered extraction: routers that are dests themselves
        hs = np.nonzero(self.self_d < m)[0]
        self.dst_router = hs                              # (H,)
        self.dst_col = self.self_d[hs]                    # (H,)
        # arrival gather as a sparse matrix: row h sums share(a)·q[a]
        # over h's in-arcs a (the reverse arcs of h's out-slots)
        import scipy.sparse as sp
        # R[h, a] = 1 where arc a ends at router h (the reverse arcs of
        # h's out-slots); data is refilled with share[a] each step, so
        # R @ q_flat is the arrival gather arr[h] = sum share(a)·q[a]
        rows = real // k
        cols = rev[real]
        self.R = sp.csr_matrix((np.ones(len(real)), (rows, cols)),
                               shape=(n, nk))
        self.R.sum_duplicates()
        self.R.sort_indices()
        # dest tiling
        self.tile = tile
        self.starts = np.arange(0, m, tile)
        self.tiles = [(int(lo), int(min(lo + tile, m)))
                      for lo in self.starts]
        self.n_tiles = len(self.tiles)
        self.fix_tile = self.fix_dst // tile              # (F,)


class _DestAxis:
    """One destination-axis view of the blocked state: the full ``M``
    active columns, or compacted to the ``C`` demanded columns.

    ``cols`` (sorted active-set indices) remaps the deliver-fixup and
    delivered-extraction index arrays onto the compacted axis; entries
    whose dest column is outside the view are dropped — exact, because
    a compacted VC never carries fluid addressed there (injection and
    conversion only feed demanded columns, transit preserves the dest).
    """

    def __init__(self, aux: _StepAux, cols=None):
        tile = aux.tile
        if cols is None:
            self.w = aux.m
            self.fix_arc, self.fix_dst = aux.fix_arc, aux.fix_dst
            self.fix_router = aux.fix_router
            self.dst_router, self.dst_col = aux.dst_router, aux.dst_col
        else:
            cols = np.asarray(cols, dtype=np.int64)
            pos = np.full(aux.m, -1, dtype=np.int64)
            pos[cols] = np.arange(len(cols))
            self.w = len(cols)
            keep = pos[aux.fix_dst] >= 0
            self.fix_arc = aux.fix_arc[keep]
            self.fix_dst = pos[aux.fix_dst[keep]]
            self.fix_router = aux.fix_router[keep]
            keep = pos[aux.dst_col] >= 0
            self.dst_router = aux.dst_router[keep]
            self.dst_col = pos[aux.dst_col[keep]]
        self.starts = np.arange(0, self.w, tile)
        self.tiles = [(int(lo), int(min(lo + tile, self.w)))
                      for lo in self.starts]
        self.n_tiles = len(self.tiles)
        self.fix_tile = self.fix_dst // tile


def _pool_diag(t: RouteTables, cols):
    """(mid, dest-col) pairs of the compacted PEND pool's self-delivery
    diagonal: pool row ``mid`` meets column ``pos[mid]`` where the mid is
    itself a demanded dest.  ``cols=None`` is the full diagonal."""
    m = t.m
    if cols is None:
        idx = np.arange(m)
        return idx, idx
    pos = np.full(m, -1, dtype=np.int64)
    pos[np.asarray(cols, dtype=np.int64)] = np.arange(len(cols))
    diag_mid = np.nonzero(pos >= 0)[0]
    return diag_mid, pos[diag_mid]


def step_aux(t: RouteTables, tile: int = DEST_TILE) -> _StepAux:
    """The (cached) arc-index structure of one RouteTables instance."""
    aux = getattr(t, "_step_aux", None)
    if aux is None or aux.tile != tile:
        aux = _StepAux(t, tile)
        t._step_aux = aux
    return aux


def resolve_dtype(name: str, backend: str):
    """State dtype for a backend: the fused backends default to float32
    (TPU-native; the dense float64 engine stays the oracle), the dense
    backends to float64."""
    if name == "auto":
        return np.float32 if backend in SPARSE_BACKENDS else np.float64
    if name in ("f32", "float32"):
        return np.float32
    if name in ("f64", "float64"):
        return np.float64
    raise ValueError(f"unknown sim dtype {name!r}; options: auto, "
                     "float32, float64")


def make_step_sparse(t: RouteTables, cfg: SimConfig, backend: str, dtype,
                     dest_cols=None):
    """Build the blocked sparse-dest ``step(state, inj, inj_cap)`` for
    ``backend`` in :data:`SPARSE_BACKENDS`.  Same contract as
    :func:`repro.sim.engine.make_step`; ``dtype`` is the state dtype
    (float32 default — the dense float64 engine is the parity oracle).
    ``dest_cols`` carries the per-VC compacted dest axis (ugal/valiant
    static compaction): state tensors q0/q2/src/pend-dest hold only
    those columns, q1/stage2 the full mid axis."""
    from .. import obs
    if cfg.mode == "ugal":
        # the decision phase runs fused (blocked q_min + threshold +
        # candidate mask) on every sparse backend — dispatch-counted
        # like the step implementations themselves
        obs.counter("sim.step_build[fused_decision]").add(1.0)
    if backend == "pallas":
        try:
            import jax
            on_tpu = jax.default_backend() == "tpu"
        except ImportError:
            on_tpu = False
        # the pallas-vs-numpy dispatch, made observable: which fused
        # implementation actually ran is otherwise invisible to callers
        if on_tpu:
            obs.counter("sim.step_build[pallas_tpu]").add(1.0)
            return _make_step_kernel(t, cfg, dtype, interpret=False,
                                     dest_cols=dest_cols)
        obs.counter("sim.step_build[fused_numpy]").add(1.0)
        return _make_step_fused_numpy(t, cfg, dtype, dest_cols=dest_cols)
    if backend == "pallas_interpret":
        obs.counter("sim.step_build[pallas_interpret]").add(1.0)
        return _make_step_kernel(t, cfg, dtype, interpret=True,
                                 dest_cols=dest_cols)
    raise ValueError(f"unknown sparse sim backend {backend!r}; "
                     f"options: {SPARSE_BACKENDS}")


# ---------------------------------------------------------------------------
# numpy fused path (CPU fast path: same blocked structure as the kernel)
# ---------------------------------------------------------------------------


def _run_slab_waves(units, run_one, workers):
    """Run independent slab units in waves of ``workers`` threads — the
    ``util_workers`` wave idiom of repro.core.utilization, under its
    OpenBLAS-pinning guard.  Units write disjoint output column ranges,
    so the result is bitwise identical at any worker count.  Per-wave
    wall times go to obs when a session is active."""
    from .. import obs
    from ..core.utilization import _blas_limit, _run_units
    sess = obs.current()
    with _blas_limit():
        for lo in range(0, len(units), workers):
            wave = units[lo:lo + workers]
            t0 = time.perf_counter() if sess is not None else 0.0
            _run_units([(lambda u=u: run_one(*u)) for u in wave],
                       workers=workers)
            if sess is not None:
                obs.counter("sim.slab_waves").add(1.0)
                obs.histogram("sim.slab_wave_seconds").observe(
                    time.perf_counter() - t0)


def _make_step_fused_numpy(t: RouteTables, cfg: SimConfig, dtype,
                           dest_cols=None):
    from ..perf import flags
    aux = step_aux(t)
    n, k, m = t.n, t.k, t.m
    nk = n * k
    asd = lambda a: np.ascontiguousarray(np.asarray(a, dtype=dtype))
    axF = _DestAxis(aux)
    axC = _DestAxis(aux, dest_cols) if dest_cols is not None else axF
    ax = (axC, axF, axC)                      # per-VC dest-axis views
    split3F = asd(t.split)                    # (N, K, M)
    reachF = asd(t.split.sum(axis=1))         # (N, M)
    if dest_cols is not None:
        csel = np.asarray(dest_cols, dtype=np.int64)
        split3C = asd(t.split[:, :, csel])    # (N, K, C)
        reachC = asd(reachF[:, csel])
        dist_c = asd(t.dist_act[:, csel])
        hval_c = asd(t.hval_rem[:, csel])
    else:
        split3C, reachC = split3F, reachF
        dist_c = asd(t.dist_act)
        hval_c = asd(t.hval_rem)
    split3_v = (split3C, split3F, split3C)
    reach_v = (reachC, reachF, reachC)
    diag_mid, diag_col = _pool_diag(t, dest_cols)
    spread = asd(t.spread)
    w_val = asd(np.einsum("nm,nkm->nk", t.spread, t.split))
    spread_T = asd(t.spread.T)
    in_active = np.zeros(n, dtype=bool)
    in_active[t.active] = True
    n_mids = asd(t.m - in_active)
    faulted = bool(getattr(t, "faulted", False))
    active = t.active
    head_flat = t.head.reshape(-1)
    mode, thr = cfg.mode, cfg.threshold
    cap = dtype(cfg.capacity)
    buf = dtype(min(cfg.buffer, _BIG))
    thr = dtype(thr)
    tiny = dtype(_TINY) if dtype == np.float64 else np.float32(1e-30)
    # private dtype-matched copy: scipy upcasts mixed-dtype products, so
    # an f64 R would silently run the whole arrival gather in f64
    R = aux.R.astype(dtype)

    # double-buffered outputs: the step is functional (inputs untouched),
    # but reuses its own previous output buffers when the caller feeds
    # the returned state back in (the run loop), avoiding allocations
    bufs = [[np.zeros((n, k, ax[v].w), dtype=dtype) for v in range(3)]
            for _ in range(2)]
    # one retention-scratch plane per VC: slab units of different VCs
    # run concurrently under sim_workers and must not share scratch
    scratch = [np.empty((nk, ax[v].w), dtype=dtype) for v in range(3)]
    # carried per-(arc, tile) occupancies, keyed by the identity of the
    # state arrays we returned; any foreign state (step 0, post-surgery)
    # triggers a fresh reduction pass
    cache = {"key": None, "ot": None}

    def occupancies(qs):
        key = tuple(id(q) for q in qs)
        if cache["key"] == key:
            return cache["ot"]
        return [np.add.reduceat(q.reshape(nk, ax[v].w), ax[v].starts,
                                axis=1)
                for v, q in enumerate(qs)]

    def step(state, inj, inj_cap):
        # f32 note: space/tiny overflows to inf and is clipped by the
        # minimum(1, .) throttle — intended, not an error
        with np.errstate(over="ignore"):
            return _step(state, inj, inj_cap)

    def _step(state, inj, inj_cap):
        q0, q1, q2, src, pend, stage2 = [np.asarray(a, dtype=dtype)
                                         for a in state]
        qs = (q0, q1, q2)
        ot = occupancies(qs)                      # 3 x (NK, T_v)
        o = [x.sum(axis=1) for x in ot]           # 3 x (NK,)
        tmass = [x.sum(axis=0) for x in ot]       # 3 x (T_v,)
        vc_live = [bool(tm.any()) for tm in tmass]

        share = cap / np.maximum(o[0] + o[1] + o[2], cap)      # (NK,)

        # -- arrivals: one sparse gather per live vc -------------------
        if any(vc_live):
            R.data[:] = share[R.indices]
        arr = []
        dl_sum = [dtype(0.0)] * 3
        stage2_add = None
        for v, q in enumerate(qs):
            axis = ax[v]
            if not vc_live[v]:
                arr.append(np.zeros((n, axis.w), dtype=dtype))
                continue
            a = np.asarray(R @ q.reshape(nk, axis.w))
            dl = a[axis.dst_router, axis.dst_col]
            if v == 1:
                stage2_add = dl.copy()
            else:
                dl_sum[v] = dl.sum()
            a[axis.dst_router, axis.dst_col] = 0.0  # transit arrivals only
            arr.append(a)

        # -- credit throttle ------------------------------------------
        s_v, damp, fac, fixdelta, rowfwd = [], [], [], [], []
        for v in range(3):
            axis = ax[v]
            own = (o[v] * (1.0 - share)).reshape(n, k).sum(axis=1)
            space = np.maximum(buf - own, 0.0)
            desire = arr[v].sum(axis=1)
            s = np.minimum(1.0, space / np.maximum(desire, tiny))
            sp = np.concatenate([s, np.ones(1, dtype=dtype)])
            d = sp[head_flat]                      # (NK,)
            f = 1.0 - share * d
            vals = qs[v].reshape(nk, axis.w)[axis.fix_arc, axis.fix_dst]
            fx = vals * share[axis.fix_arc] * (1.0 - d[axis.fix_arc])
            rf = (o[v] * f).reshape(n, k).sum(axis=1) \
                - np.bincount(axis.fix_router, weights=fx,
                              minlength=n).astype(dtype)
            arr[v] *= s[:, None]
            s_v.append(s)
            damp.append(d)
            fac.append(f)
            fixdelta.append(fx)
            rowfwd.append(rf)

        delivered = dl_sum[0] + dl_sum[2]

        # -- phase-1 conversions --------------------------------------
        if stage2_add is not None:
            stage2 = stage2.copy()
            stage2[axF.dst_col] += stage2_add
        conv2 = None
        if stage2.any() and pend.any():
            occ2_now = rowfwd[2] + arr[2].sum(axis=1)
            avail2 = np.maximum(buf - occ2_now, 0.0)[active]
            pend_sum = pend.sum(axis=1)
            drain = np.minimum(np.minimum(stage2, avail2), pend_sum)
            mix = pend / np.maximum(pend_sum, tiny)[:, None]
            take = drain[:, None] * mix            # (M, C)
            pend = pend - take
            stage2 = stage2 - drain
            delivered = delivered + take[diag_mid, diag_col].sum()
            take = take.copy()
            take[diag_mid, diag_col] = 0.0
            conv2 = np.zeros((n, axC.w), dtype=dtype)
            conv2[active] = take

        # -- injection -------------------------------------------------
        src = src + inj
        srcsum = src.sum(axis=1)
        frac = np.minimum(srcsum, inj_cap) / np.maximum(srcsum, tiny)
        q_inj = src * frac[:, None]
        src = src - q_inj

        # -- routing decision (fused: q_min + threshold + mask) --------
        cand = arr[0] + q_inj                      # (N, C)
        div_tot = dtype(0.0)
        if mode == "minimal":
            div_eff = None
            trans_keep = arr[0]
            inj_keep = q_inj
        else:
            if mode == "valiant":
                div_cand = cand
            else:
                # the per-hop UGAL decision folded into the blocked
                # pass: decisions only matter where candidate fluid
                # exists, and a zero-backlog row never diverts (the
                # inequality's LHS is 0 and thr >= 0), so the q_min
                # contraction runs over live candidate tiles x
                # backlogged rows only — reusing the occupancy carry
                b0 = np.maximum(o[0] - cap, 0.0).reshape(n, k)
                rows = np.nonzero(b0.any(axis=1))[0]
                div_cand = np.zeros_like(cand)
                if rows.size:
                    b1 = np.maximum(o[1] - cap, 0.0).reshape(n, k)
                    q_val = (b1 * w_val).sum(axis=1)
                    if rows.size > n // 4:
                        ctm = np.add.reduceat(cand.sum(axis=0), axC.starts)
                        ti = 0
                        while ti < axC.n_tiles:
                            if not ctm[ti] > 0:
                                ti += 1
                                continue
                            tj = ti
                            while (tj + 1 < axC.n_tiles
                                   and ctm[tj + 1] > 0):
                                tj += 1
                            lo, hi = axC.tiles[ti][0], axC.tiles[tj][1]
                            q_min = np.matmul(
                                b0[:, None, :],
                                split3C[:, :, lo:hi])[:, 0, :]
                            ind = (dist_c[:, lo:hi] * q_min
                                   > thr + hval_c[:, lo:hi]
                                   * q_val[:, None])
                            np.multiply(cand[:, lo:hi], ind,
                                        out=div_cand[:, lo:hi])
                            ti = tj + 1
                    else:
                        for r in rows:
                            q_min = b0[r] @ split3C[r]
                            ind = (dist_c[r] * q_min
                                   > thr + hval_c[r] * q_val[r])
                            div_cand[r] = cand[r] * ind
            occ1_now = rowfwd[1] + arr[1].sum(axis=1)
            space1 = np.maximum(buf - occ1_now, 0.0)
            desire1 = div_cand.sum(axis=1)
            s1d = np.minimum(1.0, space1 / np.maximum(desire1, tiny))
            div_eff = div_cand * s1d[:, None]
            div_tot = div_eff.sum()
            if div_tot > 0:
                if faulted:
                    pend = pend + spread_T @ div_eff
                else:
                    scaled = div_eff / n_mids[:, None]
                    pend = pend + scaled.sum(0)[None, :] - scaled[active, :]
            keep = cand - div_eff
            keep_frac = keep / np.maximum(cand, tiny)
            trans_keep = arr[0] * keep_frac
            inj_keep = q_inj * keep_frac

        occ0_now = rowfwd[0] + trans_keep.sum(axis=1)
        space0 = np.maximum(buf - occ0_now, 0.0)
        desire0 = inj_keep.sum(axis=1)
        s0i = np.minimum(1.0, space0 / np.maximum(desire0, tiny))
        inj_adm = inj_keep * s0i[:, None]
        src = src + (inj_keep - inj_adm)

        inflow = [trans_keep + inj_adm, None, None]
        if div_eff is not None and div_tot > 0:
            inflow[1] = arr[1] + div_eff.sum(axis=1)[:, None] * spread
        elif vc_live[1]:
            inflow[1] = arr[1]
        if conv2 is not None:
            inflow[2] = arr[2] + conv2
        elif vc_live[2]:
            inflow[2] = arr[2]

        # -- fused update + enqueue over live (dest-tile) slabs --------
        # contiguous runs of live tiles process as one slab: fewer numpy
        # dispatches and contiguous column ranges, same blocks skipped.
        # Slabs are independent (disjoint output columns), so they are
        # collected as work units and run in sim_workers waves when the
        # live cell count clears the threading threshold.
        out_set = 1 if any(q is bufs[0][v] for v, q in enumerate(qs)) else 0
        new_qs = [None] * 3
        new_ot = [None] * 3
        plane = [None] * 3
        occ_total = stage2.sum()
        units = []                  # (v, tile-run ti..tj, cols lo..hi)
        for v in range(3):
            q = qs[v]
            axis = ax[v]
            live = vc_live[v] or (inflow[v] is not None
                                  and bool(inflow[v].any()))
            if not live:
                new_qs[v] = q                      # all-zero: pass through
                new_ot[v] = ot[v]
                continue
            infl = inflow[v]
            if infl is None:
                infl = np.zeros((n, axis.w), dtype=dtype)
            itm = np.add.reduceat(infl.sum(axis=0), axis.starts)
            out = bufs[out_set][v]
            if out is q:                           # never alias the input
                out = bufs[1 - out_set][v]
            outf = out.reshape(nk, axis.w)
            qf = q.reshape(nk, axis.w)
            otn = np.empty_like(ot[v])
            live_t = (tmass[v] > 0) | (itm > 0)
            ti = 0
            while ti < axis.n_tiles:
                if not live_t[ti]:
                    outf[:, axis.tiles[ti][0]:axis.tiles[ti][1]] = 0.0
                    otn[:, ti] = 0.0
                    ti += 1
                    continue
                tj = ti
                while tj + 1 < axis.n_tiles and live_t[tj + 1]:
                    tj += 1
                units.append((v, ti, tj, axis.tiles[ti][0],
                              axis.tiles[tj][1]))
                ti = tj + 1
            occ_total = occ_total + rowfwd[v].sum() \
                + (infl * reach_v[v]).sum()
            new_qs[v] = out
            new_ot[v] = otn
            plane[v] = (qf, outf, otn, infl)

        def run_slab(v, ti, tj, lo, hi):
            qf, outf, otn, infl = plane[v]
            out3 = new_qs[v]
            # out = inflow*split + q*fac over the slab; the retention
            # product goes through a preallocated scratch plane (a
            # fresh 20 MB temporary per vc per step would be mmap'd
            # and page-faulted every time)
            np.multiply(infl[:, None, lo:hi], split3_v[v][:, :, lo:hi],
                        out=out3[:, :, lo:hi])
            np.multiply(qf[:, lo:hi], fac[v][:, None],
                        out=scratch[v][:, lo:hi])
            outf[:, lo:hi] += scratch[v][:, lo:hi]
            # per-(arc, tile) occupancies fall out of one reduction
            # over the finished slab (retention + enqueue together)
            otn[:, ti:tj + 1] = np.add.reduceat(
                outf[:, lo:hi], ax[v].starts[ti:tj + 1] - lo, axis=1)

        workers = flags().sim_workers
        if (workers > 1 and len(units) > 1
                and sum(nk * (hi - lo)
                        for _, _, _, lo, hi in units)
                >= SIM_THREAD_MIN_CELLS):
            _run_slab_waves(units, run_slab, workers)
        else:
            for u in units:
                run_slab(*u)

        for v in range(3):
            if plane[v] is None:
                continue
            axis = ax[v]
            if len(axis.fix_arc):
                _, outf, otn, _ = plane[v]
                outf[axis.fix_arc, axis.fix_dst] -= fixdelta[v]
                otn[axis.fix_arc, axis.fix_tile] -= fixdelta[v]

        cache["key"] = tuple(id(q) for q in new_qs)
        cache["ot"] = new_ot

        accepted = q_inj.sum() - (inj_keep - inj_adm).sum()
        stats = np.array([delivered, accepted, inj.sum(), occ_total,
                          src.sum(), div_tot], dtype=np.float64)
        return (new_qs[0], new_qs[1], new_qs[2], src, pend, stage2), stats

    return step


# ---------------------------------------------------------------------------
# pallas-kernel path (TPU deploy target; interpret mode on CPU for parity)
# ---------------------------------------------------------------------------


def _make_step_kernel(t: RouteTables, cfg: SimConfig, dtype, interpret,
                      dest_cols=None):
    import jax
    import jax.numpy as jnp

    from ..kernels.sim_step import fused_decision, fused_step_update

    aux = step_aux(t)
    n, k, m = t.n, t.k, t.m
    nk = n * k
    tile = aux.tile
    axF = _DestAxis(aux)
    axC = _DestAxis(aux, dest_cols) if dest_cols is not None else axF
    ax = (axC, axF, axC)
    widths = tuple(a.w for a in ax)
    asd = lambda a: jnp.asarray(np.asarray(a, dtype=dtype))
    split3F = asd(t.split)
    deliverF = asd(t.deliver)
    if dest_cols is not None:
        csel = np.asarray(dest_cols, dtype=np.int64)
        split3C = asd(t.split[:, :, csel])
        deliverC = asd(t.deliver[:, :, csel])
        dist_c = asd(t.dist_act[:, csel])
        hval_c = asd(t.hval_rem[:, csel])
    else:
        split3C, deliverC = split3F, deliverF
        dist_c = asd(t.dist_act)
        hval_c = asd(t.hval_rem)
    split3_v = (split3C, split3F, split3C)
    deliver_v = (deliverC, deliverF, deliverC)
    diag_mid, diag_col = _pool_diag(t, dest_cols)
    spread = asd(t.spread)
    w_val = asd(np.einsum("nm,nkm->nk", t.spread, t.split))
    spread_T = asd(t.spread.T)
    in_active = np.zeros(n, dtype=bool)
    in_active[t.active] = True
    n_mids = asd(t.m - in_active)
    faulted = bool(getattr(t, "faulted", False))
    active = jnp.asarray(t.active)
    head_flat = jnp.asarray(t.head.reshape(-1))
    # reverse-arc gather: sentinel -> the appended zero row
    rev = jnp.asarray(np.where(aux.rev >= 0, aux.rev, nk).reshape(n, k))
    mode, thr = cfg.mode, cfg.threshold
    npdt = dtype
    cap = npdt(cfg.capacity)
    buf = npdt(min(cfg.buffer, _BIG))
    thr = npdt(thr)
    tiny = npdt(_TINY) if npdt == np.float64 else np.float32(1e-30)

    def tile_sums(x, v):                     # (..., W_v) -> (..., T_v)
        pad = ax[v].n_tiles * tile - widths[v]
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        return xp.reshape(x.shape[:-1] + (ax[v].n_tiles, tile)).sum(-1)

    def step_impl(state, inj, inj_cap):
        q0, q1, q2, src, pend, stage2 = state
        qs = (q0, q1, q2)
        o = [q.reshape(nk, widths[v]).sum(axis=1)
             for v, q in enumerate(qs)]
        share = cap / jnp.maximum(o[0] + o[1] + o[2], cap)    # (NK,)

        arr, dl_sum, s_v, damp = [], [], [], []
        stage2_new = stage2
        for v, q in enumerate(qs):
            axis = ax[v]
            zrow = jnp.zeros((1, axis.w), dtype=q0.dtype)
            mv = jnp.concatenate([q.reshape(nk, axis.w) * share[:, None],
                                  zrow])
            a = mv[rev.reshape(-1)].reshape(n, k, axis.w).sum(axis=1)
            dl = a[axis.dst_router, axis.dst_col]
            if v == 1:
                stage2_new = stage2_new.at[axis.dst_col].add(dl)
            dl_sum.append(dl.sum())
            a = a.at[axis.dst_router, axis.dst_col].set(0.0)
            own = (o[v] * (1.0 - share)).reshape(n, k).sum(axis=1)
            space = jnp.maximum(buf - own, 0.0)
            desire = a.sum(axis=1)
            s = jnp.minimum(1.0, space / jnp.maximum(desire, tiny))
            d = jnp.concatenate([s, jnp.ones(1, q0.dtype)])[head_flat]
            arr.append(a * s[:, None])
            s_v.append(s)
            damp.append(d)

        delivered = dl_sum[0] + dl_sum[2]
        stage2 = stage2_new

        def rowfwd(v):
            # post-forward per-router occupancy, without touching q:
            # retention of o minus the delivered fluid's extra share
            axis = ax[v]
            f = (o[v] * (1.0 - share * damp[v])).reshape(n, k).sum(axis=1)
            vals = qs[v].reshape(nk, axis.w)[axis.fix_arc, axis.fix_dst]
            fx = vals * share[axis.fix_arc] \
                * (1.0 - damp[v][axis.fix_arc])
            return f - jnp.zeros(n, q0.dtype).at[axis.fix_router].add(fx)

        # -- conversions ----------------------------------------------
        occ2_now = rowfwd(2) + arr[2].sum(axis=1)
        avail2 = jnp.maximum(buf - occ2_now, 0.0)[active]
        pend_sum = pend.sum(axis=1)
        drain = jnp.minimum(jnp.minimum(stage2, avail2), pend_sum)
        mix = pend / jnp.maximum(pend_sum, tiny)[:, None]
        take = drain[:, None] * mix                # (M, C)
        pend = pend - take
        stage2 = stage2 - drain
        delivered = delivered + take[diag_mid, diag_col].sum()
        take = take.at[diag_mid, diag_col].set(0.0)
        conv2 = jnp.zeros((n, widths[2]), q0.dtype).at[active].set(take)

        # -- injection -------------------------------------------------
        src = src + inj
        srcsum = src.sum(axis=1)
        frac = jnp.minimum(srcsum, inj_cap) / jnp.maximum(srcsum, tiny)
        q_inj = src * frac[:, None]
        src = src - q_inj

        # -- decision (fused kernel: q_min + threshold + mask) ---------
        cand = arr[0] + q_inj
        if mode == "minimal":
            div_eff = jnp.zeros_like(cand)
        else:
            if mode == "valiant":
                div_cand = cand
            else:
                b0 = jnp.maximum(o[0] - cap, 0.0).reshape(n, k)
                b1 = jnp.maximum(o[1] - cap, 0.0).reshape(n, k)
                q_val = (b1 * w_val).sum(axis=1)
                ctm = tile_sums(cand.sum(axis=0), 0)
                div_cand = fused_decision(
                    b0, split3_v[0], dist_c, hval_c, cand, q_val,
                    (ctm > 0).astype(jnp.int32), thr=float(thr),
                    interpret=interpret)
            occ1_now = rowfwd(1) + arr[1].sum(axis=1)
            space1 = jnp.maximum(buf - occ1_now, 0.0)
            desire1 = div_cand.sum(axis=1)
            s1d = jnp.minimum(1.0, space1 / jnp.maximum(desire1, tiny))
            div_eff = div_cand * s1d[:, None]
            if faulted:
                pend = pend + spread_T @ div_eff
            else:
                scaled = div_eff / n_mids[:, None]
                pend = pend + scaled.sum(0)[None, :] - scaled[active, :]

        keep = cand - div_eff
        keep_frac = keep / jnp.maximum(cand, tiny)
        trans_keep = arr[0] * keep_frac
        inj_keep = q_inj * keep_frac
        occ0_now = rowfwd(0) + trans_keep.sum(axis=1)
        space0 = jnp.maximum(buf - occ0_now, 0.0)
        desire0 = inj_keep.sum(axis=1)
        s0i = jnp.minimum(1.0, space0 / jnp.maximum(desire0, tiny))
        inj_adm = inj_keep * s0i[:, None]
        src = src + (inj_keep - inj_adm)

        inflow = [trans_keep + inj_adm,
                  arr[1] + div_eff.sum(axis=1)[:, None] * spread,
                  arr[2] + conv2]

        # -- fused kernel: forward + throttle retention + enqueue ------
        occ = stage2.sum()
        new_qs = []
        for v in range(3):
            fac2 = (1.0 - share * damp[v]).reshape(n, k)
            corr2 = (share * (1.0 - damp[v])).reshape(n, k)
            mass = tile_sums(qs[v].reshape(nk, widths[v]).sum(axis=0)
                             + inflow[v].sum(axis=0), v)
            tmask = (mass > 0).astype(jnp.int32)
            qn, on = fused_step_update(qs[v], split3_v[v], deliver_v[v],
                                       fac2, corr2, inflow[v], tmask,
                                       interpret=interpret)
            occ = occ + on.sum()
            new_qs.append(qn)

        accepted = q_inj.sum() - (inj_keep - inj_adm).sum()
        stats = jnp.stack([delivered, accepted, inj.sum(), occ,
                           src.sum(), div_eff.sum()])
        return (new_qs[0], new_qs[1], new_qs[2], src, pend, stage2), stats

    jitted = jax.jit(step_impl)
    if dtype == np.float64:
        def step(state, inj, inj_cap):
            with jax.experimental.enable_x64():
                return jitted(state, inj, inj_cap)
        return step
    return jitted
