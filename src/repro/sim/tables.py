"""Precomputed routing structure for the flow-level simulator.

The simulator's inner loop is pure tensor algebra; everything that depends
only on the topology (shortest-path next-hop splits, delivery masks, the
Valiant intermediate spread, remaining-hop estimates for the UGAL rule) is
compiled once per ``(graph, active)`` pair into dense arrays laid out over
``(router, out-slot, dest)``:

  * out-slot ``k`` of router ``r`` is directed arc ``indptr[r] + k`` — the
    ``(N, degree)`` plane is the padded per-router view of the graph's arc
    order, so occupancy tensors are the ``(N, degree, vc)`` arrays the
    credit machinery reasons about;
  * the dest axis is restricted to the ``active`` set (all routers, or the
    leaf set of an indirect network) — spine routers of an OFT carry
    transit fluid but are never a routing destination.

``SPLIT[r, k, d]`` is the fraction of fluid at ``r`` headed for active
dest ``d`` that leaves through slot ``k`` under equal-split minimal
routing: ``1/m`` over the ``m`` out-arcs that lie on a shortest path,
0 elsewhere.  This is exactly the per-hop ECMP split the analytical
engines (repro.core.utilization) integrate in closed form, which is what
makes the zero-threshold / infinite-buffer simulation converge to the
fluid theta (see docs/simulation.md, "parity conditions").
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.graph import Graph, bfs_distances_batched

__all__ = ["RouteTables", "build_tables"]


@dataclass
class RouteTables:
    """Topology-dependent constants of one simulator instance.

    Shapes: N routers, K = max degree (padded out-slots), M active dests.

    The fault-mask block describes the degraded fabric the tables were
    compiled for (``build_tables(faults=...)``); on pristine tables every
    mask is all-alive and ``faulted`` is False, so the step function can
    keep its cheap pristine code paths.
    """

    n: int
    k: int
    m: int
    active: np.ndarray          # (M,) router id of each dest index
    head: np.ndarray = field(repr=False)       # (N, K) int, pad = N
    split: np.ndarray = field(repr=False)      # (N, K, M) minimal ECMP split
    deliver: np.ndarray = field(repr=False)    # (N, K, M) bool, head == dest
    spread: np.ndarray = field(repr=False)     # (N, M) Valiant intermediates
    dist_act: np.ndarray = field(repr=False)   # (N, M) hops to each dest
    hval_rem: np.ndarray = field(repr=False)   # (N, M) mean two-leg estimate
    slot_ok: np.ndarray = field(repr=False, default=None)    # (N, K) bool
    router_ok: np.ndarray = field(repr=False, default=None)  # (N,) bool
    dest_ok: np.ndarray = field(repr=False, default=None)    # (M,) bool
    routable: np.ndarray = field(repr=False, default=None)   # (N, M) bool
    faulted: bool = False


def build_tables(g: Graph, active: np.ndarray, dtype=np.float64,
                 faults=None) -> RouteTables:
    """Compile the dense routing tables for ``g`` restricted to ``active``
    destinations.  One batched all-source BFS plus O(N * K * M) table
    fills; the result is reused across every run on the same instance.

    With ``faults`` (a repro.core.faults.FaultSet) the tables are compiled
    for the degraded fabric while KEEPING the pristine ``(N, K)`` state
    layout — dead routers and dead out-slots stay addressable (so fluid
    state carries across a mid-run fault event) but are masked out of
    every split/spread and flagged in ``slot_ok``/``routable``.  Distances
    and ECMP splits are recomputed on the surviving graph: per-hop ECMP
    through masked split tables IS the reroute.  Because split only ever
    sends fluid one hop closer on the alive graph, ``routable[r, d]``
    (same alive component) is invariant along every route — masked tables
    plus one state surgery (repro.sim.faults) keep fluid conserved."""
    active = np.asarray(active, dtype=np.int64)
    n, m = g.n, len(active)
    if m < 2:
        raise ValueError("need at least 2 active vertices")
    deg = g.degrees
    k = int(deg.max())
    sent = np.iinfo(np.int32).max // 2   # unreachable / padded-slot marker

    faulted = faults is not None and not faults.empty
    if faulted:
        edge_alive = faults.edge_alive(g)
        router_ok = faults.router_mask(g)
        dist = bfs_distances_batched(g.subgraph(edge_mask=edge_alive),
                                     np.arange(n)).astype(np.int32)
        dist[dist < 0] = sent
    else:
        edge_alive = np.ones(g.num_edges, dtype=bool)
        router_ok = np.ones(n, dtype=bool)
        dist = bfs_distances_batched(g, np.arange(n)).astype(np.int32)
        if (dist < 0).any():
            raise ValueError("graph is disconnected")

    head = np.full((n, k), n, dtype=np.int64)
    slot_ok = np.zeros((n, k), dtype=bool)
    arc_ok = edge_alive[g.arc_edge_id]
    for r in range(n):
        d = int(deg[r])
        head[r, :d] = g.indices[g.indptr[r]: g.indptr[r + 1]]
        slot_ok[r, :d] = arc_ok[g.indptr[r]: g.indptr[r + 1]]

    dest_ok = router_ok[active]
    dist_act = dist[:, active]                        # (N, M)
    routable = (router_ok[:, None] & dest_ok[None, :]
                & (dist_act < sent))
    if faulted:
        if int(dest_ok.sum()) < 2:
            raise ValueError("fewer than 2 active destinations survive "
                             "the faults")
        alive_ids = np.nonzero(dest_ok)[0]
        if not routable[np.ix_(active[dest_ok], alive_ids)].all():
            raise ValueError(
                "faults disconnect the active set: surviving active "
                "vertices are not mutually reachable")

    # dist from each slot's head router to each active dest; padded and
    # dead slots get an unreachable sentinel so they never look like a
    # next hop
    dist_pad = np.vstack([dist, np.full((1, n), sent, dtype=np.int32)])
    head_dist = dist_pad[head][:, :, active]          # (N, K, M)
    min_mask = (head_dist == (dist_act[:, None, :] - 1)) \
        & slot_ok[:, :, None]
    count = min_mask.sum(axis=1)                      # (N, M)
    split = (min_mask / np.maximum(count, 1)[:, None, :]).astype(dtype)

    deliver = head[:, :, None] == active[None, None, :]
    # Valiant intermediate spread: uniform over the surviving active mids
    # this router can reach, other than itself (rows of routers outside
    # the active set use all reachable mids), normalized per row so
    # diversion conserves fluid
    not_self = active[None, :] != np.arange(n)[:, None]
    ok_mid = not_self & routable
    spread = (ok_mid / np.maximum(ok_mid.sum(axis=1, keepdims=True), 1)
              ).astype(dtype)

    # remaining-hop estimates for the per-hop UGAL rule: minimal is the
    # true distance; the Valiant detour from r to d is estimated as the
    # mean over surviving intermediates of dist(r, m) + dist(m, d)
    alive_act = active[dest_ok]
    mean_to_mid = dist[:, alive_act].mean(axis=1)     # (N,)
    mean_from_mid = dist[np.ix_(alive_act, active)].mean(axis=0)  # (M,)
    hval_rem = (mean_to_mid[:, None] + mean_from_mid[None, :]).astype(dtype)
    dist_out = dist_act.astype(dtype)
    if faulted:
        # zero the sentinel entries: unroutable pairs never carry fluid,
        # and downstream consumers (default_steps, the UGAL inequality)
        # must not see the unreachable marker as a distance
        dist_out = np.where(routable, dist_out, 0.0).astype(dtype)
        hval_rem = np.where(routable, hval_rem, 0.0).astype(dtype)

    return RouteTables(
        n=n, k=k, m=m, active=active, head=head, split=split,
        deliver=deliver, spread=spread, dist_act=dist_out,
        hval_rem=hval_rem, slot_ok=slot_ok, router_ok=router_ok,
        dest_ok=dest_ok, routable=routable, faulted=faulted)
