"""Precomputed routing structure for the flow-level simulator.

The simulator's inner loop is pure tensor algebra; everything that depends
only on the topology (shortest-path next-hop splits, delivery masks, the
Valiant intermediate spread, remaining-hop estimates for the UGAL rule) is
compiled once per ``(graph, active)`` pair into dense arrays laid out over
``(router, out-slot, dest)``:

  * out-slot ``k`` of router ``r`` is directed arc ``indptr[r] + k`` — the
    ``(N, degree)`` plane is the padded per-router view of the graph's arc
    order, so occupancy tensors are the ``(N, degree, vc)`` arrays the
    credit machinery reasons about;
  * the dest axis is restricted to the ``active`` set (all routers, or the
    leaf set of an indirect network) — spine routers of an OFT carry
    transit fluid but are never a routing destination.

``SPLIT[r, k, d]`` is the fraction of fluid at ``r`` headed for active
dest ``d`` that leaves through slot ``k`` under equal-split minimal
routing: ``1/m`` over the ``m`` out-arcs that lie on a shortest path,
0 elsewhere.  This is exactly the per-hop ECMP split the analytical
engines (repro.core.utilization) integrate in closed form, which is what
makes the zero-threshold / infinite-buffer simulation converge to the
fluid theta (see docs/simulation.md, "parity conditions").
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from ..core.graph import Graph, bfs_distances_batched

__all__ = ["RouteTables", "build_tables"]


@dataclass
class RouteTables:
    """Topology-dependent constants of one simulator instance.

    Shapes: N routers, K = max degree (padded out-slots), M active dests.
    """

    n: int
    k: int
    m: int
    active: np.ndarray          # (M,) router id of each dest index
    head: np.ndarray = field(repr=False)       # (N, K) int, pad = N
    split: np.ndarray = field(repr=False)      # (N, K, M) minimal ECMP split
    deliver: np.ndarray = field(repr=False)    # (N, K, M) bool, head == dest
    spread: np.ndarray = field(repr=False)     # (N, M) Valiant intermediates
    dist_act: np.ndarray = field(repr=False)   # (N, M) hops to each dest
    hval_rem: np.ndarray = field(repr=False)   # (N, M) mean two-leg estimate


def build_tables(g: Graph, active: np.ndarray,
                 dtype=np.float64) -> RouteTables:
    """Compile the dense routing tables for ``g`` restricted to ``active``
    destinations.  One batched all-source BFS plus O(N * K * M) table
    fills; the result is reused across every run on the same instance."""
    active = np.asarray(active, dtype=np.int64)
    n, m = g.n, len(active)
    if m < 2:
        raise ValueError("need at least 2 active vertices")
    deg = g.degrees
    k = int(deg.max())

    dist = bfs_distances_batched(g, np.arange(n)).astype(np.int32)
    if (dist < 0).any():
        raise ValueError("graph is disconnected")

    head = np.full((n, k), n, dtype=np.int64)
    for r in range(n):
        d = int(deg[r])
        head[r, :d] = g.indices[g.indptr[r]: g.indptr[r + 1]]

    # dist from each slot's head router to each active dest; padded slots
    # get an unreachable sentinel so they never look like a next hop
    dist_pad = np.vstack([dist, np.full((1, n), np.iinfo(np.int32).max // 2,
                                        dtype=np.int32)])
    dist_act = dist[:, active]                        # (N, M)
    head_dist = dist_pad[head][:, :, active]          # (N, K, M)
    min_mask = head_dist == (dist_act[:, None, :] - 1)
    count = min_mask.sum(axis=1)                      # (N, M)
    split = (min_mask / np.maximum(count, 1)[:, None, :]).astype(dtype)

    deliver = head[:, :, None] == active[None, None, :]
    # Valiant intermediate spread: uniform over active mids other than the
    # diverting router itself (rows of routers outside the active set use
    # all m mids), normalized per row so diversion conserves fluid
    not_self = active[None, :] != np.arange(n)[:, None]
    spread = (not_self / not_self.sum(axis=1, keepdims=True)).astype(dtype)

    # remaining-hop estimates for the per-hop UGAL rule: minimal is the
    # true distance; the Valiant detour from r to d is estimated as the
    # mean over intermediates of dist(r, m) + dist(m, d)
    mean_to_mid = dist[:, active].mean(axis=1)        # (N,)
    mean_from_mid = dist[np.ix_(active, active)].mean(axis=0)  # (M,)
    hval_rem = (mean_to_mid[:, None] + mean_from_mid[None, :]).astype(dtype)

    return RouteTables(
        n=n, k=k, m=m, active=active, head=head, split=split,
        deliver=deliver, spread=spread, dist_act=dist_act.astype(dtype),
        hval_rem=hval_rem)
