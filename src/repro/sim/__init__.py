"""repro.sim — a JAX-vectorized flow-level network simulator: the
queueing-dynamics ground truth behind the analytical theta tables.

The analytical stack (repro.core.traffic / routing) prices every topology
under *fluid* routing models: loads are closed-form path integrals, UGAL
is the theta-optimal convex blend.  Real routers make per-hop decisions
on local queue state, divert only past a threshold, and run out of buffer
— none of which a closed form sees.  This package replays the same
demand matrices (every ``TrafficPattern``, ad-hoc matrices, and the
placement pipeline's byte matrices) through a time-stepped simulator
whose inner loop is fully vectorized over ``(router, out-slot, dest)``
tensors — numpy float64 as the reference backend, a jit-compiled JAX
step for large instances — with:

  * ``minimal`` / ``valiant`` / per-hop ``ugal_threshold(T)`` router
    models (UGAL-L on local output-queue backlog),
  * three virtual channels (minimal, Valiant leg 1, leg 2) with finite
    per-router buffers and credit-based backpressure,
  * open-loop injectors driven by any pattern from the traffic registry.

Entry points
------------
``simulate(g, pattern, routing=..., offered=...)`` runs one offered load
and reports delivered throughput, Little's-law mean latency, and the
measured minimal fraction alpha.  ``saturation_sweep`` ramps offered
load, returns the latency-vs-load curve plus the measured saturation
throughput ``theta`` — directly comparable to the analytic
``saturation_report`` theta in the zero-threshold / infinite-buffer
limit (the parity seam tested in tests/test_sim.py and benchmarked into
BENCH_5.json).  ``simulate_placement`` replays a (StepProfile,
Placement) byte matrix with fabric.placement's busiest-chip
normalization, so measured theta is comparable to ``placement_report``.

See docs/simulation.md for the step semantics, the credit model, the
threshold rule, and the exact parity conditions.
"""

from __future__ import annotations

import hashlib
import time
from collections import deque
from dataclasses import asdict, dataclass, field

import numpy as np

from .. import obs
from ..core.graph import Graph
from ..core.traffic import make_pattern, normalize_demand, saturation_report
from ..obs import balance_stats
from .engine import (SIM_JAX_MIN_WORK, SIM_MAX_CELLS, SimConfig, SimState,
                     init_state, make_step, parse_sim_routing, pick_backend)
from .faults import FaultEvent, apply_fault_surgery, normalize_events
from .kernel import SPARSE_BACKENDS, make_step_sparse, resolve_dtype
from .tables import RouteTables, build_tables

__all__ = [
    "SimConfig", "SimRun", "SimSweep", "Simulator", "simulate",
    "saturation_sweep", "simulate_placement", "fluid_routing_spec",
    "FaultEvent", "DEFAULT_LOAD_GRID", "SIM_MAX_CELLS",
]

# offered-load grid of a sweep, as fractions of the analytic fluid theta:
# four sub-saturation points for the latency curve plus one past
# saturation to pin the delivered-throughput plateau
DEFAULT_LOAD_GRID = (0.3, 0.6, 0.85, 1.0, 1.2)


def fluid_routing_spec(sim_routing) -> str:
    """The repro.core.routing spec whose fluid theta the simulator
    converges to in the zero-threshold / infinite-buffer limit:
    ``minimal`` and ``valiant`` map to themselves, every finite
    ``ugal_threshold(T)`` to the exact ``ugal`` blend — and T = inf to
    ``minimal``, since an infinite margin never diverts (same
    degeneration as the core registry's analytic entry)."""
    mode, t = parse_sim_routing(sim_routing)
    if mode == "ugal" and np.isinf(t):
        return "minimal"
    return {"minimal": "minimal", "valiant": "valiant", "ugal": "ugal"}[mode]


@dataclass
class SimRun:
    """Steady-state measurements of one (demand, routing, offered) run.

    ``theta`` is the delivered per-step throughput in the demand's own
    normalization (busiest source = 1 unit for registry patterns, so it
    is directly comparable to the analytic theta); ``latency`` the
    Little's-law mean steps in the network (>= mean hops; meaningful
    below saturation — past it, it grows with the run length);
    ``alpha`` the measured fraction of accepted fluid that was never
    diverted; ``residual`` the relative flow-conservation defect.
    ``dest_stability_min`` / ``dest_stability_mean`` are the per-dest-
    column delivered/offered ratios over the trailing window (NaN unless
    the run was asked for them with ``per_dest=True``) — the sharp knee
    criterion for asymmetric sparse demand, where a handful of saturated
    columns hide inside a healthy aggregate ratio."""

    routing: str
    offered: float
    theta: float
    delivered_rate: float
    accepted_rate: float
    latency: float
    alpha: float
    occupancy: float
    src_backlog: float
    residual: float
    steps: int
    window: int
    backend: str
    dropped: float = 0.0         # fluid lost to fault surgery (cumulative)
    faults: str | None = None    # final fault state's label, if any
    dest_stability_min: float = float("nan")
    dest_stability_mean: float = float("nan")
    history: dict = field(repr=False, default_factory=dict)


@dataclass
class SimSweep:
    """A latency-vs-offered-load curve plus the measured saturation
    throughput.

    ``theta`` is the knee of the throughput curve: the largest offered
    load the fabric demonstrably sustains (delivered/offered >=
    ``stable_ratio`` over the measurement window), refined by bisection
    between the last stable and first unstable probe.  Past the knee an
    open-loop fluid network *collapses* (sustained over-injection lets
    young fluid crowd transit fluid out of the proportional arc shares),
    so the over-saturated delivered rate understates capacity — the knee,
    not the plateau, is the analytic theta's counterpart.
    ``theta_unstable`` is the smallest offered load observed to collapse
    (the bracket's other side; inf if every probe was stable),
    ``theta_analytic`` the fluid-model reference that scaled the grid.
    ``knee`` records which stability criterion decided the bracket:
    ``aggregate`` (delivered/offered over all columns) or ``per_dest``
    (the MINIMUM per-dest-column ratio — sharper for sparse asymmetric
    demand, see :meth:`Simulator.run`)."""

    pattern: str
    routing: str
    theta: float
    theta_unstable: float
    theta_analytic: float
    stable_ratio: float
    loads: np.ndarray
    delivered: np.ndarray
    latency: np.ndarray
    alpha: np.ndarray
    knee: str = "aggregate"
    runs: list = field(repr=False, default_factory=list)


class Simulator:
    """One compiled simulator instance: routing tables + a backend step
    function for a ``(graph, active set, config)`` triple, reusable
    across demand matrices and offered loads (one jit compilation serves
    a whole sweep)."""

    def __init__(self, g: Graph, config: SimConfig = SimConfig(),
                 targets_mask: np.ndarray | None = None,
                 demand: np.ndarray | None = None):
        self.g = g
        self.config = config
        if config.compact not in ("auto", "off"):
            raise ValueError(f"unknown compact mode {config.compact!r}; "
                             f"options: auto, off")
        if targets_mask is None:
            targets_mask = g.meta.get("leaf_mask")
        self.active = (np.arange(g.n) if targets_mask is None
                       else np.nonzero(np.asarray(targets_mask, bool))[0])
        m_dense = len(self.active)
        used = None
        if demand is not None and config.compact == "auto":
            used = np.asarray(demand)[:, self.active].sum(axis=0) > 0
        # Static dest compaction, phase 1 — the active set itself.  Under
        # minimal routing every dest column evolves independently, so
        # dropping the columns ``demand`` never addresses is exact on
        # EVERY backend; shrinking BEFORE backend selection also sizes
        # the auto choice and the dense-cell guard to the state that
        # will actually be allocated (a sparse-demand pn27 fits the
        # jit-compiled jax step without ever needing the fused path).
        if used is not None and config.mode == "minimal" and not used.all():
            self.active = self.active[used]
            used = None
        dense_cells = g.n * g.max_degree * len(self.active)
        self.backend = pick_backend(config.backend, dense_cells)
        if (self.backend not in SPARSE_BACKENDS
                and dense_cells > SIM_MAX_CELLS):
            raise ValueError(
                f"simulation state is dense (router, out-slot, dest) "
                f"tensors: {dense_cells} cells > "
                f"SIM_MAX_CELLS={SIM_MAX_CELLS} "
                f"(~{8 * 3 * SIM_MAX_CELLS >> 30} GB of queue state).  "
                f"Use backend='pallas' (the blocked sparse-dest step) or "
                f"a smaller instance of the same family.")
        # phase 2 — the per-VC dest axis.  ugal/valiant spread diversions
        # over the whole active set, so the active set must stay whole —
        # but only the FINAL-destination axes need the demanded columns.
        # The fused backends carry q0/q2/src and the PEND pool's dest
        # axis on the compacted columns while q1/stage2 keep the full
        # mid axis (repro.sim.kernel); the stage-2 closure is the
        # demanded set itself (diverted fluid keeps its destination), so
        # this is exact — and what lets a pn27-class fabric sweep
        # adaptively.
        self.dest_cols = None
        if (used is not None and config.mode in ("ugal", "valiant")
                and self.backend in SPARSE_BACKENDS and not used.all()):
            self.dest_cols = np.nonzero(used)[0]
        m_comp = (len(self.active) if self.dest_cols is None
                  else len(self.dest_cols))
        obs.gauge("sim.dest_cols.dense").set(float(m_dense))
        obs.gauge("sim.dest_cols.compacted").set(float(m_comp))
        obs.gauge("sim.compact_ratio").set(m_comp / max(m_dense, 1))
        # dense backends default to float64 (the jax step runs under a
        # scoped enable_x64 — float32 rounding bias visibly shifts the
        # threshold rule's diversion duty cycle); the fused sparse-dest
        # backends default to float32, the TPU-native dtype, with the
        # dense float64 path as their parity oracle
        self.dtype = resolve_dtype(config.dtype, self.backend)
        with obs.span("sim.build_tables", backend=self.backend, n=g.n,
                      dests=len(self.active)):
            self.tables = build_tables(g, self.active, dtype=self.dtype)
            self._step = self._make_step(self.tables)
        obs.counter(f"sim.backend[{self.backend}]").add(1.0)
        # fault-state label -> (tables, compiled step); one compile per
        # distinct fault state serves every run and every load probe
        self._fault_cache: dict = {}

    def _make_step(self, tb):
        if self.backend in SPARSE_BACKENDS:
            return make_step_sparse(tb, self.config, self.backend,
                                    self.dtype, dest_cols=self.dest_cols)
        return make_step(tb, self.config, self.backend, self.dtype)

    def _tables_for(self, fs):
        """Route tables + step function for one fault state (None or an
        empty FaultSet = the pristine pair)."""
        if fs is None or fs.empty:
            return self.tables, self._step
        key = fs.label
        if key not in self._fault_cache:
            with obs.span("sim.fault_tables", label=key):
                tb = build_tables(self.g, self.active, dtype=self.dtype,
                                  faults=fs)
                self._fault_cache[key] = (tb, self._make_step(tb))
        return self._fault_cache[key]

    def default_steps(self, events=None) -> int:
        """Enough steps for the slowest feedback loop to settle: several
        two-leg traversals plus a fixed transient allowance.  Fault
        ``events`` can grow distances when the fabric degrades, so the
        sizing takes the max distance over every fault segment's tables
        (cached — a run with the same schedule reuses them)."""
        dmax = int(self.tables.dist_act.max())
        for e in normalize_events(events):
            if e.faults is not None and not e.faults.empty:
                tb, _ = self._tables_for(e.faults)
                dmax = max(dmax, int(tb.dist_act.max()))
        return 48 + 16 * 2 * dmax

    def run(self, demand: np.ndarray, offered: float,
            steps: int | None = None, window: int | None = None,
            events=None, per_dest: bool = False) -> SimRun:
        """Open-loop run: every source offers ``offered * demand[s, :]``
        per step; measurements average the trailing ``window`` steps.
        ``demand`` is a dense (N, N) matrix in the caller's normalization
        (diagonal and inactive columns must be zero).

        ``events`` is a fault schedule — FaultEvents or ``(step,
        FaultSet)`` pairs, each the CUMULATIVE fault state from that step
        on (recovery = a later event with fewer faults).  At each
        boundary the run swaps in tables compiled for the new fault state
        and passes the live fluid through
        :func:`repro.sim.faults.apply_fault_surgery`; sources stop being
        offered fluid toward unroutable dests for the duration.  theta is
        measured against the FINAL fault state's surviving demand, so a
        static fault (one event at step 0) is directly comparable to the
        analytic ``degraded_report`` theta.  Mind the window: trailing
        measurements should sit after the last event to read steady
        state.

        Under an active :mod:`repro.obs` session the run publishes its
        conservation counters (``sim.injected`` / ``sim.delivered`` /
        ``sim.accepted`` / ``sim.diverted`` / ``sim.dropped`` — the SAME
        floats this method's own residual/alpha accounting uses, so they
        match the returned :class:`SimRun` bit-exactly) plus the
        link-utilization balance statistics; with per-step series
        capture on (trace mode) also the per-VC occupancy series and the
        per-dest-column stability metric.  See docs/observability.md.

        ``per_dest=True`` additionally tracks per-dest-column mass
        conservation over the trailing window and fills the run's
        ``dest_stability_min`` / ``dest_stability_mean`` fields: the
        per-column delivered/offered ratio that
        ``saturation_sweep(knee="per_dest")`` uses as its (sharper)
        stability criterion for asymmetric sparse demand.  Costs one
        host-side pass over the final-dest tensors per window step."""
        with obs.span("sim.run", routing=self.config.routing,
                      offered=float(offered), backend=self.backend):
            return self._run(demand, offered, steps, window, events,
                             per_dest)

    def _run(self, demand, offered, steps, window, events,
             per_dest=False) -> SimRun:
        t = self.tables
        demand = np.asarray(demand, dtype=np.float64)
        if demand.shape != (t.n, t.n):
            raise ValueError(f"demand is {demand.shape}, graph has N={t.n}")
        inj_norm = demand[:, t.active]
        lost = demand.sum() - inj_norm.sum()
        if lost > 1e-9 * max(demand.sum(), 1.0):
            raise ValueError("demand addresses routers outside the active "
                             "set; pass a matching targets_mask")
        if np.abs(np.diagonal(demand)).sum() > 1e-9 * max(demand.sum(), 1.0):
            raise ValueError("demand has self-addressed (diagonal) entries; "
                             "zero the diagonal (TrafficPattern.demand and "
                             "placement_demand already do)")
        if inj_norm.sum() <= 0:
            raise ValueError("demand matrix is all zero")
        cols = self.dest_cols
        if cols is not None:
            off_cols = inj_norm.sum(axis=0)
            outside = float(off_cols.sum() - off_cols[cols].sum())
            if outside > 1e-9 * max(float(off_cols.sum()), 1.0):
                raise ValueError(
                    "demand addresses destination columns outside the "
                    "compacted dest axis this Simulator was built for; "
                    "rebuild with Simulator(demand=...) covering them, "
                    "or SimConfig(compact='off')")
            inj_norm_run = inj_norm[:, cols]
        else:
            inj_norm_run = inj_norm
        evs = normalize_events(events)
        steps = (self.default_steps(events=evs) if steps is None
                 else int(steps))
        window = max(steps // 3, 8) if window is None else int(window)
        window = min(window, steps)

        if evs and evs[-1].step >= steps:
            raise ValueError(f"fault event at step {evs[-1].step} is past "
                             f"the run's {steps} steps")
        # segments of constant fault state: (start, end, FaultSet | None)
        marks = ([] if evs and evs[0].step == 0 else [(0, None)])
        marks += [(e.step, e.faults) for e in evs]
        segs = [(s0, (marks[i + 1][0] if i + 1 < len(marks) else steps), fs)
                for i, (s0, fs) in enumerate(marks)]

        inj = (offered * inj_norm_run).astype(self.dtype)
        # host numpy in, host numpy out: the jax step converts on entry
        # (under its enable_x64 scope, so float64 survives the round trip)
        st = init_state(t, self.dtype, dest_cols=cols).as_tuple()
        hist = np.empty((steps, 6), dtype=np.float64)
        # per-step surviving-demand total: each fault segment's history
        # is normalized by ITS OWN fault state's surviving demand, not
        # the final one — a pre-event curve segment is in pre-event units
        seg_total = np.empty(steps, dtype=np.float64)
        dropped_total = 0.0
        tb = t
        # per-step series capture is opt-in (an active obs session with
        # series on): `cap is None` is the only per-step cost otherwise
        sess = obs.current()
        cap = (_SimCapture(sess, self.config, steps, window)
               if sess is not None and sess.enabled and sess.series
               else None)
        # flight recorder + watchdog ride the same seam: armed only when
        # the session carries them, `mon is None` is the whole cost
        # otherwise (the obs-off overhead guard covers this hook too)
        rec = sess.recorder if sess is not None and sess.enabled else None
        wd = sess.watchdog if sess is not None and sess.enabled else None
        if wd is not None and wd.exhausted:
            wd = None
        mon = None
        if rec is not None or wd is not None:
            if wd is not None:
                fp = hashlib.sha256(
                    np.ascontiguousarray(inj_norm).tobytes()).hexdigest()
                wd.begin_run(config=asdict(self.config),
                             backend=self.backend,
                             offered=float(offered), steps=steps,
                             window=window, n=t.n,
                             dests=len(self.active),
                             demand_fingerprint=fp[:16])
            mon = _StepMonitor(rec, wd)
        # per-dest-column conservation over the trailing window (the
        # per-dest knee criterion): mass snapshots at the window edges
        # plus the offered inflow between them, exactly the accounting
        # _SimCapture.finalize publishes as sim.dest_stability
        win_start = steps - window
        pd_mass0 = pd_off = pd_last = None
        for s0, s1, fs in segs:
            tb, step_fn = self._tables_for(fs)
            if fs is not None:
                with obs.span("sim.fault_surgery", label=fs.label,
                              step=s0):
                    st, dropped = apply_fault_surgery(st, tb,
                                                      dest_cols=cols)
                dropped_total += dropped
                obs.counter("sim.fault_events").add(1.0)
            rt = tb.routable if cols is None else tb.routable[:, cols]
            inj_seg = (inj * rt).astype(self.dtype) if tb.faulted else inj
            inj_cap = (self.config.inj_factor
                       * inj_seg.sum(axis=1)).astype(self.dtype)
            seg_total[s0:s1] = float((inj_norm * tb.routable).sum()
                                     if tb.faulted else inj_norm.sum())
            if cap is not None:
                cap.set_segment(tb, inj_seg)
            off_dest = (np.asarray(inj_seg, np.float64).sum(axis=0)
                        if per_dest else None)
            if mon is not None:
                mon.set_segment(
                    float(seg_total[s0]),
                    (off_dest if off_dest is not None else
                     np.asarray(inj_seg, np.float64).sum(axis=0))
                    if mon.stab_win else None,
                    dropped_total)
            for i in range(s0, s1):
                st, stats = step_fn(st, inj_seg, inj_cap)
                hist[i] = np.asarray(stats, dtype=np.float64)
                if cap is not None:
                    cap.on_step(i, st, hist[i])
                if mon is not None:
                    mon.on_step(i, st, hist[i])
                if per_dest and i >= win_start:
                    dm = _dest_mass_host(st)
                    if pd_mass0 is None:
                        pd_mass0 = dm
                        pd_off = np.zeros_like(dm)
                    else:
                        pd_off = pd_off + off_dest
                    pd_last = dm
            if fs is not None:
                st = tuple(np.asarray(a) for a in st)
        # final fluid state, host-side (tests probe buffer occupancies)
        self.last_state = SimState(*(np.asarray(a) for a in st))

        # theta in the FINAL fault state's surviving demand units — the
        # value the analytic degraded_report theta is comparable to
        total = float(seg_total[-1])
        if total <= 0:
            raise ValueError("faults removed every offered demand")
        # a mid-run segment can have zero surviving demand (recovered
        # later); its normalized history rows are identically zero
        norm = np.where(seg_total > 0, seg_total, np.inf)
        w = hist[-window:]
        delivered_rate = float(w[:, 0].mean())
        accepted_rate = float(w[:, 1].mean())
        occupancy = float(w[:, 3].mean())
        src_backlog = float(hist[-1, 4])
        injected_cum = float(hist[:, 2].sum())
        delivered_cum = float(hist[:, 0].sum())
        residual = abs(injected_cum - delivered_cum - float(hist[-1, 3])
                       - src_backlog - dropped_total) \
            / max(injected_cum, 1e-30)
        acc_cum = float(hist[:, 1].sum())
        div_cum = float(hist[:, 5].sum())
        alpha = 1.0 - div_cum / max(acc_cum, 1e-30)
        latency = occupancy / max(delivered_rate, 1e-30)
        dest_stab_min = dest_stab_mean = float("nan")
        if per_dest and pd_last is not None and pd_off is not None:
            sel = pd_off > 0
            if sel.any():
                delivered_d = pd_mass0 - pd_last + pd_off
                stab = np.clip(delivered_d[sel] / pd_off[sel], 0.0, None)
                dest_stab_min = float(stab.min())
                dest_stab_mean = float(stab.mean())
        final_fs = segs[-1][2]
        if sess is not None and sess.enabled:
            # publish the run's own accounting: the SAME float values the
            # residual/alpha identities above consumed, so the counters
            # are bit-exact with the returned SimRun (pinned in
            # tests/test_obs.py, mid-run fault surgery included)
            m = sess.metrics
            m.counter("sim.runs").add(1.0)
            m.counter("sim.steps").add(float(steps))
            m.counter("sim.injected").add(injected_cum)
            m.counter("sim.delivered").add(delivered_cum)
            m.counter("sim.accepted").add(acc_cum)
            m.counter("sim.diverted").add(div_cum)
            m.counter("sim.dropped").add(dropped_total)
            m.gauge("sim.final_occupancy").set(float(hist[-1, 3]))
            m.gauge("sim.final_src_backlog").set(src_backlog)
            m.gauge("sim.residual").set(residual)
            m.gauge("sim.alpha").set(alpha)
            m.gauge("sim.delivered_rate").set(delivered_rate)
            m.gauge("sim.theta").set(delivered_rate / total)
            if cap is not None:
                cap.finalize()
            else:
                # cheap one-shot balance proxy: the FINAL state's per-arc
                # occupancy clipped at capacity (below saturation every
                # queue drains each step, so this IS the per-link flit
                # rate); the window-averaged sim.link_util histogram
                # needs per-step series capture
                ls = self.last_state
                o_tot = (np.asarray(ls.q0, np.float64).sum(-1)
                         + np.asarray(ls.q1, np.float64).sum(-1)
                         + np.asarray(ls.q2, np.float64).sum(-1))
                capacity = float(self.config.capacity)
                util = (np.minimum(o_tot[np.asarray(tb.slot_ok, bool)],
                                   capacity) / capacity)
                m.histogram("sim.link_util_final").observe_many(util)
                _publish_balance(m, util)
        return SimRun(
            routing=self.config.routing, offered=float(offered),
            theta=delivered_rate / total, delivered_rate=delivered_rate,
            accepted_rate=accepted_rate, latency=latency, alpha=alpha,
            occupancy=occupancy, src_backlog=src_backlog, residual=residual,
            steps=steps, window=window, backend=self.backend,
            dropped=dropped_total,
            faults=(None if final_fs is None or final_fs.empty
                    else final_fs.label),
            dest_stability_min=dest_stab_min,
            dest_stability_mean=dest_stab_mean,
            history={"delivered": hist[:, 0] / norm,
                     "accepted": hist[:, 1] / norm,
                     "offered": hist[:, 2] / norm,
                     "occupancy": hist[:, 3], "src_backlog": hist[:, 4],
                     "diverted": hist[:, 5],
                     "fault_events": np.array([e.step for e in evs],
                                              dtype=np.int64)})


def _dest_mass_host(st):
    """Per-FINAL-dest fluid mass of a step state, host-side: vc0 + vc2
    queues + source backlog + the (mid, dest) pool column sums.  vc1 and
    stage2 fluid is addressed to intermediates and its final-dest split
    IS the pend pool (the invariant repro.sim.faults documents), so
    adding it would double count.  Width follows the state's dest axis
    (compacted or dense)."""
    q0, q1, q2, src, pend, stage2 = (np.asarray(a, np.float64) for a in st)
    return (q0.sum(axis=(0, 1)) + q2.sum(axis=(0, 1))
            + src.sum(axis=0) + pend.sum(axis=0))


def _publish_balance(m, util) -> None:
    """Gauge the balance statistics of a per-link utilization vector —
    the paper's balanced-utilization thesis as a measured number."""
    bs = balance_stats(util)
    m.gauge("sim.balance.gini").set(bs["gini"])
    m.gauge("sim.balance.p99_over_mean").set(bs["p99_over_mean"])
    m.gauge("sim.balance.max_over_mean").set(bs["max_over_mean"])


class _SimCapture:
    """Per-step series capture for one :meth:`Simulator.run` under an
    active obs session with series on (trace mode by default).

    Publishes per-VC occupancy / injection-stall / diverted-fraction
    series, accumulates the trailing window's per-arc forwarded mass
    into the measured ``sim.link_util`` histogram + balance gauges, and
    takes per-dest mass snapshots at the window edges for the
    per-dest-column stability metric ``sim.dest_stability`` — the sharp
    per-dest knee criterion that supersedes the aggregate
    delivered/offered ("mushy knee") diagnosis for asymmetric sparse
    demand.  All sums run host-side on the post-step state (one extra
    pass over the queue tensors per step — the documented cost of series
    capture; a jax-backend state is synced to host each captured step).
    """

    def __init__(self, sess, cfg: SimConfig, steps: int, window: int):
        m = sess.metrics
        self.m = m
        self.cap = float(cfg.capacity)
        self.win_start = steps - window
        self.s_vc0 = m.series("sim.occ_vc0")
        self.s_vc1 = m.series("sim.occ_vc1")
        self.s_vc2 = m.series("sim.occ_vc2")
        self.s_src = m.series("sim.src_backlog")
        self.s_div = m.series("sim.diverted_frac")
        self.s_stall = m.series("sim.inj_stalled")
        self.tb = None
        self.off_dest = None    # (M,) per-step offered mass per dest
        self.util_sum = None    # (N, K) window forwarded-mass accumulator
        self.n_win = 0
        self.mass0 = None       # per-dest mass at the first window step
        self.off_acc = None     # offered mass between the mass snapshots
        self.mass_last = None

    def set_segment(self, tb, inj_seg) -> None:
        self.tb = tb
        self.off_dest = np.asarray(inj_seg, np.float64).sum(axis=0)

    def on_step(self, i: int, st, row) -> None:
        q0, q1, q2, src, pend, stage2 = \
            (np.asarray(a, np.float64) for a in st)
        self.s_vc0.append(float(q0.sum()))
        # stage2 fluid is converted-but-unlaunched phase-1 mass: it sits
        # between vc1 and vc2, counted with vc1 (where its credit lives)
        self.s_vc1.append(float(q1.sum() + stage2.sum()))
        self.s_vc2.append(float(q2.sum()))
        self.s_src.append(float(row[4]))
        self.s_div.append(float(row[5] / max(row[1], 1e-30)))
        self.s_stall.append(float(max(row[2] - row[1], 0.0)))
        if i < self.win_start:
            return
        # forwarded mass next step = min(occupancy, capacity) per arc
        # (processor sharing); sampled post-step — over a steady-state
        # window the one-step offset is immaterial
        o_tot = q0.sum(-1) + q1.sum(-1) + q2.sum(-1)
        if self.util_sum is None:
            self.util_sum = np.zeros_like(o_tot)
            self.mass0 = self._dest_mass(q0, q2, src, pend)
            self.off_acc = np.zeros_like(self.mass0)
        else:
            self.off_acc = self.off_acc + self.off_dest
        self.util_sum += np.minimum(o_tot, self.cap)
        self.n_win += 1
        self.mass_last = self._dest_mass(q0, q2, src, pend)

    @staticmethod
    def _dest_mass(q0, q2, src, pend):
        # per-FINAL-dest fluid mass: vc0 + vc2 queues + source backlog +
        # the (mid, dest) pool column sums.  vc1/stage2 fluid is
        # addressed to intermediates and its final-dest split IS the
        # pend pool (the invariant repro.sim.faults documents), so
        # adding q1 or stage2 would double count.
        return (q0.sum(axis=(0, 1)) + q2.sum(axis=(0, 1))
                + src.sum(axis=0) + pend.sum(axis=0))

    def finalize(self) -> None:
        if self.util_sum is None or self.tb is None or self.n_win == 0:
            return
        ok = np.asarray(self.tb.slot_ok, bool)
        util = self.util_sum[ok] / (self.n_win * self.cap)
        self.m.histogram("sim.link_util").observe_many(util)
        _publish_balance(self.m, util)
        if self.n_win >= 2:
            # per-dest conservation over the window: delivered mass =
            # mass drop + offered inflow between the snapshots; a column
            # whose ratio stays ~1 is individually stable — the per-dest
            # knee criterion (fault-surgery drops inside the window
            # lower it, correctly reading as instability)
            delivered = self.mass0 - self.mass_last + self.off_acc
            sel = self.off_acc > 0
            if sel.any():
                stab = np.clip(delivered[sel] / self.off_acc[sel],
                               0.0, None)
                self.m.histogram("sim.dest_stability").observe_many(stab)
                self.m.gauge("sim.dest_stability.min").set(float(stab.min()))
                self.m.gauge("sim.dest_stability.mean").set(
                    float(stab.mean()))


class _StepMonitor:
    """Flight-recorder + watchdog hook for one :meth:`Simulator._run`:
    computes the shared per-step digests ONCE and feeds both.

    Recorder channels mirror ``SimRun.history`` — delivered / accepted /
    offered divided per step by the SAME per-segment norm the run's
    post-loop normalization uses (IEEE float64 division is elementwise
    deterministic, so a reloaded bundle window compares bit-exactly
    against the history arrays), occupancy / src_backlog / diverted raw
    — plus the per-VC occupancy sums and the running conservation
    residual.  The per-dest mass digest (one host pass over the dest
    tensors per step) is computed only when a dest_stability trigger is
    armed; per-step wall time only when a step_time trigger is.
    """

    def __init__(self, rec, wd):
        self.rec = rec
        self.wd = wd
        self.stab_win = wd.stability_window() if wd is not None else None
        self.need_time = wd is not None and wd.needs("step_seconds")
        self._mass_hist = (deque(maxlen=self.stab_win + 1)
                          if self.stab_win else None)
        self.norm = np.inf
        self.off_dest = None
        self.dropped = 0.0
        self.inj_cum = 0.0
        self.dlv_cum = 0.0
        self._t_prev = time.perf_counter()

    def set_segment(self, seg_total: float, off_dest, dropped: float):
        self.norm = seg_total if seg_total > 0 else np.inf
        self.off_dest = off_dest
        self.dropped = dropped

    def on_step(self, i: int, st, row) -> None:
        dt = None
        if self.need_time:
            now = time.perf_counter()
            dt = now - self._t_prev
            self._t_prev = now
        self.inj_cum += float(row[2])
        self.dlv_cum += float(row[0])
        # the run's conservation identity, evaluated live: at the final
        # step this equals SimRun.residual up to summation order
        residual = (abs(self.inj_cum - self.dlv_cum - float(row[3])
                        - float(row[4]) - self.dropped)
                    / max(self.inj_cum, 1e-30))
        stab_min = float("nan")
        stab_col = mass_min = None
        arrs = None
        if self.rec is not None or self._mass_hist is not None:
            # one host view of the state per step; the digest sums below
            # accumulate in float64 WITHOUT materializing float64 copies
            # of the queue tensors (the fused backends run float32, and
            # a per-step 8-byte copy of the whole state would dominate
            # the monitor's cost)
            arrs = tuple(np.asarray(a) for a in st)
        if self._mass_hist is not None:
            q0, _q1, q2, src, pend, _s2 = arrs
            dm = (q0.sum(axis=(0, 1), dtype=np.float64)
                  + q2.sum(axis=(0, 1), dtype=np.float64)
                  + src.sum(axis=0, dtype=np.float64)
                  + pend.sum(axis=0, dtype=np.float64))
            mass_min = float(dm.min())
            self._mass_hist.append(dm)
            w, off = self.stab_win, self.off_dest
            if len(self._mass_hist) == w + 1 and off is not None:
                # delivered per column over the trailing window = mass
                # drop + offered inflow (_SimCapture's bookkeeping
                # identity, evaluated live each step)
                delivered = self._mass_hist[0] - dm + off * w
                sel = off > 0
                if sel.any():
                    stab = delivered[sel] / (off[sel] * w)
                    j = int(np.argmin(stab))
                    stab_min = float(stab[j])
                    stab_col = int(np.nonzero(sel)[0][j])
        if self.rec is not None:
            q0, q1, q2, _src, _pend, stage2 = arrs
            ch = {"delivered": float(row[0] / self.norm),
                  "accepted": float(row[1] / self.norm),
                  "offered": float(row[2] / self.norm),
                  "occupancy": float(row[3]),
                  "src_backlog": float(row[4]),
                  "diverted": float(row[5]),
                  "occ_vc0": float(q0.sum(dtype=np.float64)),
                  "occ_vc1": float(q1.sum(dtype=np.float64)
                                  + stage2.sum(dtype=np.float64)),
                  "occ_vc2": float(q2.sum(dtype=np.float64)),
                  "residual": residual}
            if self._mass_hist is not None:
                ch["dest_stability_min"] = stab_min
            self.rec.record(i, ch)
        if self.wd is not None:
            sample = {"step": i, "delivered": float(row[0]),
                      "accepted": float(row[1]),
                      "offered": float(row[2]),
                      "occupancy": float(row[3]),
                      "src_backlog": float(row[4]),
                      "diverted": float(row[5]),
                      "residual": residual}
            if dt is not None:
                sample["step_seconds"] = dt
            if mass_min is not None:
                sample["dest_mass_min"] = mass_min
                sample["dest_stability_min"] = stab_min
                if stab_col is not None:
                    sample["dest_stability_col"] = stab_col
            self.wd.on_step(sample)


def _demand_for(g: Graph, pattern, targets_mask, normalize: bool):
    if targets_mask is None:
        targets_mask = g.meta.get("leaf_mask")
    pat = make_pattern(pattern)
    demand = pat.demand(g, targets_mask)
    if normalize:
        demand = normalize_demand(demand)
    return pat, demand, targets_mask


def simulate(g: Graph, pattern, routing: str = "minimal",
             offered: float = 0.5, steps: int | None = None,
             config: SimConfig | None = None,
             targets_mask: np.ndarray | None = None,
             normalize: bool = True, events=None) -> SimRun:
    """Simulate one (pattern, routing, offered load) point.

    ``pattern`` is any repro.core.traffic spec (registry name,
    TrafficPattern, or raw (N, N) matrix); ``offered`` is the injection
    rate of the busiest source in link-equivalents (the analytic theta's
    units).  ``config`` overrides buffers/backend; its routing field is
    superseded by ``routing``.  ``events`` is a mid-run fault schedule
    (see :meth:`Simulator.run`)."""
    cfg = _config_with(config, routing)
    _, demand, targets_mask = _demand_for(g, pattern, targets_mask, normalize)
    return Simulator(g, cfg, targets_mask, demand=demand).run(
        demand, offered, steps, events=events)


def _config_with(config: SimConfig | None, routing: str) -> SimConfig:
    base = config or SimConfig()
    parse_sim_routing(routing)  # validate before building tables
    return SimConfig(routing=routing, buffer=base.buffer,
                     capacity=base.capacity, inj_factor=base.inj_factor,
                     backend=base.backend, dtype=base.dtype,
                     compact=base.compact)


def saturation_sweep(g: Graph, pattern, routing: str = "minimal",
                     loads=None, steps: int | None = None,
                     config: SimConfig | None = None,
                     targets_mask: np.ndarray | None = None,
                     refine: int = 3, stable_ratio: float = 0.98,
                     theta_analytic: float | None = None,
                     events=None, knee: str = "aggregate") -> SimSweep:
    """Latency-vs-offered-load curve and measured saturation throughput
    for one (topology, pattern, routing).

    ``loads`` defaults to :data:`DEFAULT_LOAD_GRID` times the analytic
    fluid theta of the matching registry model (minimal / valiant / the
    ugal blend), so the grid brackets the expected saturation point; the
    grid is extended when every probe lands on one side.  The measured
    ``theta`` is the largest offered load whose delivered/offered ratio
    stays >= ``stable_ratio``, sharpened by ``refine`` bisection probes
    inside the (stable, unstable) bracket.  Pass ``theta_analytic`` to
    reuse an already-computed fluid reference (skips one analytic
    solve).  ``events`` applies one fault schedule to EVERY probe (see
    :meth:`Simulator.run`) — the measured knee is then the degraded
    saturation throughput, comparable to the analytic
    ``degraded_report`` theta of the final fault state; pass a ``loads``
    grid scaled to the expected degraded theta so the bracket lands.

    ``knee`` picks the stability criterion: ``aggregate`` (default — the
    total delivered/offered ratio) or ``per_dest`` (stable only while
    the MINIMUM per-dest-column delivered/offered ratio stays >=
    ``stable_ratio``).  Aggregate knees go mushy on sparse asymmetric
    demand — a few saturated columns drown in the healthy majority and
    the measured theta overshoots; the per-dest criterion reads each
    column's own conservation over the window (``per_dest=True`` runs)
    and snaps the knee to the first column that collapses."""
    if knee not in ("aggregate", "per_dest"):
        raise ValueError(f"unknown knee criterion {knee!r}; options: "
                         f"aggregate, per_dest")
    per_dest = knee == "per_dest"
    cfg = _config_with(config, routing)
    pat, demand, targets_mask = _demand_for(g, pattern, targets_mask, True)
    sweep_span = obs.span("sim.sweep", pattern=pat.name,
                          routing=cfg.routing)
    with sweep_span:
        ref = (theta_analytic if theta_analytic is not None else
               saturation_report(g, pat, routing=fluid_routing_spec(routing),
                                 targets_mask=targets_mask).theta)
        if loads is None:
            loads = np.asarray(DEFAULT_LOAD_GRID) * ref
        loads = np.sort(np.asarray(loads, dtype=np.float64))
        simr = Simulator(g, cfg, targets_mask, demand=demand)

        def stable(r):
            if per_dest and np.isfinite(r.dest_stability_min):
                return r.dest_stability_min >= stable_ratio
            return r.theta >= stable_ratio * r.offered

        n_probes = [0]

        def probe(lam, phase):
            # each probe is one spanned run, tagged with the sweep phase
            # (grid / bracket extension / bisection refinement) and
            # counted per phase — the probe-budget telemetry
            obs.counter(f"sim.probes[{phase}]").add(1.0)
            with obs.span("sim.probe", phase=phase, offered=float(lam)):
                r = simr.run(demand, lam, steps, events=events,
                             per_dest=per_dest)
            ok = stable(r)
            n_probes[0] += 1
            # live sweep telemetry: one streamed event per probe (no-op
            # without a streaming session) + the oscillation trigger's
            # stability-frontier feed
            obs.emit("sim.probe", pattern=pat.name, routing=cfg.routing,
                     phase=phase, probe=n_probes[0], offered=float(lam),
                     theta=r.theta, latency=r.latency, stable=ok)
            s = obs.current()
            if s is not None and s.enabled and s.watchdog is not None:
                s.watchdog.on_probe(float(lam), ok)
            return r

        runs = [probe(lam, "grid") for lam in loads]

        # extend the bracket when the grid missed the knee entirely
        for _ in range(2):
            if any(stable(r) for r in runs):
                break
            runs.append(probe(0.5 * min(r.offered for r in runs),
                              "bracket"))
        for _ in range(2):
            if any(not stable(r) for r in runs):
                break
            runs.append(probe(1.4 * max(r.offered for r in runs),
                              "bracket"))

        lo = max((r.offered for r in runs if stable(r)), default=0.0)
        unstable = [r.offered for r in runs
                    if not stable(r) and r.offered > lo]
        hi = min(unstable) if unstable else float("inf")
        if lo > 0.0 and np.isfinite(hi):
            for _ in range(refine):
                r = probe(0.5 * (lo + hi), "bisect")
                runs.append(r)
                if stable(r):
                    lo = r.offered
                else:
                    hi = r.offered
        sweep_span.set(theta=lo, probes=len(runs))
    # the curve includes EVERY probe — grid, bracket extensions, and
    # bisection refinements — sorted by offered load, so a sweep whose
    # initial grid missed the knee still returns points near saturation
    curve = sorted(runs, key=lambda r: r.offered)
    return SimSweep(
        pattern=pat.name, routing=cfg.routing, theta=lo, theta_unstable=hi,
        theta_analytic=float(ref), stable_ratio=stable_ratio,
        loads=np.array([r.offered for r in curve]),
        delivered=np.array([r.theta for r in curve]),
        latency=np.array([r.latency for r in curve]),
        alpha=np.array([r.alpha for r in curve]), knee=knee, runs=runs)


def simulate_placement(placement, profile, routing: str = "ugal_threshold(0)",
                       offered: float | None = None,
                       steps: int | None = None,
                       config: SimConfig | None = None,
                       axis_of=None) -> SimRun:
    """Replay a (StepProfile, Placement) byte matrix through the
    simulator in fabric.placement's normalization: demand is scaled so
    the busiest CHIP injects one unit (``chip_wire_bytes``), making the
    measured theta directly comparable to ``placement_report``'s.
    ``offered`` defaults to 1.2x the analytic theta so the run reports
    the saturation plateau."""
    from ..fabric.placement import (chip_wire_bytes, placement_demand,
                                    placement_report)
    cfg = _config_with(config, routing)
    demand = placement_demand(profile, placement, axis_of)
    per_chip = chip_wire_bytes(profile, placement.mesh_shape,
                               placement.axis_names, axis_of)
    if per_chip == 0.0 or not demand.any():
        raise ValueError("placement demand is all router-local; "
                         "nothing to simulate")
    norm = demand / per_chip
    if offered is None:
        ref = placement_report(placement, profile,
                               routing=fluid_routing_spec(routing),
                               axis_of=axis_of).theta
        offered = 1.2 * ref
    return Simulator(placement.graph, cfg, demand=norm).run(
        norm, offered, steps)
