"""Mid-run fault events for the flow-level simulator.

A live fabric does not fail at t=0: links and routers die (and come
back) while fluid is in flight.  ``Simulator.run(events=...)`` takes a
schedule of :class:`FaultEvent`\\ s; at each event boundary the run
switches to route tables compiled for the event's fault state
(``build_tables(faults=...)`` — masked splits ARE the reroute, since all
transit fluid re-splits per hop) and passes the live state through
:func:`apply_fault_surgery`:

  * fluid whose (router, dest) pair is no longer routable — the dest
    died, or the faults cut the router off from it — is DROPPED and
    accounted (``SimRun.dropped``; the conservation residual includes
    it);
  * fluid queued in a dead out-slot is requeued through the new minimal
    split of its router (in-flight requeue, conserving);
  * the Valiant pending pool loses its dead (mid, dest) columns, and the
    matching fraction of vc1/stage2 fluid is dropped with it — the
    per-mid invariant ``pend row mass == vc1-toward-mid + stage2`` that
    conversion mixing relies on survives the surgery;
  * source backlog toward unroutable dests is dropped (those sources
    also stop being offered fluid for the duration — see
    ``Simulator.run``).

Each event's ``faults`` is the CUMULATIVE fault state from that step on
(not a delta); recovery is a later event with a smaller — or empty —
FaultSet.  See docs/faults.md for the event model and the
static-vs-dynamic parity conditions.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..core.faults import FaultSet
from .tables import RouteTables

__all__ = ["FaultEvent", "normalize_events", "apply_fault_surgery"]


@dataclass(frozen=True)
class FaultEvent:
    """``faults`` is the full fault state of the fabric from ``step`` on."""

    step: int
    faults: FaultSet

    def __post_init__(self):
        if int(self.step) != self.step or self.step < 0:
            raise ValueError(f"event step must be a nonnegative int, "
                             f"got {self.step!r}")
        object.__setattr__(self, "step", int(self.step))
        if not isinstance(self.faults, FaultSet):
            raise TypeError(f"event faults must be a FaultSet, "
                            f"got {type(self.faults).__name__}")


def normalize_events(events) -> tuple:
    """Sorted tuple of FaultEvents from an iterable of FaultEvents or
    ``(step, FaultSet)`` pairs; duplicate steps are rejected (each step
    has ONE fault state — merge upstream)."""
    if events is None:
        return ()
    evs = []
    for e in events:
        if isinstance(e, FaultEvent):
            evs.append(e)
        else:
            step, fs = e
            evs.append(FaultEvent(step=step, faults=fs))
    evs.sort(key=lambda e: e.step)
    steps = [e.step for e in evs]
    if len(set(steps)) != len(steps):
        raise ValueError(f"duplicate fault-event steps in {steps}")
    return tuple(evs)


def apply_fault_surgery(state: tuple, t: RouteTables,
                        dest_cols=None) -> tuple[tuple, float]:
    """Reconcile live fluid state with new route tables ``t``.

    ``state`` is the step tuple ``(q0, q1, q2, src, pend, stage2)`` (any
    backend; converted to host numpy).  Returns ``(new_state, dropped)``
    where ``dropped`` is the total fluid mass removed — unroutable queue
    fluid, source backlog toward dead dests, and the vc1/stage2 fraction
    matched to dead pending columns.  Requeue of fluid from dead
    out-slots conserves mass exactly (the new split rows sum to 1 on
    every surviving routable pair).  Idempotent: a second pass against
    the same tables drops nothing.

    With ``dest_cols`` (the fused backends' per-VC compacted dest axis,
    see repro.sim.kernel) the final-dest tensors q0/q2/src and the pend
    pool's dest axis carry only those active columns; the routable and
    split views are column-selected to match, while q1/stage2 keep the
    full mid axis exactly as in the dense layout."""
    q0, q1, q2, src, pend, stage2 = \
        [np.asarray(a, dtype=np.float64).copy() for a in state]
    routable = np.asarray(t.routable, dtype=bool)
    slot_ok = np.asarray(t.slot_ok, dtype=bool)
    split = np.asarray(t.split, dtype=np.float64)
    if dest_cols is None:
        routable_c, split_c = routable, split
        keep_pend = routable[t.active, :]             # (M, M)
    else:
        cols = np.asarray(dest_cols, dtype=np.int64)
        routable_c = routable[:, cols]                # (N, C)
        split_c = split[:, :, cols]                   # (N, K, C)
        keep_pend = routable[t.active][:, cols]       # (M, C)
    dropped = 0.0

    # 1. pending-pool columns: pend[mid, dest] survives iff dest is still
    # routable FROM the mid; vc1 fluid and stage2 credit shrink by the
    # same per-mid fraction, keeping conversion mixing consistent
    row_tot = pend.sum(axis=1)
    pend *= keep_pend
    frac = np.where(row_tot > 0,
                    pend.sum(axis=1) / np.maximum(row_tot, 1e-300), 1.0)
    before = q1.sum() + stage2.sum()
    q1 *= frac[None, None, :]                         # q1 dest axis = mid
    stage2 *= frac
    dropped += before - (q1.sum() + stage2.sum())

    # 2. unroutable (router, dest) fluid is lost with the fault
    for q, rt in ((q0, routable_c), (q1, routable), (q2, routable_c)):
        before = q.sum()
        q *= rt[:, None, :]
        dropped += before - q.sum()

    # 3. fluid in dead out-slots requeues through the new minimal split
    dead = ~slot_ok
    for q, sp in ((q0, split_c), (q1, split), (q2, split_c)):
        moved = (q * dead[:, :, None]).sum(axis=1)    # (N, W)
        q *= slot_ok[:, :, None]
        q += moved[:, None, :] * sp

    # 4. backlog toward unroutable dests goes home (is dropped)
    before = src.sum()
    src *= routable_c
    dropped += before - src.sum()

    return (q0, q1, q2, src, pend, stage2), float(dropped)
