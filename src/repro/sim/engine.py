"""The simulator's time-stepped core: one vectorized step function,
instantiated over numpy (float64, the reference backend) or JAX (jit
compiled, float32) from the same code path.

Model (full semantics in docs/simulation.md):

* Fluid flow at one-hop-per-step granularity.  Traffic lives in per-arc
  output queues ``Q[router, out-slot, dest]`` tagged by routing
  destination, one tensor per virtual channel: vc0 carries minimal-mode
  traffic, vc1 the first Valiant leg (routing dest = the intermediate),
  vc2 the second leg — the classic two-VC deadlock assignment, which is
  also exactly the state the UGAL rule compares.
* Each step every arc forwards up to ``capacity`` flits, shared
  proportionally across (vc, dest) — processor sharing, the fluid limit
  of round-robin arbitration.  Arriving fluid is ejected when the head
  router is its routing dest, otherwise re-enqueued through the
  equal-split minimal table (per-hop ECMP).
* Credit-based finite buffers: a router's per-vc occupancy may not
  exceed ``buffer``; transit arrivals beyond the remaining space stall in
  the upstream queue (backpressure), blocked injections stay in the
  source backlog, blocked diversions continue minimally.
* Per-hop threshold-UGAL: every vc0 enqueue (fresh injection or transit
  arrival) at router r toward dest d diverts to vc1 iff

      dist(r, d) * q_min > T + hval(r, d) * q_val

  with q_min the best minimal-slot vc0 backlog, q_val the best vc1 slot
  backlog at r, both sampled at the start of the step — the local-state
  form of UGAL-L, applied progressively (a diverted packet never
  re-enters vc0).  Diverted fluid spreads uniformly over the active
  intermediates; the pairing of in-flight phase-1 fluid with its final
  destinations is kept in an aggregate ``PEND[(intermediate, dest)]``
  pool and drawn down proportionally at conversion (fluid mixing — exact
  in aggregate, which is all the rank-1 Valiant fluid model resolves
  anyway; see repro.core.routing.valiant_demands).
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

from .tables import RouteTables

__all__ = ["SimConfig", "SimState", "make_step", "init_state",
           "parse_sim_routing", "pick_backend", "SIM_JAX_MIN_WORK",
           "SIM_MAX_CELLS"]

_BIG = 1e12     # unreachable-queue sentinel for masked mins
_TINY = 1e-30   # safe-division floor

# Above this many (router, slot, dest) cells the jit-compiled JAX step
# beats numpy; below it, trace/dispatch overhead dominates.
SIM_JAX_MIN_WORK = 1_500_000

# Dense-backend ceiling on (router, slot, dest) cells (~2.4 GB of f64
# queue planes): above it the dense numpy/jax steps are refused and the
# blocked sparse-dest backends (repro.sim.kernel) take over — via
# ``auto`` resolution, or explicitly with backend="pallas".
SIM_MAX_CELLS = 50_000_000

_SIM_SPEC_RE = re.compile(
    r"^\s*(minimal|valiant|ugal|ugal_threshold)\s*(?:\(\s*([^)]*)\s*\))?\s*$")


def parse_sim_routing(spec) -> tuple[str, float]:
    """``(mode, threshold)`` from a simulator routing spec: ``minimal``,
    ``valiant``, ``ugal_threshold(T)``, or ``ugal`` (= threshold 0)."""
    m = _SIM_SPEC_RE.match(str(spec))
    if not m:
        raise ValueError(
            f"unknown sim routing {spec!r}; options: minimal, valiant, "
            f"ugal, ugal_threshold(T)")
    name, arg = m.group(1), m.group(2)
    if name in ("minimal", "valiant"):
        if arg:
            raise ValueError(f"{name} takes no argument, got {spec!r}")
        return name, 0.0
    t = float(arg) if arg else 0.0
    if not t >= 0:  # also rejects nan, matching the core registry
        raise ValueError(f"threshold must be >= 0, got {t}")
    return "ugal", t


@dataclass(frozen=True)
class SimConfig:
    """Knobs of one simulation run.

    ``routing`` is a simulator spec (:func:`parse_sim_routing`);
    ``buffer`` the per-(router, vc) occupancy limit in flit units
    (``inf`` = the fluid limit); ``capacity`` the per-arc flits/step;
    ``inj_factor`` caps the per-step source drain at ``inj_factor`` times
    the offered quantum so a backlogged source cannot flood the fabric in
    one step; ``backend`` is ``auto`` / ``numpy`` / ``jax`` /
    ``pallas`` / ``pallas_interpret`` (the fused blocked sparse-dest
    step of repro.sim.kernel — the pallas kernel on TPU, the same
    blocked structure in numpy on CPU, or the kernel under the pallas
    interpreter); ``dtype`` is the state dtype — ``auto`` (float64 for
    the dense backends, float32 for the fused ones), ``float32``, or
    ``float64``; ``compact`` gates static dest compaction against the
    run's demand matrix — ``auto`` (default: shrink the active set under
    minimal routing, carry the per-VC compacted dest axis on the fused
    backends under ugal/valiant) or ``off`` (keep every column; the
    all-columns baseline the compaction benchmarks time against)."""

    routing: str = "minimal"
    buffer: float = float("inf")
    capacity: float = 1.0
    inj_factor: float = 1.0
    backend: str = "auto"
    dtype: str = "auto"
    compact: str = "auto"

    @property
    def mode(self) -> str:
        return parse_sim_routing(self.routing)[0]

    @property
    def threshold(self) -> float:
        return parse_sim_routing(self.routing)[1]


@dataclass
class SimState:
    """All mutable fluid of one run (a pytree of backend arrays)."""

    q0: object = field(repr=False)      # (N, K, M) minimal-mode queues
    q1: object = field(repr=False)      # (N, K, M) Valiant leg 1 queues
    q2: object = field(repr=False)      # (N, K, M) Valiant leg 2 queues
    src: object = field(repr=False)     # (N, M) source backlog
    pend: object = field(repr=False)    # (M, M) phase-1 (mid, dest) pool
    stage2: object = field(repr=False)  # (M,) converted, awaiting vc2 space

    def as_tuple(self):
        return (self.q0, self.q1, self.q2, self.src, self.pend, self.stage2)


def pick_backend(backend: str, work: int) -> str:
    """Resolve ``auto`` (and validate explicit choices) against what is
    importable: the fused sparse-dest backend beyond the dense cell cap,
    JAX for large instances, numpy otherwise.  An ``auto`` request
    defers to the ``sim_backend`` perf flag first (REPRO_PERF), so whole
    runs can be pinned without threading a config through."""
    if backend == "auto":
        from ..perf import flags
        backend = flags().sim_backend
    if backend in ("pallas", "pallas_interpret"):
        return backend
    if backend == "numpy":
        return "numpy"
    if backend not in ("jax", "auto"):
        raise ValueError(f"unknown sim backend {backend!r}; options: "
                         f"auto, numpy, jax, pallas, pallas_interpret")
    if backend == "auto" and work > SIM_MAX_CELLS:
        return "pallas"
    try:
        import jax  # noqa: F401
    except ImportError:
        if backend == "jax":
            raise RuntimeError("sim backend 'jax' requested but jax is "
                               "not importable; use backend='numpy'")
        return "numpy"
    if backend == "jax":
        return "jax"
    return "jax" if work >= SIM_JAX_MIN_WORK else "numpy"


def init_state(t: RouteTables, dtype, dest_cols=None) -> SimState:
    """Zero fluid state for ``t``.  With ``dest_cols`` (the fused
    backends' per-VC compacted dest axis) the final-dest tensors — q0,
    q2, src, and the pend pool's dest axis — carry only the ``C``
    demanded columns; q1 and stage2 keep the full ``M`` mid axis, since
    Valiant leg-1 fluid is addressed to intermediates."""
    n, k, m = t.n, t.k, t.m
    c = m if dest_cols is None else len(dest_cols)
    z = lambda *s: np.zeros(s, dtype=dtype)
    return SimState(q0=z(n, k, c), q1=z(n, k, m), q2=z(n, k, c),
                    src=z(n, c), pend=z(m, c), stage2=z(m))


# stats vector layout emitted by one step
STAT_NAMES = ("delivered", "accepted", "offered", "occupancy",
              "src_backlog", "diverted")


def make_step(t: RouteTables, cfg: SimConfig, backend: str, dtype):
    """Build ``step(state, inj, inj_cap) -> (state, stats)`` for one
    backend.  ``inj`` is the (N, M) per-step offered quantum, ``inj_cap``
    the (N,) per-source drain limit; both are traced arguments so one
    compiled step serves a whole load sweep."""
    from .. import obs
    obs.counter(f"sim.step_build[{backend}]").add(1.0)
    if backend == "jax":
        import jax.numpy as jnp
        xp = jnp

        def scatter_rows(values, rows, nrows):
            return jnp.zeros((nrows, values.shape[-1]), values.dtype) \
                      .at[rows].add(values)

        def zero_diag(a):
            i = jnp.arange(a.shape[0])
            return a.at[i, i].set(0.0)
    else:
        xp = np

        def scatter_rows(values, rows, nrows):
            out = np.zeros((nrows, values.shape[-1]), values.dtype)
            np.add.at(out, rows, values)
            return out

        def zero_diag(a):
            a = a.copy()
            np.fill_diagonal(a, 0.0)
            return a

    # constants stay host-side numpy; the jax trace captures them at the
    # requested precision (the step runs under a scoped enable_x64, see
    # below — float32 rounding bias measurably shifts the threshold rule's
    # duty cycle, so both backends default to float64)
    asd = lambda a: np.asarray(a, dtype=dtype)
    n, k, m = t.n, t.k, t.m
    split = asd(t.split)
    deliver = asd(t.deliver)
    spread = asd(t.spread)
    # expected first-hop slot usage of freshly diverted fluid: the spread
    # over intermediates pushed through the ECMP split (rows sum to 1)
    w_val = asd(np.einsum("nm,nkm->nk", t.spread, t.split))
    dist_act = asd(t.dist_act)
    hval_rem = asd(t.hval_rem)
    head_flat = xp.asarray(t.head.reshape(-1))
    active = xp.asarray(t.active)
    # mids available to a diverting router: m - 1 inside the active set
    # (never via itself), all m mids from a transit-only (spine) router
    in_active = np.zeros(t.n, dtype=bool)
    in_active[t.active] = True
    n_mids = asd(t.m - in_active)
    # faulted tables break the uniform-spread structure the cheap pend
    # expansion below hard-codes; fall back to the general contraction
    faulted = bool(getattr(t, "faulted", False))
    spread_T = asd(t.spread.T)               # (M, N), mids x routers
    mode, thr = cfg.mode, cfg.threshold
    cap = dtype(cfg.capacity)
    buf = dtype(min(cfg.buffer, _BIG))
    midx = xp.arange(m)

    def step(state, inj, inj_cap):
        q0, q1, q2, src, pend, stage2 = state

        # -- start-of-step backlog: what the credit/decision logic sees --
        o0 = q0.sum(-1)                      # (N, K) per-slot vc occupancy
        o1 = q1.sum(-1)
        o2 = q2.sum(-1)

        # -- forward: proportional share of each arc's capacity ----------
        share = cap / xp.maximum(o0 + o1 + o2, cap)      # (N, K) <= 1
        mv0 = q0 * share[:, :, None]
        mv1 = q1 * share[:, :, None]
        mv2 = q2 * share[:, :, None]
        del0 = mv0 * deliver                 # ejected at the head router
        del1 = mv1 * deliver                 # phase-1 reaches intermediate
        del2 = mv2 * deliver
        cont0 = mv0 - del0
        cont1 = mv1 - del1
        cont2 = mv2 - del2

        # -- credits: continuing arrivals need space at the head ---------
        arr0 = scatter_rows(cont0.reshape(n * k, m), head_flat, n + 1)[:n]
        arr1 = scatter_rows(cont1.reshape(n * k, m), head_flat, n + 1)[:n]
        arr2 = scatter_rows(cont2.reshape(n * k, m), head_flat, n + 1)[:n]

        def throttle(q, mv, arr):
            own = q.sum(axis=(1, 2)) - mv.sum(axis=(1, 2))
            space = xp.maximum(buf - own, 0.0)
            desire = arr.sum(-1)
            return xp.minimum(1.0, space / xp.maximum(desire, _TINY))

        s0 = throttle(q0, mv0, arr0)         # (N,) admit fraction per vc
        s1v = throttle(q1, mv1, arr1)
        s2 = throttle(q2, mv2, arr2)
        one = xp.ones((1,), dtype=dtype)
        damp0 = xp.concatenate([s0, one])[head_flat].reshape(n, k)
        damp1 = xp.concatenate([s1v, one])[head_flat].reshape(n, k)
        damp2 = xp.concatenate([s2, one])[head_flat].reshape(n, k)
        q0 = q0 - del0 - cont0 * damp0[:, :, None]   # blocked fluid stays
        q1 = q1 - del1 - cont1 * damp1[:, :, None]
        q2 = q2 - del2 - cont2 * damp2[:, :, None]
        arr0 = arr0 * s0[:, None]
        arr1 = arr1 * s1v[:, None]
        arr2 = arr2 * s2[:, None]

        delivered = del0.sum() + del2.sum()

        # -- phase-1 conversions: intermediate reached, draw final dests -
        stage2 = stage2 + del1.sum(axis=(0, 1))       # (M,) by intermediate
        occ2_now = q2.sum(axis=(1, 2)) + arr2.sum(-1)
        avail2 = xp.maximum(buf - occ2_now, 0.0)[active]
        pend_sum = pend.sum(-1)
        drain = xp.minimum(xp.minimum(stage2, avail2), pend_sum)
        mix = pend / xp.maximum(pend_sum, _TINY)[:, None]
        take = drain[:, None] * mix                   # (M, M) mid x dest
        pend = pend - take
        stage2 = stage2 - drain
        # a conversion whose intermediate IS the destination is delivered
        delivered = delivered + take[midx, midx].sum()
        take = zero_diag(take)
        conv2 = scatter_rows(take, active, n)         # (N, M) vc2 inflow

        # -- injection: drain the backlog up to the per-step cap ---------
        src = src + inj
        srcsum = src.sum(-1)
        frac = xp.minimum(srcsum, inj_cap) / xp.maximum(srcsum, _TINY)
        q_inj = src * frac[:, None]
        src = src - q_inj

        # -- routing decision on every vc0 enqueue (per-hop UGAL) --------
        cand = arr0 + q_inj                           # (N, M) vc0 stream
        if mode == "minimal":
            div_eff = xp.zeros_like(cand)
            s1d = xp.ones_like(s0)
        else:
            if mode == "valiant":
                div_ind = xp.ones_like(cand)
            else:
                # backlog = occupancy beyond what one step drains (a queue
                # holding exactly its in-flight fluid is uncongested),
                # averaged over the slots the fluid would actually join:
                # minimal fluid splits per the ECMP table, diverted fluid
                # per the expected first hop toward a uniform intermediate
                b0 = xp.maximum(o0 - cap, 0.0)
                b1 = xp.maximum(o1 - cap, 0.0)
                q_min = xp.einsum("nk,nkm->nm", b0, split)
                q_val = (b1 * w_val).sum(axis=1)
                div_ind = (dist_act * q_min
                           > thr + hval_rem * q_val[:, None]).astype(dtype)
            div_cand = cand * div_ind
            occ1_now = q1.sum(axis=(1, 2)) + arr1.sum(-1)
            space1 = xp.maximum(buf - occ1_now, 0.0)
            desire1 = div_cand.sum(-1)
            s1d = xp.minimum(1.0, space1 / xp.maximum(desire1, _TINY))
            div_eff = div_cand * s1d[:, None]         # blocked stays vc0
            # commit (mid, dest) pairs with the SAME per-row spread the
            # vc1 fluid routes by: (r, d) fluid puts spread[r, m] on mid
            # m, i.e. pend += spread.T @ div_eff, expanded to O(N * M)
            # via spread[r, m] = (1 - [active[m] == r]) / n_mids[r];
            # faulted spreads are not uniform, so take the O(N * M^2)
            # contraction literally there
            if faulted:
                pend = pend + spread_T @ div_eff
            else:
                scaled = div_eff / n_mids[:, None]
                pend = pend + scaled.sum(0)[None, :] - scaled[active, :]

        keep = cand - div_eff
        keep_frac = keep / xp.maximum(cand, _TINY)
        trans_keep = arr0 * keep_frac
        inj_keep = q_inj * keep_frac
        # fresh minimal-mode injections need vc0 credit; transit already
        # holds its claim (admitted above), blocked injections go home
        occ0_now = q0.sum(axis=(1, 2)) + trans_keep.sum(-1)
        space0 = xp.maximum(buf - occ0_now, 0.0)
        desire0 = inj_keep.sum(-1)
        s0i = xp.minimum(1.0, space0 / xp.maximum(desire0, _TINY))
        inj_adm = inj_keep * s0i[:, None]
        src = src + (inj_keep - inj_adm)

        # -- enqueue through the equal-split minimal table ---------------
        inflow0 = trans_keep + inj_adm
        inflow1 = arr1 + div_eff.sum(-1)[:, None] * spread
        inflow2 = arr2 + conv2
        q0 = q0 + inflow0[:, None, :] * split
        q1 = q1 + inflow1[:, None, :] * split
        q2 = q2 + inflow2[:, None, :] * split

        occ = q0.sum() + q1.sum() + q2.sum() + stage2.sum()
        accepted = q_inj.sum() - (inj_keep - inj_adm).sum()
        stats = xp.stack([delivered, accepted, inj.sum(), occ,
                          src.sum(), div_eff.sum()])
        return (q0, q1, q2, src, pend, stage2), stats

    if backend == "jax":
        import jax
        jitted = jax.jit(step)

        def step(state, inj, inj_cap):  # noqa: F811 - jitted wrapper
            with jax.experimental.enable_x64():
                return jitted(state, inj, inj_cap)

    return step
