"""Performance-experiment flags (§Perf hillclimbing).

Every optimization is gated so the paper-faithful baseline and each
optimized variant can be compiled from the same tree:

  REPRO_PERF="bf16_experts,gqa_grouped,prob_bf16,microbatch=4" \
      python -m repro.launch.dryrun ...

or programmatically ``perf.set_flags(bf16_experts=True)`` (tests use this to
assert numerical parity between paths).  Flags are read at TRACE time; a
process sees a consistent setting.

Flags:
  bf16_experts  — MoE expert matmuls read bf16 operands with fp32 MXU
                  accumulation (instead of materializing fp32 casts of the
                  all-gathered expert weights).
  gqa_grouped   — GQA attention contracts (B, Hkv, G, S, D) grouped einsums
                  instead of jnp.repeat'ing K/V to Hq (removes the group-
                  factor from K/V bytes).
  prob_bf16     — attention probabilities cast to bf16 for the p·V matmul
                  (max/lse stay fp32; flash-attention standard practice).
  microbatch=N  — grad-accumulation over N microbatches inside the train
                  step (activation temp ÷ N; grads reduced once).
  opt_all       — shorthand for every boolean flag above.

Topology-analytics flags (the batched all-source BFS/Brandes engine behind
``repro.core.utilization``):
  util_engine=NAME — which arc-load engine to use: ``auto`` (default),
                  ``naive`` (the per-source reference loops), ``numpy``
                  (batched level-synchronous GEMM engine; bipartite graphs
                  run on half-size biadjacency blocks, graphs beyond
                  util_dense_max fall back to a CSR reduceat sweep),
                  ``csr`` (force the sparse sweep), ``jax`` (jnp GEMMs,
                  jit-compiled, chunked over source blocks), ``pallas``
                  (the jax recurrences through the fused mask+GEMM
                  kernels of repro.kernels.mask_gemm — compiled on TPU,
                  pallas-interpreter float64 elsewhere), or ``orbit``
                  (force the automorphism shortcut; errors if the family
                  has no known generators).
  util_orbits=0 — disable the orbit shortcut inside ``auto``.  The
                  shortcut runs one Brandes sweep per automorphism vertex
                  orbit (1–2 for PN/demi-PN/MMS/Hamming, 2 for OFT column
                  symmetry) and reconstructs exact per-arc loads from
                  arc-orbit averages; it is exact, not approximate — this
                  flag exists to measure the exact engines.  It also
                  gates the weighted path's uniform-demand rerouting
                  (``arc_loads_weighted`` detects ``w * (ones - I)``
                  demand — incl. spread collectives and the Valiant
                  phases of any permutation — and runs the uniform
                  engines instead of a full weighted sweep).
  util_dense_max=N — largest vertex count that uses dense (N, N)
                  adjacency GEMMs (default 6144); beyond it auto prefers
                  jax (if importable, up to util_jax_max) then CSR.
  util_jax_max=N — largest vertex count auto will hand to the jax dense
                  engine (default 12288).
  util_block=N  — source-block row count for the batched engines
                  (0 = size blocks to ~48 MB of working set).

e.g. ``REPRO_PERF="util_engine=naive" python -m benchmarks.run`` times the
paper tables on the reference implementation.

Flow-level simulator flags (repro.sim):
  sim_backend=NAME — default backend for ``SimConfig(backend="auto")``:
                  auto | numpy | jax | pallas | pallas_interpret.
  sim_workers=N — Python threads over independent (vc, dest-tile) slab
                  updates inside the fused numpy sim step (waves, like
                  util_workers; numpy releases the GIL in the slab
                  ufuncs).  Bitwise deterministic at any N — slabs write
                  disjoint output columns.  1 = sequential.

Observability (repro.obs):
  obs=MODE      — default mode for ``obs.session()`` calls that do not
                  pin one: ``none`` (default; spans/counters are shared
                  no-op singletons), ``metrics``, or ``trace``
                  (Chrome-trace spans + metrics).  See
                  docs/observability.md.
"""

from __future__ import annotations

import dataclasses
import os

__all__ = ["PerfFlags", "flags", "set_flags", "from_env"]


@dataclasses.dataclass
class PerfFlags:
    bf16_experts: bool = False
    gqa_grouped: bool = False
    prob_bf16: bool = False
    microbatch: int = 1
    # MoE dispatch enters shard_map in the residual's natural (B, S, M)
    # layout (batch->dp axes, seq->model) and flattens INSIDE the body.
    # The baseline's (B·S, M) flatten has no efficient SPMD lowering from
    # the 2-axis layout, so GSPMD replicates the full activation every MoE
    # layer ('involuntary full rematerialization' warnings).
    # DEFAULT ON after the §Perf hillclimb (-67% collective on deepseek
    # train_4k, routing-identical); baselines reproduce with
    # REPRO_PERF=moe_3d=0.
    moe_3d: bool = True
    # ZeRO-1 grad path in the dry-run's train step (reduce-scatter grads +
    # all-gather updated params instead of all-reduce)
    zero1: bool = False
    # When a model's head count does not divide the model axis (smollm: 9
    # heads, mamba2: 24 SSD heads, vs 16-way TP), the baseline replicates
    # the whole mixer on the model axis (16x flops+bytes).  This flag
    # spreads BATCH over the model axis inside such blocks instead — pure
    # DP where TP has nothing to shard.
    dp_over_model: bool = False
    # Override the SSD chunk length (0 = use the arch config).  Intra-chunk
    # score/decay streams scale with chunk Q (total ~ L·Q elements), so
    # smaller chunks trade matmul shape for bytes.
    ssd_chunk: int = 0
    # Replicate ff-dim weight shards (rules ff->None).  Pairs with
    # dp_over_model on small models: model-sharded conv/MLP weights force
    # a batch-(data,model) -> channel-model activation transition that
    # GSPMD can only do by full replication (observed on mamba2: 382 GB/dev
    # all-gather).  Replicated weights make those blocks pure local DP.
    replicate_ff: bool = False
    # Arc-load engine selection for repro.core.utilization (see module
    # docstring): auto | naive | numpy | csr | jax | pallas | orbit.
    util_engine: str = "auto"
    # Let `auto` use the automorphism-orbit shortcut (exact; one Brandes
    # sweep per vertex orbit instead of per vertex).
    util_orbits: bool = True
    # Size thresholds for auto's exact-engine choice.
    util_dense_max: int = 6144
    util_jax_max: int = 12288
    # Source-block rows for the batched engines (0 = auto ~48 MB blocks).
    util_block: int = 0
    # BLAS threads while inside the dense engines (0 = leave the pool
    # alone).  The per-level GEMMs are a few hundred rows square, where
    # OpenBLAS threading measures 3-4x SLOWER than one core.
    util_blas_threads: int = 1
    # Python threads running independent source-block sweeps (numpy
    # releases the GIL in GEMM/ufunc loops, so 2 single-BLAS-thread sweeps
    # overlap ~perfectly on 2 cores).  1 = sequential.
    util_workers: int = 2
    # Flow-level simulator backend (repro.sim): auto | numpy | jax |
    # pallas | pallas_interpret.  auto picks the jit-compiled jax step
    # for large (N * degree * dests) instances, the numpy reference
    # otherwise, and the fused blocked sparse-dest step (repro.sim.kernel
    # — the pallas kernel on TPU, its blocked numpy mirror on CPU) once
    # the dense cell count exceeds engine.SIM_MAX_CELLS; pallas_interpret
    # runs the actual kernel through the pallas interpreter (parity
    # testing).  SimConfig(backend=...) overrides per run.
    sim_backend: str = "auto"
    # Python threads running independent (vc, dest-tile) slab updates
    # inside the fused numpy sim step (repro.sim.kernel) — the
    # util_workers wave idiom one layer down.  Slab outputs are disjoint
    # column ranges, so the result is bitwise identical at any worker
    # count; threading engages only past a live-cell threshold so tiny
    # instances keep the serial path.  1 = sequential.
    sim_workers: int = 2
    # Observability default mode for repro.obs sessions opened without an
    # explicit mode: none (off — every span/counter helper returns a
    # shared no-op singleton, the hot paths pay one global read), metrics
    # (counters/gauges/histograms), or trace (spans too, exportable as
    # Chrome-trace JSON).  Nothing records until obs.session() is
    # entered; REPRO_PERF=obs=trace makes every such session trace.
    obs: str = "none"


_FLAGS = PerfFlags()


def flags() -> PerfFlags:
    return _FLAGS


def set_flags(**kw) -> PerfFlags:
    for k, v in kw.items():
        if not hasattr(_FLAGS, k):
            raise KeyError(k)
        setattr(_FLAGS, k, v)
    return _FLAGS


def from_env(env: str | None = None) -> PerfFlags:
    """Parse REPRO_PERF and apply."""
    spec = env if env is not None else os.environ.get("REPRO_PERF", "")
    for tok in filter(None, (t.strip() for t in spec.split(","))):
        if tok == "opt_all":
            set_flags(bf16_experts=True, gqa_grouped=True, prob_bf16=True,
                      moe_3d=True)
        elif "=" in tok:
            k, v = tok.split("=", 1)
            try:
                val: int | str = int(v)
            except ValueError:
                val = v  # string-valued flags, e.g. util_engine=numpy
            set_flags(**{k: val})
        else:
            set_flags(**{tok: True})
    return _FLAGS


from_env()
