#!/usr/bin/env bash
# CI entry point: tier-1 validation + a bounded smoke slice of the slow
# JAX suites + the benchmark JSON artifacts.
#
#   scripts/ci.sh            # tier-1 + slow smoke + BENCH_2.json + BENCH_3.json
#   scripts/ci.sh --fast     # tier-1 only
#
# The slow smoke subset pins ONE pallas kernel shape and ONE multi-device
# system config so regressions in the heavyweight paths surface without
# paying for the full sweep (`pytest -m slow` runs everything).  Each
# phase runs under `timeout` so a wedged XLA compile fails the build
# instead of hanging it.  benchmarks.run itself exits nonzero when any
# table's max_rel_err exceeds its --err-budget (default 0.25), so a
# paper-reproduction or routing-invariant regression fails the build
# without post-processing.

set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

TIER1_BUDGET="${CI_TIER1_BUDGET:-600}"         # seconds
OBS_BUDGET="${CI_OBS_BUDGET:-300}"             # seconds
SLOW_BUDGET="${CI_SLOW_BUDGET:-600}"           # seconds
BENCH_BUDGET="${CI_BENCH_BUDGET:-600}"         # seconds
ROUTING_BUDGET="${CI_ROUTING_BUDGET:-300}"     # seconds
PLACEMENT_BUDGET="${CI_PLACEMENT_BUDGET:-300}" # seconds
SIM_BUDGET="${CI_SIM_BUDGET:-900}"             # seconds
FAULT_BUDGET="${CI_FAULT_BUDGET:-600}"         # seconds
KERNEL_BUDGET="${CI_KERNEL_BUDGET:-600}"       # seconds
# wall-time regression budget (percent) for benchmarks.compare against the
# previous BENCH artifact; shared-VM timings swing 2-3x run to run, so the
# default only catches order-of-magnitude blowups — parity (max_rel_err)
# regressions stay on compare's tight default budget regardless
REGRESSION_PCT="${CI_REGRESSION_PCT:-250}"

snapshot_bench() {  # keep the previous artifact so the fresh run has a baseline
    if [[ -f "$1" ]]; then cp "$1" "$1.base"; fi
}
compare_bench() {   # diff fresh vs baseline; a regression fails the build here
    if [[ -f "$1.base" ]]; then
        python -m benchmarks.compare "$1.base" "$1" \
            --wall-pct "$REGRESSION_PCT"
        rm -f "$1.base"
    fi
}

echo "== tier-1 (budget ${TIER1_BUDGET}s) =="
timeout "$TIER1_BUDGET" python -m pytest -x -q

if [[ "${1:-}" == "--fast" ]]; then
    echo "== done (fast mode: slow smoke + bench skipped) =="
    exit 0
fi

echo "== slow smoke subset (budget ${SLOW_BUDGET}s) =="
# one pallas kernel shape (fwd + bwd) and one multi-device system config
timeout "$SLOW_BUDGET" python -m pytest -q -m slow \
    "tests/test_kernels.py::test_attention_pallas_interpret_vs_ref[float32-case0]" \
    "tests/test_kernels.py::test_flash_attention_backward_interpret_vs_ref[case0]" \
    "tests/test_kernels.py::test_ssd_pallas_interpret_vs_ref[case0]" \
    "tests/test_system.py::test_zero1_single_device_parity"

echo "== benchmarks: paper tables + traffic sweep -> BENCH_2.json (budget ${BENCH_BUDGET}s) =="
snapshot_bench BENCH_2.json
timeout "$BENCH_BUDGET" python -m benchmarks.run --json BENCH_2.json --only tables
timeout "$BENCH_BUDGET" python -m benchmarks.run --json BENCH_2_traffic.json --only traffic
python - <<'EOF'
import json
tables = json.load(open("BENCH_2.json"))
traffic = json.load(open("BENCH_2_traffic.json"))
tables["entries"] += traffic["entries"]
tables["total_seconds"] = round(tables["total_seconds"]
                                + traffic["total_seconds"], 6)
json.dump(tables, open("BENCH_2.json", "w"), indent=2)
import os; os.remove("BENCH_2_traffic.json")
print(f"BENCH_2.json: {len(tables['entries'])} entries, "
      f"{tables['total_seconds']:.1f}s total")
EOF
compare_bench BENCH_2.json

echo "== benchmarks: adversarial routing table -> BENCH_3.json (budget ${ROUTING_BUDGET}s) =="
snapshot_bench BENCH_3.json
timeout "$ROUTING_BUDGET" python -m benchmarks.run --json BENCH_3.json --only routing
compare_bench BENCH_3.json

echo "== benchmarks: placement strategy/fragmentation table -> BENCH_4.json (budget ${PLACEMENT_BUDGET}s) =="
# benchmarks.run exits nonzero when the pipeline identities break (the
# best non-linear strategy below the linear baseline on ep_heavy, packed
# losing where it must win, or pn16's ep_heavy search not strictly
# beating linear), mirroring the routing bench
snapshot_bench BENCH_4.json
timeout "$PLACEMENT_BUDGET" python -m benchmarks.run --json BENCH_4.json --only placement
compare_bench BENCH_4.json

echo "== benchmarks: simulator parity table -> BENCH_5.json (budget ${SIM_BUDGET}s) =="
# benchmarks.run exits nonzero when any row's parity gap (measured vs
# fluid theta) or band violation (threshold-UGAL outside the
# [theta_minimal, theta_ugal] bracket) exceeds --err-budget
snapshot_bench BENCH_5.json
timeout "$SIM_BUDGET" python -m benchmarks.run --json BENCH_5.json --only sim
compare_bench BENCH_5.json

echo "== benchmarks: fault degradation curves -> BENCH_6.json (budget ${FAULT_BUDGET}s) =="
# benchmarks.run exits nonzero when any degradation curve is not monotone
# non-increasing in k (relative violation > --err-budget) or the
# static-vs-dynamic sim fault parity row's knee gap blows the budget
snapshot_bench BENCH_6.json
timeout "$FAULT_BUDGET" python -m benchmarks.run --json BENCH_6.json --only faults
compare_bench BENCH_6.json

echo "== benchmarks: fused step kernel rows -> BENCH_7.json (budget ${KERNEL_BUDGET}s) =="
# the fused sparse-dest sim backend: pn16 step timings + the 10x sweep
# acceptance row + the compacted-adaptive rows (pn16 neighbor-fed ugal
# vs the all-columns path — err forced to 1.0 if the speedup drops
# under 3x — and the PN(27) ugal sweep that only fits compacted) + the
# PN(27) past-the-dense-cap minimal sweep.  --err-budget 0.025 is the
# ISSUE's 2.5% knee-parity bound — benchmarks.run exits nonzero when
# any row's measured theta drifts further from analytic
snapshot_bench BENCH_7.json
timeout "$KERNEL_BUDGET" python -m benchmarks.run --json BENCH_7.json \
    --only kernels --err-budget 0.025
compare_bench BENCH_7.json

echo "== observability: watchdog smoke + HTML report artifact (budget ${OBS_BUDGET}s) =="
# drives a seeded past-knee pn16 run that MUST fire the dest-stability
# watchdog and write a postmortem bundle (exit 1 when it stays silent —
# a dead watchdog is a regression), verifies the bundle's ring-buffer
# channels replay the run history bit-exactly, then renders report.html:
# the BENCH_2-7 trajectory (deltas vs the artifacts just refreshed
# above), the smoke session's balance gauges/series, and the bundle
timeout "$OBS_BUDGET" python scripts/obs_smoke.py \
    --report report.html --bench-dir .

echo "== ci.sh green =="
