"""Observability smoke for CI: drive a seeded past-knee simulation that
MUST fire the per-dest stability watchdog, check the postmortem bundle
round-trips bit-exactly against the run's own history, and render the
single-file HTML report artifact (BENCH trajectory + the smoke session +
the bundle).

Exit codes: 0 all good; 1 the watchdog did not fire (or the bundle
failed verification) — a silent-watchdog regression fails the build; 2
setup errors.

    PYTHONPATH=src python scripts/obs_smoke.py --report report.html \
        --bench-dir .
"""

from __future__ import annotations

import argparse
import sys

import numpy as np

# pn16 uniform analytic theta under minimal/UGAL is ~6.97 link-equivalents
# per node; 2x that offered load is comfortably past the knee, so the
# delivered/offered stability ratio must collapse and the watchdog fires
_PN_Q = 16
_THETA_PN16_UNIFORM = 6.9714
_OFFERED_FACTOR = 2.0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--report", default="report.html", metavar="OUT.html")
    ap.add_argument("--bench-dir", default=".", metavar="PATH",
                    help="directory whose BENCH_*.json trajectory the "
                         "report renders (default: cwd)")
    ap.add_argument("--dir", default="postmortems", metavar="PATH",
                    help="postmortem bundle directory")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--stream", default=None, metavar="OUT.jsonl",
                    help="also stream live telemetry events")
    args = ap.parse_args(argv)

    from repro import obs, sim
    from repro.core import pn_graph
    from repro.obs import report as obs_report

    g = pn_graph(_PN_Q)
    d = np.ones((g.n, g.n)) - np.eye(g.n)
    demand = d / d.sum(axis=1, keepdims=True)
    offered = _OFFERED_FACTOR * _THETA_PN16_UNIFORM

    rec = obs.FlightRecorder(window=24)
    wd = obs.Watchdog(
        [obs.dest_stability(ratio=0.8, window=16, warmup=16)],
        action="continue", dir=args.dir)
    simr = sim.Simulator(g, sim.SimConfig(routing="ugal_threshold(0)",
                                          backend="pallas"))
    with obs.session(mode="trace", series=True, recorder=rec, watchdog=wd,
                     stream=args.stream) as sess:
        with obs.span("obs_smoke.run", offered=float(offered)):
            run = simr.run(demand, offered, steps=args.steps)
        snap = sess.snapshot()
        series = obs_report.session_series(sess)

    if not wd.fired:
        print("# FAIL: past-knee probe did not fire the dest-stability "
              "watchdog (no postmortem bundle written)", file=sys.stderr)
        return 1
    name, path = wd.fired[0]
    print(f"# watchdog fired: {name} -> {path}")

    # the bundle's ring-buffer channels must replay the run's own history
    # bit-exactly (the flight-recorder contract docs/observability.md pins)
    bundle = obs.load_bundle(path)
    brec = bundle["recorder"]
    steps_idx = np.asarray(brec["steps"], dtype=np.int64)
    bad = []
    for key in ("delivered", "accepted", "offered", "occupancy",
                "src_backlog", "diverted"):
        got = np.asarray(brec["channels"][key], dtype=np.float64)
        want = np.asarray(run.history[key], dtype=np.float64)[steps_idx]
        if not np.array_equal(got, want):
            bad.append(key)
    if bad:
        print(f"# FAIL: bundle channels diverge from run.history: {bad}",
              file=sys.stderr)
        return 1
    print(f"# bundle verified bit-exact over {len(steps_idx)} steps x "
          f"{len(brec['channels'])} channels")

    obs_report.render_report(
        args.report, bench_dir=args.bench_dir,
        sessions=[("obs_smoke", snap, series)], bundles=[bundle],
        title="repro CI observability report")
    print(f"# wrote {args.report}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
