"""End-to-end training driver: train an LM on the synthetic pipeline with
checkpoint/resume, straggler detection and loss logging.

Presets (this container is a single CPU core; pick your budget):
  --preset tiny   ~2M params,  300 steps  (~minutes)     [default]
  --preset small  ~20M params, 300 steps  (~1h CPU)
  --preset full   smollm-135m as assigned, seq 512       (real-cluster scale)

Resume: re-running the same command continues from the last checkpoint.

Run:  PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 300
"""

import argparse

import numpy as np

from repro.configs import get_arch
from repro.data import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig, cosine_schedule
from repro.train.train_step import TrainStepConfig
from repro.train.trainer import Trainer, TrainerConfig


def make_cfg(preset: str):
    base = get_arch("smollm-135m")
    if preset == "tiny":
        return base.replace(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                            head_dim=32, d_ff=384, vocab=2048), 128, 4
    if preset == "small":
        return base.replace(n_layers=8, d_model=384, n_heads=6, n_kv_heads=2,
                            head_dim=64, d_ff=1024, vocab=8192), 256, 4
    return base, 512, 8  # full: the assigned smollm-135m config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=["tiny", "small", "full"],
                    default="tiny")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()

    cfg, seq, batch = make_cfg(args.preset)
    from repro.models import count_params
    print(f"arch={cfg.name} preset={args.preset} "
          f"params={count_params(cfg)/1e6:.1f}M seq={seq} batch={batch}")

    trainer = Trainer(
        cfg=cfg,
        data=DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch),
        mesh=make_host_mesh(1, 1),
        tcfg=TrainerConfig(total_steps=args.steps, checkpoint_every=100,
                           checkpoint_dir=args.ckpt_dir, log_every=10),
        scfg=TrainStepConfig(optimizer=AdamWConfig(
            lr=cosine_schedule(args.lr, warmup=20, total=args.steps))),
    )
    trainer.run()

    losses = [h.loss for h in trainer.history]
    if losses:
        k = max(1, len(losses) // 10)
        first, last = float(np.mean(losses[:k])), float(np.mean(losses[-k:]))
        print(f"\nloss: first-{k}-avg {first:.4f} -> last-{k}-avg {last:.4f} "
              f"({100 * (first - last) / first:.1f}% reduction)")
        print(f"stragglers flagged: {len(trainer.straggler_steps)}")


if __name__ == "__main__":
    main()
