"""Placement demo: where should a 256-chip training job sit on the paper's
demi-PN fabric?

Routes the job's collective schedule (DP ring + EP all-to-all, byte counts
from a dry-run profile) over shortest paths for several chip->router
placements and reports the max link load — §Fabric of EXPERIMENTS.md.

Run:  PYTHONPATH=src python examples/placement_demo.py --q 27 --delta0 14
"""

import argparse

from repro.core import build_topology
from repro.fabric.placement import (collective_traffic, evaluate_placements,
                                    greedy_improve, link_loads, place_mesh)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--q", type=int, default=27, help="demi-PN order")
    ap.add_argument("--delta0", type=int, default=14)
    ap.add_argument("--ring-gb", type=float, default=4.1,
                    help="DP ring payload per chip (GB)")
    ap.add_argument("--a2a-gb", type=float, default=6.6,
                    help="EP all-to-all payload per chip (GB)")
    ap.add_argument("--iters", type=int, default=150)
    args = ap.parse_args()

    g = build_topology("demi_pn", args.q)
    mesh, axes = (16, 16), ("data", "model")
    spec = {"data": ("ring", args.ring_gb),
            "model": ("all_to_all", args.a2a_gb)}
    print(f"fabric: {g.name} ({g.n} routers, Δ0={args.delta0} -> "
          f"{g.n * args.delta0} terminals); job: 256 chips, "
          f"{args.ring_gb} GB ring + {args.a2a_gb} GB a2a per chip")

    out = evaluate_placements(g, mesh, axes, args.delta0, spec)
    for k, v in out.items():
        print(f"  {k:7s} max={v['max']:9.2f} GB/link  mean={v['mean']:6.2f}")

    traffic = collective_traffic(mesh, axes, spec)
    p0 = place_mesh(g, mesh, axes, args.delta0, "random", seed=1)
    p_opt, best = greedy_improve(p0, traffic, iters=args.iters, seed=2)
    print(f"  greedy  max={best:9.2f} GB/link "
          f"(from random {link_loads(p0, traffic)['max']:.2f})")
    print("\n=> on a diameter-2 projective fabric, an under-subscribed job "
          "wants to SPREAD (per-router injection bw = Δ·u/k̄ links, Eq. 1); "
          "packing strategies that win on tori lose here.")


if __name__ == "__main__":
    main()
