"""Placement demo: where should a 256-chip training job sit on the paper's
demi-PN fabric — under the routing it actually runs?

Compiles the job's collective schedule (DP ring + EP all-to-all byte
counts, dry-run-profile style) and a chip->router placement into a
router-level demand matrix, scores it through the routing registry
(minimal / valiant / ugal), and compares every registered placement
strategy by theta — the per-chip saturation injection rate in Eq. 1's
link-equivalent units — plus the worst case the adversarial harness finds
over the routers the job occupies.  §Fabric of EXPERIMENTS.md.

Run:  PYTHONPATH=src python examples/placement_demo.py --q 27 --delta0 14
"""

import argparse

from repro.core import build_topology
from repro.fabric import StepProfile, fragmentation_sweep, placement_search


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--q", type=int, default=27, help="demi-PN order")
    ap.add_argument("--delta0", type=int, default=14)
    ap.add_argument("--ring-gb", type=float, default=4.1,
                    help="DP all-reduce payload per chip (GB)")
    ap.add_argument("--a2a-gb", type=float, default=6.6,
                    help="EP all-to-all payload per chip (GB)")
    ap.add_argument("--routing", default="ugal",
                    help="routing model to score under (minimal/valiant/ugal)")
    ap.add_argument("--iters", type=int, default=60,
                    help="greedy_swap descent iterations")
    ap.add_argument("--adversary", action="store_true",
                    help="also score each occupied router set against the "
                         "worst pattern repro.core.adversary finds")
    args = ap.parse_args()

    g = build_topology("demi_pn", args.q)
    mesh, axes = (16, 16), ("data", "model")
    prof = StepProfile({"all-reduce": args.ring_gb * 1e9,
                        "all-to-all": args.a2a_gb * 1e9})
    print(f"fabric: {g.name} ({g.n} routers, Δ0={args.delta0} -> "
          f"{g.n * args.delta0} terminals); job: 256 chips, "
          f"{args.ring_gb} GB ring + {args.a2a_gb} GB a2a per chip; "
          f"routing={args.routing}")

    out = placement_search(
        g, mesh, axes, args.delta0, prof,
        strategies=("linear", "group", "random", "orbit",
                    f"greedy_swap({args.iters})"),
        routing=args.routing, adversary=args.adversary)
    for name, r in out["rows"].items():
        alpha = "" if r["alpha"] is None else f"  alpha={r['alpha']:.3f}"
        adv = ("" if "adv_theta" not in r
               else f"  adv_theta={r['adv_theta']:.4f}@{r['adv_pattern']}")
        print(f"  {name:18s} theta={r['theta']:7.4f}  "
              f"max={r['max_bytes'] / 1e9:7.2f} GB/link{alpha}{adv}")
    print(f"  => best: {out['best']} "
          f"(theta {out['rows'][out['best']]['theta']:.4f} vs linear "
          f"{out['rows']['linear']['theta']:.4f})")

    tmesh = mesh
    while 2 * tmesh[0] * tmesh[1] > g.n * args.delta0:
        tmesh = (tmesh[0] // 2, tmesh[1])  # halve the DP axis until 2 fit
    frag = fragmentation_sweep(g, [(tmesh, axes, prof)] * 2, args.delta0,
                               routing=args.routing, background="tornado")
    fl = frag["layouts"]
    print(f"\ntwo co-tenant jobs + tornado background "
          f"({args.routing}): " +
          "  ".join(f"{k}={v['theta']:.4f}" for k, v in fl.items()) +
          f"  => {frag['best']}")
    print("\n=> on a diameter-2 projective fabric an under-subscribed job "
          "wants to SPREAD across routers (Eq. 1's per-router injection "
          "budget), but co-tenants must not SHARE routers: packed beats "
          "the fragmented interleaved schedule, while chip-major linear "
          "splits every EP group — the placement-aware demand pipeline "
          "prices all of it under the routing the fabric actually runs.")


if __name__ == "__main__":
    main()
