"""Quickstart: the paper in 60 seconds, then one train step.

1. Build the demi-PN graph over P2(F_q) and check Theorem 3.9 numerically.
2. Ask the Section-5 selector which fabric to buy for a 10k-chip cluster.
3. Run one training step of a reduced assigned architecture on the host mesh.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.core import build_topology, utilization
from repro.core.select import select_topology


def main():
    # --- 1. the paper's object: demi-PN = modified incidence graph of P2(Fq)
    q = 9  # any prime power
    g = build_topology("demi_pn", q)
    rep = utilization(g)  # exact shortest-path edge-load counting
    u_thm = (2 * q * q + q + 1) / (2 * q * (q + 1))  # Theorem 3.9
    print(f"demi-PN(q={q}): N={g.n} routers, degree in {{{q},{q+1}}}, "
          f"diameter={rep.diameter}, kbar={rep.kbar:.4f}")
    print(f"  link utilization u = {rep.u:.6f}  (Theorem 3.9: {u_thm:.6f}, "
          f"err {abs(rep.u - u_thm):.2e})")

    pn = build_topology("pn", q)
    rep_pn = utilization(pn)
    print(f"PN(q={q}):      N={pn.n} routers, u = {rep_pn.u:.6f} "
          f"(symmetric graph -> exactly 1)")

    # --- 2. Section 5 operationalized: best fabric for 10,000 terminals,
    #        radix <= 48 routers (the paper's 'cases of use')
    print("\nOptimal fabrics for T>=10,000, R<=48 (paper Sec. 5.3):")
    for r in select_topology(10_000, max_radix=48)[:5]:
        print(f"  {r.family:10s} param={r.param:<4d} T={r.terminals:7.0f} "
              f"R={r.radix:5.1f} kbar/u={r.cost_figure:.3f}")

    # --- 3. the framework: one train step of an assigned arch (reduced)
    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.train.train_step import (TrainStepConfig, init_train_state,
                                        make_train_step)
    cfg = get_arch("smollm-135m").reduced()
    mesh = make_host_mesh(1, 1)
    step_fn, _ = make_train_step(cfg, mesh)
    state = init_train_state(cfg, jax.random.key(0), TrainStepConfig())
    tokens = jax.random.randint(jax.random.key(1), (2, 32), 0, cfg.vocab)
    state, metrics = step_fn(state, {"tokens": tokens})
    print(f"\ntrain step on {cfg.name} (reduced): "
          f"loss={float(np.asarray(metrics['loss'])):.4f}")


if __name__ == "__main__":
    main()
