"""From compiled XLA program to 'which network should the cluster buy':

Loads a dry-run cell (arch x shape, produced by repro.launch.dryrun), takes
its per-device collective byte profile, and ranks the paper's fabrics
(demi-PN / PN / Slim-Fly MMS / dragonfly / Hamming) for a target chip count
by per-step collective time AND the paper's $-and-Watts cost model.

This is Section 5 of the paper operationalized for an ML training job.

Run:  PYTHONPATH=src python examples/fabric_planner.py --arch deepseek-v3-671b \
          --shape train_4k --chips 10000
"""

import argparse
import json
import os

import numpy as np

from repro.fabric import StepProfile, plan

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-v3-671b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--chips", type=int, default=10_000)
    ap.add_argument("--radix", type=int, default=64)
    ap.add_argument("--mesh", default=None, metavar="MxD",
                    help="place a (model, data) job mesh (e.g. 16x16) and "
                         "rank fabrics by PLACED step time: the (profile, "
                         "placement) demand matrix routed under --routing, "
                         "busiest link serializing the step")
    ap.add_argument("--placement", default="group",
                    help="placement strategy for --mesh (fabric.placement "
                         "registry: linear/group/random/orbit/greedy_swap)")
    ap.add_argument("--routing", default="ugal",
                    help="routing model for --mesh pricing")
    args = ap.parse_args()

    path = os.path.join(DRYRUN_DIR, f"{args.arch}__{args.shape}__pod1.json")
    if not os.path.exists(path):
        raise SystemExit(
            f"no dry-run artifact at {path}; run\n  PYTHONPATH=src python -m "
            f"repro.launch.dryrun --arch {args.arch} --shape {args.shape}")
    with open(path) as f:
        rec = json.load(f)
    if rec.get("status") != "ok":
        raise SystemExit(f"dry-run cell status={rec.get('status')}")

    coll = rec["collective_bytes_per_device"]
    print(f"profile: {args.arch} x {args.shape} on mesh {rec['mesh']}")
    for k, v in sorted(coll.items()):
        print(f"  {k:20s} {v / 2**20:10.1f} MiB/device/step")

    prof = StepProfile.from_dryrun(rec)
    mesh = (tuple(int(t) for t in args.mesh.split("x"))
            if args.mesh else None)
    rows = plan(prof, min_terminals=args.chips, max_radix=args.radix,
                mesh_shape=mesh, placement_strategy=args.placement,
                routing=args.routing)
    print(f"\nfabric ranking for >= {args.chips} chips, radix <= {args.radix}"
          f" (paper cost model + saturation collective model"
          + (f"; {np.prod(mesh)}-chip job placed via {args.placement!r}, "
               f"priced under {args.routing}" if mesh else "") + "):")
    hdr = ("fabric", "T", "R", "kbar", "u", "kbar/u", "comm ms/step",
           "$/node", "W/node", "placed ms")
    print(f"{hdr[0]:16s} {hdr[1]:>7s} {hdr[2]:>4s} {hdr[3]:>6s} {hdr[4]:>6s} "
          f"{hdr[5]:>7s} {hdr[6]:>12s} {hdr[7]:>8s} {hdr[8]:>7s}"
          + (f" {hdr[9]:>10s}" if mesh else ""))
    for r in rows:
        placed = ("" if not mesh else
                  f" {r['placed_comm_ms']:10.3f}" if "placed_comm_ms" in r
                  else f" {'-':>10s}")
        print(f"{r['fabric']:16s} {r['terminals']:7d} {r['radix']:4d} "
              f"{r['kbar']:6.3f} {r['u']:6.3f} {r['kbar_over_u']:7.3f} "
              f"{r['step_comm_ms']:12.3f} {r['usd_per_node']:8.2f} "
              f"{r['watts_per_node']:7.2f}{placed}")
    # Every fabric here is dimensioned for full bisection (Δ0 = Δ·u/k̄), so
    # step times land within a few %; the differentiator — the paper's whole
    # point — is $/W at equal throughput.
    t_best = rows[0]["step_comm_ms"]
    near = [r for r in rows if r["step_comm_ms"] <= 1.05 * t_best]
    cheap = min(near, key=lambda r: r["usd_per_node"])
    frugal = min(near, key=lambda r: r["watts_per_node"])
    print(f"\n=> within 5% of the best step time ({t_best:.0f} ms): "
          f"{cheap['fabric']} is cheapest (${cheap['usd_per_node']}/node), "
          f"{frugal['fabric']} lowest power ({frugal['watts_per_node']} W/node)"
          f" — Section 5's conclusion, reproduced from a compiled XLA step.")


if __name__ == "__main__":
    main()
