"""Topology explorer: build any of the paper's graphs, measure it exactly,
price it with the Section-5 cost model, and stress it with traffic patterns.

Examples:
  PYTHONPATH=src python examples/topology_explorer.py --topology demi_pn --param 27
  PYTHONPATH=src python examples/topology_explorer.py --topology mms --param 19
  PYTHONPATH=src python examples/topology_explorer.py --compare 10000 --radix 48
  PYTHONPATH=src python examples/topology_explorer.py --topology pn --param 8 \\
      --patterns "uniform,tornado,bit_reversal,hot_region(0.2,4)"
  PYTHONPATH=src python examples/topology_explorer.py --topology pn --param 8 \\
      --patterns --routing ugal
"""

import argparse
import contextlib
import re

from repro import obs
from repro.core import (DirectNetworkSpec, build_topology, cable_split,
                        dollars_per_node, electrical_groups, saturation_report,
                        utilization, watts_per_node)
from repro.core.traffic import DEFAULT_SWEEP
from repro.core.moore import min_kbar, moore_bound
from repro.core.registry import TOPOLOGIES
from repro.core.select import select_topology


def inspect(name: str, param: int, delta0: float | None):
    """Prints the instance summary; returns the built graph (with its
    warmed structure cache) for further analysis."""
    g = build_topology(name, param)
    rep = utilization(g)
    print(f"{g.name}: N={g.n} |E|={g.num_edges} "
          f"degree=[{g.degrees.min()},{g.max_degree}]")
    print(f"  diameter={rep.diameter}  kbar={rep.kbar:.4f}  u={rep.u:.4f}  "
          f"kbar/u={rep.kbar / rep.u:.4f}")
    print(f"  Moore bound M(D={g.max_degree}, k={rep.diameter}) = "
          f"{moore_bound(g.max_degree, rep.diameter)}  (N/M = "
          f"{g.n / moore_bound(g.max_degree, rep.diameter):.3f})")
    kb_min = min_kbar(g.max_degree, g.n)
    print(f"  generalized-Moore minimal kbar for (Delta,N): {kb_min:.4f} "
          f"(achieved: {rep.kbar:.4f})")
    leaf = g.meta.get("leaf_mask")
    n_leaf = int(leaf.sum()) if leaf is not None else g.n
    if leaf is not None:
        # indirect network (Section 6, delta=0): Delta0 = (u/kbar)·2Δ_leaf,
        # every router keeps the same radix, all cables optical
        leaf_deg = int(g.degrees[leaf].max())
        d0 = delta0 if delta0 is not None else 2 * leaf_deg * rep.u / rep.kbar
        ne, no = 0, g.num_edges
        spec = DirectNetworkSpec(
            name=g.name, terminals=int(round(n_leaf * d0)),
            radix=int(g.degrees.max()), routers=g.n, degree=leaf_deg,
            terminals_per_router=d0, kbar=rep.kbar, u=rep.u,
            electrical_cables=ne, optical_cables=no, indirect=True)
    else:
        d0 = delta0 if delta0 is not None else g.max_degree * rep.u / rep.kbar
        labels = electrical_groups(g, d0)
        ne, no = cable_split(g, labels)
        spec = DirectNetworkSpec(
            name=g.name, terminals=int(round(n_leaf * d0)),
            radix=int(round(g.max_degree + d0)), routers=g.n,
            degree=g.max_degree, terminals_per_router=d0, kbar=rep.kbar,
            u=rep.u, electrical_cables=ne, optical_cables=no)
    print(f"  dimensioning: Delta0={d0:.2f} -> T={spec.terminals} "
          f"R={spec.radix}  cables: {ne} electrical / {no} optical")
    print(f"  cost model:  {dollars_per_node(spec):8.2f} $/node   "
          f"{watts_per_node(spec):5.2f} W/node")
    return g


def patterns_table(g, specs, routing=None, sim=False, sim_steps=None):
    """Theta/u per pattern under minimal and Valiant, plus an extra column
    for ``routing`` (e.g. "ugal": the adaptive blend and its alpha).

    With ``sim=True`` two measured columns ride along (repro.sim): the
    simulator's saturation knee under the chosen routing (per-hop
    threshold-UGAL when ``--routing`` names a ugal variant) and the
    Little's-law mean latency, in steps, at the sweep's lowest load
    point — the queueing ground truth beside the fluid closed forms."""
    extra = None if routing in (None, "minimal", "valiant") else routing
    if sim:
        from repro.sim import saturation_sweep
        from repro.sim.engine import parse_sim_routing
        sim_routing = routing if routing else "minimal"
        try:
            parse_sim_routing(sim_routing)
        except ValueError:
            # fluid-only specs (e.g. "ugal(source)") map to their
            # simulator counterpart: the per-hop threshold rule
            sim_routing = ("ugal_threshold(0)" if "ugal" in str(sim_routing)
                           else "minimal")
    print(f"{g.name}: saturation throughput theta (per-node injection, "
          f"link-equivalents) and balance u by pattern")
    head = (f"{'pattern':28s} {'theta_min':>9s} {'u_min':>7s} "
            f"{'theta_val':>9s} {'u_val':>7s} {'kbar_eff':>8s}")
    if extra:
        head += f" {'theta_' + extra[:4]:>10s} {'alpha':>6s}"
    if sim:
        head += f" {'theta_sim':>9s} {'lat_sim':>8s}"
    print(head)
    for spec in specs:
        rmin = saturation_report(g, spec, routing="minimal")
        rval = saturation_report(g, spec, routing="valiant")
        line = (f"{rmin.pattern:28s} {rmin.theta:9.4f} {rmin.u:7.4f} "
                f"{rval.theta:9.4f} {rval.u:7.4f} {rmin.kbar_eff:8.4f}")
        if extra:
            rx = saturation_report(g, spec, routing=extra)
            alpha = "" if rx.alpha is None else f"{rx.alpha:6.3f}"
            line += f" {rx.theta:10.4f} {alpha:>6s}"
        if sim:
            sw = saturation_sweep(g, spec, routing=sim_routing,
                                  steps=sim_steps, refine=1)
            line += f" {sw.theta:9.4f} {sw.latency[0]:8.2f}"
        print(line)


def compare(terminals: int, radix: int):
    print(f"feasible topologies for T>={terminals}, R<={radix} "
          f"(sorted by kbar/u, the paper's cost figure):")
    print(f"{'family':12s} {'param':>5s} {'T':>8s} {'R':>6s} {'N':>7s} "
          f"{'kbar':>6s} {'u':>6s} {'kbar/u':>7s}")
    for r in select_topology(terminals, max_radix=radix)[:12]:
        print(f"{r.family:12s} {r.param:5d} {r.terminals:8.0f} {r.radix:6.1f} "
              f"{r.routers:7.0f} {r.kbar:6.3f} {r.u:6.3f} {r.cost_figure:7.3f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--topology", choices=sorted(TOPOLOGIES), default=None)
    ap.add_argument("--param", type=int, default=7)
    ap.add_argument("--delta0", type=float, default=None)
    ap.add_argument("--compare", type=int, default=None,
                    help="terminal count to run the Section-5 selector for")
    ap.add_argument("--radix", type=int, default=48)
    ap.add_argument("--patterns", nargs="?", const=",".join(DEFAULT_SWEEP),
                    default=None, metavar="SPECS",
                    help="comma-separated traffic patterns to stress the "
                         "topology with (default sweep when bare); e.g. "
                         "'uniform,tornado,hot_region(0.2,4)'")
    ap.add_argument("--routing", default=None, metavar="MODEL",
                    help="extra routing model column for the patterns "
                         "table (any repro.core.routing spec, e.g. 'ugal' "
                         "or 'ugal(source)'); minimal and Valiant always "
                         "print")
    ap.add_argument("--sim", action="store_true",
                    help="add measured-theta and mean-latency columns from "
                         "the flow-level simulator (repro.sim) under the "
                         "--routing model (per-hop threshold-UGAL for ugal "
                         "specs); expect seconds-to-minutes per pattern on "
                         "large instances")
    ap.add_argument("--sim-steps", type=int, default=None, metavar="N",
                    help="simulator steps per load point (default: sized "
                         "from the topology's diameter)")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record a repro.obs trace of the whole run and "
                         "write it as Chrome-trace JSON (load in "
                         "chrome://tracing or ui.perfetto.dev); also prints "
                         "the top-5 spans by total time")
    ap.add_argument("--metrics", action="store_true",
                    help="capture repro.obs metrics over the run and print "
                         "the snapshot table (counters, gauges, histogram "
                         "summaries) at the end")
    ap.add_argument("--report", default=None, metavar="OUT.html",
                    help="write a single-file HTML report of the run's obs "
                         "session (gauge tiles, span table, per-step series "
                         "sparklines); implies metrics capture")
    args = ap.parse_args()
    want_obs = args.trace or args.metrics or args.report
    # full tracing when asked for a trace or a report (the report's span
    # table and series sparklines need it); metrics-only otherwise
    mode = "trace" if (args.trace or args.report) else "metrics"
    sess_cm = (obs.session(mode=mode) if want_obs
               else contextlib.nullcontext(None))
    with sess_cm as sess:
        if args.topology:
            g = inspect(args.topology, args.param, args.delta0)
            if args.patterns:
                print()
                # split on commas outside parentheses: hot_region(0.2,4)
                # is one spec
                specs = [s.strip() for s in
                         re.split(r",(?![^(]*\))", args.patterns)
                         if s.strip()]
                patterns_table(g, specs, routing=args.routing, sim=args.sim,
                               sim_steps=args.sim_steps)
        if args.compare:
            compare(args.compare, args.radix)
        if not args.topology and not args.compare:
            inspect("demi_pn", 27, None)   # the paper's 10k-node case
            print()
            compare(10_000, 48)
    if args.trace and sess is not None and sess.enabled:
        sess.write_chrome(args.trace)
        print(f"\ntrace written to {args.trace} "
              f"({len(sess.events)} spans)")
        print("top spans by total time:")
        for name, total_s, count in sess.top_spans(5):
            print(f"  {name:32s} {count:6d}x  total {total_s*1e3:9.2f} ms")
    if args.metrics and sess is not None and sess.enabled:
        print("\nmetrics snapshot:")
        snap = sess.metrics.snapshot()
        for name in sorted(snap):
            rec = snap[name]
            kind = rec.get("type")
            if kind in ("counter", "gauge"):
                print(f"  {name:40s} {rec['value']:12.4f}  ({kind})")
            else:
                print(f"  {name:40s} count={rec.get('count', 0):<6d} "
                      f"mean={rec.get('mean', 0.0):.4g} "
                      f"p99={rec.get('p99', 0.0):.4g}  ({kind})")
    if args.report and sess is not None and sess.enabled:
        from repro.obs import report as obs_report
        label = args.topology or ("compare" if args.compare else "default")
        obs_report.render_report(
            args.report,
            sessions=[(label, sess.snapshot(),
                       obs_report.session_series(sess))],
            title=f"topology explorer — {label}")
        print(f"\nreport written to {args.report}")


if __name__ == "__main__":
    main()
