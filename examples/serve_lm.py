"""Batched serving demo: submit a queue of prompts to the Engine and decode
them with continuous batching; verifies greedy decode matches the
full-forward argmax for one probe prompt.

Works for any cache family — try --arch mamba2-130m (SSD state cache) or
--arch h2o-danube-3-4b (sliding-window ring cache).

Run:  PYTHONPATH=src python examples/serve_lm.py --arch smollm-135m --requests 12
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, get_arch
from repro.models import build, unbox
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="smollm-135m")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()  # CPU-sized variant of the family
    bundle = build(cfg)
    params = unbox(bundle.init(jax.random.key(0)))
    eng = Engine(cfg, params,
                 ServeConfig(max_batch=args.max_batch, max_len=128))

    rng = np.random.default_rng(0)
    rids = []
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        rids.append((eng.submit(prompt, max_new=args.max_new), prompt))

    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in results.values())
    print(f"{args.arch} (reduced family): served {len(results)} requests, "
          f"{n_tok} tokens in {dt:.2f}s ({n_tok / dt:.1f} tok/s on 1 CPU core)")

    # consistency probe: greedy engine output == argmax of the full forward
    rid, prompt = rids[0]
    from repro.models.transformer import forward
    seq = np.concatenate([prompt, np.asarray(results[rid][:-1], np.int32)])
    logits = forward(cfg, params, jax.numpy.asarray(seq[None]), mode="train")[
        "logits"]
    want = np.asarray(jax.numpy.argmax(logits[0, len(prompt) - 1:], -1))
    got = np.asarray(results[rid], np.int32)
    match = int((want[: len(got)] == got).sum())
    print(f"greedy-vs-full-forward agreement on probe: {match}/{len(got)}")
    assert match >= len(got) - 1, "decode diverged from full forward"


if __name__ == "__main__":
    main()
