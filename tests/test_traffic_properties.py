"""Property tests for traffic-matrix invariants.

Invariants (each checked by a hypothesis-driven test AND a deterministic
seeded sweep so they are exercised even where hypothesis is unavailable
and tests/conftest.py substitutes its skipping stub):

  1. conservation — total arc load equals total demand-weighted distance:
     sum_a L_a == sum_{s,t} D[s,t] · dist(s,t), because every unit of
     demand occupies exactly dist(s,t) arcs whichever shortest path mix
     carries it.
  2. uniform equivalence — D = ones - I reproduces PR 1's uniform
     arc_loads bit-identically per engine (see also test_traffic_golden).
  3. per-source flow conservation — for a permutation pattern, the net
     outflow of each source's tree equals its injected demand: summing
     loads over arcs leaving s of traffic sourced at s is exactly D[s]
     row sum (checked via single-source runs).
"""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import demi_pn_graph, hypercube_graph, pn_graph
from repro.core.utilization import arc_loads, arc_loads_weighted
from repro.fabric.model import torus3d_graph

GRAPHS = [
    lambda: pn_graph(3),
    lambda: demi_pn_graph(4),
    lambda: torus3d_graph(3, 3, 1),
    lambda: hypercube_graph(3),
]


def _distances(g):
    from repro.core.graph import bfs_distances_batched
    return bfs_distances_batched(g, np.arange(g.n)).astype(np.float64)


def _check_conservation(g, demand, engine="numpy"):
    loads, kbar, _ = arc_loads_weighted(g, demand, engine=engine)
    d = demand.copy()
    np.fill_diagonal(d, 0.0)
    weighted_dist = float((_distances(g) * d).sum())
    assert loads.sum() == pytest.approx(weighted_dist, rel=1e-9)
    assert kbar == pytest.approx(weighted_dist / d.sum(), rel=1e-9)


def _check_flow_per_source(g, perm, weights):
    """Permutation demand: each source's tree carries exactly its injected
    demand across the arcs leaving the source."""
    n = g.n
    for s in range(n):
        t = perm[s]
        if t == s:
            continue
        d = np.zeros((n, n))
        d[s, t] = weights[s]
        loads, _, _ = arc_loads_weighted(g, d, engine="numpy")
        out_arcs = g.arc_src == s
        assert loads[out_arcs].sum() == pytest.approx(weights[s], rel=1e-9)
        # and the same amount arrives over the target's incoming arcs
        in_arcs = g.indices == t
        assert loads[in_arcs].sum() == pytest.approx(weights[s], rel=1e-9)


# ---------------------------------------------------------------------------
# hypothesis-driven (run under the real dependency; skip under the stub)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data=st.data())
def test_hyp_conservation_random_demand(data):
    g = GRAPHS[data.draw(st.integers(0, len(GRAPHS) - 1), label="graph")]()
    n = g.n
    seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
    density = data.draw(st.floats(0.05, 1.0), label="density")
    rng = np.random.default_rng(seed)
    demand = rng.random((n, n)) * (rng.random((n, n)) < density)
    if not (demand.sum(axis=1) > 0).any():
        demand[0, 1] = 1.0
    _check_conservation(g, demand)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1))
def test_hyp_uniform_reproduces_pr1_loads(seed):
    g = GRAPHS[seed % len(GRAPHS)]()
    u = np.ones((g.n, g.n)) - np.eye(g.n)
    lw = arc_loads_weighted(g, u, engine="csr")[0]
    l0 = arc_loads(g, engine="csr")[0]
    assert np.array_equal(lw, l0)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data=st.data())
def test_hyp_permutation_conserves_flow(data):
    g = GRAPHS[data.draw(st.integers(0, len(GRAPHS) - 1), label="graph")]()
    seed = data.draw(st.integers(0, 2**31 - 1), label="seed")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(g.n)
    weights = rng.random(g.n) + 0.25
    # spot-check a handful of sources (full loop is the deterministic test)
    for s in rng.choice(g.n, size=3, replace=False):
        t = perm[s]
        if t == s:
            continue
        d = np.zeros((g.n, g.n))
        d[s, t] = weights[s]
        loads, _, _ = arc_loads_weighted(g, d, engine="numpy")
        assert loads[g.arc_src == s].sum() == pytest.approx(weights[s],
                                                            rel=1e-9)


# ---------------------------------------------------------------------------
# deterministic sweeps of the same invariants (always run)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("build", GRAPHS)
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_det_conservation_random_demand(build, seed):
    g = build()
    rng = np.random.default_rng(seed)
    demand = rng.random((g.n, g.n)) * (rng.random((g.n, g.n)) < 0.4)
    demand[0, (1 + seed) % g.n] += 1.0
    _check_conservation(g, demand)
    _check_conservation(g, demand, engine="naive")


@pytest.mark.parametrize("build", GRAPHS)
def test_det_uniform_reproduces_pr1_loads(build):
    g = build()
    u = np.ones((g.n, g.n)) - np.eye(g.n)
    for eng in ["csr", "naive"]:
        assert np.array_equal(arc_loads_weighted(g, u, engine=eng)[0],
                              arc_loads(g, engine=eng)[0]), eng


@pytest.mark.parametrize("build", GRAPHS[:2])
def test_det_permutation_conserves_flow(build):
    g = build()
    rng = np.random.default_rng(7)
    perm = rng.permutation(g.n)
    weights = rng.random(g.n) + 0.25
    _check_flow_per_source(g, perm, weights)


def test_det_conservation_is_tight_for_whole_permutation():
    """The full permutation matrix at once: total load == weighted distance
    and per-source inflow/outflow hold simultaneously."""
    g = torus3d_graph(3, 3, 1)
    rng = np.random.default_rng(11)
    perm = rng.permutation(g.n)
    w = rng.random(g.n) + 0.5
    d = np.zeros((g.n, g.n))
    d[np.arange(g.n), perm] = w
    _check_conservation(g, d)
    loads, _, _ = arc_loads_weighted(g, d, engine="numpy")
    dist = _distances(g)
    # sources at distance 1 from their target: load on (s, perm[s]) arc
    for s in np.nonzero(dist[np.arange(g.n), perm] == 1)[0]:
        arc = np.nonzero((g.arc_src == s) & (g.indices == perm[s]))[0]
        assert loads[arc].sum() >= w[s] - 1e-9  # direct arc carries it all
