"""repro.core.faults: degraded-graph compilation, analytic reroute, and
the resilience sweep.

The normalization contract under test (docs/faults.md): demand is built
and normalized on the PRISTINE graph, restricted to the survivors, and
evaluated on the degraded graph — so degraded theta stays in pristine
units and theta can only go down when components die.  Conservation on
the degraded graph (sum of arc loads == demand-weighted degraded
distance) pins that the reroute really re-converged on the surviving
topology, in hypothesis form over random fault draws AND as a
deterministic seeded sweep (the test_traffic_properties convention)."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (FaultSet, demi_pn_graph, degradation_sweep,
                        degraded_report, dragonfly_graph, fault_report,
                        hypercube_graph, oft_graph, pn_graph, random_faults,
                        targeted_faults)
from repro.core.graph import bfs_distances_batched
from repro.core.orbits import automorphism_generators
from repro.core.traffic import make_pattern, normalize_demand, saturation_report
from repro.fabric.model import torus3d_graph

GRAPHS = [
    ("pn5", lambda: pn_graph(5)),
    ("demi_pn4", lambda: demi_pn_graph(4)),
    ("oft3", lambda: oft_graph(3)),
    ("torus_4x4", lambda: torus3d_graph(4, 4, 1)),
    ("hcube4", lambda: hypercube_graph(4)),
]


def _active(g):
    leaf = g.meta.get("leaf_mask")
    return None if leaf is None else np.asarray(leaf, dtype=bool)


def _degraded_conservation(g, fs, rep):
    """sum(loads) == sum(D_restricted * dist_degraded), the Brandes
    identity on the SURVIVING topology."""
    gd = fs.apply(g)
    dem = fs.restrict_demand(
        g, normalize_demand(make_pattern("uniform").demand(g, _active(g))))
    np.fill_diagonal(dem, 0.0)
    dist = bfs_distances_batched(gd, np.arange(gd.n)).astype(np.float64)
    assert rep.loads.sum() == pytest.approx(float((dist * dem).sum()),
                                            rel=1e-8)


# ---------------------------------------------------------------------------
# FaultSet: canonical identity and graph resolution
# ---------------------------------------------------------------------------


def test_faultset_canonicalization():
    fs = FaultSet(links=[(7, 3), (3, 7), (1, 2)], routers=[9, 4, 9])
    assert fs.links == ((1, 2), (3, 7))          # sorted, deduped, u < v
    assert fs.routers == (4, 9)
    assert fs == FaultSet(links=[(2, 1), (7, 3)], routers=(9, 4))
    assert fs.label == "links[1-2,3-7]+routers[4,9]"
    assert FaultSet().empty and FaultSet().label == "none"
    assert not fs.empty


def test_faultset_rejects_self_loop():
    with pytest.raises(ValueError, match="self-loop"):
        FaultSet(links=[(3, 3)])


def test_edge_ids_rejects_non_edges():
    g = pn_graph(4)
    u, v = (int(x) for x in g.edges[0])
    assert FaultSet(links=[(u, v)]).edge_ids(g).tolist() == [0]
    nonedge = None
    adj = {tuple(sorted(map(int, e))) for e in g.edges}
    for a in range(g.n):
        for b in range(a + 1, g.n):
            if (a, b) not in adj:
                nonedge = (a, b)
                break
        if nonedge:
            break
    with pytest.raises(ValueError, match="not edges"):
        FaultSet(links=[nonedge]).edge_ids(g)


def test_router_ids_out_of_range():
    g = pn_graph(4)
    with pytest.raises(ValueError, match="out of range"):
        FaultSet(routers=[g.n]).router_ids(g)


# ---------------------------------------------------------------------------
# apply: degraded-graph compilation
# ---------------------------------------------------------------------------


def test_apply_link_faults_preserves_n_and_family():
    g = torus3d_graph(4, 4, 1)
    fs = random_faults(g, k_links=3, seed=1)
    gd = fs.apply(g)
    assert gd.n == g.n
    assert gd.num_edges == g.num_edges - 3
    assert gd.meta.get("family") == g.meta.get("family")
    assert gd.meta["faults"] == fs.label
    # the removed undirected pairs are exactly fs.links
    lost = {tuple(sorted(map(int, e))) for e in g.edges} \
        - {tuple(sorted(map(int, e))) for e in gd.edges}
    assert lost == set(fs.links)


def test_apply_router_faults_relabels_survivors():
    g = pn_graph(4)
    fs = FaultSet(routers=[0, 5])
    gd = fs.apply(g)
    assert gd.n == g.n - 2
    assert "family" not in gd.meta and gd.meta["faults"] == fs.label
    surv = gd.meta["fault_survivors"]
    assert surv.tolist() == [v for v in range(g.n) if v not in (0, 5)]
    # every degraded edge maps back to a pristine edge between survivors
    adj = {tuple(sorted(map(int, e))) for e in g.edges}
    for a, b in gd.edges:
        assert tuple(sorted((int(surv[a]), int(surv[b])))) in adj


def test_apply_empty_raises():
    with pytest.raises(ValueError, match="empty FaultSet"):
        FaultSet().apply(pn_graph(4))


def test_router_faults_restrict_leaf_mask():
    g = oft_graph(3)
    leaf = np.asarray(g.meta["leaf_mask"], dtype=bool)
    dead = int(np.nonzero(~leaf)[0][0])     # kill a non-leaf router
    gd = FaultSet(routers=[dead]).apply(g)
    assert gd.meta["leaf_mask"].sum() == leaf.sum()
    assert gd.meta["leaf_mask"].shape == (g.n - 1,)


def test_degraded_graph_disables_orbit_shortcut():
    g = pn_graph(5)
    assert automorphism_generators(g) is not None
    gd = random_faults(g, k_links=1, seed=0).apply(g)
    assert automorphism_generators(gd) is None


def test_fault_report_connectivity():
    g = torus3d_graph(4, 4, 1)
    rep = fault_report(g, random_faults(g, k_links=2, seed=3))
    assert rep.connected and rep.evaluable and rep.n_components == 1
    assert rep.edges_removed == 2 and rep.n_degraded == g.n
    # cutting all 4 edges of a torus vertex isolates it
    vid = 5
    cut = [tuple(sorted(map(int, e))) for e in g.edges
           if vid in (int(e[0]), int(e[1]))]
    rep = fault_report(g, FaultSet(links=cut))
    assert not rep.connected and not rep.evaluable
    assert sorted(rep.component_sizes) == [1, g.n - 1]


def test_random_faults_deterministic_and_connected():
    g = pn_graph(5)
    a = random_faults(g, k_links=4, k_routers=1, seed=7)
    b = random_faults(g, k_links=4, k_routers=1, seed=7)
    assert a == b
    assert a != random_faults(g, k_links=4, k_routers=1, seed=8)
    assert fault_report(g, a).evaluable


# ---------------------------------------------------------------------------
# Analytic reroute: degraded theta semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name,builder", GRAPHS)
@pytest.mark.parametrize("routing", ["minimal", "ugal"])
def test_degraded_theta_below_pristine_with_conservation(name, builder,
                                                         routing):
    g = builder()
    fs = random_faults(g, k_links=2, seed=0)
    pristine = saturation_report(g, "uniform", routing=routing).theta
    rep = degraded_report(g, "uniform", fs, routing=routing)
    assert rep.theta <= pristine * (1 + 1e-9)
    assert rep.faults == fs.label
    if routing == "minimal":
        _degraded_conservation(g, fs, rep)


def test_saturation_report_faults_delegates():
    g = pn_graph(5)
    fs = random_faults(g, k_links=3, seed=2)
    via_kw = saturation_report(g, "uniform", routing="minimal", faults=fs)
    direct = degraded_report(g, "uniform", fs, routing="minimal")
    assert via_kw.theta == pytest.approx(direct.theta, rel=1e-12)
    assert via_kw.faults == fs.label
    # empty fault set falls through to the pristine path
    pristine = saturation_report(g, "uniform", routing="minimal",
                                 faults=FaultSet())
    assert pristine.faults is None


def test_degraded_router_faults_drop_demand_rows():
    """A dead router takes its injected AND addressed traffic with it:
    total degraded demand is the pristine total minus those rows/cols."""
    g = pn_graph(5)
    fs = FaultSet(routers=[3])
    dem = normalize_demand(make_pattern("uniform").demand(g, None))
    rep = degraded_report(g, "uniform", fs, routing="minimal")
    expect = dem.sum() - dem[3, :].sum() - dem[:, 3].sum()
    assert rep.total_demand == pytest.approx(expect, rel=1e-12)


def test_targeted_cut_at_least_as_damaging_as_random_mean():
    g = torus3d_graph(4, 4, 1)
    fs = targeted_faults(g, k=2, kind="links")
    assert len(fs.links) == 2 and fault_report(g, fs).evaluable
    th_t = degraded_report(g, "uniform", fs).theta
    th_r = np.mean([degraded_report(
        g, "uniform", random_faults(g, k_links=2, seed=s)).theta
        for s in range(6)])
    assert th_t <= th_r + 1e-12


def test_targeted_router_cut():
    g = pn_graph(5)
    fs = targeted_faults(g, k=1, kind="routers")
    assert len(fs.routers) == 1
    assert degraded_report(g, "uniform", fs).theta \
        <= saturation_report(g, "uniform").theta + 1e-12


# ---------------------------------------------------------------------------
# degradation_sweep
# ---------------------------------------------------------------------------


def test_degradation_sweep_curves():
    g = pn_graph(5)
    sw = degradation_sweep(g, k_failures=(0, 1, 3), trials=4, seed=0)
    assert sw.thetas.shape == (4, 3)
    # k=0 column is the pristine theta, exactly
    assert np.allclose(sw.thetas[:, 0], sw.pristine_theta)
    # nested prefixes -> every trial's curve is monotone non-increasing
    assert (np.diff(sw.thetas, axis=1) <= 1e-12).all()
    assert (np.diff(sw.mean) <= 1e-12).all()
    assert (sw.worst <= sw.mean + 1e-12).all()
    assert (sw.mean <= sw.best + 1e-12).all()
    assert set(sw.bands) == {10, 50, 90}
    # seeded determinism
    sw2 = degradation_sweep(g, k_failures=(0, 1, 3), trials=4, seed=0)
    np.testing.assert_array_equal(sw.thetas, sw2.thetas)


def test_degradation_sweep_router_kind():
    g = demi_pn_graph(4)
    sw = degradation_sweep(g, k_failures=(0, 1, 2), trials=3, kind="routers",
                           seed=1)
    assert (np.diff(sw.thetas, axis=1) <= 1e-12).all()
    with pytest.raises(ValueError, match="unknown fault kind"):
        degradation_sweep(g, kind="switches")


# ---------------------------------------------------------------------------
# Adversary / placement / planner wiring
# ---------------------------------------------------------------------------


def test_worst_case_on_degraded_graph():
    from repro.core.adversary import worst_case
    g = torus3d_graph(4, 4, 1)
    fs = random_faults(g, k_links=2, seed=0)
    pristine = worst_case(g, model="minimal", n_random=2)
    degraded = worst_case(g, model="minimal", n_random=2, faults=fs)
    assert degraded.worst_theta <= pristine.worst_theta + 1e-12


def test_placement_report_faults():
    from repro.fabric import StepProfile, place_mesh, placement_report
    g = demi_pn_graph(9)
    p = place_mesh(g, (8, 8), ("data", "model"), 4, "group")
    prof = StepProfile({"all-to-all": 8e9, "all-reduce": 1e9})
    pristine = placement_report(p, prof, routing="minimal")
    fs = random_faults(g, k_links=2, seed=0)
    degraded = placement_report(p, prof, routing="minimal", faults=fs)
    assert degraded.faults == fs.label and pristine.faults is None
    assert degraded.theta <= pristine.theta * (1 + 1e-9)


def test_planner_resilience_columns():
    from repro.fabric import StepProfile, plan
    prof = StepProfile(bytes_by_kind={"all-reduce": 1e9, "all-to-all": 1e8})
    rows = plan(prof, min_terminals=100, resilience_k=1, resilience_trials=2)
    small = [r for r in rows if "resilience_theta" in r]
    assert small, "no candidate got resilience columns"
    for r in small:
        assert r["resilience_k"] == 1
        assert 0 < r["resilience_frac"] <= 1.0 + 1e-9
        assert r["resilience_theta"] > 0


# ---------------------------------------------------------------------------
# Property: degraded theta <= pristine + conservation, random fault sets
# (hypothesis AND a deterministic seeded twin, per repo convention)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(gi=st.integers(0, len(GRAPHS) - 1), seed=st.integers(0, 2 ** 16),
       k=st.integers(1, 3))
def test_property_degraded_theta_and_conservation(gi, seed, k):
    g = GRAPHS[gi][1]()
    fs = random_faults(g, k_links=k, seed=seed)
    rep = degraded_report(g, "uniform", fs, routing="minimal")
    assert rep.theta <= saturation_report(g, "uniform").theta * (1 + 1e-9)
    _degraded_conservation(g, fs, rep)


def test_property_degraded_theta_deterministic_twin():
    for gi in range(len(GRAPHS)):
        g = GRAPHS[gi][1]()
        for seed, k in [(0, 1), (1, 2), (2, 3)]:
            fs = random_faults(g, k_links=k, seed=seed)
            rep = degraded_report(g, "uniform", fs, routing="minimal")
            assert rep.theta \
                <= saturation_report(g, "uniform").theta * (1 + 1e-9)
            _degraded_conservation(g, fs, rep)
