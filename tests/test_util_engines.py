"""Parity of the batched arc-load engines against the naive reference.

The batched engines (numpy dense generic / dense bipartite / CSR, jax,
orbit shortcut) must reproduce the naive per-source Brandes accumulation
to float64 round-off on every family the paper uses, including the
leaf-restricted indirect networks."""

import numpy as np
import pytest

from repro.core import (
    Graph,
    bfs_distances,
    bfs_distances_batched,
    complete_bipartite_graph,
    complete_graph,
    demi_pn_graph,
    distance_distribution,
    hamming_graph,
    hypercube_graph,
    mlfm_graph,
    mms_graph,
    oft_graph,
    orbit_info,
    paley_graph,
    pn_graph,
    turan_graph,
    utilization,
)
from repro.core.utilization import arc_loads

FAMILIES = [
    lambda: pn_graph(8),            # bipartite fast path, diameter 3
    lambda: demi_pn_graph(9),       # dense generic
    lambda: oft_graph(4),           # bipartite + leaf mask (below)
    lambda: mlfm_graph(5),          # bipartite indirect
    lambda: mms_graph(9),           # dense generic, 2 orbits
    lambda: hamming_graph(5, 2),    # vertex-transitive, non-bipartite
    lambda: hypercube_graph(5),     # bipartite, diameter 5, sigma > 1
    lambda: turan_graph(10, 3),     # no known automorphism generators
]


def _ring(n):
    e = np.stack([np.arange(n), (np.arange(n) + 1) % n], axis=1)
    return Graph(n, e, name=f"ring{n}")


@pytest.mark.parametrize("build", FAMILIES)
@pytest.mark.parametrize("engine", ["numpy", "csr", "auto"])
def test_engine_parity_vs_naive(build, engine):
    g = build()
    tm = g.meta.get("leaf_mask")
    ref_loads, ref_kbar, ref_diam = arc_loads(g, targets_mask=tm, engine="naive")
    loads, kbar, diam = arc_loads(g, targets_mask=tm, engine=engine)
    np.testing.assert_allclose(loads, ref_loads, rtol=1e-9, atol=1e-9)
    assert kbar == pytest.approx(ref_kbar, abs=1e-12)
    assert diam == ref_diam


@pytest.mark.parametrize("n", [12, 13])  # even ring = bipartite, odd = not
def test_engine_parity_deep_diameter(n):
    g = _ring(n)
    ref = arc_loads(g, engine="naive")
    for engine in ["numpy", "csr"]:
        got = arc_loads(g, engine=engine)
        np.testing.assert_allclose(got[0], ref[0], rtol=1e-9, atol=1e-9)
        assert got[2] == ref[2]


@pytest.mark.parametrize("build", [
    lambda: pn_graph(8), lambda: demi_pn_graph(9), lambda: oft_graph(4),
    lambda: mlfm_graph(5), lambda: mms_graph(9), lambda: hamming_graph(5, 2),
])
def test_orbit_engine_parity(build):
    g = build()
    tm = g.meta.get("leaf_mask")
    ref = arc_loads(g, targets_mask=tm, engine="naive")
    got = arc_loads(g, targets_mask=tm, engine="orbit")
    np.testing.assert_allclose(got[0], ref[0], rtol=1e-9, atol=1e-9)
    assert got[1] == pytest.approx(ref[1], abs=1e-12)
    assert got[2] == ref[2]


def test_orbit_engine_rejects_unknown_family():
    with pytest.raises(ValueError, match="automorphism"):
        arc_loads(turan_graph(10, 3), engine="orbit")


def test_orbit_counts_match_theory():
    # PN is vertex- and arc-transitive (PGL + point-line duality);
    # demi-PN has the 3 PGO orbits (isotropic + two norm classes);
    # OFT has the leaf/spine column symmetry the paper leans on.
    assert orbit_info(pn_graph(8)).n_vertex_orbits == 1
    assert len(orbit_info(pn_graph(8)).arc_sizes) == 1
    assert orbit_info(demi_pn_graph(9)).n_vertex_orbits == 3
    assert orbit_info(oft_graph(4)).n_vertex_orbits == 2
    assert orbit_info(mms_graph(9)).n_vertex_orbits == 2
    assert orbit_info(hamming_graph(5, 2)).n_vertex_orbits == 1


def test_jax_engine_parity():
    jax = pytest.importorskip("jax")
    del jax
    for g in [pn_graph(5), hypercube_graph(4)]:
        ref = arc_loads(g, engine="naive")
        got = arc_loads(g, engine="jax")
        np.testing.assert_allclose(got[0], ref[0], rtol=1e-9, atol=1e-9)
        assert got[2] == ref[2]


def test_disconnected_graph_raises():
    g = Graph(4, np.array([[0, 1], [2, 3]]))
    for engine in ["naive", "numpy", "csr", "auto"]:
        with pytest.raises(ValueError, match="disconnected"):
            arc_loads(g, engine=engine)


def test_trailing_isolated_vertex():
    """A degree-0 vertex with the highest index must report unreachable
    (-1), not crash the CSR reduceat sweep (offset == n_arcs)."""
    import repro.core.graph as graph_mod
    g = Graph(4, np.array([[0, 1], [1, 2]]))  # vertex 3 isolated
    dist = graph_mod._bfs_block_csr(g, np.array([0]))
    np.testing.assert_array_equal(dist[0], [0, 1, 2, -1])
    with pytest.raises(ValueError, match="disconnected"):
        arc_loads(g, engine="csr")


def test_oft_leaf_restricted_targets_mask():
    """Section 6: OFT traffic restricted to leaves gives u = 1, kbar = 2,
    identically across engines — including the orbit shortcut, which must
    use only mask-preserving automorphisms."""
    g = oft_graph(4)
    leaf = g.meta["leaf_mask"]
    ref = arc_loads(g, targets_mask=leaf, engine="naive")
    for engine in ["numpy", "csr", "orbit", "auto"]:
        loads, kbar, diam = arc_loads(g, targets_mask=leaf, engine=engine)
        np.testing.assert_allclose(loads, ref[0], rtol=1e-9, atol=1e-9)
        assert kbar == pytest.approx(2.0)
        assert diam == 2
    rep = utilization(g)  # leaf_mask picked up from meta
    assert rep.u == pytest.approx(1.0, abs=1e-10)


def test_explicit_sources_subset_parity():
    g = demi_pn_graph(8)
    srcs = np.array([0, 3, 17, 40])
    ref = arc_loads(g, sources=srcs, engine="naive")
    for engine in ["numpy", "csr", "auto"]:
        got = arc_loads(g, sources=srcs, engine=engine)
        np.testing.assert_allclose(got[0], ref[0], rtol=1e-9, atol=1e-9)
        assert got[1] == pytest.approx(ref[1], abs=1e-12)


def test_engine_flag_selection():
    from repro import perf
    g = pn_graph(4)
    ref = arc_loads(g, engine="naive")
    old = perf.flags().util_engine
    try:
        perf.set_flags(util_engine="numpy")
        got = arc_loads(g)  # no explicit engine: flag applies
        np.testing.assert_allclose(got[0], ref[0], rtol=1e-9, atol=1e-9)
        with pytest.raises(ValueError, match="unknown engine"):
            arc_loads(g, engine="warp-drive")
    finally:
        perf.set_flags(util_engine=old)


def test_batched_bfs_matches_single_source():
    for g in [pn_graph(5), mms_graph(9), _ring(13)]:
        dist = bfs_distances_batched(g, np.arange(g.n))
        for s in range(0, g.n, max(1, g.n // 7)):
            np.testing.assert_array_equal(dist[s], bfs_distances(g, s))


def test_batched_bfs_csr_path():
    """Force the CSR sweep (used beyond util_dense_max) on a small graph."""
    import repro.core.graph as graph_mod
    g = mms_graph(9)
    dense = bfs_distances_batched(g, np.arange(g.n))
    sparse = np.vstack([graph_mod._bfs_block_csr(g, np.arange(g.n))])
    np.testing.assert_array_equal(dense, sparse)


def test_distance_distribution_consistency():
    g = demi_pn_graph(9)
    w = distance_distribution(g)
    assert w[0] == 1.0
    # demi-PN(q) is diameter 2 with N-1 reachable peers per vertex
    assert len(w) == 3
    assert w[1] + w[2] == pytest.approx(g.n - 1)
    # vertex-transitive family: single-source distribution is exact
    h = hamming_graph(5, 2)
    np.testing.assert_allclose(distance_distribution(h, [0]),
                               distance_distribution(h), rtol=1e-9)


def test_loads_conservation_across_engines():
    g = mms_graph(9)
    for engine in ["numpy", "orbit"]:
        loads, kbar, _ = arc_loads(g, engine=engine)
        assert loads.sum() == pytest.approx(kbar * g.n * (g.n - 1))
