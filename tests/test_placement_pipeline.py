"""The placement-aware demand pipeline (PR 4): (StepProfile, Placement)
-> router demand matrix -> routing registry.

Covers the parity satellite (old ECMP link_loads accounting vs the
weighted engines), placement theta semantics, the strategy registry
(orbit shortcut, greedy determinism), the fragmentation sweep, and the
planner wiring.
"""

import importlib

import numpy as np
import pytest

from repro.core import build_topology, dragonfly_graph, oft_graph, pn_graph
from repro.core.graph import bfs_distances_batched
from repro.core.traffic import saturation_report
from repro.fabric import (FabricModel, StepProfile, collective_traffic,
                          evaluate_placements, fragmentation_sweep,
                          greedy_improve, link_loads, place_mesh,
                          placement_demand, placement_report,
                          placement_search, placement_step_seconds,
                          schedule_from_profile)
from repro.fabric.model import torus3d_graph
from repro.fabric.placement import chip_wire_bytes

MESH = (8, 8)
AXES = ("data", "model")
TRAFFIC = {"data": ("ring", 1.0), "model": ("all_to_all", 1.0)}
PROFILE = StepProfile({"all-to-all": 8e9, "all-reduce": 1e9})  # EP-heavy


def _ecmp_link_loads(p, traffic):
    """Inline replica of the pre-PR 4 link_loads: per-source BFS with
    equal next-hop (ECMP) split, the accounting the shim replaced."""
    g = p.graph
    src, dst, byts = traffic
    rs, rd = p.router_of[src], p.router_of[dst]
    key = rs * g.n + rd
    agg = np.zeros(g.n * g.n)
    np.add.at(agg, key, byts)
    dist = bfs_distances_batched(g, np.arange(g.n)).astype(np.int64)
    arc_load = np.zeros(len(g.indices))
    for s in range(g.n):
        demand = agg[s * g.n: (s + 1) * g.n].copy()
        demand[s] = 0.0
        if not demand.any():
            continue
        order = np.argsort(dist[s])
        down = demand.copy()
        for v in order[::-1]:
            if v == s or down[v] <= 0:
                continue
            lo, hi = g.indptr[v], g.indptr[v + 1]
            nbrs = g.indices[lo:hi]
            preds = lo + np.nonzero(dist[s][nbrs] == dist[s][v] - 1)[0]
            if len(preds) == 0:
                continue
            share = down[v] / len(preds)
            for a in preds:
                u = g.indices[a]
                lo_u, hi_u = g.indptr[u], g.indptr[u + 1]
                arc = lo_u + int(np.nonzero(g.indices[lo_u:hi_u] == v)[0][0])
                arc_load[arc] += share
                down[u] += share
    return arc_load


# ---------------------------------------------------------------------------
# Satellite: parity of the old byte accounting vs the weighted engines
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("builder", [
    lambda: build_topology("demi_pn", 9),   # diameter 2
    lambda: oft_graph(4),                   # indirect, diameter 2 on leaves
    lambda: torus3d_graph(4, 4, 4),
])
def test_link_loads_parity_with_ecmp_oracle(builder):
    """On the paper's families (and the torus reference) the ECMP
    per-hop split coincides with the equal-path split of the weighted
    engines arc-by-arc: pin (near-)bit-identity under minimal routing."""
    g = builder()
    p = place_mesh(g, MESH, AXES, 2, "random", seed=5)
    traffic = collective_traffic(MESH, AXES, TRAFFIC)
    old = _ecmp_link_loads(p, traffic)
    new = link_loads(p, traffic, routing="minimal")["loads"]
    np.testing.assert_allclose(new, old, rtol=1e-12, atol=1e-12 * old.max())


def test_link_loads_ecmp_delta_documented_on_dragonfly():
    """Dragonfly's unbalanced shortest-path DAGs are where ECMP per-hop
    split and equal-path split genuinely differ: golden-pin the
    normalization delta (per-arc ~12% at this seed) while byte-hops —
    sum(loads) == sum(bytes x dist) — stay identical, so both
    accountings conserve the same total work."""
    g = dragonfly_graph(3)
    p = place_mesh(g, MESH, AXES, 2, "random", seed=5)
    traffic = collective_traffic(MESH, AXES, TRAFFIC)
    old = _ecmp_link_loads(p, traffic)
    new = link_loads(p, traffic, routing="minimal")["loads"]
    assert old.sum() == pytest.approx(new.sum(), rel=1e-12)
    rel = np.abs(old - new).max() / old.max()
    assert 0.05 < rel < 0.2  # the split difference is real but bounded


def test_link_loads_routing_registry():
    """The shim accepts any registered routing model; Valiant's byte-hops
    exceed minimal's (detour), ugal's max load is <= both."""
    g = build_topology("demi_pn", 9)
    p = place_mesh(g, MESH, AXES, 2, "linear")
    traffic = collective_traffic(MESH, AXES, TRAFFIC)
    r_min = link_loads(p, traffic, routing="minimal")
    r_val = link_loads(p, traffic, routing="valiant")
    r_ugal = link_loads(p, traffic, routing="ugal")
    assert r_val["loads"].sum() > r_min["loads"].sum()
    assert r_ugal["max"] <= min(r_min["max"], r_val["max"]) * (1 + 1e-12)


# ---------------------------------------------------------------------------
# placement_demand semantics
# ---------------------------------------------------------------------------


def test_placement_demand_uniform_shape_for_spanning_group():
    """A single model group, one chip per router across the whole fabric,
    compiles to uniform-shaped demand w * (ones - I) — exactly the shape
    the orbit shortcut accepts."""
    g = pn_graph(4)
    p = place_mesh(g, (1, g.n), ("data", "model"), 1, "linear")
    d = placement_demand({"model": ("all_to_all", 3.0)}, p)
    w = 3.0 / g.n
    expect = w * (np.ones((g.n, g.n)) - np.eye(g.n))
    np.testing.assert_allclose(d, expect, rtol=1e-12)


def test_placement_demand_conserves_off_router_bytes():
    g = build_topology("demi_pn", 9)
    p = place_mesh(g, MESH, AXES, 4, "group", seed=1)
    traffic = collective_traffic(MESH, AXES, TRAFFIC)
    d = placement_demand(TRAFFIC, p)
    src, dst, byts = traffic
    off = p.router_of[src] != p.router_of[dst]
    assert d.sum() == pytest.approx(byts[off].sum(), rel=1e-12)
    assert np.diagonal(d).sum() == 0.0


def test_schedule_from_profile_byte_accounting():
    """StepProfile kinds map onto mesh axes with fabric.collectives' wire
    accounting: an all-gather of b bytes equals an all-reduce of b/2
    (half the wire bytes), a2a kinds ride the model axis."""
    sched = schedule_from_profile(
        StepProfile({"all-reduce": 4.0, "all-gather": 2.0,
                     "all-to-all": 6.0, "collective-permute": 1.0,
                     "reduce-scatter": 0.0}),
        ("data", "model"))
    assert sched["data"] == ("ring", pytest.approx(5.0))   # 4 + 2/2
    assert sched["model"] == ("all_to_all", pytest.approx(7.0))

    with pytest.raises(ValueError, match="unknown collective kind"):
        schedule_from_profile(StepProfile({"broadcast": 1.0}), AXES)
    with pytest.raises(ValueError, match="no 'model' axis"):
        schedule_from_profile(StepProfile({"all-to-all": 1.0}),
                              ("data", "pod"))
    # zero-byte ops drop out entirely
    assert schedule_from_profile(StepProfile({"all-to-all": 0.0}),
                                 ("data",)) == {}


def test_placement_theta_scale_invariant():
    """theta is normalized by per-chip wire bytes, so scaling the payload
    leaves it unchanged (Eq. 1 semantics, comparable across fabrics)."""
    g = build_topology("demi_pn", 9)
    p = place_mesh(g, MESH, AXES, 4, "group")
    r1 = placement_report(p, StepProfile({"all-to-all": 1e9}),
                          routing="minimal")
    r7 = placement_report(p, StepProfile({"all-to-all": 7e9}),
                          routing="minimal")
    assert r1.theta == pytest.approx(r7.theta, rel=1e-12)
    assert chip_wire_bytes({"model": ("all_to_all", 8.0)}, MESH, AXES) \
        == pytest.approx(8.0 * 7 / 8)


def test_placement_report_all_local_raises():
    g = build_topology("demi_pn", 9)
    p = place_mesh(g, (1, 8), ("data", "model"), 8, "linear")
    with pytest.raises(ValueError, match="router-local"):
        placement_report(p, {"model": ("all_to_all", 1.0)})


# ---------------------------------------------------------------------------
# Acceptance: end-to-end through the registry; search beats linear on pn16
# ---------------------------------------------------------------------------


def test_saturation_report_on_placement_demand_ugal():
    g = pn_graph(8)
    p = place_mesh(g, MESH, AXES, 2, "group")
    rep = saturation_report(g, placement_demand(PROFILE, p), routing="ugal")
    assert rep.theta > 0
    assert rep.routing == "ugal"
    assert rep.alpha is not None


def test_search_beats_linear_on_pn16_nonuniform():
    """The headline claim: under the routing the fabric actually runs
    (ugal), placement search strictly beats the naive linear baseline's
    theta on pn16 for an EP-heavy profile (also recorded in
    BENCH_4.json)."""
    g = pn_graph(16)
    out = placement_search(g, (16, 16), ("model", "data"), 8, PROFILE,
                           strategies=("linear", "group", "random"),
                           routing="ugal")
    rows = out["rows"]
    assert rows[out["best"]]["theta"] > rows["linear"]["theta"]


def test_placement_search_adversary_scores_occupied_set():
    g = build_topology("demi_pn", 9)
    out = placement_search(g, (4, 8), AXES, 2, {"model": ("all_to_all", 1.0)},
                           strategies=("linear", "random"),
                           routing="minimal", adversary=True, n_random=2)
    for row in out["rows"].values():
        assert 0 < row["adv_theta"] <= row["theta"] * 10  # sane scale
        assert isinstance(row["adv_pattern"], str)


# ---------------------------------------------------------------------------
# Strategy registry: orbit + greedy
# ---------------------------------------------------------------------------


def test_orbit_strategy_fills_leaf_columns_first():
    g = oft_graph(4)  # 63 routers, 42 leaves
    leaf = g.meta["leaf_mask"]
    p = place_mesh(g, (4, 8), AXES, 1, "orbit")
    assert leaf[p.router_of].all()
    # linear ploughs straight through the spine columns
    p_lin = place_mesh(g, (4, 8), AXES, 1, "linear")
    assert not leaf[p_lin.router_of].all()


def test_orbit_placement_hits_orbit_shortcut(monkeypatch):
    """A model group spanning the whole fabric one-chip-per-router
    produces uniform-shaped demand, so the weighted engines reroute
    through PR 1's orbit shortcut (the point of the orbit strategy)."""
    util = importlib.import_module("repro.core.utilization")
    g = pn_graph(4)
    hits = []
    real = util._loads_orbit

    def spy(*a, **kw):
        hits.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(util, "_loads_orbit", spy)
    p = place_mesh(g, (1, g.n), ("data", "model"), 1, "orbit")
    rep = placement_report(p, {"model": ("all_to_all", 1.0)},
                           routing="minimal", engine="auto")
    assert hits, "spanning-group placement demand missed the orbit path"
    assert rep.theta > 0


def test_greedy_improve_deterministic_and_monotone():
    g = build_topology("demi_pn", 9)
    traffic = collective_traffic(MESH, AXES, TRAFFIC)
    p0 = place_mesh(g, MESH, AXES, 2, "random", seed=3)
    base = link_loads(p0, traffic)["max"]
    p_a, best_a, hist = greedy_improve(p0, traffic, iters=40, seed=4,
                                       return_history=True)
    p_b, best_b = greedy_improve(p0, traffic, iters=40, seed=4)
    # seed-deterministic: identical assignment and objective
    np.testing.assert_array_equal(p_a.router_of, p_b.router_of)
    assert best_a == best_b
    # monotone non-increasing objective, never worse than the start
    assert hist[0] == pytest.approx(base)
    assert all(a >= b for a, b in zip(hist, hist[1:]))
    assert best_a <= base


def test_greedy_swap_strategy_needs_schedule():
    g = build_topology("demi_pn", 9)
    with pytest.raises(ValueError, match="schedule"):
        place_mesh(g, MESH, AXES, 2, "greedy_swap")
    p = place_mesh(g, MESH, AXES, 2, "greedy_swap(20)", schedule=TRAFFIC)
    lin = place_mesh(g, MESH, AXES, 2, "group")
    traffic = collective_traffic(MESH, AXES, TRAFFIC)
    assert link_loads(p, traffic)["max"] <= link_loads(lin, traffic)["max"]


def test_place_mesh_rejects_oversubscription():
    from repro.fabric import PlacementStrategy
    g = build_topology("demi_pn", 9)
    bad = PlacementStrategy(
        "bad", lambda g, mesh, axes, d0, **kw:
        np.zeros(int(np.prod(mesh)), dtype=np.int64))
    with pytest.raises(ValueError, match="oversubscribed"):
        place_mesh(g, MESH, AXES, 2, bad)


# ---------------------------------------------------------------------------
# Satellite: fragmentation — packed vs interleaved vs linear
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("builder,mesh,delta0", [
    (lambda: pn_graph(16), (16, 16), 8),
    (lambda: dragonfly_graph(3), (8, 8), 4),
])
def test_packed_dominates_fragmented_under_tornado_ugal(builder, mesh, delta0):
    """Two co-tenant EP-heavy jobs, interleaved (router terminals split
    between tenants, every model group forced off-router) vs packed
    (groups on whole routers): packed strictly dominates both the
    fragmented and the chip-major linear layout under tornado background
    + ugal routing."""
    g = builder()
    jobs = [(mesh, ("model", "data"), PROFILE)] * 2
    out = fragmentation_sweep(g, jobs, delta0, routing="ugal",
                              background="tornado")
    rows = out["layouts"]
    assert out["best"] == "packed"
    assert rows["packed"]["theta"] > rows["interleaved"]["theta"]
    assert rows["packed"]["theta"] > rows["linear"]["theta"]


# ---------------------------------------------------------------------------
# Planner wiring
# ---------------------------------------------------------------------------


def test_placement_step_seconds_prices_busiest_link():
    g = build_topology("demi_pn", 9)
    fab = FabricModel(g, terminals_per_router=4)
    p = fab.place(MESH, AXES, strategy="group")
    t_group = placement_step_seconds(fab, PROFILE, p, routing="minimal")
    d = placement_demand(PROFILE, p)
    from repro.core import arc_loads_weighted
    loads, kbar, _ = arc_loads_weighted(g, d)
    expect = loads.max() / fab.link_bytes_per_s
    assert t_group == pytest.approx(expect, rel=1e-6, abs=1e-4)
    # all-local placement is free on the fabric
    p_local = fab.place((1, 4), AXES, strategy="linear")
    assert placement_step_seconds(
        fab, {"model": ("all_to_all", 1e9)}, p_local) == 0.0


def test_fabric_model_placement_report_wiring():
    g = pn_graph(8)
    fab = FabricModel(g, terminals_per_router=2)
    p = fab.place(MESH, AXES)
    rep = fab.placement_report(PROFILE, p, routing="ugal")
    assert rep.routing == "ugal"
    assert rep.theta > 0


def test_adversary_accepts_router_id_lists():
    from repro.core.adversary import worst_case
    g = pn_graph(4)
    ids = np.arange(8)
    mask = np.zeros(g.n, dtype=bool)
    mask[ids] = True
    a = worst_case(g, "minimal", n_random=2, targets_mask=ids)
    b = worst_case(g, "minimal", n_random=2, targets_mask=mask)
    assert a.worst_pattern == b.worst_pattern
    assert a.worst_theta == pytest.approx(b.worst_theta, rel=1e-12)
