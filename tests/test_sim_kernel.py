"""The fused sparse-destination step kernel seam (repro.sim.kernel +
repro.kernels.sim_step/mask_gemm) and the PR's sim-reporting fixes.

Parity contract: the dense numpy float64 engine is the oracle.
``backend="pallas"`` on CPU runs the same blocked sparse-dest algebra in
numpy (bit-level comparable at float64); ``backend="pallas_interpret"``
runs the actual pallas kernel through the interpreter — same fluid, TPU
summation order, so float64 agreement to round-off.  Dest compaction
(minimal routing only) must be EXACT: dropping never-addressed dest
columns is a reindexing, not an approximation.

The reporting regressions pinned here:
  * run histories are normalized per fault segment (a pre-event curve
    segment is in pre-event surviving-demand units);
  * saturation_sweep curves include every probe (bracket extensions and
    bisection refinements), sorted by offered load;
  * default_steps sizes from the max distance over the run's fault
    segments, not just the pristine tables.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import pn_graph, random_faults
from repro.core.traffic import make_pattern, normalize_demand
from repro.core.utilization import arc_loads, arc_loads_weighted
from repro.fabric.model import torus3d_graph
from repro.sim import (SIM_MAX_CELLS, SimConfig, Simulator, saturation_sweep)
from repro.sim.kernel import SPARSE_BACKENDS, resolve_dtype

G16 = torus3d_graph(4, 4, 1)
PN3 = pn_graph(3)


def _uniform(g):
    return normalize_demand(make_pattern("uniform").demand(g, None))


def _random_demand(g, seed, density=0.4):
    rng = np.random.default_rng(seed)
    dem = rng.random((g.n, g.n)) * (rng.random((g.n, g.n)) < density)
    np.fill_diagonal(dem, 0.0)
    for r in np.nonzero(dem.sum(axis=1) == 0)[0]:  # no all-zero rows
        dem[r, (r + 1) % g.n] = 0.5
    return normalize_demand(dem)


def _histories_close(a, b, rtol, atol=1e-12):
    for key in ("delivered", "accepted", "offered", "occupancy",
                "src_backlog", "diverted"):
        np.testing.assert_allclose(
            a.history[key], b.history[key], rtol=rtol, atol=atol,
            err_msg=f"history[{key!r}] diverges")


def _run_backend(g, demand, backend, routing="minimal", offered=0.5,
                 steps=24, buffer=float("inf"), events=None):
    cfg = SimConfig(routing=routing, backend=backend, dtype="float64",
                    buffer=buffer)
    return Simulator(g, cfg, demand=demand).run(demand, offered, steps,
                                                events=events)


# ---------------------------------------------------------------------------
# numpy vs pallas step parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("routing", ["minimal", "valiant",
                                     "ugal_threshold(0)"])
def test_fused_numpy_matches_dense_float64(routing):
    """The CPU 'pallas' backend (blocked sparse-dest numpy) against the
    dense oracle, all routing modes, float64: round-off-level identity."""
    dem = _uniform(G16)
    a = _run_backend(G16, dem, "numpy", routing, offered=0.7)
    b = _run_backend(G16, dem, "pallas", routing, offered=0.7)
    _histories_close(a, b, rtol=1e-9)
    assert a.residual < 1e-9 and b.residual < 1e-9


@pytest.mark.parametrize("seed", [0, 1])
def test_interpret_kernel_parity_random_demand(seed):
    """The ACTUAL pallas kernel (interpret mode) against the dense numpy
    oracle on random demand with finite buffers and a mid-run fault —
    the ISSUE's property test, float64 end to end."""
    dem = _random_demand(G16, seed)
    fs = random_faults(G16, k_links=3, seed=seed)
    kw = dict(routing="ugal_threshold(0)", offered=0.6, steps=24,
              buffer=6.0, events=[(8, fs)])
    a = _run_backend(G16, dem, "numpy", **kw)
    b = _run_backend(G16, dem, "pallas_interpret", **kw)
    # the kernel's TPU summation order differs from the dense einsum's;
    # the threshold rule amplifies that round-off through its diversion
    # decisions, so float64 agreement is ~1e-8, not 1e-15
    _histories_close(a, b, rtol=1e-6, atol=1e-9)
    assert b.residual < 1e-7


def test_sparse_dest_compaction_is_exact():
    """Empty dest columns (a permutation over half the routers) must not
    change the fluid: compacted sparse-dest run == dense run, and the
    compaction must actually have happened."""
    rng = np.random.default_rng(3)
    sub = rng.choice(G16.n, size=8, replace=False)
    dem = np.zeros((G16.n, G16.n))
    dem[sub, np.roll(sub, 1)] = 1.0  # cycle permutation on the subset
    dem = normalize_demand(dem)

    cfg = SimConfig(routing="minimal", backend="pallas", dtype="float64")
    sim = Simulator(G16, cfg, demand=dem)
    assert len(sim.active) == 8  # compacted to the populated columns

    a = _run_backend(G16, dem, "numpy", offered=0.8)
    b = sim.run(dem, 0.8, 24)
    _histories_close(a, b, rtol=1e-9)


def test_compaction_gated_to_minimal():
    """ugal spreads diversions over the whole active set; compaction
    would change the intermediate pool, so it must not trigger."""
    dem = np.zeros((G16.n, G16.n))
    dem[0, 1] = dem[1, 0] = 1.0
    cfg = SimConfig(routing="ugal_threshold(0)", backend="pallas")
    assert len(Simulator(G16, cfg, demand=dem).active) == G16.n


def test_backend_and_dtype_resolution():
    assert set(SPARSE_BACKENDS) == {"pallas", "pallas_interpret"}
    assert resolve_dtype("auto", "pallas") == np.float32
    assert resolve_dtype("auto", "numpy") == np.float64
    assert resolve_dtype("float32", "numpy") == np.float32
    with pytest.raises(ValueError):
        resolve_dtype("bf16", "pallas")
    # auto escalates to the sparse step above the dense cell cap, and
    # the sparse backends pass through untouched at any size
    from repro.sim.engine import pick_backend
    assert pick_backend("auto", SIM_MAX_CELLS + 1) == "pallas"
    assert pick_backend("pallas", SIM_MAX_CELLS + 1) == "pallas"
    assert pick_backend("pallas_interpret", 10) == "pallas_interpret"


def test_dense_backend_above_cap_names_the_escape_hatch():
    g27 = pn_graph(27)  # 1514 routers: 64.2M dense cells > SIM_MAX_CELLS
    assert g27.n * g27.max_degree * g27.n > SIM_MAX_CELLS
    with pytest.raises(ValueError, match="pallas"):
        Simulator(g27, SimConfig(backend="numpy"))


# ---------------------------------------------------------------------------
# utilization: the mask+GEMM kernel engine
# ---------------------------------------------------------------------------


def test_util_pallas_engine_uniform():
    l0, k0, d0 = arc_loads(PN3, engine="numpy")
    l1, k1, d1 = arc_loads(PN3, engine="pallas")
    np.testing.assert_allclose(l1, l0, rtol=1e-12)
    assert k0 == pytest.approx(k1) and d0 == d1


def test_util_pallas_engine_weighted():
    dem = _random_demand(PN3, 7)
    l0, k0, d0 = arc_loads_weighted(PN3, dem, engine="numpy")
    l1, k1, d1 = arc_loads_weighted(PN3, dem, engine="pallas")
    np.testing.assert_allclose(l1, l0, rtol=1e-12, atol=1e-12)
    assert k0 == pytest.approx(k1) and d0 == d1


def test_util_pallas_engine_targets_mask():
    mask = np.zeros(PN3.n, dtype=bool)
    mask[:PN3.n // 2] = True
    l0, k0, d0 = arc_loads(PN3, targets_mask=mask, engine="numpy")
    l1, k1, d1 = arc_loads(PN3, targets_mask=mask, engine="pallas")
    np.testing.assert_allclose(l1, l0, rtol=1e-12, atol=1e-12)
    assert k0 == pytest.approx(k1) and d0 == d1


# ---------------------------------------------------------------------------
# reporting regressions
# ---------------------------------------------------------------------------


def test_history_normalized_per_fault_segment():
    """A router-killing event shrinks the surviving demand; each history
    segment must be in ITS OWN segment's units.  The offered series is
    then ~constant at the offered load across the event — the pre-event
    segment used to be inflated by pristine/final."""
    dem = _uniform(G16)
    fs = random_faults(G16, k_links=4, k_routers=1, seed=0)
    cfg = SimConfig(routing="minimal", backend="numpy", dtype="float64")
    sim = Simulator(G16, cfg, demand=dem)
    ev_step = 12
    r = sim.run(dem, 0.5, 30, events=[(ev_step, fs)])
    offered = r.history["offered"]
    np.testing.assert_allclose(offered[:ev_step], 0.5, rtol=1e-12)
    np.testing.assert_allclose(offered[ev_step:], 0.5, rtol=1e-12)
    # and theta stays in FINAL-state units (comparable to degraded_report)
    assert r.theta <= 0.5 + 1e-9


def test_sweep_curve_includes_all_probes():
    """A grid placed entirely below the knee: the returned curve must
    contain the bracket-extension and bisection probes, sorted."""
    sw = saturation_sweep(G16, "uniform", routing="minimal",
                          loads=[0.05, 0.1], steps=24, refine=2)
    assert len(sw.loads) == len(sw.runs) > 2
    assert np.all(np.diff(sw.loads) >= 0)
    assert sw.loads.max() > 0.1  # an extension probe made it into the curve
    for arr in (sw.delivered, sw.latency, sw.alpha):
        assert len(arr) == len(sw.loads)


def test_default_steps_sizes_from_fault_segments():
    """links[0-1,0-4,4-7,8-12] grows the 4x4 torus diameter 4 -> 5, so a
    run carrying that event must size longer than the pristine run."""
    sim = Simulator(G16, SimConfig(), demand=_uniform(G16))
    fs = random_faults(G16, k_links=4, seed=2)
    tb, _ = sim._tables_for(fs)
    assert tb.dist_act.max() > sim.tables.dist_act.max()  # fixture holds
    assert sim.default_steps(events=[(4, fs)]) > sim.default_steps()
