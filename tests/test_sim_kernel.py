"""The fused sparse-destination step kernel seam (repro.sim.kernel +
repro.kernels.sim_step/mask_gemm) and the PR's sim-reporting fixes.

Parity contract: the dense numpy float64 engine is the oracle.
``backend="pallas"`` on CPU runs the same blocked sparse-dest algebra in
numpy (bit-level comparable at float64); ``backend="pallas_interpret"``
runs the actual pallas kernel through the interpreter — same fluid, TPU
summation order, so float64 agreement to round-off.  Dest compaction
must be EXACT in both shapes: the minimal-mode active-set shrink, and
the ugal/valiant per-VC compacted dest axis (q0/q2/src/pend-dest on the
demanded columns, q1/stage2 on the full mid axis) — dropping
never-addressed dest columns is a reindexing, not an approximation.
The fused UGAL decision and the sim_workers threaded slab loop must be
bitwise identical to their serial dense counterparts.

The reporting regressions pinned here:
  * run histories are normalized per fault segment (a pre-event curve
    segment is in pre-event surviving-demand units);
  * saturation_sweep curves include every probe (bracket extensions and
    bisection refinements), sorted by offered load;
  * default_steps sizes from the max distance over the run's fault
    segments, not just the pristine tables.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import pn_graph, random_faults
from repro.core.traffic import make_pattern, normalize_demand
from repro.core.utilization import arc_loads, arc_loads_weighted
from repro.fabric.model import torus3d_graph
from repro.sim import (SIM_MAX_CELLS, SimConfig, Simulator, saturation_sweep)
from repro.sim.kernel import SPARSE_BACKENDS, resolve_dtype

G16 = torus3d_graph(4, 4, 1)
PN3 = pn_graph(3)


def _uniform(g):
    return normalize_demand(make_pattern("uniform").demand(g, None))


def _random_demand(g, seed, density=0.4):
    rng = np.random.default_rng(seed)
    dem = rng.random((g.n, g.n)) * (rng.random((g.n, g.n)) < density)
    np.fill_diagonal(dem, 0.0)
    for r in np.nonzero(dem.sum(axis=1) == 0)[0]:  # no all-zero rows
        dem[r, (r + 1) % g.n] = 0.5
    return normalize_demand(dem)


def _histories_close(a, b, rtol, atol=1e-12):
    for key in ("delivered", "accepted", "offered", "occupancy",
                "src_backlog", "diverted"):
        np.testing.assert_allclose(
            a.history[key], b.history[key], rtol=rtol, atol=atol,
            err_msg=f"history[{key!r}] diverges")


def _run_backend(g, demand, backend, routing="minimal", offered=0.5,
                 steps=24, buffer=float("inf"), events=None):
    cfg = SimConfig(routing=routing, backend=backend, dtype="float64",
                    buffer=buffer)
    return Simulator(g, cfg, demand=demand).run(demand, offered, steps,
                                                events=events)


# ---------------------------------------------------------------------------
# numpy vs pallas step parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("routing", ["minimal", "valiant",
                                     "ugal_threshold(0)"])
def test_fused_numpy_matches_dense_float64(routing):
    """The CPU 'pallas' backend (blocked sparse-dest numpy) against the
    dense oracle, all routing modes, float64: round-off-level identity."""
    dem = _uniform(G16)
    a = _run_backend(G16, dem, "numpy", routing, offered=0.7)
    b = _run_backend(G16, dem, "pallas", routing, offered=0.7)
    _histories_close(a, b, rtol=1e-9)
    assert a.residual < 1e-9 and b.residual < 1e-9


@pytest.mark.parametrize("seed", [0, 1])
def test_interpret_kernel_parity_random_demand(seed):
    """The ACTUAL pallas kernel (interpret mode) against the dense numpy
    oracle on random demand with finite buffers and a mid-run fault —
    the ISSUE's property test, float64 end to end."""
    dem = _random_demand(G16, seed)
    fs = random_faults(G16, k_links=3, seed=seed)
    kw = dict(routing="ugal_threshold(0)", offered=0.6, steps=24,
              buffer=6.0, events=[(8, fs)])
    a = _run_backend(G16, dem, "numpy", **kw)
    b = _run_backend(G16, dem, "pallas_interpret", **kw)
    # the kernel's TPU summation order differs from the dense einsum's;
    # the threshold rule amplifies that round-off through its diversion
    # decisions, so float64 agreement is ~1e-8, not 1e-15
    _histories_close(a, b, rtol=1e-6, atol=1e-9)
    assert b.residual < 1e-7


def test_sparse_dest_compaction_is_exact():
    """Empty dest columns (a permutation over half the routers) must not
    change the fluid: compacted sparse-dest run == dense run, and the
    compaction must actually have happened."""
    rng = np.random.default_rng(3)
    sub = rng.choice(G16.n, size=8, replace=False)
    dem = np.zeros((G16.n, G16.n))
    dem[sub, np.roll(sub, 1)] = 1.0  # cycle permutation on the subset
    dem = normalize_demand(dem)

    cfg = SimConfig(routing="minimal", backend="pallas", dtype="float64")
    sim = Simulator(G16, cfg, demand=dem)
    assert len(sim.active) == 8  # compacted to the populated columns

    a = _run_backend(G16, dem, "numpy", offered=0.8)
    b = sim.run(dem, 0.8, 24)
    _histories_close(a, b, rtol=1e-9)


def _sparse_cols_demand(g, seed, n_cols=5, n_srcs=6):
    """Demand addressing only a scattered subset of dest columns — the
    shape the per-VC compacted dest axis exists for."""
    rng = np.random.default_rng(seed)
    cols = np.sort(rng.choice(g.n, size=n_cols, replace=False))
    dem = np.zeros((g.n, g.n))
    for c in cols:
        srcs = rng.choice(g.n, size=n_srcs, replace=False)
        dem[srcs, c] = rng.random(n_srcs)
    np.fill_diagonal(dem, 0.0)
    return normalize_demand(dem)


@pytest.mark.parametrize("routing", ["ugal_threshold(0)", "valiant"])
def test_compacted_adaptive_matches_dense_float64(routing):
    """The per-VC compacted dest axis under adaptive routing against the
    all-columns dense float64 oracle — finite buffers and a mid-run
    FaultSet event included, so the compacted surgery path is covered."""
    dem = _sparse_cols_demand(G16, 11)
    fs = random_faults(G16, k_links=3, seed=5)
    a = _run_backend(G16, dem, "numpy", routing, offered=0.6, steps=24,
                     buffer=6.0, events=[(8, fs)])
    cfg = SimConfig(routing=routing, backend="pallas", dtype="float64",
                    buffer=6.0)
    sim = Simulator(G16, cfg, demand=dem)
    assert sim.dest_cols is not None and len(sim.dest_cols) < G16.n
    assert len(sim.active) == G16.n      # the active set stays whole
    b = sim.run(dem, 0.6, 24, events=[(8, fs)])
    _histories_close(a, b, rtol=1e-9)
    assert b.residual < 1e-7


def test_compacted_run_rejects_foreign_demand():
    """A compacted Simulator must refuse a demand addressing columns it
    dropped, not silently lose the fluid."""
    dem = _sparse_cols_demand(G16, 11)
    cfg = SimConfig(routing="ugal_threshold(0)", backend="pallas",
                    dtype="float64")
    sim = Simulator(G16, cfg, demand=dem)
    other = _uniform(G16)
    with pytest.raises(ValueError, match="compact"):
        sim.run(other, 0.5, 8)


def test_sim_workers_bitwise_deterministic(monkeypatch):
    """Slab units write disjoint output column ranges: any sim_workers
    count must produce bit-identical histories (threshold forced to 0 so
    the small fixture actually threads)."""
    import repro.sim.kernel as K
    from repro.perf import flags
    monkeypatch.setattr(K, "SIM_THREAD_MIN_CELLS", 0)
    dem = _random_demand(G16, 3)
    out = {}
    for w in (1, 4):
        monkeypatch.setattr(flags(), "sim_workers", w)
        out[w] = _run_backend(G16, dem, "pallas", "ugal_threshold(0)",
                              offered=0.7, buffer=6.0)
    for key in ("delivered", "accepted", "offered", "occupancy",
                "src_backlog", "diverted"):
        np.testing.assert_array_equal(
            out[1].history[key], out[4].history[key],
            err_msg=f"history[{key!r}] not bitwise equal across workers")


def test_fused_decision_interior_blend_parity():
    """torus2d_8x16 tornado at ugal_threshold(0): the blend optimum is
    interior (0 < alpha < 1), so both branches of the fused decision —
    divert and keep — carry fluid.  Blocked fused decision vs the dense
    einsum decision, float64."""
    g = torus3d_graph(8, 16, 1)
    dem = normalize_demand(make_pattern("tornado").demand(g, None))
    a = _run_backend(g, dem, "numpy", "ugal_threshold(0)", offered=0.38,
                     steps=40)
    b = _run_backend(g, dem, "pallas", "ugal_threshold(0)", offered=0.38,
                     steps=40)
    _histories_close(a, b, rtol=1e-9)
    assert 0.0 < a.alpha < 1.0       # both decision branches were live


def test_ugal_keeps_active_set_but_compacts_dest_axis():
    """ugal spreads diversions over the whole active set — the active
    set must stay whole — while the FINAL-dest axes compact to the
    demanded columns on the fused backends (and only there)."""
    dem = np.zeros((G16.n, G16.n))
    dem[0, 1] = dem[1, 0] = 1.0
    cfg = SimConfig(routing="ugal_threshold(0)", backend="pallas")
    sim = Simulator(G16, cfg, demand=dem)
    assert len(sim.active) == G16.n
    assert sorted(sim.dest_cols) == [0, 1]
    # dense backends have no index-mapped views: every column stays
    cfg = SimConfig(routing="ugal_threshold(0)", backend="numpy")
    assert Simulator(G16, cfg, demand=dem).dest_cols is None
    # compact="off" is the all-columns baseline on the fused path too
    cfg = SimConfig(routing="ugal_threshold(0)", backend="pallas",
                    compact="off")
    assert Simulator(G16, cfg, demand=dem).dest_cols is None


def test_guard_and_auto_sized_from_compacted_cells(monkeypatch):
    """Backend auto-selection and the SIM_MAX_CELLS guard see the state
    that will actually be allocated: post-shrink dense cells under
    minimal, so a sparse-demand instance over the cap runs dense; under
    ugal the dense guard still fires while auto escalates to the fused
    path and compacts the dest axis."""
    import repro.sim as S
    import repro.sim.engine as E
    dem = np.zeros((G16.n, G16.n))
    dem[0, 1] = dem[1, 0] = 1.0
    cells_full = G16.n * G16.max_degree * G16.n
    monkeypatch.setattr(S, "SIM_MAX_CELLS", cells_full - 1)
    monkeypatch.setattr(E, "SIM_MAX_CELLS", cells_full - 1)
    # minimal: the active set shrinks to 2 columns BEFORE the guard
    sim = Simulator(G16, SimConfig(backend="numpy"), demand=dem)
    assert len(sim.active) == 2
    # without a demand there is nothing to shrink: the guard still fires
    with pytest.raises(ValueError, match="pallas"):
        Simulator(G16, SimConfig(backend="numpy"))
    # ugal keeps every dense cell on dense backends...
    with pytest.raises(ValueError, match="pallas"):
        Simulator(G16, SimConfig(routing="ugal_threshold(0)",
                                 backend="numpy"), demand=dem)
    # ...while auto escalates to the fused path and compacts
    sim = Simulator(G16, SimConfig(routing="ugal_threshold(0)"),
                    demand=dem)
    assert sim.backend == "pallas" and len(sim.dest_cols) == 2


def test_per_dest_stability_fields():
    """per_dest=True fills the per-dest-column stability fields; a run
    far below saturation reads ~1 on every column, and the fields stay
    NaN unless asked for."""
    dem = _sparse_cols_demand(G16, 2)
    cfg = SimConfig(routing="minimal", backend="numpy", dtype="float64")
    sim = Simulator(G16, cfg, demand=dem)
    r = sim.run(dem, 0.3, 30, per_dest=True)
    assert np.isfinite(r.dest_stability_min)
    assert r.dest_stability_min >= 0.98
    assert r.dest_stability_mean >= r.dest_stability_min
    assert np.isnan(sim.run(dem, 0.3, 30).dest_stability_min)


def test_per_dest_knee_sweep():
    sw = saturation_sweep(G16, "uniform", routing="minimal",
                          loads=[0.2], steps=24, refine=0,
                          knee="per_dest")
    assert sw.knee == "per_dest"
    assert all(np.isfinite(r.dest_stability_min) for r in sw.runs)
    assert sw.theta > 0
    with pytest.raises(ValueError, match="knee"):
        saturation_sweep(G16, "uniform", loads=[0.2], knee="sharpest")


def test_backend_and_dtype_resolution():
    assert set(SPARSE_BACKENDS) == {"pallas", "pallas_interpret"}
    assert resolve_dtype("auto", "pallas") == np.float32
    assert resolve_dtype("auto", "numpy") == np.float64
    assert resolve_dtype("float32", "numpy") == np.float32
    with pytest.raises(ValueError):
        resolve_dtype("bf16", "pallas")
    # auto escalates to the sparse step above the dense cell cap, and
    # the sparse backends pass through untouched at any size
    from repro.sim.engine import pick_backend
    assert pick_backend("auto", SIM_MAX_CELLS + 1) == "pallas"
    assert pick_backend("pallas", SIM_MAX_CELLS + 1) == "pallas"
    assert pick_backend("pallas_interpret", 10) == "pallas_interpret"


def test_dense_backend_above_cap_names_the_escape_hatch():
    g27 = pn_graph(27)  # 1514 routers: 64.2M dense cells > SIM_MAX_CELLS
    assert g27.n * g27.max_degree * g27.n > SIM_MAX_CELLS
    with pytest.raises(ValueError, match="pallas"):
        Simulator(g27, SimConfig(backend="numpy"))


# ---------------------------------------------------------------------------
# utilization: the mask+GEMM kernel engine
# ---------------------------------------------------------------------------


def test_util_pallas_engine_uniform():
    l0, k0, d0 = arc_loads(PN3, engine="numpy")
    l1, k1, d1 = arc_loads(PN3, engine="pallas")
    np.testing.assert_allclose(l1, l0, rtol=1e-12)
    assert k0 == pytest.approx(k1) and d0 == d1


def test_util_pallas_engine_weighted():
    dem = _random_demand(PN3, 7)
    l0, k0, d0 = arc_loads_weighted(PN3, dem, engine="numpy")
    l1, k1, d1 = arc_loads_weighted(PN3, dem, engine="pallas")
    np.testing.assert_allclose(l1, l0, rtol=1e-12, atol=1e-12)
    assert k0 == pytest.approx(k1) and d0 == d1


def test_util_pallas_engine_targets_mask():
    mask = np.zeros(PN3.n, dtype=bool)
    mask[:PN3.n // 2] = True
    l0, k0, d0 = arc_loads(PN3, targets_mask=mask, engine="numpy")
    l1, k1, d1 = arc_loads(PN3, targets_mask=mask, engine="pallas")
    np.testing.assert_allclose(l1, l0, rtol=1e-12, atol=1e-12)
    assert k0 == pytest.approx(k1) and d0 == d1


# ---------------------------------------------------------------------------
# reporting regressions
# ---------------------------------------------------------------------------


def test_history_normalized_per_fault_segment():
    """A router-killing event shrinks the surviving demand; each history
    segment must be in ITS OWN segment's units.  The offered series is
    then ~constant at the offered load across the event — the pre-event
    segment used to be inflated by pristine/final."""
    dem = _uniform(G16)
    fs = random_faults(G16, k_links=4, k_routers=1, seed=0)
    cfg = SimConfig(routing="minimal", backend="numpy", dtype="float64")
    sim = Simulator(G16, cfg, demand=dem)
    ev_step = 12
    r = sim.run(dem, 0.5, 30, events=[(ev_step, fs)])
    offered = r.history["offered"]
    np.testing.assert_allclose(offered[:ev_step], 0.5, rtol=1e-12)
    np.testing.assert_allclose(offered[ev_step:], 0.5, rtol=1e-12)
    # and theta stays in FINAL-state units (comparable to degraded_report)
    assert r.theta <= 0.5 + 1e-9


def test_sweep_curve_includes_all_probes():
    """A grid placed entirely below the knee: the returned curve must
    contain the bracket-extension and bisection probes, sorted."""
    sw = saturation_sweep(G16, "uniform", routing="minimal",
                          loads=[0.05, 0.1], steps=24, refine=2)
    assert len(sw.loads) == len(sw.runs) > 2
    assert np.all(np.diff(sw.loads) >= 0)
    assert sw.loads.max() > 0.1  # an extension probe made it into the curve
    for arr in (sw.delivered, sw.latency, sw.alpha):
        assert len(arr) == len(sw.loads)


def test_default_steps_sizes_from_fault_segments():
    """links[0-1,0-4,4-7,8-12] grows the 4x4 torus diameter 4 -> 5, so a
    run carrying that event must size longer than the pristine run."""
    sim = Simulator(G16, SimConfig(), demand=_uniform(G16))
    fs = random_faults(G16, k_links=4, seed=2)
    tb, _ = sim._tables_for(fs)
    assert tb.dist_act.max() > sim.tables.dist_act.max()  # fixture holds
    assert sim.default_steps(events=[(4, fs)]) > sim.default_steps()
