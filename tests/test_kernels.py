"""Kernel validation: Pallas (interpret) + jnp paths vs. pure-jnp oracles,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# Heavyweight JAX suite: excluded from tier-1 (see pyproject.toml)
pytestmark = pytest.mark.slow


rng = np.random.default_rng(42)


def rand(*s, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(rng.normal(size=s) * scale, dtype)


ATTN_SHAPES = [
    # (b, hq, hkv, sq, skv, d, causal, window)
    (2, 4, 2, 64, 64, 32, True, None),
    (1, 8, 1, 128, 128, 16, True, 32),     # MQA + window
    (2, 4, 4, 32, 96, 32, False, None),    # cross-attn-like
    (1, 2, 2, 16, 64, 8, True, None),      # decode-ish offset
    (1, 6, 3, 96, 96, 64, True, 48),
]


@pytest.mark.parametrize("case", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_jnp_vs_ref(case, dtype):
    b, hq, hkv, sq, skv, d, causal, window = case
    q, k, v = rand(b, hq, sq, d, dtype=dtype), rand(b, hkv, skv, d, dtype=dtype), \
        rand(b, hkv, skv, d, dtype=dtype)
    off = skv - sq
    o_ref = ref.attention_ref(q, k, v, causal=causal, window=window, q_offset=off)
    o = ops.attention(q, k, v, causal=causal, window=window, q_offset=off,
                      impl="jnp", block_q=16)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o, np.float32), atol=tol, rtol=tol)


PALLAS_ATTN = [
    (1, 2, 1, 64, 64, 32, True, None),
    (1, 4, 2, 128, 128, 32, True, 64),
    (2, 2, 2, 64, 64, 16, False, None),
    (1, 4, 4, 256, 256, 64, True, None),
]


@pytest.mark.parametrize("case", PALLAS_ATTN)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_attention_pallas_interpret_vs_ref(case, dtype):
    b, hq, hkv, sq, skv, d, causal, window = case
    q, k, v = rand(b, hq, sq, d, dtype=dtype), rand(b, hkv, skv, d, dtype=dtype), \
        rand(b, hkv, skv, d, dtype=dtype)
    o_ref = ref.attention_ref(q, k, v, causal=causal, window=window)
    o = ops.attention(q, k, v, causal=causal, window=window,
                      impl="pallas_interpret", block_q=32, block_k=32)
    tol = 3e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o_ref, np.float32),
                               np.asarray(o, np.float32), atol=tol, rtol=tol)


SSD_SHAPES = [
    # (b, l, h, p, g, n, chunk)
    (2, 64, 4, 16, 1, 32, 16),
    (1, 96, 6, 8, 2, 16, 32),
    (1, 32, 2, 32, 1, 64, 32),
    (2, 128, 8, 16, 4, 8, 64),
]


@pytest.mark.parametrize("case", SSD_SHAPES)
def test_ssd_jnp_vs_ref(case):
    b, l, h, p, g, n, chunk = case
    x = rand(b, l, h, p)
    dt = jnp.abs(rand(b, l, h)) * 0.1 + 0.01
    a_log = rand(h, scale=0.5)
    bm, cm, ds = rand(b, l, g, n), rand(b, l, g, n), rand(h)
    y1, s1 = ref.ssd_ref(x, dt, a_log, bm, cm, ds)
    y2, s2 = ops.ssd(x, dt, a_log, bm, cm, ds, chunk=chunk, impl="jnp")
    np.testing.assert_allclose(y1, y2, atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(s1, s2, atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("case", SSD_SHAPES[:2])
def test_ssd_pallas_interpret_vs_ref(case):
    b, l, h, p, g, n, chunk = case
    x = rand(b, l, h, p)
    dt = jnp.abs(rand(b, l, h)) * 0.1 + 0.01
    a_log = rand(h, scale=0.5)
    bm, cm, ds = rand(b, l, g, n), rand(b, l, g, n), rand(h)
    y1, _ = ref.ssd_ref(x, dt, a_log, bm, cm, ds)
    y3, _ = ops.ssd(x, dt, a_log, bm, cm, ds, chunk=chunk,
                    impl="pallas_interpret")
    np.testing.assert_allclose(y1, y3, atol=3e-4, rtol=3e-4)


def test_ssd_carry_state_chunked_vs_ref():
    """Chunked prefill with carried state == one long ref recurrence."""
    b, l, h, p, g, n = 1, 64, 4, 16, 1, 32
    x = rand(b, l, h, p)
    dt = jnp.abs(rand(b, l, h)) * 0.1 + 0.01
    a_log = rand(h, scale=0.5)
    bm, cm, ds = rand(b, l, g, n), rand(b, l, g, n), rand(h)
    y_ref, s_ref = ref.ssd_ref(x, dt, a_log, bm, cm, ds)
    # split into two halves, carrying state
    y1, s_mid = ops.ssd(x[:, :32], dt[:, :32], a_log, bm[:, :32], cm[:, :32],
                        ds, chunk=16, impl="jnp")
    y2, s_end = ops.ssd(x[:, 32:], dt[:, 32:], a_log, bm[:, 32:], cm[:, 32:],
                        ds, chunk=16, impl="jnp", state=s_mid)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_ref,
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(s_end, s_ref, atol=3e-4, rtol=3e-4)


def test_ssd_decode_steps_match_ref():
    b, l, h, p, g, n = 1, 16, 4, 8, 1, 16
    x = rand(b, l, h, p)
    dt = jnp.abs(rand(b, l, h)) * 0.1 + 0.01
    a_log = rand(h, scale=0.5)
    bm, cm, ds = rand(b, l, g, n), rand(b, l, g, n), rand(h)
    y_ref, _ = ref.ssd_ref(x, dt, a_log, bm, cm, ds)
    s = jnp.zeros((b, h, n, p))
    for t in range(l):
        y_t, s = ops.ssd_decode_step(s, x[:, t], dt[:, t], a_log,
                                     bm[:, t], cm[:, t], ds)
        np.testing.assert_allclose(y_t, y_ref[:, t], atol=3e-4, rtol=3e-4)


def test_rglru_vs_ref_and_decode():
    b, l, d = 2, 48, 24
    x, ag, ig, ap = rand(b, l, d), rand(b, l, d), rand(b, l, d), rand(d)
    y1, s1 = ref.rglru_ref(x, ag, ig, ap)
    y2, s2 = ops.rglru(x, ag, ig, ap)
    np.testing.assert_allclose(y1, y2, atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(s1, s2, atol=2e-5, rtol=2e-5)
    s = jnp.zeros((b, d))
    for t in range(8):
        y_t, s = ops.rglru_decode_step(s, x[:, t], ag[:, t], ig[:, t], ap)
        np.testing.assert_allclose(y_t, y1[:, t], atol=2e-5, rtol=2e-5)


def test_rglru_carry_state():
    b, l, d = 1, 32, 16
    x, ag, ig, ap = rand(b, l, d), rand(b, l, d), rand(b, l, d), rand(d)
    y_ref, _ = ref.rglru_ref(x, ag, ig, ap)
    y1, s_mid = ops.rglru(x[:, :16], ag[:, :16], ig[:, :16], ap)
    y2, _ = ops.rglru(x[:, 16:], ag[:, 16:], ig[:, 16:], ap, state=s_mid)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_ref,
                               atol=2e-5, rtol=2e-5)


def test_attention_kv_len_masking():
    """decode-style: only the first kv_len keys are attendable."""
    q = rand(2, 2, 1, 16)
    k = rand(2, 2, 32, 16)
    v = rand(2, 2, 32, 16)
    kv_len = jnp.array([5, 9])
    o = ops.attention(q, k, v, causal=False, kv_len=kv_len, impl="jnp")
    o_ref = ref.attention_ref(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(o, o_ref, atol=2e-5, rtol=2e-5)
    # equals truncated-cache attention per batch row
    for i, n in enumerate([5, 9]):
        o_t = ref.attention_ref(q[i:i+1], k[i:i+1, :, :n], v[i:i+1, :, :n],
                                causal=False)
        np.testing.assert_allclose(o[i:i+1], o_t, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("case", [
    dict(b=1, hq=4, hkv=4, sq=128, skv=128, d=32, causal=True, window=None),
    dict(b=2, hq=6, hkv=2, sq=128, skv=128, d=16, causal=True, window=None),
    dict(b=1, hq=4, hkv=1, sq=128, skv=128, d=32, causal=True, window=48),
    dict(b=1, hq=2, hkv=2, sq=128, skv=256, d=32, causal=False, window=None),
])
def test_flash_attention_backward_interpret_vs_ref(case):
    """The Pallas flash backward (dq/dk/dv) must match jax.vjp of the
    pure-jnp oracle, including GQA group-summed dk/dv and window masks."""
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import attention_ref
    ks = jax.random.split(jax.random.key(7), 4)
    q = jax.random.normal(ks[0], (case["b"], case["hq"], case["sq"], case["d"]),
                          jnp.float32)
    k = jax.random.normal(ks[1], (case["b"], case["hkv"], case["skv"], case["d"]),
                          jnp.float32)
    v = jax.random.normal(ks[2], (case["b"], case["hkv"], case["skv"], case["d"]),
                          jnp.float32)
    do = jax.random.normal(ks[3], q.shape, jnp.float32)

    def f_ref(q, k, v):
        return attention_ref(q, k, v, causal=case["causal"],
                             window=case["window"])

    def f_pallas(q, k, v):
        return flash_attention(q, k, v, causal=case["causal"],
                               window=case["window"], block_q=64, block_k=64,
                               interpret=True)

    o_ref, vjp_ref = jax.vjp(f_ref, q, k, v)
    o_pal, vjp_pal = jax.vjp(f_pallas, q, k, v)
    np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_ref),
                               atol=2e-3, rtol=2e-3)
    for g_ref, g_pal, name in zip(vjp_ref(do), vjp_pal(do), "qkv"):
        np.testing.assert_allclose(
            np.asarray(g_pal), np.asarray(g_ref), atol=3e-3, rtol=3e-3,
            err_msg=f"d{name} mismatch in {case}")


def test_flash_attention_backward_bf16_grads_finite():
    from repro.kernels.flash_attention import flash_attention
    ks = jax.random.split(jax.random.key(3), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 2, 128, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 2, 128, 32), jnp.bfloat16)
    loss = lambda q, k, v: flash_attention(
        q, k, v, causal=True, interpret=True).astype(jnp.float32).sum()
    gq, gk, gv = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert np.isfinite(np.asarray(g, np.float32)).all()
