"""fabric.placement: routing conservation, strategy comparison, and the
paper tie-in (packing TP groups beats naive placement on a projective
fabric)."""

import numpy as np
import pytest

from repro.core import build_topology
from repro.fabric.placement import (Placement, collective_traffic,
                                    evaluate_placements, greedy_improve,
                                    link_loads, place_mesh)

MESH = (8, 8)
AXES = ("data", "model")
TRAFFIC = {"data": ("ring", 1.0), "model": ("all_to_all", 1.0)}


def _graph():
    return build_topology("demi_pn", 9)  # 91 routers


def test_traffic_conservation():
    src, dst, byts = collective_traffic(MESH, AXES, TRAFFIC)
    n = int(np.prod(MESH))
    # ring: every chip sends 2(n-1)/n once; a2a: (n-1) sends of 1/n
    expect = n * (2 * 7 / 8) + n * 7 * (1 / 8)
    assert byts.sum() == pytest.approx(expect)
    assert (src != dst).all()


def test_link_loads_route_all_bytes():
    g = _graph()
    p = place_mesh(g, MESH, AXES, terminals_per_router=1, strategy="linear")
    traffic = collective_traffic(MESH, AXES, TRAFFIC)
    r = link_loads(p, traffic)
    # total arc-bytes = sum over demands of bytes * distance(src, dst) —
    # shortest-path routing conserves byte-hops
    from repro.core.graph import bfs_distances
    src, dst, byts = traffic
    rs, rd = p.router_of[src], p.router_of[dst]
    dist = np.stack([bfs_distances(g, s) for s in range(g.n)])
    expect = float((byts * dist[rs, rd]).sum())
    assert r["loads"].sum() == pytest.approx(expect, rel=1e-9)
    assert r["max"] >= r["mean"] > 0


def test_same_router_traffic_is_free():
    g = _graph()
    # all chips of a model group on one router -> a2a stays local
    p = place_mesh(g, (1, 8), ("data", "model"), terminals_per_router=8,
                   strategy="linear")
    traffic = collective_traffic((1, 8), ("data", "model"),
                                 {"model": ("all_to_all", 1.0)})
    assert link_loads(p, traffic)["max"] == 0.0


def test_group_placement_beats_linear_for_tp_traffic():
    """Packing each TP group onto few routers (the electrical-group /
    subplane layout) must reduce max link load vs spreading it."""
    g = _graph()
    traffic = collective_traffic(MESH, AXES, {"model": ("all_to_all", 1.0)})
    # linear fills routers chip-major => model groups are split across
    # routers at delta0=1... with delta0=4, 'group' packs each 8-chip model
    # group onto 2 routers while 'linear' already does the same; use a
    # transposed mesh so linear splits groups:
    p_bad = place_mesh(g, (8, 8), ("model", "data"), 4, "linear")
    tr_bad = collective_traffic((8, 8), ("model", "data"),
                                {"model": ("all_to_all", 1.0)})
    p_good = place_mesh(g, (8, 8), ("data", "model"), 4, "group")
    m_bad = link_loads(p_bad, tr_bad)["max"]
    m_good = link_loads(p_good, traffic)["max"]
    assert m_good <= m_bad


def test_greedy_improve_never_worse():
    g = _graph()
    traffic = collective_traffic(MESH, AXES, TRAFFIC)
    p0 = place_mesh(g, MESH, AXES, 1, "random", seed=3)
    base = link_loads(p0, traffic)["max"]
    _, improved = greedy_improve(p0, traffic, iters=60, seed=4)
    assert improved <= base


def test_evaluate_placements_reports_all_strategies():
    g = _graph()
    out = evaluate_placements(g, MESH, AXES, 1, TRAFFIC, routing="minimal")
    assert set(out) == {"linear", "group", "random", "orbit"}
    for v in out.values():
        # theta in Eq. 1 link-equivalents, raw bytes kept for capacity work
        assert v["theta"] > 0
        assert 0 < v["u"] <= 1
        assert v["max_bytes"] >= v["mean_bytes"] >= 0


# ---------------------------------------------------------------------------
# Property tests (hypothesis): routing invariants hold for arbitrary traffic
# ---------------------------------------------------------------------------

from hypothesis import given, settings, strategies as st


@settings(max_examples=15, deadline=None)
@given(
    q=st.sampled_from([5, 7, 8]),
    d0=st.integers(1, 4),
    dshape=st.sampled_from([(4, 4), (2, 8), (8, 2)]),
    ring_b=st.floats(0.1, 10.0),
    a2a_b=st.floats(0.0, 10.0),
    strat=st.sampled_from(["linear", "group", "random"]),
)
def test_byte_hop_conservation_property(q, d0, dshape, ring_b, a2a_b, strat):
    """For ANY placement and payload mix, routed arc-bytes must equal
    Σ demand·distance (shortest-path routing conserves byte-hops)."""
    g = build_topology("demi_pn", q)
    if int(np.prod(dshape)) > g.n * d0:
        return  # job doesn't fit this fabric
    spec = {"data": ("ring", ring_b), "model": ("all_to_all", a2a_b)}
    p = place_mesh(g, dshape, ("data", "model"), d0, strat, seed=1)
    traffic = collective_traffic(dshape, ("data", "model"), spec)
    from repro.core.graph import bfs_distances
    src, dst, byts = traffic
    rs, rd = p.router_of[src], p.router_of[dst]
    dist = np.stack([bfs_distances(g, s) for s in range(g.n)])
    r = link_loads(p, traffic)
    assert r["loads"].sum() == pytest.approx(
        float((byts * dist[rs, rd]).sum()), rel=1e-9)
    assert (r["loads"] >= -1e-12).all()
