"""Routing-model invariants (repro.core.routing).

Covered here, each as a hypothesis property test AND a deterministic
sweep (the conftest stub skips the former on a bare interpreter):

  1. dominance — theta_ugal >= max(theta_minimal, theta_valiant) - eps on
     every registered pattern/topology pair (the blend evaluates both
     endpoints, so it can never do worse than the better pure routing).
  2. blend validity — the reported alpha lies in [0, 1]; the blended
     loads reproduce alpha*L_min + (1-alpha)*L_val.
  3. uniform reduction — ugal reduces to minimal on uniform traffic
     (l_val == 2*l_min exactly, so alpha = 1): theta equal, loads
     bit-identical, on PN (the paper's balanced case) and every other
     family.
  4. refactor bit-identity — the registry's minimal/valiant models
     reproduce PR 2's saturation_report computation bit-for-bit: the
     minimal path IS one arc_loads_weighted call and the Valiant path IS
     the two rank-1 phases, checked against an inline replica of the
     PR 2 code on explicit engines (the orbit uniform shortcut only
     engages under auto).
  5. blend_optimum exactness — against a dense alpha grid scan.

Plus the orbit shortcut satellite: uniform-shaped weighted demand routes
through the orbit path under engine="auto" with numpy-engine parity.
"""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (
    demi_pn_graph,
    make_routing,
    oft_graph,
    pn_graph,
    saturation_report,
)
from repro.core.routing import (
    ROUTINGS,
    RoutingModel,
    blend_optimum,
    evaluate_models,
    valiant_demands,
)
from repro.core.traffic import _normalize_rows, make_pattern
from repro.core.utilization import arc_loads, arc_loads_weighted
from repro.fabric.model import torus3d_graph

GRAPHS = {
    "pn4": lambda: pn_graph(4),
    "demi_pn5": lambda: demi_pn_graph(5),
    "oft3": lambda: oft_graph(3),
    "torus_8x8": lambda: torus3d_graph(8, 8, 1),
    "torus_8x16": lambda: torus3d_graph(8, 16, 1),
}

# every registered zero-arg-constructible pattern
PATTERN_SPECS = ["uniform", "bit_reversal", "transpose", "shift(1)",
                 "tornado", "random_permutation(7)", "hot_region(0.2,4)",
                 "collective(ring-all-reduce)"]


def _report_trio(g, spec):
    rmin = saturation_report(g, spec, routing="minimal")
    rval = saturation_report(g, spec, routing="valiant")
    rug = saturation_report(g, spec, routing="ugal")
    return rmin, rval, rug


# ---------------------------------------------------------------------------
# registry plumbing
# ---------------------------------------------------------------------------


def test_registry_and_spec_parsing():
    for name in ["minimal", "valiant", "ugal"]:
        assert name in ROUTINGS
    assert make_routing("minimal").name == "minimal"
    assert make_routing("ugal").name == "ugal"
    assert make_routing("ugal(source)").name == "ugal(source)"
    mod = make_routing("valiant")
    assert make_routing(mod) is mod  # pass-through
    with pytest.raises(ValueError, match="unknown routing"):
        make_routing("teleport")
    with pytest.raises(ValueError, match="granularity"):
        make_routing("ugal(per-hop)")


def test_custom_model_registers_and_routes_everywhere():
    from repro.core.routing import RoutingResult, register_routing

    calls = []

    @register_routing("_test_double_minimal")
    def _factory(scale: float = 2.0) -> RoutingModel:
        def evaluate(g, demand, active, engine=None):
            calls.append(scale)
            loads, kbar, diam = arc_loads_weighted(g, demand, engine=engine)
            return RoutingResult("_test_double_minimal", loads * scale,
                                 kbar, int(diam))
        return RoutingModel("_test_double_minimal", evaluate, "test stub")

    try:
        g = torus3d_graph(4, 4, 1)
        base = saturation_report(g, "tornado")
        rep = saturation_report(g, "tornado", routing="_test_double_minimal(4)")
        assert calls == [4]
        assert rep.theta == pytest.approx(base.theta / 4.0, rel=1e-12)
    finally:
        del ROUTINGS["_test_double_minimal"]


# ---------------------------------------------------------------------------
# 1 + 2 + 3: dominance, alpha validity, uniform reduction (deterministic)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("spec", PATTERN_SPECS)
def test_det_ugal_dominates_pure_routings(gname, spec):
    g = GRAPHS[gname]()
    rmin, rval, rug = _report_trio(g, spec)
    assert rug.theta >= max(rmin.theta, rval.theta) - 1e-9, (gname, spec)
    assert rug.alpha is not None and 0.0 <= rug.alpha <= 1.0
    # the blend is what it says: alpha*L_min + (1-alpha)*L_val
    np.testing.assert_allclose(
        rug.loads, rug.alpha * rmin.loads + (1 - rug.alpha) * rval.loads,
        rtol=1e-9, atol=1e-12)


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_det_ugal_reduces_to_minimal_on_uniform(gname):
    g = GRAPHS[gname]()
    rmin, rval, rug = _report_trio(g, "uniform")
    assert rug.alpha == 1.0
    assert rug.theta == rmin.theta  # bitwise: the minimal sweep is reused
    assert np.array_equal(rug.loads, rmin.loads)
    assert rug.kbar_eff == rmin.kbar_eff
    # and valiant really is the doubled ensemble the reduction rests on
    np.testing.assert_allclose(rval.loads, 2.0 * rmin.loads, rtol=1e-9)


def test_ugal_strictly_interior_on_tornado_torus():
    """The acceptance case: on the 8x16 torus the tornado blend is
    strictly better than BOTH pure routings (minimal overloads the short
    x-rings one-directionally, Valiant overloads the long y-rings, and
    the crossing sits in between)."""
    g = torus3d_graph(8, 16, 1)
    rmin, rval, rug = _report_trio(g, "tornado")
    assert rug.theta > max(rmin.theta, rval.theta) + 1e-6
    assert 0.0 < rug.alpha < 1.0


# ---------------------------------------------------------------------------
# 4: refactored models bit-identical to PR 2's saturation_report
# ---------------------------------------------------------------------------


def _pr2_saturation_loads(g, spec, routing, engine):
    """Inline replica of PR 2's saturation_report load computation."""
    pat = make_pattern(spec)
    tm = g.meta.get("leaf_mask")
    demand = _normalize_rows(pat.demand(g, tm))
    if routing == "minimal":
        return arc_loads_weighted(g, demand, engine=engine)[0]
    active = (np.arange(g.n) if tm is None
              else np.nonzero(np.asarray(tm, dtype=bool))[0])
    d1, d2 = valiant_demands(demand, active)
    l1 = arc_loads_weighted(g, d1, engine=engine)[0]
    l2 = l1 if np.array_equal(d1, d2) else arc_loads_weighted(
        g, d2, engine=engine)[0]
    return l1 + l2


@pytest.mark.parametrize("gname", ["pn4", "oft3", "torus_8x8"])
@pytest.mark.parametrize("spec", ["uniform", "tornado", "hot_region(0.2,4)"])
@pytest.mark.parametrize("routing", ["minimal", "valiant"])
def test_det_refactored_models_bit_identical_to_pr2(gname, spec, routing):
    g = GRAPHS[gname]()
    for engine in ["numpy", "csr"]:
        expect = _pr2_saturation_loads(g, spec, routing, engine)
        got = saturation_report(g, spec, routing=routing, engine=engine)
        assert np.array_equal(got.loads, expect), (gname, spec, engine)


# ---------------------------------------------------------------------------
# 5: blend_optimum exactness
# ---------------------------------------------------------------------------


def _grid_min(l_min, l_val, grid=20001):
    alphas = np.linspace(0.0, 1.0, grid)
    f = (l_val[None, :]
         + alphas[:, None] * (l_min - l_val)[None, :]).max(axis=1)
    i = int(np.argmin(f))
    return float(alphas[i]), float(f[i])


@pytest.mark.parametrize("seed", range(6))
def test_det_blend_optimum_matches_grid(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 400))
    l_min = rng.random(n) * 4.0
    l_val = rng.random(n) * 4.0
    alpha, fval, visited = blend_optimum(l_min, l_val)
    assert 0.0 <= alpha <= 1.0 and visited >= 1
    ga, gf = _grid_min(l_min, l_val, 20001)
    assert fval <= gf + 1e-9  # exact beats (or ties) the grid
    # and the claimed value is the true envelope value at alpha
    assert fval == pytest.approx(
        float((l_val + alpha * (l_min - l_val)).max()), abs=1e-12)


def test_blend_optimum_endpoint_cases():
    # l_val == 2*l_min (uniform identity): pure minimal, certified at once
    l_min = np.array([1.0, 2.0, 0.5])
    a, f, _ = blend_optimum(l_min, 2.0 * l_min)
    assert a == 1.0 and f == 2.0
    # minimal strictly dominated everywhere: pure valiant
    a, f, _ = blend_optimum(np.array([5.0, 6.0]), np.array([1.0, 1.0]))
    assert a == 0.0 and f == 1.0
    # crossing structure: min at the interior breakpoint
    a, f, _ = blend_optimum(np.array([0.0, 2.0]), np.array([2.0, 0.0]))
    assert a == pytest.approx(0.5) and f == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# ugal(source): the per-source LP refinement
# ---------------------------------------------------------------------------


def test_ugal_source_refines_global_blend():
    pytest.importorskip("scipy")
    g = torus3d_graph(4, 4, 1)
    for spec in ["tornado", "hot_region(0.25,4)"]:
        rug = saturation_report(g, spec, routing="ugal")
        rsrc = saturation_report(g, spec, routing="ugal(source)")
        assert rsrc.theta >= rug.theta - 1e-9, spec
        assert 0.0 <= rsrc.alpha <= 1.0


def test_ugal_source_guard_on_large_graphs():
    pytest.importorskip("scipy")
    from repro.core import routing as routing_mod
    g = pn_graph(4)
    old = routing_mod.UGAL_SOURCE_MAX_N
    routing_mod.UGAL_SOURCE_MAX_N = 8
    try:
        with pytest.raises(ValueError, match="smaller instance"):
            saturation_report(g, "tornado", routing="ugal(source)")
    finally:
        routing_mod.UGAL_SOURCE_MAX_N = old


# ---------------------------------------------------------------------------
# shared-sweep evaluation
# ---------------------------------------------------------------------------


def test_evaluate_models_matches_individual_reports():
    g = torus3d_graph(8, 16, 1)
    demand = _normalize_rows(make_pattern("tornado").demand(g))
    out = evaluate_models(g, demand, np.arange(g.n))
    assert set(out) == {"minimal", "valiant", "ugal"}
    for model in ["minimal", "valiant", "ugal"]:
        rep = saturation_report(g, "tornado", routing=model)
        assert np.array_equal(out[model].loads, rep.loads), model


def test_evaluate_models_honors_name_colliding_custom_factory():
    """A registered factory whose RoutingModel reuses a built-in display
    name (e.g. a threshold variant calling itself "ugal") must run its
    own evaluate — sweep sharing keys on the resolved factory, not the
    name.  Same for RoutingModel instances passed directly (and the
    adversary harness accepts them without KeyError)."""
    from repro.core import worst_case
    from repro.core.routing import RoutingResult, register_routing

    g = torus3d_graph(4, 4, 1)
    demand = _normalize_rows(make_pattern("tornado").demand(g))
    calls = []

    @register_routing("_test_ugal_variant")
    def _factory() -> RoutingModel:
        def evaluate(g, d, a, engine=None):
            calls.append(1)
            return RoutingResult("ugal", np.full(len(g.arc_src), 7.25),
                                 1.0, 1)
        return RoutingModel("ugal", evaluate, "name-colliding variant")

    try:
        out = evaluate_models(g, demand, np.arange(g.n),
                              models=("_test_ugal_variant", "ugal"))
        assert calls == [1]
        assert np.all(out["_test_ugal_variant"].loads == 7.25)
        assert not np.array_equal(out["ugal"].loads,
                                  out["_test_ugal_variant"].loads)
        # instance specs work end-to-end through the adversary harness
        inst = make_routing("ugal")
        rep = worst_case(g, inst, n_random=2)
        assert rep.routing == "ugal" and rep.worst_theta > 0
    finally:
        del ROUTINGS["_test_ugal_variant"]


# ---------------------------------------------------------------------------
# fabric wiring
# ---------------------------------------------------------------------------


def test_fabric_and_collectives_accept_ugal():
    from repro.fabric import collective_time
    from repro.fabric.model import FabricModel

    fab = FabricModel(torus3d_graph(8, 8, 1))
    # uniform fast path: ugal == minimal (blend alpha = 1), valiant halves
    assert fab.pattern_node_bw("uniform", routing="ugal") == \
        fab.node_uniform_bw
    assert fab.pattern_kbar("uniform", routing="ugal") == fab.kbar
    # adversarial pattern: the ugal collective is never slower than either
    # pure routing's (dominance through the whole fabric stack)
    n, b = fab.graph.n, 1e9
    tmin = collective_time(fab, "all-reduce", b, n, pattern="tornado")
    tval = collective_time(fab, "all-reduce", b, n, pattern="tornado",
                           routing="valiant")
    tug = collective_time(fab, "all-reduce", b, n, pattern="tornado",
                          routing="ugal")
    assert tug.bandwidth_s <= min(tmin.bandwidth_s, tval.bandwidth_s) + 1e-12
    with pytest.raises(ValueError, match="unknown routing"):
        fab.pattern_node_bw("uniform", routing="warp-drive")


# ---------------------------------------------------------------------------
# satellite: raw demand / pattern-object inputs
# ---------------------------------------------------------------------------


def test_saturation_report_accepts_raw_matrix():
    g = torus3d_graph(4, 4, 1)
    d = make_pattern("tornado").demand(g)
    by_name = saturation_report(g, "tornado")
    by_matrix = saturation_report(g, d)
    assert by_matrix.pattern == f"matrix({g.n}x{g.n})"
    assert np.array_equal(by_matrix.loads, by_name.loads)
    assert by_matrix.theta == by_name.theta
    # the caller's matrix must not be mutated (diagonal zeroing happens
    # on a copy)
    d2 = d + np.eye(g.n)
    before = d2.copy()
    saturation_report(g, d2)
    assert np.array_equal(d2, before)
    with pytest.raises(ValueError, match="square"):
        saturation_report(g, np.ones((3, 4)))
    with pytest.raises(ValueError, match="graph has"):
        saturation_report(g, np.ones((3, 3)))


def test_arc_loads_weighted_accepts_pattern_object():
    g = torus3d_graph(4, 4, 1)
    pat = make_pattern("tornado")
    by_obj = arc_loads_weighted(g, pat, engine="numpy")
    by_mat = arc_loads_weighted(g, pat.demand(g), engine="numpy")
    assert np.array_equal(by_obj[0], by_mat[0])
    assert by_obj[1:] == by_mat[1:]


def test_saturation_report_accepts_nested_list_matrix():
    g = torus3d_graph(4, 1, 1)
    d = [[0, 1, 0, 0], [0, 0, 1, 0], [0, 0, 0, 1], [1, 0, 0, 0]]
    rep = saturation_report(g, d)
    assert rep.pattern == "matrix(4x4)"
    assert np.array_equal(rep.loads,
                          saturation_report(g, np.array(d, float)).loads)


def test_orbit_engine_falls_back_without_generators():
    """engine="orbit" + uniform-shaped demand on a family with no known
    automorphism generators keeps PR 2's contract: the exact engines run
    instead of raising."""
    from repro.core.reference import random_regular_graph

    rr = random_regular_graph(20, 4)
    u = np.ones((rr.n, rr.n)) - np.eye(rr.n)
    l_orb = arc_loads_weighted(rr, u, engine="orbit")
    l_np = arc_loads_weighted(rr, u, engine="numpy")
    np.testing.assert_allclose(l_orb[0], l_np[0], rtol=1e-9, atol=1e-12)
    assert l_orb[1] == pytest.approx(l_np[1], abs=1e-12)


# ---------------------------------------------------------------------------
# satellite: orbit shortcut on uniform-shaped weighted demand
# ---------------------------------------------------------------------------


def test_uniform_demand_routes_through_orbit_shortcut(monkeypatch):
    # repro.core re-exports the utilization FUNCTION, shadowing the
    # submodule attribute; go through the module registry instead
    import importlib
    util = importlib.import_module("repro.core.utilization")

    g = pn_graph(4)
    w = 0.375
    d = np.full((g.n, g.n), w)
    np.fill_diagonal(d, 0.0)

    hits = []
    real = util._loads_orbit

    def spy(*a, **kw):
        hits.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(util, "_loads_orbit", spy)
    loads_auto, kbar_auto, diam_auto = arc_loads_weighted(g, d, engine="auto")
    assert hits, "uniform-shaped demand did not take the orbit path"
    # parity against the exact batched engine
    loads_np, kbar_np, diam_np = arc_loads_weighted(g, d, engine="numpy")
    np.testing.assert_allclose(loads_auto, loads_np, rtol=1e-9, atol=1e-12)
    assert kbar_auto == pytest.approx(kbar_np, abs=1e-12)
    assert diam_auto == diam_np
    # scaling: w times the unweighted uniform loads, bitwise
    base = arc_loads(g, engine="auto")
    np.testing.assert_array_equal(loads_auto, base[0] * w)


def test_orbit_shortcut_respects_leaf_restriction():
    g = oft_graph(3)
    leaf = g.meta["leaf_mask"]
    d = np.zeros((g.n, g.n))
    d[np.ix_(leaf, leaf)] = 2.5
    np.fill_diagonal(d, 0.0)
    loads_auto = arc_loads_weighted(g, d, engine="auto")
    loads_np = arc_loads_weighted(g, d, engine="numpy")
    np.testing.assert_allclose(loads_auto[0], loads_np[0],
                               rtol=1e-9, atol=1e-12)
    assert loads_auto[1] == pytest.approx(loads_np[1], abs=1e-12)


def test_non_uniform_demand_skips_orbit_shortcut(monkeypatch):
    import importlib
    util = importlib.import_module("repro.core.utilization")

    g = pn_graph(3)
    d = np.full((g.n, g.n), 1.0)
    np.fill_diagonal(d, 0.0)
    d[1, 2] = 1.5  # one perturbed entry: no longer uniform-shaped
    hits = []
    real = util._loads_orbit

    def spy(*a, **kw):
        hits.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(util, "_loads_orbit", spy)
    arc_loads_weighted(g, d, engine="auto")
    assert not hits


# ---------------------------------------------------------------------------
# hypothesis-driven forms (skip under the conftest stub)
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(data=st.data())
def test_hyp_ugal_dominates_pure_routings(data):
    names = sorted(GRAPHS)
    g = GRAPHS[names[data.draw(st.integers(0, len(names) - 1))]]()
    spec = PATTERN_SPECS[data.draw(st.integers(0, len(PATTERN_SPECS) - 1))]
    rmin, rval, rug = _report_trio(g, spec)
    assert rug.theta >= max(rmin.theta, rval.theta) - 1e-9
    assert 0.0 <= rug.alpha <= 1.0


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 600))
def test_hyp_blend_optimum_is_exact(seed, n):
    rng = np.random.default_rng(seed)
    l_min = rng.random(n) * rng.choice([0.5, 1.0, 4.0])
    l_val = rng.random(n) * rng.choice([0.5, 1.0, 4.0])
    alpha, fval, _ = blend_optimum(l_min, l_val)
    assert 0.0 <= alpha <= 1.0
    ga, gf = _grid_min(l_min, l_val, 4001)
    assert fval <= gf + 1e-9
    assert fval == pytest.approx(
        float((l_val + alpha * (l_min - l_val)).max()), abs=1e-12)


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1))
def test_hyp_uniform_reduction_everywhere(seed):
    names = sorted(GRAPHS)
    g = GRAPHS[names[seed % len(names)]]()
    rmin = saturation_report(g, "uniform")
    rug = saturation_report(g, "uniform", routing="ugal")
    assert rug.alpha == 1.0
    assert np.array_equal(rug.loads, rmin.loads)


# ---------------------------------------------------------------------------
# ugal_threshold: the fluid approximation of the per-hop threshold rule
# ---------------------------------------------------------------------------


def test_ugal_threshold_fluid_is_threshold_invariant():
    """Any finite margin reaches the same saturation blend in the fluid
    limit: theta and loads match the exact ugal optimum bitwise, only the
    model name records the threshold (repro.sim resolves what T actually
    changes — the diversion onset and latency)."""
    g = torus3d_graph(8, 16, 1)
    blend = saturation_report(g, "tornado", routing="ugal")
    for spec in ("ugal_threshold", "ugal_threshold(0)", "ugal_threshold(2)",
                 "ugal_threshold(7.5)"):
        rep = saturation_report(g, "tornado", routing=spec)
        assert rep.theta == blend.theta
        assert rep.alpha == blend.alpha
        assert np.array_equal(rep.loads, blend.loads)
    assert saturation_report(g, "tornado",
                             routing="ugal_threshold(2)").routing \
        == "ugal_threshold(2)"


def test_ugal_threshold_inf_degenerates_to_minimal():
    g = torus3d_graph(8, 16, 1)
    rmin = saturation_report(g, "tornado", routing="minimal")
    rinf = saturation_report(g, "tornado", routing="ugal_threshold(inf)")
    assert rinf.theta == rmin.theta
    assert np.array_equal(rinf.loads, rmin.loads)
    assert rinf.alpha == 1.0
    assert rinf.routing == "ugal_threshold(inf)"


def test_ugal_threshold_validates_and_lists():
    assert "ugal_threshold" in ROUTINGS
    with pytest.raises(ValueError):
        make_routing("ugal_threshold(-3)")
    m = make_routing("ugal_threshold(1.5)")
    assert m.name == "ugal_threshold(1.5)"
