"""Flight recorder, watchdog triggers, streaming export, and HTML
report tests (the PR 10 observability layer).

The heavyweight anchor is the postmortem e2e: a past-knee
``ugal_threshold`` probe on PN(16) MUST fire the dest-stability
watchdog, and the reloaded bundle's ring-buffer channels MUST replay
``SimRun.history`` bit-exactly (float64 through JSON via shortest-repr).
Everything else drives the triggers directly through synthetic samples.
"""

import json
import math
import os
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import pn_graph
from repro.obs import report as obs_report
from repro.sim import SimConfig, Simulator

# -- flight recorder -------------------------------------------------------


def test_recorder_ring_semantics():
    rec = obs.FlightRecorder(window=4)
    assert len(rec) == 0 and rec.channels == [] and rec.window_arrays() == {}
    for i in range(10):
        rec.record(i, {"b": float(i), "a": float(-i)})
    assert rec.channels == ["a", "b"]        # fixed sorted on first record
    assert len(rec) == 4 and rec.count == 10
    win = rec.window_arrays()
    assert win["step"].tolist() == [6, 7, 8, 9]   # oldest first, wrapped
    assert win["b"].tolist() == [6.0, 7.0, 8.0, 9.0]
    assert win["a"].tolist() == [-6.0, -7.0, -8.0, -9.0]
    # a later call missing a fixed channel raises instead of writing NaN
    with pytest.raises(KeyError):
        rec.record(10, {"b": 1.0})
    rec.reset()
    assert len(rec) == 0 and rec.channels == []


def test_recorder_partial_window_and_snapshot_roundtrip():
    rec = obs.FlightRecorder(window=8)
    vals = [0.1, 1 / 3, math.pi, 1e-300]
    for i, v in enumerate(vals):
        rec.record(i, {"x": v})
    win = rec.window_arrays()
    assert win["step"].tolist() == [0, 1, 2, 3]
    snap = json.loads(json.dumps(rec.snapshot()))
    assert snap["schema"] == "repro.obs/recorder/1"
    assert snap["window"] == 8 and snap["count"] == 4
    # float64 -> json -> float64 is bit-exact (shortest-repr round-trip)
    assert np.array_equal(np.asarray(snap["channels"]["x"]), win["x"])


def test_recorder_window_validation():
    with pytest.raises(ValueError):
        obs.FlightRecorder(window=0)


# -- watchdog triggers (synthetic samples) ---------------------------------


def _sample(step, **kw):
    base = {"step": step, "delivered": 1.0, "accepted": 1.0,
            "offered": 1.0, "occupancy": 0.5, "src_backlog": 0.0,
            "diverted": 0.0, "residual": 0.0}
    base.update(kw)
    return base


def test_residual_trigger_warmup_and_bundle(tmp_path):
    wd = obs.Watchdog([obs.residual(tol=1e-6, warmup=4)],
                      dir=str(tmp_path))
    wd.begin_run(backend="test", offered=1.0)
    wd.on_step(_sample(0, residual=1.0))    # inside warmup: armed, silent
    assert not wd.fired
    wd.on_step(_sample(5, residual=1e-3))
    assert len(wd.fired) == 1
    name, path = wd.fired[0]
    assert name == "residual" and os.path.exists(path)
    bundle = obs.load_bundle(path)
    assert bundle["schema"] == "repro.obs/postmortem/1"
    assert bundle["trigger"] == {"name": "residual", "tol": 1e-6,
                                 "warmup": 4}
    assert "residual" in bundle["reason"]
    assert bundle["context"]["backend"] == "test"
    assert bundle["sample"]["step"] == 5
    # one bundle per trigger: the same anomaly does not dump again
    wd.on_step(_sample(6, residual=1e-3))
    assert len(wd.fired) == 1 and wd.exhausted


def test_nonfinite_trigger_nan_and_negative_mass(tmp_path):
    wd = obs.Watchdog([obs.nonfinite()], dir=str(tmp_path))
    wd.on_step(_sample(0, delivered=float("nan")))
    assert wd.fired and "non-finite" in wd.last_bundle["reason"]
    wd2 = obs.Watchdog([obs.nonfinite()], dir=None)
    wd2.on_step(_sample(3, occupancy=-1e-3))
    assert wd2.fired[0] == ("nonfinite", None)   # dir=None: in-memory only
    assert "negative mass" in wd2.last_bundle["reason"]
    wd3 = obs.Watchdog([obs.nonfinite()], dir=None)
    wd3.on_step(_sample(1, dest_mass_min=-1.0))
    assert "per-dest" in wd3.last_bundle["reason"]


def test_step_time_trigger_spike(tmp_path):
    wd = obs.Watchdog([obs.step_time(factor=10.0, warmup=4,
                                     floor_s=0.01)], dir=None)
    for i in range(8):
        wd.on_step(_sample(i, step_seconds=0.001))
    assert not wd.fired
    wd.on_step(_sample(8, step_seconds=0.5))     # 500x the running mean
    assert wd.fired and "running mean" in wd.last_bundle["reason"]


def test_dest_stability_trigger_reads_digest(tmp_path):
    wd = obs.Watchdog([obs.dest_stability(ratio=0.5, window=8, warmup=4)],
                      dir=None)
    assert wd.needs("dest_mass") and wd.stability_window() == 8
    assert not wd.needs("step_seconds")
    # below warmup+window: silent even with a collapsed digest
    wd.on_step(_sample(5, dest_stability_min=0.1, dest_stability_col=3))
    assert not wd.fired
    wd.on_step(_sample(12, dest_stability_min=0.1, dest_stability_col=3))
    assert wd.fired and "(dest col 3)" in wd.last_bundle["reason"]
    # once fired, the monitor may drop the digest entirely
    assert not wd.needs("dest_mass") and wd.stability_window() is None


def test_oscillation_trigger_on_probe(tmp_path):
    wd = obs.Watchdog([obs.oscillation()], dir=str(tmp_path))
    wd.on_probe(2.0, stable=True)     # fine: stable below any collapse
    wd.on_probe(3.0, stable=False)    # the frontier
    wd.on_probe(2.5, stable=True)     # fine: below the collapsed load
    assert not wd.fired
    wd.on_probe(3.5, stable=True)     # stable ABOVE a collapsed probe
    assert wd.fired[0][0] == "oscillation"
    assert "non-monotone" in wd.last_bundle["reason"]
    assert wd.fired[0][1].endswith("postmortem_oscillation_probe.json")


def test_watchdog_halt_raises(tmp_path):
    wd = obs.Watchdog([obs.residual(tol=1e-6, warmup=0)], action="halt",
                      dir=str(tmp_path))
    with pytest.raises(obs.WatchdogFired) as ei:
        wd.on_step(_sample(1, residual=1.0))
    assert ei.value.trigger == "residual" and ei.value.path is not None
    assert os.path.exists(ei.value.path)


def test_watchdog_max_bundles_and_begin_run_rearm(tmp_path):
    wd = obs.Watchdog([obs.residual(tol=1e-6, warmup=0),
                       obs.nonfinite()], dir=str(tmp_path), max_bundles=1)
    wd.on_step(_sample(1, residual=1.0))
    assert len(wd.fired) == 1 and wd.exhausted
    # exhausted: the second trigger can no longer dump
    wd.on_step(_sample(2, delivered=float("nan")))
    assert len(wd.fired) == 1
    # begin_run re-arms only unfired triggers
    wd.begin_run()
    assert wd.triggers[0].fired and not wd.triggers[1].fired


def test_watchdog_rejects_unknown_action():
    with pytest.raises(ValueError):
        obs.Watchdog([], action="explode")


# -- postmortem e2e: past-knee probe fires, bundle is bit-exact ------------


def test_postmortem_e2e_past_knee_bit_exact(tmp_path):
    g = pn_graph(16)
    d = np.ones((g.n, g.n)) - np.eye(g.n)
    demand = d / d.sum(axis=1, keepdims=True)
    # pn16 uniform analytic theta ~6.97; 2x is comfortably past the knee
    offered = 2.0 * 6.9714
    rec = obs.FlightRecorder(window=24)
    wd = obs.Watchdog([obs.dest_stability(ratio=0.8, window=16, warmup=16)],
                      action="continue", dir=str(tmp_path / "pm"))
    simr = Simulator(g, SimConfig(routing="ugal_threshold(0)",
                                  backend="pallas"))
    with obs.session(mode="metrics", recorder=rec, watchdog=wd) as sess:
        assert sess.recorder is rec and sess.watchdog is wd
        run = simr.run(demand, offered, steps=60)
    assert wd.fired, "past-knee probe must fire the dest-stability watchdog"
    name, path = wd.fired[0]
    assert name == "dest_stability"

    bundle = obs.load_bundle(path)
    assert bundle["context"]["config"]["routing"] == "ugal_threshold(0)"
    assert bundle["context"]["demand_fingerprint"]
    # the ring window replays the run's own history arrays bit-exactly
    steps_idx = np.asarray(bundle["recorder"]["steps"], dtype=np.int64)
    assert len(steps_idx) == 24
    for key in ("delivered", "accepted", "offered", "occupancy",
                "src_backlog", "diverted"):
        got = np.asarray(bundle["recorder"]["channels"][key])
        want = np.asarray(run.history[key], dtype=np.float64)[steps_idx]
        assert np.array_equal(got, want), f"channel {key} diverged"
    # the digest channel exists and ends collapsed (below the ratio)
    stab = bundle["recorder"]["channels"]["dest_stability_min"]
    finite = [v for v in stab if v == v]
    assert finite and min(finite) < 0.8
    # and the firing sample carries the same story
    assert bundle["sample"]["dest_stability_min"] < 0.8


def test_monitor_skips_digests_without_triggers():
    # recorder-only session: no dest-mass pass, but channels still record
    g = pn_graph(16)
    d = np.ones((g.n, g.n)) - np.eye(g.n)
    demand = d / d.sum(axis=1, keepdims=True)
    rec = obs.FlightRecorder(window=8)
    simr = Simulator(g, SimConfig(backend="pallas"))
    with obs.session(mode="metrics", recorder=rec):
        run = simr.run(demand, 0.5, steps=20)
    assert len(rec) == 8
    assert "dest_stability_min" not in rec.channels
    win = rec.window_arrays()
    assert np.array_equal(win["delivered"],
                          np.asarray(run.history["delivered"])[win["step"]])


# -- thread-safe metrics ----------------------------------------------------


def test_counter_exact_under_4_workers():
    n_workers, n_inc = 4, 25_000
    with obs.session(mode="metrics") as sess:
        c = sess.metrics.counter("stress.total")
        h = sess.metrics.histogram("stress.obs")
        s = sess.metrics.series("stress.series")

        def work():
            for _ in range(n_inc):
                c.add(1.0)
                h.observe(1.0)
                s.append(1.0)

        threads = [threading.Thread(target=work) for _ in range(n_workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    # lost updates would show as a short count; the locks make it exact
    assert c.value == float(n_workers * n_inc)
    assert len(h.values) == n_workers * n_inc
    snap = sess.metrics.snapshot()
    assert snap["stress.total"]["value"] == float(n_workers * n_inc)
    assert snap["stress.series"]["count"] == n_workers * n_inc


# -- streaming export -------------------------------------------------------


def test_streamer_header_events_and_emit(tmp_path):
    path = str(tmp_path / "telemetry.jsonl")
    with obs.session(mode="metrics", stream=path) as sess:
        assert sess.stream is not None
        obs.emit("checkpoint", phase="one", value=1.5)
        obs.emit("checkpoint", phase="two", arr=np.float64(2.0))
    lines = [json.loads(ln) for ln in open(path)]
    assert lines[0]["schema"] == "repro.obs/stream/1"
    assert lines[1]["kind"] == "checkpoint" and lines[1]["phase"] == "one"
    assert lines[2]["arr"] == 2.0
    assert all("t_s" in ln for ln in lines[1:])
    # emit without a session (or without a stream) is a silent no-op
    obs.emit("nobody", listening=True)
    with obs.session(mode="metrics"):
        obs.emit("nobody", listening=True)


def test_progress_emits_done_total_eta(tmp_path):
    path = str(tmp_path / "prog.jsonl")
    with obs.session(mode="metrics", stream=path) as sess:
        p = obs.Progress("adversary.candidates", total=4)
        for i in range(4):
            p.step(pattern=f"p{i}")
        snap = sess.metrics.snapshot()
    assert snap["adversary.candidates.done"]["value"] == 4.0
    events = [json.loads(ln) for ln in open(path)][1:]
    assert [e["done"] for e in events] == [1, 2, 3, 4]
    assert all(e["kind"] == "progress" and e["total"] == 4 for e in events)
    assert events[0]["pct"] == 25.0 and "eta_s" in events[0]
    assert events[-1]["pct"] == 100.0 and "eta_s" not in events[-1]


def test_openmetrics_text_format():
    reg = obs.MetricsRegistry()
    reg.counter("sim.delivered").add(12.5)
    reg.gauge("sim.backend[pallas]").set(1.0)
    reg.histogram("sim.link_util").observe_many([0.1, 0.5, 0.9])
    reg.series("sim.occ_vc0").append(3.0)
    text = obs.openmetrics_text(reg)
    assert "# TYPE repro_sim_delivered counter" in text
    assert "repro_sim_delivered_total 12.5" in text
    assert 'repro_sim_backend{variant="pallas"} 1.0' in text
    assert "# TYPE repro_sim_link_util summary" in text
    assert 'repro_sim_link_util{quantile="0.5"}' in text
    assert "repro_sim_link_util_count 3" in text
    assert text.endswith("# EOF\n")
    # snapshot dicts and sessions render identically
    assert obs.openmetrics_text(reg.snapshot()) == text


def test_write_openmetrics(tmp_path):
    reg = obs.MetricsRegistry()
    reg.counter("a.b").add(1.0)
    out = tmp_path / "metrics.prom"
    obs.write_openmetrics(str(out), reg)
    assert out.read_text().endswith("# EOF\n")


# -- HTML report ------------------------------------------------------------


def _bench_payload(seconds, err, with_error=False):
    return {"schema_version": 2, "total_seconds": seconds,
            "entries": [{"name": "sim[pn16:ugal]", "seconds": seconds,
                         "max_rel_err": err}],
            "errors": ([{"section": "sim", "error": "Boom"}]
                       if with_error else [])}


def test_html_report_bench_session_bundle(tmp_path):
    for i, (s, e) in enumerate([(1.0, 0.01), (1.2, 0.02), (0.9, 0.015)]):
        (tmp_path / f"BENCH_{i}.json").write_text(
            json.dumps(_bench_payload(s, e, with_error=(i == 2))))
    with obs.session(mode="trace") as sess:
        with obs.span("sim.run", offered=1.0):
            sess.metrics.gauge("sim.balance.gini").set(0.12)
            sess.metrics.series("sim.occ_vc0").append(1.0)
            sess.metrics.series("sim.occ_vc0").append(2.0)
    wd = obs.Watchdog([obs.residual(tol=1e-9, warmup=0)],
                      dir=str(tmp_path / "pm"))
    wd.begin_run(backend="numpy")
    wd.on_step(_sample(3, residual=1.0))
    bundle = obs.load_bundle(wd.fired[0][1])

    doc = obs_report.html_report(
        bench_dir=str(tmp_path),
        sessions=[("probe", sess.snapshot(),
                   obs_report.session_series(sess))],
        bundles=[bundle], title="test report")
    assert doc.startswith("<!DOCTYPE html>") and doc.endswith("</html>")
    assert "BENCH trajectory (3 files)" in doc
    assert "sim[pn16:ugal]" in doc and "<svg" in doc
    assert "crashed sections in BENCH_2.json" in doc       # banner
    assert "session: probe" in doc and "sim.balance.gini" in doc
    assert "sim.run" in doc
    assert "postmortem: residual" in doc
    assert "conservation residual" in doc                  # the reason
    # no external references: self-contained single file
    assert "http" not in doc.replace("http://www.w3.org", "")


def test_report_cli_and_error_paths(tmp_path, capsys):
    out = tmp_path / "r.html"
    (tmp_path / "BENCH_0.json").write_text(
        json.dumps(_bench_payload(1.0, 0.01)))
    rc = obs_report.main(["-o", str(out), "--bench-dir", str(tmp_path)])
    assert rc == 0 and out.exists()
    assert "<h1>" in out.read_text()
    # a --session file that is neither a snapshot nor a BENCH payload
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"nope": 1}))
    rc = obs_report.main(["-o", str(out), "--session", str(bad)])
    assert rc == 2
    # a BENCH payload with an obs block loads per-section sessions
    payload = _bench_payload(1.0, 0.01)
    payload["obs"] = {"sim": {"schema": "repro.obs/1", "mode": "trace",
                              "spans": {}, "metrics": {}}}
    snap = tmp_path / "BENCH_obs.json"
    snap.write_text(json.dumps(payload))
    rc = obs_report.main(["-o", str(out), "--session", str(snap)])
    assert rc == 0 and "BENCH_obs.json:sim" in out.read_text()
