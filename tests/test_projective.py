"""Projective-plane axioms and the structure of PN / demi-PN / OFT / MLFM."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    demi_pn_graph,
    get_field,
    incidence_lists,
    mlfm_graph,
    num_points,
    oft_graph,
    pn_graph,
    points,
    self_orthogonal_points,
    subplane_classes,
    subplane_line_classes,
)
from repro.core.projective import normalize_points, point_index

QS = [2, 3, 4, 5, 7, 8, 9]


@pytest.mark.parametrize("q", QS)
def test_plane_axioms(q):
    """q+1 points per line; every point on q+1 lines; two distinct points on
    exactly one common line (the dual of Lemma 3.8's uniqueness)."""
    inc = incidence_lists(q)
    n = num_points(q)
    assert inc.shape == (n, q + 1)
    # each point lies on exactly q+1 lines
    counts = np.bincount(inc.reshape(-1), minlength=n)
    assert (counts == q + 1).all()
    # any two points on exactly one common line
    member = np.zeros((n, n), dtype=np.int32)  # member[line, point]
    member[np.repeat(np.arange(n), q + 1), inc.reshape(-1)] = 1
    common = member.T @ member
    off = common - np.diag(np.diag(common))
    assert off.max() == 1 and (off + np.eye(n, dtype=np.int32) * (q + 1) >= 1).all()


@pytest.mark.parametrize("q", QS)
def test_incidence_is_orthogonality(q):
    f = get_field(q)
    pts = points(q)
    inc = incidence_lists(q)
    lines = np.repeat(np.arange(num_points(q)), q + 1)
    dots = f.dot3(pts[inc.reshape(-1)], pts[lines])
    assert (dots == 0).all()


@pytest.mark.parametrize("q", QS)
def test_pn_structure(q):
    g = pn_graph(q)
    n = num_points(q)
    assert g.n == 2 * n
    assert g.is_regular() and g.max_degree == q + 1
    # bipartite: all edges cross the point/line split
    assert ((g.edges[:, 0] < n) != (g.edges[:, 1] < n)).all()
    w = g.distance_distribution([0, n])
    assert np.allclose(w, [1, q + 1, q * q + q, q * q])
    kbar = g.average_distance([0])
    assert abs(kbar - (5 * q * q + 3 * q + 1) / (2 * q * q + 2 * q + 1)) < 1e-12


def test_pn2_is_heawood():
    g = pn_graph(2)
    assert (g.n, g.num_edges, g.max_degree) == (14, 21, 3)
    assert g.diameter([0]) == 3
    # girth 6 (no 4-cycles): adjacency^2 off-diagonal <= 1
    a = g.adjacency_dense().astype(np.int32)
    a2 = a @ a
    off = a2 - np.diag(np.diag(a2))
    assert off.max() <= 1


@pytest.mark.parametrize("q", QS)
def test_demi_pn_structure(q):
    g = demi_pn_graph(q)
    n = num_points(q)
    assert g.n == n
    assert g.num_edges == q * (q + 1) ** 2 // 2
    so = self_orthogonal_points(q)
    assert len(so) == q + 1
    deg = g.degrees
    assert (deg[so] == q).all()
    mask = np.ones(n, dtype=bool)
    mask[so] = False
    assert (deg[mask] == q + 1).all()
    assert g.diameter() == 2


@pytest.mark.parametrize("q", [2, 3, 4, 5, 7])
def test_demi_pn_unique_shortest_paths(q):
    """Lemma 3.8: no 4-cycles => unique minimal path between any pair."""
    g = demi_pn_graph(q)
    a = g.adjacency_dense().astype(np.int64)
    a2 = a @ a
    off = a2 - np.diag(np.diag(a2))
    # distance-2 pairs have exactly one common neighbour; adjacent pairs have
    # at most ... no square means adjacent pairs can share at most 1 too
    nonadj = (~g.adjacency_dense()) & ~np.eye(g.n, dtype=bool)
    assert (off[nonadj] == 1).all()


@pytest.mark.parametrize("q", [2, 3, 4, 5])
def test_oft_structure(q):
    g = oft_graph(q)
    n = num_points(q)
    assert g.n == 3 * n
    deg = g.degrees
    assert (deg[:n] == q + 1).all() and (deg[2 * n :] == q + 1).all()
    assert (deg[n : 2 * n] == 2 * (q + 1)).all()
    # max distance between leaves is 2
    leaf = g.meta["leaf_mask"]
    for v in [0, 1, 2 * n, 3 * n - 1]:
        d = g.distances_from(v)
        assert d[leaf].max() == 2


@pytest.mark.parametrize("n", [3, 4, 5, 6])
def test_mlfm_structure(n):
    g = mlfm_graph(n)
    n_leaves = n * (n - 1)
    assert g.n == n_leaves + n * (n - 1) // 2
    deg = g.degrees
    assert (deg[:n_leaves] == n - 1).all()
    assert (deg[n_leaves:] == 2 * (n - 1)).all()
    leaf = g.meta["leaf_mask"]
    for v in range(0, n_leaves, max(1, n_leaves // 4)):
        d = g.distances_from(v)
        assert d[leaf].max() == 2


@pytest.mark.parametrize("q", [4, 9])
def test_subplane_partition(q):
    p = int(round(q**0.5))
    cls = subplane_classes(q)
    r = p * p - p + 1
    assert len(np.unique(cls)) == r
    assert (np.bincount(cls) == p * p + p + 1).all()
    lcls = subplane_line_classes(q, cls)
    # each class of the PN graph induces a copy of G_p: (p^2+p+1)(p+1) incidences
    g = pn_graph(q)
    n = num_points(q)
    lbl = np.concatenate([cls, lcls])
    same = lbl[g.edges[:, 0]] == lbl[g.edges[:, 1]]
    per = np.bincount(lbl[g.edges[:, 0]][same], minlength=r)
    assert (per == (p * p + p + 1) * (p + 1)).all()


@given(st.sampled_from([3, 4, 5, 7, 8, 9]), st.data())
@settings(max_examples=60, deadline=None)
def test_normalize_point_roundtrip(q, data):
    """Scaling a canonical point by any nonzero scalar normalizes back."""
    f = get_field(q)
    pts = points(q)
    i = data.draw(st.integers(0, num_points(q) - 1))
    s = data.draw(st.integers(1, q - 1))
    scaled = np.stack([f.mul(pts[i, k], s) for k in range(3)])
    canon = normalize_points(f, scaled)
    assert (canon == pts[i]).all()
    assert int(point_index(q, canon)) == i
