"""Per-arch smoke tests: reduced config of the same family, one forward +
train step on CPU, asserting output shapes and no NaNs; plus prefill/decode
consistency (decode token-by-token == full forward) for each cache family.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_arch

# Heavyweight JAX suite: excluded from tier-1 (see pyproject.toml)
pytestmark = pytest.mark.slow

from repro.models import build, unbox
from repro.models.transformer import forward


def _batch_for(cfg, b=2, s=32, key=0):
    tokens = jax.random.randint(jax.random.key(key), (b, s), 0, cfg.vocab)
    batch = {"tokens": tokens}
    if cfg.vision is not None:
        batch["memory"] = jnp.ones((b, cfg.vision.n_image_tokens, cfg.d_model),
                                   jnp.bfloat16) * 0.01
    if cfg.encoder is not None:
        batch["memory"] = jnp.ones((b, max(1, s // cfg.encoder.frame_ratio),
                                    cfg.d_model), jnp.bfloat16) * 0.01
    return batch


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_forward_shapes_and_finite(name):
    cfg = get_arch(name).reduced()
    bundle = build(cfg)
    params = unbox(bundle.init(jax.random.key(0)))
    batch = _batch_for(cfg)
    out = forward(cfg, params, batch["tokens"], mode="train",
                  memory_inputs=batch.get("memory"))
    assert out["logits"].shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(out["logits"])).all()
    loss, metrics = bundle.loss(params, batch)
    assert np.isfinite(float(loss))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_reduced_train_step_decreases_loss(name):
    from repro.train.train_step import TrainStepConfig, init_train_state, \
        make_train_step
    from repro.launch.mesh import make_host_mesh
    cfg = get_arch(name).reduced()
    mesh = make_host_mesh(1, 1)
    step_fn, _ = make_train_step(cfg, mesh)
    state = init_train_state(cfg, jax.random.key(0), TrainStepConfig())
    batch = _batch_for(cfg)
    losses = []
    for _ in range(5):
        state, metrics = step_fn(state, batch)
        losses.append(float(np.asarray(metrics["loss"])))
    assert all(np.isfinite(losses))
    assert losses[-1] < losses[0], losses  # same batch: must overfit


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_matches_full_forward(name):
    """Teacher-forced decode over a prefilled cache must reproduce the
    full-sequence forward logits position by position."""
    cfg = get_arch(name).reduced()
    bundle = build(cfg)
    params = unbox(bundle.init(jax.random.key(0)))
    b, s = 1, 16
    n_dec = 4
    batch = _batch_for(cfg, b=b, s=s, key=3)
    tokens = batch["tokens"]
    full = forward(cfg, params, tokens, mode="train",
                   memory_inputs=batch.get("memory"))["logits"]

    prompt = tokens[:, : s - n_dec]
    mem = batch.get("memory")
    logits_p, cache = bundle.prefill(params, prompt, memory=mem,
                                     cache_slots=s)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, -1], np.float32),
        np.asarray(full[:, s - n_dec - 1], np.float32), atol=3e-2, rtol=3e-2)
    for i in range(n_dec):
        pos = jnp.full((b, 1), s - n_dec + i, jnp.int32)
        tok = tokens[:, s - n_dec + i: s - n_dec + i + 1]
        logits_d, cache = bundle.decode_step(params, cache, tok, pos)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, s - n_dec + i], np.float32),
            atol=3e-2, rtol=3e-2,
            err_msg=f"{name}: decode step {i} diverges from full forward")


def test_sliding_window_ring_cache_eviction():
    """Danube-style SWA: decoding far past the window must equal the full
    forward (ring buffer evicts correctly)."""
    cfg = get_arch("h2o-danube-3-4b").reduced().replace(window=8)
    bundle = build(cfg)
    params = unbox(bundle.init(jax.random.key(1)))
    b, s = 1, 24
    tokens = jax.random.randint(jax.random.key(5), (b, s), 0, cfg.vocab)
    full = forward(cfg, params, tokens, mode="train")["logits"]
    n_dec = 12  # decode well past one window
    logits_p, cache = bundle.prefill(params, tokens[:, : s - n_dec])
    for i in range(n_dec):
        pos = jnp.full((b, 1), s - n_dec + i, jnp.int32)
        tok = tokens[:, s - n_dec + i: s - n_dec + i + 1]
        logits_d, cache = bundle.decode_step(params, cache, tok, pos)
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0], np.float32),
            np.asarray(full[:, s - n_dec + i], np.float32),
            atol=3e-2, rtol=3e-2, err_msg=f"window decode step {i}")


def test_mtp_and_aux_losses_present():
    cfg = get_arch("deepseek-v3-671b").reduced()
    bundle = build(cfg)
    params = unbox(bundle.init(jax.random.key(0)))
    batch = _batch_for(cfg)
    loss, metrics = bundle.loss(params, batch)
    assert "mtp" in metrics and np.isfinite(float(metrics["mtp"]))
    assert float(metrics["aux"]) > 0.0  # MoE balance loss active


def test_moe_dense_path_routes_all_tokens():
    from repro.models.moe import apply_moe, init_moe
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    p = unbox(init_moe(cfg, jax.random.key(0)))
    x = jax.random.normal(jax.random.key(1), (2, 8, cfg.d_model), jnp.float32)
    y, aux = apply_moe(cfg, p, x, mesh=None, impl="dense")
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
