"""repro.sim: fluid-limit parity, threshold/buffer semantics, determinism,
and flow conservation.

The parity seam (docs/simulation.md): with zero threshold and infinite
buffers the simulator's saturation knee must reproduce the analytic
theta of the matching registry model — minimal and valiant everywhere,
and the exact ugal blend where the optimum is interior (the 8x16-torus
tornado).  Stability probes here assert the two sides of the knee
directly (delivered tracks offered just below the analytic theta,
collapses above) instead of running full bisection sweeps — same
physics, a fraction of the wall time; BENCH_5.json carries the refined
bisection numbers.

Conservation is exact by construction (every step moves fluid between
ledger entries), so the residual invariant is checked in hypothesis form
over random patterns/loads AND as a deterministic sweep (the repo's
test_traffic_properties convention)."""

from __future__ import annotations

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import oft_graph, pn_graph
from repro.core.traffic import make_pattern, normalize_demand, saturation_report
from repro.fabric.model import torus3d_graph
from repro.sim import (SimConfig, Simulator, fluid_routing_spec,
                       saturation_sweep, simulate, simulate_placement)
from repro.sim.engine import parse_sim_routing, pick_backend

TORUS = torus3d_graph(8, 16, 1)
TH_UNIFORM_MIN = 0.4961  # analytic references on the 8x16 torus (BENCH_3)
TH_TORNADO_MIN = 1.0 / 3.0
TH_TORNADO_UGAL = 0.4147
TH_TORNADO_VAL = 0.2480


def _ratio(run):
    return run.theta / run.offered


# ---------------------------------------------------------------------------
# fluid-limit parity: torus2d_8x16 (uniform + tornado)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pattern,routing,theta", [
    ("uniform", "minimal", TH_UNIFORM_MIN),
    ("tornado", "minimal", TH_TORNADO_MIN),
    ("tornado", "valiant", TH_TORNADO_VAL),
])
def test_fluid_parity_torus_pure(pattern, routing, theta):
    ref = saturation_report(TORUS, pattern, routing=routing).theta
    assert ref == pytest.approx(theta, rel=2e-3)
    below = simulate(TORUS, pattern, routing=routing, offered=0.97 * ref,
                     steps=280)
    assert _ratio(below) > 0.99          # sustains just below analytic theta
    above = simulate(TORUS, pattern, routing=routing, offered=1.12 * ref,
                     steps=280)
    assert _ratio(above) < 0.97          # collapses just above it


def test_fluid_parity_torus_tornado_ugal():
    """Zero-threshold / infinite-buffer UGAL reproduces the exact blend
    theta on tornado's home ground — the optimum is interior (alpha
    ~0.40), so this is the real adaptive-routing claim, not a relabeled
    minimal run.  Measured diversion matches the blend's alpha."""
    ref = saturation_report(TORUS, "tornado", routing="ugal")
    assert ref.theta == pytest.approx(TH_TORNADO_UGAL, rel=2e-3)
    below = simulate(TORUS, "tornado", routing="ugal_threshold(0)",
                     offered=0.97 * ref.theta, steps=400)
    assert _ratio(below) > 0.99
    assert below.theta > 1.1 * TH_TORNADO_MIN   # genuinely beats minimal
    assert below.alpha == pytest.approx(ref.alpha, abs=0.12)
    above = simulate(TORUS, "tornado", routing="ugal_threshold(0)",
                     offered=1.12 * ref.theta, steps=400)
    assert _ratio(above) < 0.97


def test_ugal_stays_minimal_below_saturation():
    """On balanced traffic the threshold rule never fires below
    saturation: alpha == 1 exactly and latency is the zero-load hop
    count (Little's law on the uncongested pipeline)."""
    r = simulate(TORUS, "uniform", routing="ugal_threshold(0)",
                 offered=0.8 * TH_UNIFORM_MIN, steps=200)
    assert _ratio(r) > 0.999
    assert r.alpha == 1.0
    kbar = TORUS.average_distance()
    assert r.latency == pytest.approx(kbar, rel=0.05)


def test_threshold_delays_diversion():
    """A positive margin diverts later: at the same sub-saturation load
    the T=2 router keeps strictly more traffic minimal than T=0, while
    both sustain the load (fluid theta is threshold-invariant)."""
    lam = 0.85 * TH_TORNADO_UGAL
    r0 = simulate(TORUS, "tornado", routing="ugal_threshold(0)",
                  offered=lam, steps=300)
    r2 = simulate(TORUS, "tornado", routing="ugal_threshold(2)",
                  offered=lam, steps=300)
    assert _ratio(r0) > 0.98 and _ratio(r2) > 0.98
    assert r2.alpha > r0.alpha + 0.1


# ---------------------------------------------------------------------------
# fluid-limit parity: pn16 (the acceptance case) and the leaf-restricted OFT
# ---------------------------------------------------------------------------


def test_fluid_parity_pn16_uniform():
    """pn16 uniform: stable at 0.95x the analytic theta, collapsed at
    1.12x — bracketing the measured knee within the 5%-parity claim that
    BENCH_5.json's bisection pins more tightly."""
    ref = saturation_report(pn_graph(16), "uniform", routing="minimal").theta
    assert ref == pytest.approx(6.9714, rel=2e-3)
    simr = Simulator(pn_graph(16), SimConfig(routing="minimal"))
    demand = normalize_demand(make_pattern("uniform").demand(simr.g))
    below = simr.run(demand, 0.95 * ref, steps=40)
    assert _ratio(below) > 0.99
    above = simr.run(demand, 1.12 * ref, steps=40)
    assert _ratio(above) < 0.97


def test_oft4_leaf_restricted():
    """Indirect network seam: only leaves inject/eject, spine routers
    carry transit fluid; the knee matches the leaf-normalized theta."""
    g = oft_graph(4)
    ref = saturation_report(g, "uniform", routing="minimal").theta
    sw = saturation_sweep(g, "uniform", routing="minimal",
                          loads=np.array([0.92, 1.1]) * ref,
                          steps=96, refine=1)
    assert sw.theta >= 0.92 * ref
    assert sw.theta_unstable <= 1.1 * ref
    spine = np.setdiff1d(np.arange(g.n), np.nonzero(g.meta["leaf_mask"])[0])
    assert len(spine) > 0  # the case is genuinely indirect


# ---------------------------------------------------------------------------
# buffers, determinism, backends, conservation
# ---------------------------------------------------------------------------


def test_finite_buffers_bound_occupancy():
    """Credit flow control keeps every router's per-vc occupancy at the
    buffer depth (small overshoot allowed: blocked upstream fluid holds
    its claim one step — the documented one-round credit approximation)."""
    buf = 3.0
    simr = Simulator(TORUS, SimConfig(routing="minimal", buffer=buf))
    demand = normalize_demand(make_pattern("tornado").demand(TORUS))
    r = simr.run(demand, 1.3 * TH_TORNADO_MIN, steps=200)
    st = simr.last_state
    for q in (st.q0, st.q1, st.q2):
        per_router = q.sum(axis=(1, 2))
        assert per_router.max() <= buf * 1.5 + 1.0
    assert r.residual < 1e-12            # backpressure never loses fluid
    assert r.src_backlog > 0.0           # the overload waits at the source


def test_determinism():
    runs = [simulate(TORUS, "random_permutation(7)",
                     routing="ugal_threshold(0)", offered=0.3, steps=80)
            for _ in range(2)]
    assert np.array_equal(runs[0].history["delivered"],
                          runs[1].history["delivered"])
    assert runs[0].theta == runs[1].theta
    other = simulate(TORUS, "random_permutation(8)",
                     routing="ugal_threshold(0)", offered=0.3, steps=80)
    assert not np.array_equal(runs[0].history["delivered"],
                              other.history["delivered"])


def test_backend_parity():
    pytest.importorskip("jax")
    demand = normalize_demand(make_pattern("tornado").demand(TORUS))
    out = {}
    for backend in ("numpy", "jax"):
        simr = Simulator(TORUS, SimConfig(routing="ugal_threshold(0)",
                                          backend=backend))
        out[backend] = simr.run(demand, 0.38, steps=120)
        assert simr.backend == backend
    assert out["jax"].theta == pytest.approx(out["numpy"].theta, rel=1e-9)
    assert out["jax"].alpha == pytest.approx(out["numpy"].alpha, rel=1e-6)


SMALL = torus3d_graph(4, 4, 1)
CONSERVE_CASES = [("uniform", "minimal", float("inf")),
                  ("tornado", "ugal_threshold(0)", 4.0),
                  ("shift(3)", "valiant", 2.0),
                  ("hot_region(0.25,4)", "ugal_threshold(1)", 8.0)]


def _check_conservation(pattern, routing, buffer, offered, steps=120):
    r = simulate(SMALL, pattern, routing=routing, offered=offered,
                 steps=steps, config=SimConfig(buffer=buffer))
    assert r.residual < 1e-12
    injected = r.history["offered"].sum()
    delivered = r.history["delivered"].sum()
    assert delivered <= injected * (1 + 1e-12)
    return r


@pytest.mark.parametrize("pattern,routing,buffer", CONSERVE_CASES)
def test_flow_conservation(pattern, routing, buffer):
    _check_conservation(pattern, routing, buffer, offered=0.5)
    _check_conservation(pattern, routing, buffer, offered=2.0)  # overload


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 2**31 - 1),
       offered=st.floats(0.05, 3.0),
       buffer=st.sampled_from([2.0, 8.0, float("inf")]))
def test_flow_conservation_hypothesis(seed, offered, buffer):
    _check_conservation(f"random_permutation({seed})", "ugal_threshold(0)",
                        buffer, offered, steps=60)


# ---------------------------------------------------------------------------
# placement replay and API validation
# ---------------------------------------------------------------------------


def test_simulate_placement():
    from repro.fabric.placement import Placement, placement_report
    g = torus3d_graph(4, 4, 1)
    p = Placement(graph=g, mesh_shape=(4, 4), axis_names=("data", "model"),
                  router_of=np.arange(16))
    schedule = {"data": ("ring", 64.0), "model": ("all_to_all", 64.0)}
    ref = placement_report(p, schedule, routing="minimal").theta
    r = simulate_placement(p, schedule, routing="minimal",
                           offered=0.9 * ref, steps=160)
    assert _ratio(r) > 0.99              # sustains below the analytic knee
    assert r.residual < 1e-12
    over = simulate_placement(p, schedule, routing="minimal", steps=160)
    assert over.offered == pytest.approx(1.2 * ref)
    assert over.theta <= over.offered * (1 + 1e-9)


def test_spec_and_input_validation():
    assert parse_sim_routing("ugal") == ("ugal", 0.0)
    assert parse_sim_routing("ugal_threshold(2.5)") == ("ugal", 2.5)
    assert parse_sim_routing("minimal")[0] == "minimal"
    with pytest.raises(ValueError):
        parse_sim_routing("ugal_threshold(-1)")
    with pytest.raises(ValueError):
        parse_sim_routing("minimal(3)")
    with pytest.raises(ValueError):
        parse_sim_routing("ecmp")
    with pytest.raises(ValueError):
        pick_backend("tpu", 10)
    simr = Simulator(SMALL, SimConfig())
    with pytest.raises(ValueError):
        simr.run(np.zeros((4, 4)), 0.5)          # wrong shape
    with pytest.raises(ValueError):
        simr.run(np.zeros((16, 16)), 0.5)        # all-zero demand
    g = oft_graph(4)
    bad = np.ones((g.n, g.n))                    # targets a spine router
    with pytest.raises(ValueError):
        Simulator(g, SimConfig()).run(bad, 0.5)
