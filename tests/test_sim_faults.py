"""Live-sim fault events: masked tables, state surgery, and the
static-vs-dynamic parity seams (docs/faults.md).

The two seams that pin the fault model:

  * static masked == removed graph, EXACTLY — a link-only FaultSet
    applied as ``events=[(0, fs)]`` on the pristine simulator must
    reproduce the per-step history of simulating ``fs.apply(g)``
    directly (same N, same steps): masking is a reindexing, not an
    approximation.
  * static == dynamic knee within 2.5% — the saturation knee with the
    fault pre-applied equals the knee with the same fault injected
    mid-run once the window sits after the reroute transient.  The torus
    seam runs in tier-1; the pn16 seam is `slow` (pn16 is ~0.4 s/step,
    the ROADMAP kernel item) and re-measured continuously as BENCH_6's
    ``faults[sim_parity:...]`` row.

Everything else here is conservation: surgery accounts every unit it
drops, requeue conserves exactly, and the run residual stays at
round-off through fault AND recovery events."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import FaultSet, degraded_report, pn_graph, random_faults
from repro.core.traffic import make_pattern, normalize_demand, saturation_report
from repro.fabric.model import torus3d_graph
from repro.sim import FaultEvent, SimConfig, Simulator, saturation_sweep
from repro.sim.faults import apply_fault_surgery, normalize_events
from repro.sim.tables import build_tables

G16 = torus3d_graph(4, 4, 1)          # 16-router workhorse, numpy backend


def _uniform(g):
    return normalize_demand(make_pattern("uniform").demand(g, None))


def _state_mass(st):
    """Conserved fluid mass of a step-state tuple: queues + source
    backlog + stage2 credit.  ``pend`` is conversion bookkeeping (its
    mass mirrors vc1 + stage2), not fluid."""
    q0, q1, q2, src, pend, stage2 = st
    return float(q0.sum() + q1.sum() + q2.sum() + src.sum() + stage2.sum())


# ---------------------------------------------------------------------------
# Masked tables
# ---------------------------------------------------------------------------


def test_pristine_tables_are_all_alive():
    t = build_tables(G16, np.arange(G16.n))
    assert not t.faulted
    assert t.slot_ok.all() and t.router_ok.all() and t.dest_ok.all()
    assert t.routable.all()


def test_faulted_tables_masks_and_splits():
    fs = random_faults(G16, k_links=3, seed=0)
    t = build_tables(G16, np.arange(G16.n), faults=fs)
    assert t.faulted
    # slot_ok mirrors edge_alive through the arc order
    alive = fs.edge_alive(G16)
    for r in range(G16.n):
        deg = G16.indptr[r + 1] - G16.indptr[r]
        arcs = np.arange(G16.indptr[r], G16.indptr[r + 1])
        np.testing.assert_array_equal(t.slot_ok[r, :deg],
                                      alive[G16.arc_edge_id[arcs]])
        assert not t.slot_ok[r, deg:].any()          # padding stays dead
    # link-only faults on a connected survivor keep every pair routable
    assert t.routable.all()
    # split rows: sum to 1 on routable non-self pairs, only via live slots
    for r in range(G16.n):
        for d in range(t.m):
            row = t.split[r, :, d]
            assert not row[~t.slot_ok[r]].any()
            if r != t.active[d]:
                assert row.sum() == pytest.approx(1.0, abs=1e-12)
    # distances recomputed on the degraded graph
    gd = fs.apply(G16)
    from repro.core.graph import bfs_distances_batched
    np.testing.assert_array_equal(
        t.dist_act, bfs_distances_batched(gd, np.arange(gd.n)))


def test_router_fault_tables_mask_dest_and_row():
    fs = FaultSet(routers=[5])
    t = build_tables(G16, np.arange(G16.n), faults=fs)
    assert not t.router_ok[5] and not t.dest_ok[5]
    assert not t.routable[5, :].any() and not t.routable[:, 5].any()
    assert not t.slot_ok[5].any()
    alive = [r for r in range(G16.n) if r != 5]
    assert t.routable[np.ix_(alive, alive)].all()
    # no split ever sends fluid toward the dead dest
    assert not t.split[:, :, 5].any()


def test_faulted_tables_disconnect_raises():
    vid = 5
    cut = [tuple(sorted(map(int, e))) for e in G16.edges
           if vid in (int(e[0]), int(e[1]))]
    with pytest.raises(ValueError, match="disconnect the active set"):
        build_tables(G16, np.arange(G16.n), faults=FaultSet(links=cut))


# ---------------------------------------------------------------------------
# Event schedule validation
# ---------------------------------------------------------------------------


def test_normalize_events():
    fs = random_faults(G16, k_links=1, seed=0)
    evs = normalize_events([(40, FaultSet()), FaultEvent(10, fs)])
    assert [e.step for e in evs] == [10, 40]
    assert evs[0].faults == fs and evs[1].faults.empty
    assert normalize_events(None) == ()
    with pytest.raises(ValueError, match="duplicate"):
        normalize_events([(10, fs), (10, FaultSet())])
    with pytest.raises(ValueError, match="nonnegative"):
        FaultEvent(-1, fs)
    with pytest.raises(TypeError, match="FaultSet"):
        FaultEvent(3, "links[0-1]")


def test_event_past_run_end_raises():
    sim = Simulator(G16, SimConfig(routing="minimal"))
    fs = random_faults(G16, k_links=1, seed=0)
    with pytest.raises(ValueError, match="past"):
        sim.run(_uniform(G16), offered=0.1, steps=50, events=[(50, fs)])


# ---------------------------------------------------------------------------
# State surgery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fs", [
    FaultSet(routers=[5]),
    random_faults(G16, k_links=3, seed=2),
])
def test_surgery_accounts_every_dropped_unit(fs):
    sim = Simulator(G16, SimConfig(routing="ugal_threshold(0)"))
    sim.run(_uniform(G16), offered=0.3, steps=40)
    st = sim.last_state.as_tuple()
    tb, _ = sim._tables_for(fs)
    st2, dropped = apply_fault_surgery(st, tb)
    assert _state_mass(st2) == pytest.approx(_state_mass(st) - dropped,
                                             rel=1e-12, abs=1e-12)
    if fs.routers:
        assert dropped > 0                    # dead router loses real fluid
    # idempotent: a second pass against the same tables drops nothing
    st3, dropped2 = apply_fault_surgery(st2, tb)
    assert dropped2 == pytest.approx(0.0, abs=1e-12)
    for a, b in zip(st2, st3):
        np.testing.assert_allclose(a, b, atol=1e-12)


def test_surgery_requeues_dead_slot_fluid():
    """Link-only faults on a connected survivor drop nothing: fluid in
    dead out-slots moves to live slots of the same router, exactly."""
    fs = random_faults(G16, k_links=3, seed=2)
    sim = Simulator(G16, SimConfig(routing="minimal"))
    sim.run(_uniform(G16), offered=0.3, steps=40)
    st = sim.last_state.as_tuple()
    tb, _ = sim._tables_for(fs)
    st2, dropped = apply_fault_surgery(st, tb)
    assert dropped == pytest.approx(0.0, abs=1e-12)
    q0 = st2[0]
    assert not (q0 * ~tb.slot_ok[:, :, None]).any()
    np.testing.assert_allclose(q0.sum(), st[0].sum(), rtol=1e-12)


# ---------------------------------------------------------------------------
# Run-level semantics
# ---------------------------------------------------------------------------


def test_static_masked_equals_removed_graph_exactly():
    """The exact seam: events=[(0, fs)] on the pristine simulator ==
    simulating fs.apply(g).  Steps must match explicitly — the two
    Simulators derive different default_steps from their diameters."""
    fs = random_faults(G16, k_links=3, seed=1)
    dem = _uniform(G16)
    masked = Simulator(G16, SimConfig(routing="ugal_threshold(1)")).run(
        dem, offered=0.3, steps=120, events=[(0, fs)])
    removed = Simulator(fs.apply(G16),
                        SimConfig(routing="ugal_threshold(1)")).run(
        dem, offered=0.3, steps=120)
    assert masked.theta == pytest.approx(removed.theta, rel=1e-12)
    for key in ("delivered", "accepted", "occupancy", "diverted"):
        np.testing.assert_allclose(masked.history[key],
                                   removed.history[key], atol=1e-12)
    assert masked.faults == fs.label


def test_midrun_fault_dip_and_recovery():
    fs = random_faults(G16, k_links=3, seed=1)
    sim = Simulator(G16, SimConfig(routing="minimal"))
    dem = _uniform(G16)
    ref = degraded_report(G16, "uniform", fs).theta
    run = sim.run(dem, offered=0.7 * ref, steps=240, window=60,
                  events=[(80, fs), (160, FaultSet())])
    d = run.history["delivered"]
    pre = d[60:80].mean()
    assert d[80:95].min() < pre - 1e-6        # reroute transient dips
    assert d[-30:].mean() == pytest.approx(pre, rel=0.02)   # heals
    assert run.residual < 1e-9
    assert run.faults is None                 # final state is pristine
    np.testing.assert_array_equal(run.history["fault_events"], [80, 160])


def test_midrun_router_fault_drops_and_conserves():
    fs = FaultSet(routers=[5])
    sim = Simulator(G16, SimConfig(routing="ugal_threshold(0)"))
    run = sim.run(_uniform(G16), offered=0.3, steps=200, window=50,
                  events=[(70, fs)])
    assert run.dropped > 0
    assert run.residual < 1e-9                # residual includes dropped
    assert run.faults == fs.label
    # theta is measured against the SURVIVING demand of the final state
    degraded = degraded_report(G16, "uniform", fs).theta
    assert run.theta / run.offered == pytest.approx(1.0, abs=0.02) \
        or run.theta <= degraded


def test_static_fault_theta_matches_analytic_below_knee():
    fs = random_faults(G16, k_links=3, seed=1)
    ref = degraded_report(G16, "uniform", fs).theta
    sim = Simulator(G16, SimConfig(routing="minimal"))
    run = sim.run(_uniform(G16), offered=0.9 * ref, steps=240, window=60,
                  events=[(0, fs)])
    assert run.theta / run.offered == pytest.approx(1.0, abs=0.01)
    run = sim.run(_uniform(G16), offered=1.15 * ref, steps=240, window=60,
                  events=[(0, fs)])
    assert run.theta / run.offered < 0.99     # collapses above the knee


# ---------------------------------------------------------------------------
# The knee parity seam (acceptance): static == dynamic within 2.5%
# ---------------------------------------------------------------------------


def _knee_parity(g, steps, event_frac=0.4, seed=0):
    fs = random_faults(g, k_links=2, seed=seed)
    ref = degraded_report(g, "uniform", fs, routing="minimal").theta
    loads = np.array([0.96, 1.05]) * ref
    static = saturation_sweep(g, "uniform", "minimal", loads=loads,
                              refine=2, theta_analytic=ref, steps=steps,
                              events=[(0, fs)])
    dynamic = saturation_sweep(g, "uniform", "minimal", loads=loads,
                               refine=2, theta_analytic=ref, steps=steps,
                               events=[(int(event_frac * steps), fs)])
    return abs(static.theta - dynamic.theta) / static.theta


def test_knee_parity_static_vs_dynamic_torus():
    assert _knee_parity(torus3d_graph(8, 16, 1), steps=648) <= 0.025


@pytest.mark.slow
def test_knee_parity_static_vs_dynamic_pn16():
    # pn16 is ~0.4 s/step (ROADMAP kernel item), so the full bisection
    # lives in the slow tier; BENCH_6 carries the torus seam continuously
    assert _knee_parity(pn_graph(16), steps=120) <= 0.025
