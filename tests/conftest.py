"""Shared test configuration.

``hypothesis`` is an optional dev dependency (requirements-dev.txt).  Four
modules import it at module level; to keep the suite *collectable* on a
bare interpreter we install a minimal stand-in into ``sys.modules`` before
those modules are imported.  Property tests then skip at call time instead
of erroring the whole collection.
"""

from __future__ import annotations

import sys
import types

import pytest

try:  # pragma: no cover - exercised only when hypothesis is installed
    import hypothesis  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Stand-in for strategy objects; tolerates any call/attr/operator."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def map(self, fn):
            return self

        def filter(self, fn):
            return self

    def _given(*_a, **_k):
        def deco(fn):
            def skipper(*_aa, **_kk):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")

            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            # keep pytest marks (e.g. parametrize) applied below @given
            skipper.pytestmark = getattr(fn, "pytestmark", [])
            return skipper

        return deco

    class _Settings:
        """Accepts both ``@settings(...)`` and ``settings.register_profile``."""

        def __init__(self, *a, **k):
            pass

        def __call__(self, fn):
            return fn

        @staticmethod
        def register_profile(*a, **k):
            pass

        @staticmethod
        def load_profile(*a, **k):
            pass

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _Settings
    _hyp.assume = lambda *a, **k: True
    _hyp.note = lambda *a, **k: None
    _hyp.example = lambda *a, **k: (lambda fn: fn)
    _hyp.HealthCheck = _Strategy()
    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy()
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
