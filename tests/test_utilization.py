"""Link-utilization validation: Theorems 3.5/3.9, MMS 8/9, OFT u=1."""

import numpy as np
import pytest

from repro.core import (
    complete_bipartite_graph,
    complete_graph,
    demi_pn_graph,
    hamming_graph,
    mms_graph,
    oft_graph,
    paley_graph,
    pn_graph,
    turan_graph,
    utilization,
)
from repro.core.mms import mms_generator_sets


@pytest.mark.parametrize("q", [3, 4, 5, 7, 8])
def test_theorem_3_9_demi_pn_u(q):
    rep = utilization(demi_pn_graph(q))
    assert abs(rep.u - (2 * q * q + q + 1) / (2 * q * (q + 1))) < 1e-10


@pytest.mark.parametrize("q", [2, 3, 4, 5])
def test_pn_symmetric_u1(q):
    """Theorem 3.5 consequence: G_q symmetric => perfectly balanced."""
    rep = utilization(pn_graph(q))
    assert abs(rep.u - 1.0) < 1e-10
    loads = rep.loads
    assert np.allclose(loads, loads[0])  # every arc carries identical load


@pytest.mark.parametrize("q,expect_moore", [(5, True), (7, False), (9, False),
                                            (11, False), (13, False)])
def test_mms_utilization(q, expect_moore):
    g = mms_graph(q)
    eps = g.meta["eps"]
    assert g.max_degree == (3 * q - eps) // 2
    rep = utilization(g)
    if expect_moore:  # Hoffman–Singleton graph: symmetric Moore graph
        assert abs(rep.u - 1.0) < 1e-10
    else:
        # Section 4.2: u converges to 8/9; all finite cases land within ~8%
        assert 0.80 < rep.u < 0.97
        assert abs(rep.u - 8 / 9) < 0.09


def test_mms_generator_sets_cover():
    for q in [5, 7, 8, 9, 11, 13, 16]:
        x0, x1, eps = mms_generator_sets(q)
        assert len(x0) == (q - eps) // 2
        union = set(x0.tolist()) | set(x1.tolist())
        assert union == set(range(1, q))


@pytest.mark.parametrize("q", [2, 3, 4])
def test_oft_edge_transitive_u1(q):
    rep = utilization(oft_graph(q))
    assert abs(rep.u - 1.0) < 1e-10
    assert rep.kbar == 2.0


def test_symmetric_references_u1():
    for g in [complete_graph(8), complete_bipartite_graph(6),
              hamming_graph(5, 2), paley_graph(13), turan_graph(12, 3)]:
        rep = utilization(g)
        assert abs(rep.u - 1.0) < 1e-10, g.name


def test_loads_conservation():
    """Total arc load equals total (distance-weighted) traffic."""
    g = demi_pn_graph(4)
    rep = utilization(g)
    total = rep.loads.sum()
    n = g.n
    assert abs(total - rep.kbar * n * (n - 1)) < 1e-6


def test_valiant_routing_doubles_load_keeps_u():
    """Valiant randomization [40]: 2x expected arc load, same u, 2x kbar
    (worst-case-traffic guarantee costs half the uniform throughput)."""
    from repro.core.utilization import utilization, valiant_report
    from repro.core import build_topology
    g = build_topology("demi_pn", 9)
    base = utilization(g)
    val = valiant_report(g)
    assert val.u == base.u
    assert val.max_load == pytest.approx(2.0 * base.max_load)
    assert val.kbar == pytest.approx(2.0 * base.kbar)
    # saturation injection halves: a = Δ·u/k̄_eff
    a_min = g.max_degree * base.u / base.kbar
    a_val = g.max_degree * val.u / val.kbar
    assert a_val == pytest.approx(a_min / 2.0)
