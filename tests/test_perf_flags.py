"""§Perf optimization flags: every gated fast path must match the
paper-faithful baseline numerically (the hillclimb must not buy roofline
with wrong answers)."""

import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import perf

import os as _os
SRC_PATH = _os.path.join(_os.path.dirname(_os.path.dirname(_os.path.abspath(__file__))), "src")


@contextlib.contextmanager
def perf_flags(**kw):
    old = {k: getattr(perf.flags(), k) for k in kw}
    perf.set_flags(**kw)
    try:
        yield
    finally:
        perf.set_flags(**old)


def _qkv(b=2, hq=6, hkv=2, sq=64, skv=64, d=32, dtype=jnp.bfloat16, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("window", [None, 24])
def test_gqa_grouped_matches_baseline(window):
    from repro.kernels import ops
    q, k, v = _qkv()
    base = ops.attention(q, k, v, causal=True, window=window, impl="jnp",
                         block_q=32)
    with perf_flags(gqa_grouped=True):
        opt = ops.attention(q, k, v, causal=True, window=window, impl="jnp",
                            block_q=32)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32), atol=2e-2, rtol=2e-2)


def test_prob_bf16_close_to_baseline():
    from repro.kernels import ops
    q, k, v = _qkv(seed=1)
    base = ops.attention(q, k, v, causal=True, impl="jnp", block_q=32)
    with perf_flags(prob_bf16=True, gqa_grouped=True):
        opt = ops.attention(q, k, v, causal=True, impl="jnp", block_q=32)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32), atol=4e-2, rtol=4e-2)


def test_prob_bf16_with_kv_len_ragged_decode():
    from repro.kernels import ops
    q, k, v = _qkv(b=3, sq=1, skv=40, seed=2)
    kv_len = jnp.asarray([5, 17, 40])
    base = ops.attention(q, k, v, causal=False, kv_len=kv_len, impl="jnp")
    with perf_flags(prob_bf16=True, gqa_grouped=True):
        opt = ops.attention(q, k, v, causal=False, kv_len=kv_len, impl="jnp")
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32), atol=4e-2, rtol=4e-2)


def test_bf16_experts_matches_fp32_path():
    from repro.configs import get_arch
    from repro.models import unbox
    from repro.models.moe import init_moe, _global_scatter_path
    cfg = get_arch("granite-moe-3b-a800m").reduced()
    p = unbox(init_moe(cfg, jax.random.key(0)))
    x = jax.random.normal(jax.random.key(1), (64, cfg.d_model), jnp.bfloat16)
    base, aux_b = _global_scatter_path(cfg, p, x)
    with perf_flags(bf16_experts=True):
        opt, aux_o = _global_scatter_path(cfg, p, x)
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(opt, np.float32), atol=4e-2, rtol=6e-2)
    assert float(aux_b) == pytest.approx(float(aux_o), rel=1e-5)


@pytest.mark.slow
def test_microbatch_grad_accumulation_parity():
    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.train.train_step import (TrainStepConfig, init_train_state,
                                        make_train_step)
    cfg = get_arch("smollm-135m").reduced()
    mesh = make_host_mesh(1, 1)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (4, 16), 0,
                                          cfg.vocab)}
    losses = {}
    for mb in (1, 4):
        with perf_flags(microbatch=mb):
            ts = TrainStepConfig()
            step_fn, _ = make_train_step(cfg, mesh, ts, donate=False)
            state = init_train_state(cfg, jax.random.key(0), ts)
            for _ in range(2):
                state, m = step_fn(state, batch)
            losses[mb] = float(np.asarray(m["loss"]))
    # same data, same model; accumulation reorders float adds only
    assert losses[1] == pytest.approx(losses[4], rel=2e-4), losses


@pytest.mark.slow
def test_moe_3d_matches_2d_dispatch():
    """moe_3d regroups tokens per device but must route every token to the
    same experts; with ample capacity (no drops) outputs are identical."""
    import os, subprocess, sys, textwrap, json
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh
        import dataclasses
        from repro import perf
        from repro.configs import get_arch
        from repro.models import unbox
        from repro.models.moe import apply_moe, init_moe

        cfg = get_arch("granite-moe-3b-a800m").reduced()
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        p = unbox(init_moe(cfg, jax.random.key(0)))
        x = jax.random.normal(jax.random.key(1), (4, 16, cfg.d_model),
                              jnp.bfloat16)
        with mesh:
            y2d, aux2d = apply_moe(cfg, p, x, mesh=mesh, impl="a2a")
            perf.set_flags(moe_3d=True)
            y3d, aux3d = apply_moe(cfg, p, x, mesh=mesh, impl="a2a")
        err = float(jnp.max(jnp.abs(y2d.astype(jnp.float32)
                                    - y3d.astype(jnp.float32))))
        print(json.dumps({"err": err, "aux2d": float(aux2d),
                          "aux3d": float(aux3d)}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC_PATH)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_PERF", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["err"] < 0.05, rep
    assert rep["aux2d"] == pytest.approx(rep["aux3d"], rel=1e-4)


def test_dp_over_model_is_sharding_only():
    """dp_over_model only changes layouts; the loss must match the baseline
    bit-for-bit-ish on a mesh whose model axis does not divide the heads."""
    import os, subprocess, sys, textwrap, json
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
        import json
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro import perf
        from repro.configs import get_arch
        from repro.models import build, unbox

        cfg = get_arch("smollm-135m").reduced()   # 4 heads
        mesh = Mesh(np.array(jax.devices()).reshape(2, 3), ("data", "model"))
        bundle = build(cfg)
        params = unbox(bundle.init(jax.random.key(0)))
        batch = {"tokens": jax.random.randint(jax.random.key(1), (6, 32), 0,
                                              cfg.vocab)}
        with mesh:
            base, _ = bundle.loss(params, batch, mesh=mesh)
            perf.set_flags(dp_over_model=True)
            opt, _ = bundle.loss(params, batch, mesh=mesh)
        print(json.dumps({"base": float(base), "opt": float(opt)}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC_PATH)
    env.pop("XLA_FLAGS", None)
    env.pop("REPRO_PERF", None)
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["base"] == pytest.approx(rep["opt"], rel=1e-5), rep
