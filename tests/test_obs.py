"""§Observability (repro.obs): span tracing, the metrics registry, the
simulator's bit-exact conservation counters, the obs=none no-op fast
path, and benchmarks/compare.py's regression gate.

The counter tests are the load-bearing ones: the simulator publishes
its conservation totals from the SAME floats its own residual/alpha
identities consume, so recomputing those identities from the counters
must equal the returned SimRun fields EXACTLY (==, not approx) — on
pn16, on the 8x16 torus, and through a mid-run fault event.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import tracemalloc

import numpy as np
import pytest

from repro import obs
from repro.core import pn_graph, random_faults
from repro.fabric.model import torus3d_graph
from repro.obs import MetricsRegistry, balance_stats
from repro.sim import SimConfig, Simulator

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_PATH = os.path.join(REPO_ROOT, "src")


def _uniform(g):
    d = np.ones((g.n, g.n)) - np.eye(g.n)
    return d / d.sum(axis=1, keepdims=True)


# -- tracing ---------------------------------------------------------------


def test_span_nesting_and_chrome_trace(tmp_path):
    with obs.session(mode="trace") as sess:
        with obs.span("outer.work", n=3):
            with obs.span("inner.work"):
                pass
            with obs.span("inner.work"):
                pass
    assert [e[0] for e in sess.events] == ["inner.work", "inner.work",
                                           "outer.work"]  # close order
    depths = {e[0]: e[4] for e in sess.events}
    assert depths["outer.work"] == 0 and depths["inner.work"] == 1
    summ = sess.span_summary()
    assert summ["inner.work"]["count"] == 2
    assert summ["outer.work"]["total_s"] >= summ["inner.work"]["total_s"]

    path = tmp_path / "trace.json"
    sess.write_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M"                       # process_name metadata
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 3
    outer = next(e for e in xs if e["name"] == "outer.work")
    assert outer["args"] == {"n": 3}
    for e in xs:                                     # Perfetto essentials
        assert {"name", "cat", "ts", "dur", "pid", "tid"} <= set(e)

    jl = tmp_path / "trace.jsonl"
    sess.write_jsonl(str(jl))
    lines = [json.loads(ln) for ln in jl.read_text().splitlines()]
    assert lines[0]["schema"] == "repro.obs/1"
    assert len(lines) == 4


def test_timed_measures_with_obs_off():
    assert obs.current() is None
    with obs.timed("standalone.step") as sp:
        sum(range(1000))
    assert sp.seconds > 0


def test_metrics_mode_records_no_spans():
    with obs.session(mode="metrics") as sess:
        with obs.span("should.be.noop"):
            obs.counter("c").add(2.0)
    assert sess.events == []
    assert sess.metrics.counter("c").value == 2.0


def test_session_modes_validate():
    with pytest.raises(ValueError, match="unknown obs mode"):
        with obs.session(mode="bogus"):
            pass
    with obs.session(mode="none") as sess:
        assert not sess.enabled
        assert sess.snapshot() is None


# -- metrics registry ------------------------------------------------------


def test_registry_kinds_and_mismatch():
    reg = MetricsRegistry()
    reg.counter("a").add(1.5)
    reg.counter("a").add(1.5)                 # get-or-create, same object
    reg.gauge("g").set(7.0)
    reg.histogram("h").observe_many([1.0, 2.0, 3.0])
    reg.series("s").append(1.0)
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("a")
    snap = reg.snapshot()
    assert snap["a"] == {"type": "counter", "value": 3.0}
    assert snap["g"] == {"type": "gauge", "value": 7.0}
    assert snap["h"]["count"] == 3 and snap["h"]["p50"] == 2.0
    assert snap["s"] == {"type": "series", "count": 1, "mean": 1.0,
                         "min": 1.0, "max": 1.0, "last": 1.0}


def test_balance_stats_known_inputs():
    flat = balance_stats(np.ones(100))
    assert flat["gini"] == pytest.approx(0.0, abs=1e-12)
    assert flat["max_over_mean"] == pytest.approx(1.0)
    assert flat["p99_over_mean"] == pytest.approx(1.0)
    # one link carries everything: gini -> (n-1)/n
    onehot = balance_stats([0.0] * 99 + [1.0])
    assert onehot["gini"] == pytest.approx(0.99)
    assert onehot["max_over_mean"] == pytest.approx(100.0)
    assert balance_stats([])["gini"] == 0.0
    assert balance_stats([0.0, 0.0])["max_over_mean"] == 1.0


# -- simulator counters: bit-exact with SimRun -----------------------------


def _counters_match_run(sess, run):
    """Recompute SimRun's residual/alpha identities from the published
    counters; every comparison is EXACT (same floats, same ops)."""
    m = sess.metrics
    inj = m.counter("sim.injected").value
    dlv = m.counter("sim.delivered").value
    acc = m.counter("sim.accepted").value
    div = m.counter("sim.diverted").value
    drop = m.counter("sim.dropped").value
    occ = m.get("sim.final_occupancy").value
    src = m.get("sim.final_src_backlog").value
    assert drop == run.dropped
    assert m.get("sim.residual").value == run.residual
    assert m.get("sim.alpha").value == run.alpha
    assert abs(inj - dlv - occ - src - drop) / max(inj, 1e-30) \
        == run.residual
    assert 1.0 - div / max(acc, 1e-30) == run.alpha
    assert m.get("sim.theta").value == run.theta
    assert run.residual < 1e-9


def test_sim_counters_bit_exact_pn16():
    g = pn_graph(16)
    sim = Simulator(g, SimConfig(routing="ugal_threshold(0)"))
    with obs.session(mode="metrics") as sess:
        run = sim.run(_uniform(g), offered=0.3, steps=120, window=30)
    _counters_match_run(sess, run)
    assert sess.metrics.counter("sim.steps").value == 120.0
    assert sess.metrics.counter("sim.runs").value == 1.0
    # final-state link utilization + balance publish even without series
    snap = sess.snapshot()
    assert snap["metrics"]["sim.link_util_final"]["count"] > 0
    assert 0.0 <= snap["metrics"]["sim.balance.gini"]["value"] < 1.0


def test_sim_counters_bit_exact_torus_with_fault_event():
    g = torus3d_graph(8, 16, 1)
    fs = random_faults(g, k_links=3, seed=1)
    sim = Simulator(g, SimConfig(routing="minimal"))
    with obs.session(mode="metrics") as sess:
        run = sim.run(_uniform(g), offered=0.2, steps=160, window=40,
                      events=[(60, fs)])
    _counters_match_run(sess, run)
    assert sess.metrics.counter("sim.fault_events").value == 1.0


def test_sim_router_fault_drop_counter_exact():
    from repro.core import FaultSet
    g = pn_graph(16)
    sim = Simulator(g, SimConfig(routing="ugal_threshold(0)"))
    with obs.session(mode="metrics") as sess:
        run = sim.run(_uniform(g), offered=0.3, steps=150, window=40,
                      events=[(50, FaultSet(routers=[5]))])
    assert run.dropped > 0
    _counters_match_run(sess, run)


def test_sim_series_capture_under_trace():
    g = pn_graph(16)
    with obs.session(mode="trace") as sess:
        # built inside the session so the sim.build_tables span records
        sim = Simulator(g, SimConfig(routing="ugal_threshold(0)"))
        run = sim.run(_uniform(g), offered=0.3, steps=80, window=20)
    m = sess.metrics
    assert len(m.series("sim.occ_vc0")) == 80
    assert len(m.series("sim.src_backlog")) == 80
    # the per-step occupancy series sums to the history's occupancy
    occ = (np.asarray(m.series("sim.occ_vc0"))
           + np.asarray(m.series("sim.occ_vc1"))
           + np.asarray(m.series("sim.occ_vc2")))
    np.testing.assert_allclose(occ, run.history["occupancy"], rtol=1e-12)
    snap = sess.snapshot()
    assert snap["metrics"]["sim.link_util"]["count"] > 0
    assert snap["metrics"]["sim.dest_stability"]["count"] == g.n
    # uniform demand well below the knee: every dest column is stable
    assert snap["metrics"]["sim.dest_stability.min"]["value"] > 0.9
    names = [e[0] for e in sess.events]
    assert "sim.run" in names and "sim.build_tables" in names


# -- the obs=none fast path ------------------------------------------------


def test_null_span_singleton_and_no_allocation():
    assert obs.current() is None
    assert obs.span("a") is obs.span("b") is obs.NULL_SPAN
    assert obs.counter("x") is obs.gauge("y") is obs.NULL_METRIC
    # the PR 10 hooks share the no-session fast path: no recorder, no
    # watchdog, and emit is a silent no-op
    assert obs.recorder() is None and obs.watchdog() is None
    obs.emit("nobody", listening=True)

    def seam():
        # the exact shape of every instrumented hot-loop seam
        with obs.span("hot.loop", k=1):
            obs.counter("hot.count").add(1.0)
        if obs.recorder() is not None or obs.watchdog() is not None:
            raise AssertionError("no session: hooks must stay None")
        obs.emit("hot.event", k=1)

    seam()  # warm up any lazy caches
    tracemalloc.start()
    before = tracemalloc.take_snapshot()
    for _ in range(200):
        seam()
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    growth = sum(s.size_diff for s in after.compare_to(before, "filename")
                 if s.size_diff > 0)
    # 200 no-op seams must not accumulate memory: a handful of KB covers
    # tracemalloc's own bookkeeping noise, while a real per-call record
    # (one dict + one tuple each) would exceed it several-fold
    assert growth < 8192, f"obs=none seam leaked {growth} B over 200 calls"


def test_perf_flag_obs_default_none():
    from repro.perf import flags
    assert flags().obs == "none"
    with obs.session() as sess:  # mode=None resolves from the flag
        assert not sess.enabled


# -- benchmarks/compare.py regression gate ---------------------------------


def _write_bench(path, seconds, err):
    payload = {"schema_version": 2, "git_rev": "test0000",
               "entries": [{"name": "sim[pn16:ugal]",
                            "seconds": seconds, "max_rel_err": err},
                           {"name": "tables[t2]", "seconds": 0.001}],
               "errors": []}
    path.write_text(json.dumps(payload))


def _compare(argv):
    env = dict(os.environ, PYTHONPATH=SRC_PATH)
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.compare", *argv],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True)


def test_compare_flags_synthetic_regression(tmp_path):
    base, new = tmp_path / "BENCH_a.json", tmp_path / "BENCH_b.json"
    _write_bench(base, seconds=10.0, err=0.01)
    _write_bench(new, seconds=12.0, err=0.01)      # +20% wall
    r = _compare([str(base), str(new), "--wall-pct", "15"])
    assert r.returncode == 1, r.stdout + r.stderr
    assert "sim[pn16:ugal]" in r.stdout and "wall" in r.stdout
    # same regression under a generous budget: passes
    r = _compare([str(base), str(new), "--wall-pct", "50"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_compare_parity_regression_and_floors(tmp_path):
    base, new = tmp_path / "BENCH_a.json", tmp_path / "BENCH_b.json"
    _write_bench(base, seconds=10.0, err=0.01)
    _write_bench(new, seconds=10.0, err=0.05)      # 5x parity drift
    r = _compare([str(base), str(new), "--wall-pct", "500"])
    assert r.returncode == 1
    assert "err" in r.stdout
    # microsecond-entry noise stays under the absolute-seconds floor:
    # tables[t2] doubling from 1 ms to 2 ms must NOT trip the gate
    _write_bench(base, seconds=10.0, err=0.01)
    payload = json.loads(new.read_text())
    payload["entries"][0].update(seconds=10.0, max_rel_err=0.01)
    payload["entries"][1]["seconds"] = 0.002
    new.write_text(json.dumps(payload))
    r = _compare([str(base), str(new), "--wall-pct", "15"])
    assert r.returncode == 0, r.stdout + r.stderr


def test_compare_trajectory_mode(tmp_path):
    _write_bench(tmp_path / "BENCH_1.json", seconds=10.0, err=0.01)
    _write_bench(tmp_path / "BENCH_2.json", seconds=10.5, err=0.01)
    _write_bench(tmp_path / "BENCH_3.json", seconds=30.0, err=0.01)
    r = _compare(["--dir", str(tmp_path), "--wall-pct", "100"])
    assert r.returncode == 1                       # the 10.5 -> 30 hop
    r = _compare(["--dir", str(tmp_path), "--wall-pct", "400"])
    assert r.returncode == 0
    r = _compare(["--dir", str(tmp_path), "--glob", "NOPE_*.json"])
    assert r.returncode == 0 and "nothing to compare" in r.stdout


def test_compare_trajectory_presence_and_err_regression(tmp_path):
    # an entry disappearing mid-trajectory is informational, never a
    # failure (sections come and go across PRs)...
    p1 = {"schema_version": 2,
          "entries": [{"name": "sim[a]", "seconds": 1.0,
                       "max_rel_err": 0.01},
                      {"name": "sim[b]", "seconds": 1.0}],
          "errors": []}
    p2 = {"schema_version": 2,
          "entries": [{"name": "sim[a]", "seconds": 1.0,
                       "max_rel_err": 0.01}],
          "errors": []}
    (tmp_path / "BENCH_1.json").write_text(json.dumps(p1))
    (tmp_path / "BENCH_2.json").write_text(json.dumps(p2))
    r = _compare(["--dir", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "sim[b]" in r.stdout                    # the presence row prints
    # ...but a mid-trajectory parity regression fails on that hop even
    # when wall time is flat and later files stay bad-but-stable
    p3 = dict(p2, entries=[{"name": "sim[a]", "seconds": 1.0,
                            "max_rel_err": 0.2}])
    (tmp_path / "BENCH_3.json").write_text(json.dumps(p3))
    (tmp_path / "BENCH_4.json").write_text(json.dumps(p3))
    r = _compare(["--dir", str(tmp_path), "--wall-pct", "1000"])
    assert r.returncode == 1
    assert "err" in r.stdout


def test_compare_verbose_shows_clean_rows(tmp_path):
    base, new = tmp_path / "BENCH_a.json", tmp_path / "BENCH_b.json"
    _write_bench(base, seconds=10.0, err=0.01)
    _write_bench(new, seconds=10.1, err=0.01)      # within every budget
    r = _compare([str(base), str(new)])
    assert r.returncode == 0
    assert "sim[pn16:ugal]" not in r.stdout        # quiet by default
    r = _compare([str(base), str(new), "-v"])
    assert r.returncode == 0
    assert "sim[pn16:ugal]" in r.stdout            # verbose lists them all


def test_compare_bad_file_fails_loud(tmp_path):
    bad = tmp_path / "BENCH_x.json"
    bad.write_text("{not json")
    good = tmp_path / "BENCH_y.json"
    _write_bench(good, seconds=1.0, err=0.01)
    r = _compare([str(bad), str(good)])
    assert r.returncode == 2
