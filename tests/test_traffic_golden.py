"""Golden-value regression pins: paper Table 2/5 (k̄, u) quantities for
small PN/OFT instances plus the new per-pattern saturation throughputs.

These literals were computed by the parity-tested engines at PR 2 and are
intentionally hardcoded so a future engine refactor (new GEMM order, new
orbit shortcut, resharded sweeps) cannot silently drift the numbers the
paper comparison rests on.  Tolerances are float64 round-off, not physics.
"""

import pytest

from repro.core import (
    demi_pn_graph,
    oft_graph,
    pn_graph,
    saturation_report,
    utilization,
)

ABS = 1e-9

# (builder, N, kbar, u, diameter) — Table 2's measured instances (PN rows
# approach k̄ -> 2.5, u = 1; OFT is the Section-6 u = 1, k̄ = 2 family;
# demi-PN(16) is the Table 5 working size scaled down).
GOLDEN_KBAR_U = [
    (lambda: pn_graph(4), 42, 2.268292682926829, 1.0, 3),
    (lambda: pn_graph(16), 546, 2.438532110091743, 1.0, 3),
    (lambda: oft_graph(3), 39, 2.0, 1.0, 2),
    (lambda: oft_graph(4), 63, 2.0, 1.0, 2),
    (lambda: demi_pn_graph(16), 273, 1.9377289377289377, 0.9724264705882353, 2),
]


@pytest.mark.parametrize("build,n,kbar,u,diam", GOLDEN_KBAR_U)
def test_golden_kbar_u(build, n, kbar, u, diam):
    g = build()
    assert g.n == n
    rep = utilization(g)
    assert rep.kbar == pytest.approx(kbar, abs=ABS)
    assert rep.u == pytest.approx(u, abs=ABS)
    assert rep.diameter == diam


# (graph tag, pattern, routing) -> (theta, u); computed at PR 2 with the
# naive-parity-tested weighted engines.  The tornado rows were recomputed
# at PR 3 when the pattern was corrected to the classic one-directional
# shift(ceil(m/2)-1) (PR 2's shift(m//2) splits both ring directions and
# does not stress a torus at all).
GOLDEN_THETA = {
    ("pn4", "uniform", "minimal"): (2.204301075268817, 1.0),
    ("pn4", "uniform", "valiant"): (1.102150537634408, 1.0),
    ("pn4", "tornado", "minimal"): (0.7142857142857143, 0.3537414965986395),
    ("pn4", "tornado", "valiant"): (1.1021505376344085, 1.0),
    ("pn4", "bit_reversal", "minimal"): (0.7142857142857143, 0.1904761904761905),
    ("pn4", "transpose", "minimal"): (0.5, 0.17142857142857143),
    ("pn4", "random_permutation", "minimal"): (0.45454545454545453,
                                               0.21212121212121213),
    ("pn4", "hot_region", "minimal"): (0.931372549019608, 0.4178921568627451),
    # OFT: bit-reversal/transpose and the one-directional tornado collapse
    # to the single-spine bottleneck (the balanced m//2 shift scored 5.0)
    ("oft4", "uniform", "minimal"): (5.0, 1.0),
    ("oft4", "tornado", "minimal"): (1.0, 0.2),
    ("oft4", "bit_reversal", "minimal"): (1.0, 0.11428571428571428),
    ("oft4", "transpose", "minimal"): (1.0, 0.14285714285714285),
    ("oft4", "uniform", "valiant"): (2.5, 1.0),
    ("oft4", "hot_region", "minimal"): (1.1585365853658536,
                                        0.22916666666666663),
}

_GRAPHS = {"pn4": lambda: pn_graph(4), "oft4": lambda: oft_graph(4)}


@pytest.mark.parametrize("key,expect", sorted(GOLDEN_THETA.items()))
def test_golden_pattern_theta(key, expect):
    tag, pattern, routing = key
    g = _GRAPHS[tag]()
    rep = saturation_report(g, pattern, routing=routing)
    theta, u = expect
    assert rep.theta == pytest.approx(theta, abs=ABS), key
    assert rep.u == pytest.approx(u, abs=ABS), key


def test_golden_uniform_bit_identical_to_arc_loads():
    """D = ones - I through the weighted engines reproduces PR 1's
    arc_loads BIT-identically engine-for-engine: the weighted backward
    coefficient (w + delta)/sigma with w == 1.0 runs the exact float ops
    of the uniform (tm + delta)/sigma path.  (The one exception is the
    unweighted numpy dispatch on bipartite graphs, which takes the
    half-size biadjacency fast path — a different, parity-tested engine.)"""
    import numpy as np
    from repro.core.utilization import arc_loads, arc_loads_weighted
    for g, engines in [(demi_pn_graph(5), ["naive", "csr", "numpy"]),
                       (pn_graph(4), ["naive", "csr"])]:
        u = np.ones((g.n, g.n)) - np.eye(g.n)
        for eng in engines:
            lw, kw, dw = arc_loads_weighted(g, u, engine=eng)
            l0, k0, d0 = arc_loads(g, engine=eng)
            assert np.array_equal(lw, l0), (g.name, eng)
            assert dw == d0
    # bipartite numpy fast path: parity to round-off, not bitwise
    g = pn_graph(4)
    u = np.ones((g.n, g.n)) - np.eye(g.n)
    np.testing.assert_allclose(arc_loads_weighted(g, u, engine="numpy")[0],
                               arc_loads(g, engine="numpy")[0],
                               rtol=1e-12, atol=1e-12)
