"""Traffic-pattern registry and saturation-report semantics: Eq. 1
consistency, Valiant's worst-case guarantee, pattern parsing, and the
fabric-layer wiring (collectives priced under non-uniform load)."""

import numpy as np
import pytest

from repro.core import (
    build_topology,
    make_pattern,
    oft_graph,
    pn_graph,
    saturation_report,
    saturation_sweep,
    utilization,
)
from repro.core.reference import dragonfly_graph
from repro.core.traffic import DEFAULT_SWEEP, PATTERNS, TrafficPattern
from repro.core.utilization import valiant_report
from repro.fabric import collective_time, make_fabric
from repro.fabric.model import FabricModel, torus3d_graph


# ---------------------------------------------------------------------------
# Pattern construction
# ---------------------------------------------------------------------------


def test_make_pattern_specs():
    assert make_pattern("uniform").name == "uniform"
    assert make_pattern("shift(3)").name == "shift(3)"
    assert make_pattern("hot_region(0.25, 4)").name == "hot_region(0.25,4)"
    assert make_pattern("collective(ring-all-reduce)").name == \
        "collective(ring-all-reduce)"
    pat = make_pattern("tornado")
    assert make_pattern(pat) is pat  # pass-through
    with pytest.raises(ValueError, match="unknown traffic pattern"):
        make_pattern("warp-drive")
    with pytest.raises(ValueError, match="unknown collective"):
        make_pattern("collective(gossip)")
    with pytest.raises(ValueError, match="frac"):
        make_pattern("hot_region(1.5)")


def test_registry_covers_issue_patterns():
    for name in ["uniform", "bit_reversal", "transpose", "shift", "tornado",
                 "random_permutation", "hot_region", "collective"]:
        assert name in PATTERNS


@pytest.mark.parametrize("spec", ["bit_reversal", "transpose", "shift(5)",
                                  "tornado", "random_permutation(3)"])
def test_permutation_patterns_send_at_most_one_unit(spec):
    g = torus3d_graph(4, 4, 1)
    d = make_pattern(spec).demand(g)
    assert d.shape == (g.n, g.n)
    assert ((d == 0) | (d == 1)).all()
    assert (d.sum(axis=1) <= 1).all()       # each source sends <= 1 target
    assert (d.sum(axis=0) <= 1).all()       # each target receives <= 1
    assert np.diagonal(d).sum() == 0


def test_bit_reversal_is_involution_on_power_of_two():
    g = torus3d_graph(4, 4, 1)  # 16 ranks
    d = make_pattern("bit_reversal").demand(g)
    perm = np.argmax(d, axis=1)
    moved = d.sum(axis=1) > 0
    assert moved.sum() > 0
    np.testing.assert_array_equal(perm[perm[moved]], np.nonzero(moved)[0])


def test_collective_demand_totals_match_byte_accounting():
    g = torus3d_graph(4, 1, 1)
    n = g.n
    # spread all-gather: each node sends (n-1)/n of bytes_global
    d = make_pattern("collective(all-gather)").demand(g)
    np.testing.assert_allclose(d.sum(axis=1), (n - 1) / n)
    # ring all-reduce moves the same 2(n-1)/n bytes down one arc per source
    r = make_pattern("collective(ring-all-reduce)").demand(g)
    np.testing.assert_allclose(r.sum(axis=1), 2 * (n - 1) / n)
    assert (np.count_nonzero(r, axis=1) == 1).all()


def test_leaf_mask_restricts_patterns():
    g = oft_graph(3)
    leaf = g.meta["leaf_mask"]
    d = make_pattern("tornado").demand(g)  # leaf_mask picked up from meta
    spine = ~leaf
    assert d[spine].sum() == 0 and d[:, spine].sum() == 0
    assert d.sum() > 0


# ---------------------------------------------------------------------------
# saturation_report semantics
# ---------------------------------------------------------------------------


def test_uniform_theta_is_eq1_injection():
    """With demand normalized to 1 unit per source, theta == d̄·u/k̄ (mean
    degree; == the paper's Δ·u/k̄ on regular graphs like PN — demi-PN's
    self-orthogonal points have reduced degree, which Eq. 1's Δ hides)."""
    for g in [pn_graph(4), build_topology("demi_pn", 5)]:
        rep = utilization(g)
        sr = saturation_report(g, "uniform")
        mean_deg = 2.0 * g.num_edges / g.n
        assert sr.theta == pytest.approx(mean_deg * rep.u / rep.kbar, abs=1e-9)
        assert sr.u == pytest.approx(rep.u, abs=1e-9)
        assert sr.kbar_eff == pytest.approx(rep.kbar, abs=1e-9)
        assert sr.diameter == rep.diameter
    # regular case: Eq. 1 exactly
    g = pn_graph(4)
    rep = utilization(g)
    assert saturation_report(g, "uniform").theta == pytest.approx(
        g.max_degree * rep.u / rep.kbar, abs=1e-9)


def test_uniform_valiant_generalizes_valiant_report():
    """The two rank-1 Valiant phases on uniform traffic reproduce the
    analytic valiant_report: 2x loads, same u, 2x k̄, half the theta."""
    g = build_topology("demi_pn", 5)
    base = saturation_report(g, "uniform")
    val = saturation_report(g, "uniform", routing="valiant")
    ref = valiant_report(g)
    assert val.u == pytest.approx(ref.u, abs=1e-9)
    assert val.kbar_eff == pytest.approx(ref.kbar, abs=1e-9)
    assert val.theta == pytest.approx(base.theta / 2.0, abs=1e-9)
    np.testing.assert_allclose(val.loads, 2.0 * base.loads, rtol=1e-9)


def test_valiant_bounds_adversarial_patterns():
    """Valiant's guarantee: theta under ANY pattern stays within the
    uniform two-phase bound, while minimal routing collapses on the
    torus tornado (the paper's balance argument, quantitatively).  The
    2D 8x8 torus is the literature's tornado setting: one-directional
    ring overload that minimal routing cannot spread."""
    g = torus3d_graph(8, 8, 1)
    uni = saturation_report(g, "uniform")
    tor_min = saturation_report(g, "tornado")
    tor_val = saturation_report(g, "tornado", routing="valiant")
    assert tor_min.u < 0.5                       # minimal routing unbalanced
    assert tor_val.u == pytest.approx(1.0, abs=1e-9)  # randomization rebalances
    assert tor_val.theta >= uni.theta / 2.5      # near the uniform/2 guarantee
    assert tor_val.theta > tor_min.theta * 0.9


def test_valiant_permutation_theta_is_exactly_half_uniform():
    """For any fixed-point-free permutation demand (doubly stochastic),
    both Valiant phases are exactly the uniform ensemble, so theta_valiant
    == theta_uniform / 2 whatever permutation the adversary picks — the
    paper's worst-case guarantee, exactly."""
    for g in [pn_graph(4), torus3d_graph(4, 4, 1)]:
        uni = saturation_report(g, "uniform")
        for spec in ["tornado", "shift(1)", "shift(3)"]:  # all derangements
            val = saturation_report(g, spec, routing="valiant")
            assert val.theta == pytest.approx(uni.theta / 2.0, rel=1e-9), spec
            np.testing.assert_allclose(val.loads, 2.0 * uni.loads, rtol=1e-9)


def test_sweep_runs_acceptance_matrix():
    """uniform + >= 4 non-uniform patterns, minimal + valiant, on the
    paper's case-study topologies (small instances for test time)."""
    assert len(DEFAULT_SWEEP) >= 5
    for g in [pn_graph(3), oft_graph(3), torus3d_graph(3, 3, 3),
              dragonfly_graph(2)]:
        reports, summary = saturation_sweep(g)
        assert len(reports) == 2 * len(DEFAULT_SWEEP)
        for rep in reports:
            assert rep.theta > 0 and 0 < rep.u <= 1 + 1e-12
        assert set(summary) == {"minimal", "valiant"}
        thetas = [r.theta for r in reports if r.routing == "minimal"]
        assert summary["minimal"]["min_theta"] == pytest.approx(min(thetas))
        assert summary["minimal"]["worst_pattern"] in [
            r.pattern for r in reports]


def test_saturation_report_rejects_bad_routing():
    with pytest.raises(ValueError, match="routing"):
        saturation_report(pn_graph(2), "uniform", routing="teleport")


def test_custom_pattern_object():
    g = torus3d_graph(4, 1, 1)

    def build(graph, active):
        d = np.zeros((graph.n, graph.n))
        d[active[0], active[-1]] = 2.0
        return d

    rep = saturation_report(g, TrafficPattern("point2point", build))
    assert rep.pattern == "point2point"
    assert rep.theta > 0


# ---------------------------------------------------------------------------
# Fabric wiring
# ---------------------------------------------------------------------------


def test_fabric_pattern_bw_uniform_matches_eq1():
    # regular fabric: theta-based bw == Eq. 1's Δ·u/k̄-based node_uniform_bw
    fab = make_fabric("pn", args=(4,), terminals_per_router=2)
    assert fab.pattern_node_bw("uniform") == pytest.approx(
        fab.node_uniform_bw, rel=1e-9)
    assert fab.pattern_kbar("uniform") == pytest.approx(fab.kbar, abs=1e-9)


def test_fabric_pattern_bw_uniform_consistent_on_dragonfly():
    """Dragonfly's uniform stats are canonical l-g-l (Table 2); the
    pattern path must NOT silently swap in shortest-path routing for
    semantically identical uniform traffic."""
    fab = FabricModel(dragonfly_graph(3))
    assert fab.pattern_node_bw("uniform") == pytest.approx(
        fab.node_uniform_bw, rel=1e-12)
    assert fab.pattern_node_bw("uniform", routing="valiant") == pytest.approx(
        fab.node_uniform_bw / 2.0, rel=1e-12)
    assert fab.pattern_kbar("uniform") == fab.kbar


def test_fabric_pattern_report_cached():
    fab = FabricModel(torus3d_graph(3, 3, 1))
    r1 = fab.pattern_report("tornado")
    r2 = fab.pattern_report("tornado")
    assert r1 is r2
    # ad-hoc TrafficPattern objects must not alias the spec cache by name
    def one_pair(g, active):
        d = np.zeros((g.n, g.n))
        d[active[0], active[1]] = 1.0
        return d

    r3 = fab.pattern_report(TrafficPattern("tornado", one_pair))
    assert r3 is not r1
    assert r3.total_demand != pytest.approx(r1.total_demand)


def test_fabric_pattern_report_large_graph_guard():
    fab = FabricModel(torus3d_graph(3, 3, 1))
    fab.PATTERN_MAX_N = 4
    with pytest.raises(ValueError, match="smaller instance"):
        fab.pattern_report("tornado2")  # never parsed: size guard first


def test_collective_time_under_adversarial_pattern():
    """A collective whose traffic lands bit-reversal-shaped on a torus
    takes longer than at uniform saturation; Valiant routing recovers it
    (minimal theta 0.6 vs valiant ~1.04 on the 4^3 torus)."""
    fab = FabricModel(torus3d_graph(4, 4, 4))
    n, b = fab.graph.n, 1e9
    base = collective_time(fab, "all-reduce", b, n)
    hot = collective_time(fab, "all-reduce", b, n, pattern="bit_reversal")
    val = collective_time(fab, "all-reduce", b, n, pattern="bit_reversal",
                          routing="valiant")
    assert hot.bandwidth_s > base.bandwidth_s
    assert val.bandwidth_s < hot.bandwidth_s
    assert base.total_s == pytest.approx(
        collective_time(fab, "all-reduce", b, n, pattern="uniform").total_s,
        rel=1e-9)
