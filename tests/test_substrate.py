"""Substrate tests: optimizer, compression, checkpointing, elasticity,
data pipeline determinism, fault-injected training."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.data import DataConfig, host_shard_batch, synthetic_batch
from repro.launch.mesh import make_host_mesh
from repro.optim import (AdamWConfig, adamw_init, adamw_update, compress,
                         decompress, ef_compress_grads, ef_init)
from repro.train import (Trainer, TrainerConfig, TrainStepConfig,
                         largest_submesh_shape, latest_step,
                         restore_checkpoint, save_checkpoint)


def test_adamw_decreases_quadratic():
    w = jnp.array([3.0, -2.0, 5.0])
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    state = adamw_init({"w": w}, cfg)
    params = {"w": w}
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(grads, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_bf16_state_close_to_fp32():
    rng = np.random.default_rng(0)
    w0 = jnp.asarray(rng.normal(size=64), jnp.float32)
    outs = {}
    for dt in (jnp.float32, jnp.bfloat16):
        cfg = AdamWConfig(lr=0.01, weight_decay=0.0, state_dtype=dt)
        params = {"w": w0}
        state = adamw_init(params, cfg)
        for i in range(20):
            g = {"w": jnp.sin(params["w"] + i)}
            params, state, _ = adamw_update(g, state, params, cfg)
        outs[str(dt)] = np.asarray(params["w"])
    err = np.abs(outs[str(jnp.float32)] - outs[str(jnp.bfloat16)]).max()
    assert err < 0.02, err


@given(st.integers(1, 6), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_compress_roundtrip_bounded_error(scale_exp, seed):
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(size=(37, 13)) * 10.0**(-scale_exp), jnp.float32)
    codes, scales, pad = compress(g)
    approx = decompress(codes, scales, pad, g.shape)
    # per-block max error <= scale = blockmax/127
    err = np.abs(np.asarray(approx - g))
    assert err.max() <= float(jnp.abs(g).max()) / 127 + 1e-12


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.full((8,), 0.001, jnp.float32)}
    e = ef_init(g)
    total = np.zeros(8)
    for _ in range(50):
        approx, e = ef_compress_grads(g, e)
        total += np.asarray(approx["w"])
    # EF: long-run mean of transmitted approximations == true gradient
    np.testing.assert_allclose(total / 50, 0.001, rtol=0.05)


def test_checkpoint_roundtrip_and_atomicity():
    state = {"params": {"a": np.arange(12.0).reshape(3, 4),
                        "b": np.ones(5, np.int32)},
             "step": np.asarray(7)}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 7, state, n_shards=2)
        save_checkpoint(d, 9, state, n_shards=1)
        assert latest_step(d) == 9
        like = jax.tree.map(lambda x: np.zeros_like(x), state)
        restored, manifest = restore_checkpoint(d, like)
        np.testing.assert_array_equal(restored["params"]["a"],
                                      state["params"]["a"])
        assert manifest["step"] == 9
        # structure mismatch must be rejected
        with pytest.raises(ValueError):
            restore_checkpoint(d, {"params": {"a": np.zeros((3, 4))}})


def test_largest_submesh_keeps_model_axis():
    assert largest_submesh_shape(512, 16) == (2, 16, 16)
    assert largest_submesh_shape(511, 16) == (1, 31, 16)[-2:] or True
    shape = largest_submesh_shape(511, 16)
    assert shape[-1] == 16 and np.prod(shape) <= 511
    shape = largest_submesh_shape(256, 16, prefer_pods=1)
    assert shape == (16, 16)
    with pytest.raises(ValueError):
        largest_submesh_shape(8, 16)


def test_data_pipeline_determinism_and_sharding():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=3)
    b1 = synthetic_batch(cfg, step=5)
    b2 = synthetic_batch(cfg, step=5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = synthetic_batch(cfg, step=6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # host shards tile the global batch exactly
    parts = [host_shard_batch(cfg, 5, h, 4)["tokens"] for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), b1["tokens"])
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 1000).all()


def test_trainer_crash_resume_fault_injection():
    """Kill the trainer at step 7; it must resume from the checkpoint and
    finish with exactly the same data order (pure function of step)."""
    cfg = get_arch("smollm-135m").reduced()
    mesh = make_host_mesh(1, 1)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)
    with tempfile.TemporaryDirectory() as d:
        crashed = {"done": False}

        def fault(step):
            if step == 7 and not crashed["done"]:
                crashed["done"] = True
                return "crash"
            return None

        tr = Trainer(cfg, data, mesh,
                     TrainerConfig(total_steps=10, checkpoint_every=5,
                                   checkpoint_dir=d, log_every=100),
                     fault_hook=fault)
        state = tr.run()
        assert crashed["done"] and tr.restarts == 1
        assert int(np.asarray(state["step"])) == 10
        # reference run without fault reaches the same loss trajectory tail
        with tempfile.TemporaryDirectory() as d2:
            tr2 = Trainer(cfg, data, mesh,
                          TrainerConfig(total_steps=10, checkpoint_every=5,
                                        checkpoint_dir=d2, log_every=100))
            state2 = tr2.run()
        l1 = [s.loss for s in tr.history if s.step == 9]
        l2 = [s.loss for s in tr2.history if s.step == 9]
        np.testing.assert_allclose(l1, l2, rtol=1e-4)


def test_straggler_detection():
    import time
    cfg = get_arch("smollm-135m").reduced()
    mesh = make_host_mesh(1, 1)
    data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=2)

    def fault(step):
        if step == 8:
            time.sleep(1.0)  # inject a stall before the step
        return None

    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(cfg, data, mesh,
                     TrainerConfig(total_steps=10, checkpoint_every=100,
                                   checkpoint_dir=d, log_every=100,
                                   straggler_factor=3.0),
                     fault_hook=fault)
        tr.run()
    assert 8 in tr.straggler_steps, tr.straggler_steps
