"""Cost-model identities (Eqs. 1/2/5) and the Table 4/6 reproduction."""

import numpy as np
import pytest

from repro.core import (
    DirectNetworkSpec,
    cable_split,
    demi_pn_graph,
    dollars_per_node,
    electrical_groups,
    hamming_graph,
    max_terminals_per_router,
    mms_graph,
    moore_bound,
    oft_graph,
    terminals_bound,
    utilization,
    watts_per_node,
)
from repro.core.cost import cost_per_node_generic
from repro.core.moore import generalized_moore_kbar, min_kbar


def test_moore_bound_known_values():
    # Petersen (Δ=3,k=2): 10; Hoffman–Singleton (Δ=7,k=2): 50
    assert moore_bound(3, 2) == 10
    assert moore_bound(7, 2) == 50
    assert moore_bound(57, 2) == 3250


def test_generalized_moore_kbar_monotone():
    # more vertices at the same degree/diameter => larger kbar
    ks = [generalized_moore_kbar(16, 2, n) for n in [100, 150, 200, 257]]
    assert all(a < b for a, b in zip(ks, ks[1:]))
    assert min_kbar(16, 257) == pytest.approx(generalized_moore_kbar(16, 2, 257))


def test_eq2_decomposition():
    # with c_i=c_t=1, c_r=0: C = 1 + kbar/u
    assert cost_per_node_generic(48, 2.0, 1.0) == pytest.approx(3.0)
    assert cost_per_node_generic(48, 2.0, 0.5) == pytest.approx(5.0)


def test_eq5_consistency_with_eq1():
    """Eq (5) is derived from Δ0 = R/(k̄+1); check the algebra numerically."""
    R, k, kbar = 64.0, 2, 1.95
    T = terminals_bound(R, k, kbar)
    delta0 = R / (kbar + 1)
    delta = R - delta0
    N = T / delta0
    # k - kbar ≈ Δ^(k-1)/N  (Eq. 4 rearranged)
    assert (k - kbar) == pytest.approx(delta ** (k - 1) / N, rel=1e-9)


def _table4_spec(g, delta0, kbar, u, name):
    labels = electrical_groups(g, delta0)
    ne, no = cable_split(g, labels)
    return DirectNetworkSpec(
        name=name, terminals=int(round(g.n * delta0)),
        radix=int(round(g.max_degree + delta0)), routers=g.n,
        degree=g.max_degree, terminals_per_router=delta0, kbar=kbar, u=u,
        electrical_cables=ne, optical_cables=no)


def test_table4_hamming_exact():
    g = hamming_graph(22, 2)
    kbar = g.average_distance([0])
    s = _table4_spec(g, 22, kbar, 1.0, "hamming")
    assert s.terminals == 10648 and s.radix == 64 and s.routers == 484
    assert (s.electrical_cables, s.optical_cables) == (5082, 5082)
    assert dollars_per_node(s) == pytest.approx(1145.41, abs=0.05)
    assert watts_per_node(s) == pytest.approx(8.15, abs=0.005)
    assert s.subscription == pytest.approx(1.002, abs=0.001)


def test_table4_demi_pn_27():
    q = 27
    g = demi_pn_graph(q)
    kbar = 2 - (q + 1) / g.n
    u = (2 * q * q + q + 1) / (2 * q * (q + 1))
    s = _table4_spec(g, 14, kbar, u, "demi-pn")
    assert s.terminals == 10598 and s.radix == 42 and s.routers == 757
    assert watts_per_node(s) == pytest.approx(8.40, abs=0.005)
    assert s.subscription == pytest.approx(0.999, abs=0.001)
    # with the PAPER's cable split the $ figure reproduces exactly;
    # our greedy layout finds a denser electrical grouping (cheaper).
    paper = DirectNetworkSpec(**{**s.__dict__, "electrical_cables": 556,
                                 "optical_cables": 10028})
    assert dollars_per_node(paper) == pytest.approx(1282.59, abs=0.05)
    assert dollars_per_node(s) <= 1282.59 + 0.05


def test_table4_mms_19():
    g = mms_graph(19)
    rep = utilization(g)
    s = _table4_spec(g, 13, rep.kbar, rep.u, "mms")
    assert s.terminals == 9386 and s.radix == 42 and s.routers == 722
    assert (s.electrical_cables, s.optical_cables) == (3971, 6498)
    assert dollars_per_node(s) == pytest.approx(1294.51, abs=0.05)
    assert watts_per_node(s) == pytest.approx(9.05, abs=0.005)
    assert s.subscription == pytest.approx(0.991, abs=0.002)


def test_table6_oft_16():
    g = oft_graph(16)
    q = 16
    n = q * q + q + 1
    s = DirectNetworkSpec(
        name="OFT(16)", terminals=2 * (q + 1) * n, radix=2 * (q + 1),
        routers=3 * n, degree=q + 1, terminals_per_router=q + 1, kbar=2.0,
        u=1.0, electrical_cables=0, optical_cables=g.num_edges, indirect=True)
    assert s.terminals == 9282 and s.radix == 34 and s.routers == 819
    assert dollars_per_node(s) == pytest.approx(1282.19, abs=0.05)
    assert watts_per_node(s) == pytest.approx(8.4, abs=0.005)


def test_eq1_bisection_meaning():
    # Δ0 at equality: injected load saturates links exactly
    assert max_terminals_per_router(28, 1.0, 2.0) == pytest.approx(14.0)
