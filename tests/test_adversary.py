"""Adversarial evaluation harness (repro.core.adversary): worst-case
search semantics, PolarFly-style table shape, and the headline claims the
BENCH_3 artifact rests on — UGAL's worst case dominates the pure
routings' everywhere, PN stays flat across permutations while the torus
collapses."""

import numpy as np
import pytest

from repro.core import pn_graph, oft_graph, saturation_report
from repro.core.adversary import (
    DEFAULT_ADVERSARY_PATTERNS,
    DEFAULT_MODELS,
    adversarial_report,
    adversarial_table,
    worst_case,
)
from repro.fabric.model import torus3d_graph


def test_worst_case_finds_registry_minimum():
    g = torus3d_graph(8, 8, 1)
    rep = worst_case(g, "minimal", n_random=4)
    assert rep.routing == "minimal"
    assert rep.worst_pattern in rep.thetas
    assert rep.worst_theta == min(rep.thetas.values())
    # every candidate's theta is reproducible from its spec string
    check = saturation_report(g, rep.worst_pattern)
    assert check.theta == pytest.approx(rep.worst_theta, rel=1e-12)
    # the named battery + 4 sampled permutations were all evaluated
    assert len(rep.thetas) == len(DEFAULT_ADVERSARY_PATTERNS) + 4


def test_worst_case_validates_model_spec():
    with pytest.raises(ValueError, match="unknown routing"):
        worst_case(torus3d_graph(3, 3, 1), "teleport", n_random=0)


def test_adversarial_report_table_shape():
    g = torus3d_graph(4, 4, 1)
    rows, worst = adversarial_report(g, n_random=3, seed=1)
    # one row per (named pattern, model) + one worst_perm row per model
    assert len(rows) == (len(DEFAULT_ADVERSARY_PATTERNS) + 1) * len(DEFAULT_MODELS)
    models = {r["routing"] for r in rows}
    assert models == set(DEFAULT_MODELS)
    for r in rows:
        assert r["theta"] > 0
        if r["routing"] == "ugal":
            assert 0.0 <= r["alpha"] <= 1.0
        if r["pattern"] == "worst_perm":
            assert r["realized_by"].startswith("random_permutation(")
            assert r["searched"] == 3
    # worst summary is the min over named + sampled candidates
    for model in DEFAULT_MODELS:
        cells = [r["theta"] for r in rows if r["routing"] == model]
        assert worst[model]["min_theta"] <= min(cells) + 1e-12


def test_ugal_worst_case_dominates_pure_routings():
    """The adaptive guarantee the bracket models understate: UGAL's
    worst-found theta is at least each pure routing's on every pattern,
    hence also on the worst case."""
    for g in [torus3d_graph(8, 8, 1), pn_graph(3), oft_graph(3)]:
        rows, worst = adversarial_report(g, n_random=3)
        by = {(r["pattern"], r["routing"]): r["theta"] for r in rows}
        for pattern in DEFAULT_ADVERSARY_PATTERNS:
            pure = max(by[(pattern, "minimal")], by[(pattern, "valiant")])
            assert by[(pattern, "ugal")] >= pure - 1e-9, pattern
        assert worst["ugal"]["min_theta"] >= max(
            worst["minimal"]["min_theta"],
            worst["valiant"]["min_theta"]) - 1e-9


def test_pn_flat_torus_collapses_under_permutations():
    """The paper's balance claim, adversarially: minimal-routing theta on
    arc-transitive PN stays within a small band across sampled
    permutations, while the 2D torus's tornado collapses it well below
    its uniform theta."""
    pn = pn_graph(4)
    rep = worst_case(pn, "minimal", n_random=6)
    perm_thetas = [v for k, v in rep.thetas.items()
                   if k.startswith("random_permutation")]
    assert max(perm_thetas) / min(perm_thetas) < 2.5
    torus = torus3d_graph(8, 8, 1)
    uni = saturation_report(torus, "uniform").theta
    tor = worst_case(torus, "minimal", n_random=2)
    assert tor.worst_theta < 0.5 * uni


def test_adversarial_table_runs_multiple_topologies():
    cases = [("torus", torus3d_graph(4, 4, 1)), ("pn3", pn_graph(3))]
    table = adversarial_table(cases, n_random=2,
                              patterns=("uniform", "tornado"))
    assert set(table) == {"torus", "pn3"}
    for name, slab in table.items():
        assert slab["n"] == dict(cases)[name].n
        assert set(slab["worst"]) == set(DEFAULT_MODELS)
