"""End-to-end system tests: the distribution layer (AOT lower/compile with
real collectives on a multi-device host mesh), the dry-run machinery's HLO
accounting, ZeRO-1 numerical parity, the serving engine's continuous
batching, and the fabric layer's paper-consistency.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Heavyweight end-to-end suite (AOT compiles, subprocesses): excluded
# from tier-1 (see pyproject.toml)
pytestmark = pytest.mark.slow

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


# ---------------------------------------------------------------------------
# Multi-device AOT integration (subprocess so we can force 8 host devices)
# ---------------------------------------------------------------------------


MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.train.train_step import (TrainStepConfig, init_train_state,
                                        make_train_step)

    assert len(jax.devices()) == 8
    cfg = get_arch("granite-moe-3b-a800m").reduced()   # MoE: EP on model axis
    mesh = make_host_mesh(2, 4)                        # data=2, model=4
    ts = TrainStepConfig(zero1=True)
    step_fn, specs = make_train_step(cfg, mesh, ts, donate=False)
    state = init_train_state(cfg, jax.random.key(0), ts)
    tokens = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tokens}

    lowered = step_fn.lower(state, batch)
    compiled = lowered.compile()
    hlo = compiled.as_text()
    found = {k: (k in hlo) for k in
             ("all-reduce", "all-gather", "all-to-all", "reduce-scatter")}
    state2, metrics = step_fn(state, batch)
    loss1 = float(np.asarray(metrics["loss"]))
    state3, metrics2 = step_fn(state2, batch)
    loss2 = float(np.asarray(metrics2["loss"]))
    ca = compiled.cost_analysis()   # jax < 0.5 returns [dict], newer a dict
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    print(json.dumps({"collectives": found, "loss1": loss1, "loss2": loss2,
                      "flops": ca.get("flops", -1.0)}))
""")


@pytest.mark.slow
def test_multidevice_aot_train_step_with_collectives():
    """8 host devices, (2,4) mesh, MoE arch with ZeRO-1: compiles, runs,
    loss decreases, and the HLO actually contains the expected collectives
    (TP all-reduce/all-gather; EP all-to-all on the tokens)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", MULTIDEV], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["loss2"] < rep["loss1"], rep
    assert np.isfinite(rep["loss1"]) and np.isfinite(rep["loss2"])
    assert rep["collectives"]["all-reduce"], rep  # TP reductions
    assert rep["flops"] > 0


# ---------------------------------------------------------------------------
# Dry-run HLO accounting
# ---------------------------------------------------------------------------


def _import_dryrun():
    """Import the dry-run module without letting its XLA_FLAGS line leak
    into this (already-initialized) process' environment."""
    saved = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch import dryrun
        return dryrun
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved


def test_collective_bytes_parser():
    dryrun = _import_dryrun()
    hlo = """
  %ag = bf16[8,128]{1,0} all-gather(bf16[1,128]{1,0} %x), replica_groups={}
  %ar.1 = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %tup = (f32[2,4]{1,0}, f32[2,4]{1,0}) all-to-all(f32[2,4]{1,0} %a, f32[2,4]{1,0} %b)
  %cp = u32[16]{0} collective-permute(u32[16]{0} %c)
  %noise = f32[4]{0} add(f32[4]{0} %d, f32[4]{0} %e)
"""
    out = dryrun.collective_bytes(hlo)
    assert out["all-gather"] == 8 * 128 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["all-to-all"] == 2 * (2 * 4 * 4)
    assert out["collective-permute"] == 16 * 4
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_roofline_row_math():
    sys.path.insert(0, REPO)
    try:
        from benchmarks.roofline import PEAK_FLOPS, roofline_row
    finally:
        sys.path.remove(REPO)
    rec = {"arch": "smollm-135m", "shape": "train_4k", "mesh": "16x16",
           "n_devices": 256, "flops": 1e15, "bytes_accessed": 1e13,
           "collective_bytes_per_device": {"total": 1e12},
           "memory": {"argument_bytes": 2**30, "temp_bytes": 2**30}}
    row = roofline_row(rec)
    assert abs(row["t_compute_s"] - 1e15 / PEAK_FLOPS) < 1e-9
    assert row["dominant"] == "collective"  # 20s > 12.2s > 5.1s
    assert 0 < row["useful_ratio"] < 1  # remat makes HLO > model flops
    assert row["hbm_gib"] == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# ZeRO-1 / compression parity on the host mesh
# ---------------------------------------------------------------------------


def test_zero1_single_device_parity():
    """With data-axis size 1 the ZeRO-1 path must be numerically identical
    to the plain path (the sharding constraint is a no-op)."""
    from repro.configs import get_arch
    from repro.launch.mesh import make_host_mesh
    from repro.train.train_step import (TrainStepConfig, init_train_state,
                                        make_train_step)
    cfg = get_arch("smollm-135m").reduced()
    mesh = make_host_mesh(1, 1)
    batch = {"tokens": jax.random.randint(jax.random.key(1), (2, 16), 0,
                                          cfg.vocab)}
    losses = {}
    for z1 in (False, True):
        ts = TrainStepConfig(zero1=z1)
        step_fn, _ = make_train_step(cfg, mesh, ts, donate=False)
        state = init_train_state(cfg, jax.random.key(0), ts)
        for _ in range(2):
            state, m = step_fn(state, batch)
        losses[z1] = float(np.asarray(m["loss"]))
    assert losses[False] == pytest.approx(losses[True], rel=1e-6)


# ---------------------------------------------------------------------------
# Serving engine
# ---------------------------------------------------------------------------


def test_engine_continuous_batching_matches_single():
    """Queue > max_batch requests; every emitted token must be a (near-)
    argmax of an independent solo teacher-forced decode.  Token-identity
    would be flaky: bf16 logits at different batch sizes can flip exact
    argmax ties, so we assert the engine's choice is within tolerance of
    the solo run's max logit instead."""
    from repro.configs import get_arch
    from repro.models import build, unbox
    from repro.serve.engine import Engine, ServeConfig

    cfg = get_arch("smollm-135m").reduced()
    bundle = build(cfg)
    params = unbox(bundle.init(jax.random.key(0)))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=int(rng.integers(3, 10)))
               .astype(np.int32) for _ in range(5)]

    eng = Engine(cfg, params, ServeConfig(max_batch=2, max_len=64))
    rids = [eng.submit(p, max_new=6) for p in prompts]
    batched = eng.run()

    for rid, prompt in zip(rids, prompts):
        toks = batched[rid]
        assert len(toks) == 6
        # solo teacher-forced reference over the engine's own tokens
        logits, cache = bundle.prefill(params, jnp.asarray(prompt[None]),
                                       cache_slots=64)
        lg = np.asarray(logits[0, -1], np.float32)
        for i, t in enumerate(toks):
            assert lg[t] >= lg.max() - 0.05, \
                f"req {rid} step {i}: engine token {t} not near-argmax " \
                f"(gap {lg.max() - lg[t]:.4f})"
            pos = jnp.full((1, 1), len(prompt) + i, jnp.int32)
            logits_d, cache = bundle.decode_step(
                params, cache, jnp.asarray([[t]], jnp.int32), pos)
            lg = np.asarray(logits_d[0, 0], np.float32)


# ---------------------------------------------------------------------------
# Fabric layer vs. the paper
# ---------------------------------------------------------------------------


def test_fabric_collective_model_consistency():
    from repro.fabric.collectives import (allgather_time, allreduce_time,
                                          reducescatter_time)
    from repro.fabric.model import make_fabric
    fab = make_fabric("demi_pn", args=(9,), terminals_per_router=5)
    n, b = 100, 1e9
    ar = allreduce_time(fab, b, n)
    rs = reducescatter_time(fab, b, n)
    ag = allgather_time(fab, b, n)
    assert ar.total_s == pytest.approx(rs.total_s + ag.total_s)
    assert allgather_time(fab, 2 * b, n).bandwidth_s == pytest.approx(
        2 * ag.bandwidth_s)


def test_fabric_planner_prefers_low_kbar_over_u():
    """The paper's core claim, end to end: at ~10k terminals, demi-PN's
    k̄/u beats Slim Fly MMS's, so the planner must rank demi-PN's
    collective time ahead of SF at equal link speed."""
    from repro.fabric import StepProfile, plan
    prof = StepProfile(bytes_by_kind={"all-reduce": 1e9, "all-to-all": 1e8})
    rows = plan(prof, min_terminals=10_000, max_radix=64)
    names = [r["fabric"] for r in rows]
    dpn = next(r for r in rows if r["fabric"].startswith("demi-PN"))
    sf = next(r for r in rows if r["fabric"].startswith("SF-MMS"))
    assert dpn["kbar_over_u"] < sf["kbar_over_u"]
    assert names.index(dpn["fabric"]) < names.index(sf["fabric"])
    # and the paper's Table-4 relation: demi-PN cheaper in W/node than SF
    assert dpn["watts_per_node"] <= sf["watts_per_node"] + 1e-6


def test_torus_fabric_reference_point():
    """A 3D torus (TPU pod) prices collectives sensibly: a 2x bigger torus
    with the same per-link bw has ~same per-node uniform bandwidth."""
    from repro.fabric.model import FabricModel, torus3d_graph
    f1 = FabricModel(torus3d_graph(4, 4, 4))
    f2 = FabricModel(torus3d_graph(8, 4, 4))
    assert f1.node_uniform_bw > 0
    # kbar grows with size, so per-node bw decreases (weak scaling of tori)
    assert f2.node_uniform_bw < f1.node_uniform_bw
