"""Field axioms for GF(q), including hypothesis property tests."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import get_field, is_prime_power, prime_power_decompose

PRIME_POWERS = [2, 3, 4, 5, 7, 8, 9, 11, 13, 16, 17, 19, 23, 25, 27, 32, 49, 64, 81]


@pytest.mark.parametrize("q", PRIME_POWERS)
def test_field_axioms_exhaustive_small(q):
    f = get_field(q)
    a = np.arange(q)
    # additive group
    assert (f.add(a, 0) == a).all()
    assert (f.add(a, f.neg(a)) == 0).all()
    # multiplicative group
    nz = a[1:]
    assert (f.mul(a, 1) == a).all()
    assert (f.mul(nz, f.inv(nz)) == 1).all()
    assert (f.mul(a, 0) == 0).all()
    # commutativity on the full table
    aa, bb = np.meshgrid(a, a)
    assert (f.add(aa, bb) == f.add(bb, aa)).all()
    assert (f.mul(aa, bb) == f.mul(bb, aa)).all()


@pytest.mark.parametrize("q", [4, 8, 9, 16, 25, 27])
def test_associativity_distributivity_sampled(q):
    f = get_field(q)
    rng = np.random.default_rng(0)
    x, y, z = (rng.integers(0, q, size=500) for _ in range(3))
    assert (f.add(f.add(x, y), z) == f.add(x, f.add(y, z))).all()
    assert (f.mul(f.mul(x, y), z) == f.mul(x, f.mul(y, z))).all()
    assert (f.mul(x, f.add(y, z)) == f.add(f.mul(x, y), f.mul(x, z))).all()


@pytest.mark.parametrize("q", [5, 8, 9, 13, 27])
def test_primitive_element_generates(q):
    f = get_field(q)
    xi = f.primitive_element()
    powers = {1}
    cur = 1
    for _ in range(q - 2):
        cur = int(f.mul(cur, xi))
        powers.add(cur)
    assert len(powers) == q - 1
    assert 0 not in powers


@pytest.mark.parametrize("q", [5, 9, 13, 17, 25])
def test_squares_are_half(q):
    # for odd q there are (q-1)/2 nonzero squares
    f = get_field(q)
    assert len(f.squares()) == (q - 1) // 2


@given(st.integers(min_value=2, max_value=2000))
@settings(max_examples=200, deadline=None)
def test_prime_power_decompose_consistent(n):
    pm = prime_power_decompose(n)
    if pm is None:
        assert not is_prime_power(n)
    else:
        p, m = pm
        assert p**m == n
        assert is_prime_power(n)


@given(st.sampled_from([3, 4, 5, 7, 8, 9, 11, 16]), st.data())
@settings(max_examples=100, deadline=None)
def test_field_properties_hypothesis(q, data):
    f = get_field(q)
    x = data.draw(st.integers(0, q - 1))
    y = data.draw(st.integers(0, q - 1))
    # sub is inverse of add
    assert int(f.add(f.sub(x, y), y)) == x
    # Frobenius: (x+y)^p = x^p + y^p in characteristic p
    p = f.p
    assert int(f.pow(f.add(x, y), p)) == int(f.add(f.pow(x, p), f.pow(y, p)))
