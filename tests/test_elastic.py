"""repro.train.elastic: submesh recovery after node loss, survivor
remeshing, and state resharding (shrink-grow round-trips).

The contract (elastic.py): the model axis NEVER changes size (weights
are sharded by it); pods then data absorb the loss.  The multi-device
round-trip runs in a subprocess so the host platform can be forced to 8
devices without leaking XLA_FLAGS into this process (the
test_system idiom)."""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.train.elastic import (largest_submesh_shape, remesh,
                                 reshard_state)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


# ---------------------------------------------------------------------------
# largest_submesh_shape: pure shape arithmetic
# ---------------------------------------------------------------------------


def test_submesh_full_survivor_set():
    assert largest_submesh_shape(16, 4) == (2, 2, 4)
    assert largest_submesh_shape(512, 16) == (2, 16, 16)


def test_submesh_data_axis_absorbs_partial_loss():
    # 16 -> 11 devices: still 2 pods, data shrinks 2 -> 1 (8 used)
    assert largest_submesh_shape(11, 4) == (2, 1, 4)
    assert largest_submesh_shape(15, 4) == (2, 1, 4)


def test_submesh_pod_axis_collapses_before_model():
    # under one pod's worth of survivors: 2-tuple, no pod axis
    assert largest_submesh_shape(7, 4) == (1, 4)
    assert largest_submesh_shape(4, 4) == (1, 4)


def test_submesh_prefer_pods():
    assert largest_submesh_shape(24, 4, prefer_pods=3) == (3, 2, 4)
    assert largest_submesh_shape(24, 4, prefer_pods=1) == (6, 4)


def test_submesh_model_axis_is_inviolable():
    with pytest.raises(ValueError, match="cannot keep model axis"):
        largest_submesh_shape(3, 4)


def test_submesh_monotone_under_loss():
    """Shrinking the survivor set never grows the mesh, and the model
    axis stays fixed — the elasticity invariant, swept."""
    model = 4
    prev = None
    for n in range(64, model - 1, -1):
        shape = largest_submesh_shape(n, model)
        assert shape[-1] == model
        used = int(np.prod(shape))
        assert used <= n
        if prev is not None:
            assert used <= prev
        prev = used


# ---------------------------------------------------------------------------
# remesh / reshard_state on the host platform
# ---------------------------------------------------------------------------


def test_remesh_single_device():
    jax = pytest.importorskip("jax")
    mesh = remesh(jax.devices(), model_axis=1)
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape == {"data": len(jax.devices()), "model": 1} \
        or mesh.shape["model"] == 1


def test_reshard_state_roundtrip_single_device():
    jax = pytest.importorskip("jax")
    from jax.sharding import PartitionSpec as P
    mesh = remesh(jax.devices()[:1], model_axis=1)
    state = {"w": np.arange(12.0).reshape(3, 4), "b": np.ones(4)}
    specs = {"w": P(), "b": P()}
    out = reshard_state(state, mesh, specs)
    np.testing.assert_array_equal(np.asarray(out["w"]), state["w"])
    np.testing.assert_array_equal(np.asarray(out["b"]), state["b"])


SHRINK_GROW = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.train.elastic import (largest_submesh_shape, remesh,
                                     reshard_state)

    devices = jax.devices()
    assert len(devices) == 8
    MODEL = 2
    state = {"w": np.arange(64.0).reshape(8, 8), "step": np.float64(7.0)}
    specs = {"w": P("model", None), "step": P()}

    # full fleet: (2, 2, 2)
    full = remesh(devices, MODEL)
    assert full.axis_names == ("pod", "data", "model")
    st = reshard_state(state, full, specs)

    # two nodes die -> 6 survivors -> (2, 1, 2), data absorbed the loss
    survivors = devices[:6]
    shrunk_shape = largest_submesh_shape(len(survivors), MODEL)
    shrunk = remesh(survivors, MODEL)
    st = reshard_state({k: np.asarray(v) for k, v in st.items()},
                       shrunk, specs)

    # nodes return -> full mesh again; values survive the round trip
    grown = remesh(devices, MODEL)
    st = reshard_state({k: np.asarray(v) for k, v in st.items()},
                       grown, specs)
    ok_w = bool(np.array_equal(np.asarray(st["w"]), state["w"]))
    ok_s = float(np.asarray(st["step"])) == 7.0
    n_shards = len(st["w"].sharding.device_set)
    print(json.dumps({"shrunk_shape": list(shrunk_shape),
                      "shrunk_ndev": int(shrunk.devices.size),
                      "grown_ndev": int(grown.devices.size),
                      "roundtrip_w": ok_w, "roundtrip_step": ok_s,
                      "w_shards": n_shards}))
""")


def test_shrink_grow_roundtrip_multidevice():
    """8 -> 6 -> 8 host devices: the mesh shrinks along pods/data with the
    model axis fixed, and the state survives both reshardings bit-exact."""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SHRINK_GROW], env=env,
                         capture_output=True, text=True, timeout=240)
    assert out.returncode == 0, out.stderr[-3000:]
    rep = json.loads(out.stdout.strip().splitlines()[-1])
    assert rep["shrunk_shape"] == [2, 1, 2]
    assert rep["shrunk_ndev"] == 4 and rep["grown_ndev"] == 8
    assert rep["roundtrip_w"] and rep["roundtrip_step"]
    assert rep["w_shards"] == 8           # P("model", None) spans the mesh
