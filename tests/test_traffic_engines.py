"""Parity of the weighted (traffic-matrix) arc-load engines against the
naive per-source weighted Brandes reference, mirroring test_util_engines.

Every batched engine (numpy dense generic, CSR reduceat, jax) must
reproduce the naive accumulation to float64 round-off on the paper's
families — including bipartite graphs (which the weighted path routes
through the dense generic engine), leaf-restricted indirect networks, and
disconnected inputs."""

import numpy as np
import pytest

from repro.core import (
    Graph,
    demi_pn_graph,
    hypercube_graph,
    oft_graph,
    pn_graph,
)
from repro.core.utilization import arc_loads, arc_loads_weighted
from repro.fabric.model import torus3d_graph

FAMILIES = [
    lambda: pn_graph(4),            # bipartite, diameter 3
    lambda: demi_pn_graph(5),       # dense generic, diameter 2
    lambda: oft_graph(3),           # bipartite indirect (leaf mask in meta)
    lambda: torus3d_graph(3, 3, 3), # the TPU-pod reference point
    lambda: hypercube_graph(4),     # bipartite, sigma > 1, diameter 4
]

ENGINES = ["numpy", "csr", "auto"]


def _rand_demand(n, seed=0, density=0.3):
    rng = np.random.default_rng(seed)
    d = rng.random((n, n)) * (rng.random((n, n)) < density)
    d[0] = 0.0  # a source with no demand at all
    return d


def _perm_demand(n, seed=1):
    rng = np.random.default_rng(seed)
    d = np.zeros((n, n))
    d[np.arange(n), rng.permutation(n)] = rng.random(n) + 0.5
    return d


@pytest.mark.parametrize("build", FAMILIES)
@pytest.mark.parametrize("make_demand", [_rand_demand, _perm_demand])
def test_weighted_parity_vs_naive(build, make_demand):
    g = build()
    d = make_demand(g.n)
    ref_loads, ref_kbar, ref_diam = arc_loads_weighted(g, d, engine="naive")
    for engine in ENGINES:
        loads, kbar, diam = arc_loads_weighted(g, d, engine=engine)
        np.testing.assert_allclose(loads, ref_loads, rtol=1e-9, atol=1e-9,
                                   err_msg=engine)
        assert kbar == pytest.approx(ref_kbar, abs=1e-12), engine
        assert diam == ref_diam, engine


def test_weighted_jax_parity():
    pytest.importorskip("jax")
    for g in [pn_graph(3), torus3d_graph(3, 3, 1)]:
        d = _rand_demand(g.n, seed=3)
        ref = arc_loads_weighted(g, d, engine="naive")
        got = arc_loads_weighted(g, d, engine="jax")
        np.testing.assert_allclose(got[0], ref[0], rtol=1e-9, atol=1e-9)
        assert got[1] == pytest.approx(ref[1], abs=1e-12)
        assert got[2] == ref[2]


def test_weighted_csr_forced_on_bipartite():
    """CSR sweep handles bipartite graphs directly (no half-size blocks)."""
    g = hypercube_graph(3)
    d = _perm_demand(g.n, seed=5)
    ref = arc_loads_weighted(g, d, engine="naive")
    got = arc_loads_weighted(g, d, engine="csr")
    np.testing.assert_allclose(got[0], ref[0], rtol=1e-9, atol=1e-9)


def test_weighted_uniform_matches_unweighted():
    """D = ones - I reproduces arc_loads bit-for-bit modulo float64
    round-off, on direct and leaf-restricted graphs."""
    g = demi_pn_graph(4)
    u = np.ones((g.n, g.n)) - np.eye(g.n)
    lw, kw, dw = arc_loads_weighted(g, u, engine="numpy")
    l0, k0, d0 = arc_loads(g, engine="naive")
    np.testing.assert_allclose(lw, l0, rtol=1e-9, atol=1e-9)
    assert kw == pytest.approx(k0, abs=1e-12)
    assert dw == d0


def test_weighted_leaf_restricted_oft():
    """Demand confined to OFT leaves reproduces the targets_mask path."""
    g = oft_graph(3)
    leaf = g.meta["leaf_mask"]
    d = np.zeros((g.n, g.n))
    d[np.ix_(leaf, leaf)] = 1.0
    lw, kw, dw = arc_loads_weighted(g, d, engine="numpy")
    l0, k0, d0 = arc_loads(g, targets_mask=leaf, engine="naive")
    np.testing.assert_allclose(lw, l0, rtol=1e-9, atol=1e-9)
    assert kw == pytest.approx(k0, abs=1e-12)
    assert dw == d0


def test_weighted_disconnected_raises():
    g = Graph(4, np.array([[0, 1], [2, 3]]))
    d = np.zeros((4, 4))
    d[0, 1] = 1.0
    for engine in ["naive", "numpy", "csr"]:
        with pytest.raises(ValueError, match="disconnected"):
            arc_loads_weighted(g, d, engine=engine)


def test_weighted_input_validation():
    g = pn_graph(2)
    with pytest.raises(ValueError, match="demand must be"):
        arc_loads_weighted(g, np.ones((3, 3)))
    neg = np.ones((g.n, g.n))
    neg[1, 2] = -1.0
    with pytest.raises(ValueError, match="nonnegative"):
        arc_loads_weighted(g, neg)
    with pytest.raises(ValueError, match="all zero"):
        arc_loads_weighted(g, np.eye(g.n))  # diagonal is ignored
    with pytest.raises(ValueError, match="unknown engine"):
        arc_loads_weighted(g, np.ones((g.n, g.n)), engine="warp-drive")


def test_weighted_diagonal_ignored():
    g = demi_pn_graph(3)
    d = _rand_demand(g.n, seed=7)
    d2 = d.copy()
    np.fill_diagonal(d2, 99.0)
    a = arc_loads_weighted(g, d, engine="numpy")
    b = arc_loads_weighted(g, d2, engine="numpy")
    np.testing.assert_array_equal(a[0], b[0])
    assert a[1] == b[1]


def test_weighted_single_pair_is_shortest_path_unit():
    """One unit s->t puts exactly 1/num_paths load on each shortest-path
    arc and nothing anywhere else."""
    g = torus3d_graph(4, 1, 1)  # a 4-ring: two antipodal shortest paths
    d = np.zeros((g.n, g.n))
    d[0, 2] = 1.0
    loads, kbar, diam = arc_loads_weighted(g, d, engine="numpy")
    assert kbar == 2.0 and diam == 2
    assert loads.sum() == pytest.approx(2.0)  # 2 hops of 1 unit
    assert loads.max() == pytest.approx(0.5)  # split over both paths
