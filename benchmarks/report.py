"""Regenerate the data-driven sections of EXPERIMENTS.md from the dry-run
artifacts (idempotent; sections are delimited by HTML markers).

Usage: PYTHONPATH=src python -m benchmarks.report
"""

from __future__ import annotations

import glob
import json
import os
import re

from .roofline import (DRYRUN_DIR, HBM_BW, LINK_BW, PEAK_FLOPS, format_markdown,
                       load_records, roofline_row, roofline_table)

EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")
OPT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "dryrun_opt")


def _inject(text: str, marker: str, payload: str) -> str:
    """Replace '<!-- marker -->' (and any previously injected block that
    follows it up to the next '---' or section marker) with the payload."""
    begin = f"<!-- {marker} -->"
    end = f"<!-- /{marker} -->"
    block = f"{begin}\n{payload}\n{end}"
    if end in text:
        pat = re.compile(re.escape(begin) + r".*?" + re.escape(end), re.S)
        return pat.sub(lambda _: block, text)
    return text.replace(begin, block)


def dryrun_summary() -> str:
    recs = load_records()
    ok = [r for r in recs if r["status"] == "ok"]
    skipped = [r for r in recs if r["status"] == "skipped"]
    err = [r for r in recs if r["status"] == "error"]
    lines = [f"**Status: {len(ok)} cells compiled OK, {len(skipped)} skipped "
             f"(documented long_500k), {len(err)} errors.**", ""]
    lines.append("| arch | shape | mesh | HBM GiB/device (args+temp) | "
                 "compile s | scan reps |")
    lines.append("|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        m = r["memory"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {(m['argument_bytes'] + m['temp_bytes']) / 2**30:.2f} "
            f"| {r['compile_seconds']} | {r.get('scan_reps') or '-'} |")
    return "\n".join(lines)


def perf_ledger() -> str:
    """Baseline vs optimized cells from experiments/dryrun_opt/<tag>/."""
    if not os.path.isdir(OPT_DIR):
        return "(no optimized runs yet)"
    lines = ["| cell | variant | compute s | memory s | collective s | "
             "HBM GiB | MFU raw / kernel-adj |", "|---|---|---|---|---|---|---|"]
    base_by_key = {}
    for r in load_records():
        if r["status"] == "ok":
            base_by_key[(r["arch"], r["shape"], r["mesh"])] = r

    def fmt(tag, r):
        pf = r.get("perf_flags")
        if pf is not None:
            dpom = "dp_over_model" in pf
        else:  # older artifacts: every sm_/mb_ variant ran dp_over_model
            dpom = (any(t in tag for t in ("dpom", "repff", "chunk"))
                    or tag.startswith(("sm_", "mb_")))
        row = roofline_row(r, dpom=dpom)
        m = r["memory"]
        return (f"| {r['arch']} × {r['shape']} ({r['mesh']}) | {tag} "
                f"| {row['t_compute_s']:.3f} | {row['t_memory_s']:.3f} "
                f"| {row['t_collective_s']:.3f} "
                f"| {(m['argument_bytes'] + m['temp_bytes']) / 2**30:.1f} "
                f"| {row['roofline_mfu']:.4f} "
                f"/ {row['roofline_mfu_kernel_adj']:.4f} |")

    seen_base = set()
    for tag_dir in sorted(glob.glob(os.path.join(OPT_DIR, "*"))):
        tag = os.path.basename(tag_dir)
        for path in sorted(glob.glob(os.path.join(tag_dir, "*.json"))):
            with open(path) as f:
                r = json.load(f)
            if r.get("status") != "ok":
                lines.append(f"| {tag} | ERROR | {r.get('error', '')[:60]} |")
                continue
            key = (r["arch"], r["shape"], r["mesh"])
            if key in base_by_key and key not in seen_base:
                seen_base.add(key)
                lines.append(fmt("**baseline**", base_by_key[key]))
            lines.append(fmt(tag, r))
    return "\n".join(lines)


def main():
    with open(EXP) as f:
        text = f.read()
    rows, skipped, errors = roofline_table("16x16")
    text = _inject(text, "ROOFLINE-TABLE", format_markdown(rows))
    text = _inject(text, "DRYRUN-SUMMARY", dryrun_summary())
    text = _inject(text, "PERF-LEDGER", perf_ledger())
    with open(EXP, "w") as f:
        f.write(text)
    print(f"EXPERIMENTS.md updated: {len(rows)} roofline rows, "
          f"{len(skipped)} skipped, {len(errors)} errors")


if __name__ == "__main__":
    main()
