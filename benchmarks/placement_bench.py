"""Placement-pipeline benchmarks: strategy search + fragmentation at pod
scale, under the routing the fabric actually runs.

For each case-study fabric, place an EP-heavy and a DP-heavy job profile
via every registered strategy (linear / group / random / orbit / a short
greedy_swap) and record theta of the compiled (profile, placement) demand
matrix under UGAL — the Eq. 1-comparable per-chip saturation rate — plus
the packed / interleaved / linear fragmentation sweep for two co-tenant
jobs under tornado background.  ``benchmarks.run --only placement``
serializes the table into BENCH_4.json.

``max_rel_err`` per case embeds the pipeline's headline identities:
search must never fall below the linear baseline, packed must dominate
the fragmented interleaved layout, and on pn16 the EP-heavy search must
STRICTLY beat linear (the PR's acceptance claim) — a regression fails the
benchmark run loudly (see run.py --err-budget).
"""

from __future__ import annotations

from repro.core import build_topology, dragonfly_graph, pn_graph
from repro.fabric import StepProfile, fragmentation_sweep, placement_search
from repro.fabric.model import torus3d_graph

PROFILES = {
    "ep_heavy": StepProfile({"all-to-all": 8e9, "all-reduce": 1e9}),
    "dp_heavy": StepProfile({"all-reduce": 6e9, "all-to-all": 5e8}),
}

STRATEGIES = ("linear", "group", "random", "orbit", "greedy_swap(30)")


def placement_cases():
    # (name, graph, mesh, axes, delta0, expect_packed); model-major meshes
    # so the linear baseline splits every TP/EP group across routers.
    # expect_packed=False on the torus: there the fragmentation direction
    # FLIPS — interleaving spreads co-tenants toward the uniform pattern a
    # high-diameter ring fabric likes, while the paper's diameter-2
    # families reward keeping groups on whole routers (docs/placement.md).
    return [
        ("pn16", pn_graph(16), (16, 16), ("model", "data"), 8, True),
        ("demi_pn9", build_topology("demi_pn", 9), (8, 8),
         ("model", "data"), 4, True),
        ("torus3d_444", torus3d_graph(4, 4, 4), (8, 8), ("model", "data"), 4,
         False),
        ("dragonfly3", dragonfly_graph(3), (8, 8), ("model", "data"), 4,
         True),
    ]


def placement_one(g, mesh, axes, delta0, expect_packed=True, routing="ugal"):
    """(rows, summary, max_rel_err) for one fabric.

    rows: one dict per (profile, strategy) with theta/u/alpha plus a
    fragmentation row per layout.  max_rel_err embeds the live pipeline
    identities: on ep_heavy, how far the best NON-linear strategy falls
    below the linear baseline (must be <= 0 on every case here — search
    includes linear, so comparing against the overall best would be
    vacuous); how far packed falls below interleaved where packing is
    expected to win (must be <= 0; the torus flips, see
    placement_cases); and on pn16 specifically, 1.0 unless ep_heavy
    search STRICTLY beats linear.  dp_heavy has no baseline guard:
    linear legitimately WINS there (chip-major fill keeps DP-ring
    neighbours adjacent) — recorded in the summary, not an error."""
    rows = []
    summary = {}
    err = 0.0
    for pname, prof in PROFILES.items():
        out = placement_search(g, mesh, axes, delta0, prof,
                               strategies=STRATEGIES, routing=routing)
        for strat, row in out["rows"].items():
            rows.append({"profile": pname, "strategy": strat,
                         "theta": round(row["theta"], 6),
                         "u": round(row["u"], 6),
                         "alpha": row["alpha"],
                         "max_bytes": row["max_bytes"]})
        lin = out["rows"]["linear"]["theta"]
        best = out["rows"][out["best"]]["theta"]
        best_nonlin = max(r["theta"] for s, r in out["rows"].items()
                          if s != "linear")
        summary[pname] = {"best": out["best"], "best_theta": best,
                          "best_nonlinear_theta": best_nonlin,
                          "linear_theta": lin,
                          "beats_linear": bool(best_nonlin > lin)}
        if pname == "ep_heavy":
            err = max(err, (lin - best_nonlin) / lin)
            if g.name == "PN(16)" and best_nonlin <= lin:
                err = max(err, 1.0)  # the PR's acceptance claim broke

    jobs = [(mesh, axes, PROFILES["ep_heavy"])] * 2
    frag = fragmentation_sweep(g, jobs, delta0, routing=routing,
                               background="tornado")
    for layout, row in frag["layouts"].items():
        rows.append({"profile": "frag_2x_ep_heavy", "strategy": layout,
                     "theta": round(row["theta"], 6),
                     "u": round(row["u"], 6), "alpha": row["alpha"]})
    fl = frag["layouts"]
    summary["fragmentation"] = {"best": frag["best"],
                                "packed_theta": fl["packed"]["theta"],
                                "interleaved_theta": fl["interleaved"]["theta"],
                                "expect_packed": expect_packed}
    if expect_packed:
        err = max(err, (fl["interleaved"]["theta"] - fl["packed"]["theta"])
                  / fl["interleaved"]["theta"])
    return rows, summary, err
