"""§Roofline: three-term roofline per (arch x shape x mesh) cell from the
dry-run artifacts.

    compute   = HLO_FLOPs / (chips x 197e12 FLOP/s)
    memory    = HLO_bytes / (chips x 819e9 B/s)
    collective= collective_bytes / (chips x 50e9 B/s per link)

HLO quantities from compiled.cost_analysis() are PER-DEVICE after SPMD
partitioning (verified in tests), so chips divide out: term = per_device /
peak.  MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (serve).  The
fabric-aware refinement multiplies the collective term by k̄/u of the
chosen interconnect (the paper's Eq. 2 figure).
"""

from __future__ import annotations

import glob
import json
import os

from repro.configs import ARCHS, SHAPES, get_arch
from repro.models import build, model_flops

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / link (ICI)

# Measured B/element streamed by the pure-jnp mirrors for tensors the Pallas
# kernels keep in VMEM on the TPU target (benchmarks: standalone AOT compile
# of ops.attention / ops.ssd fwd and grad at (2,4/2,1024,64) resp.
# (2,1024,8,64,chunk=256); linear q/k/v/o terms subtracted for attention).
ATTN_BPE = {"train": 108.8, "prefill": 36.1}
SSD_BPE = {"train": 172.4, "prefill": 44.7}

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def _vmem_resident_bytes(cfg, shape, *, model_axis=16, data_axis=16,
                         dpom=False) -> float:
    """Per-device bytes the jnp mirror streams through HBM for score/chunk
    tensors that the validated Pallas kernels (flash fwd+bwd, ssd_scan) hold
    in VMEM on the deploy target.  Used for the kernel-adjusted memory term."""
    from repro.models import layer_plan
    if shape.kind == "decode":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    b_loc = b / data_axis
    plan = layer_plan(cfg)
    h = cfg.n_heads
    h_loc = h / model_axis if h % model_axis == 0 else h
    if dpom and h % model_axis and b % (data_axis * model_axis) == 0:
        b_loc, h_loc = b / (data_axis * model_axis), h
    attn_elems = ssd_elems = 0.0
    for kind in plan.kinds:
        if kind == "attn":
            attn_elems += b_loc * h_loc * s * s
        elif kind == "dec_xattn":
            mem = s // cfg.encoder.frame_ratio if cfg.encoder else s
            attn_elems += b_loc * h_loc * s * (s + mem)
        elif kind == "xattn":
            attn_elems += b_loc * h_loc * s * cfg.vision.n_image_tokens
        elif kind == "ssd":
            ssm = cfg.ssm
            hs = (ssm.expand * cfg.d_model) // ssm.head_dim
            hs_loc = hs / model_axis if hs % model_axis == 0 else hs
            bl = b_loc
            if dpom and hs % model_axis and b % (data_axis * model_axis) == 0:
                bl, hs_loc = b / (data_axis * model_axis), hs
            ssd_elems += bl * hs_loc * s * min(ssm.chunk, s)
    if cfg.encoder is not None:
        sf = max(1, s // cfg.encoder.frame_ratio)
        attn_elems += cfg.encoder.n_layers * b_loc * h_loc * sf * sf
    f = "train" if shape.kind == "train" else "prefill"
    return attn_elems * ATTN_BPE[f] + ssd_elems * SSD_BPE[f]


def roofline_row(rec: dict, dpom: bool = False) -> dict:
    arch = rec["arch"]
    shape = SHAPES[rec["shape"]]
    cfg = get_arch(arch)
    flops = rec["flops"]
    bytes_acc = rec["bytes_accessed"]
    coll = rec["collective_bytes_per_device"].get("total", 0.0)
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mflops = model_flops(cfg, tokens, "train" if shape.kind == "train" else "serve")
    hlo_global = flops * rec["n_devices"]
    useful = mflops / hlo_global if hlo_global else 0.0
    bound = max(terms.values())
    # roofline fraction: useful work per second at the bound vs peak
    mfu_bound = (mflops / rec["n_devices"] / bound) / PEAK_FLOPS if bound else 0.0
    # kernel-adjusted memory term: subtract streams the Pallas kernels keep
    # in VMEM on the deploy target (never below the compulsory HBM floor)
    vmem = _vmem_resident_bytes(cfg, shape, dpom=dpom)
    t_mem_adj = max(bytes_acc - vmem, 0.05 * bytes_acc) / HBM_BW
    bound_adj = max(t_compute, t_mem_adj, t_coll)
    mfu_adj = (mflops / rec["n_devices"] / bound_adj) / PEAK_FLOPS \
        if bound_adj else 0.0
    return {
        "arch": arch, "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dominant,
        "model_flops": mflops, "hlo_flops_global": hlo_global,
        "useful_ratio": useful, "roofline_mfu": mfu_bound,
        "t_memory_kernel_adj_s": t_mem_adj, "roofline_mfu_kernel_adj": mfu_adj,
        "hbm_gib": (rec["memory"]["argument_bytes"]
                    + rec["memory"]["temp_bytes"]) / 2**30,
    }


def load_records(mesh: str | None = None):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") not in (mesh, None):
            continue
        recs.append(r)
    return recs


def roofline_table(mesh: str = "16x16"):
    rows, skipped, errors = [], [], []
    for r in load_records():
        if r.get("mesh") != mesh and r["status"] == "ok":
            continue
        if r["status"] == "ok":
            rows.append(roofline_row(r))
        elif r["status"] == "skipped":
            key = (r.get("arch"), r.get("shape"))
            skipped.append({"cell": os.path.basename(str(key)), **r})
        else:
            errors.append(r)
    return rows, skipped, errors


def format_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | mem s (kernel-adj) "
           "| collective s | dominant | MODEL/HLO | MFU | MFU (kernel-adj) |"
           "\n|---|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} "
            f"| {r['t_memory_kernel_adj_s']:.3e} "
            f"| {r['t_collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.3f} | {r['roofline_mfu']:.3f} "
            f"| {r['roofline_mfu_kernel_adj']:.3f} |")
    return "\n".join(lines)
