"""Traffic-pattern saturation benchmarks: the paper's balance claim under
stress.

For each case-study topology (PN, OFT leaf-restricted, 3D torus, dragonfly)
run the default pattern sweep under minimal and Valiant routing and record
theta = 1/max_load (per-node saturation injection, link-equivalents) and
u = mean/max.  The headline number per topology is the worst-case minimal-
routing theta over patterns — the throughput guarantee a scheduler can
count on without randomized routing.
"""

from __future__ import annotations

from repro.core import pn_graph, oft_graph
from repro.core.reference import dragonfly_graph
from repro.core.traffic import DEFAULT_SWEEP, saturation_sweep
from repro.fabric.model import torus3d_graph


def traffic_cases():
    return [
        ("pn16", pn_graph(16)),
        ("oft4", oft_graph(4)),           # leaf-restricted (Section 6)
        ("torus3d_444", torus3d_graph(4, 4, 4)),
        ("dragonfly3", dragonfly_graph(3)),
    ]


def traffic_one(g, patterns=DEFAULT_SWEEP):
    """(per-(pattern, routing) rows, summary) for one topology."""
    reports, summary = saturation_sweep(g, patterns=patterns)
    rows = [{"pattern": r.pattern, "routing": r.routing,
             "theta": round(r.theta, 6), "u": round(r.u, 6),
             "kbar_eff": round(r.kbar_eff, 4)} for r in reports]
    return rows, summary


def traffic_suite(patterns=DEFAULT_SWEEP):
    out = {}
    for name, g in traffic_cases():
        rows, summary = traffic_one(g, patterns)
        out[name] = {"n": g.n, "rows": rows,
                     "min_theta_minimal": summary["minimal"]["min_theta"],
                     "worst_pattern": summary["minimal"]["worst_pattern"],
                     "valiant_guarantee": summary["valiant"]["min_theta"]}
    return out
