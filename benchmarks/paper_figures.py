"""Reproduction of the paper's Figures 5-9 as data series (CSV-friendly).

fig5: MMS vertex count / Moore bound -> 8/9        (Section 4.2)
fig6: MMS link utilization -> 8/9                  (Section 4.2, Fig. 6)
fig7: cost figure k̄/u vs terminals at R<=64, with the Eq.(5) bound curve
fig8: scalability T(R) per family
fig9: PN / demi-PN / SF-MMS k̄ and k̄/u vs terminals
"""

from __future__ import annotations

import numpy as np

from repro.core import (all_realizations, mms_graph, moore_bound,
                        realizations_for_family, terminals_bound, utilization)
from repro.core.gf import is_prime_power

MMS_QS = [5, 7, 8, 9, 11, 13, 16, 17, 19, 23, 25]


def fig5():
    """N(MMS)/M(Δ,2) convergence to 8/9."""
    rows = []
    for q in MMS_QS:
        eps = {1: 1, 3: -1, 0: 0}[q % 4]
        n = 2 * q * q
        delta = (3 * q - eps) // 2
        ratio = n / moore_bound(delta, 2)
        rows.append({"q": q, "N": n, "moore": moore_bound(delta, 2),
                     "ratio": round(ratio, 4)})
    tail = [r["ratio"] for r in rows[-3:]]
    err = abs(np.mean(tail) - 8 / 9) / (8 / 9)
    return rows, err


def fig6():
    """Numeric u(MMS(q)) — converges to 8/9 (u=1 exactly at q=5, the
    Hoffman–Singleton Moore graph)."""
    rows = []
    for q in MMS_QS:
        rep = utilization(mms_graph(q))
        rows.append({"q": q, "N": 2 * q * q, "u": round(rep.u, 4),
                     "kbar": round(rep.kbar, 4)})
    assert abs(rows[0]["u"] - 1.0) < 1e-9  # Hoffman–Singleton
    tail = [r["u"] for r in rows[-4:]]
    err = abs(np.mean(tail) - 8 / 9) / (8 / 9)
    return rows, err


def fig7(max_radix: int = 64):
    """k̄/u vs T for each family at R<=64 + the generalized-Moore bound."""
    rows = []
    for fam, reals in all_realizations(max_radix).items():
        for r in reals:
            if r.terminals < 64:
                continue
            rows.append({"family": fam, "param": r.param,
                         "T": round(r.terminals), "R": round(r.radix, 1),
                         "kbar_over_u": round(r.cost_figure, 4)})
    # bound curve from Eq. (5): for k = 2..4 sweep kbar in (k-1, k)
    bound = []
    for k in (2, 3, 4):
        for kbar in np.linspace(k - 0.98, k - 0.02, 25):
            t = terminals_bound(max_radix, k, kbar)
            bound.append({"family": "bound", "param": k, "T": round(t),
                          "R": max_radix, "kbar_over_u": round(kbar, 4)})
    # validation: every realization sits on/above the bound at its T
    err = 0.0
    bt = np.array([b["T"] for b in bound])
    bk = np.array([b["kbar_over_u"] for b in bound])
    order = np.argsort(bt)
    bt, bk = bt[order], bk[order]
    for r in rows:
        if r["family"] in ("mms", "random"):  # u<1 families sit above
            continue
        i = np.searchsorted(bt, r["T"])
        if i >= len(bt):
            continue
        # generalized-Moore optimality: kbar/u >= bound_kbar(T) - small slack
        if r["kbar_over_u"] < bk[i] - 0.08:
            err = max(err, (bk[i] - r["kbar_over_u"]) / bk[i])
    return rows + bound, err


def fig8(max_radix: int = 64):
    """Scalability T(R): max terminals per family for radix budgets."""
    rows = []
    for fam, reals in all_realizations(max_radix).items():
        best: dict[int, float] = {}
        for r in reals:
            rb = int(np.ceil(r.radix))
            best[rb] = max(best.get(rb, 0), r.terminals)
        for rb in sorted(best):
            rows.append({"family": fam, "R": rb, "T_max": round(best[rb])})
    return rows, 0.0


def fig9(max_radix: int = 64):
    """PN vs demi-PN vs SF-MMS: k̄ and k̄/u vs T (the paper's headline)."""
    rows = []
    for fam in ("pn", "demi_pn", "mms"):
        for r in realizations_for_family(fam, max_radix):
            rows.append({"family": fam, "T": round(r.terminals),
                         "kbar": round(r.kbar, 4),
                         "kbar_over_u": round(r.cost_figure, 4)})
    # headline check: above ~1000 terminals demi-PN has lower k̄/u than MMS
    demi = {r["T"]: r["kbar_over_u"] for r in rows if r["family"] == "demi_pn"}
    mms = [(r["T"], r["kbar_over_u"]) for r in rows if r["family"] == "mms"]
    viol = 0
    for t, c in mms:
        if t < 1000:
            continue
        close = min(demi.items(), key=lambda kv: abs(np.log(kv[0] / t)))
        if close[1] > c + 1e-9:
            viol += 1
    return rows, float(viol)
